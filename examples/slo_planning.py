"""Tail-SLO planning: the mean-optimal plan is not the tail-optimal plan.

The paper's §V observation in planner form: pick the (B, r, scheduler)
configuration that *minimizes mean* job time and you will often buy a lot of
replication -- great for the average, expensive in worker-seconds, and not
what a p99 response-time SLO actually asks for.  ``RedundancyPlanner.plan_slo``
sweeps the (scheduler x pool-width x B) grid on the streaming simulator,
reads the p99 off the on-device response-time histogram, and returns the
*cheapest feasible* candidate instead: the least worker-seconds that still
meets ``SLO(quantile=0.99, target_s=..., arrival_rate=...)``.

This example runs that sweep for the three parametric tails (Exp / SExp /
Pareto) and prints, side by side:

  * the cheapest p99-feasible candidate (what ``plan_slo`` picks), and
  * the mean-optimal candidate on the same grid (what a mean planner picks),

showing that they differ -- mean-optimal buys full replication (r = width)
while the SLO is already met by a leaner plan at a fraction of the cost --
and what happens when the target is impossible (an explicit infeasible
verdict, never a silent fallback).

Run me::

    PYTHONPATH=src python examples/slo_planning.py
"""

from repro.core import SLO, Exponential, Pareto, ShiftedExponential
from repro.core.planner import RedundancyPlanner

N_WORKERS = 8
RATE = 0.05  # Poisson arrivals, jobs per second: light load, tails dominate

# p99 response targets per tail family, sized to be feasible but not trivial
CASES = [
    ("Exp(1)", Exponential(1.0), 12.0),
    ("SExp(0.3, 1)", ShiftedExponential(0.3, 1.0), 15.0),
    ("Pareto(1, 1.5)", Pareto(1.0, 1.5), 60.0),
]


def describe(c) -> str:
    """One line for a candidate: schedule shape, cost, and achieved tail."""
    width = "whole cluster" if c.workers_per_job is None else f"w={c.workers_per_job}"
    return (
        f"{c.scheduler:9s} {width:13s} B={c.n_batches} r={c.replication}  "
        f"p99={c.achieved[0]:8.2f}s  mean={c.mean_response:6.2f}s  "
        f"cost={c.cost_worker_seconds:8.0f} worker-s"
    )


def main() -> None:
    planner = RedundancyPlanner(N_WORKERS)
    print(f"{N_WORKERS} workers, Poisson arrivals at {RATE}/s, p99 SLO per family\n")
    for name, dist, target in CASES:
        slo = SLO(quantile=0.99, target_s=target, arrival_rate=RATE)
        plan = planner.plan_slo([dist], slo, schedulers=("fifo_gang", "packed"))
        mean_opt = min(plan.candidates, key=lambda c: c.mean_response)
        best = plan.best
        print(f"{name}: p99 target {target:.0f}s")
        print(f"  cheapest feasible   {describe(best)}")
        print(f"  mean-optimal        {describe(mean_opt)}")
        same = (best.scheduler, best.workers_per_job, best.n_batches) == (
            mean_opt.scheduler,
            mean_opt.workers_per_job,
            mean_opt.n_batches,
        )
        if not same:
            ratio = mean_opt.cost_worker_seconds / best.cost_worker_seconds
            print(
                f"  -> mean-optimal != tail-optimal: the mean planner pays "
                f"{ratio:.1f}x the worker-seconds for capacity the SLO never asked for\n"
            )
        else:
            print("  -> the two coincide on this grid\n")

    # an impossible target: p99 below the service floor -- plan_slo must say
    # so explicitly rather than quietly returning the least-bad candidate
    slo = SLO(quantile=0.99, target_s=0.05, arrival_rate=RATE)
    plan = planner.plan_slo([Pareto(1.0, 1.5)], slo, schedulers=("fifo_gang", "packed"))
    print(f"Pareto(1, 1.5): p99 target 0.05s -> feasible={plan.feasible}, best={plan.best}")
    try:
        plan.require_feasible()
    except ValueError as ex:
        print(f"  require_feasible() raises: {ex}")


if __name__ == "__main__":
    main()
