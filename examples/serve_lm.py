"""Serve a small model with batched prefill+decode, then plan request
replication from the measured service times (paper §VII methodology).

Run:  PYTHONPATH=src python examples/serve_lm.py
"""
from repro.launch import serve


def main():
    serve.main([
        "--arch", "qwen2-1.5b", "--smoke",
        "--requests", "6", "--prompt-len", "24", "--gen", "8",
        "--workers", "12",
    ])


if __name__ == "__main__":
    main()
