"""Execute a redundancy plan on the live runtime -- and diff it vs the engine.

Everything else in ``examples/`` *simulates* plans.  This one runs one for
real: an asyncio master on a localhost socket, four worker processes (here:
threads, each with its own event loop) executing sleep payloads, with
heartbeats, task leases, replica cancellation -- and then replays the
master's recorded trace through the discrete-event ``ClusterEngine`` to show
the two implementations agree on every decision, bit for bit.

    PYTHONPATH=src python examples/runtime_quickstart.py
"""

import numpy as np

from repro.cluster import Scenario
from repro.cluster.runtime import LiveJob, Runtime, replay_trace
from repro.core.planner import RedundancyPlanner
from repro.core.service_time import Pareto

N_WORKERS = 4

# -- 1. Plan: pick (B, r) for a heavy-tailed workload (closed forms) ---------
dist = Pareto(sigma=0.05, alpha=1.8)  # ~50ms-scale tasks, heavy tail
plan = RedundancyPlanner(N_WORKERS).plan(dist, objective="blend")
print(f"plan: B={plan.n_batches}, r={plan.replication}  (source: {plan.source})")

# -- 2. Execute: run real task payloads under that plan, live ----------------
# Task costs are drawn from the planned-for distribution; the per-worker
# skew stands in for machines whose true speeds the master doesn't know --
# the straggler spread that replica cancellation reclaims.
rng = np.random.default_rng(0)
jobs = [
    LiveJob(
        job_id=i,
        costs=tuple(np.round(dist.sample_np(rng, (8,)), 3)),
        skew=0.6,
        name=f"job-{i}",
    )
    for i in range(3)
]
scenario = Scenario(n_batches=plan.n_batches, cancel_redundant=True)
report = Runtime(N_WORKERS, scenario).run(jobs, timeout_s=60.0)

print(f"\nlive run: {len(report.records)} jobs, {len(report.trace)} trace events")
for r in report.records:
    print(
        f"  job {r.job_id}: start={r.start:.3f}s finish={r.finish:.3f}s "
        f"(B={r.n_batches}, r={r.replication})"
    )

# -- 3. Diff: the engine is the runtime's digital twin -----------------------
twin = replay_trace(report.trace, N_WORKERS, scenario)
print("\naccounting                 live        engine-replay")
for key, live_v in report.accounting().items():
    eng_v = twin.accounting()[key]
    print(f"  {key:<27}{live_v:<12.6g}{eng_v:.6g}")

assert twin.accounting() == report.accounting(), "twin diverged!"
assert [r.finish for r in twin.records] == [r.finish for r in report.records]
print("\nexact: the engine re-derived every dispatch/cancel/finish decision")
print("from the trace and landed on identical accounting and job records.")
