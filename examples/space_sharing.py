"""Space sharing: two job classes with different redundancy compete for workers.

The paper's (B, r) results are *per job* -- but a real cluster runs many jobs
at once, and the whole-cluster FIFO gang (the engine's default) forces every
concurrent job onto one schedule and one plan.  The space-sharing scheduler
lifts that: jobs request disjoint worker subsets (``workers_per_job``) and
each carries its own ``JobPlan`` (B, r, cancellation), so the §V
mean-vs-predictability trade-off becomes a *policy choice per job class*:

  * class A ("interactive"): 4 workers at full diversity B=1 (r=4) -- every
    task replicated everywhere in the subset; slowest mean, tightest tail;
  * class B ("batch"): 4 workers at full parallelism B=4 (r=1) -- fastest
    mean under light tails, widest spread under heavy ones.

Run me::

    PYTHONPATH=src python examples/space_sharing.py
"""

import numpy as np

from repro.cluster import ClusterEngine, Job, JobPlan, Scenario, simulate_epochs
from repro.core.planner import RedundancyPlanner
from repro.core.service_time import Pareto

N, WPJ = 12, 4
DIST = Pareto(sigma=1.0, alpha=1.8)  # heavy-tailed stragglers: §V's regime
PLAN_A = JobPlan(workers=WPJ, n_batches=1)  # full diversity within the subset
PLAN_B = JobPlan(workers=WPJ, n_batches=WPJ)  # full parallelism within it


def one_timeline() -> None:
    """A single seeded run, printed: three jobs run concurrently."""
    jobs = [
        Job(job_id=i, dist=DIST, n_tasks=WPJ, plan=(PLAN_A, PLAN_B)[i % 2])
        for i in range(8)
    ]
    rep = ClusterEngine(N, seed=7, scheduler="packed").run(jobs)
    print(f"one packed timeline on {N} workers ({WPJ} per job):")
    for r in rep.records:
        klass = "A (B=1,r=4)" if r.job_id % 2 == 0 else "B (B=4,r=1)"
        print(
            f"  job {r.job_id} [{klass}]  start {r.start:7.2f}  "
            f"finish {r.finish:7.2f}  response {r.response_time:7.2f}"
        )


def class_stats() -> None:
    """Monte-Carlo per-class response stats, packed vs the gang baseline."""
    n_jobs, reps = 16, 400
    plans = [PLAN_A, PLAN_B]
    arr = np.zeros(n_jobs)
    packed = simulate_epochs(
        DIST, N, None, arr, reps, seed=1, scenario=Scenario(scheduler="packed", job_plans=plans)
    )
    gang = simulate_epochs(DIST, N, None, arr, reps, seed=1)
    print("\nper-class response times (packed space sharing, mean over "
          f"{reps} reps x {n_jobs} jobs):")
    resp = packed.response_times
    for k, name in ((0, "A full diversity"), (1, "B full parallelism")):
        cls = resp[:, k::2].ravel()
        print(
            f"  class {name:<20s} mean {cls.mean():7.2f}  "
            f"p95 {np.percentile(cls, 95):7.2f}  CoV {cls.std() / cls.mean():.2f}"
        )
    print(
        f"  gang baseline (serial)   mean {gang.response_times.mean():7.2f}  "
        f"p95 {np.percentile(gang.response_times, 95):7.2f}"
    )
    print("  -> under heavy tails diversity wins both mean and tail (the")
    print("     paper's §V point), and *both* classes beat the serial gang:")
    print(f"     the cluster runs {N // WPJ} jobs at once instead of one.")
    print("     The mean-vs-predictability tension shows up in the frontier")
    print("     sweep below: B* flips between the mean and cov objectives.")


def plan_against_competition() -> None:
    """Sweep class A's frontier while class B holds its plan fixed."""
    planner = RedundancyPlanner(N, candidates=[1, 2, 4])
    for objective in ("mean", "cov"):
        plan = planner.plan_cluster(
            DIST,
            objective,
            n_reps=256,
            seed=3,
            scenario=Scenario(
                scheduler="packed",
                workers_per_job=WPJ,
                job_plans=[None, PLAN_B],  # even jobs sweep B, odd jobs stay batch
            ),
        )
        print(
            f"\nclass-A plan against fixed class-B competition "
            f"(objective={objective}): B*={plan.n_batches} "
            f"(r={WPJ // min(plan.n_batches, WPJ)} within its {WPJ}-worker subset)"
        )
        frontier = ", ".join(
            f"B={b}: {m:.2f}/{c:.2f}"
            for b, m, c in zip(plan.frontier_B, plan.frontier_mean, plan.frontier_cov)
        )
        print(f"  frontier (mean/CoV): {frontier}")


if __name__ == "__main__":
    one_timeline()
    class_stats()
    plan_against_competition()
