"""End-to-end driver: train a small LM for a few hundred steps on CPU with
replication-planned data sharding, checkpointing, and a mid-run simulated
failure + restart.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300] [--arch qwen2-1.5b]

The model is the reduced (same-family) config; pass --full-scale to print the
full-config training setup that the production launcher would use instead.
"""
import argparse
import shutil

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.core.service_time import ShiftedExponential
from repro.data import PipelineConfig, SyntheticLM
from repro.distributed import rdp
from repro.models import build_model
from repro.optim import AdamW, cosine_with_warmup
from repro.runtime.train import init_state, make_train_step

CKPT = "/tmp/repro_example_train"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()

    # 1. replication plan for a 16-worker budget with moderate straggling
    ctl = rdp.ElasticController(ShiftedExponential(delta=0.05, mu=5.0))
    plan = ctl.initial_plan(16)
    print(f"[plan] B={plan.n_batches} shards x r={plan.replication} replicas")

    cfg = get_config(args.arch, smoke=True)
    model = build_model(cfg)
    pipe = SyntheticLM(PipelineConfig(cfg.vocab_size, args.seq, args.batch, seed=1))
    opt = AdamW(cosine_with_warmup(3e-3, 20, args.steps))
    step_fn = jax.jit(make_train_step(model, opt), donate_argnums=(0,))

    shutil.rmtree(CKPT, ignore_errors=True)
    mgr = CheckpointManager(CKPT, keep=2)
    state = init_state(model, opt, jax.random.key(0))
    ceiling = pipe.bigram_ceiling_loss()
    uniform = float(np.log(cfg.vocab_size))
    print(f"[data] uniform loss {uniform:.3f}, bigram ceiling {ceiling:.3f}")

    crash_at = args.steps // 2
    first_loss = None
    for step in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in pipe.global_batch(step).items()}
        state, metrics = step_fn(state, batch)
        if first_loss is None:
            first_loss = float(metrics["loss"])
        if step % 25 == 0:
            print(f"step {step:4d} loss {float(metrics['loss']):.4f}")
        if step == crash_at:
            mgr.save(step, state)
            print(f"[failure-injection] crash at step {step}; restarting from checkpoint")
            # simulate process restart: rebuild everything from disk
            state = init_state(model, opt, jax.random.key(0))
            restored, s = mgr.restore(jax.eval_shape(lambda: state))
            state = jax.tree.map(jnp.asarray, restored)
            assert s == crash_at
            # a worker also died: replan replication for the survivors
            tr = ctl.on_membership_change(plan, n_healthy=14)
            print(f"[elastic] replanned: B={tr.new_plan.n_batches} r={tr.new_plan.replication}")
    final = float(metrics["loss"])
    print(f"[done] loss {first_loss:.3f} -> {final:.3f} (ceiling {ceiling:.3f})")
    assert final < first_loss * 0.7, "model failed to learn"
    print("OK: model learned the bigram structure through a failure+restart")


if __name__ == "__main__":
    main()
