"""Reproduce the paper's §VII experiment end-to-end on trace-like jobs:

  1. build per-job task service-time datasets (Google-trace stand-ins),
  2. classify tails (Fig 11),
  3. sweep redundancy level B and estimate normalized E[T] (Figs 12-13),
  4. report the planned speedup per job.

Run:  PYTHONPATH=src python examples/straggler_planning.py
"""
import numpy as np

from repro.core import traces
from repro.core.planner import RedundancyPlanner

N = 100  # worker budget, as in the paper's figures


def main():
    jobs = traces.synthetic_google_jobs()
    planner = RedundancyPlanner(N)
    print(f"{'job':8s} {'family':12s} {'tasks':>6s} {'B*':>4s} {'r*':>4s} "
          f"{'E[T]/E[T_B=N]':>14s} {'speedup':>8s}")
    for j in jobs:
        fam = traces.tail_family(j.task_times)
        plan = planner.plan_empirical(j.task_times, "mean", n_mc=6000, seed=0)
        means = np.asarray(plan.frontier_mean)
        base = means[plan.frontier_B.index(N)]  # full parallelism = no redundancy
        best = means.min()
        print(
            f"{j.name:8s} {fam:12s} {j.n_tasks:6d} {plan.n_batches:4d} "
            f"{plan.replication:4d} {best / base:14.3f} {base / best:7.1f}x"
        )
    print("\nheavy-tail jobs gain up to an order of magnitude from planned "
          "replication; exponential-tail jobs with large shifts prefer full "
          "parallelism -- the paper's Figs 12-13 conclusion.")


if __name__ == "__main__":
    main()
