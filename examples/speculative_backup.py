"""Speculative execution from partial progress, on all three substrates.

The paper's replication is *proactive*: pick (B, r) up front and pay the
redundancy on every batch.  The :class:`Speculation` policy is *reactive*:
run with no (or less) redundancy, watch each batch's elapsed time against
``theta x`` the running median of completed siblings, and launch a backup
replica on a free worker only for the laggards.  One policy object drives
all three substrates identically:

  1. the Python event engine (the reference semantics);
  2. the vectorized jax epoch scan (pinned to the engine bit-for-bit by
     ``tests/test_speculation.py``);
  3. the live asyncio runtime, where worker heartbeats double as partial
     progress reports and the recorded trace replays through the engine
     as its digital twin.

The walkthrough ends with the Scenario v2 serialization story: the frozen
spec round-trips through JSON exactly, and ``replace()`` derives variants.

Run:  PYTHONPATH=src python examples/speculative_backup.py
"""
import numpy as np

from repro.cluster import ClusterEngine, Job, Scenario, Speculation, simulate_epochs
from repro.cluster.runtime import LiveJob, Runtime, replay_trace
from repro.core.planner import RedundancyPlanner
from repro.core.service_time import Pareto


def main():
    n_workers = 10
    n_jobs = 40
    dist = Pareto(sigma=1.0, alpha=1.5)  # heavy tail: the straggler regime
    spec = Speculation(interval=0.4, theta=2.0, min_observations=3)

    # --- 1. engine: planned vs speculative vs hybrid -------------------------
    plan = RedundancyPlanner(n_workers).plan(dist, objective="mean")
    variants = {
        "no redundancy": (n_workers, None),
        "planned      ": (plan.n_batches, None),
        "speculative  ": (n_workers, spec),
        "hybrid       ": (plan.n_batches, spec),
    }
    base = None
    for label, (b, sp) in variants.items():
        rep = ClusterEngine(
            n_workers, seed=0, n_batches=b, cancel_redundant=True, speculation=sp
        ).run([Job(job_id=i, dist=dist, n_tasks=n_workers) for i in range(n_jobs)])
        mean_t = float(rep.compute_times.mean())
        base = base or mean_t
        print(
            f"[eng ] {label} B={b:2d}: mean job time {mean_t:6.2f} "
            f"(x{base / mean_t:.2f} vs baseline), "
            f"{rep.n_speculative or 0} backups, "
            f"{rep.worker_seconds:.0f} worker-seconds"
        )

    # --- 2. the same policy on the jax epoch scan ----------------------------
    sc = Scenario(speculation=spec, cancel_redundant=True)
    rep = simulate_epochs(
        dist, n_workers, n_workers, np.zeros(n_jobs), n_reps=200, seed=0, scenario=sc
    )
    t = rep.compute_times
    print(
        f"[scan] 200 Monte-Carlo reps in one device call: mean job time "
        f"{t[np.isfinite(t)].mean():.2f}, "
        f"{rep.n_speculative.mean():.1f} backups per rep "
        f"(engine-exact semantics; see tests/test_speculation.py)"
    )

    # --- 3. live runtime: backups from real partial progress ----------------
    live_sc = Scenario(
        n_batches=3, cancel_redundant=True, speculation=Speculation(interval=0.12, theta=2.0)
    )
    # worker 2's skew makes batch 2 a genuine straggler; its heartbeats carry
    # the partial-progress evidence the master requires before backing it up
    report = Runtime(3, live_sc).run([LiveJob(job_id=0, costs=(0.15, 0.15, 1.0), skew=0.8)])
    acct = report.accounting()
    print(
        f"[live] 1 job on 3 workers: {acct['n_speculative']} speculative "
        f"launch(es), {acct['cancelled_seconds_saved']:.2f}s reclaimed by "
        f"cancelling the overtaken original"
    )
    # the trace alone is replayable: its first event embeds the Scenario, and
    # each speculative launch stamp replays as a scripted speculation epoch
    twin = replay_trace(report.trace)
    assert twin.accounting() == acct  # bit-for-bit digital twin
    print("[live] replay_trace(trace) == live accounting, bit for bit")

    # --- 4. Scenario v2: exact JSON round-trip + replace() -------------------
    blob = live_sc.to_json()
    assert Scenario.from_json(blob) == live_sc  # every field, floats bit-exact
    hotter = live_sc.replace(speculation=Speculation(interval=0.06, theta=1.5))
    print(
        f"[spec] Scenario round-trips through {len(blob)} bytes of JSON; "
        f"replace() derives variants (theta {live_sc.speculation.theta} -> "
        f"{hotter.speculation.theta}) without mutating the frozen original"
    )


if __name__ == "__main__":
    main()
