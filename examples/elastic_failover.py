"""Elastic failover on the event-driven cluster engine.

The walkthrough closes the planner -> engine -> replanner loop:

  1. plan (B, r) for a heavy-tail workload from the closed forms;
  2. execute a stream of jobs on :class:`ClusterEngine` with worker
     fail/join churn -- dead replicas are rescued, coverage never breaks;
  3. the :class:`OnlineReplanner` refits the service-time model from the
     engine's observed task times and re-picks (B, r) mid-stream;
  4. the mesh-level view (``repro.distributed.rdp``) shows how the final
     plan maps onto a ("replica", "shard") device-mesh factorization;
  5. the same churned + heterogeneous + replanning scenario replayed on the
     vectorized jax epoch scan -- hundreds of Monte-Carlo reps in one device
     call, and a whole-frontier churned ``plan_cluster`` sweep that used to
     require one Python event loop per candidate.

Run:  PYTHONPATH=src python examples/elastic_failover.py
"""
import numpy as np

from repro.cluster import (
    ChurnProcess,
    ClusterEngine,
    Job,
    OnlineReplanner,
    ReplanConfig,
    Scenario,
    simulate_epochs,
)
from repro.core.planner import RedundancyPlanner
from repro.core.service_time import Pareto
from repro.distributed import rdp


def main():
    n_workers = 16
    dist = Pareto(sigma=1.0, alpha=1.8)  # heavy-tail step times

    # --- 1. plan from the closed forms --------------------------------------
    plan = RedundancyPlanner(n_workers).plan(dist, objective="mean")
    print(
        f"[plan] N={n_workers}: B={plan.n_batches} shards x r={plan.replication} "
        f"replicas (predicted E[T]={plan.predicted_mean:.2f})"
    )

    # --- 2. execute under churn ---------------------------------------------
    controller = OnlineReplanner(
        n_workers, window=512, refit_every=128, min_observations=96, initial_plan=plan
    )
    engine = ClusterEngine(
        n_workers,
        seed=42,
        cancel_redundant=True,
        churn=ChurnProcess(fail_rate=0.02, mean_downtime=3.0),
        controller=controller,
    )
    jobs = [Job(job_id=i, dist=dist, n_tasks=n_workers) for i in range(40)]
    report = engine.run(jobs)

    t = report.compute_times
    print(
        f"[run ] {len(report.records)} jobs, {report.n_worker_failures} worker failures, "
        f"{report.n_replicas_rescued} replicas rescued, all completed: "
        f"{bool(np.isfinite(t).all())}"
    )
    print(
        f"[run ] mean job time {t[np.isfinite(t)].mean():.2f}, "
        f"{report.cancelled_seconds_saved:.0f} worker-seconds reclaimed by cancellation"
    )

    # --- 3. the replanner refit from observed task times ---------------------
    final = controller.current
    print(
        f"[ctl ] {report.n_replans} replan(s) from "
        f"{len(controller.observations)} observed task times: "
        f"B {plan.n_batches} -> {final.n_batches} ({final.source})"
    )

    # --- 4. mesh view: plan -> ("replica", "shard") factorization ------------
    cov = rdp.surviving_coverage(final, [True] * final.n_workers)
    print(
        f"[mesh] final plan factorizes the data axis as "
        f"(replica={final.replication}, shard={final.n_batches}); "
        f"replicas per shard: {cov['replicas_per_shard']}"
    )
    # --- 5. the same dynamics, vectorized: the jax epoch scan -----------------
    rep = simulate_epochs(
        dist,
        n_workers,
        plan.n_batches,
        np.zeros(40),
        n_reps=200,
        seed=42,
        scenario=Scenario(
            cancel_redundant=True,
            churn=ChurnProcess(fail_rate=0.02, mean_downtime=3.0),
            replan=ReplanConfig(window=512, refit_every=128, min_observations=96),
        ),
    )
    t = rep.compute_times
    print(
        f"[scan] 200 Monte-Carlo reps of the same churned scenario in one "
        f"device call: mean job time {t[np.isfinite(t)].mean():.2f}, "
        f"{rep.n_worker_failures.mean():.1f} failures and "
        f"{rep.n_replicas_rescued.mean():.1f} rescues per rep, "
        f"{rep.n_replans.mean():.1f} replans"
    )
    hetero = RedundancyPlanner(n_workers).plan_cluster(
        dist,
        n_reps=400,
        seed=7,
        scenario=Scenario(
            churn=ChurnProcess(fail_rate=0.02, mean_downtime=3.0),
            speeds=tuple(1.0 + 0.5 * (i % 3) for i in range(n_workers)),
        ),
    )
    print(
        f"[scan] churned + heterogeneous frontier sweep on jax "
        f"({hetero.source}): B={hetero.n_batches} x r={hetero.replication}"
    )
    print(
        "\nCheckpoint restore across mesh shapes is exercised in "
        "tests/test_distributed_multidev.py::test_checkpoint_cross_mesh_restore."
    )


if __name__ == "__main__":
    main()
