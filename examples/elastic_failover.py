"""Elastic failover walkthrough: plan -> fail workers -> coverage check ->
replan -> cross-mesh checkpoint restore semantics.

Run:  PYTHONPATH=src python examples/elastic_failover.py
"""
import numpy as np

from repro.core.planner import RedundancyPlanner
from repro.core.service_time import Pareto
from repro.distributed import rdp


def main():
    dist = Pareto(sigma=1.0, alpha=1.8)  # heavy-tail step times
    ctl = rdp.ElasticController(dist, objective="mean")

    plan = ctl.initial_plan(16)
    print(f"[t0] plan for N=16: B={plan.n_batches} shards x r={plan.replication} replicas"
          f" (predicted E[step]={plan.predicted_mean:.2f})")

    # --- two workers from different replica groups die -----------------------
    healthy = [True] * 16
    healthy[3] = healthy[12] = False  # shards 3%B and 12%B (distinct groups)
    cov = rdp.surviving_coverage(plan, healthy)
    print(f"[t1] workers 3,12 down -> shards still covered: {cov['covered']} "
          f"(replicas per shard: {cov['replicas_per_shard']})")
    assert cov["covered"], "replication absorbed the failures: no shard lost"

    # --- a full replica group dies: coverage breaks, controller replans ------
    for w in range(16):
        if w % plan.n_batches == 2:
            healthy[w] = False
    cov = rdp.surviving_coverage(plan, healthy)
    print(f"[t2] shard-2 group down -> covered: {cov['covered']} "
          f"(lost shards: {cov['lost_shards']})")
    n_healthy = int(np.sum(healthy))
    tr = ctl.on_membership_change(plan, n_healthy=n_healthy)
    print(f"[t3] replanned for N={n_healthy}: B={tr.new_plan.n_batches} x "
          f"r={tr.new_plan.replication} ({tr.reason}); mesh {tr.mesh_change[0]} -> "
          f"{tr.mesh_change[1]}")

    # --- straggler onset detected from observed step times -------------------
    rng = np.random.default_rng(0)
    heavy_steps = 1.0 * rng.uniform(size=3000) ** (-1 / 1.2)
    tr2 = ctl.on_observed_step_times(tr.new_plan, heavy_steps)
    if tr2:
        print(f"[t4] drift detected: B {tr.new_plan.n_batches} -> {tr2.new_plan.n_batches} "
              f"(more replication for the heavier tail)")
    print("\nCheckpoint restore across mesh shapes is exercised in "
          "tests/test_distributed_multidev.py::test_checkpoint_cross_mesh_restore; "
          "data needs no migration (counter-deterministic pipeline).")


if __name__ == "__main__":
    main()
