"""Chaos-test the live runtime -- kill a worker, crash the master, recover.

``runtime_quickstart.py`` shows a clean run agreeing with its engine replay.
This example makes the same claim under fire:

1. a ``FaultPlan`` on the ``Scenario`` injects a scheduled worker kill, a
   worker slowdown, and a mid-task payload exception (retried with capped
   exponential backoff under the ``Retry`` policy);
2. halfway through, the master itself "crashes" -- torn sockets, no
   cleanup, a write-ahead journal that ends mid-run;
3. ``RuntimeMaster.recover`` rebuilds queued jobs, in-flight leases, retry
   timers, and accounting from that journal and resumes with fresh workers;
4. the finished journal -- kill, retries, crash, and recovery as ONE trace
   -- replays through the discrete-event engine bit-for-bit.

    PYTHONPATH=src python examples/chaos_recovery.py
"""

import asyncio

from repro.cluster import FaultPlan, Retry, Scenario
from repro.cluster.runtime import (
    LiveJob,
    RuntimeMaster,
    read_journal,
    replay_trace,
    spawn_worker_thread,
    trace_accounting,
)

N_WORKERS = 3
JOURNAL = "chaos_recovery_journal.jsonl"

# -- 1. A scenario with a fault plan and a retry policy ----------------------
# Everything is plain data on the frozen Scenario, so the whole chaos
# experiment serializes (and lands in the journal's first record, which is
# how recovery knows what it is resuming).
scenario = Scenario(
    n_batches=3,
    retry=Retry(max_attempts=2, backoff_s=0.05, max_backoff_s=0.2),
    faults=FaultPlan(
        seed=0,
        kills=((0, 0.35),),  # tear worker 0's socket 0.35s in
        slowdowns=((1, 0.0, 2.0),),  # worker 1 runs at half speed throughout
        payload_errors=((0, 1, 1),),  # job 0 batch 1: first attempt raises
    ),
)
jobs = [
    LiveJob(job_id=0, costs=(0.5, 0.5, 0.5), name="chaotic"),
    LiveJob(job_id=1, costs=(0.6, 0.6, 0.6), arrival=0.05, name="later"),
]


async def join_threads(threads):
    # join worker threads off the event loop so socket-close callbacks
    # (which deliver the EOFs the workers exit on) keep running
    loop = asyncio.get_running_loop()
    for t in threads:
        await loop.run_in_executor(None, t.join, 10.0)


# -- 2. Run until job 1 is in flight, then kill the master -------------------
async def phase_one() -> None:
    master = RuntimeMaster(N_WORKERS, scenario, journal=JOURNAL)
    port = await master.start()
    threads = [spawn_worker_thread(master.host, port) for _ in range(N_WORKERS)]
    await master.wait_for_workers()
    run_task = asyncio.ensure_future(master.run(jobs, timeout_s=60.0))
    while not any(e["ev"] == "dispatch" and e["job"] == 1 for e in master.recorder.events):
        await asyncio.sleep(0.01)
    run_task.cancel()
    try:
        await run_task
    except asyncio.CancelledError:
        pass
    await master.crash()  # kill -9 stand-in: no shutdown frames, no flush
    await join_threads(threads)
    print(
        f"phase 1: master crashed with {len(master.recorder.events)} journaled "
        f"events; job 1 in flight, job 0's retried batch "
        f"{'done' if master.records else 'pending'}"
    )


# -- 3. Recover from the journal and finish the run --------------------------
async def phase_two():
    master = RuntimeMaster.recover(JOURNAL)
    port = await master.start()
    threads = [spawn_worker_thread(master.host, port) for _ in range(N_WORKERS)]
    report = await master.resume(timeout_s=60.0)
    await master.close()
    await join_threads(threads)
    return report


asyncio.run(phase_one())
report = asyncio.run(phase_two())

print(f"phase 2: recovered and finished {len(report.records)} jobs")
for r in sorted(report.records, key=lambda rec: rec.job_id):
    print(f"  job {r.job_id} ({r.name}): start={r.start:.3f}s finish={r.finish:.3f}s")

# -- 4. One journal, one exact replay ----------------------------------------
events = read_journal(JOURNAL)
marks = [e["ev"] for e in events]
print(
    f"\njournal: {len(events)} events -- {marks.count('chaos')} chaos, "
    f"{marks.count('task_fail')} task_fail, {marks.count('retry')} retry, "
    f"{marks.count('fail')} worker-fail, {marks.count('recover')} recover"
)

twin = replay_trace(events)
print("\naccounting                 live        engine-replay")
for key, live_v in report.accounting().items():
    eng_v = twin.accounting()[key]
    print(f"  {key:<27}{live_v:<12.6g}{eng_v:.6g}")

assert twin.accounting() == report.accounting() == trace_accounting(events)
assert [r.finish for r in twin.records] == [
    r.finish for r in sorted(report.records, key=lambda rec: rec.job_id)
]
print("\nexact: the engine re-derived the kill, the retries, the crash, and")
print("the recovery from the journal and landed on identical accounting.")
