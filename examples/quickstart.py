"""Quickstart: plan replication for a workload and check it against MC.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.core import analysis, simulator
from repro.core.planner import RedundancyPlanner, fit_service_time
from repro.core.service_time import Exponential, Pareto, ShiftedExponential


def main():
    n = 24  # worker budget

    print("=== 1. closed-form planning (paper §VI) ===")
    for dist, label in [
        (Exponential(mu=2.0), "exponential tasks (memoryless)"),
        (ShiftedExponential(delta=0.5, mu=2.0), "shifted-exp tasks (deterministic floor)"),
        (Pareto(sigma=1.0, alpha=1.5), "pareto tasks (heavy tail)"),
    ]:
        planner = RedundancyPlanner(n)
        pm = planner.plan(dist, "mean")
        pc = planner.plan(dist, "cov")
        print(
            f"{label:42s} B*(mean)={pm.n_batches:3d} (r={pm.replication}) "
            f"B*(CoV)={pc.n_batches:3d} -- the paper's avg-vs-predictability tradeoff"
        )

    print("\n=== 2. Monte-Carlo check of the chosen plan ===")
    dist = Pareto(sigma=1.0, alpha=1.5)
    plan = RedundancyPlanner(n).plan(dist, "mean")
    for b in (1, plan.n_batches, n):
        t = simulator.simulate_balanced(jax.random.key(0), dist, n, b, 100_000)
        st = simulator.stats_from_samples(t)
        closed = analysis.mean_T(dist, n, b)
        mark = " <- planned" if b == plan.n_batches else ""
        print(
            f"B={b:3d}: E[T] closed={closed:8.3f} MC={st.mean:8.3f} "
            f"CoV={st.cov:.3f} p99={st.p99:8.3f}{mark}"
        )

    print("\n=== 3. fitting from observed service times (paper §VII) ===")
    rng = np.random.default_rng(0)
    observed = 2.0 * rng.uniform(size=5000) ** (-1 / 1.3)  # unknown heavy tail
    fitted = fit_service_time(observed)
    plan = RedundancyPlanner(100).plan_auto(observed, "mean")
    print(f"fitted family: {type(fitted).__name__}: {fitted}")
    print(
        f"plan for N=100: B={plan.n_batches}, r={plan.replication}; "
        f"predicted E[T]={plan.predicted_mean:.2f} vs "
        f"no-redundancy={plan.frontier_mean[plan.frontier_B.index(100)]:.2f}"
    )


if __name__ == "__main__":
    main()
