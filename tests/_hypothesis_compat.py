"""Seeded stand-in for hypothesis so the suite collects without the test extra.

CI installs ``.[test]`` and runs the real hypothesis engine.  In environments
without it (the tier-1 container), the property tests still run: each ``@given``
test is executed against ``max_examples`` pseudo-random draws from a fixed seed.
No shrinking, no database -- just deterministic example generation covering the
same strategy surface the tests use (integers, floats, lists, tuples, just,
sampled_from, permutations, flatmap).
"""
from __future__ import annotations

import numpy as np

_FALLBACK_SEED = 20_200_603  # arXiv:2006.02318's submission date
_DEFAULT_MAX_EXAMPLES = 20


class Strategy:
    """A value generator: ``draw(rng) -> value``, composable via flatmap."""

    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng: np.random.Generator):
        return self._draw(rng)

    def flatmap(self, fn) -> "Strategy":
        return Strategy(lambda rng: fn(self._draw(rng)).draw(rng))

    def map(self, fn) -> "Strategy":
        return Strategy(lambda rng: fn(self._draw(rng)))


class _Strategies:
    @staticmethod
    def integers(min_value: int, max_value: int) -> Strategy:
        return Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))

    @staticmethod
    def floats(min_value: float, max_value: float) -> Strategy:
        return Strategy(lambda rng: float(rng.uniform(min_value, max_value)))

    @staticmethod
    def sampled_from(elements) -> Strategy:
        pool = list(elements)
        return Strategy(lambda rng: pool[int(rng.integers(len(pool)))])

    @staticmethod
    def just(value) -> Strategy:
        return Strategy(lambda rng: value)

    @staticmethod
    def booleans() -> Strategy:
        return Strategy(lambda rng: bool(rng.integers(2)))

    @staticmethod
    def lists(elements: Strategy, min_size: int = 0, max_size: int = 10) -> Strategy:
        def draw(rng):
            size = int(rng.integers(min_size, max_size + 1))
            return [elements.draw(rng) for _ in range(size)]

        return Strategy(draw)

    @staticmethod
    def tuples(*strategies: Strategy) -> Strategy:
        return Strategy(lambda rng: tuple(s.draw(rng) for s in strategies))

    @staticmethod
    def permutations(values) -> Strategy:
        pool = list(values)
        return Strategy(lambda rng: [pool[i] for i in rng.permutation(len(pool))])


st = _Strategies()


def given(*arg_strategies: Strategy, **kwarg_strategies: Strategy):
    """Run the test once per generated example (no shrinking)."""

    def decorate(fn):
        def wrapper():
            rng = np.random.default_rng(_FALLBACK_SEED)
            n = getattr(wrapper, "_max_examples", _DEFAULT_MAX_EXAMPLES)
            for _ in range(n):
                args = [s.draw(rng) for s in arg_strategies]
                kwargs = {k: s.draw(rng) for k, s in kwarg_strategies.items()}
                fn(*args, **kwargs)

        # keep pytest's view of the signature parameterless (no fixtures), so
        # no functools.wraps here -- copy identity attributes by hand
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper

    return decorate


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None, **_ignored):
    """Record max_examples on the (already @given-wrapped) test function."""

    def decorate(fn):
        fn._max_examples = max_examples
        return fn

    return decorate
