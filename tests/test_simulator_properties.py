"""Hypothesis property tests on the simulator's system invariants."""
import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ModuleNotFoundError:  # test extra not installed: seeded fallback engine
    from _hypothesis_compat import given, settings, st

from repro.core import analysis, simulator
from repro.core.service_time import Exponential, Pareto, ShiftedExponential, min_of

MC = 60_000


@settings(max_examples=10, deadline=None)
@given(
    b=st.sampled_from([1, 2, 3, 4, 6]),
    mu=st.floats(0.5, 4.0),
)
def test_more_replicas_never_slower(b, mu):
    """Adding replicas to every batch (same B) stochastically speeds the job:
    E[T | r+1] <= E[T | r] -- min over more i.i.d. draws is smaller."""
    n1 = b * 2
    n2 = b * 3  # one more replica per batch
    d = Exponential(mu=mu)
    t1 = simulator.simulate_balanced(jax.random.key(0), d, n1, b, MC, size_dependent=False)
    t2 = simulator.simulate_balanced(jax.random.key(1), d, n2, b, MC, size_dependent=False)
    assert t2.mean() <= t1.mean() * 1.02  # MC slack


@settings(max_examples=10, deadline=None)
@given(b=st.sampled_from([2, 3, 6]), delta=st.floats(0.01, 1.0), mu=st.floats(0.5, 5.0))
def test_min_of_closure_matches_mc(b, delta, mu):
    """min_of's closed-form first order statistic matches empirical mins."""
    d = ShiftedExponential(delta=delta, mu=mu)
    m = min_of(d, b)
    draws = d.sample(jax.random.key(2), (MC, b))
    emp_mean = float(np.asarray(draws.min(axis=1)).mean())
    assert emp_mean == pytest.approx(m.mean(), rel=0.05)


@settings(max_examples=8, deadline=None)
@given(alpha=st.floats(2.2, 8.0))
def test_job_time_exceeds_single_batch_time(alpha):
    """T = max over B batches >= the time of any single batch (sanity of the
    max-min structure) and the closed form respects it."""
    n, b = 12, 4
    d = Pareto(sigma=1.0, alpha=alpha)
    et = analysis.pareto_mean_T(n, b, 1.0, alpha)
    # a single batch is the min of r=3 workers on N/B=3 tasks
    single = min_of(d.scaled_by(n / b), n // b).mean()
    assert et >= single * 0.99


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 100))
def test_coverage_failure_yields_inf(seed):
    """Uncovered batches (coupon-collector failure) => incomplete job (inf)."""
    rng = np.random.default_rng(seed)
    counts = rng.multinomial(6, np.ones(6) / 6)  # 6 draws over 6 batches
    t = simulator.simulate_counts(
        jax.random.key(seed), Exponential(1.0), counts, 2000
    )
    if (counts == 0).any():
        assert np.isinf(t).all()
    else:
        assert np.isfinite(t).all()


def test_all_zero_counts_returns_inf():
    """Regression: an all-zero counts vector used to sample a zero-width axis
    (max_c = 0) and crash in jnp.min; it must mean 'no batch is hosted' =>
    every sample is an incomplete job (inf)."""
    t = simulator.simulate_counts(jax.random.key(0), Exponential(1.0), np.zeros(4, int), 100)
    assert t.shape == (100,)
    assert np.isinf(t).all()


def test_partial_zero_counts_still_inf():
    """Mixed vector: any zero-host batch makes the whole job incomplete."""
    t = simulator.simulate_counts(
        jax.random.key(1), Exponential(1.0), np.array([3, 0, 2]), 500
    )
    assert np.isinf(t).all()


def test_balanced_beats_unbalanced_montecarlo():
    """Lemma 2 via MC: the balanced counts vector has the smallest E[T]."""
    d = Exponential(mu=1.0)
    t_bal = simulator.simulate_counts(jax.random.key(0), d, np.array([2, 2, 2]), MC)
    t_unb = simulator.simulate_counts(jax.random.key(1), d, np.array([4, 1, 1]), MC)
    assert t_bal.mean() < t_unb.mean()
