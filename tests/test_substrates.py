"""Substrate tests: optimizer, data pipeline, checkpointing, elastic control."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.core.service_time import Exponential, Pareto, ShiftedExponential
from repro.data import PipelineConfig, SyntheticLM
from repro.distributed import rdp
from repro.optim import AdamW, apply_updates, cosine_with_warmup


# ------------------------------------------------------------------ optimizer


def test_adamw_reduces_quadratic():
    opt = AdamW(learning_rate=0.1, weight_decay=0.0)
    params = {"w": jnp.array([3.0, -2.0]), "b": jnp.array([1.0])}
    state = opt.init(params)

    def loss_fn(p):
        return jnp.sum(p["w"] ** 2) + jnp.sum(p["b"] ** 2)

    for _ in range(200):
        grads = jax.grad(loss_fn)(params)
        updates, state, metrics = opt.update(grads, state, params)
        params = apply_updates(params, updates)
    assert float(loss_fn(params)) < 1e-3
    assert np.isfinite(float(metrics["grad_norm"]))


def test_adamw_clip_norm():
    opt = AdamW(learning_rate=1.0, clip_norm=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros(3)}
    state = opt.init(params)
    grads = {"w": jnp.array([100.0, 0.0, 0.0])}
    updates, state, metrics = opt.update(grads, state, params)
    assert float(metrics["grad_norm"]) == pytest.approx(100.0)
    # post-clip step magnitude bounded by lr * 1/sqrt(...) scale ~ lr
    assert float(jnp.abs(updates["w"]).max()) <= 1.0 + 1e-5


def test_cosine_schedule_shape():
    fn = cosine_with_warmup(1.0, warmup=10, total=100)
    xs = [float(fn(jnp.asarray(s))) for s in [0, 5, 10, 50, 100]]
    assert xs[0] == 0.0 and xs[1] == pytest.approx(0.5)
    assert xs[2] == pytest.approx(1.0)
    assert xs[2] > xs[3] > xs[4]
    assert xs[4] == pytest.approx(0.1, rel=1e-3)


def test_weight_decay_only_on_matrices():
    opt = AdamW(learning_rate=1.0, weight_decay=0.5)
    params = {"w": jnp.ones((2, 2)), "b": jnp.ones((2,))}
    state = opt.init(params)
    grads = jax.tree.map(jnp.zeros_like, params)
    updates, _, _ = opt.update(grads, state, params)
    assert float(jnp.abs(updates["w"]).sum()) > 0  # decay applied
    assert float(jnp.abs(updates["b"]).sum()) == 0  # biases not decayed


# ------------------------------------------------------------------ pipeline


def test_pipeline_determinism_and_shapes():
    cfg = PipelineConfig(vocab_size=97, seq_len=16, global_batch=8, n_shards=4, seed=3)
    pipe = SyntheticLM(cfg)
    a = pipe.shard_batch(step=7, shard=2)
    b = pipe.shard_batch(step=7, shard=2)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert a["tokens"].shape == (2, 16)
    c = pipe.shard_batch(step=8, shard=2)
    assert not np.array_equal(a["tokens"], c["tokens"])  # steps differ
    d = pipe.shard_batch(step=7, shard=3)
    assert not np.array_equal(a["tokens"], d["tokens"])  # shards differ


def test_pipeline_replicated_workers_same_shard():
    """Paper policy: workers of a replica group read identical data."""
    cfg = PipelineConfig(
        vocab_size=97, seq_len=8, global_batch=8, n_shards=2, replication=3
    )
    pipe = SyntheticLM(cfg)
    # workers 0..5 -> shards 0,1,0,1,0,1: balanced non-overlapping
    shards = [pipe.shard_of_worker(w) for w in range(6)]
    assert shards == [0, 1, 0, 1, 0, 1]
    np.testing.assert_array_equal(
        pipe.worker_batch(0, 0)["tokens"], pipe.worker_batch(0, 2)["tokens"]
    )
    assert not np.array_equal(
        pipe.worker_batch(0, 0)["tokens"], pipe.worker_batch(0, 1)["tokens"]
    )


def test_pipeline_global_batch_coverage():
    cfg = PipelineConfig(vocab_size=31, seq_len=4, global_batch=12, n_shards=3)
    pipe = SyntheticLM(cfg)
    g = pipe.global_batch(0)
    assert g["tokens"].shape == (12, 4)
    assert g["labels"].shape == (12, 4)


def test_pipeline_is_learnable_structure():
    cfg = PipelineConfig(vocab_size=64, seq_len=32, global_batch=4, bigram_p=1.0)
    pipe = SyntheticLM(cfg)
    b = pipe.global_batch(0)
    # with p=1 the chain is deterministic: labels follow the permutation
    pred = pipe._perm[b["tokens"]]
    np.testing.assert_array_equal(pred, b["labels"])
    assert pipe.bigram_ceiling_loss() < np.log(64)


# ------------------------------------------------------------------ checkpoint


def _tiny_state():
    return {
        "params": {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3)},
        "step": jnp.asarray(4, jnp.int32),
    }


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    state = _tiny_state()
    mgr.save(4, state)
    like = jax.eval_shape(lambda: state)
    restored, step = mgr.restore(like)
    assert step == 4
    np.testing.assert_array_equal(restored["params"]["w"], state["params"]["w"])


def test_checkpoint_keep_k_and_latest(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    for s in (1, 2, 3):
        mgr.save(s, _tiny_state())
    assert mgr.all_steps() == [2, 3]
    assert mgr.latest_step() == 3


def test_checkpoint_corruption_fallback(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=3)
    state = _tiny_state()
    mgr.save(1, state)
    mgr.save(2, state)
    # corrupt step 2's first leaf
    leaf = next((tmp_path / "step_00000002").glob("leaf_*.npy"))
    arr = np.load(leaf)
    np.save(leaf, arr + 1)
    restored, step = mgr.restore(jax.eval_shape(lambda: state))
    assert step == 1  # CRC check rejected step 2


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    mgr.save_async(7, _tiny_state())
    mgr.wait()
    assert mgr.latest_step() == 7


# ------------------------------------------------------------------ RDP / elastic


def test_surviving_coverage():
    from repro.core.planner import RedundancyPlanner

    plan = RedundancyPlanner(8).plan(Exponential(mu=1.0), "blend")
    healthy = [True] * plan.n_workers
    assert rdp.surviving_coverage(plan, healthy)["covered"]
    # kill one full replica group of shard 0 (workers w with w % B == 0)
    for w in range(plan.n_workers):
        if w % plan.n_batches == 0:
            healthy[w] = False
    cov = rdp.surviving_coverage(plan, healthy)
    assert not cov["covered"] and 0 in cov["lost_shards"]


def test_elastic_replans_on_failure():
    ctl = rdp.ElasticController(ShiftedExponential(0.05, 5.0))
    plan = ctl.initial_plan(16)
    assert plan.n_workers == 16
    tr = ctl.on_membership_change(plan, n_healthy=12)
    assert tr is not None
    assert tr.new_plan.n_workers == 12
    assert tr.new_plan.n_batches * tr.new_plan.replication == 12
    assert ctl.on_membership_change(plan, n_healthy=16) is None


def test_elastic_replans_on_drift():
    """Straggler onset (heavy tail appears) should raise redundancy."""
    ctl = rdp.ElasticController(ShiftedExponential(1.0, 10.0))  # low randomness
    plan = ctl.initial_plan(100)
    rng = np.random.default_rng(0)
    heavy = 1.0 * rng.uniform(size=4000) ** (-1 / 1.2)  # heavy-tail step times
    tr = ctl.on_observed_step_times(plan, heavy)
    assert tr is not None and tr.reason == "drift"
    assert tr.new_plan.n_batches < plan.n_batches  # more replication


def test_assignment_matrix_is_balanced():
    from repro.core.planner import RedundancyPlanner

    plan = RedundancyPlanner(12).plan(Pareto(1.0, 2.0), "mean")
    m = rdp.assignment_matrix(plan)
    from repro.core import batching

    diag = batching.validate_scheme(m)
    assert diag["balanced"]
