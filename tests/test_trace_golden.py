"""Trace-driven golden regression: §VII planning summary stats, pinned.

``core.traces.synthetic_google_jobs`` -> ``plan_sweep`` on both backends,
with the resulting (B*, frontier means) pinned to a committed golden file.
The nightly bench measures the §VII trace *speedup*; this test makes sure the
underlying planning numbers cannot silently drift on every PR.

Tolerances (documented contract):

  * chosen ``B*`` and replication are pinned **exactly** -- both backends are
    seeded and deterministic, so any change here is a semantic change;
  * ``frontier_mean`` entries are pinned to ``rtol=5e-3`` -- wide enough for
    cross-platform float reassociation (BLAS, accelerator math) but far
    tighter than any statistical drift a semantics change would cause
    (Monte-Carlo error at these sample sizes is ~2-5%).

Regenerate (after an *intentional* semantic change) with:

    PYTHONPATH=src:tests python tests/test_trace_golden.py --regen
"""
import json
import pathlib

import numpy as np

from repro.core import traces
from repro.core.planner import plan_sweep
from repro.core.service_time import Empirical

GOLDEN = pathlib.Path(__file__).parent / "golden" / "trace_plan_sweep.json"

# job1: exponential family (plans at full parallelism); job6: heavy tail
# (plans real redundancy) -- one of each keeps the regression surface small
# enough to run on every PR while still covering both §VII regimes.
TRACE_JOBS = ("job1", "job6")
BUDGETS = (10,)
N_REPS = 256
SEED = 0


def _summarize() -> dict:
    jobs = {j.name: j for j in traces.synthetic_google_jobs()}
    dists = [Empirical(samples=tuple(float(x) for x in jobs[n].task_times)) for n in TRACE_JOBS]
    out = {}
    for backend in ("jax", "python"):
        plans = plan_sweep(
            dists, list(BUDGETS), "mean", n_reps=N_REPS, seed=SEED, backend=backend
        )
        rows = {}
        for name, row in zip(TRACE_JOBS, plans):
            rows[name] = [
                {
                    "n_workers": p.n_workers,
                    "B": p.n_batches,
                    "replication": p.replication,
                    "frontier_B": list(p.frontier_B),
                    "frontier_mean": [float(m) for m in p.frontier_mean],
                }
                for p in row
            ]
        out[backend] = rows
    return out


def test_trace_plan_sweep_matches_golden():
    assert GOLDEN.exists(), (
        f"golden file missing: {GOLDEN} -- generate it with "
        "`PYTHONPATH=src:tests python tests/test_trace_golden.py --regen` and commit it"
    )
    golden = json.loads(GOLDEN.read_text())
    current = _summarize()
    assert set(current) == set(golden)
    for backend in golden:
        for name in golden[backend]:
            for cur, ref in zip(current[backend][name], golden[backend][name]):
                ctx = (backend, name, ref["n_workers"])
                assert cur["n_workers"] == ref["n_workers"], ctx
                assert cur["B"] == ref["B"], ctx
                assert cur["replication"] == ref["replication"], ctx
                assert cur["frontier_B"] == ref["frontier_B"], ctx
                np.testing.assert_allclose(
                    cur["frontier_mean"], ref["frontier_mean"], rtol=5e-3, err_msg=str(ctx)
                )


def test_trace_golden_covers_both_regimes():
    """Independent of the pinned numbers: the heavy-tail job must actually
    use redundancy (B* < N) and the exponential job must not (B* = N)."""
    golden = json.loads(GOLDEN.read_text()) if GOLDEN.exists() else _summarize()
    for backend in golden:
        assert golden[backend]["job1"][0]["B"] == BUDGETS[0]
        assert golden[backend]["job6"][0]["B"] < BUDGETS[0]


if __name__ == "__main__":
    import sys

    if "--regen" in sys.argv:
        GOLDEN.parent.mkdir(parents=True, exist_ok=True)
        GOLDEN.write_text(json.dumps(_summarize(), indent=2) + "\n")
        print(f"wrote {GOLDEN}")
    else:
        print(__doc__)
