"""Trace-scale streaming: the bit-for-bit contract, the resampler, the golden day.

The tentpole property: on float64 lanes, the streaming accumulators carried by
the scan equal the sequential host fold of the materialized per-job outputs
**bit for bit** -- same seeds, same job order, same ops, same dtype.  That is
asserted three ways:

  * ``simulate_stream(outputs="full")`` returns both the arrays and the
    accumulators the same kernel run carried; ``fold_stream_stats`` of the
    arrays must equal those accumulators exactly;
  * ``outputs="stream"`` (a separate compile without the collected outputs)
    must produce the very same accumulators;
  * any slab partition (1 / prime / all) must too -- draw streams are a
    prefix-stable function of the per-rep generator.

``simulate_epochs(outputs="stream")`` gets the same treatment against
``epoch_stream_stats`` of the full report, including speeds and the space
lane.  The golden test pins the 10k-job synthetic cluster-day summary:

    PYTHONPATH=src:tests python tests/test_stream.py --regen
"""
import json
import pathlib

import jax
import numpy as np
import pytest

from repro.cluster import (
    EpochStreamReport,
    Scenario,
    StreamFullReport,
    StreamStats,
    epoch_stream_stats,
    fold_stream_stats,
    simulate_epochs,
    simulate_stream,
)
from repro.cluster.stream import _ACC_FIELDS
from repro.core.service_time import ShiftedExponential
from repro.core.traces import (
    STREAM_VERSION,
    TraceStream,
    synthetic_cluster_day,
    synthetic_google_jobs,
)

GOLDEN = pathlib.Path(__file__).parent / "golden" / "trace_day_summary.json"


@pytest.fixture
def x64():
    prev = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", True)
    try:
        yield
    finally:
        jax.config.update("jax_enable_x64", prev)


def _small_stream(n_jobs=96, seed=11) -> TraceStream:
    jobs = tuple(synthetic_google_jobs(2020)[:4])
    rng = np.random.default_rng(seed)
    arrivals = np.sort(rng.uniform(0.0, 40.0 * n_jobs, size=n_jobs))
    job_ids = rng.integers(0, len(jobs), size=n_jobs)
    return TraceStream(arrivals=arrivals, job_ids=job_ids, sources=jobs, seed=seed)


def _assert_stats_equal(a: StreamStats, b: StreamStats, ctx=""):
    for f in _ACC_FIELDS:
        x, y = getattr(a, f), getattr(b, f)
        assert x.dtype == y.dtype, (f, x.dtype, y.dtype, ctx)
        # bitwise: exact array equality, inf-safe (== would be True for inf
        # too, but assert_array_equal reports indices on mismatch)
        np.testing.assert_array_equal(x, y, err_msg=f"{f} {ctx}")


# --------------------------------------------------------------------------
# TraceStream: construction, resampling, slab invariance of the draws
# --------------------------------------------------------------------------


def test_trace_stream_validates():
    jobs = tuple(synthetic_google_jobs(2020)[:2])
    with pytest.raises(ValueError, match="sorted"):
        TraceStream(np.array([2.0, 1.0]), np.array([0, 0]), jobs, seed=0)
    with pytest.raises(ValueError, match="non-empty"):
        TraceStream(np.array([]), np.array([], dtype=int), jobs, seed=0)
    with pytest.raises(ValueError):
        TraceStream(np.array([0.0, 1.0]), np.array([0]), jobs, seed=0)
    with pytest.raises(ValueError):
        TraceStream(np.array([0.0, 1.0]), np.array([0, 7]), jobs, seed=0)


def test_sample_slab_draws_from_source_ecdf():
    st = _small_stream(40)
    rng = st.make_rng(0)
    draws = st.sample_slab(rng, 0, 40, 6)
    assert draws.shape == (40, 6) and draws.dtype == np.float64
    # every draw is an actual sample of that arrival's source job
    for j in range(40):
        src = set(np.asarray(st.sources[int(st.job_ids[j])].task_times).tolist())
        assert all(float(x) in src for x in draws[j])


def test_sample_slab_partition_invariant():
    """Any slab partition of the same rep's generator yields the same draws."""
    st = _small_stream(50)
    whole = st.sample_slab(st.make_rng(3), 0, 50, 8)
    rng = st.make_rng(3)
    parts = [st.sample_slab(rng, lo, hi, 8) for lo, hi in st.slabs(7)]
    np.testing.assert_array_equal(whole, np.concatenate(parts, axis=0))
    # distinct reps and distinct stream seeds decorrelate
    other = st.sample_slab(st.make_rng(4), 0, 50, 8)
    assert not np.array_equal(whole, other)


def test_stream_seed_versioned():
    st = _small_stream(20)
    bumped = TraceStream(
        st.arrivals, st.job_ids, st.sources, seed=st.seed, version=STREAM_VERSION + 1
    )
    a = st.sample_slab(st.make_rng(0), 0, 20, 4)
    b = bumped.sample_slab(bumped.make_rng(0), 0, 20, 4)
    assert not np.array_equal(a, b)


def test_synthetic_cluster_day_shape():
    day = synthetic_cluster_day(n_jobs=500, duration=3600.0, seed=9)
    assert day.n_jobs == 500
    assert day.arrivals[0] >= 0.0 and day.arrivals[-1] <= 3600.0
    assert np.all(np.diff(day.arrivals) >= 0.0)
    again = synthetic_cluster_day(n_jobs=500, duration=3600.0, seed=9)
    np.testing.assert_array_equal(day.arrivals, again.arrivals)
    np.testing.assert_array_equal(day.job_ids, again.job_ids)


# --------------------------------------------------------------------------
# the tentpole property: streaming == materialized, bit for bit (f64)
# --------------------------------------------------------------------------


@pytest.mark.parametrize(
    "scheduler,wpj,cancel",
    [
        ("fifo_gang", None, True),
        ("fifo_gang", None, False),
        ("packed", 6, True),
        ("balanced", 6, False),
    ],
)
def test_stream_equals_materialized_bitwise_f64(x64, scheduler, wpj, cancel):
    st = _small_stream(96)
    kw = dict(
        scheduler=scheduler,
        workers_per_job=wpj,
        cancel_redundant=cancel,
        dtype="float64",
    )
    full = simulate_stream(
        st, 12, 6, 3, scenario=Scenario(outputs="full", **kw), slab=37
    )
    assert isinstance(full, StreamFullReport)
    # (1) host fold of the materialized arrays == the carried accumulators
    _assert_stats_equal(
        fold_stream_stats(full.waits, full.t_job, full.busy_j, full.planned_j, full.saved_j),
        full.stats,
        f"fold vs full {scheduler}",
    )
    # (2) the streaming-only compile (no collected outputs) == same accumulators
    lean = simulate_stream(
        st, 12, 6, 3, scenario=Scenario(outputs="stream", **kw), slab=37
    )
    assert isinstance(lean, StreamStats)
    _assert_stats_equal(lean, full.stats, f"stream vs full {scheduler}")
    # sanity on the materialized side: starts respect arrivals, counts complete
    assert np.all(full.waits >= 0.0)
    assert int(lean.count.sum()) == 3 * 96


def test_stream_slab_partition_bitwise_f64(x64):
    """slab in {1, prime, all}: one accumulator, to the last bit."""
    st = _small_stream(60, seed=5)
    sc = Scenario(outputs="stream", dtype="float64")
    ref = simulate_stream(st, 10, 5, 2, scenario=sc, slab=None)
    for slab in (1, 7, 60):
        got = simulate_stream(st, 10, 5, 2, scenario=sc, slab=slab)
        _assert_stats_equal(got, ref, f"slab={slab}")


def test_stream_f32_slab_invariant_and_sane():
    """The f32 lane is slab-invariant too (same compiled fold per width is
    not required -- the draws and fold order are), and summaries are finite."""
    st = _small_stream(50, seed=8)
    sc = Scenario(outputs="stream", scheduler="packed", workers_per_job=5)
    ref = simulate_stream(st, 10, 5, 2, scenario=sc, slab=None)
    got = simulate_stream(st, 10, 5, 2, scenario=sc, slab=13)
    _assert_stats_equal(got, ref, "f32 slab")
    s = ref.summary()
    assert s["n_jobs_done"] == 2 * 50
    assert np.isfinite(s["mean_response"]) and s["mean_response"] > 0.0
    assert s["p50_response"] <= s["p95_response"] <= s["p99_response"]
    assert s["worker_seconds"] > 0.0


def test_stream_rejects_dynamic_knobs_and_bad_pools():
    st = _small_stream(10)
    with pytest.raises(ValueError, match="churn"):
        from repro.cluster import ChurnProcess

        simulate_stream(
            st, 8, 4, 1, scenario=Scenario(outputs="stream", churn=ChurnProcess(0.1, 1.0))
        )
    with pytest.raises(ValueError, match="speeds"):
        simulate_stream(
            st, 8, 4, 1, scenario=Scenario(outputs="stream", speeds=(1.0,) * 8)
        )
    with pytest.raises(ValueError, match="workers_per_job"):
        simulate_stream(st, 8, 4, 1, scenario=Scenario(outputs="stream", scheduler="packed"))
    with pytest.raises(ValueError, match=r"workers_per_job.*\[1, 8\]"):
        simulate_stream(
            st,
            8,
            4,
            1,
            scenario=Scenario(outputs="stream", scheduler="packed", workers_per_job=16),
        )
    with pytest.raises(ValueError, match=r"\[1, 8\]"):
        simulate_stream(st, 8, 9, 1, scenario=Scenario(outputs="stream"))
    with pytest.raises(TypeError, match="TraceStream"):
        simulate_stream(np.zeros(3), 8, 4, 1)


def test_scenario_outputs_knob():
    with pytest.raises(ValueError, match="outputs"):
        Scenario(outputs="compact").validate(8)
    with pytest.raises(ValueError, match="Python engine"):
        Scenario(outputs="stream").validate(8, backend="python")
    Scenario(outputs="stream").validate(8, backend="jax")


# --------------------------------------------------------------------------
# simulate_epochs(outputs="stream"): same contract on the engine-exact lanes
# --------------------------------------------------------------------------


@pytest.mark.parametrize(
    "kw",
    [
        {},
        {"cancel_redundant": False},
        {"speeds": (1.0, 1.4, 0.8, 1.2, 1.0, 0.9, 1.1, 1.3)},
        {"scheduler": "packed", "workers_per_job": 4},
        {"scheduler": "balanced", "workers_per_job": 4},
    ],
    ids=["gang", "no-cancel", "speeds", "space-packed", "space-balanced"],
)
def test_epoch_stream_equals_full_bitwise_f64(x64, kw):
    d = ShiftedExponential(delta=1.0, mu=0.5)
    arr = np.sort(np.random.default_rng(2).uniform(0.0, 30.0, size=24))
    base = dict(seed=6, dtype="float64", **kw)
    full = simulate_epochs(d, 8, 4, arr, 3, **base)
    got = simulate_epochs(d, 8, 4, arr, 3, outputs="stream", **base)
    assert isinstance(got, EpochStreamReport)
    _assert_stats_equal(got.stats, epoch_stream_stats(full), str(kw))
    np.testing.assert_array_equal(got.worker_seconds, full.worker_seconds)
    np.testing.assert_array_equal(
        got.cancelled_seconds_saved, full.cancelled_seconds_saved
    )
    assert np.array_equal(got.n_unfinished, np.zeros(3, dtype=got.n_unfinished.dtype))
    # the accounting dict keeps the EpochReport keying
    np.testing.assert_array_equal(
        got.accounting()["worker_seconds"], full.accounting()["worker_seconds"]
    )


def test_epoch_stream_churn_bitwise_and_truncation_flag(x64):
    """Churned lanes aggregate bitwise too, and a horizon-truncated rep is
    flagged on the stream report (the full report warns the same way)."""
    from repro.cluster import ChurnProcess

    d = ShiftedExponential(delta=1.0, mu=0.5)
    arr = np.sort(np.random.default_rng(0).uniform(0.0, 30.0, size=20))
    kw = dict(
        seed=2,
        dtype="float64",
        churn=ChurnProcess(fail_rate=0.05, mean_downtime=2.0),
        churn_pairs_per_worker=2,
    )
    with pytest.warns((RuntimeWarning, DeprecationWarning)):
        full = simulate_epochs(d, 8, 4, arr, 3, **kw)
    with pytest.warns((RuntimeWarning, DeprecationWarning)):
        got = simulate_epochs(d, 8, 4, arr, 3, outputs="stream", **kw)
    _assert_stats_equal(got.stats, epoch_stream_stats(full), "churn")
    assert got.churn_truncated is not None and got.churn_truncated.dtype == bool
    np.testing.assert_array_equal(got.n_worker_failures, full.n_worker_failures)


def test_epoch_stream_summary_tracks_full_f32():
    """f32 lanes: not bitwise by contract, but the summaries must agree to
    float32 accumulation error."""
    d = ShiftedExponential(delta=1.0, mu=0.5)
    arr = np.sort(np.random.default_rng(4).uniform(0.0, 20.0, size=16))
    full = simulate_epochs(d, 6, 3, arr, 4, seed=1)
    got = simulate_epochs(d, 6, 3, arr, 4, seed=1, outputs="stream")
    resp = full.finishes - arr[None, :]
    np.testing.assert_allclose(
        got.stats.mean_response, resp.mean(axis=1), rtol=1e-5
    )
    np.testing.assert_allclose(got.stats.resp_max, resp.max(axis=1), rtol=1e-6)


# --------------------------------------------------------------------------
# golden: the 10k-job synthetic cluster-day summary, pinned
# --------------------------------------------------------------------------

# f32 kernel + pooled summary; exact integer fields pinned exactly, float
# fields to 1e-5 (cross-platform reassociation headroom, far below any
# semantic drift).  The cluster is trace-sized (the 2011 Google trace holds
# ~12.5k machines): 2304 pools of 6 give mild queueing, so the pinned
# quantiles actually spread instead of saturating at the histogram tail.
DAY_CFG = dict(n_jobs=10_000, duration=86_400.0, seed=7)
DAY_RUN = dict(n_workers=13_824, n_batches=3, n_reps=2, slab=1024)


def _day_summary() -> dict:
    day = synthetic_cluster_day(**DAY_CFG)
    sc = Scenario(
        outputs="stream", scheduler="packed", workers_per_job=6, cancel_redundant=True
    )
    stats = simulate_stream(
        day, DAY_RUN["n_workers"], DAY_RUN["n_batches"], DAY_RUN["n_reps"],
        scenario=sc, slab=DAY_RUN["slab"],
    )
    return stats.summary()


def test_cluster_day_summary_matches_golden():
    assert GOLDEN.exists(), (
        f"golden file missing: {GOLDEN} -- generate it with "
        "`PYTHONPATH=src:tests python tests/test_stream.py --regen` and commit it"
    )
    golden = json.loads(GOLDEN.read_text())
    current = _day_summary()
    assert set(current) == set(golden)
    assert current["n_jobs_done"] == golden["n_jobs_done"] == (
        DAY_CFG["n_jobs"] * DAY_RUN["n_reps"]
    )
    for k in golden:
        if k == "n_jobs_done":
            continue
        np.testing.assert_allclose(current[k], golden[k], rtol=1e-5, err_msg=k)


if __name__ == "__main__":
    import sys

    if "--regen" in sys.argv:
        GOLDEN.parent.mkdir(parents=True, exist_ok=True)
        GOLDEN.write_text(json.dumps(_day_summary(), indent=2) + "\n")
        print(f"wrote {GOLDEN}")
    else:
        print(__doc__)
