"""Cluster engine: determinism, simulator equivalence, cancellation, replanning."""
import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ModuleNotFoundError:  # test extra not installed: seeded fallback engine
    from _hypothesis_compat import given, settings, st

import strategies as scn
from repro.cluster import (
    ChurnProcess,
    ClusterEngine,
    Job,
    OnlineReplanner,
    jobs_from_traces,
    sample_job_times,
)
from repro.core import analysis, simulator, traces
from repro.core.planner import RedundancyPlanner
from repro.core.service_time import Exponential, Pareto, ShiftedExponential

# --------------------------------------------------------------------------
# determinism
# --------------------------------------------------------------------------


def test_deterministic_under_fixed_seed():
    a = sample_job_times(Exponential(1.0), 6, 3, 80, seed=7)
    b = sample_job_times(Exponential(1.0), 6, 3, 80, seed=7)
    c = sample_job_times(Exponential(1.0), 6, 3, 80, seed=8)
    assert np.array_equal(a, b)
    assert not np.array_equal(a, c)
    assert np.isfinite(a).all()


@settings(max_examples=4, deadline=None)
@given(
    dist=scn.light_tailed_dists(),
    setup=scn.worker_setups(4, 8),
    seed=st.integers(0, 99),
)
def test_deterministic_on_generated_scenarios(dist, setup, seed):
    """Shared-strategy scenarios (any fitted family, optional hetero speeds)
    replay bit-for-bit under a fixed seed."""
    n, speeds = setup
    b = max(1, n // 2)
    runs = []
    for _ in range(2):
        jobs = [Job(job_id=i, dist=dist, n_tasks=n) for i in range(10)]
        runs.append(ClusterEngine(n, seed=seed, n_batches=b, speeds=speeds).run(jobs))
    assert np.array_equal(runs[0].compute_times, runs[1].compute_times)
    assert runs[0].worker_seconds == runs[1].worker_seconds


def test_churn_schedule_replay_and_epoch_fields():
    """An explicit ChurnSchedule replays verbatim, and the report exposes the
    applied epoch boundaries + accounting (the cross-backend surface)."""
    sched = scn.seeded_schedule(8, seed=1, fail_rate=0.05, mean_downtime=1.0, pairs_per_worker=2)
    assert len(sched) > 0
    jobs = [Job(job_id=i, dist=Pareto(1.0, 2.2), n_tasks=8) for i in range(40)]
    rep = ClusterEngine(8, seed=2, n_batches=4, churn_schedule=sched).run(jobs)
    jobs2 = [Job(job_id=i, dist=Pareto(1.0, 2.2), n_tasks=8) for i in range(40)]
    rep2 = ClusterEngine(8, seed=2, n_batches=4, churn_schedule=sched).run(jobs2)
    assert np.array_equal(rep.compute_times, rep2.compute_times)
    assert rep.epoch_times == rep2.epoch_times
    # boundaries are applied in order and come from the schedule
    assert list(rep.epoch_times) == sorted(rep.epoch_times)
    assert set(rep.epoch_times) <= set(sched.times)
    assert rep.n_epochs == len(rep.epoch_times) + 1
    assert rep.n_worker_failures == sum(1 for u in sched.ups if not u)
    acc = rep.accounting()
    assert set(acc) == {
        "worker_seconds",
        "cancelled_seconds_saved",
        "n_worker_failures",
        "n_replicas_rescued",
        "n_replans",
        "n_speculative",
        "n_task_failures",
        "n_retries",
    }


def test_full_report_replays_exactly():
    jobs = [Job(job_id=i, dist=Pareto(1.0, 2.2), n_tasks=8) for i in range(40)]
    churn = ChurnProcess(fail_rate=0.05, mean_downtime=1.0)
    r1 = ClusterEngine(8, seed=11, n_batches=4, cancel_redundant=True, churn=churn).run(jobs)
    r2 = ClusterEngine(8, seed=11, n_batches=4, cancel_redundant=True, churn=churn).run(jobs)
    assert np.array_equal(r1.compute_times, r2.compute_times)
    assert r1.worker_seconds == r2.worker_seconds
    assert r1.n_worker_failures == r2.n_worker_failures


# --------------------------------------------------------------------------
# equivalence with the vectorized Monte-Carlo oracle
# --------------------------------------------------------------------------


def _assert_stats_agree(t_engine: np.ndarray, t_sim: np.ndarray):
    """Mean and p95 must agree within 3 sigma of Monte-Carlo error."""
    se_mean = np.sqrt(t_engine.var() / t_engine.size + t_sim.var() / t_sim.size)
    assert abs(t_engine.mean() - t_sim.mean()) < 3.0 * se_mean, (
        t_engine.mean(),
        t_sim.mean(),
        se_mean,
    )
    # bootstrap standard error of the engine's p95
    rng = np.random.default_rng(0)
    boots = [
        np.percentile(rng.choice(t_engine, size=t_engine.size, replace=True), 95)
        for _ in range(200)
    ]
    se_p95 = float(np.std(boots)) + 1e-9
    assert abs(np.percentile(t_engine, 95) - np.percentile(t_sim, 95)) < 3.0 * se_p95


def test_engine_matches_simulate_balanced_exponential():
    dist = Exponential(mu=1.0)
    t_e = sample_job_times(dist, 8, 4, 4000, seed=1)
    t_s = np.asarray(simulator.simulate_balanced(jax.random.key(0), dist, 8, 4, 200_000))
    _assert_stats_agree(t_e, t_s)


def test_engine_matches_simulate_balanced_sexp():
    dist = ShiftedExponential(delta=0.5, mu=2.0)
    t_e = sample_job_times(dist, 12, 3, 4000, seed=2)
    t_s = np.asarray(simulator.simulate_balanced(jax.random.key(1), dist, 12, 3, 200_000))
    _assert_stats_agree(t_e, t_s)


def test_engine_matches_simulate_membership_batch_model():
    """§IV batch-level model (size_dependent=False) vs the membership path."""
    import repro.core.batching as batching

    n, b = 6, 3
    dist = Exponential(mu=1.0)
    t_e = sample_job_times(dist, n, b, 4000, seed=3, size_dependent=False)
    m = batching.non_overlapping(n, b)
    t_s = np.asarray(
        simulator.simulate_membership(jax.random.key(2), dist, m, 200_000, size_dependent=False)
    )
    _assert_stats_agree(t_e, t_s)


# --------------------------------------------------------------------------
# cancellation
# --------------------------------------------------------------------------


def test_cancellation_reduces_worker_seconds():
    jobs = [Job(job_id=i, dist=Pareto(1.0, 2.0), n_tasks=8) for i in range(150)]
    on = ClusterEngine(8, seed=3, n_batches=2, cancel_redundant=True).run(jobs)
    off = ClusterEngine(8, seed=3, n_batches=2, cancel_redundant=False).run(jobs)
    # same seed => same service draws => identical job compute times ...
    assert np.allclose(on.compute_times, off.compute_times)
    # ... but cancellation reclaims the redundant replicas' tails
    assert on.worker_seconds < off.worker_seconds
    assert on.cancelled_seconds_saved > 0.0
    committed = on.worker_seconds + on.cancelled_seconds_saved
    assert np.isclose(committed, off.worker_seconds, rtol=1e-9)
    # stragglers of job k delay job k+1's gang dispatch unless cancelled
    assert (on.response_times <= off.response_times + 1e-9).all()
    assert on.response_times.mean() < off.response_times.mean()


# --------------------------------------------------------------------------
# churn
# --------------------------------------------------------------------------


def test_churn_jobs_still_complete():
    jobs = [Job(job_id=i, dist=Pareto(1.0, 2.0), n_tasks=8) for i in range(60)]
    churn = ChurnProcess(fail_rate=0.05, mean_downtime=1.0)
    rep = ClusterEngine(8, seed=5, n_batches=2, churn=churn).run(jobs)
    assert rep.n_worker_failures > 0
    assert np.isfinite(rep.compute_times).all()


def test_cancellation_does_not_disable_churn():
    """Regression: cancelling a replica bumps the worker's assignment epoch;
    that must NOT invalidate its pending WORKER_FAIL event (churn staleness
    is tracked separately), or cancelled-from workers become immortal."""
    jobs = [Job(job_id=i, dist=Pareto(1.0, 2.0), n_tasks=8) for i in range(300)]
    churn = ChurnProcess(fail_rate=0.05, mean_downtime=1.0)
    on = ClusterEngine(8, seed=7, n_batches=2, cancel_redundant=True, churn=churn).run(jobs)
    off = ClusterEngine(8, seed=7, n_batches=2, cancel_redundant=False, churn=churn).run(jobs)
    assert on.n_worker_failures > 50
    # same churn process, same seed: failure counts are the same order
    assert on.n_worker_failures > off.n_worker_failures * 0.2


def test_replica_rescue_on_total_batch_loss():
    """Replication r=1 means any failure kills a batch's only replica; the
    master must rescue it on a freed/joined worker for the job to finish."""
    jobs = [Job(job_id=i, dist=ShiftedExponential(1.0, 0.5), n_tasks=8) for i in range(40)]
    churn = ChurnProcess(fail_rate=0.08, mean_downtime=0.5)
    rep = ClusterEngine(8, seed=13, n_batches=8, churn=churn).run(jobs)
    assert rep.n_worker_failures > 0
    assert rep.n_replicas_rescued > 0
    assert np.isfinite(rep.compute_times).all()


# --------------------------------------------------------------------------
# queueing
# --------------------------------------------------------------------------


def test_fifo_queueing_serializes_jobs():
    jobs = [Job(job_id=i, dist=Exponential(1.0), n_tasks=8, arrival=0.0) for i in range(10)]
    rep = ClusterEngine(8, seed=1, n_batches=4).run(jobs)
    starts = np.array([r.start for r in rep.records])
    finishes = np.array([r.finish for r in rep.records])
    # FIFO whole-cluster gang scheduling: job k+1 starts after job k finishes
    assert (np.diff(starts) >= -1e-9).all()
    assert (starts[1:] >= finishes[:-1] - 1e-9).all()
    # queueing delay accumulates
    waits = np.array([r.queue_wait for r in rep.records])
    assert waits[-1] > waits[0]


def test_trace_workload_arrivals():
    tj = traces.synthetic_google_jobs()[:4]
    jobs = jobs_from_traces(tj, n_tasks=10, arrival_rate=0.01, seed=0)
    assert [j.arrival for j in jobs] == sorted(j.arrival for j in jobs)
    rep = ClusterEngine(10, seed=1, n_batches=5).run(jobs)
    assert np.isfinite(rep.response_times).all()
    assert {r.name for r in rep.records} == {j.name for j in tj}


# --------------------------------------------------------------------------
# online replanning
# --------------------------------------------------------------------------


def test_replanning_converges_to_closed_form_optimum():
    """Exponential workload: the replanner must land on the closed-form
    optimal B (Thm 3: E[T] = H_B / mu, minimized at full diversity B=1)."""
    n = 8
    dist = Exponential(mu=1.0)
    controller = OnlineReplanner(n, window=512, refit_every=64, min_observations=64)
    # start deliberately wrong: full parallelism
    engine = ClusterEngine(n, seed=9, n_batches=n, controller=controller)
    jobs = [Job(job_id=i, dist=dist, n_tasks=n) for i in range(80)]
    rep = engine.run(jobs)
    b_star = analysis.argmin_B(dist, n, metric="mean")
    assert rep.n_replans >= 1
    assert controller.current is not None
    assert controller.current.n_batches == b_star == 1
    # the final dispatched jobs actually ran under the replanned B
    assert rep.records[-1].n_batches == b_star


def test_replanner_corrects_cancellation_censoring():
    """With cancellation only batch winners are observed (min of r draws);
    the replanner must undo that censoring, or it fits a tail r times
    lighter than reality and under-replicates."""
    rng = np.random.default_rng(0)
    true = Pareto(1.0, 2.0)
    r = 4
    winners = true.sample_np(rng, (600, r)).min(axis=1)  # ~ Pareto(1, 8)
    ctl = OnlineReplanner(12, window=600, refit_every=1, min_observations=1)
    ctl.observe_many(winners, n_competitors=r)
    plan = ctl.replan()
    assert isinstance(ctl.last_fit, Pareto)
    assert ctl.last_fit.alpha == pytest.approx(true.alpha, rel=0.25)
    ref = RedundancyPlanner(12).plan(true, objective="mean")
    assert plan.n_batches == ref.n_batches


def test_engine_tags_censored_observations():
    ctl = OnlineReplanner(8, refit_every=10**9, min_observations=10**9)
    jobs = [Job(job_id=i, dist=Exponential(1.0), n_tasks=8) for i in range(20)]
    ClusterEngine(8, seed=1, n_batches=2, cancel_redundant=True, controller=ctl).run(jobs)
    # B=2 over 8 workers => each winner raced r=4 replicas
    assert {c for _, c in ctl.observations} == {4}
    ctl2 = OnlineReplanner(8, refit_every=10**9, min_observations=10**9)
    jobs2 = [Job(job_id=i, dist=Exponential(1.0), n_tasks=8) for i in range(20)]
    ClusterEngine(8, seed=1, n_batches=2, cancel_redundant=False, controller=ctl2).run(jobs2)
    # without cancellation every replica completes: observations are unbiased
    assert {c for _, c in ctl2.observations} == {1}


def test_engine_run_is_single_shot():
    engine = ClusterEngine(4, seed=0, n_batches=2)
    engine.run([Job(job_id=0, dist=Exponential(1.0), n_tasks=4)])
    with pytest.raises(RuntimeError, match="single-shot"):
        engine.run([Job(job_id=1, dist=Exponential(1.0), n_tasks=4)])


@pytest.mark.parametrize("backend", ["python", "jax"])
def test_plan_cluster_agrees_with_closed_form(backend):
    planner = RedundancyPlanner(8)
    plan = planner.plan_cluster(Exponential(1.0), n_reps=300, seed=0, backend=backend)
    assert plan.source == f"cluster_engine:{backend}"
    assert plan.n_batches == analysis.argmin_B(Exponential(1.0), 8, metric="mean")
    # frontier means track the closed form within MC noise
    for b, m in zip(plan.frontier_B, plan.frontier_mean):
        assert abs(m - analysis.mean_T(Exponential(1.0), 8, b)) < 0.35, (b, m)


# --------------------------------------------------------------------------
# heterogeneous workers
# --------------------------------------------------------------------------


def test_faster_workers_speed_up_jobs():
    slow = sample_job_times(Exponential(1.0), 6, 3, 500, seed=4)
    fast_engine = ClusterEngine(6, seed=4, n_batches=3, speeds=[4.0] * 6)
    fast_jobs = [Job(job_id=i, dist=Exponential(1.0), n_tasks=6) for i in range(500)]
    fast = fast_engine.run(fast_jobs).compute_times
    # speed 4 workers finish the same draws 4x faster (same seed, same stream)
    assert np.allclose(fast * 4.0, slow)
