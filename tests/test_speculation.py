"""Speculative (reactive) replication: engine semantics, invariants, replay.

The hand-computable fixture used throughout: 4 workers, one slow
(speed 1/4), a single job of 4 unit tasks split into B=4 batches (r=1,
so planned redundancy contributes nothing -- every backup is reactive).
With ``Empirical((1.0,))`` every draw is exactly 1.0, so the fast batches
complete at t=1, the straggler would run to t=4, and all arithmetic is
exact in binary floating point (speeds and epochs are powers of two).

Timeline under Speculation(interval=0.25, theta=1.5):
  t=1      three sibling batches complete -> obs median 1.0; the straggler's
           replica started at 0, so it crosses at 0 + 1.5*1.0 = 1.5
  t=1.75   first heartbeat epoch strictly after the crossing with a free
           worker -> ONE backup launched (first lagging batch in order)
  t=2.75   the backup (unit task on a unit-speed worker) finishes first:
           the job covers at 2.75 instead of 4.0
"""
import math
import warnings

import numpy as np
import pytest

from repro.cluster import (
    ClusterEngine,
    Job,
    Scenario,
    Speculation,
    SpeculativePolicy,
    sample_job_times,
)
from repro.cluster.scheduler import JobPlan
from repro.core.service_time import Empirical, Pareto

UNIT = Empirical(samples=(1.0,))
SPEC = Speculation(interval=0.25, theta=1.5)


def run_one(speeds, speculation, *, cancel=True, n_jobs=1, dist=UNIT, seed=0, **kw):
    n = len(speeds)
    jobs = [Job(job_id=i, dist=dist, n_tasks=n) for i in range(n_jobs)]
    engine = ClusterEngine(
        n,
        seed=seed,
        n_batches=kw.pop("n_batches", n),
        cancel_redundant=cancel,
        speeds=speeds,
        speculation=speculation,
        **kw,
    )
    return engine.run(jobs)


# --------------------------------------------------------------------------
# the trigger: median, theta, heartbeat grid
# --------------------------------------------------------------------------


def test_backup_rescues_straggler_at_the_predicted_epoch():
    rep = run_one((1.0, 1.0, 1.0, 0.25), SPEC)
    assert rep.n_speculative == 1
    assert rep.records[0].compute_time == 2.75  # exact: epoch 1.75 + 1.0
    # the original replica's tail is reclaimed by cancellation
    assert rep.cancelled_seconds_saved == 4.0 - 2.75
    base = run_one((1.0, 1.0, 1.0, 0.25), None)
    assert base.n_speculative == 0
    assert base.records[0].compute_time == 4.0


def test_no_backup_when_theta_never_crossed():
    rep = run_one((1.0, 1.0, 1.0, 0.25), Speculation(interval=0.25, theta=10.0))
    assert rep.n_speculative == 0
    assert rep.records[0].compute_time == 4.0


def test_min_observations_gates_the_median():
    # two stragglers leave only 2 completed siblings; demanding 3 means the
    # median never becomes available and no backup launches
    rep = run_one((1.0, 1.0, 0.25, 0.25), Speculation(interval=0.25, theta=1.5, min_observations=3))
    assert rep.n_speculative == 0
    assert rep.records[0].compute_time == 4.0


def test_max_backups_caps_per_job_and_one_launch_per_epoch():
    speeds = (1.0, 1.0, 0.25, 0.25)
    capped = run_one(speeds, Speculation(interval=0.25, theta=1.5, max_backups=1))
    assert capped.n_speculative == 1
    # batch 2 (first lagging in batch order) gets the backup at 1.75 and
    # covers at 2.75; batch 3 stays with its straggler until 4.0
    assert capped.records[0].compute_time == 4.0

    both = run_one(speeds, Speculation(interval=0.25, theta=1.5, max_backups=2))
    assert both.n_speculative == 2
    # one launch per heartbeat: batch 2 at 1.75, batch 3 at the NEXT epoch
    # 2.0 -> covers at 3.0
    assert both.records[0].compute_time == 3.0
    assert both.cancelled_seconds_saved == (4.0 - 2.75) + (4.0 - 3.0)


def test_policy_pure_functions():
    pol = SpeculativePolicy(Speculation(interval=0.25, theta=2.0, min_observations=3))
    assert pol.median([3.0, 1.0]) is None  # below min_observations
    assert pol.median([3.0, 1.0, 2.0]) == 2.0
    assert pol.median([4.0, 1.0, 2.0, 3.0]) == 2.0  # lower median
    assert pol.lagging(4.1, 2.0) and not pol.lagging(4.0, 2.0)  # strict
    assert pol.next_epoch(1.5, 1.0) == 1.75  # first epoch strictly after 1.5
    assert pol.next_epoch(1.75, 1.0) == 2.0  # grid point itself is too early
    assert pol.next_epoch(0.2, 1.0) == 1.25  # past crossing: next after now


# --------------------------------------------------------------------------
# accounting invariants and composition
# --------------------------------------------------------------------------


def test_worker_seconds_invariant_with_speculation():
    """ws(cancel on) + saved == ws(cancel off), exactly, with backups racing."""
    on = run_one((1.0, 1.0, 1.0, 0.25), SPEC, cancel=True)
    off = run_one((1.0, 1.0, 1.0, 0.25), SPEC, cancel=False)
    assert on.n_speculative == off.n_speculative == 1
    assert on.worker_seconds + on.cancelled_seconds_saved == off.worker_seconds
    # without cancellation the covering time is the same (backup still wins)
    assert off.records[0].compute_time == 2.75
    assert off.cancelled_seconds_saved == 0.0


def test_speculation_is_deterministic_and_composes_with_churn():
    dist = Pareto(1.0, 1.5)
    spec = Speculation(interval=0.23, theta=2.0)
    runs = []
    for _ in range(2):
        jobs = [Job(job_id=i, dist=dist, n_tasks=8) for i in range(30)]
        from repro.cluster import ChurnProcess

        eng = ClusterEngine(
            8,
            seed=5,
            n_batches=8,
            cancel_redundant=True,
            speculation=spec,
            churn=ChurnProcess(fail_rate=0.02, mean_downtime=2.0),
        )
        runs.append(eng.run(jobs))
    assert np.array_equal(runs[0].compute_times, runs[1].compute_times)
    assert runs[0].n_speculative == runs[1].n_speculative
    assert runs[0].worker_seconds == runs[1].worker_seconds
    assert np.isfinite(runs[0].compute_times).all()


def test_speculation_reduces_pareto_tail_latency():
    """On a heavy tail with r=1, reactive backups must beat no-redundancy."""
    dist = Pareto(1.0, 1.2)
    times = {}
    for name, spec in [("off", None), ("on", Speculation(interval=0.23, theta=2.0))]:
        jobs = [Job(job_id=i, dist=dist, n_tasks=8) for i in range(120)]
        eng = ClusterEngine(
            8, seed=3, n_batches=8, cancel_redundant=True, speculation=spec
        )
        times[name] = eng.run(jobs)
    assert times["on"].n_speculative > 0
    assert times["on"].compute_times.mean() < times["off"].compute_times.mean()


def test_speculation_under_space_sharing_uses_own_allocation_first():
    # two 2-worker jobs side by side; job 0's second batch straggles on w1
    # and is backed up on its own freed worker w0, not on job 1's subset
    n = 4
    speeds = (1.0, 0.25, 1.0, 1.0)
    jobs = [Job(job_id=i, dist=UNIT, n_tasks=2) for i in range(2)]
    eng = ClusterEngine(
        n,
        seed=0,
        n_batches=2,
        cancel_redundant=True,
        speeds=speeds,
        speculation=SPEC,
        scheduler="packed",
        workers_per_job=2,
    )
    rep = eng.run(jobs)
    assert rep.n_speculative == 1
    recs = {r.job_id: r for r in rep.records}
    assert recs[1].compute_time == 1.0  # untouched by job 0's backup
    assert recs[0].compute_time == 2.75


# --------------------------------------------------------------------------
# scripted replay (the live-trace mode)
# --------------------------------------------------------------------------


def test_scripted_launch_times_replay_the_grid_run_exactly():
    grid = run_one((1.0, 1.0, 1.0, 0.25), SPEC)
    scripted = run_one((1.0, 1.0, 1.0, 0.25), SPEC, speculation_times=(1.75,))
    assert scripted.n_speculative == grid.n_speculative == 1
    assert scripted.records[0].compute_time == grid.records[0].compute_time
    assert scripted.worker_seconds == grid.worker_seconds
    assert scripted.cancelled_seconds_saved == grid.cancelled_seconds_saved


def test_scripted_replay_diverging_stamp_raises():
    with pytest.raises(RuntimeError, match="speculation replay diverged"):
        run_one((1.0, 1.0, 1.0, 0.25), SPEC, speculation_times=(0.5,))


def test_scripted_times_require_the_policy():
    with pytest.raises(ValueError, match="speculation_times"):
        ClusterEngine(4, speculation_times=(1.0,))


# --------------------------------------------------------------------------
# Scenario plumbing and validation
# --------------------------------------------------------------------------


def test_speculation_config_validates():
    for bad in (
        dict(interval=0.0),
        dict(theta=-1.0),
        dict(min_observations=0),
        dict(max_backups=0),
    ):
        with pytest.raises(ValueError):
            Speculation(**bad)


def test_scenario_rejects_speculation_with_replanning():
    from repro.cluster import ReplanConfig

    sc = Scenario(speculation=SPEC, replan=ReplanConfig())
    with pytest.raises(ValueError, match="mutually exclusive"):
        sc.validate(n_workers=4, backend="python")


def test_scenario_speculation_is_dynamic_and_python_only_for_space():
    assert Scenario(speculation=SPEC).is_dynamic
    sc = Scenario(speculation=SPEC, workers_per_job=2)
    sc.validate(n_workers=4, backend="python")  # fine on the engine
    with pytest.raises(ValueError, match="backend='python' only"):
        sc.validate(n_workers=4, backend="jax")


# --------------------------------------------------------------------------
# the jax lane: simulate_epochs replays the engine's speculation exactly
# --------------------------------------------------------------------------


def _scan_one(speeds, speculation, *, cancel=True, n_jobs=1, n_batches=None,
              dist=UNIT, seed=0, n_reps=2, dtype="float32", **kw):
    """simulate_epochs under the same fixture run_one builds for the engine."""
    from repro.cluster import simulate_epochs

    n = len(speeds)
    sc = Scenario(
        speculation=speculation, speeds=speeds, cancel_redundant=cancel,
        dtype=dtype, **kw,
    )
    return simulate_epochs(
        dist, n, n_batches or n, np.zeros(n_jobs), n_reps, seed=seed, scenario=sc
    )


def _assert_scan_matches_engine(er, sr):
    """Every lane reproduces the engine's times and accounting bit-for-bit
    (the fixture's values are all exactly representable in float32)."""
    e_fin = np.array([r.compute_time for r in er.records])
    for lane in range(sr.finishes.shape[0]):
        s_fin = np.asarray(sr.finishes[lane]) - np.asarray(sr.starts[lane])
        assert np.array_equal(s_fin, e_fin), (lane, s_fin, e_fin)
        assert float(sr.worker_seconds[lane]) == er.worker_seconds
        assert float(sr.cancelled_seconds_saved[lane]) == er.cancelled_seconds_saved
        assert int(sr.n_speculative[lane]) == er.n_speculative
        assert int(sr.n_worker_failures[lane]) == er.n_worker_failures
        assert int(sr.n_replicas_rescued[lane]) == er.n_replicas_rescued


FIXTURES = [
    # (name, speeds, speculation, cancel)
    ("backup-cancel", (1.0, 1.0, 1.0, 0.25), SPEC, True),
    ("backup-nocancel", (1.0, 1.0, 1.0, 0.25), SPEC, False),
    ("theta-never-crossed", (1.0, 1.0, 1.0, 0.25), Speculation(interval=0.25, theta=10.0), True),
    (
        "min-obs-gate",
        (1.0, 1.0, 0.25, 0.25),
        Speculation(interval=0.25, theta=1.5, min_observations=3),
        True,
    ),
    (
        "max-backups-1",
        (1.0, 1.0, 0.25, 0.25),
        Speculation(interval=0.25, theta=1.5, max_backups=1),
        True,
    ),
    (
        "two-backups-staggered",
        (1.0, 1.0, 0.25, 0.25),
        Speculation(interval=0.25, theta=1.5, max_backups=2),
        True,
    ),
]


@pytest.mark.parametrize("name,speeds,spec,cancel", FIXTURES, ids=[f[0] for f in FIXTURES])
def test_jax_scan_matches_engine_exactly(name, speeds, spec, cancel):
    """The trigger (median, theta, heartbeat grid, one launch per firing),
    the winner-duration observations, and the cancellation accounting all
    replay the event engine exactly on the hand-computable fixture."""
    er = run_one(speeds, spec, cancel=cancel)
    sr = _scan_one(speeds, spec, cancel=cancel)
    _assert_scan_matches_engine(er, sr)


def test_jax_scan_speculation_composes_with_churn_exactly():
    """w0 finishes its batch at t=1 and is killed idle at t=1.25: the 1.75
    backup must land on w1 (lowest *alive* free worker) on both substrates."""
    from repro.cluster import ChurnSchedule

    speeds = (1.0, 1.0, 1.0, 0.25)
    sched = ChurnSchedule(times=(1.25, 5.0), wids=(0, 0), ups=(False, True))
    er = run_one(speeds, SPEC, churn_schedule=sched)
    sr = _scan_one(speeds, SPEC, churn_schedule=sched)
    assert er.n_worker_failures == 1 and er.n_speculative == 1
    assert er.records[0].compute_time == 2.75
    _assert_scan_matches_engine(er, sr)


def test_jax_scan_speculation_multi_job_resets_per_dispatch():
    """Three queued jobs each get their own observation window and backup
    budget; per-job spec_used/median reset at dispatch on both substrates."""
    er = run_one((1.0, 1.0, 1.0, 0.25), SPEC, n_jobs=3)
    sr = _scan_one((1.0, 1.0, 1.0, 0.25), SPEC, n_jobs=3)
    assert er.n_speculative == 3
    _assert_scan_matches_engine(er, sr)


def test_jax_scan_speculation_with_planned_redundancy():
    """b=2, r=2: planned replicas already cover the stragglers, so the
    reactive layer stays silent -- identically on both substrates."""
    er = run_one((1.0, 1.0, 0.25, 0.25), SPEC, n_batches=2)
    sr = _scan_one((1.0, 1.0, 0.25, 0.25), SPEC, n_batches=2)
    assert er.n_speculative == 0
    _assert_scan_matches_engine(er, sr)


def test_jax_scan_speculation_f64_lanes_exact():
    import jax

    er = run_one((1.0, 1.0, 1.0, 0.25), SPEC)
    prev = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", True)
    try:
        sr = _scan_one((1.0, 1.0, 1.0, 0.25), SPEC, dtype="float64")
    finally:
        jax.config.update("jax_enable_x64", prev)
    _assert_scan_matches_engine(er, sr)


def test_jax_scan_speculation_stochastic_pareto():
    """On a heavy tail the two substrates draw different task times, so we
    compare mean job latency by a 3-sigma z-test across independent runs."""
    from repro.cluster import simulate_epochs

    dist = Pareto(1.0, 1.5)
    spec = Speculation(interval=0.23, theta=2.0)
    n, n_jobs = 8, 40
    eng = []
    for seed in range(6):
        rep = run_one(
            tuple([1.0] * n), spec, n_jobs=n_jobs, dist=dist, seed=seed, n_batches=n
        )
        eng.append(rep.compute_times.mean())
    eng = np.array(eng)
    sc = Scenario(speculation=spec, cancel_redundant=True)
    sr = simulate_epochs(dist, n, n, np.zeros(n_jobs), 24, seed=100, scenario=sc)
    assert (np.asarray(sr.n_speculative) > 0).all()
    lanes = (np.asarray(sr.finishes) - np.asarray(sr.starts)).mean(axis=1)
    se = math.sqrt(eng.var(ddof=1) / len(eng) + lanes.var(ddof=1) / len(lanes))
    z = (eng.mean() - lanes.mean()) / se
    assert abs(z) < 3.0, z


def test_sample_job_times_speculation_kwarg_warns_scenario_does_not():
    with pytest.warns(DeprecationWarning, match="sample_job_times"):
        loose = sample_job_times(UNIT, 4, 4, 2, seed=0, speculation=SPEC, speeds=(1, 1, 1, 0.25))
    sc = Scenario(speculation=SPEC, speeds=(1, 1, 1, 0.25))
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        scoped = sample_job_times(UNIT, 4, 4, 2, seed=0, scenario=sc)
    assert np.array_equal(loose, scoped)
    assert (scoped == 2.75).all()
