"""Multi-device semantics tests (run in subprocesses with 8 fake CPU devices).

Covers:
  * sharded train step == single-device train step (bitwise-ish)
  * replicated-DP (replica x shard mesh) == plain DP gradients
  * int8 error-feedback compressed all-reduce: accuracy + telescoping EF
  * elastic restart: checkpoint from an 8-device mesh restores on 4 devices
"""
import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, devices: int = 8) -> subprocess.CompletedProcess:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    return subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=900,
    )


def _check(r):
    assert r.returncode == 0, f"STDOUT:\n{r.stdout[-2000:]}\nSTDERR:\n{r.stderr[-4000:]}"
    assert "PASS" in r.stdout, r.stdout[-2000:]


PRELUDE = """
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.models import build_model
from repro.optim import AdamW
from repro.runtime.train import init_state, jit_train_step, make_train_step
from repro.launch.mesh import make_mesh

cfg = get_config("qwen2-1.5b", smoke=True, param_dtype="float32", compute_dtype="float32")
model = build_model(cfg)
opt = AdamW(learning_rate=1e-2, weight_decay=0.0)
B, S = 8, 16
key = jax.random.key(0)
batch = {
    "tokens": jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size),
    "labels": jax.random.randint(jax.random.key(2), (B, S), 0, cfg.vocab_size),
    "loss_mask": jnp.ones((B, S), jnp.float32),
}
state0 = init_state(model, opt, key)
ref_step = jax.jit(make_train_step(model, opt))
ref_state, ref_metrics = ref_step(state0, batch)
ref_loss = float(ref_metrics["loss"])
"""


def test_sharded_step_matches_single_device():
    code = PRELUDE + """
shape = ShapeConfig("t", S, B, "train")
mesh = make_mesh((4, 2), ("data", "model"))
with mesh:
    fn, st_sh, b_sh = jit_train_step(mesh, model, opt, shape, donate=False)
    st = jax.device_put(init_state(model, opt, key), st_sh)
    bt = jax.device_put(batch, b_sh)
    new_state, metrics = fn(st, bt)
assert abs(float(metrics["loss"]) - ref_loss) < 1e-3, (float(metrics["loss"]), ref_loss)
# parameters after one step must match the single-device result
flat_a = jax.tree.leaves(jax.tree.map(np.asarray, new_state.params))
flat_b = jax.tree.leaves(jax.tree.map(np.asarray, ref_state.params))
for a, b in zip(flat_a, flat_b):
    np.testing.assert_allclose(a, b, atol=2e-3, rtol=2e-3)
print("PASS")
"""
    _check(_run(code))


def test_rdp_mesh_matches_plain_dp():
    """replica x shard factorization is numerically plain DP (DESIGN §3)."""
    code = PRELUDE + """
shape = ShapeConfig("t", S, B, "train")
# RDP: 2 replicas x 2 shards x 2 model; batch shards over "shard" only
mesh = make_mesh((2, 2, 2), ("replica", "shard", "model"))
with mesh:
    fn, st_sh, b_sh = jit_train_step(mesh, model, opt, shape, donate=False)
    st = jax.device_put(init_state(model, opt, key), st_sh)
    bt = jax.device_put(batch, b_sh)
    new_state, metrics = fn(st, bt)
assert abs(float(metrics["loss"]) - ref_loss) < 1e-3
flat_a = jax.tree.leaves(jax.tree.map(np.asarray, new_state.params))
flat_b = jax.tree.leaves(jax.tree.map(np.asarray, ref_state.params))
for a, b in zip(flat_a, flat_b):
    np.testing.assert_allclose(a, b, atol=2e-3, rtol=2e-3)
print("PASS")
"""
    _check(_run(code))


def test_microbatched_step_matches_full_batch():
    code = PRELUDE + """
mb_step = jax.jit(make_train_step(model, opt, microbatches=4))
new_state, metrics = mb_step(state0, batch)
# same data, same global batch -> same result up to fp32 reduction order
assert abs(float(metrics["loss"]) - ref_loss) < 1e-4
flat_a = jax.tree.leaves(jax.tree.map(np.asarray, new_state.params))
flat_b = jax.tree.leaves(jax.tree.map(np.asarray, ref_state.params))
for a, b in zip(flat_a, flat_b):
    np.testing.assert_allclose(a, b, atol=5e-4, rtol=5e-4)
print("PASS")
"""
    _check(_run(code))


def test_compressed_allreduce():
    code = """
import jax, jax.numpy as jnp, numpy as np
from functools import partial
from jax.sharding import PartitionSpec as P
from repro.distributed.collectives import compressed_allreduce_mean
from repro.distributed.compat import shard_map
from repro.launch.mesh import make_mesh

mesh = make_mesh((8,), ("pod",))
x = jax.random.normal(jax.random.key(0), (8, 64, 64))
ef = jnp.zeros_like(x)

@partial(shard_map, mesh=mesh, in_specs=(P("pod"), P("pod")), out_specs=(P("pod"), P("pod")))
def reduce_fn(xs, efs):
    m, e = compressed_allreduce_mean(xs[0], efs[0], "pod")
    return m[None], e[None]

mean_est, ef1 = reduce_fn(x, ef)
true_mean = x.mean(axis=0)
# one-shot int8 error vs the true mean: bounded by the quantization step
err = float(jnp.abs(np.asarray(mean_est)[0] - true_mean).max())
scale = float(jnp.abs(x).max()) / 127.0
assert err <= scale * 1.01, (err, scale)

# error feedback telescopes: the TIME-AVERAGED estimate is unbiased, so the
# running mean of the outputs converges to the true mean (each single step
# still carries one quantization-step of noise)
efs = ef
running = jnp.zeros_like(true_mean)
for i in range(30):
    m, efs = reduce_fn(x, efs)
    running = running + np.asarray(m)[0]
avg_err = float(jnp.abs(running / 30 - true_mean).max())
assert avg_err < err * 0.25, (avg_err, err)
# compression is worthwhile: int8 payload is 4x smaller than f32
print("PASS", err, avg_err)
"""
    _check(_run(code))


def test_checkpoint_cross_mesh_restore():
    """Elastic scaling: save on an 8-device mesh, restore on 4 devices."""
    code_save = PRELUDE + """
import tempfile, pathlib
from repro.checkpoint import CheckpointManager
shape = ShapeConfig("t", S, B, "train")
mesh = make_mesh((4, 2), ("data", "model"))
with mesh:
    fn, st_sh, b_sh = jit_train_step(mesh, model, opt, shape, donate=False)
    st = jax.device_put(init_state(model, opt, key), st_sh)
    bt = jax.device_put(batch, b_sh)
    st, _ = fn(st, bt)
mgr = CheckpointManager("/tmp/repro_test_xmesh", keep=1)
mgr.save(1, st)
print("PASS saved")
"""
    _check(_run(code_save, devices=8))
    code_restore = PRELUDE + """
from repro.checkpoint import CheckpointManager
from repro.runtime.train import state_shardings
shape = ShapeConfig("t", S, B, "train")
mesh = make_mesh((2, 2), ("data", "model"))  # different topology (4 devices)
mgr = CheckpointManager("/tmp/repro_test_xmesh", keep=1)
like = jax.eval_shape(lambda: init_state(model, opt, key))
restored, step = mgr.restore(like)
assert step == 1
with mesh:
    st_sh = state_shardings(mesh, model, opt)
    st = jax.device_put(restored, st_sh)  # reshard onto the smaller mesh
    fn, _, b_sh = jit_train_step(mesh, model, opt, shape, donate=False)
    bt = jax.device_put(batch, b_sh)
    st2, metrics = fn(st, bt)
assert np.isfinite(float(metrics["loss"]))
print("PASS")
"""
    _check(_run(code_restore, devices=4))


def test_seq_sharded_kv_decode_matches_plain():
    """decode_kv_seq_sharded (true-KV ring sharded over TP by sequence,
    shard_map flash-combine) must equal the plain repeated-KV decode."""
    code = """
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.models import build_model
from repro.runtime.serve import jit_serve_step
from repro.launch.mesh import make_mesh

B, S_PRE, S_MAX = 8, 12, 16
cfg = get_config("qwen2-1.5b", smoke=True, param_dtype="float32",
                 compute_dtype="float32", pad_heads_to=4, decode_kv_seq_sharded=True)
model = build_model(cfg)
params = model.init(jax.random.key(0))
cfg_plain = get_config("qwen2-1.5b", smoke=True, param_dtype="float32",
                       compute_dtype="float32", pad_heads_to=4)
model_plain = build_model(cfg_plain)
toks = jax.random.randint(jax.random.key(1), (B, S_MAX), 0, cfg.vocab_size)

mesh = make_mesh((2, 4), ("data", "model"))
shape = ShapeConfig("d", S_MAX, B, "decode")
with mesh:
    step, p_sh, c_sh, tok_sh = jit_serve_step(mesh, model, shape, donate=False)
    pt = jax.device_put(params, p_sh)
    logits, cache, t = model.prefill(params, {"tokens": toks[:, :S_PRE]}, max_len=S_MAX)
    cache = jax.device_put(cache, c_sh)
    logits_ref, cache_ref, t_ref = model_plain.prefill(
        params, {"tokens": toks[:, :S_PRE]}, max_len=S_MAX)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(logits_ref), atol=2e-3, rtol=2e-3)
    for i in range(3):
        tok = toks[:, S_PRE+i:S_PRE+i+1]
        logits, cache, t = step(pt, cache, tok, t)
        logits_ref, cache_ref, t_ref = model_plain.decode_step(params, cache_ref, tok, t_ref)
        np.testing.assert_allclose(np.asarray(logits), np.asarray(logits_ref),
                                   atol=3e-3, rtol=3e-3, err_msg=f"step {i}")
print("PASS")
"""
    _check(_run(code))
