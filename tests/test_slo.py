"""Tail-SLO planning: per-class stream state, quantile accuracy, plan_slo.

Three contracts pinned here:

  * the per-class response state (`class_count` / `class_resp_sum` /
    `class_hist`) carried by the streaming kernel equals the sequential
    host fold of the materialized outputs **bit for bit** on f64 lanes,
    under any slab partition;
  * the histogram quantile estimator is conservative within its committed
    bound: for the k-th pooled order statistic r_k (k = ceil(q * total)),
    ``r_k <= quantile(q) <= r_k * (1 + STREAM_QUANTILE_RTOL)`` -- on
    adversarial workloads (heavy Pareto tails, near-degenerate service
    times, multi-slab boundaries);
  * `plan_slo` returns the cheapest feasible (B, r, scheduler) -- a
    feasible verdict survives a fresh independent simulation, an
    impossible target yields an explicit infeasible verdict (never a
    silent fallback), and the grid exhibits the paper's second core
    result: the mean-optimal candidate is not the SLO-optimal one.
"""
import jax
import numpy as np
import pytest

from repro.cluster import (
    SLO,
    STREAM_QUANTILE_RTOL,
    Scenario,
    fold_stream_stats,
    simulate_stream,
)
from repro.cluster.stream import _CLASS_FIELDS
from repro.core import RedundancyPlanner
from repro.core.service_time import Exponential, Pareto
from repro.core.traces import TraceJob, TraceStream, poisson_stream


@pytest.fixture
def x64():
    prev = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", True)
    try:
        yield
    finally:
        jax.config.update("jax_enable_x64", prev)


def _mixed_stream(n_jobs=90, seed=5) -> TraceStream:
    """Two far-apart classes so per-class quantiles differ visibly.

    Arrivals are spread thin (mean gap 400 s against ~1-100 s services), so
    responses track each class's own service law instead of a shared queue
    backlog -- the regime where per-class quantiles must separate.
    """
    rng = np.random.default_rng(77)
    fast = TraceJob("fast", "exponential", 1.0 + rng.exponential(0.5, size=300))
    slow = TraceJob("slow", "heavy", 30.0 * rng.pareto(1.6, size=300) + 30.0)
    arr_rng = np.random.default_rng(seed)
    arrivals = np.sort(arr_rng.uniform(0.0, 400.0 * n_jobs, size=n_jobs))
    job_ids = arr_rng.integers(0, 2, size=n_jobs)
    return TraceStream(arrivals=arrivals, job_ids=job_ids, sources=(fast, slow), seed=seed)


def _order_stat(resp: np.ndarray, q: float) -> float:
    """The k-th pooled order statistic the histogram estimator brackets."""
    x = np.sort(resp.ravel())
    k = int(np.ceil(q * x.size))
    return float(x[max(k, 1) - 1])


# --------------------------------------------------------------------------
# per-class stream state: bit-for-bit vs the host fold, slab-invariant
# --------------------------------------------------------------------------


def test_class_state_matches_fold_bitwise_f64(x64):
    st = _mixed_stream(90)
    sc = Scenario(outputs="full", dtype="float64", cancel_redundant=True)
    rep = simulate_stream(st, 6, 3, 4, scenario=sc, slab=32)
    folded = fold_stream_stats(
        rep.waits, rep.t_job, rep.busy_j, rep.planned_j, rep.saved_j,
        class_ids=st.job_ids, classes=("fast", "slow"),
    )
    assert rep.stats.classes == ("fast", "slow")
    for f in _CLASS_FIELDS:
        x, y = getattr(rep.stats, f), getattr(folded, f)
        assert x.dtype == y.dtype, f
        np.testing.assert_array_equal(x, y, err_msg=f)
    # class marginals are consistent with the pooled accumulators
    np.testing.assert_array_equal(rep.stats.class_count.sum(axis=1), rep.stats.count)
    np.testing.assert_array_equal(rep.stats.class_hist.sum(axis=1), rep.stats.hist)


@pytest.mark.parametrize("slab", [1, 7, None])
def test_class_state_slab_invariant(x64, slab):
    st = _mixed_stream(40)
    sc = Scenario(outputs="stream", dtype="float64")
    got = simulate_stream(st, 4, 2, 3, scenario=sc, slab=slab)
    ref = simulate_stream(st, 4, 2, 3, scenario=sc, slab=16)
    for f in _CLASS_FIELDS:
        np.testing.assert_array_equal(getattr(got, f), getattr(ref, f), err_msg=f)


def test_class_summary_and_quantile_lookup(x64):
    st = _mixed_stream(80)
    stats = simulate_stream(
        st, 4, 2, 3,
        scenario=Scenario(outputs="stream", dtype="float64", size_dependent=False),
    )
    summ = stats.class_summary()
    assert set(summ) == {"fast", "slow"}
    # medians separate by class (tails can mix: a fast job behind a giant
    # slow job inherits its wait, so only the bulk is class-ordered)
    assert summ["slow"]["p50_response"] > summ["fast"]["p50_response"]
    assert summ["slow"]["mean_response"] > summ["fast"]["mean_response"]
    assert stats.quantile(0.9, job_class="slow") == stats.quantile(0.9, job_class=1)
    with pytest.raises(KeyError):
        stats.quantile(0.9, job_class="nope")
    # the epoch-scan stream lane carries no class state: explicit error
    bare = stats.__class__(**{
        f: getattr(stats, f)
        for f in ("count", "resp_sum", "resp_sq", "resp_min", "resp_max",
                  "comp_sum", "busy_sum", "saved_sum", "hist")
    })
    with pytest.raises(ValueError, match="per-class"):
        bare.quantile(0.9, job_class=0)
    with pytest.raises(ValueError, match="per-class"):
        bare.class_summary()


# --------------------------------------------------------------------------
# committed quantile accuracy on adversarial workloads
# --------------------------------------------------------------------------


def _adversarial_sources(kind: str):
    rng = np.random.default_rng(13)
    if kind == "pareto_tail":
        # alpha ~ 1.1: extreme right tail spanning many histogram decades
        x = 2.0 * (rng.pareto(1.1, size=500) + 1.0)
        return (TraceJob("heavy", "heavy", x),)
    if kind == "degenerate":
        # near-constant service: every response lands in one or two bins
        x = 5.0 + rng.uniform(-1e-9, 1e-9, size=400)
        return (TraceJob("flat", "exponential", x),)
    raise AssertionError(kind)


@pytest.mark.parametrize("kind", ["pareto_tail", "degenerate"])
@pytest.mark.parametrize("slab", [7, None])
def test_stream_quantile_within_committed_bound(x64, kind, slab):
    sources = _adversarial_sources(kind)
    rng = np.random.default_rng(3)
    n = 120
    arrivals = np.sort(rng.uniform(0.0, 50.0 * n, size=n))
    st = TraceStream(
        arrivals=arrivals,
        job_ids=np.zeros(n, dtype=np.int64),
        sources=sources,
        seed=3,
    )
    rep = simulate_stream(
        st, 4, 2, 3,
        scenario=Scenario(outputs="full", dtype="float64", size_dependent=False),
        slab=slab,
    )
    resp = np.asarray(rep.response_times, np.float64)
    for q in (0.5, 0.9, 0.99, 0.999):
        r_k = _order_stat(resp, q)
        est = rep.stats.quantile(q)
        assert r_k <= est <= r_k * (1.0 + STREAM_QUANTILE_RTOL) * (1 + 1e-12), (
            kind, q, r_k, est,
        )
        est_c = rep.stats.quantile(q, job_class=0)
        assert r_k <= est_c <= r_k * (1.0 + STREAM_QUANTILE_RTOL) * (1 + 1e-12)


def test_stream_quantile_per_class_bound_mixed(x64):
    st = _mixed_stream(100)
    rep = simulate_stream(
        st, 4, 2, 4,
        scenario=Scenario(outputs="full", dtype="float64", size_dependent=False),
        slab=33,
    )
    resp = np.asarray(rep.response_times, np.float64)
    for c, name in enumerate(("fast", "slow")):
        rc = resp[:, st.job_ids == c]
        for q in (0.5, 0.95, 0.99):
            r_k = _order_stat(rc, q)
            est = rep.stats.quantile(q, job_class=name)
            assert r_k <= est <= r_k * (1.0 + STREAM_QUANTILE_RTOL) * (1 + 1e-12), (
                name, q, r_k, est,
            )


# --------------------------------------------------------------------------
# plan_slo: cheapest feasible candidate, explicit infeasibility
# --------------------------------------------------------------------------


def test_plan_slo_feasible_survives_fresh_simulation():
    planner = RedundancyPlanner(4)
    slo = SLO(quantile=0.99, target_s=40.0, arrival_rate=0.05)
    plan = planner.plan_slo(
        Pareto(sigma=2.0, alpha=1.5), slo,
        n_jobs=400, n_reps=3, seed=1, schedulers=("fifo_gang", "packed"),
    )
    best = plan.require_feasible()
    assert plan.feasible and best.feasible
    assert best.achieved[0] <= slo.target_s
    # cheapest: no other feasible candidate is cheaper
    for c in plan.candidates:
        if c.feasible:
            assert best.cost_worker_seconds <= c.cost_worker_seconds + 1e-9
    # the verdict holds on a fresh, independently-seeded arrival stream:
    # re-simulate the winning candidate alone and re-check the quantile
    # (conservative estimator + sampling slack of one histogram bin)
    rng = np.random.default_rng(np.random.SeedSequence((1, 0x51_0, 0)))
    src = TraceJob(
        "pareto", "fitted", Pareto(sigma=2.0, alpha=1.5).sample_np(rng, (4000,))
    )
    fresh = poisson_stream((src,), slo.arrival_rate, 400, seed=99)
    stats = simulate_stream(
        fresh, 4, best.n_batches, 3,
        scenario=Scenario(
            scheduler=best.scheduler,
            workers_per_job=best.workers_per_job,
            size_dependent=False,
            outputs="stream",
        ),
    )
    got = stats.quantile(slo.quantile)
    assert got <= slo.target_s * (1.0 + STREAM_QUANTILE_RTOL), (best, got)


def test_plan_slo_impossible_target_is_explicit():
    planner = RedundancyPlanner(4)
    plan = planner.plan_slo(
        Exponential(mu=1.0),
        SLO(quantile=0.99, target_s=1e-4, arrival_rate=0.05),
        n_jobs=150, n_reps=2, seed=0, schedulers=("fifo_gang",),
    )
    assert not plan.feasible
    assert plan.best is None
    assert all(not c.feasible for c in plan.candidates)
    with pytest.raises(ValueError, match="no \\(B, r, scheduler\\)"):
        plan.require_feasible()


def test_plan_slo_mean_optimal_differs_from_tail_optimal():
    """The paper's second core result, as a planning assertion.

    On this grid the candidate with the best *mean* response buys extra
    replication (r=2 pools), while the cheapest candidate meeting the p99
    target is the unreplicated one -- mean-optimal and SLO-optimal provably
    differ, and cost (worker-seconds) is what separates them.
    """
    planner = RedundancyPlanner(4)
    plan = planner.plan_slo(
        Pareto(sigma=2.0, alpha=1.5),
        SLO(quantile=0.99, target_s=40.0, arrival_rate=0.05),
        n_jobs=400, n_reps=3, seed=1, schedulers=("fifo_gang", "packed"),
    )
    best = plan.require_feasible()
    mean_opt = min(plan.candidates, key=lambda c: c.mean_response)
    key = lambda c: (c.scheduler, c.workers_per_job, c.n_batches)
    assert key(mean_opt) != key(best)
    assert mean_opt.cost_worker_seconds > best.cost_worker_seconds
    # and the mean-optimal point is itself feasible here: the planner chose
    # the *cheaper* feasible candidate, not the best-mean one
    assert mean_opt.feasible


def test_plan_slo_per_class_space_sharing():
    rng = np.random.default_rng(21)
    fast = TraceJob("fast", "exponential", 1.0 + rng.exponential(0.3, size=500))
    slow = TraceJob("slow", "heavy", 4.0 * (rng.pareto(1.8, size=500) + 1.0))
    slos = (
        SLO(quantile=0.9, target_s=12.0, arrival_rate=0.08, job_class="fast"),
        SLO(quantile=0.9, target_s=80.0, arrival_rate=0.08, job_class="slow"),
    )
    planner = RedundancyPlanner(4)
    plan = planner.plan_slo(
        (fast, slow), slos,
        n_jobs=300, n_reps=2, seed=4, schedulers=("packed", "balanced"),
    )
    assert plan.classes == ("fast", "slow")
    assert all(len(c.achieved) == 2 for c in plan.candidates)
    # per-class re-ranking uses only that class's SLOs
    for name in ("fast", "slow"):
        b = plan.best_for(name)
        if b is not None:
            i = plan.classes.index(name)
            assert b.achieved[i] <= slos[i].target_s
    with pytest.raises(KeyError):
        plan.best_for("nope")
    # a joint-feasible plan must satisfy both classes at once
    if plan.feasible:
        assert all(
            a <= s.target_s for a, s in zip(plan.best.achieved, slos)
        )


def test_plan_slo_validation_errors():
    planner = RedundancyPlanner(4)
    with pytest.raises(ValueError, match="needs an SLO"):
        planner.plan_slo(Exponential(mu=1.0))
    with pytest.raises(ValueError, match="arrival_rate"):
        planner.plan_slo(
            Exponential(mu=1.0),
            (SLO(arrival_rate=1.0), SLO(arrival_rate=2.0)),
            n_jobs=10,
        )
    with pytest.raises(ValueError, match="job_class"):
        planner.plan_slo(
            Exponential(mu=1.0), SLO(job_class="missing"), n_jobs=10
        )
    with pytest.raises(ValueError, match="unknown scheduler"):
        planner.plan_slo(
            Exponential(mu=1.0), SLO(target_s=5.0), n_jobs=10,
            schedulers=("warp",),
        )
    with pytest.raises(ValueError, match="must divide"):
        planner.plan_slo(
            Exponential(mu=1.0), SLO(target_s=5.0), n_jobs=10,
            schedulers=("packed",), pool_widths=(3,),
        )


def test_plan_slo_via_scenario_slo_field():
    sc = Scenario(
        slo=SLO(quantile=0.9, target_s=50.0, arrival_rate=0.05),
        size_dependent=False,
    )
    planner = RedundancyPlanner(2)
    plan = planner.plan_slo(
        Exponential(mu=0.5), scenario=sc,
        n_jobs=120, n_reps=2, seed=2, schedulers=("fifo_gang",),
    )
    assert plan.slos == (sc.slo,)
    assert plan.source == "stream"


def test_plan_slo_dynamic_lane_epoch_scan():
    sc = Scenario(speeds=(1.0, 0.5), size_dependent=False)
    planner = RedundancyPlanner(2)
    plan = planner.plan_slo(
        Exponential(mu=0.5),
        SLO(quantile=0.9, target_s=60.0, arrival_rate=0.05),
        scenario=sc, n_jobs=40, n_reps=2, seed=3,
        schedulers=("fifo_gang",),
    )
    assert plan.source == "epoch_scan"
    assert all(c.scheduler == "fifo_gang" for c in plan.candidates)
    # dynamic + multiple classes / per-class SLOs: explicit rejection
    with pytest.raises(ValueError, match="single job class"):
        planner.plan_slo(
            (Exponential(mu=0.5), Exponential(mu=1.0)),
            SLO(quantile=0.9, target_s=60.0, arrival_rate=0.05),
            scenario=sc, n_jobs=20, n_reps=2, schedulers=("fifo_gang",),
        )
    with pytest.raises(ValueError, match="fifo_gang"):
        planner.plan_slo(
            Exponential(mu=0.5),
            SLO(quantile=0.9, target_s=60.0, arrival_rate=0.05),
            scenario=sc, n_jobs=20, n_reps=2, schedulers=("packed",),
        )
