"""Differential harness: the jax epoch-scan engine vs the Python event engine.

Every dynamic the event engine expresses -- fail/join churn with replica
rescue, heterogeneous speeds, FIFO arrivals, replica cancellation, online
replanning -- must be replayed by ``repro.cluster.epoch_scan`` either

  * **exactly**, when both backends share one churn schedule and a degenerate
    (constant) service-time distribution pins every draw: full trajectory,
    worker-seconds, cancelled-seconds-saved, failure/rescue counts, and epoch
    boundaries match to float32 tolerance; or
  * **in distribution**, at 3 sigma of Monte-Carlo error on compute/response
    times when draws are random, with the accounting *identities* (same-seed
    cancel on/off: identical compute times and ``worker_seconds + saved ==
    worker_seconds(off)``) holding exactly per rep within the backend.

Scenario configs come from ``tests/strategies.py`` -- shared with the engine
and backend suites instead of hand-rolled here.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ModuleNotFoundError:  # test extra not installed: seeded fallback engine
    from _hypothesis_compat import given, settings, st

import strategies as scn
from repro.cluster import (
    ChurnProcess,
    ClusterEngine,
    Job,
    ReplanConfig,
    sample_job_times,
    simulate_epochs,
    simulate_fifo,
)
from repro.cluster.epoch_scan import frontier_job_times_dynamic
from repro.cluster.workers import ChurnSchedule
from repro.core import analysis
from repro.core.planner import RedundancyPlanner, plan_sweep
from repro.core.service_time import Empirical, Exponential, Pareto, ShiftedExponential


def _z_mean(a: np.ndarray, b: np.ndarray) -> float:
    se = np.sqrt(a.var() / a.size + b.var() / b.size)
    if se == 0.0:  # both degenerate (e.g. deterministic counts): exact compare
        return 0.0 if a.mean() == b.mean() else np.inf
    return float(abs(a.mean() - b.mean()) / se)


def _engine_runs(dist, n, b, n_jobs, n_seeds, seed0=100, **kw):
    """Per-run mean compute/response times from the event engine."""
    ct, rt = [], []
    for s in range(n_seeds):
        jobs = [Job(job_id=i, dist=dist, n_tasks=n) for i in range(n_jobs)]
        rep = ClusterEngine(n, seed=seed0 + s, n_batches=b, **kw).run(jobs)
        t = rep.compute_times
        ct.append(t[np.isfinite(t)].mean())
        r = rep.response_times
        rt.append(r[np.isfinite(r)].mean())
    return np.array(ct), np.array(rt)


# --------------------------------------------------------------------------
# static case: the epoch scan degenerates to the known-good semantics
# --------------------------------------------------------------------------


def test_static_matches_engine_and_fifo_scan():
    d = Exponential(1.0)
    rep = simulate_epochs(d, 8, 4, np.zeros(20), 150, seed=0)
    t_py = sample_job_times(d, 8, 4, 2000, seed=1, backend="python")
    assert _z_mean(rep.compute_times.ravel(), t_py) < 3.0
    # FIFO arrivals, no churn: agrees with the dedicated fifo lax.scan
    arr = np.arange(10) * 1.5
    a = simulate_epochs(Pareto(1.0, 2.0), 8, 2, arr, 400, seed=3)
    f = simulate_fifo(Pareto(1.0, 2.0), 8, 2, arr, 400, seed=9)
    assert _z_mean(a.response_times.mean(axis=1), f.response_times.mean(axis=1)) < 3.0
    assert (a.queue_waits >= -1e-5).all()
    assert (np.diff(a.starts, axis=1) >= -1e-4).all()


def test_deterministic_and_seed_sensitive():
    d = Pareto(1.0, 2.0)
    churn = ChurnProcess(fail_rate=0.05, mean_downtime=1.0)
    a = simulate_epochs(d, 6, 3, np.zeros(8), 5, seed=3, churn=churn, churn_pairs_per_worker=2)
    b = simulate_epochs(d, 6, 3, np.zeros(8), 5, seed=3, churn=churn, churn_pairs_per_worker=2)
    c = simulate_epochs(d, 6, 3, np.zeros(8), 5, seed=4, churn=churn, churn_pairs_per_worker=2)
    assert np.array_equal(a.finishes, b.finishes)
    assert np.array_equal(a.worker_seconds, b.worker_seconds)
    assert not np.array_equal(a.finishes, c.finishes)


def test_churn_horizon_autosizes_and_warns_when_truncation_bites():
    """Sampled churn no longer silently truncates under long streams: the
    default horizon auto-sizes from the stream length (no warning, no flag),
    while an explicit short horizon that the timeline outruns emits a loud
    RuntimeWarning and flags the report."""
    import warnings as _warnings

    d = Empirical(samples=(1.0,))
    churn = ChurnProcess(fail_rate=0.5, mean_downtime=0.5)
    arr = np.arange(40, dtype=np.float64)  # ~40+ s stream, churn period 2.5 s
    with _warnings.catch_warnings():
        _warnings.simplefilter("error", RuntimeWarning)  # auto horizon covers it
        rep = simulate_epochs(d, 4, 2, arr, 3, seed=5, churn=churn)
    assert rep.churn_truncated is not None and not rep.churn_truncated.any()
    with pytest.warns(RuntimeWarning, match="churn horizon"):
        short = simulate_epochs(
            d, 4, 2, arr, 3, seed=5, churn=churn, churn_pairs_per_worker=1
        )
    assert short.churn_truncated.any()
    # churn-free runs carry no flag at all
    assert simulate_epochs(d, 4, 2, arr[:4], 1, seed=5).churn_truncated is None


# --------------------------------------------------------------------------
# exact differential: shared schedule + constant service time pins every draw
# --------------------------------------------------------------------------


@pytest.mark.parametrize("cancel", [False, True], ids=["cancel_off", "cancel_on"])
def test_exact_trajectory_on_shared_schedule(cancel):
    """Constant task times make both backends' draws identical, so churn,
    rescue, cancellation, hetero speeds, and all accounting must replay the
    event engine bit-comparably (float32 tolerance)."""
    d = Empirical(samples=(1.3,))
    n, b, n_jobs = 6, 3, 8
    sched = ChurnSchedule(
        times=(0.7, 1.9, 3.35, 5.1, 7.77, 9.4),
        wids=(2, 5, 2, 0, 5, 0),
        ups=(False, False, True, False, True, True),
    )
    speeds = (1.0, 1.5, 0.7, 1.2, 0.9, 1.1)
    jobs = [Job(job_id=i, dist=d, n_tasks=n) for i in range(n_jobs)]
    er = ClusterEngine(
        n, seed=3, n_batches=b, cancel_redundant=cancel, speeds=speeds, churn_schedule=sched
    ).run(jobs)
    vr = simulate_epochs(
        d,
        n,
        b,
        np.zeros(n_jobs),
        1,
        seed=3,
        cancel_redundant=cancel,
        speeds=speeds,
        churn_schedule=sched,
    )
    e_start = np.array([r.start for r in er.records])
    e_fin = np.array([r.finish for r in er.records])
    assert np.allclose(vr.starts[0], e_start, rtol=1e-4)
    assert np.allclose(vr.finishes[0], e_fin, rtol=1e-4)
    # worker-seconds accounting matches the event engine *exactly* (f32 eps)
    ea, va = er.accounting(), vr.accounting()
    assert set(ea) == set(va)
    assert np.isclose(va["worker_seconds"][0], ea["worker_seconds"], rtol=1e-5)
    assert np.isclose(
        va["cancelled_seconds_saved"][0], ea["cancelled_seconds_saved"], rtol=1e-5, atol=1e-6
    )
    assert va["n_worker_failures"][0] == ea["n_worker_failures"] == 3
    assert va["n_replicas_rescued"][0] == ea["n_replicas_rescued"]
    assert ea["n_replicas_rescued"] > 0
    # same epoch boundaries on both backends
    vt = vr.epoch_times[0]
    assert np.allclose(vt[np.isfinite(vt)], np.asarray(er.epoch_times), rtol=1e-5)


def test_churn_event_unblocking_dispatch_sets_start_time():
    """Regression: when the churn event *itself* frees the gang (a fail
    killing the last straggler), the next job starts at the event time --
    not at the stale last-completion cursor."""
    d = Empirical(samples=(2.0,))
    speeds = (1.0, 0.25)  # worker 1 straggles 4x
    sched = ChurnSchedule(times=(5.0,), wids=(1,), ups=(False,))
    jobs = [Job(job_id=i, dist=d, n_tasks=2) for i in range(2)]
    er = ClusterEngine(2, seed=0, n_batches=1, speeds=speeds, churn_schedule=sched).run(jobs)
    vr = simulate_epochs(d, 2, 1, np.zeros(2), 1, seed=0, speeds=speeds, churn_schedule=sched)
    # job 0's batch wins at t=4 (worker 0), but the straggler holds the gang
    # until its worker fails at t=5; job 1 then runs on the 1 alive worker
    assert er.records[1].start == pytest.approx(5.0)
    assert er.records[1].finish == pytest.approx(9.0)
    assert np.allclose(vr.starts[0], [r.start for r in er.records], rtol=1e-5)
    assert np.allclose(vr.finishes[0], [r.finish for r in er.records], rtol=1e-5)


# --------------------------------------------------------------------------
# accounting identities (exact per rep, within the backend)
# --------------------------------------------------------------------------


def test_cancellation_identity_heterogeneous():
    """Same seed, hetero speeds: cancellation must not change compute times
    and must reclaim exactly the redundant tails: ws(on) + saved == ws(off)."""
    speeds = scn.seeded_speeds(8, seed=2)
    kw = dict(seed=5, speeds=speeds)
    on = simulate_epochs(
        Pareto(1.0, 2.0), 8, 2, np.zeros(10), 60, cancel_redundant=True, **kw
    )
    off = simulate_epochs(
        Pareto(1.0, 2.0), 8, 2, np.zeros(10), 60, cancel_redundant=False, **kw
    )
    # same draws => same compute times; f32 rounding differs because absolute
    # start offsets differ between the runs (see the module's precision note)
    assert np.allclose(on.compute_times, off.compute_times, rtol=1e-4, atol=1e-3)
    assert np.allclose(
        on.worker_seconds + on.cancelled_seconds_saved, off.worker_seconds, rtol=1e-4
    )
    assert (on.cancelled_seconds_saved > 0).all()
    assert (off.cancelled_seconds_saved == 0).all()
    assert (on.response_times <= off.response_times + 1e-3).all()


def test_uniform_speed_rescales_exactly():
    """speeds = c on every worker is a pure time rescale of speeds = 1."""
    slow = simulate_epochs(Exponential(1.0), 6, 3, np.zeros(30), 8, seed=4)
    fast = simulate_epochs(Exponential(1.0), 6, 3, np.zeros(30), 8, seed=4, speeds=[4.0] * 6)
    assert np.allclose(fast.compute_times * 4.0, slow.compute_times, rtol=1e-5)
    assert np.allclose(fast.worker_seconds * 4.0, slow.worker_seconds, rtol=1e-5)


# --------------------------------------------------------------------------
# stochastic differential: 3-sigma equivalence under churn / hetero speeds
# --------------------------------------------------------------------------


def test_churned_compute_and_response_match_engine():
    """Both backends replay one shared churn schedule; per-stream mean
    compute and response times must agree at 3 sigma."""
    d = ShiftedExponential(delta=1.0, mu=0.5)
    n, b, n_jobs = 8, 4, 24
    sched = scn.seeded_schedule(n, seed=7, fail_rate=0.06, mean_downtime=1.0, pairs_per_worker=4)
    assert len(sched) > 0
    e_ct, e_rt = _engine_runs(d, n, b, n_jobs, 30, churn_schedule=sched)
    vr = simulate_epochs(
        d, n, b, np.zeros(n_jobs), 300, seed=1, churn_schedule=sched, cancel_redundant=False
    )
    assert np.isfinite(vr.compute_times).all()
    assert _z_mean(e_ct, vr.compute_times.mean(axis=1)) < 3.0
    assert _z_mean(e_rt, vr.response_times.mean(axis=1)) < 3.0
    assert (vr.n_worker_failures > 0).all()


def test_rescue_counts_match_engine_on_shared_schedule():
    """r = 1 makes every failure kill a batch's only replica: rescues are
    load-bearing, and their counts must match the engine statistically."""
    d = ShiftedExponential(delta=1.0, mu=0.5)
    n = 6
    sched = scn.seeded_schedule(n, seed=3, fail_rate=0.1, mean_downtime=0.5, pairs_per_worker=4)
    n_resc, n_fail = [], []
    for s in range(25):
        jobs = [Job(job_id=i, dist=d, n_tasks=n) for i in range(16)]
        rep = ClusterEngine(n, seed=200 + s, n_batches=n, churn_schedule=sched).run(jobs)
        n_resc.append(rep.n_replicas_rescued)
        n_fail.append(rep.n_worker_failures)
    vr = simulate_epochs(d, n, n, np.zeros(16), 200, seed=2, churn_schedule=sched)
    assert np.isfinite(vr.compute_times).all()
    assert vr.n_replicas_rescued.mean() > 0
    assert _z_mean(np.array(n_resc, float), vr.n_replicas_rescued.astype(float)) < 3.0
    assert _z_mean(np.array(n_fail, float), vr.n_worker_failures.astype(float)) < 3.0


def test_heterogeneous_speeds_match_engine():
    d = Exponential(1.0)
    n, b = 6, 3
    speeds = scn.seeded_speeds(n, seed=11, lo=0.5, hi=2.0)
    e_ct, _ = _engine_runs(d, n, b, 30, 30, speeds=speeds)
    vr = simulate_epochs(d, n, b, np.zeros(30), 300, seed=6, speeds=speeds)
    assert _z_mean(e_ct, vr.compute_times.mean(axis=1)) < 3.0


# --------------------------------------------------------------------------
# online replanning: windowed refit converges on both backends
# --------------------------------------------------------------------------


@settings(max_examples=3, deadline=None)
@given(seed=st.integers(0, 30))
def test_replanning_converges_to_closed_form_optimum_both_backends(seed):
    """Exponential tails: Thm 3 says E[T] = H_B / mu, minimized at full
    diversity B* = 1.  Starting deliberately wrong (full parallelism), the
    windowed replanner must land on B* on *both* backends."""
    n, n_jobs = 8, 80
    dist = Exponential(mu=1.0)
    b_star = analysis.argmin_B(dist, n, metric="mean")
    cfg = ReplanConfig(window=256, refit_every=64, min_observations=64)

    ctl = cfg.to_controller(n)
    jobs = [Job(job_id=i, dist=dist, n_tasks=n) for i in range(n_jobs)]
    er = ClusterEngine(n, seed=seed, n_batches=n, controller=ctl).run(jobs)
    assert er.n_replans >= 1
    assert ctl.current.n_batches == b_star == 1
    assert er.records[-1].n_batches == b_star

    vr = simulate_epochs(dist, n, n, np.zeros(n_jobs), 2, seed=seed, replan=cfg)
    assert (vr.n_replans >= 1).all()
    assert (vr.final_n_batches == b_star).all()
    # same windowing => comparable replan cadence
    assert abs(vr.n_replans.mean() - er.n_replans) <= 3


def test_replanning_under_cancellation_censoring():
    """With cancellation only batch winners are observed; the jax replanner
    must undo the min-of-r censoring like the Python one, or it would fit a
    tail r times lighter and drift away from B*."""
    n, n_jobs = 8, 100
    dist = Exponential(mu=1.0)
    cfg = ReplanConfig(window=256, refit_every=32, min_observations=32)
    vr = simulate_epochs(
        dist, n, n, np.zeros(n_jobs), 4, seed=2, cancel_redundant=True, replan=cfg
    )
    assert (vr.n_replans >= 1).all()
    assert (vr.final_n_batches == 1).all()


# --------------------------------------------------------------------------
# planner integration: no Python fallback left
# --------------------------------------------------------------------------


def test_plan_cluster_dynamic_scenarios_stay_on_jax():
    n = 8
    churn = ChurnProcess(fail_rate=0.03, mean_downtime=1.0)
    speeds = scn.seeded_speeds(n, seed=1)
    plan = RedundancyPlanner(n).plan_cluster(
        Exponential(1.0), n_reps=96, seed=0, churn=churn, speeds=speeds
    )
    assert plan.source == "cluster_engine:jax"
    assert np.isfinite(plan.frontier_mean).all()
    # exponential tails under mild churn keep the full-diversity optimum,
    # and the python engine agrees on the pick
    plan_py = RedundancyPlanner(n).plan_cluster(
        Exponential(1.0), n_reps=96, seed=0, churn=churn, speeds=speeds, backend="python"
    )
    assert plan.n_batches == plan_py.n_batches == 1
    # replanning while scoring also stays on the jax path
    plan_r = RedundancyPlanner(n).plan_cluster(
        Exponential(1.0),
        n_reps=64,
        seed=0,
        churn=churn,
        replan=ReplanConfig(window=64, refit_every=32, min_observations=32),
    )
    assert plan_r.source == "cluster_engine:jax"
    assert plan_r.n_batches in analysis.feasible_B(n)


def test_frontier_dynamic_rows_match_engine_scoring():
    """Frontier rows under a shared schedule agree with per-candidate engine
    sampling at 3 sigma (the plan_cluster differential)."""
    n = 6
    d = Exponential(1.0)
    sched = scn.seeded_schedule(n, seed=5, fail_rate=0.04, mean_downtime=1.0, pairs_per_worker=3)
    cands = scn.frontier(n)
    rows = frontier_job_times_dynamic(
        d, n, cands, 240, seed=0, n_jobs=12, churn_schedule=sched
    )
    assert rows.shape[0] == len(cands)
    for i, b in enumerate(cands):
        e_ct, _ = _engine_runs(d, n, b, 12, 20, seed0=400 + 37 * i, churn_schedule=sched)
        v = rows[i].reshape(-1, 12).mean(axis=1)
        assert _z_mean(e_ct, v) < 3.0, (b, e_ct.mean(), v.mean())


def test_plan_sweep_dynamic_shapes_and_sources():
    plans = plan_sweep(
        [Exponential(1.0)],
        [4, 6],
        n_reps=48,
        seed=1,
        churn=ChurnProcess(fail_rate=0.02, mean_downtime=1.0),
        speeds=lambda n: scn.seeded_speeds(n, seed=n),
    )
    assert len(plans) == 1 and len(plans[0]) == 2
    for p, budget in zip(plans[0], [4, 6]):
        assert p.source == "cluster_engine:jax"
        assert p.n_workers == budget
        assert p.n_batches in analysis.feasible_B(budget)


# --------------------------------------------------------------------------
# generated-scenario invariants (shared strategies)
# --------------------------------------------------------------------------


@settings(max_examples=4, deadline=None)
@given(
    dist=scn.light_tailed_dists(),
    setup=scn.worker_setups(6, 6),
    churn=scn.churn_processes(),
    seed=st.integers(0, 99),
)
def test_epoch_scan_invariants_on_generated_scenarios(dist, setup, churn, seed):
    n, speeds = setup
    rep = simulate_epochs(
        dist,
        n,
        max(1, n // 2),
        np.zeros(8),
        3,
        seed=seed,
        speeds=speeds,
        churn=churn,
        churn_pairs_per_worker=2,
    )
    assert (rep.worker_seconds > 0).all()
    assert (rep.cancelled_seconds_saved == 0).all()  # cancel off
    ct = rep.compute_times
    assert (ct[np.isfinite(ct)] > 0).all()
    fin = np.isfinite(rep.starts)
    assert (rep.n_batches_used[fin] >= 1).all()
    assert (rep.n_batches_used * rep.replication_used <= n).all()
    # FIFO: dispatched jobs start in order
    for row in rep.starts:
        r = row[np.isfinite(row)]
        assert (np.diff(r) >= -1e-4).all()


# --------------------------------------------------------------------------
# validation
# --------------------------------------------------------------------------


def test_validation_errors():
    d = Exponential(1.0)
    with pytest.raises(ValueError, match="sorted"):
        simulate_epochs(d, 4, 2, [3.0, 1.0], 2)
    with pytest.raises(ValueError, match="n_batches"):
        simulate_epochs(d, 4, 9, np.zeros(2), 2)
    with pytest.raises(ValueError, match="speeds"):
        simulate_epochs(d, 4, 2, np.zeros(2), 2, speeds=[1.0, 2.0])
    with pytest.raises(ValueError, match="not both"):
        simulate_epochs(
            d, 4, 2, np.zeros(2), 2,
            churn=ChurnProcess(0.1, 1.0),
            churn_schedule=ChurnSchedule((), (), ()),
        )
    with pytest.raises(ValueError, match="window"):
        simulate_epochs(d, 8, 2, np.zeros(2), 2, replan=ReplanConfig(window=4))
    with pytest.raises(ValueError, match="alternate"):
        ChurnSchedule(times=(1.0,), wids=(0,), ups=(True,))
    with pytest.raises(ValueError, match="candidate"):
        frontier_job_times_dynamic(d, 4, [], 8)
    with pytest.raises(ValueError, match="not both"):
        ClusterEngine(
            4, churn=ChurnProcess(0.1, 1.0), churn_schedule=ChurnSchedule((), (), ())
        )
    # out-of-range schedule worker ids are rejected up front on BOTH backends
    bad_neg = ChurnSchedule(times=(1.0,), wids=(-1,), ups=(False,))
    bad_big = ChurnSchedule(times=(1.0,), wids=(7,), ups=(False,))
    for bad in (bad_neg, bad_big):
        with pytest.raises(ValueError, match="worker ids"):
            ClusterEngine(4, churn_schedule=bad)
        with pytest.raises(ValueError, match="worker ids"):
            simulate_epochs(d, 4, 2, np.zeros(2), 2, churn_schedule=bad)


# --------------------------------------------------------------------------
# scale-out contracts: rep chunking, device sharding, float64 lanes, buckets
# --------------------------------------------------------------------------


def test_rep_chunk_bit_identical():
    """n_reps in one chunk vs k chunks: per-lane seed derivation makes the
    device results bit-identical, not merely statistically equivalent."""
    d = Pareto(1.0, 2.0)
    churn = ChurnProcess(fail_rate=0.05, mean_downtime=1.0)
    kw = dict(seed=7, churn=churn, churn_pairs_per_worker=2, cancel_redundant=True)
    one = simulate_epochs(d, 6, 3, np.zeros(8), 30, **kw)
    for chunk in (7, 13, 30):
        part = simulate_epochs(d, 6, 3, np.zeros(8), 30, rep_chunk=chunk, **kw)
        assert np.array_equal(one.finishes, part.finishes)
        assert np.array_equal(one.starts, part.starts)
        assert np.array_equal(one.worker_seconds, part.worker_seconds)
        assert np.array_equal(one.cancelled_seconds_saved, part.cancelled_seconds_saved)
        assert np.array_equal(one.epoch_times, part.epoch_times)
    rows = frontier_job_times_dynamic(
        d, 6, [1, 2, 3], 60, seed=3, n_jobs=10, churn=churn, churn_pairs_per_worker=2
    )
    for chunk in (2, 4):
        rows_c = frontier_job_times_dynamic(
            d, 6, [1, 2, 3], 60, seed=3, n_jobs=10, churn=churn,
            churn_pairs_per_worker=2, rep_chunk=chunk,
        )
        assert np.array_equal(rows, rows_c)
    with pytest.raises(ValueError, match="rep_chunk"):
        simulate_epochs(d, 6, 3, np.zeros(4), 8, rep_chunk=0)


def test_sharded_devices_match_single_device():
    """devices > 1 shards the lane grid via shard_map; per-lane seed
    derivation keeps the results exactly equal to single-device runs."""
    import subprocess
    import sys
    import textwrap

    code = textwrap.dedent("""
        import numpy as np, jax
        from repro.cluster import ChurnProcess, simulate_epochs
        from repro.cluster.epoch_scan import frontier_job_times_dynamic
        from repro.core.service_time import Exponential
        assert len(jax.devices()) >= 4, jax.devices()
        d, churn = Exponential(1.0), ChurnProcess(0.05, 1.0)
        kw = dict(seed=2, churn=churn, churn_pairs_per_worker=2)
        a = simulate_epochs(d, 6, 3, np.zeros(6), 10, devices=1, **kw)
        b = simulate_epochs(d, 6, 3, np.zeros(6), 10, devices=4, **kw)
        assert np.array_equal(a.finishes, b.finishes)
        assert np.array_equal(a.worker_seconds, b.worker_seconds)
        assert np.array_equal(a.n_replicas_rescued, b.n_replicas_rescued)
        ra = frontier_job_times_dynamic(d, 6, [1, 2, 3, 6], 40, n_jobs=8,
                                        devices=1, **kw)
        rb = frontier_job_times_dynamic(d, 6, [1, 2, 3, 6], 40, n_jobs=8,
                                        devices=4, **kw)
        assert np.array_equal(ra, rb)
        print("PASS")
    """)
    import os

    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        env.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=4"
    ).strip()
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"
    )
    r = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, env=env,
        timeout=600,
    )
    assert r.returncode == 0, f"STDOUT:\n{r.stdout[-2000:]}\nSTDERR:\n{r.stderr[-4000:]}"
    assert "PASS" in r.stdout
    # on the in-process (single device) backend, over-asking must be a
    # clear error, not a silent fallback
    with pytest.raises(ValueError, match="devices"):
        simulate_epochs(
            Exponential(1.0), 4, 2, np.zeros(2), 2, devices=len(__import__("jax").devices()) + 1
        )


def test_float64_lanes_fix_large_arrival_offsets():
    """Absolute times ~1e7 would quantize float32 queue waits (a ulp there is
    ~1 s); the float32 lane now refuses such arrivals loudly, naming
    dtype='float64', while float64 lanes track the (float64) engine to
    ~1e-6."""
    import jax

    d = Empirical(samples=(1.3,))
    n, b, n_jobs = 6, 3, 6
    off = 1.0e7
    arr = off + np.arange(n_jobs) * 1.5
    speeds = (1.0, 1.5, 0.7, 1.2, 0.9, 1.1)
    jobs = [
        Job(job_id=i, dist=d, n_tasks=n, arrival=float(t)) for i, t in enumerate(arr)
    ]
    er = ClusterEngine(n, seed=3, n_batches=b, speeds=speeds).run(jobs)
    e_start = np.array([r.start for r in er.records])
    e_fin = np.array([r.finish for r in er.records])
    # the float32 lane refuses rather than returning quantized statistics,
    # and the message names the fix
    with pytest.raises(ValueError, match=r'dtype="float64"'):
        simulate_epochs(d, n, b, arr, 1, seed=3, speeds=speeds)
    # ... the space-delegated lane of simulate_fifo inherits the same guard
    with pytest.raises(ValueError, match=r'dtype="float64"'):
        simulate_fifo(d, n, b, arr, 1, seed=3, scheduler="packed", workers_per_job=2)
    # arrivals within the f32-safe range stay accepted on the float32 lane
    simulate_epochs(d, n, b, arr - off, 1, seed=3, speeds=speeds)
    prev = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", True)
    try:
        f64 = simulate_epochs(d, n, b, arr, 1, seed=3, speeds=speeds, dtype="float64")
    finally:
        jax.config.update("jax_enable_x64", prev)
    err64 = np.max(np.abs(f64.finishes[0] - e_fin))
    assert err64 < 1e-6, err64
    assert np.max(np.abs(f64.starts[0] - e_start)) < 1e-6
    # float64 without x64 enabled is a loud error, not silent downcast
    with pytest.raises(ValueError, match="x64"):
        simulate_epochs(d, n, b, arr, 1, seed=3, dtype="float64")
    with pytest.raises(ValueError, match="dtype"):
        simulate_epochs(d, n, b, arr - off, 1, seed=3, dtype="float16")


def test_plan_sweep_one_compile_per_shape_bucket():
    """A dynamic (distribution x budget) sweep whose budgets share one shape
    bucket compiles the step runner exactly once (the bucketed jit cache);
    host-side draw prep keeps distributions out of the compile key."""
    from repro.cluster.epoch_scan import clear_runner_cache, runner_cache_stats

    clear_runner_cache()
    churn = ChurnProcess(fail_rate=0.03, mean_downtime=1.0)
    plans = plan_sweep(
        [Exponential(1.0), Exponential(2.0), ShiftedExponential(delta=0.5, mu=1.0)],
        [6, 5],
        n_reps=32,
        seed=0,
        churn=churn,
        candidates=[1, 2],
        jobs_per_stream=8,
        churn_pairs_per_worker=2,
    )
    assert len(plans) == 3 and len(plans[0]) == 2
    stats = runner_cache_stats()
    assert sum(stats.values()) == 1, stats
