"""Numerical correctness of model components against naive oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.models.layers import (
    apply_mrope,
    apply_rope,
    attention_reference,
    flash_attention,
)
from repro.models.moe import init_moe, moe_ffn, moe_ffn_reference
from repro.models.rglru import init_rglru_block, rglru_reference, rglru_scan, rglru_step
from repro.models.ssm import ssd_chunked, ssd_reference
from repro.models.transformer import HeadLayout


# --------------------------------------------------------------------------
# flash (blockwise jnp) attention vs naive reference
# --------------------------------------------------------------------------


@pytest.mark.parametrize("h,kh", [(4, 4), (8, 2), (6, 3)])
@pytest.mark.parametrize("causal,window", [(True, None), (True, 5), (False, None)])
def test_flash_vs_reference(h, kh, causal, window):
    key = jax.random.key(0)
    b, s, hd = 2, 37, 16  # deliberately non-multiple of block
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, kh, hd))
    v = jax.random.normal(ks[2], (b, s, kh, hd))
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    want = attention_reference(q, k, v, pos, pos, causal=causal, window=window)
    got = flash_attention(q, k, v, pos, pos, causal=causal, window=window, block_k=8)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


def test_flash_decode_matches_reference():
    key = jax.random.key(1)
    b, sk, h, kh, hd = 2, 33, 4, 2, 16
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, 1, h, hd))
    k = jax.random.normal(ks[1], (b, sk, kh, hd))
    v = jax.random.normal(ks[2], (b, sk, kh, hd))
    qpos = jnp.full((b, 1), 20)
    kpos = jnp.broadcast_to(jnp.where(jnp.arange(sk) <= 20, jnp.arange(sk), -1)[None], (b, sk))
    want = attention_reference(q, k, v, qpos, kpos, causal=True)
    got = flash_attention(q, k, v, qpos, kpos, causal=True, block_k=8)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


def test_head_layouts():
    # (H, K, pad) -> (K_pad, G_pad, H_pad, n_masked)
    cases = {
        (12, 2, 16): (16, 1, 16, 4),
        (32, 4, 16): (16, 2, 32, 0),
        (24, 2, 16): (16, 2, 32, 8),
        (28, 4, 16): (16, 2, 32, 4),
        (10, 1, 16): (16, 1, 16, 6),
        (48, 8, 16): (16, 3, 48, 0),
        (64, 4, 16): (16, 4, 64, 0),
        (16, 16, 16): (16, 1, 16, 0),
    }
    for (h, k, pad), (k_pad, g_pad, h_pad, masked) in cases.items():
        lo = HeadLayout.make(h, k, pad)
        assert (lo.k_pad, lo.g_pad, lo.h_pad) == (k_pad, g_pad, h_pad), (h, k)
        assert int(lo.h_pad - lo.head_mask().sum()) == masked, (h, k)
        assert lo.h_pad % pad == 0 and lo.k_pad % pad == 0


def test_padded_heads_exact_semantics():
    """pad_heads_to must not change the *math*, only the layout.

    We check that a padded model produces the same loss as an unpadded one
    when the real-slot weights are copied across (mapping true head h of kv
    group t to padded slot (t*R + h // G_pad')*hd ...).  Simpler equivalent
    check: gradients w.r.t. masked slots are zero and outputs don't depend
    on masked-slot weights.
    """
    cfg = get_config("qwen2-1.5b", smoke=True, pad_heads_to=8)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    lo = HeadLayout.make(cfg.n_heads, cfg.n_kv_heads, 8)
    assert lo.h_pad > cfg.n_heads  # padding actually engaged
    batch = {
        "tokens": jax.random.randint(jax.random.key(1), (2, 8), 0, cfg.vocab_size),
        "labels": jax.random.randint(jax.random.key(2), (2, 8), 0, cfg.vocab_size),
        "loss_mask": jnp.ones((2, 8), jnp.float32),
    }
    loss0, _ = model.train_loss(params, batch)

    # perturb masked wq slots: output must be invariant
    mask = lo.head_mask()  # (H_pad,)
    hd = cfg.head_dim
    wq = params["layers"]["attn"]["wq"]
    noise = jax.random.normal(jax.random.key(3), wq.shape, wq.dtype)
    slot_mask = jnp.repeat(1.0 - mask, hd)[None, None, :]  # 1 on masked slots
    params["layers"]["attn"]["wq"] = wq + noise * slot_mask
    loss1, _ = model.train_loss(params, batch)
    np.testing.assert_allclose(float(loss0), float(loss1), rtol=1e-6)


# --------------------------------------------------------------------------
# rope
# --------------------------------------------------------------------------


def test_rope_relative_property():
    """<rope(q,i), rope(k,j)> depends only on i - j."""
    key = jax.random.key(0)
    q = jax.random.normal(key, (1, 1, 1, 32))
    k = jax.random.normal(jax.random.key(1), (1, 1, 1, 32))

    def score(i, j):
        qi = apply_rope(q, jnp.full((1, 1), i))
        kj = apply_rope(k, jnp.full((1, 1), j))
        return float(jnp.sum(qi * kj))

    assert score(5, 3) == pytest.approx(score(12, 10), rel=1e-4)
    assert score(7, 0) == pytest.approx(score(107, 100), rel=1e-4)


def test_mrope_degenerates_to_rope_for_text():
    """When t == h == w (text tokens), M-RoPE == 1-D RoPE (paper property)."""
    key = jax.random.key(0)
    x = jax.random.normal(key, (2, 6, 4, 32))
    pos1d = jnp.broadcast_to(jnp.arange(6)[None], (2, 6))
    pos3d = jnp.broadcast_to(jnp.arange(6)[None, :, None], (2, 6, 3))
    a = apply_rope(x, pos1d)
    b = apply_mrope(x, pos3d, sections=(6, 5, 5))
    np.testing.assert_allclose(a, b, atol=1e-6)


# --------------------------------------------------------------------------
# MoE
# --------------------------------------------------------------------------


@pytest.mark.parametrize("e,k", [(4, 2), (8, 2), (8, 4)])
def test_moe_matches_reference(e, k):
    key = jax.random.key(0)
    d, f, b, s = 16, 32, 2, 12
    params = init_moe(key, d, f, e, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (b, s, d))
    # capacity high enough that nothing is dropped
    got, aux = moe_ffn(params, x, k, capacity_factor=float(e))
    want = moe_ffn_reference(params, x, k)
    np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-4)
    assert jnp.isfinite(aux) and float(aux) > 0


def test_moe_capacity_drops_are_bounded():
    key = jax.random.key(0)
    d, f, e, k, b, s = 8, 16, 4, 2, 2, 64
    params = init_moe(key, d, f, e, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (b, s, d))
    full, _ = moe_ffn(params, x, k, capacity_factor=float(e))
    tight, _ = moe_ffn(params, x, k, capacity_factor=1.0)
    # with cf=1 some tokens may be dropped; outputs differ but stay finite
    assert jnp.isfinite(tight).all()
    # dropped-token outputs are a subset: rows equal or shrunk toward zero
    diff_norm = jnp.linalg.norm(full - tight)
    assert jnp.isfinite(diff_norm)


def test_moe_decode_path_single_token():
    key = jax.random.key(0)
    d, f, e, k = 8, 16, 8, 2
    params = init_moe(key, d, f, e, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (4, 1, d))  # decode: S=1
    got, _ = moe_ffn(params, x, k, capacity_factor=float(e))
    want = moe_ffn_reference(params, x, k)
    np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-4)


# --------------------------------------------------------------------------
# SSD (mamba2)
# --------------------------------------------------------------------------


@pytest.mark.parametrize("chunk", [4, 8, 16])
def test_ssd_chunked_vs_naive(chunk):
    key = jax.random.key(0)
    b, s, nh, hp, n = 2, 24, 3, 8, 4
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, s, nh, hp))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, nh)))
    a_neg = -jnp.exp(jax.random.normal(ks[2], (nh,)) * 0.3)
    bmat = jax.random.normal(ks[3], (b, s, n))
    cmat = jax.random.normal(ks[4], (b, s, n))
    d_skip = jnp.ones((nh,))
    y_ref, h_ref = ssd_reference(x, dt, a_neg, bmat, cmat, d_skip)
    y, h = ssd_chunked(x, dt, a_neg, bmat, cmat, d_skip, chunk)
    np.testing.assert_allclose(y, y_ref, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(h, h_ref, atol=1e-4, rtol=1e-4)


def test_ssd_carried_state():
    """Splitting a sequence in two with carried state == one pass."""
    key = jax.random.key(1)
    b, s, nh, hp, n = 1, 16, 2, 4, 4
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, s, nh, hp))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, nh)))
    a_neg = -jnp.exp(jax.random.normal(ks[2], (nh,)) * 0.3)
    bmat = jax.random.normal(ks[3], (b, s, n))
    cmat = jax.random.normal(ks[4], (b, s, n))
    d_skip = jnp.zeros((nh,))
    y_full, h_full = ssd_chunked(x, dt, a_neg, bmat, cmat, d_skip, 4)
    half = s // 2
    y1, h1 = ssd_chunked(
        x[:, :half], dt[:, :half], a_neg, bmat[:, :half], cmat[:, :half], d_skip, 4
    )
    y2, h2 = ssd_chunked(
        x[:, half:], dt[:, half:], a_neg, bmat[:, half:], cmat[:, half:], d_skip, 4, h0=h1
    )
    np.testing.assert_allclose(jnp.concatenate([y1, y2], 1), y_full, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(h2, h_full, atol=1e-4, rtol=1e-4)


# --------------------------------------------------------------------------
# RG-LRU
# --------------------------------------------------------------------------


def test_rglru_scan_vs_loop():
    key = jax.random.key(0)
    d = 16
    params = init_rglru_block(key, d, d, 4, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (2, 20, d))
    y_ref, h_ref = rglru_reference(params, x, c=8.0)
    y, h = rglru_scan(params, x, c=8.0)
    np.testing.assert_allclose(y, y_ref, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(h, h_ref, atol=1e-5, rtol=1e-5)


def test_rglru_step_continues_scan():
    key = jax.random.key(2)
    d = 8
    params = init_rglru_block(key, d, d, 4, jnp.float32)
    x = jax.random.normal(jax.random.key(3), (1, 9, d))
    y_full, h_full = rglru_scan(params, x, c=8.0)
    _, h8 = rglru_scan(params, x[:, :8], c=8.0)
    y_step, h9 = rglru_step(params, x[:, 8], h8, c=8.0)
    np.testing.assert_allclose(y_step, y_full[:, 8], atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(h9, h_full, atol=1e-5, rtol=1e-5)


# --------------------------------------------------------------------------
# prefill+decode == teacher-forced forward (end-to-end cache correctness)
# --------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "gemma-7b", "dbrx-132b", "mamba2-2.7b",
                                  "recurrentgemma-2b"])
def test_decode_matches_teacher_forcing(arch):
    cfg = get_config(arch, smoke=True, param_dtype="float32", compute_dtype="float32")
    if cfg.is_moe:
        cfg = get_config(arch, smoke=True, param_dtype="float32",
                         compute_dtype="float32", capacity_factor=8.0)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    b, s_pre, s_dec = 2, 7, 4
    tokens = jax.random.randint(jax.random.key(1), (b, s_pre + s_dec), 0, cfg.vocab_size)

    # teacher-forced full forward
    from repro.models import hybrid, mamba, transformer

    mod = {"hybrid": hybrid, "ssm": mamba}.get(cfg.family, transformer)
    if cfg.family in ("hybrid", "ssm"):
        full_logits, _ = mod.forward(params, cfg, tokens)
    else:
        full_logits, _, _ = mod.forward(params, cfg, tokens=tokens)

    # prefill + step-by-step decode
    logits, cache, t = model.prefill(params, {"tokens": tokens[:, :s_pre]}, max_len=s_pre + s_dec)
    np.testing.assert_allclose(logits, full_logits[:, s_pre - 1], atol=2e-3, rtol=2e-3)
    for i in range(s_dec):
        tok = tokens[:, s_pre + i : s_pre + i + 1]
        logits, cache, t = model.decode_step(params, cache, tok, t)
        np.testing.assert_allclose(
            logits, full_logits[:, s_pre + i], atol=2e-3, rtol=2e-3,
            err_msg=f"{arch} step {i}",
        )
