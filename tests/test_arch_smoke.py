"""Per-arch smoke tests: reduced config, one forward/train step on CPU,
asserting output shapes + finiteness (assignment requirement (f))."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import build_model

B, S = 2, 16


def _make_batch(cfg, key):
    ks = jax.random.split(key, 4)
    batch = {}
    if cfg.family in ("vlm", "encoder"):
        batch["embeds"] = jax.random.normal(ks[0], (B, S, cfg.d_model), cfg.dtype("compute"))
    else:
        batch["tokens"] = jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size)
    if cfg.family == "vlm":
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None, :, None], (B, S, 3))
        batch["mrope_positions"] = pos
    batch["labels"] = jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size)
    batch["loss_mask"] = jnp.ones((B, S), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = _make_batch(cfg, jax.random.key(1))

    (loss, metrics), grads = jax.value_and_grad(model.train_loss, has_aux=True)(params, batch)
    assert jnp.isfinite(loss), f"{arch}: non-finite loss"
    assert float(loss) > 0.0
    gnorms = [float(jnp.linalg.norm(g.astype(jnp.float32))) for g in jax.tree.leaves(grads)]
    assert all(np.isfinite(g) for g in gnorms), f"{arch}: non-finite grads"
    assert any(g > 0 for g in gnorms), f"{arch}: all-zero grads"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_shapes(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = _make_batch(cfg, jax.random.key(1))
    if cfg.family == "encoder":
        # encoders expose train_loss only; logits checked via loss finiteness
        loss, _ = model.train_loss(params, batch)
        assert jnp.isfinite(loss)
        return
    logits, cache, t = model.prefill(params, batch, max_len=S + 8)
    assert logits.shape == (B, cfg.padded_vocab)
    assert jnp.isfinite(logits).all()
    assert int(t) == S


@pytest.mark.parametrize(
    "arch", [a for a in ARCH_IDS if get_config(a).family != "encoder"]
)
def test_smoke_decode_steps(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = _make_batch(cfg, jax.random.key(1))
    logits, cache, t = model.prefill(params, batch, max_len=S + 8)
    tok = jnp.argmax(logits[:, : cfg.vocab_size], axis=-1)[:, None].astype(jnp.int32)
    for _ in range(3):
        logits, cache, t = model.decode_step(params, cache, tok, t)
        assert logits.shape == (B, cfg.padded_vocab)
        assert jnp.isfinite(logits).all()
        tok = jnp.argmax(logits[:, : cfg.vocab_size], axis=-1)[:, None].astype(jnp.int32)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_constructs(arch):
    """The exact published config must at least construct + report params."""
    cfg = get_config(arch)
    assert cfg.n_layers >= 26 or cfg.family == "ssm" or arch == "qwen2-1.5b"
    n = cfg.param_count_estimate()
    assert n > 1e8  # every assigned arch is >100M params
