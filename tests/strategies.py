"""Shared scenario generators for the cluster / backend / planner suites.

One place to draw service-time distributions, worker setups (count + optional
heterogeneous speeds), churn processes and explicit churn schedules, arrival
processes, and candidate frontiers -- instead of every test file hand-rolling
its own configs.  Everything composes from the ``st`` surface that both real
hypothesis and the seeded fallback (``tests/_hypothesis_compat.py``) provide
(``sampled_from`` / ``floats`` / ``tuples`` / ``lists`` / ``map`` /
``flatmap``), so property tests run identically with or without the test
extra installed.

Two layers:

  * hypothesis strategies (``service_dists()``, ``worker_setups()``, ...)
    for ``@given`` property tests;
  * seeded plain helpers (``seeded_schedule()``, ``seeded_speeds()``, ...)
    for deterministic differential tests that need one shared realization
    on both backends.
"""
import numpy as np

try:
    from hypothesis import strategies as st
except ModuleNotFoundError:  # test extra not installed: seeded fallback engine
    from _hypothesis_compat import st

from repro.cluster.scheduler import JobPlan
from repro.cluster.workers import ChurnProcess, ChurnSchedule, sample_churn_schedule
from repro.core import analysis
from repro.core.service_time import Exponential, Pareto, ShiftedExponential

__all__ = [
    "service_dists",
    "light_tailed_dists",
    "worker_counts",
    "worker_setups",
    "churn_processes",
    "arrival_grids",
    "objectives",
    "space_schedulers",
    "worker_requests",
    "job_plan_cycles",
    "frontier",
    "seeded_speeds",
    "seeded_schedule",
    "seeded_job_plans",
]


# --------------------------------------------------------------------------
# hypothesis strategies
# --------------------------------------------------------------------------


def service_dists(include_heavy: bool = True):
    """A fitted-family service-time distribution with sane parameters.

    Pareto shapes stay above 1.6 so means/variances used in 3-sigma
    comparisons exist; pass ``include_heavy=False`` where heavy tails would
    make Monte-Carlo error bounds vacuous.
    """
    fams = [
        st.floats(0.5, 3.0).map(lambda mu: Exponential(mu=mu)),
        st.tuples(st.floats(0.2, 2.0), st.floats(0.5, 3.0)).map(
            lambda p: ShiftedExponential(delta=p[0], mu=p[1])
        ),
    ]
    if include_heavy:
        fams.append(
            st.tuples(st.floats(0.5, 2.0), st.floats(1.6, 3.0)).map(
                lambda p: Pareto(sigma=p[0], alpha=p[1])
            )
        )
    return st.sampled_from(fams).flatmap(lambda s: s)


def light_tailed_dists():
    return service_dists(include_heavy=False)


def worker_counts(min_workers: int = 4, max_workers: int = 12):
    """Even cluster sizes (rich divisor frontiers, affordable engine runs)."""
    return st.sampled_from(list(range(min_workers, max_workers + 1, 2)))


def worker_setups(min_workers: int = 4, max_workers: int = 12):
    """(n_workers, speeds) with speeds None (homogeneous) or a per-worker tuple."""

    def mk(n):
        return st.tuples(
            st.just(n),
            st.sampled_from([False, True]).flatmap(
                lambda het: st.lists(st.floats(0.5, 2.0), min_size=n, max_size=n).map(tuple)
                if het
                else st.just(None)
            ),
        )

    return worker_counts(min_workers, max_workers).flatmap(mk)


def churn_processes(max_fail_rate: float = 0.08):
    """Fail/join dynamics mild enough that jobs still complete."""
    return st.tuples(st.floats(0.01, max_fail_rate), st.floats(0.5, 3.0)).map(
        lambda p: ChurnProcess(fail_rate=p[0], mean_downtime=p[1])
    )


def arrival_grids(max_jobs: int = 24):
    """Evenly spaced arrival vectors (gap 0 = everything queued at t=0)."""
    return st.tuples(st.integers(4, max_jobs), st.floats(0.0, 4.0)).map(
        lambda p: np.arange(p[0]) * p[1]
    )


def objectives():
    return st.sampled_from(["mean", "cov", "blend"])


def space_schedulers(include_gang: bool = True):
    """A space-sharing placement policy name (optionally incl. fifo_gang)."""
    names = ["packed", "balanced"] + (["fifo_gang"] if include_gang else [])
    return st.sampled_from(names)


def worker_requests(n_workers: int):
    """A worker-subset size request in [1, n_workers] (space sharing)."""
    return st.integers(1, n_workers)


def job_plan_cycles(n_workers: int, max_len: int = 3):
    """A short cycle of per-job plan overrides (None = inherit defaults).

    Entries mix full overrides (workers + B + cancellation), B-only plans,
    and None, so a stream carries genuinely heterogeneous (B, r) plans --
    the per-job grids the space-sharing differential tests replay on both
    backends.
    """
    full = st.tuples(
        st.integers(1, n_workers), st.integers(1, n_workers), st.sampled_from([False, True])
    ).map(lambda p: JobPlan(workers=p[0], n_batches=p[1], cancel_redundant=p[2]))
    b_only = st.integers(1, n_workers).map(lambda b: JobPlan(n_batches=b))
    entry = st.sampled_from([full, b_only, st.just(None)]).flatmap(lambda s: s)
    return st.lists(entry, min_size=1, max_size=max_len)


# --------------------------------------------------------------------------
# seeded plain helpers (shared realizations for differential tests)
# --------------------------------------------------------------------------


def frontier(n_workers: int):
    """The feasible candidate frontier B | N (plain list, not a strategy)."""
    return analysis.feasible_B(n_workers)


def seeded_speeds(n_workers: int, seed: int = 0, lo: float = 0.5, hi: float = 2.0):
    """A reproducible heterogeneous speed vector."""
    rng = np.random.default_rng(seed)
    return tuple(float(s) for s in rng.uniform(lo, hi, size=n_workers))


def seeded_job_plans(n_workers: int, seed: int = 0, length: int = 3):
    """A reproducible heterogeneous per-job plan cycle (one entry is None)."""
    rng = np.random.default_rng(seed)
    plans = [
        JobPlan(
            workers=int(rng.integers(1, n_workers + 1)),
            n_batches=int(rng.integers(1, n_workers + 1)),
            cancel_redundant=bool(rng.integers(0, 2)),
        )
        for _ in range(max(1, length - 1))
    ]
    return plans + [None]


def seeded_schedule(
    n_workers: int,
    seed: int = 0,
    fail_rate: float = 0.05,
    mean_downtime: float = 1.0,
    pairs_per_worker: int = 4,
) -> ChurnSchedule:
    """One shared churn realization both backends replay verbatim."""
    rng = np.random.default_rng(seed)
    return sample_churn_schedule(
        ChurnProcess(fail_rate=fail_rate, mean_downtime=mean_downtime),
        n_workers,
        rng,
        pairs_per_worker=pairs_per_worker,
    )
