"""Scenario API: one frozen spec == the legacy loose-kwarg call forms.

Every public entry point (``sample_job_times``, ``plan_cluster``,
``plan_sweep``, ``frontier_job_times_dynamic``) accepts ``scenario=`` and
must produce results identical to the deprecated loose-kwarg spelling; the
loose spelling must warn, mixing the two must raise, and validation is one
shared path whose errors name the offending field.
"""

import contextlib
import warnings

import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ModuleNotFoundError:  # test extra not installed: seeded fallback engine
    from _hypothesis_compat import given, settings, st

import strategies as scn
from repro.cluster import ChurnProcess, ClusterEngine, Job, Scenario, sample_job_times
from repro.cluster.epoch_scan import frontier_job_times_dynamic
from repro.cluster.scenario import UNSET, resolve_scenario, scenario_from_kwargs
from repro.cluster.scheduler import JobPlan
from repro.core import Scenario as CoreScenario
from repro.core.planner import RedundancyPlanner, plan_sweep
from repro.core.service_time import Exponential, Pareto, ShiftedExponential

POLICIES = ("fifo_gang", "packed", "balanced")


@contextlib.contextmanager
def no_warnings():
    """Context that turns any DeprecationWarning into a failure."""
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        yield


def test_scenario_exported_from_both_packages():
    assert CoreScenario is Scenario  # one class, two doors


# --------------------------------------------------------------------------
# scenario == legacy kwargs, on all three scheduling policies
# --------------------------------------------------------------------------


@pytest.mark.parametrize("policy", POLICIES)
def test_sample_job_times_scenario_equals_legacy(policy):
    d = ShiftedExponential(0.3, 1.0)
    wpj = None if policy == "fifo_gang" else 2
    with pytest.warns(DeprecationWarning, match="sample_job_times"):
        legacy = sample_job_times(
            d,
            6,
            2,
            40,
            seed=3,
            backend="python",
            cancel_redundant=True,
            scheduler=policy,
            workers_per_job=wpj,
        )
    sc = Scenario(cancel_redundant=True, scheduler=policy, workers_per_job=wpj)
    with no_warnings():
        new = sample_job_times(d, 6, 2, 40, seed=3, backend="python", scenario=sc)
    assert np.array_equal(legacy, new)


@settings(max_examples=6, deadline=None)
@given(
    dist=scn.light_tailed_dists(),
    cancel=st.booleans(),
    size_dep=st.booleans(),
    seed=st.integers(0, 99),
)
def test_sample_job_times_roundtrip_property(dist, cancel, size_dep, seed):
    """Property: for any generated scenario the Scenario spelling and the
    legacy spelling draw identical samples under a shared seed."""
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        legacy = sample_job_times(
            dist,
            5,
            2,
            30,
            seed=seed,
            backend="python",
            cancel_redundant=cancel,
            size_dependent=size_dep,
        )
    sc = Scenario(cancel_redundant=cancel, size_dependent=size_dep)
    new = sample_job_times(dist, 5, 2, 30, seed=seed, backend="python", scenario=sc)
    assert np.array_equal(legacy, new)


@pytest.mark.parametrize("backend", ["python", "jax"])
def test_plan_cluster_scenario_equals_legacy(backend):
    d = Pareto(1.0, 2.2)
    planner = RedundancyPlanner(8, candidates=[1, 2, 4])
    with pytest.warns(DeprecationWarning, match="plan_cluster"):
        legacy = planner.plan_cluster(d, n_reps=40, seed=2, backend=backend, cancel_redundant=True)
    with no_warnings():
        new = planner.plan_cluster(
            d, n_reps=40, seed=2, backend=backend, scenario=Scenario(cancel_redundant=True)
        )
    assert legacy == new  # frozen dataclass: full frontier equality


def test_plan_cluster_dynamic_scenario_equals_legacy():
    """The dynamic (epoch-scan) lane: speeds route both spellings through
    frontier_job_times_dynamic with identical results."""
    d = Exponential(1.0)
    planner = RedundancyPlanner(4, candidates=[1, 2])
    speeds = (1.0, 1.0, 2.0, 0.5)
    with pytest.warns(DeprecationWarning, match="plan_cluster"):
        legacy = planner.plan_cluster(d, n_reps=30, seed=5, backend="jax", speeds=speeds)
    with no_warnings():
        new = planner.plan_cluster(
            d, n_reps=30, seed=5, backend="jax", scenario=Scenario(speeds=speeds)
        )
    assert legacy == new


def test_plan_cluster_scenario_plus_loose_kwargs_raises():
    planner = RedundancyPlanner(4)
    with pytest.raises(ValueError, match="fold them into the Scenario"):
        planner.plan_cluster(
            Exponential(1.0),
            backend="python",
            cancel_redundant=True,
            scenario=Scenario(cancel_redundant=True),
        )


def test_plan_sweep_scenario_equals_legacy():
    dists = [Exponential(1.0), Pareto(1.0, 2.5)]
    budgets = [4, 6]
    with pytest.warns(DeprecationWarning, match="plan_sweep"):
        legacy = plan_sweep(
            dists, budgets, n_reps=30, seed=1, backend="python", cancel_redundant=True
        )
    with no_warnings():
        new = plan_sweep(
            dists,
            budgets,
            n_reps=30,
            seed=1,
            backend="python",
            scenario=Scenario(cancel_redundant=True),
        )
    assert legacy == new


def test_frontier_dynamic_scenario_equals_legacy():
    d = Exponential(1.0)
    speeds = (1.0, 2.0, 1.0, 0.5)
    with pytest.warns(DeprecationWarning, match="frontier_job_times_dynamic"):
        legacy = frontier_job_times_dynamic(
            d, 4, [1, 2], 30, seed=7, speeds=speeds, cancel_redundant=True
        )
    with no_warnings():
        new = frontier_job_times_dynamic(
            d, 4, [1, 2], 30, seed=7, scenario=Scenario(speeds=speeds, cancel_redundant=True)
        )
    assert np.array_equal(np.asarray(legacy), np.asarray(new))


def test_engine_kwargs_translation_differential():
    """ClusterEngine built from Scenario.to_engine_kwargs() replays the
    loose-kwarg construction bit for bit."""
    sched = scn.seeded_schedule(6, seed=3, fail_rate=0.05, mean_downtime=1.0)
    sc = Scenario(n_batches=3, cancel_redundant=True, churn_schedule=sched)
    d = Pareto(1.0, 2.2)

    def jobs():
        return [Job(job_id=i, dist=d, n_tasks=6) for i in range(30)]

    a = ClusterEngine(6, seed=9, **sc.to_engine_kwargs(6)).run(jobs())
    b = ClusterEngine(6, seed=9, n_batches=3, cancel_redundant=True, churn_schedule=sched).run(
        jobs()
    )
    assert a.accounting() == b.accounting()
    assert np.array_equal(a.compute_times, b.compute_times)


# --------------------------------------------------------------------------
# the compat shim itself
# --------------------------------------------------------------------------


def test_resolve_scenario_warns_and_builds():
    with pytest.warns(DeprecationWarning, match="somewhere: passing cancel_redundant"):
        sc = resolve_scenario(None, {"cancel_redundant": True, "speeds": UNSET}, where="somewhere")
    assert sc == Scenario(cancel_redundant=True)


def test_resolve_scenario_passthrough_no_warning():
    sc = Scenario(n_batches=2)
    with no_warnings():
        out = resolve_scenario(sc, {"speeds": UNSET}, where="somewhere")
    assert out is sc


def test_scenario_from_kwargs_is_silent_internal_plumbing():
    with no_warnings():
        sc = scenario_from_kwargs(cancel_redundant=True, n_tasks=UNSET)
    assert sc == Scenario(cancel_redundant=True)


# --------------------------------------------------------------------------
# the single validation path: errors name the field, once, everywhere
# --------------------------------------------------------------------------


def test_validate_messages_name_the_field():
    sched = scn.seeded_schedule(4, seed=0, fail_rate=0.1, mean_downtime=1.0)
    with pytest.raises(ValueError, match="not both"):
        Scenario(churn=ChurnProcess(0.1, 1.0), churn_schedule=sched).validate()
    with pytest.raises(ValueError, match=r"worker ids must lie in \[0, 2\)"):
        Scenario(churn_schedule=sched).validate(n_workers=2)
    with pytest.raises(ValueError, match="unknown scheduler"):
        Scenario(scheduler="round_robin").validate()
    with pytest.raises(ValueError, match="Scenario.n_batches"):
        Scenario(n_batches=9).validate(n_workers=4)
    with pytest.raises(ValueError, match="Scenario.n_workers=4 does not match"):
        Scenario(n_workers=4).validate(n_workers=6)
    with pytest.raises(ValueError, match="Scenario.speeds"):
        Scenario(speeds=(1.0, -1.0)).validate()
    with pytest.raises(ValueError, match="Scenario.dtype"):
        Scenario(dtype="float16").validate()
    with pytest.raises(ValueError, match="backend='jax'"):
        Scenario(dtype="float64").validate(backend="python")
    with pytest.raises(ValueError, match="Scenario.devices"):
        Scenario(devices=2).validate(backend="python")


def test_engine_constructor_routes_through_scenario_validate():
    """The Python engine shares the one validation path: its errors are the
    Scenario ones.  (``n_batches`` is deliberately absent: the engine clamps
    it to the alive-worker count at dispatch.)"""
    with pytest.raises(ValueError, match="one entry per worker"):
        ClusterEngine(4, speeds=[1.0, 1.0])
    with pytest.raises(ValueError, match="not both"):
        sched = scn.seeded_schedule(4, seed=0, fail_rate=0.1, mean_downtime=1.0)
        ClusterEngine(4, churn=ChurnProcess(0.1, 1.0), churn_schedule=sched)


def test_entry_points_reject_dtype_on_python_backend():
    with pytest.raises(ValueError, match="Scenario.dtype"):
        sample_job_times(
            Exponential(1.0), 4, 2, 10, backend="python", scenario=Scenario(dtype="float64")
        )


# --------------------------------------------------------------------------
# the frozen object itself
# --------------------------------------------------------------------------


def test_scenario_hashable_and_replace():
    sc = Scenario(speeds=[2.0, 1.0], job_plans=[JobPlan(n_batches=1), None])
    assert isinstance(sc.speeds, tuple) and isinstance(sc.job_plans, tuple)
    assert isinstance(hash(sc), int)  # frozen: can key jit/plan caches
    sc2 = sc.replace(cancel_redundant=True)
    assert sc2.cancel_redundant and not sc.cancel_redundant
    assert sc.job_plan_for(0) == JobPlan(n_batches=1)
    assert sc.job_plan_for(1) is None
    assert sc.job_plan_for(2) == JobPlan(n_batches=1)  # cycles


def test_scenario_routing_predicates():
    assert not Scenario().is_dynamic and not Scenario().is_space
    assert Scenario(speeds=(1.0, 2.0)).is_dynamic
    assert Scenario(workers_per_job=2).is_space
    assert Scenario(scheduler="packed").is_space


def test_to_engine_kwargs_requires_workers():
    with pytest.raises(ValueError, match="n_workers"):
        Scenario().to_engine_kwargs()
    kw = Scenario(n_batches=2, cancel_redundant=True).to_engine_kwargs(4)
    assert kw["n_batches"] == 2 and kw["cancel_redundant"] is True
    assert set(kw) == {
        "n_batches",
        "cancel_redundant",
        "size_dependent",
        "speeds",
        "churn",
        "churn_schedule",
        "controller",
        "scheduler",
        "workers_per_job",
    }
