"""Scenario API: one frozen spec == the legacy loose-kwarg call forms.

Every public entry point (``sample_job_times``, ``plan_cluster``,
``plan_sweep``, ``frontier_job_times_dynamic``) accepts ``scenario=`` and
must produce results identical to the deprecated loose-kwarg spelling; the
loose spelling must warn, mixing the two must raise, and validation is one
shared path whose errors name the offending field.
"""

import contextlib
import warnings

import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ModuleNotFoundError:  # test extra not installed: seeded fallback engine
    from _hypothesis_compat import given, settings, st

import strategies as scn
from repro.cluster import ChurnProcess, ClusterEngine, Job, Scenario, sample_job_times
from repro.cluster.epoch_scan import frontier_job_times_dynamic
from repro.cluster.scenario import UNSET, resolve_scenario, scenario_from_kwargs
from repro.cluster.scheduler import JobPlan
from repro.core import Scenario as CoreScenario
from repro.core.planner import RedundancyPlanner, plan_sweep
from repro.core.service_time import Exponential, Pareto, ShiftedExponential

POLICIES = ("fifo_gang", "packed", "balanced")


@contextlib.contextmanager
def no_warnings():
    """Context that turns any DeprecationWarning into a failure."""
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        yield


def test_scenario_exported_from_both_packages():
    assert CoreScenario is Scenario  # one class, two doors


# --------------------------------------------------------------------------
# scenario == legacy kwargs, on all three scheduling policies
# --------------------------------------------------------------------------


@pytest.mark.parametrize("policy", POLICIES)
def test_sample_job_times_scenario_equals_legacy(policy):
    d = ShiftedExponential(0.3, 1.0)
    wpj = None if policy == "fifo_gang" else 2
    with pytest.warns(DeprecationWarning, match="sample_job_times"):
        legacy = sample_job_times(
            d,
            6,
            2,
            40,
            seed=3,
            backend="python",
            cancel_redundant=True,
            scheduler=policy,
            workers_per_job=wpj,
        )
    sc = Scenario(cancel_redundant=True, scheduler=policy, workers_per_job=wpj)
    with no_warnings():
        new = sample_job_times(d, 6, 2, 40, seed=3, backend="python", scenario=sc)
    assert np.array_equal(legacy, new)


@settings(max_examples=6, deadline=None)
@given(
    dist=scn.light_tailed_dists(),
    cancel=st.booleans(),
    size_dep=st.booleans(),
    seed=st.integers(0, 99),
)
def test_sample_job_times_roundtrip_property(dist, cancel, size_dep, seed):
    """Property: for any generated scenario the Scenario spelling and the
    legacy spelling draw identical samples under a shared seed."""
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        legacy = sample_job_times(
            dist,
            5,
            2,
            30,
            seed=seed,
            backend="python",
            cancel_redundant=cancel,
            size_dependent=size_dep,
        )
    sc = Scenario(cancel_redundant=cancel, size_dependent=size_dep)
    new = sample_job_times(dist, 5, 2, 30, seed=seed, backend="python", scenario=sc)
    assert np.array_equal(legacy, new)


@pytest.mark.parametrize("backend", ["python", "jax"])
def test_plan_cluster_scenario_equals_legacy(backend):
    d = Pareto(1.0, 2.2)
    planner = RedundancyPlanner(8, candidates=[1, 2, 4])
    with pytest.warns(DeprecationWarning, match="plan_cluster"):
        legacy = planner.plan_cluster(d, n_reps=40, seed=2, backend=backend, cancel_redundant=True)
    with no_warnings():
        new = planner.plan_cluster(
            d, n_reps=40, seed=2, backend=backend, scenario=Scenario(cancel_redundant=True)
        )
    assert legacy == new  # frozen dataclass: full frontier equality


def test_plan_cluster_dynamic_scenario_equals_legacy():
    """The dynamic (epoch-scan) lane: speeds route both spellings through
    frontier_job_times_dynamic with identical results."""
    d = Exponential(1.0)
    planner = RedundancyPlanner(4, candidates=[1, 2])
    speeds = (1.0, 1.0, 2.0, 0.5)
    with pytest.warns(DeprecationWarning, match="plan_cluster"):
        legacy = planner.plan_cluster(d, n_reps=30, seed=5, backend="jax", speeds=speeds)
    with no_warnings():
        new = planner.plan_cluster(
            d, n_reps=30, seed=5, backend="jax", scenario=Scenario(speeds=speeds)
        )
    assert legacy == new


def test_plan_cluster_scenario_plus_loose_kwargs_raises():
    planner = RedundancyPlanner(4)
    with pytest.raises(ValueError, match="fold them into the Scenario"):
        planner.plan_cluster(
            Exponential(1.0),
            backend="python",
            cancel_redundant=True,
            scenario=Scenario(cancel_redundant=True),
        )


def test_plan_sweep_scenario_equals_legacy():
    dists = [Exponential(1.0), Pareto(1.0, 2.5)]
    budgets = [4, 6]
    with pytest.warns(DeprecationWarning, match="plan_sweep"):
        legacy = plan_sweep(
            dists, budgets, n_reps=30, seed=1, backend="python", cancel_redundant=True
        )
    with no_warnings():
        new = plan_sweep(
            dists,
            budgets,
            n_reps=30,
            seed=1,
            backend="python",
            scenario=Scenario(cancel_redundant=True),
        )
    assert legacy == new


def test_frontier_dynamic_scenario_equals_legacy():
    d = Exponential(1.0)
    speeds = (1.0, 2.0, 1.0, 0.5)
    with pytest.warns(DeprecationWarning, match="frontier_job_times_dynamic"):
        legacy = frontier_job_times_dynamic(
            d, 4, [1, 2], 30, seed=7, speeds=speeds, cancel_redundant=True
        )
    with no_warnings():
        new = frontier_job_times_dynamic(
            d, 4, [1, 2], 30, seed=7, scenario=Scenario(speeds=speeds, cancel_redundant=True)
        )
    assert np.array_equal(np.asarray(legacy), np.asarray(new))


def test_engine_kwargs_translation_differential():
    """ClusterEngine built from Scenario.to_engine_kwargs() replays the
    loose-kwarg construction bit for bit."""
    sched = scn.seeded_schedule(6, seed=3, fail_rate=0.05, mean_downtime=1.0)
    sc = Scenario(n_batches=3, cancel_redundant=True, churn_schedule=sched)
    d = Pareto(1.0, 2.2)

    def jobs():
        return [Job(job_id=i, dist=d, n_tasks=6) for i in range(30)]

    a = ClusterEngine(6, seed=9, **sc.to_engine_kwargs(6)).run(jobs())
    b = ClusterEngine(6, seed=9, n_batches=3, cancel_redundant=True, churn_schedule=sched).run(
        jobs()
    )
    assert a.accounting() == b.accounting()
    assert np.array_equal(a.compute_times, b.compute_times)


# --------------------------------------------------------------------------
# the compat shim itself
# --------------------------------------------------------------------------


def test_resolve_scenario_warns_and_builds():
    with pytest.warns(DeprecationWarning, match="somewhere: passing cancel_redundant"):
        sc = resolve_scenario(None, {"cancel_redundant": True, "speeds": UNSET}, where="somewhere")
    assert sc == Scenario(cancel_redundant=True)


def test_resolve_scenario_passthrough_no_warning():
    sc = Scenario(n_batches=2)
    with no_warnings():
        out = resolve_scenario(sc, {"speeds": UNSET}, where="somewhere")
    assert out is sc


def test_scenario_from_kwargs_is_silent_internal_plumbing():
    with no_warnings():
        sc = scenario_from_kwargs(cancel_redundant=True, n_tasks=UNSET)
    assert sc == Scenario(cancel_redundant=True)


# --------------------------------------------------------------------------
# every entry point's loose-kwarg shim: warns exactly once, naming itself
# --------------------------------------------------------------------------


def _call_sample_job_times(kw):
    sample_job_times(Exponential(1.0), 4, 2, 4, seed=0, backend="python", **kw)


def _call_simulate_epochs(kw):
    from repro.cluster import simulate_epochs

    simulate_epochs(Exponential(1.0), 2, 2, np.zeros(1), 2, seed=0, **kw)


def _call_frontier_dynamic(kw):
    frontier_job_times_dynamic(
        Exponential(1.0), 2, [1], 2, seed=0, **dict(kw, speeds=(1.0, 1.0))
    )


def _call_plan_cluster(kw):
    planner = RedundancyPlanner(4, candidates=[1, 2])
    planner.plan_cluster(Exponential(1.0), n_reps=4, seed=0, backend="python", **kw)


def _call_plan_sweep(kw):
    plan_sweep([Exponential(1.0)], [4], n_reps=4, seed=0, backend="python", **kw)


def _call_runtime(kw):
    from repro.cluster.runtime import Runtime

    Runtime(2, **kw)  # construction resolves the scenario; no sockets yet


def _call_runtime_master(kw):
    from repro.cluster.runtime import RuntimeMaster

    RuntimeMaster(2, **kw)


def _loose_kwarg_cases():
    from repro.cluster import Speculation

    return [
        pytest.param({"cancel_redundant": True}, id="cancel_redundant"),
        pytest.param({"speculation": Speculation(interval=0.5, theta=2.0)}, id="speculation"),
    ]


@pytest.mark.parametrize("kw", _loose_kwarg_cases())
@pytest.mark.parametrize(
    "name,call",
    [
        ("sample_job_times", _call_sample_job_times),
        ("simulate_epochs", _call_simulate_epochs),
        ("frontier_job_times_dynamic", _call_frontier_dynamic),
        ("plan_cluster", _call_plan_cluster),
        ("plan_sweep", _call_plan_sweep),
        ("Runtime", _call_runtime),
        ("RuntimeMaster", _call_runtime_master),
    ],
    ids=lambda v: v if isinstance(v, str) else "",
)
def test_every_entry_point_loose_kwarg_warns_once_naming_itself(name, call, kw):
    """Every public entry point -- including the live runtime constructors --
    shims every legacy loose-kwarg spelling through one DeprecationWarning
    that names the entry point; nested delegation (plan_sweep -> plan_cluster
    -> sample_job_times) must not warn again."""
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        call(kw)
    shim = [
        w
        for w in caught
        if issubclass(w.category, DeprecationWarning) and "loose keyword" in str(w.message)
    ]
    assert len(shim) == 1, [str(w.message) for w in caught]
    assert str(shim[0].message).startswith(f"{name}: "), str(shim[0].message)


# --------------------------------------------------------------------------
# the single validation path: errors name the field, once, everywhere
# --------------------------------------------------------------------------


def test_validate_messages_name_the_field():
    sched = scn.seeded_schedule(4, seed=0, fail_rate=0.1, mean_downtime=1.0)
    with pytest.raises(ValueError, match="not both"):
        Scenario(churn=ChurnProcess(0.1, 1.0), churn_schedule=sched).validate()
    with pytest.raises(ValueError, match=r"worker ids must lie in \[0, 2\)"):
        Scenario(churn_schedule=sched).validate(n_workers=2)
    with pytest.raises(ValueError, match="unknown scheduler"):
        Scenario(scheduler="round_robin").validate()
    with pytest.raises(ValueError, match="Scenario.n_batches"):
        Scenario(n_batches=9).validate(n_workers=4)
    with pytest.raises(ValueError, match="Scenario.n_workers=4 does not match"):
        Scenario(n_workers=4).validate(n_workers=6)
    with pytest.raises(ValueError, match="Scenario.speeds"):
        Scenario(speeds=(1.0, -1.0)).validate()
    with pytest.raises(ValueError, match="Scenario.dtype"):
        Scenario(dtype="float16").validate()
    with pytest.raises(ValueError, match="backend='jax'"):
        Scenario(dtype="float64").validate(backend="python")
    with pytest.raises(ValueError, match="Scenario.devices"):
        Scenario(devices=2).validate(backend="python")


def test_engine_constructor_routes_through_scenario_validate():
    """The Python engine shares the one validation path: its errors are the
    Scenario ones.  (``n_batches`` is deliberately absent: the engine clamps
    it to the alive-worker count at dispatch.)"""
    with pytest.raises(ValueError, match="one entry per worker"):
        ClusterEngine(4, speeds=[1.0, 1.0])
    with pytest.raises(ValueError, match="not both"):
        sched = scn.seeded_schedule(4, seed=0, fail_rate=0.1, mean_downtime=1.0)
        ClusterEngine(4, churn=ChurnProcess(0.1, 1.0), churn_schedule=sched)


def test_entry_points_reject_dtype_on_python_backend():
    with pytest.raises(ValueError, match="Scenario.dtype"):
        sample_job_times(
            Exponential(1.0), 4, 2, 10, backend="python", scenario=Scenario(dtype="float64")
        )


# --------------------------------------------------------------------------
# the frozen object itself
# --------------------------------------------------------------------------


def test_scenario_hashable_and_replace():
    sc = Scenario(speeds=[2.0, 1.0], job_plans=[JobPlan(n_batches=1), None])
    assert isinstance(sc.speeds, tuple) and isinstance(sc.job_plans, tuple)
    assert isinstance(hash(sc), int)  # frozen: can key jit/plan caches
    sc2 = sc.replace(cancel_redundant=True)
    assert sc2.cancel_redundant and not sc.cancel_redundant
    assert sc.job_plan_for(0) == JobPlan(n_batches=1)
    assert sc.job_plan_for(1) is None
    assert sc.job_plan_for(2) == JobPlan(n_batches=1)  # cycles


def test_scenario_routing_predicates():
    assert not Scenario().is_dynamic and not Scenario().is_space
    assert Scenario(speeds=(1.0, 2.0)).is_dynamic
    assert Scenario(workers_per_job=2).is_space
    assert Scenario(scheduler="packed").is_space


def test_to_engine_kwargs_requires_workers():
    with pytest.raises(ValueError, match="n_workers"):
        Scenario().to_engine_kwargs()
    kw = Scenario(n_batches=2, cancel_redundant=True).to_engine_kwargs(4)
    assert kw["n_batches"] == 2 and kw["cancel_redundant"] is True
    assert set(kw) == {
        "n_batches",
        "cancel_redundant",
        "size_dependent",
        "speeds",
        "churn",
        "churn_schedule",
        "controller",
        "scheduler",
        "workers_per_job",
        "speculation",
        "retry",
    }


# --------------------------------------------------------------------------
# Scenario v2 serialization: exact JSON round-trip + replace()
# --------------------------------------------------------------------------


def _kitchen_sink_scenario():
    from repro.cluster import ChurnSchedule, Speculation

    return Scenario(
        dist=Pareto(sigma=0.1 + 0.2, alpha=2.2),  # non-representable floats
        n_workers=8,
        n_batches=4,
        n_tasks=16,
        cancel_redundant=True,
        size_dependent=False,
        speeds=(1.0, 0.3, 1.7, 1.0, 1.0, 1.0, 1.0, 2.0 / 3.0),
        churn_schedule=ChurnSchedule(times=(0.5, 1.25), wids=(3, 3), ups=(False, True)),
        speculation=Speculation(interval=0.23, theta=1.5, min_observations=2, max_backups=3),
        scheduler="packed",
        workers_per_job=2,
        job_plans=(JobPlan(workers=2, n_batches=2), None),
        jobs_per_stream=8,
        dtype="float64",
        rep_chunk=32,
        devices=1,
    )


def test_scenario_json_roundtrip_is_exact():
    sc = _kitchen_sink_scenario()
    back = Scenario.from_json(sc.to_json())
    assert back == sc  # dataclass equality: every field, floats bit-exact
    assert Scenario.from_json(Scenario().to_json()) == Scenario()
    # each distribution family round-trips
    for dist in (
        Exponential(1.0 / 3.0),
        ShiftedExponential(0.1 + 0.2, 1.7),
        Pareto(0.9, 2.2),
    ):
        assert Scenario.from_json(Scenario(dist=dist).to_json()) == Scenario(dist=dist)
    from repro.core.service_time import Empirical

    emp = Scenario(dist=Empirical(samples=(0.5, 1.0 / 7.0, 2.0)))
    assert Scenario.from_json(emp.to_json()) == emp


def test_scenario_json_churn_process_and_replan_roundtrip():
    from repro.cluster import ReplanConfig

    sc = Scenario(
        churn=ChurnProcess(fail_rate=0.05, mean_downtime=1.0 / 3.0),
        replan=ReplanConfig(window=256, refit_every=64, min_observations=32, objective="cov"),
    )
    assert Scenario.from_json(sc.to_json()) == sc


def test_scenario_json_schema_is_tagged_and_versioned():
    import json

    d = json.loads(_kitchen_sink_scenario().to_json())
    assert d["version"] == 2
    assert d["dist"] == {"kind": "Pareto", "sigma": 0.1 + 0.2, "alpha": 2.2}
    assert d["speculation"]["theta"] == 1.5
    assert d["scheduler"] == "packed"
    assert d["job_plans"][1] is None


def test_scenario_from_dict_rejects_bad_version_and_unknown_fields():
    with pytest.raises(ValueError, match="version"):
        Scenario.from_dict({"version": 1})
    with pytest.raises(ValueError, match="unknown fields"):
        Scenario.from_dict({"version": 2, "frobnicate": 1})
    with pytest.raises(ValueError, match="unknown distribution kind"):
        Scenario.from_dict({"version": 2, "dist": {"kind": "Cauchy"}})


def test_scenario_replace_derives_variants():
    base = Scenario(n_batches=2, cancel_redundant=False)
    on = base.replace(cancel_redundant=True)
    assert on.cancel_redundant and on.n_batches == 2
    assert base.cancel_redundant is False  # frozen original untouched
    with pytest.raises(TypeError):
        base.replace(no_such_field=1)
