"""End-to-end integration: training learns, restart is deterministic,
serving round-trips, planner wiring works."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data import PipelineConfig, SyntheticLM
from repro.models import build_model
from repro.optim import AdamW, cosine_with_warmup
from repro.runtime.serve import make_prefill_step, make_serve_step
from repro.runtime.train import init_state, make_train_step


def _setup(arch="qwen2-1.5b", steps=40, seq=32, batch=8):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    pipe = SyntheticLM(PipelineConfig(cfg.vocab_size, seq, batch, seed=0))
    opt = AdamW(cosine_with_warmup(3e-3, 5, steps))
    step = jax.jit(make_train_step(model, opt), donate_argnums=(0,))
    return cfg, model, pipe, opt, step


def test_training_reduces_loss():
    cfg, model, pipe, opt, step = _setup(steps=60)
    state = init_state(model, opt, jax.random.key(0))
    losses = []
    for s in range(60):
        batch = {k: jnp.asarray(v) for k, v in pipe.global_batch(s).items()}
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.8, (losses[0], losses[-1])
    assert all(np.isfinite(losses))


def test_restart_determinism(tmp_path):
    """Stop at step k, restore, continue: the loss stream must be identical
    to an uninterrupted run (checkpoint/restart is exact)."""
    total, k = 20, 10
    cfg, model, pipe, opt, step = _setup(steps=total)

    # uninterrupted run
    state = init_state(model, opt, jax.random.key(0))
    ref_losses = []
    ckpt_state = None
    for s in range(total):
        batch = {k2: jnp.asarray(v) for k2, v in pipe.global_batch(s).items()}
        state, m = step(state, batch)
        ref_losses.append(float(m["loss"]))
        if s == k - 1:
            ckpt_state = jax.tree.map(np.asarray, state)

    mgr = CheckpointManager(tmp_path, keep=1)
    mgr.save(k, ckpt_state)

    # restart from the checkpoint (fresh everything)
    cfg2, model2, pipe2, opt2, step2 = _setup(steps=total)
    like = jax.eval_shape(lambda: init_state(model2, opt2, jax.random.key(0)))
    restored, s0 = mgr.restore(like)
    state2 = jax.tree.map(jnp.asarray, restored)
    assert s0 == k
    for s in range(k, total):
        batch = {k2: jnp.asarray(v) for k2, v in pipe2.global_batch(s).items()}
        state2, m = step2(state2, batch)
        # bitwise-deterministic continuation on the same backend
        assert float(m["loss"]) == pytest.approx(ref_losses[s], abs=1e-6), s


def test_serve_prefill_decode_roundtrip():
    cfg = get_config("qwen2-1.5b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    prefill = jax.jit(make_prefill_step(model, 24))
    step = jax.jit(make_serve_step(model))
    toks = jax.random.randint(jax.random.key(1), (2, 16), 0, cfg.vocab_size)
    logits, cache, t = prefill(params, {"tokens": toks})
    outs = []
    tok = jnp.argmax(logits[:, : cfg.vocab_size], -1)[:, None].astype(jnp.int32)
    tok0 = tok  # first greedy token: the determinism reference below
    for _ in range(8):
        logits, cache, t = step(params, cache, tok, t)
        tok = jnp.argmax(logits[:, : cfg.vocab_size], -1)[:, None].astype(jnp.int32)
        outs.append(tok)
    assert int(t) == 24
    assert jnp.isfinite(logits).all()
    # greedy decode is deterministic: rerun matches
    logits2, cache2, t2 = prefill(params, {"tokens": toks})
    tok2 = jnp.argmax(logits2[:, : cfg.vocab_size], -1)[:, None].astype(jnp.int32)
    np.testing.assert_array_equal(np.asarray(tok0), np.asarray(tok2))


def test_perf_flags_do_not_change_loss():
    """sequence_parallel / cache_in_carry / remat_policy are numerics-neutral."""
    base_cfg, model, pipe, opt, step = _setup(steps=3)
    state = init_state(model, opt, jax.random.key(0))
    batch = {k: jnp.asarray(v) for k, v in pipe.global_batch(0).items()}
    _, m0 = step(state, batch)

    for overrides in (
        {"remat_policy": "block_outs"},
        {"sequence_parallel": True},  # no mesh context: annotation no-ops
        {"remat": False},
    ):
        cfg2 = get_config("qwen2-1.5b", smoke=True, **overrides)
        model2 = build_model(cfg2)
        step2 = jax.jit(make_train_step(model2, opt))
        state2 = init_state(model2, opt, jax.random.key(0))
        _, m2 = step2(state2, batch)
        assert float(m2["loss"]) == pytest.approx(float(m0["loss"]), abs=1e-5), overrides
