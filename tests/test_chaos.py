"""Chaos harness e2e: FaultPlan-injected faults, payload-failure retries, a
master crash with durable-journal recovery -- and after all of it, the one
journal still replays through the DES engine bit-for-bit.

The acceptance shape (per seed): a scheduled worker kill + a worker slowdown
+ an injected payload exception land during a two-job run whose master
journals every decision; mid-run the master "crashes" (torn sockets, no
cleanup), ``RuntimeMaster.recover`` rebuilds it from the journal, fresh
workers re-join the recovered wids, ``resume()`` finishes the jobs -- and the
full journal (crash and recovery as one trace) replays exactly: identical
accounting counters and identical per-job records.
"""

import asyncio
import json
import os

import pytest

from repro.cluster import FaultPlan, Retry, Scenario
from repro.cluster.runtime import (
    LiveJob,
    Runtime,
    RuntimeMaster,
    read_journal,
    replay_trace,
    spawn_worker_thread,
    trace_accounting,
)

pytestmark = pytest.mark.timeout(180)

# CI's chaos leg (and local soak runs) widen the sweep via CHAOS_SEEDS=<n>
SEEDS = list(range(max(5, int(os.environ.get("CHAOS_SEEDS", "5")))))


async def join_threads(threads, timeout_s=10.0):
    """Join worker threads off the event loop: a blocking ``Thread.join`` on
    the loop thread would stall the callbacks that flush the master's socket
    closes, so workers would never see EOF and every join would time out."""
    loop = asyncio.get_running_loop()
    for t in threads:
        await loop.run_in_executor(None, t.join, timeout_s)


def record_tuple(rec):
    return (
        rec.job_id,
        rec.name,
        rec.arrival,
        rec.start,
        rec.finish,
        rec.n_batches,
        rec.replication,
    )


def assert_exact_twin(events, report):
    """Fold, replay, and live counters all agree exactly; job records match."""
    acct = trace_accounting(events)
    assert acct == report.accounting()
    eng = replay_trace(events)
    assert eng.accounting() == acct
    live = sorted(report.records, key=lambda r: r.job_id)
    twin = sorted(eng.records, key=lambda r: r.job_id)
    assert len(live) == len(twin)
    for lr, er in zip(live, twin):
        assert record_tuple(lr) == record_tuple(er)
    return eng


# --------------------------------------------------------------------------
# the acceptance scenario: kill + slowdown + payload raise + crash + recover
# --------------------------------------------------------------------------


@pytest.mark.parametrize("seed", SEEDS)
def test_chaos_kill_retry_crash_recover_exact_twin(tmp_path, seed):
    journal = str(tmp_path / f"chaos-{seed}.jsonl")
    sc = Scenario(
        n_batches=3,
        retry=Retry(max_attempts=2, backoff_s=0.05, max_backoff_s=0.2),
        faults=FaultPlan(
            seed=seed,
            kills=((seed % 3, 0.35),),  # tear one worker's socket mid-job-0
            slowdowns=(((seed + 1) % 3, 0.0, 2.0),),  # one worker runs at half speed
            payload_errors=((0, 1, 1),),  # job 0 batch 1: first dispatch raises
        ),
    )
    kw = dict(heartbeat_s=0.05, heartbeat_timeout_s=2.0, lease_floor_s=30.0)

    async def phase_one():
        master = RuntimeMaster(3, sc, journal=journal, **kw)
        port = await master.start()
        threads = [spawn_worker_thread(master.host, port) for _ in range(3)]
        await master.wait_for_workers()
        jobs = [
            LiveJob(job_id=0, costs=(0.5, 0.5, 0.5), name="chaotic"),
            LiveJob(job_id=1, costs=(0.6, 0.6, 0.6), arrival=0.05, name="later"),
        ]
        run_task = asyncio.ensure_future(master.run(jobs, timeout_s=60.0))
        # crash once job 1 is genuinely in flight: queued + in-flight state,
        # delivered faults, and consumed retries all cross the crash boundary
        while not any(e["ev"] == "dispatch" and e["job"] == 1 for e in master.recorder.events):
            await asyncio.sleep(0.01)
        await asyncio.sleep(0.05)
        run_task.cancel()
        try:
            await run_task
        except asyncio.CancelledError:
            pass
        await master.crash()
        await join_threads(threads, 5.0)

    async def phase_two():
        master = RuntimeMaster.recover(journal, **kw)
        port = await master.start()
        threads = [spawn_worker_thread(master.host, port) for _ in range(3)]
        try:
            report = await master.resume(timeout_s=60.0)
        finally:
            await master.close()
            await join_threads(threads, 5.0)
        return report

    asyncio.run(phase_one())
    mid = read_journal(journal)  # what survived the crash, before recovery
    assert mid[0]["ev"] == "scenario"
    assert not any(e["ev"] == "recover" for e in mid)

    report = asyncio.run(phase_two())

    # the journal IS the trace: one file covers crash + recovery
    events = read_journal(journal)
    assert events == json.loads(json.dumps(list(report.trace)))

    # both jobs completed despite kill + payload raise + crash
    assert [r.job_id for r in sorted(report.records, key=lambda r: r.job_id)] == [0, 1]
    assert all(r.finish < float("inf") for r in report.records)

    # every injected fault left its mark
    chaos_kinds = {e["kind"] for e in events if e["ev"] == "chaos"}
    assert "kill" in chaos_kinds and "raise" in chaos_kinds
    fail_causes = [e["cause"] for e in events if e["ev"] == "fail"]
    assert "eof" in fail_causes  # the chaos kill, detected as a torn socket
    assert "crash" in fail_causes  # workers lost with the master
    assert report.n_task_failures >= 1  # the injected payload raise
    assert report.n_retries >= 1  # its backoff-released re-dispatch
    assert any(e["ev"] == "task_fail" for e in events)
    assert any(e["ev"] == "retry" for e in events)
    assert sum(1 for e in events if e["ev"] == "recover") == 1
    assert "PayloadError" in report.task_errors[0][3]

    # the tentpole claim: bit-exact accounting and records through the twin
    assert_exact_twin(events, report)


# --------------------------------------------------------------------------
# wire faults: drop/dup/delay under a respawning supervisor, still exact
# --------------------------------------------------------------------------


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_wire_chaos_with_supervisor_replays_exactly(seed):
    """Frames dropped, duplicated, and delayed at the master's send/receive
    boundary.  A dropped task or finish frame eventually blows the replica's
    lease, the master declares the worker dead, and a supervisor (one
    replacement per observed failure) re-joins capacity -- so the run always
    makes progress, whatever the fault dice rolled.  The trace, chaos scars
    and all, must still replay exactly."""
    sc = Scenario(
        n_batches=2,
        retry=Retry(max_attempts=3, backoff_s=0.05, max_backoff_s=0.2),
        faults=FaultPlan(seed=seed, drop_p=0.15, dup_p=0.10, delay_p=0.10, delay_s=0.02),
    )

    async def run():
        master = RuntimeMaster(
            2, sc, heartbeat_s=0.05, heartbeat_timeout_s=1.0, lease_factor=4.0, lease_floor_s=1.0
        )
        port = await master.start()
        threads = [spawn_worker_thread(master.host, port) for _ in range(2)]
        await master.wait_for_workers()

        async def supervise():
            handled = 0
            while not master._finalized:
                await asyncio.sleep(0.05)
                fails = sum(1 for e in master.recorder.events if e["ev"] == "fail")
                while handled < fails:
                    handled += 1
                    threads.append(spawn_worker_thread(master.host, port))

        sup = asyncio.ensure_future(supervise())
        try:
            report = await master.run(
                [LiveJob(job_id=0, costs=(0.2, 0.2, 0.2, 0.2), name="wired")],
                timeout_s=90.0,
            )
        finally:
            sup.cancel()
            await master.close()
            await join_threads(threads, 5.0)
        return report

    report = asyncio.run(run())
    assert len(report.records) == 1
    assert report.records[0].finish < float("inf")
    # the seeds exercise the wire layer for real
    assert any(e["ev"] == "chaos" for e in report.trace)
    assert_exact_twin(report.trace, report)


# --------------------------------------------------------------------------
# retry semantics without chaos: deterministic budget exhaustion
# --------------------------------------------------------------------------


def test_retry_budget_exhausted_abandons_exactly():
    """One worker, one batch, a payload that always raises: dispatch, fail,
    backoff, retry -- max_attempts times -- then the job is abandoned with
    finish=inf.  Counters are exact and the trace replays exactly."""
    sc = Scenario(n_batches=1, retry=Retry(max_attempts=2, backoff_s=0.05))
    report = Runtime(1, sc).run(
        [LiveJob(job_id=0, costs=(0.1,), payload="raise", name="doomed")], timeout_s=60.0
    )
    assert report.n_task_failures == 3  # initial attempt + 2 retries, all raise
    assert report.n_retries == 2
    assert len(report.records) == 1
    assert report.records[0].finish == float("inf")
    retries = [e for e in report.trace if e["ev"] == "retry"]
    assert [e["attempt"] for e in retries] == [1, 2]
    assert any(e["ev"] == "job_fail" for e in report.trace)
    # each backoff respected its floor
    fails = [e for e in report.trace if e["ev"] == "task_fail"]
    for f, r in zip(fails, retries):
        assert r["t"] - f["t"] >= 0.05 - 1e-9
    assert_exact_twin(report.trace, report)


# --------------------------------------------------------------------------
# journal plumbing: durability, torn tails, serialization round-trips
# --------------------------------------------------------------------------


def test_journal_equals_trace_and_survives_torn_tail(tmp_path):
    path = str(tmp_path / "run.jsonl")
    sc = Scenario(n_batches=2)
    report = Runtime(2, sc, journal=path).run(
        [LiveJob(job_id=0, costs=(0.05, 0.05), name="journaled")], timeout_s=30.0
    )
    events = read_journal(path)
    assert events == json.loads(json.dumps(list(report.trace)))
    assert_exact_twin(events, report)
    # a crash can tear the final line mid-write: the complete prefix survives
    with open(path, "ab") as f:
        f.write(b'{"ev": "disp')  # no newline, invalid JSON
    assert read_journal(path) == events
    # mid-file corruption is NOT silently skipped
    with open(path, "wb") as f:
        f.write(b'{"ev": "join", "t": 1.0}\n???garbage???\n{"ev": "flush", "t": 2.0}\n')
    with pytest.raises(json.JSONDecodeError):
        read_journal(path)


def test_faultplan_and_retry_serialize_and_validate():
    sc = Scenario(
        n_batches=2,
        retry=Retry(max_attempts=3, backoff_s=0.01, max_backoff_s=0.5),
        faults=FaultPlan(
            seed=7,
            kills=((1, 0.2),),
            slowdowns=((0, 0.0, 3.0),),
            hb_stalls=((1, 0.1, 0.4),),
            payload_errors=((0, 0, 2),),
            drop_p=0.05,
            dup_p=0.05,
            delay_p=0.05,
            delay_s=0.01,
        ),
    )
    assert Scenario.from_dict(json.loads(json.dumps(sc.to_dict()))) == sc
    # simulation backends reject the live-only knobs at the shared gate
    with pytest.raises(ValueError, match="faults"):
        Scenario(faults=FaultPlan(seed=1)).validate(n_workers=2, backend="python")
    with pytest.raises(ValueError, match="retry"):
        Scenario(retry=Retry()).validate(n_workers=2, backend="jax")
    # a fault plan naming an out-of-range wid is caught before anything runs
    with pytest.raises(ValueError, match="worker ids"):
        Scenario(faults=FaultPlan(seed=0, kills=((5, 0.1),))).validate(
            n_workers=2, backend="live"
        )
    # the backoff schedule: exponential, capped
    r = Retry(max_attempts=4, backoff_s=0.1, max_backoff_s=0.35)
    assert [r.backoff(k) for k in (1, 2, 3, 4)] == [0.1, 0.2, 0.35, 0.35]


def test_recovered_master_refuses_run_and_fresh_refuses_resume(tmp_path):
    path = str(tmp_path / "run.jsonl")
    sc = Scenario(n_batches=1)
    Runtime(1, sc, journal=path).run([LiveJob(job_id=0, costs=(0.02,))], timeout_s=30.0)

    async def check():
        fresh = RuntimeMaster(1, sc)
        with pytest.raises(RuntimeError, match="resume"):
            await fresh.resume()
        recovered = RuntimeMaster.recover(path)
        with pytest.raises(RuntimeError, match="resume"):
            await recovered.run([])
        # the journaled run had completed: resume finalizes immediately
        report = await recovered.resume(timeout_s=5.0)
        await recovered.close()
        return report

    report = asyncio.run(check())
    assert len(report.records) == 1
    assert report.records[0].finish < float("inf")
