"""RedundancyPlanner + distribution fitting + trace workloads (§VI-§VII)."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings
except ModuleNotFoundError:  # test extra not installed: seeded fallback engine
    from _hypothesis_compat import given, settings

import strategies as scn
from repro.core import analysis, traces
from repro.core.planner import RedundancyPlanner, fit_service_time
from repro.core.service_time import Empirical, Exponential, Pareto, ShiftedExponential


@settings(max_examples=8, deadline=None)
@given(dist=scn.service_dists(), n=scn.worker_counts())
def test_plan_picks_frontier_argmin_on_generated_dists(dist, n):
    """Any shared-strategy scenario: the plan's B sits at the argmin of its
    own closed-form frontier, over exactly the feasible divisor set."""
    plan = RedundancyPlanner(n).plan(dist, objective="mean")
    assert plan.frontier_B == tuple(analysis.feasible_B(n))
    finite = [m for m in plan.frontier_mean if np.isfinite(m)]
    assert plan.predicted_mean == min(finite)
    assert plan.n_batches * plan.replication <= n


def test_plan_exponential_endpoints():
    p = RedundancyPlanner(16)
    plan_mean = p.plan(Exponential(mu=2.0), "mean")
    plan_cov = p.plan(Exponential(mu=2.0), "cov")
    assert plan_mean.n_batches == 1 and plan_mean.replication == 16
    assert plan_cov.n_batches == 16 and plan_cov.replication == 1
    assert plan_mean.diversity == 1.0 and plan_cov.diversity == 0.0


def test_plan_sexp_middle():
    n, delta, mu = 100, 0.05, 5.0  # N*delta*mu = 25 -> middle point
    plan = RedundancyPlanner(n).plan(ShiftedExponential(delta, mu), "mean")
    assert 1 < plan.n_batches < n
    assert plan.n_batches == analysis.argmin_B(ShiftedExponential(delta, mu), n, "mean")


def test_plan_blend_between_endpoints():
    p = RedundancyPlanner(16)
    d = Exponential(mu=1.0)
    b_mean = p.plan(d, "mean").n_batches
    b_cov = p.plan(d, "cov").n_batches
    b_blend = p.plan(d, "blend", blend=0.5).n_batches
    assert min(b_mean, b_cov) <= b_blend <= max(b_mean, b_cov)


def test_fit_recovers_families():
    rng = np.random.default_rng(0)
    x_exp = rng.exponential(2.0, size=4000)
    x_sexp = 5.0 + rng.exponential(0.5, size=4000)
    x_par = 2.0 * rng.uniform(size=4000) ** (-1 / 1.5)
    assert isinstance(fit_service_time(x_exp), (Exponential, ShiftedExponential))
    f = fit_service_time(x_sexp)
    assert isinstance(f, ShiftedExponential) and f.delta == pytest.approx(5.0, rel=0.05)
    f = fit_service_time(x_par)
    assert isinstance(f, Pareto) and f.alpha == pytest.approx(1.5, rel=0.1)


def test_empirical_plan_matches_closed_form_when_exponential():
    rng = np.random.default_rng(1)
    samples = rng.exponential(1.0, size=8000)
    p = RedundancyPlanner(8)
    emp = p.plan_empirical(samples, "mean", n_mc=8000)
    # closed form says full diversity for exponential tasks
    assert emp.n_batches in (1, 2)  # MC noise may pick the neighbour
    assert emp.frontier_mean[0] < emp.frontier_mean[-1]


def test_plan_auto_on_heavy_tail_prefers_redundancy():
    rng = np.random.default_rng(2)
    samples = 1.0 * rng.uniform(size=6000) ** (-1 / 1.3)  # Pareto alpha=1.3
    plan = RedundancyPlanner(100).plan_auto(samples, "mean")
    assert plan.n_batches < 100  # some replication chosen
    assert plan.source.startswith("closed_form:Pareto")


def test_empirical_dist_plan_path():
    samples = tuple(np.random.default_rng(3).exponential(1.0, size=2000).tolist())
    plan = RedundancyPlanner(8).plan(Empirical(samples=samples), "mean")
    assert plan.source == "empirical_bootstrap"


def test_blend_select_ignores_degenerate_candidates():
    """Regression: an inf CoV lane (zero-mean candidate) used to poison the
    blend normalization with inf - inf = NaN, and np.argmin then picked the
    degenerate candidate.  The blend must rank finite candidates only."""
    p = RedundancyPlanner(8, candidates=[1, 2, 4])
    means = np.array([0.0, 1.0, 2.0])
    covs = np.array([np.inf, 0.2, 0.1])
    with np.errstate(all="raise"):  # any inf - inf NaN arithmetic fails loudly
        picked = p._select(means, covs, "blend", blend=0.5)
    assert picked in (2, 4)
    # all-degenerate frontier: selection still returns a candidate, no NaNs
    all_bad = np.array([np.inf] * 3)
    assert p._select(all_bad, all_bad, "blend", blend=0.5) == 1


@pytest.mark.parametrize("backend", ["python", "jax"])
def test_plan_cluster_blend_survives_zero_mean_samples(backend):
    """End-to-end: a degenerate all-zero trace makes every candidate's mean 0
    and CoV inf; plan_cluster(objective='blend') must still return a plan
    with finite machinery (no NaN scores, no RuntimeWarnings)."""
    dist = Empirical(samples=(0.0, 0.0, 0.0))
    planner = RedundancyPlanner(4)
    with np.errstate(invalid="raise"):
        plan = planner.plan_cluster(dist, objective="blend", n_reps=30, seed=0, backend=backend)
    assert plan.n_batches in planner.candidates
    assert not any(np.isnan(m) for m in plan.frontier_mean)


def test_trace_jobs_families_and_planning():
    jobs = traces.synthetic_google_jobs(seed=7)
    assert len(jobs) == 10
    fams = {j.name: traces.tail_family(j.task_times) for j in jobs}
    # generator families should mostly agree with the classifier
    agree = sum(fams[j.name] == j.family for j in jobs)
    assert agree >= 7
    # heavy-tail jobs should plan more redundancy than exp-tail large-shift jobs
    p = RedundancyPlanner(100)
    heavy = [j for j in jobs if j.family == "heavy"][0]
    exp4 = [j for j in jobs if j.name == "job4"][0]  # shift 1000 job
    b_heavy = p.plan_empirical(heavy.task_times, "mean", n_mc=4000).n_batches
    b_exp = p.plan_empirical(exp4.task_times, "mean", n_mc=4000).n_batches
    assert b_heavy <= b_exp  # more redundancy (smaller B) for heavy tails


def test_trace_roundtrip(tmp_path):
    jobs = traces.synthetic_google_jobs(seed=9)
    traces.save_jobs(jobs, tmp_path / "jobs")
    loaded = traces.load_jobs(tmp_path / "jobs")
    assert {j.name for j in loaded} == {j.name for j in jobs}
    by_name = {j.name: j for j in loaded}
    for j in jobs:
        np.testing.assert_allclose(by_name[j.name].task_times, j.task_times)
