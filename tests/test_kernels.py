"""Pallas kernels vs pure-jnp oracles: shape/dtype sweep + properties.

All kernels run in interpret mode on CPU (the kernel body executes exactly
as it would inside the TPU grid).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ModuleNotFoundError:  # test extra not installed: seeded fallback engine
    from _hypothesis_compat import given, settings, st

from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.ref import flash_attention_ref, rms_norm_ref
from repro.kernels.rmsnorm import rms_norm_fused


def _qkv(key, b, h, kh, sq, sk, hd, dtype):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, h, sq, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (b, kh, sk, hd), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (b, kh, sk, hd), jnp.float32).astype(dtype)
    return q, k, v


TOL = {jnp.float32: dict(atol=2e-5, rtol=2e-5), jnp.bfloat16: dict(atol=3e-2, rtol=3e-2)}


# ---------------------------------------------------------------- flash attn


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,h,kh,s,hd",
    [
        (1, 4, 4, 128, 64),   # MHA, one block
        (2, 4, 2, 256, 64),   # GQA 2:1, multiple blocks
        (1, 8, 1, 192, 128),  # MQA, ragged seq vs block
        (1, 2, 2, 64, 256),   # gemma-style head_dim 256
    ],
)
@pytest.mark.parametrize("causal", [True, False])
def test_flash_kernel_sweep(dtype, b, h, kh, s, hd, causal):
    q, k, v = _qkv(jax.random.key(0), b, h, kh, s, s, hd, dtype)
    got = flash_attention_fwd(
        q, k, v, causal=causal, block_q=64, block_k=64, interpret=True
    )
    want = flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(
        got.astype(jnp.float32), want.astype(jnp.float32), **TOL[dtype]
    )


def test_flash_kernel_window():
    q, k, v = _qkv(jax.random.key(1), 1, 2, 1, 256, 256, 64, jnp.float32)
    got = flash_attention_fwd(
        q, k, v, causal=True, window=96, block_q=64, block_k=64, interpret=True
    )
    want = flash_attention_ref(q, k, v, causal=True, window=96)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


def test_flash_kernel_cross_attention_lengths():
    # Sq != Sk (e.g. chunked prefill append)
    q, k, v = _qkv(jax.random.key(2), 1, 2, 2, 64, 192, 64, jnp.float32)
    got = flash_attention_fwd(q, k, v, causal=False, block_q=64, block_k=64, interpret=True)
    want = flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


@settings(max_examples=12, deadline=None)
@given(
    s=st.integers(16, 160),
    h=st.sampled_from([2, 4]),
    g=st.sampled_from([1, 2]),
    causal=st.booleans(),
)
def test_flash_kernel_property(s, h, g, causal):
    """Property: arbitrary (non-block-aligned) seq lengths match the oracle."""
    kh = h // g
    q, k, v = _qkv(jax.random.key(s), 1, h, kh, s, s, 32, jnp.float32)
    got = flash_attention_fwd(q, k, v, causal=causal, block_q=32, block_k=32, interpret=True)
    want = flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(got, want, atol=3e-5, rtol=3e-5)


def test_flash_matches_model_layer_path():
    """Kernel contract == the model's blockwise-jnp attention."""
    from repro.models.layers import flash_attention as jnp_flash

    b, s, h, kh, hd = 2, 96, 4, 2, 32
    q, k, v = _qkv(jax.random.key(3), b, h, kh, s, s, hd, jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    got = flash_attention_fwd(q, k, v, causal=True, block_q=32, block_k=32, interpret=True)
    want = jnp_flash(
        jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2),
        pos, pos, causal=True, block_k=32,
    )
    np.testing.assert_allclose(got, jnp.swapaxes(want, 1, 2), atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------- rmsnorm


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(4, 96, 64), (3, 128), (1, 7, 33)])
@pytest.mark.parametrize("plus_one", [False, True])
def test_rmsnorm_kernel_sweep(dtype, shape, plus_one):
    x = jax.random.normal(jax.random.key(0), shape, jnp.float32).astype(dtype)
    w = jax.random.normal(jax.random.key(1), shape[-1:], jnp.float32).astype(dtype) * 0.1
    got = rms_norm_fused(x, w, plus_one=plus_one, block_rows=32, interpret=True)
    want = rms_norm_ref(x, w, plus_one=plus_one)
    np.testing.assert_allclose(
        got.astype(jnp.float32), want.astype(jnp.float32), **TOL[dtype]
    )


def test_rmsnorm_matches_model_layer():
    from repro.models.layers import rms_norm

    x = jax.random.normal(jax.random.key(2), (4, 17, 48))
    w = jnp.ones((48,)) * 1.3
    got = rms_norm_fused(x, w, interpret=True)
    np.testing.assert_allclose(got, rms_norm(x, w), atol=1e-6, rtol=1e-6)


# ------------------------------------------------------------- masked cover


def test_masked_cover_matches_oracle():
    """Fused Pallas ``max_b min_r`` == gang_cover_times on a (B, r) sweep,
    including padded slots and non-divisible rep/block shapes."""
    from repro.core.simulator import gang_cover_times
    from repro.kernels.cover import bench_masked_cover, masked_cover_times

    draws = jax.random.exponential(jax.random.key(3), (37, 6, 4))
    for b, r in [(1, 1), (3, 2), (6, 4), (2, 4), (6, 1)]:
        got = masked_cover_times(draws, jnp.int32(b), jnp.int32(r), block_rows=16)
        want = gang_cover_times(draws, b, r)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)
    # the measurement hook runs everywhere and reports honestly: interpret
    # mode off-TPU, where the XLA fusion is expected to keep winning
    m = bench_masked_cover(reps=256, iters=1)
    assert set(m) == {"pallas_seconds", "jnp_seconds", "interpret", "pallas_wins"}
    if jax.default_backend() != "tpu":
        assert m["interpret"]


def test_pallas_cover_routing_is_opt_in(monkeypatch):
    from repro.cluster import vectorized
    from repro.kernels.cover import pallas_cover_wins

    monkeypatch.delenv("REPRO_PALLAS_COVER", raising=False)
    assert not pallas_cover_wins()
    assert vectorized._cover_impl() is vectorized._frontier_cover
    monkeypatch.setenv("REPRO_PALLAS_COVER", "1")
    if jax.default_backend() != "tpu":
        assert not pallas_cover_wins()  # interpret mode loses: stay on XLA
