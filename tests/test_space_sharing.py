"""Space-sharing scheduler differential harness: engine vs jax space lane.

The space-sharing subsystem (``cluster/scheduler.py`` + the epoch scan's
space lane) runs concurrent jobs on disjoint worker subsets under per-job
heterogeneous (B, r, cancellation) plans.  The contract mirrors the dynamic
harness in ``tests/test_epoch_scan.py``:

  * ``fifo_gang`` is *bit-compatible* with the pre-scheduler engine on the
    same seeds, and the space lane in gang mode reproduces the legacy lane;
  * on a shared churn schedule with degenerate (constant) service times the
    jax space lane replays the engine **exactly** (float64 lanes: the
    engine's f64 arithmetic is mirrored formula-for-formula, so even
    tie-breaking decisions coincide) for all three policies;
  * with random draws, per-stream mean compute/response times agree at
    3 sigma;
  * accounting identities (cancellation reclaims exactly the redundant
    tails; worker-seconds conservation) hold per rep within the backend.

Scenario configs come from ``tests/strategies.py`` (the space-shared
generators added with this subsystem).
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ModuleNotFoundError:  # test extra not installed: seeded fallback engine
    from _hypothesis_compat import given, settings, st

import strategies as scn
from repro.cluster import (
    ClusterEngine,
    Job,
    JobPlan,
    make_scheduler,
    sample_job_times,
    simulate_epochs,
    simulate_fifo,
)
from repro.cluster.scheduler import BalancedScheduler, PackedScheduler
from repro.cluster.workers import ChurnSchedule
from repro.core.planner import RedundancyPlanner
from repro.core.service_time import Empirical, Exponential

# the crafted shared timeline every exact test replays: three failures, three
# rejoins, distinct event times, against six distinct worker speeds
SCHED = ChurnSchedule(
    times=(0.7, 1.9, 3.35, 5.1, 7.77, 9.4),
    wids=(2, 5, 2, 0, 5, 0),
    ups=(False, False, True, False, True, True),
)
SPEEDS = (1.0, 1.5, 0.7, 1.2, 0.9, 1.1)


def _records(report):
    starts = np.array([r.start for r in report.records])
    fins = np.array([r.finish for r in report.records])
    return starts, fins


def _z_mean(a: np.ndarray, b: np.ndarray) -> float:
    se = np.sqrt(a.var() / a.size + b.var() / b.size)
    if se == 0.0:
        return 0.0 if a.mean() == b.mean() else np.inf
    return float(abs(a.mean() - b.mean()) / se)


def _x64():
    import jax

    class _Ctx:
        def __enter__(self):
            self.prev = jax.config.jax_enable_x64
            jax.config.update("jax_enable_x64", True)

        def __exit__(self, *exc):
            jax.config.update("jax_enable_x64", self.prev)

    return _Ctx()


def _assert_exact(er, vr, rtol=1e-9):
    """Full-trajectory + accounting equality, engine vs one space-lane rep."""
    e_start, e_fin = _records(er)
    assert np.allclose(vr.starts[0], e_start, rtol=rtol, atol=1e-12)
    assert np.allclose(vr.finishes[0], e_fin, rtol=rtol, atol=1e-12)
    ea, va = er.accounting(), vr.accounting()
    assert np.isclose(va["worker_seconds"][0], ea["worker_seconds"], rtol=rtol)
    assert np.isclose(
        va["cancelled_seconds_saved"][0], ea["cancelled_seconds_saved"], rtol=rtol, atol=1e-9
    )
    assert va["n_worker_failures"][0] == ea["n_worker_failures"]
    assert va["n_replicas_rescued"][0] == ea["n_replicas_rescued"]
    vt = vr.epoch_times[0]
    assert np.allclose(vt[np.isfinite(vt)], np.asarray(er.epoch_times), rtol=rtol)


# --------------------------------------------------------------------------
# fifo_gang reduces to the current behavior (the bit-compat identity)
# --------------------------------------------------------------------------


def test_fifo_gang_engine_identity():
    """The scheduler refactor must leave the default engine path untouched:
    an explicit fifo_gang scheduler replays the default-constructed engine
    bit-for-bit on a churned, heterogeneous, cancelling workload."""
    d = Exponential(1.0)
    sched = scn.seeded_schedule(6, seed=9, fail_rate=0.08, mean_downtime=1.0)
    kw = dict(seed=5, n_batches=3, cancel_redundant=True, speeds=SPEEDS, churn_schedule=sched)
    jobs = lambda: [Job(job_id=i, dist=d, n_tasks=6) for i in range(12)]  # noqa: E731
    base = ClusterEngine(6, **kw).run(jobs())
    explicit = ClusterEngine(6, scheduler="fifo_gang", workers_per_job=None, **kw).run(jobs())
    assert _records(base)[0].tolist() == _records(explicit)[0].tolist()
    assert _records(base)[1].tolist() == _records(explicit)[1].tolist()
    assert base.accounting() == explicit.accounting()


def test_packed_full_width_requests_degenerate_to_gang():
    """workers_per_job = n on a static cluster: packed placement admits one
    job at a time on the whole pool -- exactly the gang schedule."""
    d = Exponential(1.0)

    def jobs():
        return [Job(job_id=i, dist=d, n_tasks=6, arrival=0.4 * i) for i in range(10)]

    for cancel in (False, True):
        gang = ClusterEngine(6, seed=2, n_batches=2, cancel_redundant=cancel).run(jobs())
        packed = ClusterEngine(
            6, seed=2, n_batches=2, cancel_redundant=cancel,
            scheduler="packed", workers_per_job=6,
        ).run(jobs())
        assert _records(gang)[0].tolist() == _records(packed)[0].tolist()
        assert _records(gang)[1].tolist() == _records(packed)[1].tolist()
        assert gang.accounting() == packed.accounting()


def test_gang_mode_space_lane_matches_legacy_lane():
    """scheduler='fifo_gang' + an all-None JobPlan forces the space lane in
    gang mode: it must reproduce the legacy single-gang lane and the engine
    exactly (float64) on the shared schedule."""
    d = Empirical(samples=(1.3,))
    with _x64():
        legacy = simulate_epochs(
            d, 6, 3, np.zeros(8), 1, seed=3, speeds=SPEEDS, churn_schedule=SCHED,
            dtype="float64",
        )
        space = simulate_epochs(
            d, 6, 3, np.zeros(8), 1, seed=3, speeds=SPEEDS, churn_schedule=SCHED,
            job_plans=[JobPlan()], dtype="float64",
        )
    assert np.allclose(space.starts, legacy.starts, rtol=1e-12)
    assert np.allclose(space.finishes, legacy.finishes, rtol=1e-12)
    assert np.isclose(space.worker_seconds[0], legacy.worker_seconds[0], rtol=1e-12)
    assert space.n_replicas_rescued[0] == legacy.n_replicas_rescued[0]
    jobs = [Job(job_id=i, dist=d, n_tasks=6) for i in range(8)]
    er = ClusterEngine(6, seed=3, n_batches=3, speeds=SPEEDS, churn_schedule=SCHED).run(jobs)
    _assert_exact(er, space)


# --------------------------------------------------------------------------
# exact differential: shared schedule + degenerate service times, 3 policies
# --------------------------------------------------------------------------


@pytest.mark.parametrize("policy", ["fifo_gang", "packed", "balanced"])
@pytest.mark.parametrize("cancel", [False, True], ids=["cancel_off", "cancel_on"])
def test_exact_trajectory_space_shared_schedule(policy, cancel):
    """Constant task times + a shared churn schedule pin every draw: the
    space lane must replay the engine's trajectory, rescues, regrants, and
    accounting exactly for every policy (f64 lanes tie-break like the f64
    engine)."""
    d = Empirical(samples=(1.3,))
    n, n_jobs, wpj = 6, 8, 2
    jobs = [Job(job_id=i, dist=d, n_tasks=n) for i in range(n_jobs)]
    er = ClusterEngine(
        n, seed=3, n_batches=2, cancel_redundant=cancel, speeds=SPEEDS,
        churn_schedule=SCHED, scheduler=policy, workers_per_job=wpj,
    ).run(jobs)
    with _x64():
        vr = simulate_epochs(
            d, n, 2, np.zeros(n_jobs), 1, seed=3, cancel_redundant=cancel,
            speeds=SPEEDS, churn_schedule=SCHED, scheduler=policy,
            workers_per_job=wpj, dtype="float64",
        )
    if policy != "fifo_gang":
        # narrow jobs overlap (space sharing exercised), and the r = 1
        # subsets make every failure a rescue
        e_start, e_fin = _records(er)
        assert (e_start[1:] < e_fin[:-1]).any()
        assert er.n_replicas_rescued > 0
    _assert_exact(er, vr)


def test_exact_heterogeneous_job_plans_shared_schedule():
    """Per-job (workers, B, cancellation) plans -- the regime the gang
    engine cannot express -- replay exactly on both backends, including
    arrivals mid-stream."""
    d = Empirical(samples=(1.7,))
    n, n_jobs = 6, 9
    arr = np.array([0.0, 0.0, 0.8, 1.2, 2.9, 4.0, 5.5, 6.1, 8.0])
    plans = scn.seeded_job_plans(n, seed=4)
    for policy in ("packed", "balanced"):
        jobs = [
            Job(job_id=i, dist=d, n_tasks=n, arrival=float(arr[i]), plan=plans[i % len(plans)])
            for i in range(n_jobs)
        ]
        er = ClusterEngine(
            n, seed=7, n_batches=3, speeds=SPEEDS, churn_schedule=SCHED,
            scheduler=policy, workers_per_job=2,
        ).run(jobs)
        with _x64():
            vr = simulate_epochs(
                d, n, 3, arr, 1, seed=7, speeds=SPEEDS, churn_schedule=SCHED,
                scheduler=policy, workers_per_job=2, job_plans=plans, dtype="float64",
            )
        _assert_exact(er, vr)
        # heterogeneous plans actually ran: at least two distinct B values
        assert len({r.n_batches for r in er.records}) >= 2


@settings(max_examples=4, deadline=None)
@given(
    policy=scn.space_schedulers(),
    wpj=scn.worker_requests(6),
    plans=scn.job_plan_cycles(6),
    seed=st.integers(0, 99),
)
def test_exact_generated_space_scenarios(policy, wpj, plans, seed):
    """Generated scenario grid: any policy x request x plan cycle must stay
    an exact engine replay on a shared schedule with degenerate draws."""
    d = Empirical(samples=(2.1,))
    n, n_jobs = 6, 6
    sched = scn.seeded_schedule(n, seed=seed, fail_rate=0.07, mean_downtime=1.2)
    jobs = [
        Job(job_id=i, dist=d, n_tasks=n, plan=plans[i % len(plans)]) for i in range(n_jobs)
    ]
    er = ClusterEngine(
        n, seed=seed, n_batches=2, speeds=SPEEDS, churn_schedule=sched,
        scheduler=policy, workers_per_job=wpj,
    ).run(jobs)
    with _x64():
        vr = simulate_epochs(
            d, n, 2, np.zeros(n_jobs), 1, seed=seed, speeds=SPEEDS, churn_schedule=sched,
            scheduler=policy, workers_per_job=wpj, job_plans=plans, dtype="float64",
        )
    _assert_exact(er, vr)


# --------------------------------------------------------------------------
# stochastic differential + accounting identities
# --------------------------------------------------------------------------


def test_space_shared_compute_and_response_match_engine():
    """Random draws, shared schedule: per-stream mean compute and response
    agree at 3 sigma between the engine and the space lane."""
    d = Exponential(1.0)
    n, n_jobs, wpj = 6, 18, 3
    sched = scn.seeded_schedule(n, seed=11, fail_rate=0.05, mean_downtime=1.0)
    e_ct, e_rt = [], []
    for s in range(25):
        jobs = [Job(job_id=i, dist=d, n_tasks=n) for i in range(n_jobs)]
        rep = ClusterEngine(
            n, seed=300 + s, n_batches=3, churn_schedule=sched,
            scheduler="packed", workers_per_job=wpj,
        ).run(jobs)
        ct, rt = rep.compute_times, rep.response_times
        e_ct.append(ct[np.isfinite(ct)].mean())
        e_rt.append(rt[np.isfinite(rt)].mean())
    vr = simulate_epochs(
        d, n, 3, np.zeros(n_jobs), 250, seed=1, churn_schedule=sched,
        scheduler="packed", workers_per_job=wpj,
    )
    assert np.isfinite(vr.compute_times).all()
    assert _z_mean(np.array(e_ct), vr.compute_times.mean(axis=1)) < 3.0
    assert _z_mean(np.array(e_rt), vr.response_times.mean(axis=1)) < 3.0


def test_mixed_cancellation_identity_on_scan():
    """Per-job cancellation must not change compute times and must reclaim
    exactly the redundant tails, rep for rep, even when only one job class
    cancels."""
    plans_on = [JobPlan(workers=4, cancel_redundant=True), JobPlan(workers=4)]
    plans_off = [JobPlan(workers=4), JobPlan(workers=4)]
    kw = dict(seed=5, scheduler="packed")
    on = simulate_epochs(Exponential(0.8), 8, 2, np.zeros(10), 50, job_plans=plans_on, **kw)
    off = simulate_epochs(Exponential(0.8), 8, 2, np.zeros(10), 50, job_plans=plans_off, **kw)
    assert np.allclose(on.compute_times, off.compute_times, rtol=1e-4, atol=1e-3)
    assert np.allclose(
        on.worker_seconds + on.cancelled_seconds_saved, off.worker_seconds, rtol=1e-4
    )
    assert (on.cancelled_seconds_saved > 0).all()
    assert (off.cancelled_seconds_saved == 0).all()


def test_space_sharing_cuts_response_time():
    """The headline effect: narrow concurrent jobs beat serial gangs on mean
    response (throughput), on both backends."""
    d = Exponential(1.0)
    arr = np.zeros(12)
    gang = simulate_fifo(d, 8, 2, arr, 200, seed=3)
    packed = simulate_fifo(d, 8, 2, arr, 200, seed=3, scheduler="packed", workers_per_job=4)
    assert packed.response_times.mean() < 0.75 * gang.response_times.mean()
    t_gang = sample_job_times(d, 8, 2, 300, seed=4, backend="python")
    # compute times per job are *worse* per job on fewer workers, but the
    # response win above comes from running 2 jobs at once; check the engine
    # agrees directionally on response via the same fifo surface
    jobs = [Job(job_id=i, dist=d, n_tasks=8) for i in range(12)]
    er_gang = ClusterEngine(8, seed=5, n_batches=2).run(jobs)
    jobs = [Job(job_id=i, dist=d, n_tasks=8) for i in range(12)]
    er_packed = ClusterEngine(8, seed=5, n_batches=2, scheduler="packed", workers_per_job=4).run(
        jobs
    )
    assert er_packed.response_times.mean() < er_gang.response_times.mean()
    assert t_gang.mean() > 0  # sanity: the static sampler still runs


def test_balanced_spreads_load_packed_hammers_low_wids():
    """With sparse 1-wide jobs (every worker idle at each arrival) and
    constant service times, packed keeps re-picking the lowest wid while
    balanced rotates the pool: the per-worker assigned load must come out
    strictly more even under balanced."""
    d = Empirical(samples=(1.0,))
    arr = [Job(job_id=i, dist=d, n_tasks=4, arrival=5.0 * i) for i in range(8)]

    def load(policy):
        eng = ClusterEngine(
            4, seed=0, n_batches=1, scheduler=policy, workers_per_job=1
        )
        eng.run([Job(job_id=j.job_id, dist=d, n_tasks=4, arrival=j.arrival) for j in arr])
        return np.array(eng._load_w)

    lp, lb = load("packed"), load("balanced")
    assert lp.sum() == pytest.approx(lb.sum())  # same total work either way
    assert lb.std() < lp.std()
    assert lb.max() < lp.max()


def test_balanced_is_speed_aware_and_backends_agree():
    """Speed-weighted balanced placement (load = duration / speed), pinned
    differentially under a 2x speed skew.

    The scenario is crafted so the legacy wall-clock metric and the
    speed-weighted one *disagree* on a placement: with speeds (2, 1) and
    sparse 1-wide jobs, the fast worker's wall-clock load catches up to the
    slow worker's after a few jobs (old metric would start alternating),
    while per-speed weighting keeps preferring the fast worker.  The engine
    must steer all but one job to the fast worker, and the f64 jax space
    lane must replay the placements exactly."""
    d = Empirical(samples=(1.0,))
    speeds = (2.0, 1.0)
    n, n_jobs = 2, 6
    arr = np.array([8.0 * i for i in range(n_jobs)])
    jobs = [Job(job_id=i, dist=d, n_tasks=n, arrival=float(arr[i])) for i in range(n_jobs)]
    eng = ClusterEngine(
        n, seed=1, n_batches=1, speeds=speeds, scheduler="balanced", workers_per_job=1
    )
    er = eng.run(jobs)
    # one job takes 2 tasks x 1.0s / speed: 1.0s on the fast worker, 2.0s on
    # the slow one.  Speed-weighted accrual (duration / speed) is 0.5 vs 2.0,
    # so after the slow worker's single job it is never preferred again:
    # 5 jobs on wid 0, 1 on wid 1.  (The legacy wall-clock metric would have
    # sent jobs 4 and 5 back to the slow worker.)
    assert eng._load_w[0] == pytest.approx(5 * (1.0 / 2.0))
    assert eng._load_w[1] == pytest.approx(2.0)
    with _x64():
        vr = simulate_epochs(
            d, n, 1, arr, 1, seed=1, speeds=speeds, scheduler="balanced",
            workers_per_job=1, dtype="float64",
        )
    _assert_exact(er, vr)


def test_balanced_speed_skew_differential_generated():
    """4x speed skew, multi-replica jobs, both backends: placement under the
    speed-weighted metric stays an exact engine replay (f64).  Speeds and
    arrivals are all distinct so no two jobs complete at the same instant
    (tied completions hit a separate, pre-existing lane-granularity limit:
    the engine releases allocations event-by-event within a timestamp while
    the lane batches them per boundary)."""
    d = Empirical(samples=(1.3,))
    speeds = (4.0, 1.0, 3.0, 1.4, 2.2, 0.8)
    n, n_jobs = 6, 10
    arr = np.array([0.0, 0.3, 0.9, 1.4, 2.2, 3.1, 4.4, 5.0, 6.3, 7.1])
    jobs = [Job(job_id=i, dist=d, n_tasks=n, arrival=float(arr[i])) for i in range(n_jobs)]
    er = ClusterEngine(
        6, seed=3, n_batches=2, speeds=speeds, scheduler="balanced", workers_per_job=2
    ).run(jobs)
    with _x64():
        vr = simulate_epochs(
            d, 6, 2, arr, 1, seed=3, speeds=speeds, scheduler="balanced",
            workers_per_job=2, dtype="float64",
        )
    _assert_exact(er, vr)


def test_rep_chunk_bit_identical_space_lane():
    """The chunk/shard reproducibility contract extends to the space lane."""
    d = Exponential(1.0)
    kw = dict(
        seed=7, scheduler="balanced", workers_per_job=3,
        job_plans=scn.seeded_job_plans(6, seed=2), churn_schedule=scn.seeded_schedule(6, seed=3),
    )
    one = simulate_epochs(d, 6, 2, np.zeros(8), 20, **kw)
    for chunk in (7, 20):
        part = simulate_epochs(d, 6, 2, np.zeros(8), 20, rep_chunk=chunk, **kw)
        assert np.array_equal(one.finishes, part.finishes)
        assert np.array_equal(one.starts, part.starts)
        assert np.array_equal(one.worker_seconds, part.worker_seconds)


# --------------------------------------------------------------------------
# planner integration + validation
# --------------------------------------------------------------------------


def test_plan_cluster_space_backends_agree():
    n = 8
    kw = dict(n_reps=96, seed=0, scheduler="packed", workers_per_job=4)
    pj = RedundancyPlanner(n).plan_cluster(Exponential(1.0), **kw)
    pp = RedundancyPlanner(n).plan_cluster(Exponential(1.0), backend="python", **kw)
    assert pj.source == "cluster_engine:jax"
    assert pp.source == "cluster_engine:python"
    # exponential tails: full diversity *within the granted subset* stays
    # optimal, and both backends agree on the pick
    assert pj.n_batches == pp.n_batches
    # a competing fixed-plan class does not break the sweep surface
    pm = RedundancyPlanner(n).plan_cluster(
        Exponential(1.0), n_reps=64, seed=1, scheduler="balanced", workers_per_job=4,
        job_plans=[None, JobPlan(workers=4, n_batches=4)],
    )
    assert pm.source == "cluster_engine:jax"
    assert np.isfinite(pm.frontier_mean).any()


def test_scheduler_validation_and_construction():
    d = Exponential(1.0)
    with pytest.raises(ValueError, match="scheduler"):
        ClusterEngine(4, scheduler="round_robin")
    with pytest.raises(ValueError, match="scheduler"):
        simulate_epochs(d, 4, 2, np.zeros(2), 2, scheduler="round_robin")
    with pytest.raises(ValueError, match="workers_per_job"):
        ClusterEngine(4, workers_per_job=9)
    with pytest.raises(ValueError, match="workers_per_job"):
        simulate_epochs(d, 4, 2, np.zeros(2), 2, scheduler="packed", workers_per_job=0)
    with pytest.raises(ValueError, match="replan"):
        from repro.cluster import ReplanConfig

        simulate_epochs(
            d, 8, 2, np.zeros(2), 2, scheduler="packed", replan=ReplanConfig(window=16)
        )
    # the python backend rejects the same combinations the jax lane does
    with pytest.raises(ValueError, match="replan"):
        from repro.cluster import ReplanConfig

        sample_job_times(
            d, 8, 2, 4, backend="python", scheduler="packed",
            replan=ReplanConfig(window=16),
        )
    with pytest.raises(ValueError, match="replan/controller"):
        from repro.cluster import OnlineReplanner

        ClusterEngine(8, scheduler="packed", controller=OnlineReplanner(8))
    with pytest.raises(ValueError, match="dtype"):
        simulate_fifo(d, 4, 2, np.zeros(2), 2, dtype="float64")
    with pytest.raises(ValueError, match="JobPlan.workers"):
        JobPlan(workers=0)
    with pytest.raises(ValueError, match="JobPlan.n_batches"):
        JobPlan(n_batches=0)
    with pytest.raises(ValueError, match="job_plans"):
        simulate_epochs(d, 4, 2, np.zeros(2), 2, job_plans=[])
    with pytest.raises(ValueError, match="job_plans"):
        simulate_epochs(d, 4, 2, np.zeros(2), 2, job_plans=["not a plan"])
    # instances pass through make_scheduler untouched
    inst = PackedScheduler()
    assert make_scheduler(inst) is inst
    assert make_scheduler("balanced").__class__ is BalancedScheduler
    assert ClusterEngine(4, scheduler=BalancedScheduler()).scheduler.name == "balanced"
