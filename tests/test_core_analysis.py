"""Closed forms vs Monte-Carlo + paper theorem checks (§IV-§VI)."""
import math

import jax
import numpy as np
import pytest

from repro.core import analysis, simulator
from repro.core.service_time import Exponential, Pareto, ShiftedExponential

N = 24  # worker budget for MC checks (divisor-rich)
MC = 200_000


def _mc_stats(dist, n, b, seed=0):
    t = simulator.simulate_balanced(jax.random.key(seed), dist, n, b, MC)
    return simulator.stats_from_samples(t)


# --------------------------------------------------------------------------
# exponential (§VI-A)
# --------------------------------------------------------------------------


@pytest.mark.parametrize("b", [1, 2, 4, 8, 24])
def test_exp_mean_matches_mc(b):
    mu = 1.7
    got = _mc_stats(Exponential(mu=mu), N, b)
    want = analysis.exp_mean_T(N, b, mu)
    assert got.mean == pytest.approx(want, rel=0.02)


@pytest.mark.parametrize("b", [1, 2, 4, 8, 24])
def test_exp_cov_matches_mc(b):
    got = _mc_stats(Exponential(mu=0.9), N, b)
    assert got.cov == pytest.approx(analysis.exp_cov_T(b), rel=0.03)


def test_thm3_full_diversity_minimizes_mean():
    # Thm 3: E[T] = H_B / mu is increasing in B => B* = 1.
    mus = [analysis.exp_mean_T(N, b, 1.0) for b in analysis.feasible_B(N)]
    assert mus == sorted(mus)
    assert analysis.argmin_B(Exponential(mu=1.0), N, "mean") == 1


def test_thm4_full_parallelism_minimizes_cov():
    covs = [analysis.exp_cov_T(b) for b in analysis.feasible_B(N)]
    assert covs == sorted(covs, reverse=True)
    assert analysis.argmin_B(Exponential(mu=1.0), N, "cov") == N


# --------------------------------------------------------------------------
# shifted exponential (§VI-B)
# --------------------------------------------------------------------------


@pytest.mark.parametrize("b", [1, 3, 6, 12, 24])
def test_sexp_mean_matches_mc(b):
    d = ShiftedExponential(delta=0.05, mu=4.0)
    got = _mc_stats(d, N, b)
    assert got.mean == pytest.approx(analysis.sexp_mean_T(N, b, d.delta, d.mu), rel=0.02)


@pytest.mark.parametrize("b", [1, 3, 6, 12, 24])
def test_sexp_cov_matches_mc(b):
    d = ShiftedExponential(delta=0.05, mu=4.0)
    got = _mc_stats(d, N, b)
    assert got.cov == pytest.approx(analysis.sexp_cov_T(N, b, d.delta, d.mu), rel=0.05)


def test_thm6_regimes():
    n = 100
    # paper's worked example: N=100, delta=0.05 => mu < 0.2 diversity,
    # 0.2 <= mu <= 13.8 middle, mu > 13.8 parallelism.
    assert analysis.sexp_mean_regime(n, 0.05, 0.1) == "full_diversity"
    assert analysis.sexp_mean_regime(n, 0.05, 5.0) == "middle"
    assert analysis.sexp_mean_regime(n, 0.05, 20.0) == "full_parallelism"
    # boundaries agree with the closed-form argmin over feasible B
    for mu, expect in [(0.1, 1), (20.0, n)]:
        assert analysis.argmin_B(ShiftedExponential(0.05, mu), n, "mean") == expect


def test_cor2_middle_optimum_near_N_delta_mu():
    n, delta, mu = 100, 0.05, 5.0
    b_star = analysis.argmin_B(ShiftedExponential(delta, mu), n, "mean")
    approx = analysis.sexp_B_star_approx(n, delta, mu)  # = 25
    # discrete optimum should be the feasible point nearest the continuous one
    feas = analysis.feasible_B(n)
    nearest = min(feas, key=lambda b: abs(b - approx))
    assert b_star == nearest


def test_thm7_cov_regimes_end_points():
    n = 100
    # Thm 7 / Cor 3 direction (confirmed against exact Lemma-5 evaluation;
    # the paper's Fig-8 *commentary* swaps the labels -- see analysis.py note):
    # small delta*mu -> full parallelism; large -> full diversity.
    assert analysis.sexp_cov_regime(n, 0.05, 0.2) == "full_parallelism"
    assert analysis.sexp_cov_regime(n, 0.05, 20.0) == "full_diversity"
    assert analysis.argmin_B(ShiftedExponential(0.05, 0.2), n, "cov") == n
    assert analysis.argmin_B(ShiftedExponential(0.05, 20.0), n, "cov") == 1
    # regime label agrees with exact argmin across a sweep
    for mu in (0.1, 0.3, 1.0, 3.0, 10.0, 40.0):
        reg = analysis.sexp_cov_regime(n, 0.05, mu)
        b = analysis.argmin_B(ShiftedExponential(0.05, mu), n, "cov")
        if reg == "full_parallelism":
            assert b == n
        elif reg == "full_diversity":
            assert b == 1


# --------------------------------------------------------------------------
# pareto (§VI-C)
# --------------------------------------------------------------------------


@pytest.mark.parametrize("b", [1, 2, 4, 8])
def test_pareto_mean_matches_mc(b):
    d = Pareto(sigma=1.0, alpha=3.0)
    got = _mc_stats(d, N, b, seed=3)
    want = analysis.pareto_mean_T(N, b, d.sigma, d.alpha)
    assert got.mean == pytest.approx(want, rel=0.05)


@pytest.mark.parametrize("b", [1, 2, 4])
def test_pareto_cov_matches_mc(b):
    d = Pareto(sigma=1.0, alpha=4.0)
    got = _mc_stats(d, N, b, seed=4)
    assert got.cov == pytest.approx(analysis.pareto_cov_T(N, b, d.alpha), rel=0.12)


def test_thm9_alpha_star_matches_paper_example():
    # paper: N=100, sigma=1 => alpha* ~= 4.7
    a_star = analysis.pareto_alpha_star(100)
    assert 3.5 < a_star < 6.0
    # behavioural check: alpha above alpha* -> full parallelism optimal;
    # alpha below -> middle point.
    n = 100
    assert analysis.argmin_B(Pareto(1.0, max(a_star + 1.0, 6.0)), n, "mean") == n
    b_mid = analysis.argmin_B(Pareto(1.0, 1.5), n, "mean")
    assert 1 < b_mid < n


def test_thm10_cov_minimized_at_full_diversity():
    n = 100
    for alpha in (2.5, 3.0, 5.0, 10.0):
        covs = [analysis.pareto_cov_T(n, b, alpha) for b in analysis.feasible_B(n)]
        finite = [c for c in covs if math.isfinite(c)]
        assert finite == sorted(finite)  # increasing in B
        assert analysis.argmin_B(Pareto(1.0, alpha), n, "cov") == 1


def test_pareto_scale_free_cov():
    assert analysis.pareto_cov_T(N, 4, 3.0) == analysis.pareto_cov_T(N, 4, 3.0)
    # sigma does not appear in the CoV signature at all (Lemma 6)


# --------------------------------------------------------------------------
# §IV batch-level model: unbalanced-assignment exact mean
# --------------------------------------------------------------------------


def test_batch_model_exact_vs_mc():
    counts = np.array([3, 2, 1])
    mu = 1.3
    want = analysis.batch_model_exp_mean_T(counts, mu)
    t = simulator.simulate_counts(jax.random.key(7), Exponential(mu=mu), counts, MC)
    assert float(np.mean(t)) == pytest.approx(want, rel=0.02)
