"""Unit tests for the nightly-bench trend table (the dashboard renderer).

The nightly workflow downloads the retained ``cluster-bench-full-*``
artifact series into ``bench-history/run-<id>/`` directories and pipes
``benchmarks/nightly_trend.py bench-history fresh.json`` into the job
summary.  The committed fixture series under
``benchmarks/artifacts/nightly_fixture/`` replays that layout -- flat
``run-<id>.json`` files *and* a ``gh run download``-style nested artifact
directory whose file stems are all identical -- so multi-file mode (row
labelling, natural chronological sort, missing-section tolerance) is pinned
here instead of only being exercised by the live workflow.
"""
import importlib.util
import json
import pathlib
import subprocess
import sys

REPO = pathlib.Path(__file__).resolve().parents[1]
SCRIPT = REPO / "benchmarks" / "nightly_trend.py"
FIXTURE = REPO / "benchmarks" / "artifacts" / "nightly_fixture"


def _mod():
    spec = importlib.util.spec_from_file_location("nightly_trend", SCRIPT)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_multi_file_mode_renders_one_row_per_run_in_order():
    out = subprocess.run(
        [sys.executable, str(SCRIPT), str(FIXTURE)],
        capture_output=True,
        text=True,
        check=True,
    ).stdout
    lines = [ln for ln in out.splitlines() if ln.startswith("|")]
    # header + separator + one row per fixture run
    assert len(lines) == 2 + 4, out
    body = lines[2:]
    # natural (chronological) order: 101 < 102 < 110 < 120, and the nested
    # gh-run-download layout is labelled by its run directory
    assert body[0].startswith("| run-101 ")
    assert body[1].startswith("| run-102 ")
    assert body[2].startswith("| run-110 ")
    assert body[3].startswith("| run-120 ")
    # the load-bearing series render with their units
    assert "91x" in body[0] and "0.41x" in body[0] and "12.81x" in body[0]
    assert "37x" in body[1] and "0.39x" in body[1]
    # run-110 predates the space_sharing section: dashes, not a crash
    assert " -..- " in body[2] and "12.50x" in body[2]
    # the speculation column: values where the section exists, dashes before
    assert "1.31x/1.88x" in body[1]
    assert "| -/- |" in body[2]
    assert "1.42x/1.95x" in body[3]
    # the trace-scale columns: only run-120 carries the section
    assert "| 2.31 | 273 |" in body[3]
    assert "| - | - |" in body[0] and "| - | - |" in body[2]
    # the SLO columns: only run-120 carries the section; older rows end in
    # dashes, not a crash
    assert body[3].rstrip().endswith("| 87% | 5821 |")
    assert body[2].rstrip().endswith("| -/- | - | - |")


def test_mixed_dir_and_file_args(tmp_path):
    # the exact filename the nightly workflow passes for tonight's run: no
    # run id in it (the artifact name gains one only on upload), so the row
    # must land at the BOTTOM of the table -- newest last, chronological
    fresh = tmp_path / "cluster-bench-full.json"
    fresh.write_text(
        json.dumps(
            {
                "backend": {"min_speedup_warm": 100.0, "max_speedup_warm": 200.0},
                "dynamic": {
                    "min_speedup_warm": 50.0,
                    "max_speedup_warm": 60.0,
                    "max_cold_seconds": 2.0,
                    "peak_rss_mb": 400.0,
                },
                "space_sharing": {
                    "min_speedup_warm": 40.0,
                    "max_speedup_warm": 45.0,
                    "response_ratio_packed_vs_gang": 0.35,
                },
                "redundancy": {"_summary": {"max_heavy_speedup": 13.0}},
            }
        )
    )
    out = subprocess.run(
        [sys.executable, str(SCRIPT), str(FIXTURE), str(fresh)],
        capture_output=True,
        text=True,
        check=True,
    ).stdout
    body = [ln for ln in out.splitlines() if ln.startswith("|")][2:]  # drop header rows
    assert len(body) == 5
    assert body[-1].startswith("| cluster-bench-full ")
    assert "0.35x" in body[-1]


def test_empty_history_is_an_error_not_a_crash(tmp_path):
    r = subprocess.run(
        [sys.executable, str(SCRIPT), str(tmp_path)], capture_output=True, text=True
    )
    assert r.returncode == 1
    assert "no bench JSONs" in r.stderr


def test_svg_flag_writes_sparklines(tmp_path):
    svg_path = tmp_path / "plots" / "trend.svg"
    r = subprocess.run(
        [sys.executable, str(SCRIPT), str(FIXTURE), "--svg", str(svg_path)],
        capture_output=True,
        text=True,
        check=True,
    )
    assert f"wrote {svg_path}" in r.stderr
    svg = svg_path.read_text()
    assert svg.startswith("<svg ") and svg.endswith("</svg>")
    # one labelled sparkline per load-bearing series, speculation included
    for label in (
        "static edge (min)",
        "dynamic edge (min)",
        "space edge (min)",
        "packed/gang response",
        "dynamic cold (s)",
        "trace sweep warm (s)",
        "trace peak RSS (MB)",
        "heavy-tail speedup",
        "spec pareto (react)",
        "spec pareto (hybrid)",
    ):
        assert label in svg
    # the single-run trace series still renders its dot + latest value
    assert "2.31" in svg and "273" in svg
    # series present in every fixture run draw a 4-point polyline; the
    # 2-point speculation series still draws a line and its latest value
    assert svg.count("<polyline") >= 7
    assert "1.42" in svg and "1.95" in svg


def test_sparkline_svg_handles_missing_and_single_point_series():
    nt = _mod()
    rows = [
        ("run-1", {"backend": {"min_speedup_warm": 90.0}}),
        ("run-2", {"speculation": {"pareto_speculative_speedup": 1.4}}),
    ]
    svg = nt.sparkline_svg(rows)
    # the single-point series renders a dot (no polyline), never crashes
    assert "<circle" in svg
    assert "spec pareto (react)" in svg and "1.40" in svg


def test_label_and_natkey_helpers():
    nt = _mod()
    assert nt._natkey("run-9") < nt._natkey("run-10") < nt._natkey("run-101")
    root = FIXTURE
    nested = next((FIXTURE / "run-102").rglob("*.json"))
    assert nt._label(root, nested) == "run-102"
    assert nt._label(root, FIXTURE / "run-101.json") == "run-101"
    # a digit-free stem falls back to the stem itself
    assert nt._label(pathlib.Path("x.json"), pathlib.Path("x.json")) == "x"
