"""Vectorized jax backend: equivalence with the event engine and oracle.

The backend must replay the Python engine's operational semantics exactly in
distribution -- single-job gang dispatch + earliest cover, FIFO multi-job
queueing, cancellation accounting -- so every test here is either a 3-sigma
statistical equivalence against the engine / ``simulate_balanced`` or an
exact structural invariant (determinism, worker-seconds identities,
plan_sweep == per-candidate plan_cluster).
"""
import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ModuleNotFoundError:  # test extra not installed: seeded fallback engine
    from _hypothesis_compat import given, settings, st

import strategies as scn
from repro.cluster import ClusterEngine, Job, sample_job_times, simulate_fifo
from repro.cluster.vectorized import frontier_job_times
from repro.core import analysis, simulator
from repro.core.planner import RedundancyPlanner, plan_sweep
from repro.core.service_time import Exponential, Pareto


def _z_mean(a: np.ndarray, b: np.ndarray) -> float:
    se = np.sqrt(a.var() / a.size + b.var() / b.size)
    return float(abs(a.mean() - b.mean()) / se)


# --------------------------------------------------------------------------
# single-job frontier: 3-sigma vs the Python engine and simulate_balanced
# --------------------------------------------------------------------------


@pytest.mark.parametrize(
    "dist",
    [Exponential(mu=1.0), Pareto(sigma=1.0, alpha=2.2)],
    ids=["exponential", "pareto"],
)
def test_frontier_matches_engine_and_oracle(dist):
    n = 8
    cands = analysis.feasible_B(n)
    rows = frontier_job_times(dist, n, cands, 60_000, seed=0)
    assert rows.shape == (len(cands), 60_000)
    for i, b in enumerate(cands):
        t_engine = sample_job_times(dist, n, b, 3000, seed=10 + i, backend="python")
        t_oracle = np.asarray(simulator.simulate_balanced(jax.random.key(i), dist, n, b, 60_000))
        assert _z_mean(rows[i], t_engine) < 3.0, (b, rows[i].mean(), t_engine.mean())
        assert _z_mean(rows[i], t_oracle) < 3.0, (b, rows[i].mean(), t_oracle.mean())


def test_frontier_deterministic_and_seed_sensitive():
    d = Pareto(1.0, 2.0)
    a = frontier_job_times(d, 6, [1, 2, 3], 200, seed=3)
    b = frontier_job_times(d, 6, [1, 2, 3], 200, seed=3)
    c = frontier_job_times(d, 6, [1, 2, 3], 200, seed=4)
    assert np.array_equal(a, b)
    assert not np.array_equal(a, c)


def test_frontier_batch_model_matches_oracle():
    """§IV batch-level model (size_dependent=False) also lines up."""
    d = Exponential(1.0)
    rows = frontier_job_times(d, 6, [3], 60_000, seed=1, size_dependent=False)
    ref = np.asarray(
        simulator.simulate_balanced(jax.random.key(9), d, 6, 3, 60_000, size_dependent=False)
    )
    assert _z_mean(rows[0], ref) < 3.0


def test_frontier_rejects_bad_candidates():
    with pytest.raises(ValueError):
        frontier_job_times(Exponential(1.0), 4, [0, 2], 10)
    with pytest.raises(ValueError):
        frontier_job_times(Exponential(1.0), 4, [8], 10)
    with pytest.raises(ValueError):
        frontier_job_times(Exponential(1.0), 4, [], 10)


def test_sample_job_times_jax_backend_dispatch():
    t = sample_job_times(Exponential(1.0), 8, 4, 500, seed=2, backend="jax")
    assert t.shape == (500,)
    t_py = sample_job_times(Exponential(1.0), 8, 4, 3000, seed=2, backend="python")
    assert _z_mean(t, t_py) < 3.0
    with pytest.raises(ValueError, match="backend"):
        sample_job_times(Exponential(1.0), 8, 4, 10, backend="numpy")


# --------------------------------------------------------------------------
# FIFO queueing scan: exact invariants + 3-sigma vs the event engine
# --------------------------------------------------------------------------


def test_fifo_cancellation_invariants():
    arrivals = np.zeros(12)
    on = simulate_fifo(Pareto(1.0, 2.0), 8, 2, arrivals, 800, seed=5, cancel_redundant=True)
    off = simulate_fifo(Pareto(1.0, 2.0), 8, 2, arrivals, 800, seed=5, cancel_redundant=False)
    # same seed => same draws => identical per-job compute times ...
    assert np.allclose(on.compute_times, off.compute_times)
    # ... while cancellation reclaims exactly the redundant replicas' tails
    assert np.allclose(
        on.worker_seconds + on.cancelled_seconds_saved, off.worker_seconds, rtol=1e-5
    )
    assert (on.cancelled_seconds_saved > 0).all()
    assert (off.cancelled_seconds_saved == 0).all()
    # stragglers of job k delay job k+1's gang dispatch unless cancelled
    assert (on.response_times <= off.response_times + 1e-5).all()
    assert on.response_times.mean() < off.response_times.mean()


@pytest.mark.parametrize("cancel", [False, True], ids=["cancel_off", "cancel_on"])
def test_fifo_matches_engine_response_times(cancel):
    dist = Pareto(1.0, 2.5)
    n, b, n_jobs = 8, 2, 12
    arrivals = np.arange(n_jobs) * 2.0
    engine_means = []
    for s in range(40):
        jobs = [
            Job(job_id=i, dist=dist, n_tasks=n, arrival=float(a)) for i, a in enumerate(arrivals)
        ]
        rep = ClusterEngine(n, seed=100 + s, n_batches=b, cancel_redundant=cancel).run(jobs)
        engine_means.append(rep.response_times.mean())
    engine_means = np.array(engine_means)
    vec = simulate_fifo(dist, n, b, arrivals, 3000, seed=7, cancel_redundant=cancel)
    vec_means = vec.response_times.mean(axis=1)
    assert _z_mean(engine_means, vec_means) < 3.0, (engine_means.mean(), vec_means.mean())


def test_fifo_no_queueing_reduces_to_frontier():
    """Arrivals far apart: every job starts on arrival, response == compute."""
    d = Exponential(1.0)
    arrivals = np.arange(6) * 1e4
    rep = simulate_fifo(d, 8, 4, arrivals, 2000, seed=11)
    assert np.allclose(rep.queue_waits, 0.0)
    assert np.allclose(rep.response_times, rep.compute_times)
    rows = frontier_job_times(d, 8, [4], 12_000, seed=12)
    assert _z_mean(rep.compute_times.ravel(), rows[0]) < 3.0


def test_fifo_waits_invariant_to_arrival_offset():
    """Regression: large absolute timestamps must not quantize queue waits --
    the scan carries slack (backlog-sized), never absolute float32 time."""
    d = Pareto(1.0, 2.0)
    arr = np.arange(10) * 1.5
    a = simulate_fifo(d, 8, 2, arr, 300, seed=9)
    b = simulate_fifo(d, 8, 2, arr + 1e7, 300, seed=9)
    assert np.array_equal(a.queue_waits, b.queue_waits)
    assert np.array_equal(a.compute_times, b.compute_times)
    assert np.allclose(b.starts - 1e7, a.starts)


def test_fifo_rejects_unsorted_arrivals():
    with pytest.raises(ValueError, match="sorted"):
        simulate_fifo(Exponential(1.0), 4, 2, [3.0, 1.0], 10)


# --------------------------------------------------------------------------
# planner integration: jax-scored plans and grid sweeps
# --------------------------------------------------------------------------


def test_plan_cluster_jax_agrees_with_closed_form():
    planner = RedundancyPlanner(8)
    plan = planner.plan_cluster(Exponential(1.0), n_reps=2000, seed=0, backend="jax")
    assert plan.source == "cluster_engine:jax"
    assert plan.n_batches == analysis.argmin_B(Exponential(1.0), 8, metric="mean")
    for b, m in zip(plan.frontier_B, plan.frontier_mean):
        assert abs(m - analysis.mean_T(Exponential(1.0), 8, b)) < 0.2, (b, m)


@settings(max_examples=6, deadline=None)
@given(
    n=scn.worker_counts(4, 10),
    objective=scn.objectives(),
    seed=st.integers(0, 50),
)
def test_plan_sweep_matches_per_candidate_plan_cluster(n, objective, seed):
    """Each sweep grid point must replay an identically-seeded plan_cluster."""
    dists = [Exponential(1.0), Pareto(1.0, 2.2)]
    budgets = [n, 2 * n]
    plans = plan_sweep(dists, budgets, objective, n_reps=80, seed=seed)
    for i, dist in enumerate(dists):
        for j, budget in enumerate(budgets):
            solo = RedundancyPlanner(budget).plan_cluster(
                dist,
                objective,
                n_reps=80,
                seed=seed + i * len(budgets) + j,
                backend="jax",
            )
            assert plans[i][j].n_batches == solo.n_batches
            assert plans[i][j].frontier_mean == solo.frontier_mean
            assert plans[i][j].frontier_cov == solo.frontier_cov
            assert plans[i][j].n_workers == budget


def test_plan_sweep_python_backend_and_shapes():
    plans = plan_sweep([Exponential(1.0)], [4, 8], n_reps=60, seed=1, backend="python")
    assert len(plans) == 1 and len(plans[0]) == 2
    assert all(p.source == "cluster_engine:python" for p in plans[0])


def test_static_frontier_rep_chunk_bit_identical():
    """rep_chunk=N in one chunk vs k chunks on the static frontier kernel:
    per-rep fold_in derivation makes the rows bit-identical."""
    from repro.core.service_time import Pareto

    d = Pareto(1.0, 2.0)
    full = frontier_job_times(d, 8, [1, 2, 4, 8], 50, seed=5, rep_chunk=50)
    for chunk in (7, 16):
        part = frontier_job_times(d, 8, [1, 2, 4, 8], 50, seed=5, rep_chunk=chunk)
        assert np.array_equal(full, part)
    # and the chunked stream stays statistically equivalent to the default
    a = frontier_job_times(d, 8, [2], 4000, seed=5, rep_chunk=1000)[0]
    b = frontier_job_times(d, 8, [2], 4000, seed=6)[0]
    se = np.sqrt(a.var() / a.size + b.var() / b.size)
    assert abs(a.mean() - b.mean()) / se < 3.0
