"""Loop-aware HLO analysis: verified against known programs."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch import hlo_stats


def _compile_text(fn, *specs):
    return jax.jit(fn).lower(*specs).compile().as_text()


def test_scan_flops_multiplied_by_trip_count():
    d, L = 64, 10

    def scanned(x, w):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=L)
        return y

    spec = jax.ShapeDtypeStruct((d, d), jnp.float32)
    hlo = _compile_text(scanned, spec, spec)
    st = hlo_stats.analyze(hlo)
    want = 2 * d * d * d * L
    assert st.flops == pytest.approx(want, rel=0.01), (st.flops, want)


def test_unrolled_matches_scan_totals():
    d, L = 32, 6

    def scanned(x, w):
        def body(c, _):
            return c @ w, None
        return jax.lax.scan(body, x, None, length=L)[0]

    def unrolled(x, w):
        for _ in range(L):
            x = x @ w
        return x

    spec = jax.ShapeDtypeStruct((d, d), jnp.float32)
    fs = hlo_stats.analyze(_compile_text(scanned, spec, spec)).flops
    fu = hlo_stats.analyze(_compile_text(unrolled, spec, spec)).flops
    assert fs == pytest.approx(fu, rel=0.01)
    assert fs == pytest.approx(2 * d**3 * L, rel=0.01)


def test_nested_scan():
    d, L1, L2 = 16, 3, 5

    def nested(x, w):
        def inner(c, _):
            return c @ w, None

        def outer(c, _):
            c, _ = jax.lax.scan(inner, c, None, length=L2)
            return c, None

        return jax.lax.scan(outer, x, None, length=L1)[0]

    spec = jax.ShapeDtypeStruct((d, d), jnp.float32)
    st = hlo_stats.analyze(_compile_text(nested, spec, spec))
    assert st.flops == pytest.approx(2 * d**3 * L1 * L2, rel=0.01)


def test_batched_dot_flops():
    b, m, k, n = 4, 8, 16, 32

    def f(x, w):
        return jnp.einsum("bmk,bkn->bmn", x, w)

    hlo = _compile_text(
        f,
        jax.ShapeDtypeStruct((b, m, k), jnp.float32),
        jax.ShapeDtypeStruct((b, k, n), jnp.float32),
    )
    st = hlo_stats.analyze(hlo)
    assert st.flops == pytest.approx(2 * b * m * k * n, rel=0.01)


@pytest.mark.skipif(len(jax.devices()) != 1, reason="needs the plain CPU runtime")
def test_collectives_counted_in_scan_subprocess():
    """psum inside a scanned layer must count L times (runs on 8 fake devices)."""
    import subprocess
    import sys

    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.launch import hlo_stats

mesh = jax.make_mesh((8,), ("data",))
L, d = 7, 32

def step(x, ws):
    # FSDP-over-scan shape: per-layer stacked weights, sliced in the body ->
    # the all-gather of each layer's shard happens inside the loop
    def body(c, w):
        return c @ w, None
    y, _ = jax.lax.scan(body, x, ws)
    return jnp.sum(y)

xsh = NamedSharding(mesh, P("data", None))
wsh = NamedSharding(mesh, P(None, "data", None))
fn = jax.jit(step, in_shardings=(xsh, wsh))
hlo = fn.lower(
    jax.ShapeDtypeStruct((64, d), jnp.float32),
    jax.ShapeDtypeStruct((L, d, d), jnp.float32),
).compile().as_text()
st = hlo_stats.analyze(hlo)
n_coll = sum(s["count"] for s in st.collectives.values())
# the in-loop all-gather must be weighted by the trip count L
assert n_coll >= L, (n_coll, st.collectives)
print("OK", n_coll)
"""
    r = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True, text=True,
        env={**__import__("os").environ, "PYTHONPATH": "src"},
        cwd="/root/repo",
    )
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout


def test_parse_iota_replica_groups():
    ids = hlo_stats._first_group_ids("all-reduce(...), replica_groups=[2,4]<=[8]")
    assert ids == [0, 1, 2, 3]
    ids = hlo_stats._first_group_ids(
        "all-reduce(...), replica_groups=[4,2]<=[2,4]T(1,0)"
    )
    assert ids == [0, 4]
    ids = hlo_stats._first_group_ids("all-reduce(...), replica_groups={{0,256},{1,257}}")
    assert ids == [0, 256]


def test_hbm_bytes_nonzero_and_loop_weighted():
    d, L = 32, 4

    def scanned(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        return jax.lax.scan(body, x, None, length=L)[0]

    spec = jax.ShapeDtypeStruct((d, d), jnp.float32)
    st1 = hlo_stats.analyze(_compile_text(scanned, spec, spec))

    def scanned2(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        return jax.lax.scan(body, x, None, length=2 * L)[0]

    st2 = hlo_stats.analyze(_compile_text(scanned2, spec, spec))
    assert st1.hbm_bytes > 0
    assert st2.hbm_bytes > 1.5 * st1.hbm_bytes  # scales with trip count
