"""Batching schemes, assignment majorization, coverage (§III-§V) + properties."""
import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ModuleNotFoundError:  # test extra not installed: seeded fallback engine
    from _hypothesis_compat import given, settings, st

from repro.core import analysis, assignment, batching, coupon, simulator
from repro.core.service_time import Exponential, ShiftedExponential

# --------------------------------------------------------------------------
# batching construction invariants
# --------------------------------------------------------------------------


@pytest.mark.parametrize("n,b", [(6, 3), (12, 4), (24, 6), (8, 8), (8, 1)])
def test_non_overlapping_valid_and_balanced(n, b):
    m = batching.non_overlapping(n, b)
    diag = batching.validate_scheme(m)
    assert diag["balanced"] and diag["batch_size"] == n // b
    assert diag["min_replication"] == n // b  # each task hosted by r workers


@pytest.mark.parametrize("n,b", [(6, 3), (12, 4), (24, 6)])
def test_cyclic_valid_and_fair(n, b):
    m = batching.cyclic(n, b)
    diag = batching.validate_scheme(m)
    assert diag["balanced"]  # every task in exactly batch_size windows
    assert diag["min_replication"] == n // b


@pytest.mark.parametrize("n,b", [(6, 3), (12, 4), (24, 6)])
def test_hybrid_valid_and_fair(n, b):
    m = batching.hybrid(n, b)
    diag = batching.validate_scheme(m)
    assert diag["min_replication"] >= 1
    assert m.shape == (n, n)


def test_cyclic_overlap_counts_match_paper():
    # §V: cyclic -> each batch shares tasks with 2(N/B - 1) others;
    # non-overlapping -> N/B - 1 others.
    n, b = 12, 4
    size = n // b
    mc = batching.cyclic(n, b)
    overlaps = ((mc @ mc.T) > 0) & ~np.eye(n, dtype=bool)
    assert (overlaps.sum(axis=1) == 2 * (size - 1)).all()
    mn = batching.non_overlapping(n, b)
    overlapsn = ((mn @ mn.T) > 0) & ~np.eye(n, dtype=bool)
    assert (overlapsn.sum(axis=1) == size - 1).all()


# --------------------------------------------------------------------------
# §V scheme ordering: E[T3] < E[T2] < E[T1]  (Fig. 6)
# --------------------------------------------------------------------------


def _scheme_mean(m, dist, seed, n_samples=150_000):
    t = simulator.simulate_membership(jax.random.key(seed), dist, m, n_samples)
    return float(np.mean(t))


@pytest.mark.parametrize("dist", [Exponential(mu=1.0), ShiftedExponential(0.2, 2.0)])
def test_scheme_ordering_n6_b3(dist):
    n, b = 6, 3
    e1 = _scheme_mean(batching.cyclic(n, b), dist, 1)
    e2 = _scheme_mean(batching.hybrid(n, b), dist, 2)
    e3 = _scheme_mean(batching.non_overlapping(n, b), dist, 3)
    assert e3 < e2 < e1


def test_scheme_ordering_larger_n():
    n, b = 12, 4
    dist = Exponential(mu=1.0)
    e1 = _scheme_mean(batching.cyclic(n, b), dist, 4)
    e3 = _scheme_mean(batching.non_overlapping(n, b), dist, 5)
    assert e3 < e1


# --------------------------------------------------------------------------
# majorization (Lemmas 2-3)
# --------------------------------------------------------------------------


def test_balanced_majorized_by_all():
    n, b = 12, 3
    bal = assignment.balanced_counts(n, b)
    rng = np.random.default_rng(0)
    for _ in range(200):
        c = assignment.random_counts(n, b, rng)
        if (c == 0).any():
            continue
        assert assignment.majorizes(c, bal)


def test_lemma2_majorization_implies_slower():
    # exact means via inclusion-exclusion (batch-level Exp model)
    mu = 1.0
    v1, v2 = np.array([4, 1, 1]), np.array([3, 2, 1])
    v3 = np.array([2, 2, 2])
    assert assignment.majorizes(v1, v2) and assignment.majorizes(v2, v3)
    e1 = analysis.batch_model_exp_mean_T(v1, mu)
    e2 = analysis.batch_model_exp_mean_T(v2, mu)
    e3 = analysis.batch_model_exp_mean_T(v3, mu)
    assert e1 > e2 > e3


@settings(max_examples=60, deadline=None)
@given(st.lists(st.integers(1, 6), min_size=2, max_size=5))
def test_property_balanced_is_minimal(counts):
    """Property: any integer composition with the same (sum, length) that is
    balanced-or-flatter gives smaller exact E[T] under Exp batch times."""
    counts = np.array(counts)
    n, b = int(counts.sum()), len(counts)
    if n % b:
        n = b * (n // b + 1)
        counts[0] += n - counts.sum()
    bal = assignment.balanced_counts(n, b)
    e_any = analysis.batch_model_exp_mean_T(counts, 1.0)
    e_bal = analysis.batch_model_exp_mean_T(bal, 1.0)
    assert e_bal <= e_any + 1e-12


@settings(max_examples=40, deadline=None)
@given(
    st.integers(2, 5).flatmap(
        lambda b: st.tuples(st.just(b), st.integers(1, 4), st.permutations(range(b)))
    )
)
def test_property_majorization_transfer(args):
    """Robin-Hood transfer (take 1 from a larger coord, give to a smaller one)
    never increases exact E[T] -- the Schur-convexity of Lemma 2."""
    b, r, perm = args
    base = np.full(b, r + 1)
    base[list(perm)[0]] += 2  # unbalance one coordinate
    donor = int(np.argmax(base))
    recv = int(np.argmin(base))
    if donor == recv:
        return
    transferred = base.copy()
    transferred[donor] -= 1
    transferred[recv] += 1
    if not assignment.majorizes(base, transferred):
        return
    assert analysis.batch_model_exp_mean_T(base, 1.0) >= analysis.batch_model_exp_mean_T(
        transferred, 1.0
    ) - 1e-12


# --------------------------------------------------------------------------
# coupon coverage (Lemma 1)
# --------------------------------------------------------------------------


@pytest.mark.parametrize("n,b", [(10, 3), (20, 5), (50, 10), (100, 10)])
def test_coverage_exact_vs_mc(n, b):
    want = coupon.coverage_probability(n, b)
    got = coupon.coverage_probability_mc(n, b, n_samples=60_000, seed=1)
    assert got == pytest.approx(want, abs=0.01)


def test_coverage_paper_fig3_shape():
    # Fig 3: with N=100, B=10 is covered w.h.p. but large B is not.
    assert coupon.coverage_probability(100, 10) > 0.99
    assert coupon.coverage_probability(100, 50) < 0.5
    # monotone decreasing in B
    ps = [coupon.coverage_probability(100, b) for b in (2, 5, 10, 20, 25, 50, 100)]
    assert all(a >= b for a, b in zip(ps, ps[1:]))


def test_coverage_edge_cases():
    assert coupon.coverage_probability(5, 1) == 1.0
    assert coupon.coverage_probability(3, 5) == 0.0
    n99 = coupon.min_workers_for_coverage(10, 0.99)
    assert coupon.coverage_probability(n99, 10) >= 0.99
    assert coupon.coverage_probability(n99 - 1, 10) < 0.99


def test_random_assignment_risk_vs_balanced():
    """End-to-end: random placement leaves batches uncovered => infinite job
    time with positive probability; balanced never does (Fig 3's lesson)."""
    rng = np.random.default_rng(3)
    n, b = 12, 6
    m_rand = batching.random_nonoverlapping(n, b, rng)
    with pytest.raises(ValueError):
        # not guaranteed to raise for every seed; seed 3 leaves a gap
        for _ in range(50):
            m_rand = batching.random_nonoverlapping(n, b, rng)
            batching.validate_scheme(m_rand)
    m_bal = batching.non_overlapping(n, b)
    batching.validate_scheme(m_bal)  # never raises
