"""Live runtime e2e: real localhost master-worker runs whose traces replay
bit-for-bit through the discrete-event engine (the digital twin), plus chaos
(SIGKILL a worker mid-task) and missed-heartbeat failure detection.

Exactness here is not a tolerance check: the master stamps every decision on
a binary time grid, so the replay's accounting and job records must equal the
live run's *exactly*, whatever interleaving the OS scheduler produced.
"""

import asyncio
import json
import os
import signal
import socket
import struct
import subprocess
import sys
import time
import types

import pytest

from repro.cluster.runtime import (
    TICK,
    LiveJob,
    Runtime,
    RuntimeMaster,
    TraceRecorder,
    replay_trace,
    spawn_worker_subprocess,
    spawn_worker_thread,
    trace_accounting,
)
from repro.cluster.runtime.protocol import MAX_FRAME, ProtocolError, read_msg, send_nowait
from repro.cluster.runtime.trace import quantize
from repro.cluster.scenario import Scenario
from repro.cluster.scheduler import JobPlan

pytestmark = pytest.mark.timeout(90)


def assert_exact_twin(report, n_workers, scenario=None):
    """The live run and its engine replay agree bit for bit."""
    eng = replay_trace(report.trace, n_workers, scenario=scenario)
    assert eng.accounting() == report.accounting()
    assert len(eng.records) == len(report.records)
    for live_r, eng_r in zip(report.records, sorted(eng.records, key=lambda r: r.job_id)):
        assert dataclass_tuple(live_r) == dataclass_tuple(eng_r)
    return eng


def dataclass_tuple(rec):
    return (
        rec.job_id,
        rec.name,
        rec.arrival,
        rec.start,
        rec.finish,
        rec.n_batches,
        rec.replication,
    )


# --------------------------------------------------------------------------
# e2e exact-twin runs (thread workers, real sockets)
# --------------------------------------------------------------------------


def test_twin_exact_basic_sleep():
    """Plan -> execute on live workers -> trace -> engine replay: exact."""
    sc = Scenario(n_batches=3)  # r = 1: plain partition, no redundancy
    jobs = [
        LiveJob(job_id=0, costs=(0.08, 0.05, 0.06, 0.04, 0.07, 0.05), name="a"),
        LiveJob(job_id=1, costs=(0.05, 0.04, 0.06), arrival=0.05, name="b"),
    ]
    report = Runtime(3, sc).run(jobs, timeout_s=30.0)
    assert [r.job_id for r in report.records] == [0, 1]
    assert report.completion_order == (0, 1)
    assert report.n_worker_failures == 0
    assert report.cancelled_seconds_saved == 0.0
    assert_exact_twin(report, 3, sc)
    # FIFO gang: job 1 cannot start before job 0 finishes
    assert report.records[1].start >= report.records[0].finish


def test_twin_exact_cancel_on_earliest_cover():
    """B=2, r=2 with a real per-worker speed skew: the slow replicas are
    cancelled when their siblings cover the batch, the reclaimed time is
    positive, and the replay reproduces the accounting exactly."""
    sc = Scenario(n_batches=2, cancel_redundant=True)
    jobs = [LiveJob(job_id=0, costs=(0.10, 0.10, 0.10, 0.10), skew=0.8)]
    report = Runtime(4, sc).run(jobs, timeout_s=30.0)
    assert report.records[0].replication == 2
    assert report.cancelled_seconds_saved > 0.05  # skewed siblings had real slack
    assert report.n_worker_failures == 0
    cancels = [e for e in report.trace if e["ev"] == "cancel"]
    assert len(cancels) == 2  # one straggler per batch reclaimed
    assert_exact_twin(report, 4, sc)


def test_twin_exact_job_plan_overrides():
    """Per-job JobPlan n_batches/cancel_redundant overrides ride through the
    live gang exactly as through the engine."""
    sc = Scenario(n_batches=2, cancel_redundant=False)
    jobs = [
        # plan override: single batch, duplicated on both workers, cancel on
        LiveJob(
            job_id=0,
            costs=(0.08, 0.06),
            skew=0.7,
            plan=JobPlan(n_batches=1, cancel_redundant=True),
        ),
        # scenario default: B=2, r=1, no cancellation
        LiveJob(job_id=1, costs=(0.05, 0.06), arrival=0.02),
    ]
    report = Runtime(2, sc).run(jobs, timeout_s=30.0)
    assert report.records[0].n_batches == 1
    assert report.records[0].replication == 2
    assert report.records[1].n_batches == 2
    assert report.records[1].replication == 1
    assert report.cancelled_seconds_saved > 0.0  # job 0's duplicate reclaimed
    assert_exact_twin(report, 2, sc)


def test_twin_exact_numpy_payload():
    """Real CPU-bound (chunked matmul) payloads: jittery wall-clock, still an
    exact replay -- exactness never depends on timing."""
    sc = Scenario(n_batches=2)
    jobs = [LiveJob(job_id=0, costs=(0.06, 0.05, 0.04, 0.05), payload="numpy")]
    report = Runtime(2, sc).run(jobs, timeout_s=30.0)
    assert len(report.records) == 1
    assert_exact_twin(report, 2, sc)


def test_trace_fold_matches_live_counters():
    """The pure trace fold reproduces the master's own running counters."""
    sc = Scenario(n_batches=2, cancel_redundant=True)
    report = Runtime(4, sc).run(
        [LiveJob(job_id=0, costs=(0.08, 0.08, 0.08, 0.08), skew=0.5)], timeout_s=30.0
    )
    assert trace_accounting(report.trace) == report.accounting()


# --------------------------------------------------------------------------
# speculative backups from partial progress -> scripted exact replay
# --------------------------------------------------------------------------


def test_twin_exact_speculative_backup():
    """Two fast batches complete and seed the median; the skewed straggler
    crosses theta x median, a backup launches from heartbeat-reported
    progress, wins the race, and the straggler is reclaimed.  The stamped
    launch replays through the engine as a scripted speculation epoch --
    exactly."""
    from repro.cluster.scenario import Speculation

    sc = Scenario(
        n_batches=3,
        cancel_redundant=True,
        speculation=Speculation(interval=0.12, theta=2.0),
    )
    # batch 2 lands on w2 (skew factor 2.6): ~2.6 s against ~0.15 s siblings
    jobs = [LiveJob(job_id=0, costs=(0.15, 0.15, 1.0), skew=0.8)]
    report = Runtime(3, sc).run(jobs, timeout_s=30.0)
    assert report.n_speculative == 1
    assert report.accounting()["n_speculative"] == 1
    specs = [e for e in report.trace if e["ev"] == "dispatch" and e.get("spec")]
    assert len(specs) == 1 and specs[0]["batch"] == 2 and not specs[0]["rescue"]
    # the backup won: the straggler's tail was reclaimed by cancellation
    assert report.cancelled_seconds_saved > 0.5
    assert report.records[0].finish < 2.0
    eng = assert_exact_twin(report, 3, sc)
    assert eng.n_speculative == 1


def test_trace_alone_replays_with_embedded_scenario():
    """The first trace event embeds the originating Scenario + worker
    budget: a JSON round-tripped trace replays with no other inputs."""
    from repro.cluster.scenario import Speculation

    sc = Scenario(
        n_batches=3,
        cancel_redundant=True,
        speculation=Speculation(interval=0.12, theta=2.0),
    )
    report = Runtime(3, sc).run(
        [LiveJob(job_id=0, costs=(0.15, 0.15, 1.0), skew=0.8)], timeout_s=30.0
    )
    head = report.trace[0]
    assert head["ev"] == "scenario" and head["n_workers"] == 3
    assert Scenario.from_dict(head["scenario"]) == sc
    # the trace is a plain JSON document; a file-loaded copy is sufficient
    events = json.loads(json.dumps(list(report.trace)))
    eng = replay_trace(events)  # no n_workers, no scenario
    assert eng.accounting() == report.accounting()
    # a trace stripped of its scenario event needs the explicit arguments
    bare = [e for e in events if e["ev"] != "scenario"]
    with pytest.raises(ValueError, match="n_workers"):
        replay_trace(bare)
    with pytest.raises(ValueError, match="Speculation"):
        replay_trace(bare, 3)  # spec launches stamped, policy missing


# --------------------------------------------------------------------------
# chaos: SIGKILL a subprocess worker mid-task -> rescue -> exact replay
# --------------------------------------------------------------------------


@pytest.mark.timeout(120)
def test_subprocess_kill_mid_task_rescued_exactly():
    """Kill the worker holding one batch's only replica mid-flight: the
    master detects the torn connection, rescues the batch onto a free
    worker, the job completes, and the trace still replays exactly."""

    async def run() -> tuple:
        sc = Scenario(n_batches=3)
        master = RuntimeMaster(3, sc, heartbeat_s=0.05, heartbeat_timeout_s=5.0)
        port = await master.start()
        procs = [spawn_worker_subprocess(master.host, port) for _ in range(3)]
        try:
            await master.wait_for_workers()
            # batch 2 = costs[2::3] is the long one: its worker is the victim
            jobs = [LiveJob(job_id=0, costs=(0.3, 0.3, 1.6), name="victim-run")]
            run_task = asyncio.ensure_future(master.run(jobs, timeout_s=60.0))
            victim_wid = None
            while victim_wid is None:
                await asyncio.sleep(0.01)
                for e in master.recorder.events:
                    if e["ev"] == "dispatch" and e["batch"] == 2:
                        victim_wid = e["wid"]
            await asyncio.sleep(0.3)  # let the batch be genuinely mid-task
            # wids are registration order, not spawn order: kill by the pid
            # the victim registered with
            os.kill(master.workers[victim_wid].pid, signal.SIGKILL)
            report = await run_task
        finally:
            await master.close()
            for p in procs:
                try:
                    p.wait(timeout=5.0)
                except Exception:
                    p.kill()
        return report, victim_wid

    report, victim_wid = asyncio.run(run())
    assert report.n_worker_failures == 1
    assert report.n_replicas_rescued == 1
    fails = [e for e in report.trace if e["ev"] == "fail"]
    assert [e["wid"] for e in fails] == [victim_wid]
    assert fails[0]["cause"] == "eof"
    rescues = [e for e in report.trace if e["ev"] == "dispatch" and e["rescue"]]
    assert len(rescues) == 1 and rescues[0]["batch"] == 2
    assert len(report.records) == 1 and report.records[0].finish < float("inf")
    assert_exact_twin(report, 3, Scenario(n_batches=3))


def test_subprocess_rejoin_serves_rescue_and_replays_exactly():
    """Kill a worker mid-task, then connect a replacement: the master retires
    the stale registration, grants the dead wid to the newcomer, the pending
    rescue runs on the re-joined worker, and the trace (fail + re-join on the
    churn timeline) still replays exactly through the engine."""

    async def run() -> tuple:
        sc = Scenario(n_batches=2)
        master = RuntimeMaster(2, sc, heartbeat_s=0.05, heartbeat_timeout_s=5.0)
        port = await master.start()
        procs = [spawn_worker_subprocess(master.host, port) for _ in range(2)]
        try:
            await master.wait_for_workers()
            # batch 0 = costs[0::2] holds the survivor busy long enough that
            # only a re-joined worker can serve the rescue of batch 1
            jobs = [LiveJob(job_id=0, costs=(2.5, 1.2), name="rejoin-run")]
            run_task = asyncio.ensure_future(master.run(jobs, timeout_s=60.0))
            victim_wid = None
            while victim_wid is None:
                await asyncio.sleep(0.01)
                for e in master.recorder.events:
                    if e["ev"] == "dispatch" and e["batch"] == 1:
                        victim_wid = e["wid"]
            await asyncio.sleep(0.3)  # let the batch be genuinely mid-task
            os.kill(master.workers[victim_wid].pid, signal.SIGKILL)
            while not any(e["ev"] == "fail" for e in master.recorder.events):
                await asyncio.sleep(0.01)
            # the replacement registers against a full budget: re-join path
            procs.append(spawn_worker_subprocess(master.host, port))
            report = await run_task
        finally:
            await master.close()
            for p in procs:
                try:
                    p.wait(timeout=5.0)
                except Exception:
                    p.kill()
        return report, victim_wid

    report, victim_wid = asyncio.run(run())
    assert report.n_worker_failures == 1
    assert report.n_replicas_rescued == 1
    joins = [e for e in report.trace if e["ev"] == "join"]
    fails = [e for e in report.trace if e["ev"] == "fail"]
    assert [e["wid"] for e in fails] == [victim_wid]
    # three joins: two initial registrations plus the re-join of the dead wid
    assert len(joins) == 3 and joins[2]["wid"] == victim_wid
    assert joins[2]["t"] > fails[0]["t"]
    rescues = [e for e in report.trace if e["ev"] == "dispatch" and e["rescue"]]
    assert len(rescues) == 1 and rescues[0]["batch"] == 1
    # the rescue ran on the re-joined wid, at or after its join stamp
    assert rescues[0]["wid"] == victim_wid
    assert rescues[0]["t"] >= joins[2]["t"]
    assert len(report.records) == 1 and report.records[0].finish < float("inf")
    assert_exact_twin(report, 2, Scenario(n_batches=2))


# --------------------------------------------------------------------------
# payload failures surface (no silent swallowing) and abandon without Retry
# --------------------------------------------------------------------------


def test_raising_payload_surfaces_in_live_report():
    """A payload that raises must not be swallowed: the worker sends a fail
    frame with the traceback, the master stamps ``task_fail``, and -- with no
    Retry policy -- the job is abandoned (finish=inf) rather than hanging.
    The faulted trace still replays exactly."""
    sc = Scenario(n_batches=2)
    report = Runtime(2, sc).run(
        [LiveJob(job_id=0, costs=(0.08, 0.06), payload="raise")], timeout_s=30.0
    )
    # the first fail frame abandons the job and finalizes the run; the
    # sibling batch's later frame (if any) lands after the freeze
    assert report.n_task_failures == 1
    assert report.n_retries == 0
    assert len(report.task_errors) == 1
    job_id, batch, wid, err = report.task_errors[0]
    assert job_id == 0
    assert "PayloadError" in err and "payload exploded" in err
    fails = [e for e in report.trace if e["ev"] == "task_fail"]
    assert len(fails) == 1 and fails[0]["attempt"] == 1
    assert "PayloadError" in fails[0]["error"]
    assert any(e["ev"] == "job_fail" for e in report.trace)
    assert len(report.records) == 1
    assert report.records[0].finish == float("inf")
    assert_exact_twin(report, 2, sc)


# --------------------------------------------------------------------------
# failure detection: missed heartbeats fire within the configured window
# --------------------------------------------------------------------------


@pytest.mark.timeout(120)
def test_heartbeat_timeout_detection_within_window():
    """A `block` payload starves its worker's heartbeat coroutine; the
    watchdog must declare the worker dead no earlier than the timeout and
    not much later."""
    timeout_s = 0.4

    async def run() -> tuple:
        sc = Scenario(n_batches=2)
        master = RuntimeMaster(2, sc, heartbeat_s=0.05, heartbeat_timeout_s=timeout_s)
        port = await master.start()
        threads = [spawn_worker_thread(master.host, port) for _ in range(2)]
        try:
            await master.wait_for_workers()
            # both batches block for ~1.5s >> the 0.4s heartbeat window
            jobs = [LiveJob(job_id=0, costs=(1.5, 1.5), payload="block")]
            run_task = asyncio.ensure_future(master.run(jobs, timeout_s=60.0))
            deadline = time.monotonic() + 30.0
            while master._n_failures < 2 and time.monotonic() < deadline:
                await asyncio.sleep(0.02)
            run_task.cancel()
            try:
                await run_task
            except asyncio.CancelledError:
                pass
            events = master.recorder.events
        finally:
            await master.close()
        # let the blocked threads unblock and exit before the test returns
        for t in threads:
            t.join(timeout=5.0)
        return events

    events = asyncio.run(run())
    fails = {e["wid"]: e for e in events if e["ev"] == "fail"}
    dispatches = {e["wid"]: e for e in events if e["ev"] == "dispatch"}
    assert set(fails) == {0, 1}
    for wid, f in fails.items():
        assert f["cause"] == "heartbeat"
        latency = f["t"] - dispatches[wid]["t"]
        # no earlier than the window (modulo one heartbeat interval -- up to
        # 1.1 x heartbeat_s with the seeded +-10% jitter -- sent just before
        # the payload starts blocking), and promptly after it (watchdog
        # period = timeout/4)
        assert latency >= timeout_s - 0.07
        assert latency <= timeout_s + 1.0


def test_short_block_survives_heartbeat_window():
    """Blocking for less than the window misses a couple of heartbeats but
    is not declared dead: detection has no false positives here."""
    sc = Scenario(n_batches=2)
    rt = Runtime(2, sc, heartbeat_s=0.05, heartbeat_timeout_s=1.0)
    report = rt.run([LiveJob(job_id=0, costs=(0.15, 0.12), payload="block")], timeout_s=30.0)
    assert report.n_worker_failures == 0
    assert len(report.records) == 1
    assert_exact_twin(report, 2, sc)


# --------------------------------------------------------------------------
# runtime Scenario validation (the shared single validation path)
# --------------------------------------------------------------------------


def test_runtime_rejects_simulation_only_knobs():
    with pytest.raises(ValueError, match="simulation-only"):
        RuntimeMaster(4, Scenario(speeds=(1.0, 1.0, 2.0, 1.0)))
    with pytest.raises(ValueError, match="space-sharing"):
        RuntimeMaster(4, Scenario(workers_per_job=2))
    with pytest.raises(ValueError, match="Scenario.n_batches"):
        RuntimeMaster(2, Scenario(n_batches=5))
    with pytest.raises(ValueError, match="spawn"):
        Runtime(2, spawn="fork-bomb")


# --------------------------------------------------------------------------
# trace + protocol units
# --------------------------------------------------------------------------


def test_trace_recorder_strictly_increasing_and_freezes():
    rec = TraceRecorder()
    stamps = [rec.stamp() for _ in range(50)]
    assert all(b - a >= TICK * 0.999 for a, b in zip(stamps, stamps[1:]))
    rec.record("join", stamps[0], wid=0)
    rec.frozen = True
    with pytest.raises(RuntimeError, match="frozen"):
        rec.record("join", stamps[1], wid=1)


def test_quantize_grid_exactness():
    assert quantize(0.0) == TICK  # durations stay strictly positive
    assert quantize(TICK / 2) == TICK
    q = quantize(0.123456)
    assert q >= 0.123456
    assert q * (1 << 20) == int(q * (1 << 20))  # exact binary fraction


def test_trace_accounting_hand_built():
    def ev(kind, t, **fields):
        return {"ev": kind, "t": t, **fields}

    t = [i * TICK for i in range(1, 12)]
    events = [
        ev("dispatch", t[0], wid=0, job=0, batch=0, planned=5 * TICK, rescue=False),
        ev("dispatch", t[1], wid=1, job=0, batch=0, planned=5 * TICK, rescue=False),
        ev("finish", t[2], wid=0, job=0, batch=0),
        ev("cancel", t[3], wid=1, job=0, batch=0, sched_end=t[1] + 5 * TICK),
        ev("dispatch", t[4], wid=2, job=1, batch=0, planned=5 * TICK, rescue=True),
        ev("fail", t[5], wid=2, cause="heartbeat"),
        ev("dispatch", t[6], wid=0, job=1, batch=0, planned=5 * TICK, rescue=True),
        ev("flush", t[7], wid=0, job=1, batch=0, sched_end=t[6] + 5 * TICK),
        # a payload failure closes its dispatch at the failure stamp; the
        # backoff-released re-dispatch counts as a retry, not a rescue
        ev("dispatch", t[8], wid=1, job=2, batch=0, planned=5 * TICK, rescue=False),
        ev("task_fail", t[9], wid=1, job=2, batch=0, attempt=1, error="boom"),
        ev("retry", t[9] + TICK / 2, job=2, batch=0, attempt=1),
        ev("dispatch", t[10], wid=1, job=2, batch=0, planned=5 * TICK, rescue=True, retry=True),
        ev("finish", t[10] + 4 * TICK, wid=1, job=2, batch=0),
    ]
    acct = trace_accounting(events)
    assert acct == {
        "worker_seconds": (t[2] - t[0])
        + (t[3] - t[1])
        + (t[5] - t[4])
        + 5 * TICK
        + (t[9] - t[8])
        + 4 * TICK,
        "cancelled_seconds_saved": (t[1] + 5 * TICK) - t[3],
        "n_worker_failures": 1,
        "n_replicas_rescued": 2,
        "n_replans": 0,
        "n_speculative": 0,
        "n_task_failures": 1,
        "n_retries": 1,
    }


def test_protocol_roundtrip_and_frame_guards():
    async def run():
        msgs = []

        async def handle(reader, writer):
            while True:
                m = await read_msg(reader)
                if m is None:
                    break
                msgs.append(m)
            writer.close()

        server = await asyncio.start_server(handle, "127.0.0.1", 0)
        port = server.sockets[0].getsockname()[1]
        reader, writer = await asyncio.open_connection("127.0.0.1", port)
        send_nowait(writer, {"type": "hb", "wid": 3})
        send_nowait(writer, {"type": "task", "costs": [0.25, 0.5], "payload": "sleep"})
        await writer.drain()
        writer.close()
        await asyncio.sleep(0.05)
        server.close()
        await server.wait_closed()
        return msgs

    msgs = asyncio.run(run())
    assert msgs == [
        {"type": "hb", "wid": 3},
        {"type": "task", "costs": [0.25, 0.5], "payload": "sleep"},
    ]
    sink = types.SimpleNamespace(write=lambda b: pytest.fail("oversized frame was sent"))
    with pytest.raises(ProtocolError, match="MAX_FRAME"):
        send_nowait(sink, {"type": "x", "blob": "a" * (MAX_FRAME + 1)})


def test_protocol_split_header_and_coalesced_frames():
    """Framing survives arbitrary TCP segmentation: a read split mid-way
    through the 4-byte header, and two frames coalesced into one segment."""

    def encode(obj):
        data = json.dumps(obj, separators=(",", ":")).encode()
        return struct.pack(">I", len(data)) + data

    async def run():
        frame = encode({"type": "hb", "wid": 1})
        reader = asyncio.StreamReader()
        pending = asyncio.ensure_future(read_msg(reader))
        reader.feed_data(frame[:2])  # half the length header
        await asyncio.sleep(0.01)
        assert not pending.done()  # must wait for the rest, not misparse
        reader.feed_data(frame[2:7])  # rest of header + part of the body
        await asyncio.sleep(0.01)
        assert not pending.done()
        reader.feed_data(frame[7:])
        assert await pending == {"type": "hb", "wid": 1}
        # two frames delivered in one segment parse as two messages
        reader.feed_data(encode({"type": "finish", "wid": 0}) + encode({"type": "hb", "wid": 2}))
        assert await read_msg(reader) == {"type": "finish", "wid": 0}
        assert await read_msg(reader) == {"type": "hb", "wid": 2}
        reader.feed_eof()
        assert await read_msg(reader) is None

    asyncio.run(run())


def test_protocol_rejects_untyped_and_oversized_frames():
    async def run():
        reader = asyncio.StreamReader()
        # a frame whose JSON is valid but is not a typed message object
        payload = json.dumps([1, 2, 3]).encode()
        reader.feed_data(struct.pack(">I", len(payload)) + payload)
        with pytest.raises(ProtocolError, match="typed message"):
            await read_msg(reader)
        reader2 = asyncio.StreamReader()
        reader2.feed_data(struct.pack(">I", MAX_FRAME + 1))
        with pytest.raises(ProtocolError, match="MAX_FRAME"):
            await read_msg(reader2)
        reader3 = asyncio.StreamReader()
        reader3.feed_data(b"\x00\x00")  # torn header
        reader3.feed_eof()
        assert await read_msg(reader3) is None

    asyncio.run(run())


# --------------------------------------------------------------------------
# worker-subprocess orphan prevention (PDEATHSIG + atexit fallback)
# --------------------------------------------------------------------------


def _dead_or_zombie(pid: int) -> bool:
    try:
        os.kill(pid, 0)
    except ProcessLookupError:
        return True
    try:
        with open(f"/proc/{pid}/stat") as f:
            return f.read().rsplit(")", 1)[1].split()[0] == "Z"
    except (FileNotFoundError, IndexError):  # pragma: no cover - non-procfs
        return True


def test_spawn_worker_subprocess_atexit_fallback_kills_orphans():
    """Spawned workers are tracked, and the atexit hook kills survivors --
    the cross-platform guarantee behind PDEATHSIG."""
    from repro.cluster.runtime import worker as worker_mod

    lst = socket.socket()
    lst.settimeout(20.0)
    lst.bind(("127.0.0.1", 0))
    lst.listen(4)
    port = lst.getsockname()[1]
    proc = worker_mod.spawn_worker_subprocess("127.0.0.1", port)
    conn = None
    try:
        assert proc in worker_mod._children
        conn, _ = lst.accept()  # the worker is up, blocked awaiting a welcome
        assert proc.poll() is None
        worker_mod._kill_orphans()
        proc.wait(timeout=10.0)
        assert proc.poll() is not None
    finally:
        if conn is not None:
            conn.close()
        lst.close()
        if proc.poll() is None:
            proc.kill()


@pytest.mark.skipif(not sys.platform.startswith("linux"), reason="PR_SET_PDEATHSIG is linux-only")
@pytest.mark.timeout(120)
def test_pdeathsig_reaps_worker_when_parent_is_sigkilled():
    """SIGKILL the process that spawned a worker (no atexit runs there): the
    kernel's PDEATHSIG must kill the worker anyway -- chaos runs that crash
    the master must not leak worker processes."""
    lst = socket.socket()
    lst.settimeout(30.0)
    lst.bind(("127.0.0.1", 0))
    lst.listen(4)
    port = lst.getsockname()[1]
    script = (
        "import time\n"
        "from repro.cluster.runtime.worker import spawn_worker_subprocess\n"
        f"p = spawn_worker_subprocess('127.0.0.1', {port})\n"
        "print(p.pid, flush=True)\n"
        "time.sleep(120)\n"
    )
    pkg_root = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
    env = os.environ.copy()
    env["PYTHONPATH"] = pkg_root + os.pathsep + env.get("PYTHONPATH", "")
    parent = subprocess.Popen([sys.executable, "-c", script], stdout=subprocess.PIPE, env=env)
    conn = None
    worker_pid = None
    try:
        worker_pid = int(parent.stdout.readline())
        conn, _ = lst.accept()  # the worker is genuinely up before the kill
        os.kill(parent.pid, signal.SIGKILL)
        parent.wait(timeout=10.0)
        deadline = time.monotonic() + 15.0
        while time.monotonic() < deadline:
            if _dead_or_zombie(worker_pid):
                break
            time.sleep(0.05)
        else:
            pytest.fail(f"worker {worker_pid} survived its parent's SIGKILL")
    finally:
        if conn is not None:
            conn.close()
        lst.close()
        for pid in (parent.pid, worker_pid):
            if pid:
                try:
                    os.kill(pid, signal.SIGKILL)
                except (ProcessLookupError, PermissionError):
                    pass
