"""Deterministic synthetic data pipeline with *replicated shard assignment*.

The paper's optimal policy (balanced, non-overlapping batches; Thms 1-2)
becomes the shard-assignment rule of the input pipeline: the global batch is
cut into ``B`` contiguous shards; worker group ``w`` reads shard ``w % B``
(so each shard is produced by exactly ``r = N/B`` replica groups -- Lemma 3's
balanced vector).  At startup the assignment is validated with the coverage
guard (Lemma 1's failure mode -- an uncovered shard -- is a hard error).

Data is generated counter-deterministically (Philox keyed on
(seed, step, shard)): any worker can reproduce any shard at any step with no
coordination, which is what makes replicated shards and elastic reassignment
free of data movement.

The token stream follows a fixed random bigram chain (90% transition, 10%
noise), so models measurably learn (loss drops well below uniform entropy)
in a few hundred CPU steps -- used by the end-to-end example.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np

from ..core import batching

Batch = Dict[str, np.ndarray]


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    n_shards: int = 1  # B: distinct data shards (paper's batches)
    replication: int = 1  # r: worker groups per shard
    seed: int = 0
    bigram_p: float = 0.9


class SyntheticLM:
    def __init__(self, cfg: PipelineConfig):
        if cfg.global_batch % cfg.n_shards:
            raise ValueError("n_shards must divide global_batch (balanced shards)")
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        self._perm = rng.permutation(cfg.vocab_size)
        # startup coverage guard: the worker->shard membership must cover
        # every shard (paper Lemma 1 turned into an invariant)
        n_workers = cfg.n_shards * cfg.replication
        m = batching.non_overlapping(
            n_tasks=cfg.n_shards * max(cfg.replication, 1),
            n_batches=cfg.n_shards,
            n_workers=n_workers,
        )
        diag = batching.validate_scheme(m)
        assert diag["balanced"], diag

    # -- generation ----------------------------------------------------------

    def _gen(self, rng: np.random.Generator, rows: int) -> np.ndarray:
        c = self.cfg
        toks = np.empty((rows, c.seq_len + 1), dtype=np.int64)
        toks[:, 0] = rng.integers(0, c.vocab_size, size=rows)
        noise = rng.random((rows, c.seq_len)) >= c.bigram_p
        rand_next = rng.integers(0, c.vocab_size, size=(rows, c.seq_len))
        for t in range(c.seq_len):
            nxt = self._perm[toks[:, t]]
            toks[:, t + 1] = np.where(noise[:, t], rand_next[:, t], nxt)
        return toks

    def _rng_for(self, step: int, shard: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.Philox(key=self.cfg.seed, counter=[0, 0, step, shard])
        )

    def shard_batch(self, step: int, shard: int) -> Batch:
        """The rows of shard ``shard`` at ``step`` (reproducible anywhere)."""
        c = self.cfg
        rows = c.global_batch // c.n_shards
        toks = self._gen(self._rng_for(step, shard), rows)
        return {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
            "loss_mask": np.ones((rows, c.seq_len), np.float32),
        }

    def global_batch(self, step: int) -> Batch:
        parts = [self.shard_batch(step, s) for s in range(self.cfg.n_shards)]
        return {k: np.concatenate([p[k] for p in parts]) for k in parts[0]}

    def worker_batch(self, step: int, worker: int) -> Batch:
        """Paper policy: worker w serves shard w % B (balanced round-robin)."""
        return self.shard_batch(step, worker % self.cfg.n_shards)

    def shard_of_worker(self, worker: int) -> int:
        return worker % self.cfg.n_shards

    def bigram_ceiling_loss(self) -> float:
        """Entropy of the generating chain = best achievable CE (nats)."""
        c = self.cfg
        p, v = c.bigram_p, c.vocab_size
        p_next = p + (1 - p) / v
        p_other = (1 - p) / v
        h = -p_next * np.log(p_next)
        if p_other > 0:
            h -= (v - 1) * p_other * np.log(p_other)
        return float(h)
