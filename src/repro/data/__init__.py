from .pipeline import Batch, PipelineConfig, SyntheticLM

__all__ = ["Batch", "PipelineConfig", "SyntheticLM"]
