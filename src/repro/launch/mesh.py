"""Production mesh construction (functions only -- importing this module
never touches jax device state)."""
from __future__ import annotations

import jax

try:
    from jax.sharding import AxisType
except ImportError:  # jax < 0.5: meshes have no axis_types concept
    AxisType = None


def _mk(shape, axes):
    if AxisType is None:
        return jax.make_mesh(tuple(shape), tuple(axes))
    return jax.make_mesh(
        tuple(shape), tuple(axes), axis_types=(AxisType.Auto,) * len(axes)
    )


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 two-pod (512 chips) mesh."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_mesh(shape, axes):
    return _mk(shape, axes)


def make_replicated_mesh(replication: int, n_shards: int, model_parallel: int):
    """RDP mesh ("replica","shard","model") for a replication plan (B, r)."""
    return _mk((replication, n_shards, model_parallel), ("replica", "shard", "model"))
