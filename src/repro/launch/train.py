"""Training launcher: replication-planned data parallelism + checkpointed loop.

The paper's technique is wired in as a first-class feature: before the run,
the RedundancyPlanner picks (B, r) for the configured worker budget from the
assumed/fitted step-time distribution; the data pipeline assigns shards by
the balanced non-overlapping policy; the trainer logs the predicted E[T] /
CoV frontier next to the measured step times, and the elastic controller
replans on (simulated) membership changes.

Example (CPU, reduced config):
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --smoke \\
      --steps 100 --global-batch 8 --seq-len 128 --workers 8 --service-dist sexp
"""
import argparse
import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import CheckpointManager
from ..configs import get_config
from ..configs.base import ShapeConfig
from ..core.planner import RedundancyPlanner
from ..core.service_time import Exponential, Pareto, ShiftedExponential
from ..data import PipelineConfig, SyntheticLM
from ..distributed import rdp
from ..models import build_model
from ..optim import AdamW, cosine_with_warmup
from ..runtime.train import init_state, jit_train_step, make_train_step
from .mesh import make_mesh

DISTS = {
    "exp": Exponential(mu=1.0),
    "sexp": ShiftedExponential(delta=0.05, mu=5.0),
    "pareto": Pareto(sigma=1.0, alpha=1.5),
}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--workers", type=int, default=8, help="DP worker budget N for planning")
    ap.add_argument("--service-dist", default="sexp", choices=list(DISTS))
    ap.add_argument("--objective", default="mean", choices=["mean", "cov", "blend"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    # --- the paper's planning step -----------------------------------------
    planner = RedundancyPlanner(args.workers)
    plan = planner.plan(DISTS[args.service_dist], args.objective)
    print(
        f"[plan] N={plan.n_workers} -> B={plan.n_batches} shards x r={plan.replication} "
        f"replicas ({plan.source}); predicted E[T]={plan.predicted_mean:.3f} "
        f"CoV={plan.predicted_cov:.3f}"
    )
    cov = rdp.surviving_coverage(plan, [True] * plan.n_workers)
    assert cov["covered"], cov

    cfg = get_config(args.arch, smoke=args.smoke)
    model = build_model(cfg)
    n_params = None

    pipe = SyntheticLM(
        PipelineConfig(
            vocab_size=cfg.vocab_size,
            seq_len=args.seq_len,
            global_batch=args.global_batch,
            n_shards=min(plan.n_batches, args.global_batch),
            replication=plan.replication,
            seed=args.seed,
        )
    )

    optimizer = AdamW(cosine_with_warmup(args.lr, max(args.steps // 20, 1), args.steps))
    shape = ShapeConfig("cli", args.seq_len, args.global_batch, "train")

    n_dev = len(jax.devices())
    if n_dev > 1:
        mesh = make_mesh((n_dev, 1), ("data", "model"))
        step_fn, st_sh, _ = jit_train_step(
            mesh, model, optimizer, shape, microbatches=args.microbatches
        )
    else:
        step_fn = jax.jit(
            make_train_step(model, optimizer, microbatches=args.microbatches),
            donate_argnums=(0,),
        )

    mgr = CheckpointManager(pathlib.Path(args.ckpt_dir) / cfg.name, keep=3)
    state = init_state(model, optimizer, jax.random.key(args.seed))
    n_params = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(state.params))
    print(f"[model] {cfg.name}: {n_params/1e6:.1f}M params, {cfg.n_layers} layers")
    start = 0
    if args.resume and mgr.latest_step() is not None:
        state, start = mgr.restore(jax.eval_shape(lambda: state))
        state = jax.tree.map(jnp.asarray, state)
        print(f"[resume] from step {start}")

    ceiling = pipe.bigram_ceiling_loss()
    times = []
    for step in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in pipe.global_batch(step).items()}
        t0 = time.time()
        state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])
        times.append(time.time() - t0)
        if step % args.log_every == 0 or step == args.steps - 1:
            print(
                f"step {step:5d} loss {loss:.4f} (ceiling {ceiling:.3f}) "
                f"grad_norm {float(metrics['grad_norm']):.3f} "
                f"lr {float(metrics['lr']):.2e} {times[-1]*1e3:.0f}ms"
            )
        if step and step % args.ckpt_every == 0:
            mgr.save_async(step, state)
    mgr.wait()
    mgr.save(args.steps, state)
    print(f"[done] final loss {loss:.4f}; median step {np.median(times)*1e3:.0f}ms")

    # replication-plan report next to measured steps (observability hook)
    report = {
        "plan": {
            "B": plan.n_batches, "r": plan.replication,
            "objective": args.objective,
            "frontier_B": plan.frontier_B,
            "frontier_mean": plan.frontier_mean,
            "frontier_cov": plan.frontier_cov,
        },
        "final_loss": loss,
        "loss_ceiling": ceiling,
        "median_step_ms": float(np.median(times) * 1e3),
        "params": n_params,
    }
    out = pathlib.Path(args.ckpt_dir) / cfg.name / "train_report.json"
    out.write_text(json.dumps(report, indent=2))
    print(f"[report] {out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
