"""Serving launcher: batched prefill + decode with replicated-prefill planning.

The paper maps to serving as *request replication*: a batch of independent
prefill jobs (the "tasks") can be replicated across worker groups, and the
batch completes when every request is served by its fastest replica
(T = max_B min_r).  The launcher serves a small model end-to-end on CPU and
reports the simulated replication speedup for the measured per-request
service times.

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --smoke \\
      --requests 8 --prompt-len 32 --gen 16
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config
from ..core import simulator
from ..core.planner import RedundancyPlanner
from ..core.service_time import Empirical
from ..models import build_model
from ..runtime.serve import make_prefill_step, make_serve_step


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    if cfg.family == "encoder":
        raise SystemExit(f"{args.arch} is encoder-only: no autoregressive serving")
    model = build_model(cfg)
    params = model.init(jax.random.key(args.seed))
    max_len = args.prompt_len + args.gen

    prefill = jax.jit(make_prefill_step(model, max_len))
    step = jax.jit(make_serve_step(model), donate_argnums=(1,))

    rng = np.random.default_rng(args.seed)
    service_times = []
    for r in range(args.requests):
        tokens = jnp.asarray(
            rng.integers(0, cfg.vocab_size, size=(1, args.prompt_len)), jnp.int32
        )
        t0 = time.time()
        if cfg.family == "vlm":
            pos = jnp.broadcast_to(
                jnp.arange(args.prompt_len, dtype=jnp.int32)[None, :, None],
                (1, args.prompt_len, 3),
            )
            embeds = params["embed"][tokens].astype(cfg.dtype("compute"))
            logits, cache, t = prefill(params, {"embeds": embeds, "mrope_positions": pos})
        else:
            logits, cache, t = prefill(params, {"tokens": tokens})
        out = []
        tok = jnp.argmax(logits[:, : cfg.vocab_size], -1)[:, None].astype(jnp.int32)
        for _ in range(args.gen):
            logits, cache, t = step(params, cache, tok, t)
            tok = jnp.argmax(logits[:, : cfg.vocab_size], -1)[:, None].astype(jnp.int32)
            out.append(int(tok[0, 0]))
        dt = time.time() - t0
        service_times.append(dt)
        print(f"request {r}: {dt*1e3:.0f}ms, generated {out[:8]}...")

    # paper: plan replication for these measured service times
    times = np.asarray(service_times)
    planner = RedundancyPlanner(args.workers)
    plan = planner.plan_empirical(times, "mean", n_mc=5000)
    base = simulator.stats_from_samples(
        simulator.simulate_balanced(
            jax.random.key(1), Empirical(tuple(times)), args.workers, args.workers, 20000
        )
    )
    best = simulator.stats_from_samples(
        simulator.simulate_balanced(
            jax.random.key(2), Empirical(tuple(times)), args.workers, plan.n_batches, 20000
        )
    )
    print(
        f"[plan] measured mean {times.mean()*1e3:.0f}ms/req; for N={args.workers} "
        f"workers the planner picks B={plan.n_batches} (r={plan.replication}): "
        f"E[T] {base.mean*1e3:.0f}ms (no redundancy) -> {best.mean*1e3:.0f}ms"
    )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
