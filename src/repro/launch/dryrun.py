import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax-importing import: jax locks the device count on init.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each applicable cell this lowers the real step function (train_step for
``train_*``, prefill for ``prefill_*``, serve_step -- one token against a
seq_len KV cache -- for ``decode_*``/``long_*``) with production shardings
onto the 16x16 single-pod and 2x16x16 multi-pod mesh, compiles it, and
records:

  * memory_analysis()   -- per-device bytes (proves the cell fits HBM)
  * cost_analysis()     -- per-device FLOPs / bytes accessed
  * a collective parse of the partitioned HLO: bytes per collective kind,
    split ICI vs DCN (groups crossing the pod boundary), with ring-model
    wire-byte estimates

into benchmarks/artifacts/dryrun/<mesh>_<arch>_<shape>.json, which
benchmarks/roofline.py turns into the three roofline terms.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --mesh both
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-9b --shape train_4k
"""
import argparse
import json
import pathlib
import time
import traceback
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from ..configs import SHAPES, applicable_shapes, get_config, skipped_shapes, ARCH_IDS
from ..models import build_model
from ..optim import AdamW, cosine_with_warmup
from ..runtime.serve import jit_prefill, jit_serve_step
from ..runtime.train import default_microbatches, init_state, jit_train_step
from . import hlo_stats
from .mesh import make_production_mesh

ARTIFACTS = pathlib.Path(__file__).resolve().parents[3] / "benchmarks" / "artifacts" / "dryrun"

# dry-run numerics: bf16 params + fp32 Adam moments, TP padding for the
# 16-wide model axis, vocab padded to 16*128 (DESIGN.md §4)
DRYRUN_OVERRIDES = dict(
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    pad_heads_to=16,
    pad_vocab_to_multiple=2048,
)


def _mem_fields(compiled) -> Dict:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {"available": False}
    if ma is None:
        return {"available": False}
    fields = [
        "generated_code_size_in_bytes",
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "alias_size_in_bytes",
        "host_generated_code_size_in_bytes",
        "host_argument_size_in_bytes",
        "host_output_size_in_bytes",
        "host_temp_size_in_bytes",
    ]
    out = {"available": True}
    for f in fields:
        v = getattr(ma, f, None)
        if v is not None:
            out[f] = int(v)
    return out


def _cost_fields(compiled) -> Dict:
    try:
        ca = compiled.cost_analysis()
    except Exception:
        return {}
    if not ca:
        return {}
    keep = {}
    for k, v in ca.items():
        if isinstance(v, (int, float)) and k in (
            "flops", "transcendentals", "bytes accessed", "optimal_seconds"
        ):
            keep[k] = float(v)
    return keep


def run_cell(
    arch: str,
    shape_name: str,
    multi_pod: bool,
    out_dir: pathlib.Path,
    skip_existing: bool = True,
    overrides: Optional[dict] = None,
    tag: str = "",
    mesh_override=None,  # e.g. the RDP ("replica","shard","model") mesh
) -> Dict:
    mesh_name = ("multipod" if multi_pod else "singlepod") + tag
    out_path = out_dir / f"{mesh_name}_{arch}_{shape_name}.json"
    if skip_existing and out_path.exists():
        return json.loads(out_path.read_text())

    shape = SHAPES[shape_name]
    ov = dict(DRYRUN_OVERRIDES)
    ov.update(overrides or {})
    mb_override = ov.pop("microbatches", None)
    mesh_axes_name = ov.pop("mesh_axes", None)
    cfg = get_config(arch, **ov)
    model = build_model(cfg)
    mesh = mesh_override if mesh_override is not None else make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    pod_stride = 256 if multi_pod else None

    record: Dict = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "mesh_shape": dict(zip(mesh.axis_names, [int(mesh.shape[a]) for a in mesh.axis_names])),
        "n_devices": int(n_dev),
        "kind": shape.kind,
        "params_estimate": int(cfg.param_count_estimate()),
        "active_params_estimate": int(cfg.active_param_count_estimate()),
        "tokens_per_step": int(
            shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
        ),
        "overrides": {k: str(v) for k, v in ov.items()},
        "ok": False,
    }

    t0 = time.time()
    try:
        with mesh:
            if shape.kind == "train":
                optimizer = AdamW(cosine_with_warmup(3e-4, 100, 10_000))
                mb = mb_override or default_microbatches(model, shape)
                record["microbatches"] = int(mb)
                mesh_axes = None
                if mesh_axes_name == "dp_over_model":
                    from ..distributed.sharding import MeshAxes

                    mesh_axes = MeshAxes.dp_over_model(mesh)
                    record["mesh_axes"] = mesh_axes_name
                step_fn, st_sh, b_sh = jit_train_step(
                    mesh, model, optimizer, shape, microbatches=mb, mesh_axes=mesh_axes
                )
                state_spec = jax.eval_shape(
                    lambda: init_state(model, optimizer, jax.random.key(0))
                )
                lowered = step_fn.lower(state_spec, model.input_specs(shape))
            elif shape.kind == "prefill":
                fn, p_sh, b_sh, c_sh = jit_prefill(mesh, model, shape)
                lowered = fn.lower(model.param_specs(), model.input_specs(shape))
            else:  # decode
                fn, p_sh, c_sh, tok_sh = jit_serve_step(mesh, model, shape)
                lowered = fn.lower(
                    model.param_specs(),
                    model.cache_specs(shape),
                    jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32),
                    jax.ShapeDtypeStruct((), jnp.int32),
                )
            record["lower_s"] = round(time.time() - t0, 2)
            t1 = time.time()
            compiled = lowered.compile()
            record["compile_s"] = round(time.time() - t1, 2)
            record["memory_analysis"] = _mem_fields(compiled)
            record["cost_analysis"] = _cost_fields(compiled)
            t2 = time.time()
            hlo = compiled.as_text()
            record["hlo_bytes"] = len(hlo)
            # loop-aware per-device stats (cost_analysis counts scan bodies once)
            st = hlo_stats.analyze(hlo, pod_stride=pod_stride)
            record["hlo_stats"] = hlo_stats.stats_to_dict(st)
            record["parse_s"] = round(time.time() - t2, 2)
            del hlo
            record["ok"] = True
    except Exception as e:  # recorded, not raised: failures are report items
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-4000:]

    out_dir.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(record, indent=2))
    status = "ok" if record["ok"] else "FAIL"
    print(
        f"[{status}] {mesh_name} {arch} {shape_name} "
        f"lower={record.get('lower_s', '-')}s compile={record.get('compile_s', '-')}s",
        flush=True,
    )
    return record


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all", help="arch id or 'all'")
    ap.add_argument("--shape", default="all", help="shape name or 'all'")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default=str(ARTIFACTS))
    ap.add_argument("--force", action="store_true", help="recompute existing cells")
    args = ap.parse_args(argv)

    out_dir = pathlib.Path(args.out)
    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    n_ok = n_fail = 0
    for arch in archs:
        shapes = applicable_shapes(arch)
        if args.shape != "all":
            if args.shape not in shapes:
                print(f"[skip] {arch} {args.shape}: {skipped_shapes(arch).get(args.shape, 'n/a')}")
                continue
            shapes = {args.shape: shapes[args.shape]}
        for shape_name in shapes:
            for mp in meshes:
                rec = run_cell(arch, shape_name, mp, out_dir, skip_existing=not args.force)
                n_ok += rec["ok"]
                n_fail += not rec["ok"]
        for shape_name, reason in skipped_shapes(arch).items():
            if args.shape in ("all", shape_name):
                p = out_dir / f"skipped_{arch}_{shape_name}.json"
                out_dir.mkdir(parents=True, exist_ok=True)
                p.write_text(json.dumps({
                    "arch": arch, "shape": shape_name, "skipped": True, "reason": reason,
                }, indent=2))
    print(f"dry-run complete: {n_ok} ok, {n_fail} failed")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
