import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ must precede any jax import (see dryrun.py)

"""EXPERIMENTS.md §Perf: the hillclimbed variants of the three chosen cells.

Each variant re-lowers the cell with one optimization applied and writes a
tagged artifact next to the baseline, so the before/after table is
reproducible from artifacts alone:

  cell A (most collective-bound): qwen3-moe-235b train_4k
      A1 _sp      sequence parallelism -> microbatches 16 -> 1
      A2 _spbig   A1 + remat-friendly bigger microbatch split if A1 fits
  cell B (serving/memory):        qwen2-1.5b decode_32k
      B1 _carry   KV cache in the layer-scan carry (in-place ring buffer)
  cell C (paper cell / worst train fraction): qwen2-1.5b train_4k
      C1 _dpom    TP axis repurposed as data parallelism (DP=256)
      C2 _dpomsp  C1 with microbatches=4 (logit-memory guard)
  technique cell: qwen2-1.5b train_4k on the RDP mesh (r=2 replication) --
      the paper's diversity end quantified in FLOPs (not an optimization;
      the fault-tolerance/straggler benefit is quantified by the simulator).

Usage: PYTHONPATH=src python -m repro.launch.perf_cells [--only TAG]
"""
import argparse

from .dryrun import ARTIFACTS, run_cell


VARIANTS = [
    # (arch, shape, tag, overrides)
    ("qwen3-moe-235b-a22b", "train_4k", "_sp",
     {"sequence_parallel": True, "microbatches": 1}),
    ("qwen2-1.5b", "decode_32k", "_carry", {"cache_in_carry": True}),
    ("qwen2-1.5b", "train_4k", "_dpom",
     {"mesh_axes": "dp_over_model", "microbatches": 2}),
    ("qwen2-1.5b", "train_4k", "_dpom_mb4",
     {"mesh_axes": "dp_over_model", "microbatches": 4}),
    # second-iteration combinations
    ("qwen3-moe-235b-a22b", "train_4k", "_sp_mb2",
     {"sequence_parallel": True, "microbatches": 2}),
    ("qwen2-1.5b", "decode_32k", "_carry_nomat",
     {"cache_in_carry": True, "remat": False}),
    # iteration 3: backward must not re-run the TP psums (remat policy) --
    # SP makes saving the block outputs affordable (they are seq-sharded)
    ("qwen3-moe-235b-a22b", "train_4k", "_sp_saveouts",
     {"sequence_parallel": True, "microbatches": 1, "remat_policy": "block_outs"}),
    ("qwen2-1.5b", "train_4k", "_saveouts",
     {"remat_policy": "block_outs", "microbatches": 4}),
    # iteration 3 for cell C: dp-over-model needs microbatch rows >= 256
    # (the earlier mb=2 run exposed the forced-replication bug; see axes.py)
    ("qwen2-1.5b", "train_4k", "_dpom_mb1",
     {"mesh_axes": "dp_over_model", "microbatches": 1}),
    # iteration 4 for cell C: combine DP=256 with the recompute-avoiding
    # remat policy (block outputs are tiny at 1 row/device)
    ("qwen2-1.5b", "train_4k", "_dpom_saveouts",
     {"mesh_axes": "dp_over_model", "microbatches": 1, "remat_policy": "block_outs"}),
    # iteration 4 for cell B: true-KV ring sharded by sequence over TP
    # (shard_map flash-combine): -Rx cache footprint/reads for kv<16 archs
    ("qwen2-1.5b", "decode_32k", "_kvseq",
     {"cache_in_carry": True, "decode_kv_seq_sharded": True}),
    # the same two decode levers applied across the zoo (kv=4 -> 4x, kv=2 -> 8x)
    ("yi-9b", "decode_32k", "_kvseq",
     {"cache_in_carry": True, "decode_kv_seq_sharded": True}),
    ("starcoder2-3b", "decode_32k", "_kvseq",
     {"cache_in_carry": True, "decode_kv_seq_sharded": True}),
    ("dbrx-132b", "decode_32k", "_kvseq",
     {"cache_in_carry": True, "decode_kv_seq_sharded": True}),
    ("gemma-7b", "decode_32k", "_carry", {"cache_in_carry": True}),  # kv=16: carry only
]


def run_technique_cell(force: bool = False):
    """The paper's own operating point on the mesh: r=2 replication.

    Mesh (replica=2, shard=8, model=16) = 256 chips; batch shards over
    "shard" only, so each microbatch is computed by 2 replica groups --
    full diversity cost is visible as ~2x per-device FLOPs vs the plain
    (16,16) baseline, and buys first-of-r straggler latency + shard-loss
    tolerance (quantified by core.simulator; EXPERIMENTS §Technique).
    """
    from .mesh import make_replicated_mesh

    mesh = make_replicated_mesh(replication=2, n_shards=8, model_parallel=16)
    return run_cell(
        "qwen2-1.5b", "train_4k", multi_pod=False, out_dir=ARTIFACTS,
        skip_existing=not force, overrides={"microbatches": 4}, tag="_rdp_r2",
        mesh_override=mesh,
    )


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", default=None, help="run one tag only")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--technique", action="store_true", help="run the RDP r=2 cell")
    args = ap.parse_args(argv)
    n_fail = 0
    if args.technique:
        rec = run_technique_cell(force=args.force)
        return 0 if rec["ok"] else 1
    for arch, shape, tag, overrides in VARIANTS:
        if args.only and args.only != tag:
            continue
        rec = run_cell(
            arch, shape, multi_pod=False, out_dir=ARTIFACTS,
            skip_existing=not args.force, overrides=overrides, tag=tag,
        )
        n_fail += not rec["ok"]
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
