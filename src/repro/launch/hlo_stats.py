"""Loop-aware analysis of compiled (post-SPMD-partitioning) HLO text.

``compiled.cost_analysis()`` counts every computation ONCE -- a scanned
28-layer transformer reports 1/28th of its real FLOPs (verified in tests).
This module parses ``compiled.as_text()`` into a computation call graph,
reads each ``while`` op's ``known_trip_count`` backend config (falling back
to the constant in its condition), and produces loop-weighted totals:

  * ``flops``            -- 2 * prod(out) * prod(contracting dims) per dot
                            (+ convolutions via output * window)
  * ``hbm_bytes``        -- sum of operand+output bytes of materializing ops
                            (fusions, dots, collectives, copies, scatters...)
                            -- an HBM-traffic estimate for the memory term
  * ``collectives``      -- per-kind counts/bytes + ring-model wire bytes,
                            split ICI vs DCN by whether the replica group
                            spans the pod stride

All numbers are per-device (the partitioned module is the per-core program).
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Dict, List, Optional, Tuple

import numpy as np

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
    "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(
    r"\b(f64|f32|f16|bf16|s64|u64|s32|u32|s16|u16|s8|u8|pred|f8e4m3fn|f8e5m2|s4|u4)\[([\d,]*)\]"
)
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_INST_RE = re.compile(r"^\s+(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")
_OPNAME_RE = re.compile(r"^(?:\([^)]*\)|\S+)\s+([a-z][a-z0-9\-]*)\(")
_OPERANDS_RE = re.compile(r"\(([^)]*)\)")
_CALLS_RE = re.compile(
    r"(?:calls|to_apply|body|condition|true_computation|false_computation)=%?([\w\.\-]+)"
)
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP_RE = re.compile(r'known_trip_count.{0,8}?n.{0,4}?"(\d+)"')
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{(\{[^}]*\}(?:,\{[^}]*\})*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?")
_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

COLLECTIVE_OPS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute",
)
# ops whose operands/outputs we count as HBM traffic (fusion boundaries)
_TRAFFIC_OPS = {
    "fusion", "dot", "convolution", "copy", "transpose", "scatter", "gather",
    "dynamic-update-slice", "dynamic-slice", "reduce", "sort", "pad",
    "concatenate", "slice", "select-and-scatter", "reduce-window", "cholesky",
    "triangular-solve", "rng", "while", "conditional",
} | set(COLLECTIVE_OPS)
_FREE_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "reshape", "broadcast", "iota", "after-all", "partition-id", "replica-id",
    "custom-call", "call", "add-dependency", "copy-start", "copy-done",
}


def _type_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        b = _DTYPE_BYTES[m.group(1)]
        for d in m.group(2).split(","):
            if d:
                b *= int(d)
        total += b
    return total


def _type_dims(type_str: str) -> Tuple[str, List[int]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return "", []
    dims = [int(d) for d in m.group(2).split(",") if d]
    return m.group(1), dims


@dataclasses.dataclass
class Instruction:
    name: str
    opcode: str
    type_str: str
    body: str  # rest of the line

    @property
    def out_bytes(self) -> int:
        return _type_bytes(self.type_str)


@dataclasses.dataclass
class Computation:
    name: str
    instructions: List[Instruction]
    shapes: Dict[str, str]  # inst name -> type str


def parse_computations(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in hlo.splitlines():
        if cur is None:
            m = _COMP_HDR_RE.match(line)
            if m:
                cur = Computation(m.group(1), [], {})
            continue
        if line.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        m = _INST_RE.match(line)
        if not m:
            continue
        name, rest = m.groups()
        om = _OPNAME_RE.match(rest)
        if not om:
            continue
        opcode = om.group(1)
        # the "type" part = everything before the opcode occurrence
        idx = rest.find(opcode + "(")
        type_str = rest[:idx]
        cur.instructions.append(Instruction(name, opcode, type_str, rest))
        cur.shapes[name] = type_str
    return comps


def _first_group_ids(body: str) -> Optional[List[int]]:
    m = _GROUPS_LIST_RE.search(body)
    if m:
        first = m.group(1).split("},")[0].strip("{}")
        return [int(x) for x in first.split(",") if x.strip()]
    m = _GROUPS_IOTA_RE.search(body)
    if m:
        g, s, dims, perm = m.groups()
        dims = [int(d) for d in dims.split(",")]
        n = int(np.prod(dims))
        arr = np.arange(n).reshape(dims)
        if perm:
            arr = arr.transpose([int(p) for p in perm.split(",")])
        arr = arr.reshape(int(g), int(s))
        return arr[0].tolist()
    return None


def _operand_names(body: str) -> List[str]:
    m = _OPERANDS_RE.search(body[body.find("("):] if "(" in body else body)
    if not m:
        return []
    group = m.group(1)
    # older HLO printers emit typed operands ("f32[4,8]{1,0} %arg.1"): the
    # %-prefixed reference is unambiguous, and comma-splitting would break
    # inside the shape brackets -- so prefer extracting the references
    names = re.findall(r"%([\w\.\-]+)", group)
    if names:
        return names
    for tok in group.split(","):
        tok = tok.strip()
        if not tok:
            continue
        cand = tok.split()[-1]
        if cand and not cand[0].isdigit():
            names.append(cand)
    return names


@dataclasses.dataclass
class Stats:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collectives: Dict[str, Dict[str, float]] = dataclasses.field(default_factory=dict)

    def add(self, other: "Stats", weight: float = 1.0):
        self.flops += other.flops * weight
        self.hbm_bytes += other.hbm_bytes * weight
        for kind, slot in other.collectives.items():
            dst = self.collectives.setdefault(
                kind, {"count": 0.0, "bytes": 0.0, "wire_bytes": 0.0,
                       "ici_bytes": 0.0, "dcn_bytes": 0.0}
            )
            for k, v in slot.items():
                dst[k] += v * weight

    def total_collective_wire_bytes(self) -> float:
        return sum(s["wire_bytes"] for s in self.collectives.values())


def _fusion_param_traffic(called: Computation) -> Dict[int, float]:
    """Per-parameter-read traffic inside a fusion.

    A fusion that only *slices* a parameter (scan bodies slicing stacked
    layer weights / caches) reads the slice, not the whole operand; counting
    the full operand per loop iteration overstates HBM traffic by the trip
    count.  Returns {param_index: bytes_read} for sliced params; params not
    in the map are read in full.
    """
    by_name: Dict[str, int] = {}
    for inst in called.instructions:
        if inst.opcode == "parameter":
            m = re.search(r"parameter\((\d+)\)", inst.body)
            if m:
                by_name[inst.name] = int(m.group(1))
    sliced: Dict[int, float] = {}
    full_use: Dict[int, bool] = {}
    for inst in called.instructions:
        ops = _operand_names(inst.body)
        for i, opn in enumerate(ops):
            if opn not in by_name:
                continue
            pidx = by_name[opn]
            if inst.opcode in ("dynamic-slice", "slice", "gather") and i == 0:
                sliced[pidx] = sliced.get(pidx, 0.0) + inst.out_bytes
            elif inst.opcode == "dynamic-update-slice" and i == 0:
                pass  # aliased in-place target: no read
            else:
                full_use[pidx] = True
    return {k: v for k, v in sliced.items() if not full_use.get(k)}


def _root_dus_update_bytes(called: Computation) -> Optional[float]:
    """If the fusion root is a dynamic-update-slice, written bytes = update."""
    root = called.instructions[-1] if called.instructions else None
    if root is None or root.opcode != "dynamic-update-slice":
        return None
    ops = _operand_names(root.body)
    if len(ops) >= 2:
        return float(_type_bytes(called.shapes.get(ops[1], "")))
    return None


def _local_stats(
    comp: Computation,
    pod_stride: Optional[int],
    comps: Optional[Dict[str, Computation]] = None,
) -> Tuple[Stats, List[Tuple[str, str, float]]]:
    """Stats of one computation, NOT including callees.

    Returns (stats, call edges [(kind, callee, weight)]).
    """
    comps = comps or {}
    st = Stats()
    edges: List[Tuple[str, str, float]] = []
    for inst in comp.instructions:
        op = inst.opcode
        if op == "dot":
            out_dtype, out_dims = _type_dims(inst.type_str)
            operands = _operand_names(inst.body)
            cdims = _CDIMS_RE.search(inst.body)
            csize = 1
            if operands and cdims is not None:
                lhs_type = comp.shapes.get(operands[0], "")
                _, lhs_dims = _type_dims(lhs_type)
                for d in cdims.group(1).split(","):
                    if d and int(d) < len(lhs_dims):
                        csize *= lhs_dims[int(d)]
            st.flops += 2.0 * float(np.prod(out_dims or [0])) * csize
        elif op == "convolution":
            # flops ~ 2 * out_elems * kernel_elems (per out channel contraction)
            out_dtype, out_dims = _type_dims(inst.type_str)
            wm = re.search(r"window=\{size=([\dx]+)", inst.body)
            kelems = 1
            if wm:
                for d in wm.group(1).split("x"):
                    kelems *= int(d)
            st.flops += 2.0 * float(np.prod(out_dims or [0])) * kelems

        if op in COLLECTIVE_OPS:
            nbytes = inst.out_bytes
            ids = _first_group_ids(inst.body)
            n = max(len(ids) if ids else 0, 2)
            crosses = bool(ids and pod_stride and (max(ids) - min(ids)) >= pod_stride)
            if op == "all-reduce":
                wire = 2.0 * (n - 1) / n * nbytes
            elif op == "all-gather":
                wire = (n - 1) / n * nbytes
            elif op == "reduce-scatter":
                wire = float(n - 1) * nbytes  # out is the scattered shard
            elif op == "all-to-all":
                wire = (n - 1) / n * nbytes
            else:  # collective-permute
                wire = float(nbytes)
            slot = st.collectives.setdefault(
                op, {"count": 0.0, "bytes": 0.0, "wire_bytes": 0.0,
                     "ici_bytes": 0.0, "dcn_bytes": 0.0}
            )
            slot["count"] += 1
            slot["bytes"] += nbytes
            slot["wire_bytes"] += wire
            slot["dcn_bytes" if crosses else "ici_bytes"] += wire

        if op in _TRAFFIC_OPS and op not in ("while", "conditional"):
            ops = _operand_names(inst.body)
            if op in ("dynamic-slice", "slice", "gather"):
                # reads only the slice; writes the slice
                traffic = 2.0 * inst.out_bytes
            elif op == "dynamic-update-slice":
                # in-place on the (aliased) target: read+write the update
                upd = _type_bytes(comp.shapes.get(ops[1], "")) if len(ops) > 1 else 0
                traffic = 2.0 * upd
            elif op == "fusion":
                cm = re.search(r"calls=%?([\w\.\-]+)", inst.body)
                called = comps.get(cm.group(1)) if cm else None
                out_b: float = inst.out_bytes
                per_param: Dict[int, float] = {}
                if called is not None:
                    per_param = _fusion_param_traffic(called)
                    dus = _root_dus_update_bytes(called)
                    if dus is not None:
                        out_b = dus
                traffic = out_b
                for i, opn in enumerate(ops):
                    if i in per_param:
                        traffic += per_param[i]
                    else:
                        traffic += _type_bytes(comp.shapes.get(opn, ""))
            else:
                traffic = inst.out_bytes
                for opn in ops:
                    traffic += _type_bytes(comp.shapes.get(opn, ""))
            st.hbm_bytes += traffic

        if op == "while":
            body = re.search(r"body=%?([\w\.\-]+)", inst.body)
            cond = re.search(r"condition=%?([\w\.\-]+)", inst.body)
            tm = _TRIP_RE.search(inst.body)
            trip = float(tm.group(1)) if tm else math.nan
            if body:
                edges.append(("while_body", body.group(1), trip))
            if cond:
                edges.append(("while_cond", cond.group(1), trip))
        elif op == "conditional":
            bm = _BRANCHES_RE.search(inst.body)
            if bm:
                for b in bm.group(1).split(","):
                    edges.append(("branch", b.strip().lstrip("%"), 1.0))
            else:
                for key in ("true_computation", "false_computation"):
                    m2 = re.search(key + r"=%?([\w\.\-]+)", inst.body)
                    if m2:
                        edges.append(("branch", m2.group(1), 1.0))
        elif op in ("fusion", "call", "custom-call", "async-start"):
            cm = re.search(r"(?:calls|to_apply)=%?([\w\.\-]+)", inst.body)
            if cm:
                edges.append(("call", cm.group(1), 1.0))
    return st, edges


def _cond_trip_fallback(comp: Computation) -> float:
    """Largest s32 constant in the condition computation (scan bound)."""
    best = 1.0
    for inst in comp.instructions:
        m = re.search(r"constant\((\d+)\)", inst.body)
        if m and inst.type_str.strip().startswith("s32"):
            best = max(best, float(m.group(1)))
    return best


def analyze(hlo: str, pod_stride: Optional[int] = None, entry: Optional[str] = None) -> Stats:
    comps = parse_computations(hlo)
    if not comps:
        return Stats()
    # entry = the computation named in "ENTRY %name" line
    if entry is None:
        m = re.search(r"^ENTRY\s+%?([\w\.\-]+)", hlo, re.M)
        entry = m.group(1) if m else next(iter(comps))

    memo: Dict[str, Stats] = {}

    def total(name: str, depth: int = 0) -> Stats:
        if name in memo:
            return memo[name]
        comp = comps.get(name)
        out = Stats()
        if comp is None or depth > 64:
            return out
        local, edges = _local_stats(comp, pod_stride, comps)
        out.add(local)
        for kind, callee, weight in edges:
            if kind in ("while_body", "while_cond"):
                w = weight
                if math.isnan(w):
                    # fall back to the constant bound in the condition
                    cond_name = next(
                        (c for k, c, _ in edges if k == "while_cond"), None
                    )
                    w = _cond_trip_fallback(comps[cond_name]) if cond_name in comps else 1.0
                out.add(total(callee, depth + 1), w)
            else:
                # fusion/call boundary: HBM traffic is accounted at the call
                # site (operands+outputs); inner slice/DUS ops are fused and
                # must not double-count -- keep only flops/collectives.
                sub = total(callee, depth + 1)
                inner = Stats(flops=sub.flops, hbm_bytes=0.0,
                              collectives={k: dict(v) for k, v in sub.collectives.items()})
                out.add(inner, 1.0)
        memo[name] = out
        return out

    return total(entry)


def stats_to_dict(st: Stats) -> Dict:
    return {
        "flops": st.flops,
        "hbm_bytes": st.hbm_bytes,
        "collective_wire_bytes": st.total_collective_wire_bytes(),
        "collectives": st.collectives,
    }
