"""Mamba-2 (SSD -- state-space duality) blocks, pure JAX.

Training/prefill uses the chunked SSD algorithm: the sequence is split into
chunks of ``Q`` steps; within a chunk the recurrence is evaluated in its
quadratic "attention-like" dual form (per-head Q x Q decay-masked scores),
and chunk-boundary states are propagated with a first-order scan.  This is
the TPU-friendly formulation: all chunk-local work is dense matmul (MXU
food), the sequential dependency collapses to S/Q scan steps, and the per
-step working set (B, Q, Q, nh) stays small and VMEM-tileable.

Decode is the O(1) recurrent update on the cached state.

Model layout follows mamba2-2.7b: d_inner = 2*d_model, scalar-per-head A,
shared B/C across heads (n_groups=1), causal conv (k=4), gated RMSNorm
before out_proj.

Sharding note: the projections are stored *separately* (w_z/w_x column-
parallel over the TP axis, w_bc/conv_bc replicated -- B/C are shared across
heads so every shard needs them in full, and they are tiny) so that the
jnp.split boundaries of a fused in_proj never cut across shard tiles.
Heads (and the per-head A/dt/D vectors) shard with the d_inner columns.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from ..distributed.axes import shard
from .common import dense_init
from .layers import rms_norm


class SSMDims(NamedTuple):
    d_model: int
    d_inner: int
    n_heads: int
    headdim: int
    d_state: int
    d_conv: int
    chunk: int

    @staticmethod
    def from_config(cfg) -> "SSMDims":
        d_inner = cfg.ssm_expand * cfg.d_model
        return SSMDims(
            d_model=cfg.d_model,
            d_inner=d_inner,
            n_heads=d_inner // cfg.ssm_headdim,
            headdim=cfg.ssm_headdim,
            d_state=cfg.ssm_state,
            d_conv=cfg.ssm_conv,
            chunk=cfg.ssm_chunk,
        )


def init_ssm_layer(key, dims: SSMDims, dtype):
    ks = jax.random.split(key, 8)
    # dt bias initialized so softplus(dt_bias) spans ~[1e-3, 1e-1] (mamba init)
    u = jax.random.uniform(ks[0], (dims.n_heads,), minval=math.log(1e-3), maxval=math.log(1e-1))
    dt_init = jnp.log(jnp.expm1(jnp.exp(u)))  # inverse softplus
    return {
        "w_z": dense_init(ks[1], (dims.d_model, dims.d_inner), dims.d_model, dtype),
        "w_x": dense_init(ks[2], (dims.d_model, dims.d_inner), dims.d_model, dtype),
        "w_bc": dense_init(ks[3], (dims.d_model, 2 * dims.d_state), dims.d_model, dtype),
        "w_dt": dense_init(ks[4], (dims.d_model, dims.n_heads), dims.d_model, dtype),
        "conv_x": dense_init(ks[5], (dims.d_conv, dims.d_inner), dims.d_conv, dtype),
        "conv_x_b": jnp.zeros((dims.d_inner,), dtype),
        "conv_bc": dense_init(ks[6], (dims.d_conv, 2 * dims.d_state), dims.d_conv, dtype),
        "conv_bc_b": jnp.zeros((2 * dims.d_state,), dtype),
        "A_log": jnp.log(
            jax.random.uniform(ks[7], (dims.n_heads,), minval=1.0, maxval=16.0)
        ).astype(jnp.float32),
        "dt_bias": dt_init.astype(jnp.float32),
        "D": jnp.ones((dims.n_heads,), jnp.float32),
        "norm_w": jnp.ones((dims.d_inner,), dtype),
        "out_proj": dense_init(ks[0], (dims.d_inner, dims.d_model), dims.d_inner, dtype),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array, tail: jax.Array | None = None):
    """Depthwise causal conv.  x: (B,S,C); w: (K,C); tail: (B,K-1,C) history."""
    k = w.shape[0]
    if tail is None:
        tail = jnp.zeros((x.shape[0], k - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([tail, x], axis=1)  # (B, S+K-1, C)
    out = sum(xp[:, i : i + x.shape[1]] * w[i] for i in range(k)) + b
    new_tail = xp[:, -(k - 1) :] if k > 1 else tail
    return jax.nn.silu(out), new_tail


def ssd_chunked(
    x: jax.Array,  # (B,S,nh,hp)
    dt: jax.Array,  # (B,S,nh) post-softplus, fp32
    a_neg: jax.Array,  # (nh,) negative A, fp32
    bmat: jax.Array,  # (B,S,N)
    cmat: jax.Array,  # (B,S,N)
    d_skip: jax.Array,  # (nh,)
    chunk: int,
    h0: jax.Array | None = None,  # (B,nh,N,hp) initial state
) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan.  Returns (y (B,S,nh,hp), final state (B,nh,N,hp))."""
    b, s, nh, hp = x.shape
    n = bmat.shape[-1]
    q = min(chunk, s)
    pad = (-s) % q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))
    nc = (s + pad) // q

    xc = jnp.moveaxis(x.reshape(b, nc, q, nh, hp), 1, 0).astype(jnp.float32)
    dtc = jnp.moveaxis(dt.reshape(b, nc, q, nh), 1, 0)
    bc = jnp.moveaxis(bmat.reshape(b, nc, q, n), 1, 0).astype(jnp.float32)
    cc = jnp.moveaxis(cmat.reshape(b, nc, q, n), 1, 0).astype(jnp.float32)
    xc = shard(xc, None, "batch", None, "model", None)
    dtc = shard(dtc, None, "batch", None, "model")

    h_init = (
        jnp.zeros((b, nh, n, hp), jnp.float32)
        if h0 is None
        else h0.astype(jnp.float32)
    )
    h_init = shard(h_init, "batch", "model", None, None)
    tri = jnp.tril(jnp.ones((q, q), jnp.bool_))  # i >= j

    def body(h, blk):
        xq, dtq, bq, cq = blk  # (B,Q,nh,hp), (B,Q,nh), (B,Q,N), (B,Q,N)
        a = dtq * a_neg  # (B,Q,nh) log-decay per step (negative)
        cum = jnp.cumsum(a, axis=1)  # inclusive
        # intra-chunk dual form
        cb = jnp.einsum("bqn,bkn->bqk", cq, bq)  # (B,Q,Q)
        decay = jnp.exp(cum[:, :, None, :] - cum[:, None, :, :])  # (B,Q,Q,nh): i,j
        decay = jnp.where(tri[None, :, :, None], decay, 0.0)
        dtx = dtq[..., None] * xq  # (B,Q,nh,hp)
        y = jnp.einsum("bqk,bqkh,bkhp->bqhp", cb, decay, dtx)
        # inter-chunk contribution from carried state
        y = y + jnp.einsum("bqn,bhnp->bqhp", cq, h) * jnp.exp(cum)[..., None]
        # state update for next chunk: S_c = sum_j exp(cum_Q - cum_j) dt_j B_j x_j^T
        w = jnp.exp(cum[:, -1:, :] - cum) * dtq  # (B,Q,nh)
        s_new = jnp.einsum("bqn,bqh,bqhp->bhnp", bq, w, xq)
        h_new = jnp.exp(cum[:, -1])[:, :, None, None] * h + s_new
        h_new = shard(h_new, "batch", "model", None, None)
        y = y + d_skip[None, None, :, None] * xq
        return h_new, shard(y, "batch", None, "model", None)

    h_final, yc = jax.lax.scan(body, h_init, (xc, dtc, bc, cc))
    y = jnp.moveaxis(yc, 0, 1).reshape(b, nc * q, nh, hp)[:, :s]
    return y, h_final


def ssd_reference(x, dt, a_neg, bmat, cmat, d_skip, h0=None):
    """Naive sequential recurrence oracle."""
    b, s, nh, hp = x.shape
    n = bmat.shape[-1]
    h = jnp.zeros((b, nh, n, hp), jnp.float32) if h0 is None else h0.astype(jnp.float32)
    ys = []
    for t in range(s):
        a_t = jnp.exp(dt[:, t] * a_neg)  # (B,nh)
        upd = jnp.einsum("bn,bh,bhp->bhnp", bmat[:, t], dt[:, t], x[:, t].astype(jnp.float32))
        h = a_t[:, :, None, None] * h + upd
        y = jnp.einsum("bn,bhnp->bhp", cmat[:, t], h) + d_skip[None, :, None] * x[:, t]
        ys.append(y)
    return jnp.stack(ys, axis=1), h


def _project(params, dims: SSMDims, x_in: jax.Array):
    z = shard(x_in @ params["w_z"], "batch", None, "model")
    xr = shard(x_in @ params["w_x"], "batch", None, "model")
    bcmat = x_in @ params["w_bc"]  # shared across heads: replicated over model
    dt_raw = shard(x_in @ params["w_dt"], "batch", None, "model")
    return z, xr, bcmat, dt_raw


def ssm_layer_apply(
    params,
    dims: SSMDims,
    x_in: jax.Array,  # (B,S,d_model)
    conv_tail_x: jax.Array | None = None,
    conv_tail_bc: jax.Array | None = None,
    h0: jax.Array | None = None,
    return_state: bool = False,
):
    """Full mamba2 mixer.  Returns y (B,S,d) [+ (tails, h) if requested]."""
    z, xr, bcmat, dt_raw = _project(params, dims, x_in)
    xr, new_tail_x = _causal_conv(xr, params["conv_x"], params["conv_x_b"], conv_tail_x)
    bcmat, new_tail_bc = _causal_conv(
        bcmat, params["conv_bc"], params["conv_bc_b"], conv_tail_bc
    )
    bmat, cmat = jnp.split(bcmat, 2, axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    a_neg = -jnp.exp(params["A_log"])
    xh = xr.reshape(*xr.shape[:-1], dims.n_heads, dims.headdim)
    y, h = ssd_chunked(xh, dt, a_neg, bmat, cmat, params["D"], dims.chunk, h0)
    y = y.reshape(*y.shape[:-2], dims.d_inner).astype(x_in.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["norm_w"])
    out = y @ params["out_proj"]
    if return_state:
        return out, (new_tail_x, new_tail_bc, h)
    return out


def ssm_decode_step(
    params,
    dims: SSMDims,
    x_in: jax.Array,  # (B,1,d)
    conv_tail_x: jax.Array,
    conv_tail_bc: jax.Array,
    h: jax.Array,
):
    """Single-token update.  Returns (y (B,1,d), new tails, new_h)."""
    z, xr, bcmat, dt_raw = _project(params, dims, x_in)
    xr, new_tail_x = _causal_conv(xr, params["conv_x"], params["conv_x_b"], conv_tail_x)
    bcmat, new_tail_bc = _causal_conv(
        bcmat, params["conv_bc"], params["conv_bc_b"], conv_tail_bc
    )
    bmat, cmat = jnp.split(bcmat, 2, axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])[:, 0]  # (B,nh)
    a_neg = -jnp.exp(params["A_log"])
    xh = xr[:, 0].reshape(x_in.shape[0], dims.n_heads, dims.headdim).astype(jnp.float32)
    a_t = jnp.exp(dt * a_neg)  # (B,nh)
    upd = jnp.einsum("bn,bh,bhp->bhnp", bmat[:, 0].astype(jnp.float32), dt, xh)
    h = a_t[:, :, None, None] * h + upd
    y = jnp.einsum("bn,bhnp->bhp", cmat[:, 0].astype(jnp.float32), h)
    y = y + params["D"][None, :, None] * xh
    y = y.reshape(x_in.shape[0], 1, dims.d_inner).astype(x_in.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["norm_w"])
    return y @ params["out_proj"], new_tail_x, new_tail_bc, h
