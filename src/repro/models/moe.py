"""Mixture-of-Experts FFN with top-k routing and sort/gather dispatch.

Dispatch strategy (TPU-native, no giant one-hot tensors): assignments are
sorted *per batch row* (so the sort never crosses the data-parallel sharding
boundary), ranked within their expert, capacity-dropped, and gathered into a
dense (E, C, d) block per row which the expert matmuls consume as a batched
einsum.  Experts shard over the "model" mesh axis (expert parallelism); the
combine scatter-add runs per row and the cross-expert sum resolves to the
same psum pattern as a TP FFN.

Covers dbrx (16e top-4) and qwen3-moe (128e top-8).  The decode path (S=1
per row) uses the identical code: C collapses to max(1, ceil(k/E * cf)).
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from ..distributed.axes import shard
from .common import dense_init


def init_moe(key, d_model: int, d_ff: int, n_experts: int, dtype):
    ks = jax.random.split(key, 4)
    return {
        "router": dense_init(ks[0], (d_model, n_experts), d_model, jnp.float32),
        "w_gate": dense_init(ks[1], (n_experts, d_model, d_ff), d_model, dtype),
        "w_up": dense_init(ks[2], (n_experts, d_model, d_ff), d_model, dtype),
        "w_down": dense_init(ks[3], (n_experts, d_ff, d_model), d_ff, dtype),
    }


def _route(logits: jax.Array, k: int) -> Tuple[jax.Array, jax.Array]:
    """Top-k expert ids + renormalized weights (qwen3/dbrx convention)."""
    top_logits, top_idx = jax.lax.top_k(logits, k)  # (..., k)
    weights = jax.nn.softmax(top_logits.astype(jnp.float32), axis=-1)
    return top_idx, weights


def _dispatch_row(x, expert_ids, weights, n_experts: int, capacity: int):
    """One batch row.  x: (S,d); expert_ids/weights: (S,k).

    Returns gathered expert inputs (E, C, d) and the combine metadata.
    """
    s, k = expert_ids.shape
    flat_e = expert_ids.reshape(-1)  # (S*k,)
    order = jnp.argsort(flat_e, stable=True)  # token-priority within expert
    sorted_e = flat_e[order]
    token_of = order // k  # source token per sorted assignment
    # rank within expert = position - start offset of that expert's run
    counts = jnp.bincount(flat_e, length=n_experts)
    starts = jnp.cumsum(counts) - counts  # exclusive prefix
    rank = jnp.arange(s * k) - starts[sorted_e]
    keep = rank < capacity
    slot = jnp.where(keep, sorted_e * capacity + rank, n_experts * capacity)
    xg = jnp.zeros((n_experts * capacity + 1, x.shape[-1]), x.dtype)
    xg = xg.at[slot].set(x[token_of], mode="drop")
    return xg[:-1], (token_of, slot, order, keep)


def _combine_row(y_flat, meta, weights, s: int, d: int):
    """y_flat: (E*C, d) expert outputs; scatter-add back to (S, d)."""
    token_of, slot, order, keep = meta
    w = weights.reshape(-1)[order].astype(y_flat.dtype)  # align with sorted order
    y_rows = y_flat[jnp.minimum(slot, y_flat.shape[0] - 1)]
    y_rows = y_rows * (w * keep.astype(y_flat.dtype))[:, None]
    out = jnp.zeros((s, d), y_flat.dtype)
    return out.at[token_of].add(y_rows)


def moe_ffn(
    params,
    x: jax.Array,
    n_experts_per_tok: int,
    capacity_factor: float = 1.25,
    act: str = "silu",
) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (y, aux_loss).  Routing/aux math in fp32."""
    b, s, d = x.shape
    e = params["router"].shape[-1]
    k = n_experts_per_tok
    capacity = max(1, math.ceil(k * s / e * capacity_factor))

    logits = (x.astype(jnp.float32) @ params["router"]).astype(jnp.float32)  # (B,S,E)
    expert_ids, weights = _route(logits, k)

    # load-balancing aux loss (Switch-style): E * sum_i f_i * P_i
    probs = jax.nn.softmax(logits, axis=-1)
    f = jnp.mean(
        jnp.sum(jax.nn.one_hot(expert_ids, e, dtype=jnp.float32), axis=-2), axis=(0, 1)
    ) / k  # fraction routed per expert
    p_mean = probs.mean(axis=(0, 1))
    aux = e * jnp.sum(f * p_mean)

    xg, meta = jax.vmap(
        lambda xr, er, wr: _dispatch_row(xr, er, wr, e, capacity)
    )(x, expert_ids, weights)
    # expert parallelism: gathered blocks shard E over the model axis
    xg = shard(xg.reshape(b, e, capacity, d), "batch", "model", None, None)

    act_fn = jax.nn.silu if act == "silu" else jax.nn.gelu
    g = jnp.einsum("becd,edf->becf", xg, params["w_gate"])
    u = jnp.einsum("becd,edf->becf", xg, params["w_up"])
    y = jnp.einsum("becf,efd->becd", act_fn(g) * u, params["w_down"])
    y = shard(y, "batch", "model", None, None)
    y_flat = y.reshape(b, e * capacity, d)

    out = jax.vmap(lambda yr, mr, wr: _combine_row(yr, mr, wr, s, d))(y_flat, meta, weights)
    return shard(out, "batch", "residual", None).astype(x.dtype), aux


def moe_ffn_reference(params, x, n_experts_per_tok: int, act: str = "silu"):
    """Oracle: per-token dense loop over all experts (no capacity drop)."""
    b, s, d = x.shape
    e = params["router"].shape[-1]
    logits = x.astype(jnp.float32) @ params["router"]
    expert_ids, weights = _route(logits, n_experts_per_tok)
    act_fn = jax.nn.silu if act == "silu" else jax.nn.gelu
    # compute every expert on every token (test sizes only)
    g = jnp.einsum("bsd,edf->besf", x, params["w_gate"])
    u = jnp.einsum("bsd,edf->besf", x, params["w_up"])
    y_all = jnp.einsum("besf,efd->besd", act_fn(g) * u, params["w_down"])  # (B,E,S,d)
    onehot = jax.nn.one_hot(expert_ids, e, dtype=jnp.float32)  # (B,S,k,E)
    w = jnp.einsum("bske,bsk->bse", onehot, weights)  # per-expert combine weight
    return jnp.einsum("besd,bse->bsd", y_all, w.astype(x.dtype)).astype(x.dtype)
