"""Core layers: norms, RoPE / M-RoPE, GQA flash-style attention, gated MLPs.

Attention is implemented blockwise over the KV axis (the flash-attention
recurrence in pure jnp with fp32 running max/sum).  This keeps 32k-sequence
prefill at O(S * block) memory instead of O(S^2) and is what the dry-run
lowers; the Pallas kernel in repro.kernels implements the same contract for
real TPU execution and is validated against `attention_reference`.
"""
from __future__ import annotations

import functools
import math
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from ..distributed.axes import shard
from .common import dense_init

NEG_INF = float(jnp.finfo(jnp.float32).min / 2)


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------


def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-6, plus_one: bool = False):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    w = weight.astype(jnp.float32)
    if plus_one:  # gemma convention: scale = (1 + w)
        w = 1.0 + w
    return (x * w).astype(dtype)


def layer_norm(x: jax.Array, weight: jax.Array, bias: jax.Array, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = x.mean(axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dtype)


# --------------------------------------------------------------------------
# rotary embeddings
# --------------------------------------------------------------------------


def _rope_inv_freq(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)


def _rotate_half(x):
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([-x2, x1], axis=-1)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """Llama-style rotary embedding.  x: (B,S,H,hd); positions: (B,S) int."""
    hd = x.shape[-1]
    inv = _rope_inv_freq(hd, theta)  # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * inv  # (B,S,hd/2)
    cos = jnp.concatenate([jnp.cos(ang)] * 2, axis=-1)[..., None, :]  # (B,S,1,hd)
    sin = jnp.concatenate([jnp.sin(ang)] * 2, axis=-1)[..., None, :]
    xf = x.astype(jnp.float32)
    return (xf * cos + _rotate_half(xf) * sin).astype(x.dtype)


def apply_mrope(
    x: jax.Array,
    positions: jax.Array,
    sections: Sequence[int],
    theta: float = 10000.0,
) -> jax.Array:
    """Qwen2-VL multimodal RoPE.  positions: (B,S,3) = (temporal,h,w) ids.

    The hd/2 frequency slots are partitioned into `sections` (t,h,w); each slot
    rotates by its own position stream.  Text tokens have t==h==w so M-RoPE
    degenerates to 1-D RoPE there (the paper's property).
    """
    hd = x.shape[-1]
    assert sum(sections) == hd // 2, (sections, hd)
    inv = _rope_inv_freq(hd, theta)
    sec_ids = jnp.repeat(
        jnp.arange(len(sections)), jnp.asarray(sections), total_repeat_length=hd // 2
    )  # (hd/2,) in {0,1,2}
    pos_sel = jnp.take_along_axis(
        positions.astype(jnp.float32), sec_ids[None, None, :], axis=-1
    )  # (B,S,hd/2): position stream per freq slot
    ang = pos_sel * inv
    cos = jnp.concatenate([jnp.cos(ang)] * 2, axis=-1)[..., None, :]
    sin = jnp.concatenate([jnp.sin(ang)] * 2, axis=-1)[..., None, :]
    xf = x.astype(jnp.float32)
    return (xf * cos + _rotate_half(xf) * sin).astype(x.dtype)


# --------------------------------------------------------------------------
# attention (blockwise flash recurrence, GQA grouped, causal/window masks)
# --------------------------------------------------------------------------


def attention_reference(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    q_positions: jax.Array,
    kv_positions: jax.Array,
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
) -> jax.Array:
    """Naive O(S^2)-memory oracle.  q:(B,Sq,H,hd) k/v:(B,Sk,K,hd)."""
    b, sq, h, hd = q.shape
    kh = k.shape[2]
    g = h // kh
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    qg = q.reshape(b, sq, kh, g, hd)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32), k.astype(jnp.float32)) * scale
    mask = kv_positions[:, None, :] >= 0  # (B,1,Sk): valid slots
    if causal:
        mask &= kv_positions[:, None, :] <= q_positions[:, :, None]
    if window is not None:
        mask &= q_positions[:, :, None] - kv_positions[:, None, :] < window
    s = jnp.where(mask[:, None, None, :, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return o.reshape(b, sq, h, hd).astype(q.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "block_k", "scale")
)
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    q_positions: jax.Array,
    kv_positions: jax.Array,
    causal: bool = True,
    window: Optional[int] = None,
    block_k: int = 1024,
    scale: Optional[float] = None,
) -> jax.Array:
    """Blockwise-KV attention with fp32 flash recurrence.

    Shapes: q (B,Sq,H,hd), k/v (B,Sk,K,hd) with H % K == 0 (GQA grouped --
    KV is never materialized repeated).  ``kv_positions < 0`` marks invalid
    (unwritten cache) slots.  Works for training (Sq == Sk), prefill and
    single-token decode (Sq == 1, Sk == cache length).
    """
    b, sq, h, hd = q.shape
    sk, kh = k.shape[1], k.shape[2]
    g = h // kh
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    qg = q.reshape(b, sq, kh, g, hd)

    bk = min(block_k, sk)
    pad = (-sk) % bk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_positions = jnp.pad(kv_positions, ((0, 0), (0, pad)), constant_values=-1)
    nb = (sk + pad) // bk
    # blocks are dynamic-sliced inside the scan body: pre-transposing KV into
    # (nb, B, bk, ...) xs copies the whole cache per step (EXPERIMENTS §Perf)
    k = shard(k, "batch", None, "model", None)
    v = shard(v, "batch", None, "model", None)

    # explicit carry shardings: scan-carry propagation from zeros-inits is
    # what otherwise replicates attention over the model axis
    m0 = shard(jnp.full((b, kh, g, sq), NEG_INF, dtype=jnp.float32),
               "batch", "model", None, None)
    l0 = shard(jnp.zeros((b, kh, g, sq), dtype=jnp.float32),
               "batch", "model", None, None)
    o0 = shard(jnp.zeros((b, kh, g, sq, hd), dtype=jnp.float32),
               "batch", "model", None, None, None)

    def body(carry, i):
        m, lsum, o = carry
        kblk = jax.lax.dynamic_slice_in_dim(k, i * bk, bk, axis=1)
        vblk = jax.lax.dynamic_slice_in_dim(v, i * bk, bk, axis=1)
        posblk = jax.lax.dynamic_slice_in_dim(kv_positions, i * bk, bk, axis=1)
        s = (
            jnp.einsum(
                "bqkgd,bskd->bkgqs", qg, kblk, preferred_element_type=jnp.float32
            )
            * scale
        )  # (B,K,G,Sq,bk)
        mask = posblk[:, None, :] >= 0
        if causal:
            mask = mask & (posblk[:, None, :] <= q_positions[:, :, None])
        if window is not None:
            mask = mask & (q_positions[:, :, None] - posblk[:, None, :] < window)
        s = jnp.where(mask[:, None, None, :, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = lsum * alpha + p.sum(axis=-1)
        pv = jnp.einsum("bkgqs,bskd->bkgqd", p, vblk, preferred_element_type=jnp.float32)
        o_new = o * alpha[..., None] + pv
        m_new = shard(m_new, "batch", "model", None, None)
        l_new = shard(l_new, "batch", "model", None, None)
        o_new = shard(o_new, "batch", "model", None, None, None)
        return (m_new, l_new, o_new), None

    (m, lsum, o), _ = jax.lax.scan(body, (m0, l0, o0), jnp.arange(nb))
    o = o / jnp.maximum(lsum[..., None], 1e-30)
    o = jnp.moveaxis(o, 3, 1).reshape(b, sq, h, hd)  # (B,K,G,Sq,hd)->(B,Sq,H,hd)
    return o.astype(q.dtype)


# --------------------------------------------------------------------------
# MLPs
# --------------------------------------------------------------------------


def init_gated_mlp(key, d_model: int, d_ff: int, dtype):
    ks = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(ks[0], (d_model, d_ff), d_model, dtype),
        "w_up": dense_init(ks[1], (d_model, d_ff), d_model, dtype),
        "w_down": dense_init(ks[2], (d_ff, d_model), d_ff, dtype),
    }


def gated_mlp(params, x, act: str = "silu"):
    """SwiGLU (silu) / GeGLU (gelu) feed-forward."""
    fn = jax.nn.silu if act == "silu" else functools.partial(jax.nn.gelu, approximate=True)
    g = fn(shard(x @ params["w_gate"], "batch", None, "model"))
    u = shard(x @ params["w_up"], "batch", None, "model")
    return shard((g * u) @ params["w_down"], "batch", "residual", None)


def init_mlp(key, d_model: int, d_ff: int, dtype, bias: bool = True):
    ks = jax.random.split(key, 2)
    p = {
        "w_in": dense_init(ks[0], (d_model, d_ff), d_model, dtype),
        "w_out": dense_init(ks[1], (d_ff, d_model), d_ff, dtype),
    }
    if bias:
        p["b_in"] = jnp.zeros((d_ff,), dtype)
        p["b_out"] = jnp.zeros((d_model,), dtype)
    return p


def mlp(params, x, act: str = "gelu"):
    fn = functools.partial(jax.nn.gelu, approximate=True) if act == "gelu" else jax.nn.relu
    h = shard(x @ params["w_in"], "batch", None, "model")
    if "b_in" in params:
        h = h + params["b_in"]
    h = fn(h)
    y = h @ params["w_out"]
    if "b_out" in params:
        y = y + params["b_out"]
    return shard(y, "batch", "residual", None)
