"""Decoder / encoder / MoE / VLM transformer with scan-over-layers.

Tensor-parallel head layout
---------------------------
To shard attention over a TP axis of size ``pad_heads_to`` we use the
standard TP-GQA construction: KV heads are *repeated* ``R = K_pad/K`` times
(exact semantics, redundant storage -- the repeated copies shard over the
axis), and query heads are laid out kv-copy-major with per-copy group size
``G_pad = ceil(G/R)``; slots beyond the true head count are masked to zero so
the math is bit-identical to the unpadded model.  ``HeadLayout`` centralizes
this.  With ``pad_heads_to=0`` (smoke tests) everything degenerates to plain
GQA.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.ad_checkpoint
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..distributed.axes import shard
from ..distributed.compat import shard_map
from .common import cast_for_compute, cross_entropy_loss, dense_init
from .layers import (
    apply_mrope,
    apply_rope,
    flash_attention,
    gated_mlp,
    init_gated_mlp,
    init_mlp,
    layer_norm,
    mlp,
    rms_norm,
)
from .moe import init_moe, moe_ffn

Params = Dict[str, Any]


# --------------------------------------------------------------------------
# head layout for TP sharding
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class HeadLayout:
    n_heads: int  # true H
    n_kv: int  # true K
    repeat: int  # R: kv repetition factor
    g_pad: int  # query slots per repeated kv head
    h_pad: int  # K_pad * g_pad total query slots

    @property
    def k_pad(self) -> int:
        return self.n_kv * self.repeat

    @staticmethod
    def make(n_heads: int, n_kv: int, pad_to: int = 0) -> "HeadLayout":
        g = n_heads // n_kv
        if pad_to <= 0:
            return HeadLayout(n_heads, n_kv, 1, g, n_heads)
        # repeat kv so K_pad = lcm(K, pad_to) is shardable over the TP axis
        r = math.lcm(n_kv, pad_to) // n_kv
        k_pad = n_kv * r
        g_pad = math.ceil(g / r)
        # ensure total query slots divisible by pad_to
        while (k_pad * g_pad) % pad_to:
            g_pad += 1
        return HeadLayout(n_heads, n_kv, r, g_pad, k_pad * g_pad)

    def head_mask(self) -> jax.Array:
        """(H_pad,) float mask: 1 for real query slots, 0 for padding.

        Slot h = (t*R + c) * G_pad + g is real iff c*G_pad + g < G (true group
        size) -- q heads of true kv t are packed across its R copies.
        """
        g_true = self.n_heads // self.n_kv
        idx = jnp.arange(self.h_pad)
        kc = idx // self.g_pad  # repeated-kv index
        g = idx % self.g_pad
        c = kc % self.repeat
        return (c * self.g_pad + g < g_true).astype(jnp.float32)


def repeat_kv(x: jax.Array, r: int) -> jax.Array:
    """(B,S,K,hd) -> (B,S,K*r,hd) with contiguous copies per true head."""
    if r == 1:
        return x
    return jnp.repeat(x, r, axis=2)


# --------------------------------------------------------------------------
# attention layer
# --------------------------------------------------------------------------


def init_attention(key, cfg: ArchConfig, layout: HeadLayout, dtype):
    d, hd = cfg.d_model, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, layout.h_pad * hd), d, dtype),
        "wk": dense_init(ks[1], (d, layout.n_kv * hd), d, dtype),
        "wv": dense_init(ks[2], (d, layout.n_kv * hd), d, dtype),
        "wo": dense_init(ks[3], (layout.h_pad * hd, d), layout.n_heads * hd, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((layout.h_pad * hd,), dtype)
        p["bk"] = jnp.zeros((layout.n_kv * hd,), dtype)
        p["bv"] = jnp.zeros((layout.n_kv * hd,), dtype)
    return p


def attention_apply(
    p: Params,
    cfg: ArchConfig,
    layout: HeadLayout,
    x: jax.Array,  # (B,S,d)
    positions: jax.Array,  # (B,S) int32
    mrope_positions: Optional[jax.Array] = None,  # (B,S,3) for vlm
    cache: Optional[Params] = None,  # {"k","v": (B,W,K_pad,hd), "pos": (W,)}
    window: Optional[int] = None,
) -> Tuple[jax.Array, Optional[Params]]:
    b, s, _ = x.shape
    hd = cfg.head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = shard(q.reshape(b, s, layout.h_pad, hd), "batch", None, "model", None)
    k = k.reshape(b, s, layout.n_kv, hd)
    v = v.reshape(b, s, layout.n_kv, hd)
    if mrope_positions is not None:
        q = apply_mrope(q, mrope_positions, cfg.mrope_sections, cfg.rope_theta)
        k = apply_mrope(k, mrope_positions, cfg.mrope_sections, cfg.rope_theta)
    else:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    new_cache = None
    if cache is not None and "ks" in cache:
        # sequence-sharded TRUE-KV cache mode (no xR head repetition)
        if s == 1:  # decode: shard_map partial-softmax combine
            t = positions[0, 0]
            o, new_cache = _seq_sharded_decode(cfg, layout, q, k, v, cache, t)
            if layout.h_pad != layout.n_heads:
                o = o * layout.head_mask()[None, None, :, None].astype(o.dtype)
            out = o.reshape(b, s, layout.h_pad * hd) @ p["wo"]
            return shard(out, "batch", "residual", None), new_cache
        # prefill: write the true-KV ring; attend over the activations (the
        # empty-cache contents are exactly k/v, so this is equivalent)
        w = cache["ks"].shape[1]
        keep = min(s, w)
        pos_tail = positions[0, s - keep :]
        slots = pos_tail % w
        new_cache = {
            "ks": cache["ks"].at[:, slots].set(k[:, s - keep :]),
            "vs": cache["vs"].at[:, slots].set(v[:, s - keep :]),
            "poss": cache["poss"].at[slots].set(pos_tail.astype(jnp.int32)),
        }
        o = flash_attention(
            q, k, v, positions, positions,
            causal=cfg.is_causal, window=window, block_k=cfg.attn_block_k,
        )
        if layout.h_pad != layout.n_heads:
            o = o * layout.head_mask()[None, None, :, None].astype(o.dtype)
        out = o.reshape(b, s, layout.h_pad * hd) @ p["wo"]
        return shard(out, "batch", "residual", None), new_cache

    k = shard(repeat_kv(k, layout.repeat), "batch", None, "model", None)
    v = shard(repeat_kv(v, layout.repeat), "batch", None, "model", None)

    if cache is not None:
        w = cache["k"].shape[1]
        if s == 1:  # decode: ring-buffer write at t % W
            t = positions[0, 0]
            slot = t % w
            ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot, axis=1)
            cpos = jax.lax.dynamic_update_slice_in_dim(
                cache["pos"], t[None].astype(jnp.int32), slot, axis=0
            )
        else:  # prefill: write the last W positions (slots form a permutation)
            keep = min(s, w)
            src_k, src_v = k[:, s - keep :], v[:, s - keep :]
            pos_tail = positions[0, s - keep :]
            slots = pos_tail % w
            ck = cache["k"].at[:, slots].set(src_k)
            cv = cache["v"].at[:, slots].set(src_v)
            cpos = cache["pos"].at[slots].set(pos_tail.astype(jnp.int32))
        new_cache = {"k": ck, "v": cv, "pos": cpos}
        k_att, v_att = ck, cv
        kv_pos = jnp.broadcast_to(cpos[None, :], (b, w))
    else:
        k_att, v_att = k, v
        kv_pos = positions

    o = flash_attention(
        q,
        k_att,
        v_att,
        positions,
        kv_pos,
        causal=cfg.is_causal,
        window=window,
        block_k=cfg.attn_block_k,
    )
    if layout.h_pad != layout.n_heads:
        o = o * layout.head_mask()[None, None, :, None].astype(o.dtype)
    out = o.reshape(b, s, layout.h_pad * hd) @ p["wo"]
    return shard(out, "batch", "residual", None), new_cache


# --------------------------------------------------------------------------
# sequence-sharded KV decode (shard_map partial-softmax combine)
# --------------------------------------------------------------------------


def _seq_sharded_decode(
    cfg: ArchConfig,
    layout: HeadLayout,
    q: jax.Array,  # (B,1,H_pad,hd), replicated over model
    k_new: jax.Array,  # (B,1,K_true,hd)
    v_new: jax.Array,
    cache: Params,  # {"ks","vs": (B,W,K_true,hd) seq-sharded, "poss": (W,)}
    t: jax.Array,  # scalar int32 position
):
    """Decode attention over a sequence-sharded true-KV cache.

    Each TP rank holds a W/TP chunk of the ring buffer (TRUE kv heads -- no
    xR repetition), writes the new token if its slot lands locally, computes
    the partial flash statistics over its chunk, and the ranks combine with
    a max/sum reduction: o = psum(acc*exp(m-M)) / psum(l*exp(m-M)).
    """
    from ..distributed import axes as _axes

    ctx = _axes.current()
    b, _, h_pad, hd = q.shape
    k_true = layout.n_kv
    gp = layout.repeat * layout.g_pad  # query slots per TRUE kv head
    scale = 1.0 / math.sqrt(hd)
    w_total = cache["ks"].shape[1]

    def _attend(qg, ck, cv, pos, t_):
        """Partial flash stats over one chunk.  Returns (m, l, acc)."""
        s = jnp.einsum(
            "bqkgd,bskd->bkgqs", qg, ck, preferred_element_type=jnp.float32
        ) * scale  # (B,K,G',1,wl)
        valid = (pos >= 0) & (pos <= t_)
        s = jnp.where(valid[None, None, None, None, :], s, float(jnp.finfo(jnp.float32).min / 2))
        m = s.max(axis=-1)
        p = jnp.exp(s - m[..., None])
        lsum = p.sum(axis=-1)
        acc = jnp.einsum("bkgqs,bskd->bkgqd", p, cv, preferred_element_type=jnp.float32)
        return m, lsum, acc

    def _write(ck, cv, pos, kn, vn, slot_local, active):
        cur_k = jax.lax.dynamic_slice_in_dim(ck, slot_local, 1, 1)
        cur_v = jax.lax.dynamic_slice_in_dim(cv, slot_local, 1, 1)
        cur_p = jax.lax.dynamic_slice_in_dim(pos, slot_local, 1, 0)
        ck = jax.lax.dynamic_update_slice_in_dim(
            ck, jnp.where(active, kn, cur_k), slot_local, 1
        )
        cv = jax.lax.dynamic_update_slice_in_dim(
            cv, jnp.where(active, vn, cur_v), slot_local, 1
        )
        pos = jax.lax.dynamic_update_slice_in_dim(
            pos, jnp.where(active, t.astype(jnp.int32)[None], cur_p), slot_local, 0
        )
        return ck, cv, pos

    if ctx is None or not ctx.model or w_total % ctx.axis_size(ctx.model):
        # single-device / unsharded fallback: same math, whole buffer local
        ck, cv, pos = _write(
            cache["ks"], cache["vs"], cache["poss"], k_new, v_new, t % w_total, True
        )
        qg = q.reshape(b, 1, k_true, gp, hd)
        m, lsum, acc = _attend(qg, ck, cv, pos, t)
        o = acc / jnp.maximum(lsum[..., None], 1e-30)
        o = o.reshape(b, 1, h_pad, hd).astype(q.dtype)
        return o, {"ks": ck, "vs": cv, "poss": pos}

    from jax.sharding import PartitionSpec as P

    ax = ctx.model
    bt = tuple(ctx.batch) if ctx.batch else None

    def body(q_l, kn_l, vn_l, ck, cv, pos):
        wl = ck.shape[1]
        idx = jax.lax.axis_index(ax)
        slot = (t % w_total).astype(jnp.int32)
        lo = idx * wl
        active = jnp.logical_and(slot >= lo, slot < lo + wl)
        slot_local = jnp.clip(slot - lo, 0, wl - 1)
        ck, cv, pos = _write(ck, cv, pos, kn_l, vn_l, slot_local, active)
        qg = q_l.reshape(q_l.shape[0], 1, k_true, gp, hd)
        m, lsum, acc = _attend(qg, ck, cv, pos, t)
        # flash combine across seq shards
        m_g = jax.lax.pmax(m, ax)
        alpha = jnp.exp(m - m_g)
        l_g = jax.lax.psum(lsum * alpha, ax)
        o = jax.lax.psum(acc * alpha[..., None], ax) / jnp.maximum(l_g[..., None], 1e-30)
        o = o.reshape(q_l.shape[0], 1, h_pad, hd).astype(q_l.dtype)
        return o, ck, cv, pos

    rep = P(bt, None, None, None)
    seq = P(bt, ax, None, None)
    o, ck, cv, pos = shard_map(
        body,
        mesh=ctx.mesh,
        in_specs=(rep, rep, rep, seq, seq, P(ax)),
        out_specs=(rep, seq, seq, P(ax)),
        check_vma=False,
    )(q, k_new, v_new, cache["ks"], cache["vs"], cache["poss"])
    return o, {"ks": ck, "vs": cv, "poss": pos}


# --------------------------------------------------------------------------
# transformer block (attention + FFN/MoE) for dense / moe / vlm / encoder
# --------------------------------------------------------------------------


def _norm(p, cfg: ArchConfig, x, name: str):
    if cfg.norm_type == "rms":
        return rms_norm(x, p[name], plus_one=cfg.norm_plus_one)
    return layer_norm(x, p[name + "_w"], p[name + "_b"])


def init_norm(cfg: ArchConfig, d: int, dtype, name: str) -> Params:
    if cfg.norm_type == "rms":
        init = jnp.zeros if cfg.norm_plus_one else jnp.ones
        return {name: init((d,), dtype)}
    return {name + "_w": jnp.ones((d,), dtype), name + "_b": jnp.zeros((d,), dtype)}


def init_block(key, cfg: ArchConfig, layout: HeadLayout, dtype) -> Params:
    ks = jax.random.split(key, 2)
    p: Params = {"attn": init_attention(ks[0], cfg, layout, dtype)}
    p.update(init_norm(cfg, cfg.d_model, dtype, "norm1"))
    p.update(init_norm(cfg, cfg.d_model, dtype, "norm2"))
    if cfg.is_moe:
        p["moe"] = init_moe(ks[1], cfg.d_model, cfg.d_ff, cfg.n_experts, dtype)
    elif cfg.gated_mlp:
        p["mlp"] = init_gated_mlp(ks[1], cfg.d_model, cfg.d_ff, dtype)
    else:
        p["mlp"] = init_mlp(ks[1], cfg.d_model, cfg.d_ff, dtype, bias=cfg.mlp_bias)
    return p


def block_apply(
    p: Params,
    cfg: ArchConfig,
    layout: HeadLayout,
    x: jax.Array,
    positions: jax.Array,
    mrope_positions=None,
    cache=None,
) -> Tuple[jax.Array, Optional[Params], jax.Array]:
    h, new_cache = attention_apply(
        p["attn"], cfg, layout, _norm(p, cfg, x, "norm1"), positions, mrope_positions,
        cache, cfg.window,
    )
    h = jax.ad_checkpoint.checkpoint_name(h, "block_out")
    x = x + h
    y_in = _norm(p, cfg, x, "norm2")
    aux = jnp.zeros((), jnp.float32)
    if cfg.is_moe:
        y, aux = moe_ffn(p["moe"], y_in, cfg.n_experts_per_tok, cfg.capacity_factor, cfg.act)
    elif cfg.gated_mlp:
        y = gated_mlp(p["mlp"], y_in, cfg.act)
    else:
        y = mlp(p["mlp"], y_in, cfg.act)
    y = jax.ad_checkpoint.checkpoint_name(y, "block_out")
    return x + y, new_cache, aux


# --------------------------------------------------------------------------
# full model
# --------------------------------------------------------------------------


def init_params(key, cfg: ArchConfig) -> Params:
    dtype = cfg.dtype("param")
    layout = HeadLayout.make(cfg.n_heads, cfg.n_kv_heads, cfg.pad_heads_to)
    ks = jax.random.split(key, cfg.n_layers + 3)
    params: Params = {
        "embed": dense_init(ks[0], (cfg.padded_vocab, cfg.d_model), cfg.d_model, dtype)
    }
    if cfg.scan_layers:
        layers = [init_block(ks[1 + i], cfg, layout, dtype) for i in range(cfg.n_layers)]
        params["layers"] = jax.tree.map(lambda *xs: jnp.stack(xs), *layers)
    else:
        params["layers"] = [
            init_block(ks[1 + i], cfg, layout, dtype) for i in range(cfg.n_layers)
        ]
    params.update(init_norm(cfg, cfg.d_model, dtype, "final_norm"))
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(
            ks[-1], (cfg.d_model, cfg.padded_vocab), cfg.d_model, dtype
        )
    return params


def _embed(params, cfg: ArchConfig, tokens=None, embeds=None) -> jax.Array:
    if embeds is None:
        embeds = params["embed"][tokens]
    x = embeds.astype(cfg.dtype("compute"))
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    return shard(x, "batch", "residual", None)


def _unembed(params, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    x = _norm(params, cfg, x, "final_norm")
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = (x @ w.astype(x.dtype)).astype(jnp.float32)
    return shard(logits, "batch", None, "model")


def forward(
    params: Params,
    cfg: ArchConfig,
    tokens: Optional[jax.Array] = None,
    embeds: Optional[jax.Array] = None,
    positions: Optional[jax.Array] = None,
    mrope_positions: Optional[jax.Array] = None,
    cache: Optional[Params] = None,
) -> Tuple[jax.Array, Optional[Params], jax.Array]:
    """Returns (logits fp32, new_cache, moe_aux)."""
    layout = HeadLayout.make(cfg.n_heads, cfg.n_kv_heads, cfg.pad_heads_to)
    x = _embed(params, cfg, tokens, embeds)
    b, s = x.shape[:2]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    def body_fn(x, layer_p, layer_cache):
        layer_p = cast_for_compute(layer_p, cfg.dtype("compute"))
        return block_apply(layer_p, cfg, layout, x, positions, mrope_positions, layer_cache)

    if cfg.remat:
        if cfg.remat_policy == "block_outs":
            # keep the post-psum block outputs: the backward recompute then
            # stops at the saved values instead of re-running the collectives
            policy = jax.checkpoint_policies.save_only_these_names("block_out")
        else:
            policy = jax.checkpoint_policies.nothing_saveable
        body_fn = jax.checkpoint(body_fn, policy=policy)

    if cfg.scan_layers:
        def scan_body(carry, xs):
            x = carry
            layer_p, layer_cache = xs
            x, new_cache, aux = body_fn(x, layer_p, layer_cache)
            return x, (new_cache, aux)

        if cache is None:
            # dummy per-layer cache of Nones is not scannable; use a unit array
            xs = (params["layers"], jnp.zeros((cfg.n_layers,), jnp.float32))

            def scan_body_nc(carry, xs):
                x = carry
                layer_p, _ = xs
                x, _, aux = body_fn(x, layer_p, None)
                return x, aux

            x, auxs = jax.lax.scan(scan_body_nc, x, xs)
            new_cache = None
        elif cfg.cache_in_carry:
            # cache lives in the scan carry: ring-buffer updates are in-place
            # dynamic-update-slices on ONE buffer (aliases under donation)
            # instead of the xs->ys double-buffer (see EXPERIMENTS §Perf).
            def scan_body_carry(carry, layer_p):
                x, cache_st, i = carry
                layer_cache = jax.tree.map(
                    lambda c: jax.lax.dynamic_index_in_dim(c, i, 0, keepdims=False),
                    cache_st,
                )
                x, nc, aux = body_fn(x, layer_p, layer_cache)
                cache_st = jax.tree.map(
                    lambda c, n: jax.lax.dynamic_update_index_in_dim(
                        c, n.astype(c.dtype), i, 0
                    ),
                    cache_st,
                    nc,
                )
                return (x, cache_st, i + 1), aux

            (x, new_cache, _), auxs = jax.lax.scan(
                scan_body_carry, (x, cache, jnp.zeros((), jnp.int32)), params["layers"]
            )
        else:
            x, (new_cache, auxs) = jax.lax.scan(scan_body, x, (params["layers"], cache))
        aux = auxs.sum()
    else:
        aux = jnp.zeros((), jnp.float32)
        new_caches = []
        for i, layer_p in enumerate(params["layers"]):
            layer_cache = None if cache is None else cache[i]
            x, nc, a = body_fn(x, layer_p, layer_cache)
            new_caches.append(nc)
            aux = aux + a
        new_cache = new_caches if cache is not None else None

    logits = _unembed(params, cfg, x)
    return logits, new_cache, aux


# --------------------------------------------------------------------------
# cache init
# --------------------------------------------------------------------------


def init_cache(cfg: ArchConfig, batch: int, max_len: int) -> Params:
    layout = HeadLayout.make(cfg.n_heads, cfg.n_kv_heads, cfg.pad_heads_to)
    w = min(max_len, cfg.window) if cfg.window else max_len
    dtype = cfg.dtype("compute")
    if cfg.decode_kv_seq_sharded and not cfg.window:
        # true kv heads, ring buffer seq-sharded over the TP axis
        one = {
            "ks": jnp.zeros((batch, w, layout.n_kv, cfg.head_dim), dtype),
            "vs": jnp.zeros((batch, w, layout.n_kv, cfg.head_dim), dtype),
            "poss": jnp.full((w,), -1, jnp.int32),
        }
        if cfg.scan_layers:
            return jax.tree.map(
                lambda x: jnp.broadcast_to(x[None], (cfg.n_layers,) + x.shape), one
            )
        return [jax.tree.map(jnp.copy, one) for _ in range(cfg.n_layers)]
    one = {
        "k": jnp.zeros((batch, w, layout.k_pad, cfg.head_dim), dtype),
        "v": jnp.zeros((batch, w, layout.k_pad, cfg.head_dim), dtype),
        "pos": jnp.full((w,), -1, jnp.int32),
    }
    if cfg.scan_layers:
        return jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (cfg.n_layers,) + x.shape), one
        )
    return [jax.tree.map(jnp.copy, one) for _ in range(cfg.n_layers)]


# --------------------------------------------------------------------------
# losses / steps (train, prefill, decode)
# --------------------------------------------------------------------------


def train_loss(params: Params, cfg: ArchConfig, batch: Dict[str, jax.Array]):
    """batch: tokens/embeds, labels, loss_mask [, mrope_positions]."""
    logits, _, aux = forward(
        params,
        cfg,
        tokens=batch.get("tokens"),
        embeds=batch.get("embeds"),
        mrope_positions=batch.get("mrope_positions"),
    )
    loss = cross_entropy_loss(
        logits, batch["labels"], batch.get("loss_mask"), real_vocab=cfg.vocab_size
    )
    total = loss + cfg.router_aux_loss * aux if cfg.is_moe else loss
    return total, {"loss": loss, "moe_aux": aux}


def prefill(params: Params, cfg: ArchConfig, batch: Dict[str, jax.Array], max_len: int):
    tokens = batch.get("tokens")
    embeds = batch.get("embeds")
    b, s = (tokens.shape if tokens is not None else embeds.shape[:2])
    cache = init_cache(cfg, b, max_len)
    logits, cache, _ = forward(
        params, cfg, tokens=tokens, embeds=embeds,
        mrope_positions=batch.get("mrope_positions"), cache=cache,
    )
    return logits[:, -1], cache, jnp.asarray(s, jnp.int32)


def decode_step(
    params: Params,
    cfg: ArchConfig,
    cache: Params,
    tokens: jax.Array,  # (B,1)
    t: jax.Array,  # scalar int32 current position
):
    b = tokens.shape[0]
    positions = jnp.broadcast_to(t[None, None], (b, 1)).astype(jnp.int32)
    mrope = None
    if cfg.family == "vlm":
        mrope = jnp.broadcast_to(t[None, None, None], (b, 1, 3)).astype(jnp.int32)
    logits, cache, _ = forward(
        params, cfg, tokens=tokens, positions=positions, mrope_positions=mrope, cache=cache
    )
    return logits[:, -1], cache, t + 1
