"""RecurrentGemma / Griffin blocks: RG-LRU recurrence + local attention (1:2).

The RG-LRU (real-gated linear recurrent unit):

    r_t = sigmoid(W_a x_t + b_a)          recurrence gate
    i_t = sigmoid(W_x x_t + b_x)          input gate
    log a_t = -c * softplus(Lambda) * r_t
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

is a diagonal first-order recurrence, evaluated in parallel over the sequence
with ``jax.lax.associative_scan`` (fp32).  The temporal-mixing block is
Griffin's: out = W_o( GeLU(W_y x) (*) RGLRU(conv4(W_x x)) ).

Attention layers use the shared GQA machinery with a sliding window (2048),
so the KV cache is bounded and the ``long_500k`` decode shape is O(window).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..distributed.axes import shard
from .common import dense_init


def init_rglru_block(
    key, d_model: int, d_rnn: int, d_conv: int, dtype, n_gate_blocks: int = 16
):
    if d_rnn % n_gate_blocks:
        n_gate_blocks = 1
    db = d_rnn // n_gate_blocks
    ks = jax.random.split(key, 7)
    # Lambda init so a^c in ~(0.9, 0.999) (griffin appendix)
    u = jax.random.uniform(ks[5], (d_rnn,), minval=0.9, maxval=0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u)))  # softplus^-1(-log u)
    return {
        "w_y": dense_init(ks[0], (d_model, d_rnn), d_model, dtype),
        "w_x": dense_init(ks[1], (d_model, d_rnn), d_model, dtype),
        "conv_w": dense_init(ks[2], (d_conv, d_rnn), d_conv, dtype),
        "conv_b": jnp.zeros((d_rnn,), dtype),
        # Griffin uses block-diagonal gate matrices; besides being faithful,
        # blocks shard over the TP axis with no collective.
        "w_a": dense_init(ks[3], (n_gate_blocks, db, db), db, dtype),
        "b_a": jnp.zeros((d_rnn,), jnp.float32),
        "w_i": dense_init(ks[4], (n_gate_blocks, db, db), db, dtype),
        "b_i": jnp.zeros((d_rnn,), jnp.float32),
        "lam": lam.astype(jnp.float32),
        "w_out": dense_init(ks[6], (d_rnn, d_model), d_rnn, dtype),
    }


def _block_diag_matmul(x, w):
    """x: (..., D) with D = nb*db; w: (nb, db, db) block-diagonal weights."""
    nb, db, _ = w.shape
    xb = x.reshape(*x.shape[:-1], nb, db)
    yb = jnp.einsum("...nd,ndk->...nk", xb, w)
    return yb.reshape(*x.shape[:-1], nb * db)


def _rglru_gates(params, x, c: float):
    """x: (..., d_rnn) fp32 -> (a, b) of the recurrence h = a h_ + b."""
    r = jax.nn.sigmoid(_block_diag_matmul(x, params["w_a"].astype(jnp.float32)) + params["b_a"])
    i = jax.nn.sigmoid(_block_diag_matmul(x, params["w_i"].astype(jnp.float32)) + params["b_i"])
    log_a = -c * jax.nn.softplus(params["lam"]) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * x)
    return a, b


def rglru_scan(params, x: jax.Array, c: float, h0: jax.Array | None = None):
    """Parallel evaluation over the sequence.  x: (B,S,D) -> (y, h_last)."""
    xf = x.astype(jnp.float32)
    a, b = _rglru_gates(params, xf, c)
    a = shard(a, "batch", None, "model")
    b = shard(b, "batch", None, "model")
    if h0 is not None:
        # fold the carried state into the first step: h_1 = a_1 h0 + b_1
        b = b.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))

    def combine(left, right):
        a_l, b_l = left
        a_r, b_r = right
        return a_l * a_r, a_r * b_l + b_r

    a_cum, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    del a_cum
    return h.astype(x.dtype), h[:, -1]


def rglru_step(params, x_t: jax.Array, h: jax.Array, c: float):
    """Single decode step.  x_t: (B,D); h: (B,D) fp32."""
    xf = x_t.astype(jnp.float32)
    a, b = _rglru_gates(params, xf, c)
    h_new = a * h.astype(jnp.float32) + b
    return h_new.astype(x_t.dtype), h_new


def rglru_reference(params, x, c: float, h0=None):
    """Sequential oracle."""
    xf = x.astype(jnp.float32)
    a, b = _rglru_gates(params, xf, c)
    h = jnp.zeros_like(xf[:, 0]) if h0 is None else h0.astype(jnp.float32)
    ys = []
    for t in range(x.shape[1]):
        h = a[:, t] * h + b[:, t]
        ys.append(h)
    return jnp.stack(ys, axis=1).astype(x.dtype), h


def recurrent_block_apply(
    params,
    x: jax.Array,  # (B,S,d_model)
    c: float,
    conv_tail: jax.Array | None = None,
    h0: jax.Array | None = None,
    return_state: bool = False,
):
    """Griffin temporal-mixing block (the RG-LRU branch x gated GeLU branch)."""
    y_branch = jax.nn.gelu(shard(x @ params["w_y"], "batch", None, "model"), approximate=True)
    xr = shard(x @ params["w_x"], "batch", None, "model")
    # causal depthwise conv, kernel d_conv
    k = params["conv_w"].shape[0]
    if conv_tail is None:
        conv_tail = jnp.zeros((x.shape[0], k - 1, xr.shape[-1]), xr.dtype)
    xp = jnp.concatenate([conv_tail, xr], axis=1)
    xr = sum(xp[:, i : i + x.shape[1]] * params["conv_w"][i] for i in range(k))
    xr = xr + params["conv_b"]
    new_tail = xp[:, -(k - 1) :] if k > 1 else conv_tail
    rec, h_last = rglru_scan(params, xr, c, h0)
    out = shard((rec * y_branch) @ params["w_out"], "batch", None, None)
    if return_state:
        return out, (new_tail, h_last)
    return out


def recurrent_block_step(params, x_t: jax.Array, c: float, conv_tail: jax.Array, h: jax.Array):
    """Decode step.  x_t: (B,1,d_model)."""
    y_branch = jax.nn.gelu(x_t @ params["w_y"], approximate=True)
    xr = x_t @ params["w_x"]  # (B,1,D)
    k = params["conv_w"].shape[0]
    xp = jnp.concatenate([conv_tail, xr], axis=1)  # (B,k,D)
    xc = sum(xp[:, -(k - i)] * params["conv_w"][i] for i in range(k)) + params["conv_b"]
    new_tail = xp[:, 1:]
    rec, h_new = rglru_step(params, xc, h, c)
    out = (rec[:, None] * y_branch) @ params["w_out"]
    return out, new_tail, h_new
