"""RecurrentGemma-style hybrid model: (RG-LRU, RG-LRU, local-attn) pattern.

Layers follow ``cfg.block_pattern`` repeated; the trailing ``L % len(pattern)``
layers take the pattern prefix (recurrentgemma-2b: 26 = 8x(R,R,A) + (R,R)).
Full pattern groups are stacked and scanned; the tail is unrolled.  Each
layer = pre-norm temporal mixing + pre-norm gated MLP, gemma conventions
((1+w) RMSNorm, sqrt(d) embedding scale, GeGLU).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .common import cast_for_compute, cross_entropy_loss, dense_init
from .layers import gated_mlp, init_gated_mlp
from .rglru import (
    init_rglru_block,
    recurrent_block_apply,
    recurrent_block_step,
)
from .transformer import (
    HeadLayout,
    _embed,
    _norm,
    _unembed,
    attention_apply,
    init_attention,
    init_norm,
)

Params = Dict[str, Any]


def _pattern_layers(cfg: ArchConfig):
    pat = cfg.block_pattern or ("rglru", "rglru", "attn")
    n_groups = cfg.n_layers // len(pat)
    tail = tuple(pat[: cfg.n_layers % len(pat)])
    return pat, n_groups, tail


def _init_layer(key, cfg: ArchConfig, kind: str, layout: HeadLayout, dtype) -> Params:
    ks = jax.random.split(key, 2)
    p: Params = {}
    p.update(init_norm(cfg, cfg.d_model, dtype, "norm1"))
    p.update(init_norm(cfg, cfg.d_model, dtype, "norm2"))
    if kind == "attn":
        p["attn"] = init_attention(ks[0], cfg, layout, dtype)
    else:
        p["rglru"] = init_rglru_block(ks[0], cfg.d_model, cfg.d_model, 4, dtype)
    p["mlp"] = init_gated_mlp(ks[1], cfg.d_model, cfg.d_ff, dtype)
    return p


def init_params(key, cfg: ArchConfig) -> Params:
    dtype = cfg.dtype("param")
    layout = HeadLayout.make(cfg.n_heads, cfg.n_kv_heads, cfg.pad_heads_to)
    pat, n_groups, tail = _pattern_layers(cfg)
    ks = jax.random.split(key, n_groups + len(tail) + 2)
    groups = []
    for gi in range(n_groups):
        gks = jax.random.split(ks[gi], len(pat))
        groups.append(
            {f"{kind}_{i}": _init_layer(gks[i], cfg, kind, layout, dtype)
             for i, kind in enumerate(pat)}
        )
    params: Params = {
        "embed": dense_init(ks[-1], (cfg.padded_vocab, cfg.d_model), cfg.d_model, dtype),
        "groups": jax.tree.map(lambda *xs: jnp.stack(xs), *groups),
        "tail": [
            _init_layer(ks[n_groups + i], cfg, kind, layout, dtype)
            for i, kind in enumerate(tail)
        ],
    }
    params.update(init_norm(cfg, cfg.d_model, dtype, "final_norm"))
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(ks[-2], (cfg.d_model, cfg.padded_vocab), cfg.d_model, dtype)
    return params


# -- caches ------------------------------------------------------------------
# attention layers: ring-buffer KV (window) like transformer.init_cache;
# rglru layers: conv tail (B,3,D) + hidden state (B,D) fp32.


def _layer_cache(cfg: ArchConfig, kind: str, batch: int, max_len: int) -> Params:
    if kind == "attn":
        layout = HeadLayout.make(cfg.n_heads, cfg.n_kv_heads, cfg.pad_heads_to)
        w = min(max_len, cfg.window) if cfg.window else max_len
        dt = cfg.dtype("compute")
        return {
            "k": jnp.zeros((batch, w, layout.k_pad, cfg.head_dim), dt),
            "v": jnp.zeros((batch, w, layout.k_pad, cfg.head_dim), dt),
            "pos": jnp.full((w,), -1, jnp.int32),
        }
    return {
        "conv": jnp.zeros((batch, 3, cfg.d_model), cfg.dtype("compute")),
        "h": jnp.zeros((batch, cfg.d_model), jnp.float32),
    }


def init_cache(cfg: ArchConfig, batch: int, max_len: int) -> Params:
    pat, n_groups, tail = _pattern_layers(cfg)
    group = {
        f"{kind}_{i}": _layer_cache(cfg, kind, batch, max_len) for i, kind in enumerate(pat)
    }
    return {
        "groups": jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (n_groups,) + x.shape), group
        ),
        "tail": [_layer_cache(cfg, kind, batch, max_len) for i, kind in enumerate(tail)],
    }


# -- forward -----------------------------------------------------------------


def _apply_layer(
    p: Params,
    cfg: ArchConfig,
    kind: str,
    layout: HeadLayout,
    x: jax.Array,
    positions: jax.Array,
    cache: Optional[Params],
    decode: bool,
) -> Tuple[jax.Array, Optional[Params]]:
    h_in = _norm(p, cfg, x, "norm1")
    new_cache = cache
    if kind == "attn":
        h, new_cache = attention_apply(
            p["attn"], cfg, layout, h_in, positions, None, cache, cfg.window
        )
    else:
        if decode:
            h, conv, hid = recurrent_block_step(
                p["rglru"], h_in, cfg.rglru_c, cache["conv"], cache["h"]
            )
            new_cache = {"conv": conv, "h": hid}
        else:
            h0 = None if cache is None else cache["h"]
            tail_in = None if cache is None else cache["conv"]
            h, (conv, hid) = recurrent_block_apply(
                p["rglru"], h_in, cfg.rglru_c, tail_in, h0, return_state=True
            )
            if cache is not None:
                new_cache = {"conv": conv.astype(cache["conv"].dtype), "h": hid}
    x = x + h
    y = gated_mlp(p["mlp"], _norm(p, cfg, x, "norm2"), cfg.act)
    return x + y, new_cache


def forward(
    params: Params,
    cfg: ArchConfig,
    tokens: jax.Array,
    positions: Optional[jax.Array] = None,
    cache: Optional[Params] = None,
) -> Tuple[jax.Array, Optional[Params]]:
    pat, n_groups, tail = _pattern_layers(cfg)
    layout = HeadLayout.make(cfg.n_heads, cfg.n_kv_heads, cfg.pad_heads_to)
    x = _embed(params, cfg, tokens)
    b, s = x.shape[:2]
    decode = s == 1 and cache is not None
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    def group_fn(x, group_p, group_cache):
        group_p = cast_for_compute(group_p, cfg.dtype("compute"))
        new_gc = {} if group_cache is not None else None
        for i, kind in enumerate(pat):
            name = f"{kind}_{i}"
            lc = None if group_cache is None else group_cache[name]
            x, nc = _apply_layer(group_p[name], cfg, kind, layout, x, positions, lc, decode)
            if new_gc is not None:
                new_gc[name] = nc
        return x, new_gc

    if cfg.remat:
        group_fn = jax.checkpoint(group_fn, policy=jax.checkpoint_policies.nothing_saveable)

    if cache is None:
        def body(x, gp):
            x, _ = group_fn(x, gp, None)
            return x, None

        x, _ = jax.lax.scan(body, x, params["groups"])
        new_group_cache = None
    else:
        def body(x, xs):
            gp, gc = xs
            x, ngc = group_fn(x, gp, gc)
            return x, ngc

        x, new_group_cache = jax.lax.scan(body, x, (params["groups"], cache["groups"]))

    new_tail = []
    for i, kind in enumerate(tail):
        lc = None if cache is None else cache["tail"][i]
        tp = cast_for_compute(params["tail"][i], cfg.dtype("compute"))
        x, nc = _apply_layer(tp, cfg, kind, layout, x, positions, lc, decode)
        new_tail.append(nc)

    logits = _unembed(params, cfg, x)
    new_cache = None
    if cache is not None:
        new_cache = {"groups": new_group_cache, "tail": new_tail}
    return logits, new_cache


def train_loss(params: Params, cfg: ArchConfig, batch: Dict[str, jax.Array]):
    logits, _ = forward(params, cfg, batch["tokens"])
    loss = cross_entropy_loss(
        logits, batch["labels"], batch.get("loss_mask"), real_vocab=cfg.vocab_size
    )
    return loss, {"loss": loss}


def prefill(params: Params, cfg: ArchConfig, batch, max_len: int):
    tokens = batch["tokens"]
    cache = init_cache(cfg, tokens.shape[0], max_len)
    logits, cache = forward(params, cfg, tokens, cache=cache)
    return logits[:, -1], cache, jnp.asarray(tokens.shape[1], jnp.int32)


def decode_step(params: Params, cfg: ArchConfig, cache, tokens, t):
    b = tokens.shape[0]
    positions = jnp.broadcast_to(t[None, None], (b, 1)).astype(jnp.int32)
    logits, cache = forward(params, cfg, tokens, positions=positions, cache=cache)
    return logits[:, -1], cache, t + 1
