"""Unified model API over the zoo: build_model(cfg) -> Model.

Model methods take/return explicit pytrees so the runtime can jit/pjit them
with sharding annotations; ``input_specs`` produces ShapeDtypeStruct
stand-ins for every input of the requested shape cell (dry-run contract).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, ShapeConfig
from . import hybrid, mamba, transformer

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig
    init: Callable
    train_loss: Callable  # (params, batch) -> (loss, metrics)
    prefill: Callable  # (params, batch, max_len) -> (last_logits, cache, t)
    decode_step: Callable  # (params, cache, tokens, t) -> (logits, cache, t+1)
    init_cache: Callable  # (batch, max_len) -> cache pytree

    # ---------------------------------------------------------------- specs

    def input_specs(self, shape: ShapeConfig) -> Dict[str, jax.ShapeDtypeStruct]:
        """ShapeDtypeStruct batch stand-ins for a shape cell (no allocation)."""
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        f32 = jnp.float32
        i32 = jnp.int32
        cdt = cfg.dtype("compute")
        if shape.kind == "decode":
            return {"tokens": jax.ShapeDtypeStruct((b, 1), i32)}
        stubbed = cfg.family in ("vlm", "encoder")  # modality frontend is a stub
        specs: Dict[str, jax.ShapeDtypeStruct] = {}
        if stubbed:
            specs["embeds"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), cdt)
        else:
            specs["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
        if cfg.family == "vlm":
            specs["mrope_positions"] = jax.ShapeDtypeStruct((b, s, 3), i32)
        if shape.kind == "train":
            specs["labels"] = jax.ShapeDtypeStruct((b, s), i32)
            specs["loss_mask"] = jax.ShapeDtypeStruct((b, s), f32)
        return specs

    def cache_specs(self, shape: ShapeConfig) -> Any:
        """ShapeDtypeStruct pytree of the decode cache for a shape cell."""
        b = shape.global_batch
        dummy = {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}
        return jax.eval_shape(lambda: self.init_cache(dummy, shape.seq_len))

    def param_specs(self, seed: int = 0) -> Any:
        return jax.eval_shape(lambda: self.init(jax.random.key(seed)))


def _transformer_model(cfg: ArchConfig) -> Model:
    return Model(
        cfg=cfg,
        init=lambda key: transformer.init_params(key, cfg),
        train_loss=lambda p, b: transformer.train_loss(p, cfg, b),
        prefill=lambda p, b, max_len: transformer.prefill(p, cfg, b, max_len),
        decode_step=lambda p, c, tok, t: transformer.decode_step(p, cfg, c, tok, t),
        init_cache=lambda b, max_len: transformer.init_cache(cfg, _batch_size(b), max_len),
    )


def _hybrid_model(cfg: ArchConfig) -> Model:
    return Model(
        cfg=cfg,
        init=lambda key: hybrid.init_params(key, cfg),
        train_loss=lambda p, b: hybrid.train_loss(p, cfg, b),
        prefill=lambda p, b, max_len: hybrid.prefill(p, cfg, b, max_len),
        decode_step=lambda p, c, tok, t: hybrid.decode_step(p, cfg, c, tok, t),
        init_cache=lambda b, max_len: hybrid.init_cache(cfg, _batch_size(b), max_len),
    )


def _mamba_model(cfg: ArchConfig) -> Model:
    return Model(
        cfg=cfg,
        init=lambda key: mamba.init_params(key, cfg),
        train_loss=lambda p, b: mamba.train_loss(p, cfg, b),
        prefill=lambda p, b, max_len: mamba.prefill(p, cfg, b, max_len),
        decode_step=lambda p, c, tok, t: mamba.decode_step(p, cfg, c, tok, t),
        init_cache=lambda b, max_len: mamba.init_cache(cfg, _batch_size(b), max_len),
    )


def _batch_size(batch) -> int:
    for k in ("tokens", "embeds"):
        if k in batch:
            return batch[k].shape[0]
    raise ValueError("batch has no tokens/embeds")


def build_model(cfg: ArchConfig) -> Model:
    if cfg.family in ("dense", "moe", "vlm", "encoder"):
        return _transformer_model(cfg)
    if cfg.family == "hybrid":
        return _hybrid_model(cfg)
    if cfg.family == "ssm":
        return _mamba_model(cfg)
    raise ValueError(f"unknown family {cfg.family!r}")
