"""Shared utilities for the pure-JAX model zoo (explicit pytree params)."""
from __future__ import annotations

import math
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

Params = Dict[str, Any]


def normal_init(key: jax.Array, shape, scale: float, dtype) -> jax.Array:
    return (jax.random.normal(key, shape, dtype=jnp.float32) * scale).astype(dtype)


def dense_init(key: jax.Array, shape, fan_in: int, dtype) -> jax.Array:
    """Truncated-normal-ish 1/sqrt(fan_in) init (standard LM practice)."""
    return normal_init(key, shape, 1.0 / math.sqrt(max(fan_in, 1)), dtype)


def split_keys(key: jax.Array, names):
    ks = jax.random.split(key, len(names))
    return dict(zip(names, ks))


def pad_to_multiple(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


def count_params(params: Params) -> int:
    return int(sum(np.prod(x.shape) for x in jax.tree.leaves(params)))


def cast_tree(params: Params, dtype) -> Params:
    return jax.tree.map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x, params
    )


# parameters whose precision is numerically sensitive stay fp32 in compute
_KEEP_FP32 = {"router", "A_log", "dt_bias", "D", "lam", "b_a", "b_i"}


def cast_for_compute(params: Params, dtype) -> Params:
    """Cast weights to the compute dtype, keeping routing/SSM params fp32.

    Called inside the (rematerialized) layer body so the low-precision copies
    are transient; master weights keep their storage dtype.
    """

    def cast(path, x):
        last = path[-1]
        name = getattr(last, "key", None) or str(last)
        if name in _KEEP_FP32:
            return x
        return x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x

    return jax.tree_util.tree_map_with_path(cast, params)


def cross_entropy_loss(
    logits: jax.Array,
    labels: jax.Array,
    mask: jax.Array | None = None,
    real_vocab: int | None = None,
    z_loss: float = 0.0,
):
    """Token CE in fp32 with padded-vocab masking and optional z-loss.

    logits: (..., V_padded); labels: (...) int ids; mask: (...) weights.
    """
    logits = logits.astype(jnp.float32)
    v = logits.shape[-1]
    if real_vocab is not None and real_vocab < v:
        neg = jnp.finfo(jnp.float32).min
        pad_mask = jnp.arange(v) >= real_vocab
        logits = jnp.where(pad_mask, neg, logits)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    # Label log-prob via a masked reduction instead of take_along_axis: a
    # gather over the vocab dim forces SPMD to all-gather the (B,S,V) fp32
    # logits when vocab is TP-sharded (observed: +39GB/device in the dry-run);
    # the where-sum contracts over the sharded dim with a cheap psum instead.
    label_hit = jnp.arange(v) == labels[..., None]
    ll = jnp.sum(jnp.where(label_hit, logits, 0.0), axis=-1)
    nll = lse - ll
    if z_loss > 0.0:
        nll = nll + z_loss * lse**2
    if mask is None:
        return nll.mean()
    mask = mask.astype(jnp.float32)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
