"""Mamba-2 language model (attention-free SSD stack)."""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .common import cast_for_compute, cross_entropy_loss, dense_init
from .ssm import SSMDims, init_ssm_layer, ssm_decode_step, ssm_layer_apply
from .transformer import _embed, _norm, _unembed, init_norm

Params = Dict[str, Any]


def init_params(key, cfg: ArchConfig) -> Params:
    dtype = cfg.dtype("param")
    dims = SSMDims.from_config(cfg)
    ks = jax.random.split(key, cfg.n_layers + 3)
    layers = []
    for i in range(cfg.n_layers):
        p = {"mixer": init_ssm_layer(ks[i], dims, dtype)}
        p.update(init_norm(cfg, cfg.d_model, dtype, "norm1"))
        layers.append(p)
    params: Params = {
        "embed": dense_init(ks[-1], (cfg.padded_vocab, cfg.d_model), cfg.d_model, dtype),
        "layers": jax.tree.map(lambda *xs: jnp.stack(xs), *layers),
    }
    params.update(init_norm(cfg, cfg.d_model, dtype, "final_norm"))
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(ks[-2], (cfg.d_model, cfg.padded_vocab), cfg.d_model, dtype)
    return params


def init_cache(cfg: ArchConfig, batch: int, max_len: int) -> Params:
    del max_len  # SSM state is O(1) in sequence length
    dims = SSMDims.from_config(cfg)
    cdt = cfg.dtype("compute")
    one = {
        "conv_x": jnp.zeros((batch, dims.d_conv - 1, dims.d_inner), cdt),
        "conv_bc": jnp.zeros((batch, dims.d_conv - 1, 2 * dims.d_state), cdt),
        "h": jnp.zeros((batch, dims.n_heads, dims.d_state, dims.headdim), jnp.float32),
    }
    return jax.tree.map(lambda x: jnp.broadcast_to(x[None], (cfg.n_layers,) + x.shape), one)


def forward(
    params: Params,
    cfg: ArchConfig,
    tokens: jax.Array,
    cache: Optional[Params] = None,
    decode: bool = False,
) -> Tuple[jax.Array, Optional[Params]]:
    dims = SSMDims.from_config(cfg)
    x = _embed(params, cfg, tokens)

    def layer_fn(x, p, lc):
        p = cast_for_compute(p, cfg.dtype("compute"))
        h_in = _norm(p, cfg, x, "norm1")
        if decode:
            y, cx, cbc, h = ssm_decode_step(
                p["mixer"], dims, h_in, lc["conv_x"], lc["conv_bc"], lc["h"]
            )
            return x + y, {"conv_x": cx, "conv_bc": cbc, "h": h}
        if lc is None:
            y = ssm_layer_apply(p["mixer"], dims, h_in)
            return x + y, None
        y, (cx, cbc, h) = ssm_layer_apply(
            p["mixer"], dims, h_in, lc["conv_x"], lc["conv_bc"], lc["h"], return_state=True
        )
        return x + y, {
            "conv_x": cx.astype(lc["conv_x"].dtype),
            "conv_bc": cbc.astype(lc["conv_bc"].dtype),
            "h": h,
        }

    if cfg.remat:
        layer_fn = jax.checkpoint(layer_fn, policy=jax.checkpoint_policies.nothing_saveable)

    if cache is None:
        def body(x, p):
            x, _ = layer_fn(x, p, None)
            return x, None

        x, _ = jax.lax.scan(body, x, params["layers"])
        new_cache = None
    else:
        def body(x, xs):
            p, lc = xs
            x, nc = layer_fn(x, p, lc)
            return x, nc

        x, new_cache = jax.lax.scan(body, x, (params["layers"], cache))

    logits = _unembed(params, cfg, x)
    return logits, new_cache


def train_loss(params: Params, cfg: ArchConfig, batch: Dict[str, jax.Array]):
    logits, _ = forward(params, cfg, batch["tokens"])
    loss = cross_entropy_loss(
        logits, batch["labels"], batch.get("loss_mask"), real_vocab=cfg.vocab_size
    )
    return loss, {"loss": loss}


def prefill(params: Params, cfg: ArchConfig, batch, max_len: int):
    tokens = batch["tokens"]
    cache = init_cache(cfg, tokens.shape[0], max_len)
    logits, cache = forward(params, cfg, tokens, cache=cache)
    return logits[:, -1], cache, jnp.asarray(tokens.shape[1], jnp.int32)


def decode_step(params: Params, cfg: ArchConfig, cache, tokens, t):
    logits, cache = forward(params, cfg, tokens, cache=cache, decode=True)
    return logits[:, -1], cache, t + 1
