from . import serve, train
from .train import TrainState, init_state, jit_train_step, make_train_step

__all__ = [
    "serve",
    "train",
    "TrainState",
    "init_state",
    "jit_train_step",
    "make_train_step",
]
