"""Train-step construction: value_and_grad + AdamW over a sharded mesh."""
from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from ..distributed import sharding
from ..distributed.axes import logical_axes
from ..models import Model
from ..optim import AdamW, OptState, apply_updates


class TrainState(NamedTuple):
    step: jax.Array  # int32 scalar
    params: Any
    opt_state: OptState


def init_state(model: Model, optimizer: AdamW, key: jax.Array) -> TrainState:
    params = model.init(key)
    return TrainState(jnp.zeros((), jnp.int32), params, optimizer.init(params))


def make_train_step(model: Model, optimizer: AdamW, microbatches: int = 1) -> Callable:
    """Train step with optional gradient accumulation.

    ``microbatches > 1`` splits the global batch along dim 0 and scans the
    value_and_grad over the chunks, accumulating fp32 grad sums -- the
    standard way to fit large-activation cells (32k-seq, deep models) into
    HBM while keeping the *global* batch semantics bit-identical.
    """
    grad_fn = jax.value_and_grad(
        lambda p, b: model.train_loss(p, b), has_aux=True
    )

    def train_step(state: TrainState, batch: Dict[str, jax.Array]):
        if microbatches == 1:
            (loss, metrics), grads = grad_fn(state.params, batch)
        else:
            mb = jax.tree.map(
                lambda x: x.reshape(microbatches, x.shape[0] // microbatches, *x.shape[1:]),
                batch,
            )

            def acc(carry, chunk):
                gsum, lsum = carry
                (loss_i, metrics_i), g_i = grad_fn(state.params, chunk)
                gsum = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), gsum, g_i
                )
                return (gsum, lsum + loss_i), metrics_i

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params
            )
            (gsum, lsum), metrics_all = jax.lax.scan(acc, (g0, 0.0), mb)
            grads = jax.tree.map(lambda g: g / microbatches, gsum)
            loss = lsum / microbatches
            metrics = jax.tree.map(lambda m: m.mean(), metrics_all)
        updates, opt_state, opt_metrics = optimizer.update(
            grads, state.opt_state, state.params
        )
        params = apply_updates(state.params, updates)
        metrics = {**metrics, **opt_metrics, "loss_total": loss}
        return TrainState(state.step + 1, params, opt_state), metrics

    return train_step


def state_shardings(mesh: Mesh, model: Model, optimizer: AdamW, axes=None):
    """NamedSharding pytree congruent with TrainState (opt moments ~ params)."""
    params_spec = model.param_specs()
    p_sh = sharding.param_shardings(mesh, params_spec, axes)
    return TrainState(
        step=sharding.scalar_sharding(mesh),
        params=p_sh,
        opt_state=OptState(count=sharding.scalar_sharding(mesh), m=p_sh, v=p_sh),
    )


def default_microbatches(model: Model, shape) -> int:
    """Pick grad-accumulation depth so activations fit ~6GB/device.

    With full remat the live set is ~ per-layer saved inputs plus the fp32
    logits pipeline (logits + softmax grads, vocab TP-sharded 16-way):
      act ~ (L * t * d * 2  +  t * V_pad/16 * 12) / M   per device.
    """
    cfg = model.cfg
    dp = 16  # production data-axis width
    t = shape.global_batch * shape.seq_len // dp  # tokens per device
    act = cfg.n_layers * t * cfg.d_model * 2 + t * (cfg.padded_vocab // 16) * 12
    m = 1
    rows = shape.global_batch
    while act / m > 6e9 and m < rows and rows % (2 * m) == 0:
        m *= 2
    return m


def jit_train_step(
    mesh: Mesh,
    model: Model,
    optimizer: AdamW,
    shape,  # ShapeConfig
    donate: bool = True,
    microbatches: int = 1,
    mesh_axes=None,  # override logical axis mapping (e.g. MeshAxes.dp_over_model)
):
    """pjit'd train step + the (state, batch) shardings used to lower it."""
    axes = mesh_axes or sharding.MeshAxes.infer(mesh)
    st_sh = state_shardings(mesh, model, optimizer, axes)
    batch_spec = model.input_specs(shape)
    b_sh = sharding.batch_shardings(mesh, batch_spec, axes)
    metric_sh = None  # inferred (replicated scalars)
    inner = make_train_step(model, optimizer, microbatches=microbatches)

    def train_step(state, batch):
        # activate logical-axis annotations for the trace
        with logical_axes(mesh, axes.batch, axes.model, seq=model.cfg.sequence_parallel):
            return inner(state, batch)

    step = jax.jit(
        train_step,
        in_shardings=(st_sh, b_sh),
        out_shardings=(st_sh, metric_sh),
        donate_argnums=(0,) if donate else (),
    )
    return step, st_sh, b_sh


def jit_init_state(mesh: Mesh, model: Model, optimizer: AdamW):
    st_sh = state_shardings(mesh, model, optimizer)
    return jax.jit(
        lambda key: init_state(model, optimizer, key), out_shardings=st_sh
    ), st_sh
