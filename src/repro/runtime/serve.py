"""Serving-step construction: prefill + batched single-token decode.

``serve_step`` is the function the ``decode_*`` dry-run cells lower: one new
token against a KV cache of ``seq_len`` (NOT a train_step).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from ..configs.base import ShapeConfig
from ..distributed import sharding
from ..distributed.axes import logical_axes
from ..models import Model


def make_prefill_step(model: Model, max_len: int) -> Callable:
    def prefill_step(params, batch):
        return model.prefill(params, batch, max_len)

    return prefill_step


def make_serve_step(model: Model) -> Callable:
    def serve_step(params, cache, tokens, t):
        return model.decode_step(params, cache, tokens, t)

    return serve_step


def jit_prefill(mesh: Mesh, model: Model, shape: ShapeConfig):
    p_sh = sharding.param_shardings(mesh, model.param_specs())
    b_sh = sharding.batch_shardings(mesh, model.input_specs(shape))
    c_sh = sharding.cache_shardings(mesh, model.cache_specs(shape))
    axes = sharding.MeshAxes.infer(mesh)
    inner = make_prefill_step(model, shape.seq_len)

    def prefill_step(params, batch):
        with logical_axes(mesh, axes.batch, axes.model, seq=model.cfg.sequence_parallel):
            return inner(params, batch)

    fn = jax.jit(
        prefill_step,
        in_shardings=(p_sh, b_sh),
        out_shardings=(None, c_sh, sharding.scalar_sharding(mesh)),
    )
    return fn, p_sh, b_sh, c_sh


def jit_serve_step(mesh: Mesh, model: Model, shape: ShapeConfig, donate: bool = True):
    p_sh = sharding.param_shardings(mesh, model.param_specs())
    c_sh = sharding.cache_shardings(mesh, model.cache_specs(shape))
    tok_sh = sharding.batch_shardings(
        mesh, {"tokens": jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)}
    )["tokens"]
    t_sh = sharding.scalar_sharding(mesh)
    axes = sharding.MeshAxes.infer(mesh)
    inner = make_serve_step(model)

    def serve_step(params, cache, tokens, t):
        with logical_axes(mesh, axes.batch, axes.model, seq=model.cfg.sequence_parallel):
            return inner(params, cache, tokens, t)

    fn = jax.jit(
        serve_step,
        in_shardings=(p_sh, c_sh, tok_sh, t_sh),
        out_shardings=(None, c_sh, t_sh),
        donate_argnums=(1,) if donate else (),
    )
    return fn, p_sh, c_sh, tok_sh
