"""AdamW with decoupled weight decay and global-norm clipping."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

Params = Any


class OptState(NamedTuple):
    count: jax.Array  # int32 scalar
    m: Params  # first moment (fp32)
    v: Params  # second moment (fp32)


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype), params, updates)


@dataclasses.dataclass(frozen=True)
class AdamW:
    learning_rate: Callable[[jax.Array], jax.Array] | float
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    # decay applies only to >=2D weights (not norms/biases), LM convention
    decay_min_ndim: int = 2

    def init(self, params) -> OptState:
        def zeros(p):
            return jnp.zeros(p.shape, jnp.float32)

        return OptState(
            count=jnp.zeros((), jnp.int32),
            m=jax.tree.map(zeros, params),
            v=jax.tree.map(zeros, params),
        )

    def _lr(self, count):
        if callable(self.learning_rate):
            return self.learning_rate(count)
        return jnp.asarray(self.learning_rate, jnp.float32)

    def update(self, grads, state: OptState, params):
        """Returns (updates, new_state, metrics)."""
        gnorm = global_norm(grads)
        if self.clip_norm is not None:
            scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gnorm, 1e-12))
            grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)
        else:
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)

        count = state.count + 1
        b1, b2 = self.b1, self.b2
        m = jax.tree.map(lambda mm, g: b1 * mm + (1 - b1) * g, state.m, grads)
        v = jax.tree.map(lambda vv, g: b2 * vv + (1 - b2) * g * g, state.v, grads)
        c1 = 1.0 - b1 ** count.astype(jnp.float32)
        c2 = 1.0 - b2 ** count.astype(jnp.float32)
        lr = self._lr(count)

        def upd(mm, vv, p):
            step = (mm / c1) / (jnp.sqrt(vv / c2) + self.eps)
            if self.weight_decay and p.ndim >= self.decay_min_ndim:
                step = step + self.weight_decay * p.astype(jnp.float32)
            return -lr * step

        updates = jax.tree.map(upd, m, v, params)
        return updates, OptState(count, m, v), {"grad_norm": gnorm, "lr": lr}
