"""Optimizer substrate: AdamW + schedules + global-norm clipping.

Self-contained (no optax).  Optimizer state is a pytree congruent with the
params, so the sharding rules for parameters apply verbatim to ``m``/``v``
(ZeRO-style sharded optimizer state under FSDP).
"""
from .adamw import AdamW, OptState, apply_updates, global_norm
from .schedule import constant, cosine_with_warmup, linear_with_warmup

__all__ = [
    "AdamW",
    "OptState",
    "apply_updates",
    "global_norm",
    "constant",
    "cosine_with_warmup",
    "linear_with_warmup",
]
