"""Learning-rate schedules (step -> lr, traceable)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def linear_with_warmup(peak: float, warmup: int, total: int, floor: float = 0.0):
    def fn(step):
        step = step.astype(jnp.float32)
        warm = peak * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        decay = peak + (floor - peak) * frac
        return jnp.where(step < warmup, warm, decay)

    return fn


def cosine_with_warmup(peak: float, warmup: int, total: int, floor_frac: float = 0.1):
    floor = peak * floor_frac

    def fn(step):
        step = step.astype(jnp.float32)
        warm = peak * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        decay = floor + 0.5 * (peak - floor) * (1.0 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, decay)

    return fn
