"""Checkpointing: atomic, integrity-checked, keep-K, async, resumable.

Layout:  <dir>/step_00000420/
             manifest.json     {tree structure, shapes, dtypes, crc32s}
             leaf_00000.npy .. leaf_NNNNN.npy

Writes go to a tmp dir and are atomically renamed, so a crash mid-save never
corrupts the latest checkpoint; restore verifies CRCs and falls back to the
newest *valid* step.  On multi-host deployments each host saves its
addressable shards under <dir>/host_<k>/ (single-host here: host_0).
"""
from __future__ import annotations

import concurrent.futures as cf
import json
import pathlib
import shutil
import zlib
from typing import Any, Optional

import jax
import numpy as np

PREFIX = "step_"


def _tree_paths(tree) -> list:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return flat, treedef


class CheckpointManager:
    def __init__(self, directory: str | pathlib.Path, keep: int = 3):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._pool = cf.ThreadPoolExecutor(max_workers=1)
        self._pending: Optional[cf.Future] = None

    # -- save -----------------------------------------------------------------

    def save(self, step: int, state: Any) -> pathlib.Path:
        host_arrays = jax.tree.map(lambda x: np.asarray(x), state)
        return self._write(step, host_arrays)

    def save_async(self, step: int, state: Any) -> None:
        """Device->host copy happens now; disk I/O overlaps the next steps."""
        self.wait()
        host_arrays = jax.tree.map(lambda x: np.asarray(x), state)
        self._pending = self._pool.submit(self._write, step, host_arrays)

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.result()
            self._pending = None

    def _write(self, step: int, host_arrays: Any) -> pathlib.Path:
        flat, treedef = _tree_paths(host_arrays)
        final = self.dir / f"{PREFIX}{step:08d}"
        tmp = self.dir / f"tmp_{step:08d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        manifest = {"step": step, "leaves": []}
        for i, (path, leaf) in enumerate(flat):
            fname = f"leaf_{i:05d}.npy"
            np.save(tmp / fname, leaf)
            manifest["leaves"].append(
                {
                    "key": jax.tree_util.keystr(path),
                    "file": fname,
                    "shape": list(leaf.shape),
                    "dtype": str(leaf.dtype),
                    "crc32": zlib.crc32(np.ascontiguousarray(leaf).tobytes()),
                }
            )
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)  # atomic publish
        self._prune()
        return final

    def _prune(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[: max(len(steps) - self.keep, 0)]:
            shutil.rmtree(self.dir / f"{PREFIX}{s:08d}", ignore_errors=True)

    # -- restore ----------------------------------------------------------------

    def all_steps(self) -> list:
        out = []
        for p in self.dir.glob(f"{PREFIX}*"):
            if (p / "manifest.json").exists():
                out.append(int(p.name[len(PREFIX):]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def _verify(self, step: int) -> bool:
        d = self.dir / f"{PREFIX}{step:08d}"
        try:
            manifest = json.loads((d / "manifest.json").read_text())
            for leaf in manifest["leaves"]:
                arr = np.load(d / leaf["file"])
                if zlib.crc32(np.ascontiguousarray(arr).tobytes()) != leaf["crc32"]:
                    return False
            return True
        except Exception:
            return False

    def restore(self, like: Any, step: Optional[int] = None) -> tuple:
        """Returns (state, step).  ``like`` provides the pytree structure
        (ShapeDtypeStructs or arrays); falls back to the newest valid step."""
        candidates = [step] if step is not None else sorted(self.all_steps(), reverse=True)
        for s in candidates:
            if not self._verify(s):
                continue
            d = self.dir / f"{PREFIX}{s:08d}"
            manifest = json.loads((d / "manifest.json").read_text())
            flat, treedef = _tree_paths(like)
            by_key = {leaf["key"]: leaf for leaf in manifest["leaves"]}
            leaves = []
            for path, spec in flat:
                key = jax.tree_util.keystr(path)
                if key not in by_key:
                    raise KeyError(f"checkpoint missing leaf {key}")
                leaves.append(np.load(d / by_key[key]["file"]))
            return jax.tree_util.tree_unflatten(treedef, leaves), s
        raise FileNotFoundError(f"no valid checkpoint under {self.dir}")
