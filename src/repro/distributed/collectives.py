"""Gradient-compression collectives (cross-pod reduction path).

``compressed_allreduce_mean`` implements int8 error-feedback all-reduce for
use under ``jax.shard_map`` on a slow axis (the DCN "pod" axis): each member
quantizes its tensor to int8 with a per-member fp32 scale, all-gathers the
int8 payloads + scales (1 byte/element/member on the wire vs 4), and
dequant-sums locally.  The quantization residual is returned as the error-
feedback buffer to be added to the *next* step's input, so the compression
error telescopes instead of accumulating (Seide et al. / 1-bit SGD lineage).

For a 2-pod mesh this moves ~4x fewer DCN bytes than an fp32 ring
all-reduce; the intra-pod reductions stay in XLA's native fp32/bf16 path.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantization.  Returns (q, scale)."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_allreduce_mean(
    x: jax.Array,
    ef: jax.Array,
    axis_name: str,
) -> Tuple[jax.Array, jax.Array]:
    """Mean over ``axis_name`` with int8 payload + error feedback.

    Must be called inside shard_map/pmap with ``axis_name`` bound.
    Returns (mean estimate, new error-feedback buffer).
    """
    y = x.astype(jnp.float32) + ef
    q, scale = quantize_int8(y)
    # wire format: int8 payload + fp32 scalar per member
    qs = jax.lax.all_gather(q, axis_name)  # (n, ...) int8
    scales = jax.lax.all_gather(scale, axis_name)  # (n,)
    n = qs.shape[0]
    total = jnp.tensordot(
        scales, qs.astype(jnp.float32).reshape(n, -1), axes=1
    ).reshape(x.shape)
    mean = total / n
    new_ef = y - dequantize_int8(q, scale)  # my own residual
    return mean, new_ef


def allreduce_mean(x: jax.Array, axis_name: str) -> jax.Array:
    """Uncompressed reference path."""
    return jax.lax.pmean(x, axis_name)
