"""Version compatibility shims for the jax API surface the runtime uses.

``shard_map`` moved from ``jax.experimental.shard_map`` to the top-level
``jax.shard_map`` namespace, and its replication-check kwarg was renamed
``check_rep`` -> ``check_vma``.  Normalize both so the repo runs on the
container's pinned jax as well as current releases.
"""
from __future__ import annotations

import inspect

try:
    from jax import shard_map as _shard_map
except ImportError:  # jax < 0.5 keeps it in experimental
    from jax.experimental.shard_map import shard_map as _shard_map

_HAS_CHECK_VMA = "check_vma" in inspect.signature(_shard_map).parameters


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """jax.shard_map with the current-release signature on any jax version."""
    kw = {"check_vma": check_vma} if _HAS_CHECK_VMA else {"check_rep": check_vma}
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)


__all__ = ["shard_map"]
