from . import collectives, rdp, sharding

__all__ = ["collectives", "rdp", "sharding"]
