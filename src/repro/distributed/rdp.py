"""Replicated data parallelism: the paper's policy as a mesh factorization.

A ``RedundancyPlan`` (B shards x r replicas over N = B*r data-parallel
groups) maps onto the mesh by splitting the data axis into
("replica", "shard").  Because every replica group consumes the *same*
shard (balanced non-overlapping assignment), psum over both axes equals
plain DP -- but the system gains:

  * fault tolerance: losing any worker of a replica group loses no data
    shard and no gradient contribution (the group's siblings carry it);
  * first-of-r semantics: a multi-controller deployment can proceed on the
    fastest member of each group (T = max_B min_r -- the paper's job time);
  * elastic replanning: on membership change, the planner re-picks (B, r)
    from the measured step-time distribution and only the mesh factorization
    changes -- data placement is counter-deterministic (see data.pipeline).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
import numpy as np

from ..core import batching
from ..core.planner import RedundancyPlan, RedundancyPlanner
from ..core.service_time import ServiceTime


def make_rdp_mesh(plan: RedundancyPlan, model_parallel: int) -> jax.sharding.Mesh:
    """Mesh ("replica", "shard", "model") realizing a replication plan."""
    return jax.make_mesh(
        (plan.replication, plan.n_batches, model_parallel),
        ("replica", "shard", "model"),
    )


def assignment_matrix(plan: RedundancyPlan) -> np.ndarray:
    """(N workers x B shards) membership of the balanced policy."""
    return batching.non_overlapping(
        n_tasks=plan.n_batches * plan.replication,
        n_batches=plan.n_batches,
        n_workers=plan.n_workers,
    )


def surviving_coverage(plan: RedundancyPlan, healthy: Sequence[bool]) -> dict:
    """After failures, which shards still have >= 1 replica?

    Returns {"covered": bool, "replicas_per_shard": [..], "lost_shards": [..]}.
    """
    healthy = np.asarray(healthy, dtype=bool)
    assert healthy.shape[0] == plan.n_workers
    shard_of = np.arange(plan.n_workers) % plan.n_batches
    reps = np.zeros(plan.n_batches, dtype=np.int64)
    np.add.at(reps, shard_of[healthy], 1)
    lost = np.flatnonzero(reps == 0).tolist()
    return {
        "covered": not lost,
        "replicas_per_shard": reps.tolist(),
        "lost_shards": lost,
    }


@dataclasses.dataclass(frozen=True)
class Transition:
    old_plan: RedundancyPlan
    new_plan: RedundancyPlan
    reason: str

    @property
    def mesh_change(self) -> Tuple[Tuple[int, int], Tuple[int, int]]:
        return (
            (self.old_plan.replication, self.old_plan.n_batches),
            (self.new_plan.replication, self.new_plan.n_batches),
        )


class ElasticController:
    """Replans (B, r) on membership changes using the paper's planner.

    The controller is given the fitted/assumed step service-time model; on
    worker loss it picks the best feasible plan for the surviving count.
    A step-time observer can also trigger replanning when the fitted
    distribution drifts (straggler onset).
    """

    def __init__(self, dist: ServiceTime, objective: str = "mean"):
        self.dist = dist
        self.objective = objective

    def initial_plan(self, n_workers: int) -> RedundancyPlan:
        return RedundancyPlanner(n_workers).plan(self.dist, self.objective)

    def on_membership_change(
        self, plan: RedundancyPlan, n_healthy: int, reason: str = "failure"
    ) -> Optional[Transition]:
        if n_healthy == plan.n_workers:
            return None
        new_plan = RedundancyPlanner(n_healthy).plan(self.dist, self.objective)
        return Transition(old_plan=plan, new_plan=new_plan, reason=reason)

    def on_observed_step_times(
        self, plan: RedundancyPlan, samples: np.ndarray, refit_threshold: float = 0.2
    ) -> Optional[Transition]:
        """Refit the service-time distribution from observed per-worker step
        times; replan if the optimal B moved by more than ``refit_threshold``."""
        planner = RedundancyPlanner(plan.n_workers)
        new_plan = planner.plan_auto(samples, self.objective)
        rel = abs(new_plan.n_batches - plan.n_batches) / max(plan.n_batches, 1)
        if rel > refit_threshold:
            return Transition(old_plan=plan, new_plan=new_plan, reason="drift")
        return None
