"""Logical sharding annotations for model code.

Model layers call ``shard(x, "batch", None, "model", ...)`` with one logical
role per dim; under an active ``logical_axes`` context (set by the step-
function wrappers at trace time) this becomes a
``jax.lax.with_sharding_constraint`` pinning the activation to the mesh.
Without a context (single-device smoke tests) it is a no-op.

These constraints are what keep XLA's SPMD propagation honest through scan
carries (layer scan, flash-attention KV scan, SSD chunk scan): an
unannotated zeros-init carry otherwise replicates the whole loop over the
model axis (observed in the dry-run: 16x FLOPs and TB-scale all-reduces).
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_CURRENT: list = []


@dataclasses.dataclass(frozen=True)
class LogicalAxes:
    mesh: Mesh
    batch: Tuple[str, ...]  # mesh axes carrying the global batch
    model: Optional[str]  # tensor-parallel axis
    seq: bool = False  # sequence parallelism: residual stream seq-shards over model

    def axis_size(self, names) -> int:
        size = 1
        for n in [names] if isinstance(names, str) else names:
            size *= self.mesh.shape[n]
        return size


@contextlib.contextmanager
def logical_axes(
    mesh: Mesh, batch: Tuple[str, ...], model: Optional[str], seq: bool = False
):
    _CURRENT.append(LogicalAxes(mesh, tuple(batch), model, seq))
    try:
        yield
    finally:
        _CURRENT.pop()


def current() -> Optional[LogicalAxes]:
    return _CURRENT[-1] if _CURRENT else None


def shard(x: jax.Array, *roles) -> jax.Array:
    """Constrain x's sharding by logical dim roles ('batch' | 'model' | None)."""
    ctx = current()
    if ctx is None:
        return x
    assert len(roles) == x.ndim, (roles, x.shape)
    U = P.UNCONSTRAINED
    spec = []
    for dim, role in zip(x.shape, roles):
        if role is None:
            spec.append(None)  # explicitly replicated on this dim
            continue
        if role == "residual":
            # sequence-parallel residual stream: seq dim shards over the TP
            # axis (Megatron-SP); plain TP keeps it replicated
            if not ctx.seq:
                spec.append(None)
                continue
            role = "model"
        names = ctx.batch if role == "batch" else ctx.model
        if not names:
            spec.append(U)  # no axis mapped: leave to the partitioner
            continue
        if dim % ctx.axis_size(names):
            # non-dividing dim: P(None) would FORCE replication -- leave the
            # dim unconstrained instead so propagation can still shard it
            spec.append(U)
        else:
            spec.append(names if isinstance(names, str) else tuple(names))
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, P(*spec)))
