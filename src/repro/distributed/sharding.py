"""Sharding rules: param / batch / cache PartitionSpecs for any mesh.

Axis roles are logical (DESIGN.md §4):
  * ``batch``  -- tuple of mesh axes carrying the global batch
                  (("pod","data") multi-pod, ("data",) single-pod, or
                  ("replica","shard") under a replication plan)
  * ``fsdp``   -- axis sharding parameters/optimizer state (ZeRO-3 style)
  * ``model``  -- tensor-parallel axis (heads / d_ff / vocab / experts)

Rules are keyed by parameter leaf name (the model zoo uses consistent
names); every rule is divisibility-checked against the actual mesh so a
non-dividing dim silently degrades to replication instead of failing --
the dry-run report shows what actually sharded.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Role = Optional[str]  # 'fsdp' | 'model' | 'batch' | None


@dataclasses.dataclass(frozen=True)
class MeshAxes:
    batch: Tuple[str, ...]
    fsdp: Optional[str]
    model: Optional[str]

    @staticmethod
    def infer(mesh: Mesh) -> "MeshAxes":
        names = mesh.axis_names
        model = "model" if "model" in names else None
        if "replica" in names and "shard" in names:
            batch: Tuple[str, ...] = ("shard",)  # replicas recompute, shards carry data
            fsdp = "shard"
        else:
            batch = tuple(n for n in names if n in ("pod", "data"))
            fsdp = "data" if "data" in names else None
        return MeshAxes(batch=batch, fsdp=fsdp, model=model)

    @staticmethod
    def dp_over_model(mesh: Mesh) -> "MeshAxes":
        """Repurpose the TP axis as extra data parallelism (small models:
        TP=16 on a 1.5B model burns ICI on psums; pure DP=256 does not)."""
        names = mesh.axis_names
        batch = tuple(n for n in names if n in ("pod", "data", "model"))
        fsdp = "data" if "data" in names else None
        return MeshAxes(batch=batch, fsdp=fsdp, model=None)


# ---------------------------------------------------------------------------
# per-leaf role rules (by trailing-dims rank after removing stacking dims)
# ---------------------------------------------------------------------------

# name -> {rank: roles}
_PARAM_RULES: Dict[str, Dict[int, Tuple[Role, ...]]] = {
    # embeddings
    "embed": {2: ("model", "fsdp")},  # (V, d): vocab col-parallel for unembed
    "lm_head": {2: ("fsdp", "model")},
    # attention
    "wq": {2: ("fsdp", "model")},
    "wk": {2: ("fsdp", None)},  # true-KV replicated over model (see DESIGN §4)
    "wv": {2: ("fsdp", None)},
    "wo": {2: ("model", "fsdp")},
    "bq": {1: ("model",)},
    "bk": {1: (None,)},
    "bv": {1: (None,)},
    # dense MLP (2D) and MoE experts (3D)
    "w_gate": {2: ("fsdp", "model"), 3: ("model", "fsdp", None)},
    "w_up": {2: ("fsdp", "model"), 3: ("model", "fsdp", None)},
    "w_down": {2: ("model", "fsdp"), 3: ("model", None, "fsdp")},
    "w_in": {2: ("fsdp", "model")},
    "w_out": {2: ("model", "fsdp")},
    "b_in": {1: ("model",)},
    "b_out": {1: (None,)},
    "router": {2: (None, None)},
    # mamba2 mixer
    "w_z": {2: ("fsdp", "model")},
    "w_x": {2: ("fsdp", "model")},
    "w_bc": {2: ("fsdp", None)},
    "w_dt": {2: ("fsdp", "model")},
    "conv_x": {2: (None, "model")},
    "conv_x_b": {1: ("model",)},
    "conv_bc": {2: (None, None)},
    "conv_bc_b": {1: (None,)},
    "A_log": {1: ("model",)},
    "dt_bias": {1: ("model",)},
    "D": {1: ("model",)},
    "norm_w": {1: ("model",)},  # over d_inner (head-aligned)
    "out_proj": {2: ("model", "fsdp")},
    # rg-lru
    "w_y": {2: ("fsdp", "model")},
    "conv_w": {2: (None, "model")},
    "conv_b": {1: ("model",)},
    "w_a": {3: ("model", None, None)},
    "w_i": {3: ("model", None, None)},
    "b_a": {1: ("model",)},
    "b_i": {1: ("model",)},
    "lam": {1: ("model",)},
}

_CACHE_RULES: Dict[str, Dict[int, Tuple[Role, ...]]] = {
    "k": {4: ("batch0", None, "model", None)},  # (B, W, K_pad, hd)
    "v": {4: ("batch0", None, "model", None)},
    "pos": {1: (None,)},
    # sequence-sharded true-KV mode: ring buffer shards over the TP axis
    "ks": {4: ("batch0", "model", None, None)},
    "vs": {4: ("batch0", "model", None, None)},
    "poss": {1: ("model",)},
    "conv_x": {3: ("batch0", None, "model")},
    "conv_bc": {3: ("batch0", None, None)},
    "conv": {3: ("batch0", None, "model")},  # rglru conv tail (B, 3, D)
    "h": {2: ("batch0", "model"), 4: ("batch0", "model", None, None)},
}


def _axis_size(mesh: Mesh, name: Optional[str]) -> int:
    if name is None:
        return 1
    return int(np.prod([mesh.shape[a] for a in ([name] if isinstance(name, str) else name)]))


def _resolve(mesh: Mesh, axes: MeshAxes, roles: Tuple[Role, ...], shape) -> P:
    spec = []
    for dim, role in zip(shape, roles):
        if role is None:
            spec.append(None)
            continue
        if role == "batch0":
            names: Any = axes.batch
        elif role == "fsdp":
            names = axes.fsdp
        elif role == "model":
            names = axes.model
        else:
            raise ValueError(role)
        if names is None or (isinstance(names, tuple) and not names):
            spec.append(None)
            continue
        size = _axis_size(mesh, names if isinstance(names, str) else tuple(names))
        if dim % size:
            spec.append(None)  # non-dividing dim degrades to replication
        else:
            spec.append(names if isinstance(names, str) else tuple(names))
    return P(*spec)


def _leaf_spec(
    mesh: Mesh, axes: MeshAxes, rules: Dict[str, Dict[int, Tuple[Role, ...]]],
    path, leaf,
) -> P:
    name = None
    for part in reversed(path):
        key = getattr(part, "key", None)
        if isinstance(key, str):
            name = key
            break
    table = rules.get(name) if name else None
    if table is None:
        return P()  # replicate (norm scales, scalars, unknown leaves)
    shape = leaf.shape
    for rank in sorted(table, reverse=True):
        if len(shape) == rank:
            return _resolve(mesh, axes, table[rank], shape)
        if len(shape) > rank:
            # stacked (scan-over-layers / pattern groups): leading dims unsharded
            lead = len(shape) - rank
            inner = _resolve(mesh, axes, table[rank], shape[lead:])
            return P(*([None] * lead), *inner)
    return P()


def param_shardings(mesh: Mesh, params_spec, axes: Optional[MeshAxes] = None):
    """NamedSharding pytree for params (or congruent opt-state moments)."""
    axes = axes or MeshAxes.infer(mesh)
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(
            mesh, _leaf_spec(mesh, axes, _PARAM_RULES, path, leaf)
        ),
        params_spec,
    )


def cache_shardings(mesh: Mesh, cache_spec, axes: Optional[MeshAxes] = None):
    axes = axes or MeshAxes.infer(mesh)
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(
            mesh, _leaf_spec(mesh, axes, _CACHE_RULES, path, leaf)
        ),
        cache_spec,
    )


def batch_shardings(mesh: Mesh, batch_spec, axes: Optional[MeshAxes] = None):
    """Batch dict: dim 0 over the batch axes, rest replicated."""
    axes = axes or MeshAxes.infer(mesh)
    bt = tuple(axes.batch)

    def spec(path, leaf):
        size = _axis_size(mesh, bt) if bt else 1
        if leaf.ndim >= 1 and size > 1 and leaf.shape[0] % size == 0:
            return NamedSharding(mesh, P(bt, *([None] * (leaf.ndim - 1))))
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map_with_path(spec, batch_spec)


def scalar_sharding(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def describe(shardings) -> Dict[str, str]:
    """path -> spec string (dry-run report)."""
    out = {}
    for path, s in jax.tree_util.tree_flatten_with_path(shardings)[0]:
        out[jax.tree_util.keystr(path)] = str(s.spec)
    return out
