"""jit'd public wrappers over the Pallas kernels.

``interpret`` defaults to True off-TPU so the kernels run (and are tested)
on CPU; on a real TPU backend the compiled kernel path is taken.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention_fwd
from .rmsnorm import rms_norm_fused


@functools.cache
def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def attention(
    q: jax.Array,  # model layout: (B, S, H, hd)
    k: jax.Array,  # (B, S, KH, hd)
    v: jax.Array,
    causal: bool = True,
    window: int | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """Flash-attention with the model's (B, S, H, hd) layout."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    out = flash_attention_fwd(
        qt, kt, vt, causal=causal, window=window,
        block_q=block_q, block_k=block_k, interpret=interpret,
    )
    return jnp.swapaxes(out, 1, 2)


def rmsnorm(
    x: jax.Array,
    weight: jax.Array,
    eps: float = 1e-6,
    plus_one: bool = False,
    interpret: bool | None = None,
) -> jax.Array:
    interpret = (not _on_tpu()) if interpret is None else interpret
    return rms_norm_fused(x, weight, eps=eps, plus_one=plus_one, interpret=interpret)
