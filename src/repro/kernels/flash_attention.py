"""Pallas TPU flash-attention forward kernel (causal / sliding-window, GQA).

TPU adaptation notes (vs the CUDA flash-attention algorithm):
  * tiling targets VMEM, not shared memory: BlockSpecs stage (bq, hd) query
    tiles and (bk, hd) KV tiles HBM->VMEM; hd (128/256) and the 128-multiple
    block sizes keep the MXU systolic array fully fed;
  * the softmax running max/sum lives in fp32 VMEM scratch across the
    "arbitrary" (sequential) KV grid dimension -- the TPU analogue of keeping
    the accumulator in registers across the SM inner loop;
  * fully-masked KV tiles are skipped with ``pl.when`` predication (the
    block-causal skip), which on TPU removes both the MXU work and the HBM
    reads for those tiles.

Grid: (batch, q_heads, Sq/bq, Sk/bk), last dim sequential.
Layout: (B, H, S, hd) -- ops.py transposes from the model's (B, S, H, hd).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .pallas_compat import CompilerParams as _CompilerParams

NEG_INF = float(jnp.finfo(jnp.float32).min / 2)


def _kernel(
    q_ref, k_ref, v_ref,  # VMEM tiles
    o_ref,  # output tile
    m_ref, l_ref, acc_ref,  # fp32 scratch
    *,
    scale: float,
    causal: bool,
    window: int | None,
    block_q: int,
    block_k: int,
    seq_k: int,
):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = iq * block_q
    k_start = ik * block_k

    # block-level relevance: skip tiles that are entirely masked out
    relevant = True
    if causal:
        relevant = q_start + block_q - 1 >= k_start  # some i >= j in tile
    if window is not None:
        relevant = jnp.logical_and(
            relevant, q_start - (k_start + block_k - 1) < window
        )

    @pl.when(relevant)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)  # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (bq, bk)

        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = kpos < seq_k  # padding tail
        if causal:
            mask = jnp.logical_and(mask, kpos <= qpos)
        if window is not None:
            mask = jnp.logical_and(mask, qpos - kpos < window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        lsum = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / lsum[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret", "scale"),
)
def flash_attention_fwd(
    q: jax.Array,  # (B, H, Sq, hd)
    k: jax.Array,  # (B, KH, Sk, hd)
    v: jax.Array,
    causal: bool = True,
    window: int | None = None,
    block_q: int = 128,
    block_k: int = 128,
    scale: float | None = None,
    interpret: bool = False,
) -> jax.Array:
    b, h, sq, hd = q.shape
    kh, sk = k.shape[1], k.shape[2]
    assert h % kh == 0, (h, kh)
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)

    bq = min(block_q, sq)
    bk = min(block_k, sk)
    pad_q = (-sq) % bq
    pad_k = (-sk) % bk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    nq = (sq + pad_q) // bq
    nk = (sk + pad_k) // bk
    g = h // kh  # query heads per kv head

    kernel = functools.partial(
        _kernel,
        scale=scale,
        causal=causal,
        window=window,
        block_q=bq,
        block_k=bk,
        seq_k=sk,
    )
    grid = (b, h, nq, nk)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda ib, ih, iq, ik: (ib, ih // g, ik, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda ib, ih, iq, ik: (ib, ih // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq + pad_q, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :sq]
