"""Pure-jnp oracles for the Pallas kernels (allclose targets)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = float(jnp.finfo(jnp.float32).min / 2)


def flash_attention_ref(
    q: jax.Array,  # (B, H, Sq, hd)
    k: jax.Array,  # (B, KH, Sk, hd)
    v: jax.Array,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
) -> jax.Array:
    b, h, sq, hd = q.shape
    kh, sk = k.shape[1], k.shape[2]
    g = h // kh
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    k = jnp.repeat(k, g, axis=1)
    v = jnp.repeat(v, g, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= qpos - kpos < window
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)


def rms_norm_ref(x: jax.Array, weight: jax.Array, eps: float = 1e-6, plus_one: bool = False):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    w = weight.astype(jnp.float32)
    if plus_one:
        w = 1.0 + w
    return (y * w).astype(x.dtype)
