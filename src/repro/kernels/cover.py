"""Pallas masked earliest-cover reduction (the ``max_b min_r`` kernel).

The vectorized cluster backends spend their inner loop on one reduction:
mask a padded ``(B_pad, r_pad)`` replica grid to the candidate's (B, r) and
take the earliest-cover time ``max_b min_r`` (`repro.core.simulator
.gang_cover_times`).  XLA fuses the two reductions well on CPU; this module
carries the fused Pallas formulation so the masked mask+min+max runs as one
VMEM pass per rep tile on TPU, plus the measurement hook that decides
whether routing the frontier kernel through it is worth it on the current
backend.

Measurement (recorded by ``bench_masked_cover``): on this repo's CPU CI the
kernel only runs under ``interpret=True``, where it loses to the XLA fusion
-- ~10x at 16k reps and ~60x at 64k reps x (16, 16) grids (interpret
overhead scales with the grid) -- so :func:`repro.cluster.vectorized` keeps
the jnp path unless ``REPRO_PALLAS_COVER=1`` is set *and* a TPU backend is
present.  On TPU the fused pass saves one VMEM round-trip of the
``(reps, B_pad)`` batch-min intermediate; re-run ``bench_masked_cover()``
there before flipping the default.
"""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

__all__ = ["masked_cover_times", "bench_masked_cover", "pallas_cover_wins"]


def _kernel(d_ref, b_ref, r_ref, o_ref):
    d = d_ref[...]  # (rows, B_pad, r_pad)
    b, r = b_ref[0], r_ref[0]
    b_pad, r_pad = d.shape[-2], d.shape[-1]
    masked = jnp.where(jax.lax.iota(jnp.int32, r_pad)[None, None, :] < r, d, jnp.inf)
    t_batch = jnp.min(masked, axis=-1)  # (rows, B_pad)
    t_batch = jnp.where(
        jax.lax.iota(jnp.int32, b_pad)[None, :] < b, t_batch, -jnp.inf
    )
    o_ref[...] = jnp.max(t_batch, axis=-1)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def masked_cover_times(
    draws: jax.Array,  # (reps, B_pad, r_pad) replica durations
    n_batches: jax.Array,  # scalar B (traced ok)
    replication: jax.Array,  # scalar r
    block_rows: int = 512,
    interpret: bool = True,
) -> jax.Array:
    """Fused masked ``max_b min_r`` over a padded replica grid.

    Semantically identical to ``gang_cover_times(draws, n_batches,
    replication)``; one VMEM pass per ``block_rows`` tile of reps.
    ``interpret=True`` (the default) runs everywhere for differential
    testing; pass ``interpret=False`` on a real TPU backend.
    """
    reps, b_pad, r_pad = draws.shape
    br = min(block_rows, max(reps, 1))
    pad = (-reps) % br
    if pad:
        draws = jnp.pad(draws, ((0, pad), (0, 0), (0, 0)))
    grid = ((reps + pad) // br,)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, b_pad, r_pad), lambda i: (i, 0, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((reps + pad,), draws.dtype),
        interpret=interpret,
    )(
        draws,
        jnp.asarray(n_batches, jnp.int32).reshape(1),
        jnp.asarray(replication, jnp.int32).reshape(1),
    )
    return out[:reps]


def pallas_cover_wins() -> bool:
    """Should the frontier kernel route through the Pallas cover reduction?

    Only when a TPU backend can compile it natively -- interpret mode on
    CPU loses to the XLA fusion by orders of magnitude (see module note).
    """
    import os

    if os.environ.get("REPRO_PALLAS_COVER") != "1":
        return False
    return jax.default_backend() == "tpu"


def bench_masked_cover(reps: int = 4096, b_pad: int = 8, r_pad: int = 8, iters: int = 5):
    """Wall-clock the Pallas cover kernel against the XLA jnp fusion.

    Returns ``{"pallas_seconds", "jnp_seconds", "pallas_wins"}`` -- the
    measurement the tentpole asked for, runnable on any backend (interpret
    mode off-TPU).
    """
    from ..core.simulator import gang_cover_times

    key = jax.random.key(0)
    draws = jax.random.exponential(key, (reps, b_pad, r_pad))
    b = jnp.asarray(b_pad // 2, jnp.int32)
    r = jnp.asarray(r_pad // 2, jnp.int32)
    interpret = jax.default_backend() != "tpu"
    oracle = jax.jit(gang_cover_times)

    jax.block_until_ready(masked_cover_times(draws, b, r, interpret=interpret))
    jax.block_until_ready(oracle(draws, b, r))
    t_pallas, t_jnp = [], []
    for _ in range(iters):
        t0 = time.time()
        jax.block_until_ready(masked_cover_times(draws, b, r, interpret=interpret))
        t_pallas.append(time.time() - t0)
        t0 = time.time()
        jax.block_until_ready(oracle(draws, b, r))
        t_jnp.append(time.time() - t0)
    out = {
        "pallas_seconds": float(np.min(t_pallas)),
        "jnp_seconds": float(np.min(t_jnp)),
        "interpret": interpret,
    }
    out["pallas_wins"] = out["pallas_seconds"] < out["jnp_seconds"]
    return out
