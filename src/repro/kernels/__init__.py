"""Pallas TPU kernels for the framework's compute hot-spots.

The paper's contribution is a scheduling algorithm (no custom kernel of its
own); these kernels serve the model substrate that the replication-planned
training runs on -- flash attention (the prefill/train hot-spot) and fused
RMSNorm -- plus ``cover.py``, the fused masked earliest-cover reduction
behind the cluster backends' frontier sweeps (TPU opt-in; CPU keeps the XLA
fusion, see its recorded measurement).  Validated on CPU with
interpret=True against oracles (ref.py / core.simulator).
"""
from .cover import bench_masked_cover, masked_cover_times
from .ops import attention, rmsnorm

__all__ = ["attention", "rmsnorm", "masked_cover_times", "bench_masked_cover"]
