"""Pallas TPU kernels for the framework's compute hot-spots.

The paper's contribution is a scheduling algorithm (no custom kernel of its
own); these kernels serve the model substrate that the replication-planned
training runs on: flash attention (the prefill/train hot-spot) and fused
RMSNorm.  Validated on CPU with interpret=True against ref.py oracles.
"""
from .ops import attention, rmsnorm

__all__ = ["attention", "rmsnorm"]
