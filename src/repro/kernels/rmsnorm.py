"""Pallas TPU fused RMSNorm kernel.

One HBM pass per row tile: mean-square, rsqrt and scale are fused in VMEM
(the unfused jnp version reads x twice and materializes the normalized
intermediate in HBM).  Rows tile over a parallel grid dimension.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .pallas_compat import CompilerParams as _CompilerParams


def _kernel(x_ref, w_ref, o_ref, *, eps: float, plus_one: bool):
    x = x_ref[...].astype(jnp.float32)  # (rows, d)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    w = w_ref[...].astype(jnp.float32)
    if plus_one:
        w = 1.0 + w
    o_ref[...] = (y * w).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("eps", "plus_one", "block_rows", "interpret")
)
def rms_norm_fused(
    x: jax.Array,  # (..., d)
    weight: jax.Array,  # (d,)
    eps: float = 1e-6,
    plus_one: bool = False,
    block_rows: int = 256,
    interpret: bool = False,
) -> jax.Array:
    orig_shape = x.shape
    d = orig_shape[-1]
    x2 = x.reshape(-1, d)
    n = x2.shape[0]
    br = min(block_rows, n)
    pad = (-n) % br
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    grid = ((n + pad) // br,)
    out = pl.pallas_call(
        functools.partial(_kernel, eps=eps, plus_one=plus_one),
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n + pad, d), x.dtype),
        compiler_params=_CompilerParams(dimension_semantics=("parallel",)),
        interpret=interpret,
    )(x2, weight)
    return out[:n].reshape(orig_shape)
