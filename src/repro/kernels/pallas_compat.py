"""Pallas API compatibility across jax releases.

``pltpu.TPUCompilerParams`` was renamed ``pltpu.CompilerParams``; import the
alias from here so every kernel tracks the rename in one place.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

__all__ = ["CompilerParams"]
