"""Efficient Replication for Straggler Mitigation (arXiv:2006.02318) as a system.

Layers, bottom to top:

  * ``repro.core``        -- the paper: batching schemes, service-time models,
    closed-form analysis, Monte-Carlo simulator, redundancy planner, traces.
  * ``repro.cluster``     -- event-driven master-worker engine that executes
    redundancy plans (queueing, cancellation, churn, online replanning).
  * ``repro.distributed`` -- the plan as a device-mesh factorization
    (replica x shard), collectives, elastic replanning controller.
  * ``repro.models`` / ``kernels`` / ``runtime`` / ``launch`` -- the jax/pallas
    training and serving stack the replication policy protects.
"""

__version__ = "0.1.0"
