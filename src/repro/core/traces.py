"""Synthetic Google-cluster-trace-like workloads (§VII stand-in).

The paper extracts per-task service times (finish - schedule timestamps) for
several jobs from the 2011 Google cluster traces [91] and observes two
families (Fig. 11): exponential-tail (jobs 1-4, shift ~ 10..1000) and
heavy-tail with near-linear log-CCDF decay (jobs 5-10).

The real traces are not redistributable inside this container, so we generate
statistically matched stand-ins: SExp jobs with large shifts for the
exponential family and Pareto/Lomax-mixture jobs for the heavy-tail family,
with sample sizes comparable to real job task counts.  The generator is
seeded and versioned so benchmark results are reproducible; the loader also
accepts external CSV/NPZ with real trace-derived task times if provided.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
from typing import Dict, List

import numpy as np

__all__ = [
    "TraceJob",
    "TraceStream",
    "STREAM_VERSION",
    "synthetic_google_jobs",
    "synthetic_cluster_day",
    "poisson_stream",
    "save_jobs",
    "load_jobs",
    "tail_family",
]


@dataclasses.dataclass(frozen=True)
class TraceJob:
    """One trace-derived job: a named bag of per-task service times."""

    name: str
    family: str  # 'exponential' | 'heavy'
    task_times: np.ndarray  # per-task service times (seconds)

    @property
    def n_tasks(self) -> int:
        """How many tasks the trace recorded for this job."""
        return int(self.task_times.size)


def synthetic_google_jobs(seed: int = 2020) -> List[TraceJob]:
    """Ten jobs mirroring the paper's Fig. 11 families.

    Jobs 1-4: exponential tail (SExp with shifts 10, 10, 10, 1000 -- the shift
    values the paper quotes for its Fig. 12 jobs).  Job 5 is the paper's
    borderline case (linear tail decay).  Jobs 6-10: heavy tail (Pareto with
    alpha in ~1.3..2.5, plus a slowdown mixture to mimic stragglers).
    """
    rng = np.random.default_rng(seed)
    jobs: List[TraceJob] = []

    sexp_params = [(10.0, 1 / 3.0), (10.0, 1 / 8.0), (10.0, 1 / 20.0), (1000.0, 1 / 150.0)]
    for i, (delta, mu) in enumerate(sexp_params, start=1):
        n = int(rng.integers(400, 1200))
        x = delta + rng.exponential(scale=1.0 / mu, size=n)
        jobs.append(TraceJob(name=f"job{i}", family="exponential", task_times=x))

    # job 5: borderline (the paper notes its optimum lands at B=50)
    n = int(rng.integers(400, 1200))
    sigma, alpha = 12.0, 3.0
    u = rng.uniform(size=n)
    x = sigma * u ** (-1.0 / alpha)
    jobs.append(TraceJob(name="job5", family="heavy", task_times=x))

    heavy_params = [(8.0, 1.4), (15.0, 1.8), (6.0, 1.3), (20.0, 2.2), (10.0, 1.6)]
    for i, (sigma, alpha) in enumerate(heavy_params, start=6):
        n = int(rng.integers(400, 1200))
        u = rng.uniform(size=n)
        x = sigma * u ** (-1.0 / alpha)
        # straggler mixture: 3% of tasks hit a 10-30x slowdown (trace artifact)
        mask = rng.uniform(size=n) < 0.03
        x = np.where(mask, x * rng.uniform(10.0, 30.0, size=n), x)
        jobs.append(TraceJob(name=f"job{i}", family="heavy", task_times=x))
    return jobs


# --------------------------------------------------------------------------
# trace-scale streams: thousands of jobs resampled from per-job ECDFs
# --------------------------------------------------------------------------

# Bump when the stream construction (arrival law, source assignment, ECDF
# inverse) changes incompatibly: the version is folded into every seed
# derivation, so old and new code can never silently produce the same draws.
STREAM_VERSION = 1


@dataclasses.dataclass(frozen=True, eq=False)
class TraceStream:
    """A cluster-scale workload: many arrivals resampling a few trace jobs.

    The paper's trace section evaluates tens of jobs; a cluster-*day* is
    thousands.  A stream keeps only what that scale needs -- sorted arrival
    times, a source-job id per arrival, and one concatenated sorted-sample
    buffer over the source jobs -- and resamples service times *per slab* via
    the ECDF inverse (``sorted_samples[floor(u * m)]``), so no caller ever
    materializes the full (reps x jobs x batches) draw tensor.

    Draws are seeded and versioned: ``sample_slab`` consumes a caller-owned
    ``numpy.random.Generator`` strictly left-to-right along the job axis, so
    the draws for jobs ``[lo, hi)`` are a prefix-stable function of the
    generator state -- any slab partition of the same stream yields the same
    numbers bit for bit.
    """

    arrivals: np.ndarray  # (n_jobs,) float64, sorted ascending
    job_ids: np.ndarray  # (n_jobs,) index into sources
    sources: tuple  # tuple[TraceJob, ...]
    seed: int
    version: int = STREAM_VERSION

    def __post_init__(self):
        arr = np.ascontiguousarray(np.asarray(self.arrivals, dtype=np.float64))
        if arr.ndim != 1 or arr.size == 0:
            raise ValueError("TraceStream needs a non-empty 1-D arrival vector")
        if np.any(np.diff(arr) < 0):
            raise ValueError("TraceStream arrivals must be sorted ascending")
        jid = np.ascontiguousarray(np.asarray(self.job_ids, dtype=np.int64))
        if jid.shape != arr.shape:
            raise ValueError("TraceStream job_ids must match arrivals in shape")
        if not self.sources:
            raise ValueError("TraceStream needs at least one source TraceJob")
        if jid.min() < 0 or jid.max() >= len(self.sources):
            raise ValueError("TraceStream job_ids index outside sources")
        object.__setattr__(self, "arrivals", arr)
        object.__setattr__(self, "job_ids", jid)
        # concatenated per-source sorted samples + offsets: one gather serves
        # every ECDF inverse draw of a slab
        sizes = np.array([s.n_tasks for s in self.sources], dtype=np.int64)
        off = np.zeros(len(self.sources), dtype=np.int64)
        np.cumsum(sizes[:-1], out=off[1:])
        flat = np.concatenate(
            [np.sort(np.asarray(s.task_times, dtype=np.float64)) for s in self.sources]
        )
        object.__setattr__(self, "_sizes", sizes)
        object.__setattr__(self, "_off", off)
        object.__setattr__(self, "_flat", flat)

    @property
    def n_jobs(self) -> int:
        """Stream length in jobs."""
        return int(self.arrivals.size)

    @property
    def n_tasks(self) -> np.ndarray:
        """Per-arrival task count: the source job's recorded task count."""
        return self._sizes[self.job_ids]

    def slabs(self, slab: int | None):
        """Yield ``(lo, hi)`` index ranges covering the stream in order."""
        n = self.n_jobs
        slab = n if slab is None else int(slab)
        if slab <= 0:
            raise ValueError(f"slab must be positive, got {slab}")
        for lo in range(0, n, slab):
            yield lo, min(lo + slab, n)

    def make_rng(self, rep: int) -> np.random.Generator:
        """The rep's draw stream, derived from (seed, version, rep)."""
        return np.random.default_rng(
            np.random.SeedSequence((int(self.seed), int(self.version), int(rep)))
        )

    def sample_slab(self, rng: np.random.Generator, lo: int, hi: int, n_slots: int):
        """ECDF-inverse service draws for jobs ``[lo, hi)``: (hi-lo, n_slots).

        Row ``i`` draws ``n_slots`` iid samples from the empirical
        distribution of source job ``job_ids[lo + i]`` -- the inverse-CDF
        transform on its sorted task times.  Exactly ``(hi-lo) * n_slots``
        uniforms are consumed, row-major, so slab partitioning never changes
        which uniform lands on which (job, slot) pair.
        """
        jid = self.job_ids[lo:hi]
        u = rng.random((hi - lo, int(n_slots)))
        m = self._sizes[jid][:, None]
        idx = np.minimum((u * m).astype(np.int64), m - 1)
        return self._flat[self._off[jid][:, None] + idx]


def synthetic_cluster_day(
    n_jobs: int = 10_000,
    duration: float = 86_400.0,
    seed: int = 7,
    families=("exponential", "heavy"),
    trace_seed: int = 2020,
) -> TraceStream:
    """A synthetic cluster-day: ``n_jobs`` arrivals over ``duration`` seconds.

    Arrivals are sorted uniforms over the day (a Poisson process conditioned
    on its count) and each arrival resamples one of the
    :func:`synthetic_google_jobs` source jobs restricted to ``families``,
    chosen uniformly.  Fully determined by ``(seed, trace_seed,
    STREAM_VERSION)``.
    """
    sources = tuple(
        j for j in synthetic_google_jobs(trace_seed) if j.family in families
    )
    if not sources:
        raise ValueError(f"no synthetic trace jobs in families {families!r}")
    rng = np.random.default_rng(
        np.random.SeedSequence((int(seed), STREAM_VERSION, 0xDA7))
    )
    arrivals = np.sort(rng.uniform(0.0, float(duration), size=int(n_jobs)))
    job_ids = rng.integers(0, len(sources), size=int(n_jobs))
    return TraceStream(arrivals=arrivals, job_ids=job_ids, sources=sources, seed=seed)


def poisson_stream(
    sources,
    arrival_rate: float,
    n_jobs: int,
    seed: int = 0,
) -> TraceStream:
    """A Poisson-arrival :class:`TraceStream` over the given source jobs.

    Inter-arrival gaps are iid Exponential(``arrival_rate``) and each
    arrival resamples one source job chosen uniformly -- the offered-load
    model :meth:`repro.core.planner.RedundancyPlanner.plan_slo` evaluates
    SLO candidates under.  Fully determined by ``(seed, STREAM_VERSION)``
    and the sources, like every stream.

    ``sources`` are :class:`TraceJob` objects; wrap a parametric
    service-time model via its sampled task times, e.g.
    ``TraceJob("exp", "exponential", dist.sample_np(rng, (4000,)))``.
    """
    sources = tuple(sources)
    if not sources:
        raise ValueError("poisson_stream needs at least one source TraceJob")
    if not (arrival_rate > 0.0):
        raise ValueError(f"arrival_rate must be > 0, got {arrival_rate}")
    rng = np.random.default_rng(
        np.random.SeedSequence((int(seed), STREAM_VERSION, 0x510))
    )
    gaps = rng.exponential(scale=1.0 / float(arrival_rate), size=int(n_jobs))
    arrivals = np.cumsum(gaps)
    job_ids = rng.integers(0, len(sources), size=int(n_jobs))
    return TraceStream(arrivals=arrivals, job_ids=job_ids, sources=sources, seed=seed)


def tail_family(task_times: np.ndarray) -> str:
    """Classify exponential vs heavy tail from the empirical log-CCDF.

    Heuristic used by the paper's Fig. 11 discussion: fit the upper-quartile
    log-CCDF against t (exponential decay => linear in t) and against log t
    (power law => linear in log t); pick the better fit.
    """
    x = np.sort(np.asarray(task_times, dtype=np.float64))
    n = x.size
    ccdf = 1.0 - (np.arange(1, n + 1) - 0.5) / n
    # use the top half of the distribution, drop zeros
    sel = slice(n // 2, n - 1)
    t, p = x[sel], ccdf[sel]
    good = p > 0
    t, p = t[good], np.log(p[good])
    if t.size < 8:
        return "exponential"

    def r2(u, v):
        a = np.polyfit(u, v, 1)
        resid = v - np.polyval(a, u)
        ss = ((v - v.mean()) ** 2).sum()
        return 1.0 - (resid**2).sum() / max(ss, 1e-12)

    r2_exp = r2(t, p)  # log-CCDF vs t
    r2_pow = r2(np.log(t), p)  # log-CCDF vs log t
    return "heavy" if r2_pow > r2_exp else "exponential"


def save_jobs(jobs: List[TraceJob], path: str | pathlib.Path) -> None:
    """Write jobs as a compressed ``.npz`` plus a ``.json`` family sidecar."""
    path = pathlib.Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    arrays = {j.name: j.task_times for j in jobs}
    meta = {j.name: j.family for j in jobs}
    np.savez_compressed(path.with_suffix(".npz"), **arrays)
    path.with_suffix(".json").write_text(json.dumps(meta, indent=2))


def load_jobs(path: str | pathlib.Path) -> List[TraceJob]:
    """Read back what :func:`save_jobs` wrote."""
    path = pathlib.Path(path)
    data = np.load(path.with_suffix(".npz"))
    meta: Dict[str, str] = json.loads(path.with_suffix(".json").read_text())
    return [TraceJob(name=k, family=meta[k], task_times=data[k]) for k in data.files]
