"""Synthetic Google-cluster-trace-like workloads (§VII stand-in).

The paper extracts per-task service times (finish - schedule timestamps) for
several jobs from the 2011 Google cluster traces [91] and observes two
families (Fig. 11): exponential-tail (jobs 1-4, shift ~ 10..1000) and
heavy-tail with near-linear log-CCDF decay (jobs 5-10).

The real traces are not redistributable inside this container, so we generate
statistically matched stand-ins: SExp jobs with large shifts for the
exponential family and Pareto/Lomax-mixture jobs for the heavy-tail family,
with sample sizes comparable to real job task counts.  The generator is
seeded and versioned so benchmark results are reproducible; the loader also
accepts external CSV/NPZ with real trace-derived task times if provided.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
from typing import Dict, List

import numpy as np

__all__ = ["TraceJob", "synthetic_google_jobs", "save_jobs", "load_jobs", "tail_family"]


@dataclasses.dataclass(frozen=True)
class TraceJob:
    name: str
    family: str  # 'exponential' | 'heavy'
    task_times: np.ndarray  # per-task service times (seconds)

    @property
    def n_tasks(self) -> int:
        return int(self.task_times.size)


def synthetic_google_jobs(seed: int = 2020) -> List[TraceJob]:
    """Ten jobs mirroring the paper's Fig. 11 families.

    Jobs 1-4: exponential tail (SExp with shifts 10, 10, 10, 1000 -- the shift
    values the paper quotes for its Fig. 12 jobs).  Job 5 is the paper's
    borderline case (linear tail decay).  Jobs 6-10: heavy tail (Pareto with
    alpha in ~1.3..2.5, plus a slowdown mixture to mimic stragglers).
    """
    rng = np.random.default_rng(seed)
    jobs: List[TraceJob] = []

    sexp_params = [(10.0, 1 / 3.0), (10.0, 1 / 8.0), (10.0, 1 / 20.0), (1000.0, 1 / 150.0)]
    for i, (delta, mu) in enumerate(sexp_params, start=1):
        n = int(rng.integers(400, 1200))
        x = delta + rng.exponential(scale=1.0 / mu, size=n)
        jobs.append(TraceJob(name=f"job{i}", family="exponential", task_times=x))

    # job 5: borderline (the paper notes its optimum lands at B=50)
    n = int(rng.integers(400, 1200))
    sigma, alpha = 12.0, 3.0
    u = rng.uniform(size=n)
    x = sigma * u ** (-1.0 / alpha)
    jobs.append(TraceJob(name="job5", family="heavy", task_times=x))

    heavy_params = [(8.0, 1.4), (15.0, 1.8), (6.0, 1.3), (20.0, 2.2), (10.0, 1.6)]
    for i, (sigma, alpha) in enumerate(heavy_params, start=6):
        n = int(rng.integers(400, 1200))
        u = rng.uniform(size=n)
        x = sigma * u ** (-1.0 / alpha)
        # straggler mixture: 3% of tasks hit a 10-30x slowdown (trace artifact)
        mask = rng.uniform(size=n) < 0.03
        x = np.where(mask, x * rng.uniform(10.0, 30.0, size=n), x)
        jobs.append(TraceJob(name=f"job{i}", family="heavy", task_times=x))
    return jobs


def tail_family(task_times: np.ndarray) -> str:
    """Classify exponential vs heavy tail from the empirical log-CCDF.

    Heuristic used by the paper's Fig. 11 discussion: fit the upper-quartile
    log-CCDF against t (exponential decay => linear in t) and against log t
    (power law => linear in log t); pick the better fit.
    """
    x = np.sort(np.asarray(task_times, dtype=np.float64))
    n = x.size
    ccdf = 1.0 - (np.arange(1, n + 1) - 0.5) / n
    # use the top half of the distribution, drop zeros
    sel = slice(n // 2, n - 1)
    t, p = x[sel], ccdf[sel]
    good = p > 0
    t, p = t[good], np.log(p[good])
    if t.size < 8:
        return "exponential"

    def r2(u, v):
        a = np.polyfit(u, v, 1)
        resid = v - np.polyval(a, u)
        ss = ((v - v.mean()) ** 2).sum()
        return 1.0 - (resid**2).sum() / max(ss, 1e-12)

    r2_exp = r2(t, p)  # log-CCDF vs t
    r2_pow = r2(np.log(t), p)  # log-CCDF vs log t
    return "heavy" if r2_pow > r2_exp else "exponential"


def save_jobs(jobs: List[TraceJob], path: str | pathlib.Path) -> None:
    path = pathlib.Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    arrays = {j.name: j.task_times for j in jobs}
    meta = {j.name: j.family for j in jobs}
    np.savez_compressed(path.with_suffix(".npz"), **arrays)
    path.with_suffix(".json").write_text(json.dumps(meta, indent=2))


def load_jobs(path: str | pathlib.Path) -> List[TraceJob]:
    path = pathlib.Path(path)
    data = np.load(path.with_suffix(".npz"))
    meta: Dict[str, str] = json.loads(path.with_suffix(".json").read_text())
    return [TraceJob(name=k, family=meta[k], task_times=data[k]) for k in data.files]
