"""Lemma 1: batch-coverage probability of *random* batch-to-worker assignment.

With N workers each drawing one of B batches uniformly at random (the coupon
collector model of [72]), the probability that all B batches are covered is

    P(n <= N) = B! / B^N * S(N, B)                              (Eq. 6)

with S the Stirling number of the second kind.  The paper uses this to argue
random assignment is unsafe (Fig. 3); our data pipeline turns it into a
startup invariant (deterministic balanced placement + coverage check).

The alternating Stirling sum overflows float64 well before the N=100..1000
range that matters, so we evaluate it with a signed log-sum-exp.
"""
from __future__ import annotations

import math

import numpy as np


def log_binom(n: int, k: int) -> float:
    """Log of the binomial coefficient C(n, k), via lgamma."""
    return math.lgamma(n + 1) - math.lgamma(k + 1) - math.lgamma(n - k + 1)


def coverage_probability(n_workers: int, n_batches: int) -> float:
    """P{all B batches covered by N uniform draws}  (Lemma 1, exact).

    Direct inclusion-exclusion in log domain:
        P = sum_{k=0}^{B} (-1)^k C(B,k) (1 - k/B)^N
    (equivalent to B! S(N,B) / B^N, but numerically stable).
    """
    b, n = n_batches, n_workers
    if b <= 0 or n <= 0:
        raise ValueError("need positive N and B")
    if n < b:
        return 0.0
    if b == 1:
        return 1.0
    # signed log-sum-exp of terms t_k = (-1)^k C(B,k) ((B-k)/B)^N, k = 0..B-1
    logs = np.empty(b)
    signs = np.empty(b)
    for k in range(b):
        logs[k] = log_binom(b, k) + n * (math.log(b - k) - math.log(b))
        signs[k] = 1.0 if k % 2 == 0 else -1.0
    m = logs.max()
    s = float(np.sum(signs * np.exp(logs - m)))
    if s <= 0.0:  # pure roundoff at extreme N/B; probability is ~0 or ~1
        return 0.0
    return float(min(1.0, math.exp(m + math.log(s))))


def coverage_probability_mc(
    n_workers: int, n_batches: int, n_samples: int, seed: int = 0
) -> float:
    """Monte-Carlo estimate of the same probability (test oracle)."""
    rng = np.random.default_rng(seed)
    draws = rng.integers(0, n_batches, size=(n_samples, n_workers))
    # covered iff every batch id appears in the row
    counts = np.zeros((n_samples, n_batches), dtype=np.int64)
    rows = np.repeat(np.arange(n_samples), n_workers)
    np.add.at(counts, (rows, draws.ravel()), 1)
    return float((counts > 0).all(axis=1).mean())


def min_workers_for_coverage(n_batches: int, confidence: float = 0.99) -> int:
    """Smallest N with coverage probability >= confidence (planner helper)."""
    n = n_batches
    while coverage_probability(n, n_batches) < confidence:
        n = max(n + 1, int(n * 1.1))
        if n > 10_000_000:
            raise RuntimeError("coverage target unreachable")
    # binary search down to the exact threshold
    lo, hi = n_batches, n
    while lo < hi:
        mid = (lo + hi) // 2
        if coverage_probability(mid, n_batches) >= confidence:
            hi = mid
        else:
            lo = mid + 1
    return lo
