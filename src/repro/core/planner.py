"""RedundancyPlanner: the paper's §VI-§VII results as a control-plane service.

Given a worker budget N and knowledge of the task/step service-time behaviour
(a fitted distribution or raw trace samples), the planner returns the
operating point on the diversity-parallelism spectrum:

    B  = number of distinct (non-overlapping) batches / data shards
    r  = N / B = replication factor per batch

optimizing either average job time (paper Thms 3/5/8), predictability
(CoV, Thms 4/7/10), or a weighted blend -- the paper's "system administrator
middle point" (§VI-A closing remark).

The distributed runtime (repro.distributed) consumes the plan to factorize
the data mesh axis into ("replica", "shard"), and the elastic controller
replans on membership changes.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Iterable, Sequence

import numpy as np

from . import analysis
from .service_time import (
    Empirical,
    Exponential,
    Pareto,
    ServiceTime,
    ShiftedExponential,
)

__all__ = ["RedundancyPlan", "RedundancyPlanner", "fit_service_time", "plan_sweep"]

# local 'kwarg not passed' sentinel: core stays importable without the
# cluster package loaded, so the shared repro.cluster.scenario.UNSET is not
# importable here at module scope -- entries still carrying this sentinel
# are dropped before they reach resolve_scenario
_UNSET = type("_PlannerUnset", (), {"__repr__": lambda self: "UNSET"})()


@dataclasses.dataclass(frozen=True)
class RedundancyPlan:
    n_workers: int
    n_batches: int  # B: distinct data shards
    replication: int  # r = N / B
    objective: str  # 'mean' | 'cov' | 'blend'
    predicted_mean: float
    predicted_cov: float
    # full frontier for observability dashboards
    frontier_B: tuple
    frontier_mean: tuple
    frontier_cov: tuple
    source: str  # 'closed_form:<dist>' | 'empirical_bootstrap'

    @property
    def diversity(self) -> float:
        """0 = full parallelism (B=N), 1 = full diversity (B=1)."""
        if self.n_workers == 1:
            return 1.0
        return 1.0 - (self.n_batches - 1) / (self.n_workers - 1)


def fit_service_time(samples: Sequence[float]) -> ServiceTime:
    """Fit Exp / SExp / Pareto by maximum likelihood and pick by log-lik.

    Mirrors §VII: classify a job's tasks as exponential-tail or heavy-tail
    from its service-time records, then plan with the matching closed form.
    """
    x = np.asarray(samples, dtype=np.float64)
    x = x[x > 0]
    if x.size < 2:
        raise ValueError("need at least 2 positive samples")
    n = x.size
    xmin, xbar = float(x.min()), float(x.mean())

    fits: list[tuple[float, ServiceTime]] = []

    # Exponential(mu): MLE mu = 1/mean
    mu = 1.0 / xbar
    ll_exp = n * math.log(mu) - mu * x.sum()
    fits.append((ll_exp, Exponential(mu=mu)))

    # ShiftedExponential(delta, mu): MLE delta = min, mu = 1/(mean - min)
    if xbar > xmin:
        delta = xmin
        mu_s = 1.0 / (xbar - xmin)
        ll_sexp = n * math.log(mu_s) - mu_s * float((x - delta).sum())
        fits.append((ll_sexp, ShiftedExponential(delta=delta, mu=mu_s)))

    # Pareto(sigma, alpha): MLE sigma = min, alpha = n / sum log(x/sigma)
    logs = np.log(x / xmin)
    s_logs = float(logs.sum())
    if s_logs > 0:
        alpha = n / s_logs
        ll_par = n * math.log(alpha) + n * alpha * math.log(xmin) - (alpha + 1.0) * float(
            np.log(x).sum()
        )
        fits.append((ll_par, Pareto(sigma=xmin, alpha=alpha)))

    fits.sort(key=lambda p: p[0], reverse=True)
    return fits[0][1]


class RedundancyPlanner:
    """Plans (B, r) for a worker budget from closed forms or traces."""

    def __init__(self, n_workers: int, candidates: Iterable[int] | None = None):
        self.n_workers = int(n_workers)
        self.candidates = (
            list(candidates) if candidates is not None else analysis.feasible_B(self.n_workers)
        )

    # -- closed-form path ---------------------------------------------------

    def plan(
        self, dist: ServiceTime, objective: str = "mean", blend: float = 0.5
    ) -> RedundancyPlan:
        if isinstance(dist, Empirical):
            return self.plan_empirical(np.asarray(dist.samples), objective, blend=blend)
        n = self.n_workers
        means = np.array([analysis.mean_T(dist, n, b) for b in self.candidates])
        covs = np.array([analysis.cov_T(dist, n, b) for b in self.candidates])
        b = self._select(means, covs, objective, blend)
        return self._mk_plan(b, means, covs, objective, f"closed_form:{type(dist).__name__}")

    # -- trace/empirical path (bootstrap over the §VI size model) -----------

    def plan_empirical(
        self,
        samples: np.ndarray,
        objective: str = "mean",
        n_mc: int = 20_000,
        seed: int = 0,
        blend: float = 0.5,
    ) -> RedundancyPlan:
        """Estimate E[T](B) and CoV(B) by resampling task times from the trace.

        This is the experiment of Figs. 12-13: for each feasible B, draw task
        service times, form batch times (N/B)*tau, take max-min.
        """
        x = np.asarray(samples, dtype=np.float64)
        rng = np.random.default_rng(seed)
        n = self.n_workers
        means, covs = [], []
        for b in self.candidates:
            r = n // b
            draws = rng.choice(x, size=(n_mc, b, r), replace=True) * (n / b)
            t = draws.min(axis=2).max(axis=1)
            means.append(float(t.mean()))
            covs.append(float(t.std() / t.mean()))
        means, covs = np.array(means), np.array(covs)
        b = self._select(means, covs, objective, blend)
        return self._mk_plan(b, means, covs, objective, "empirical_bootstrap")

    def plan_auto(self, samples: np.ndarray, objective: str = "mean") -> RedundancyPlan:
        """§VII methodology: fit the tail family, then use its closed form."""
        dist = fit_service_time(samples)
        return self.plan(dist, objective=objective)

    # -- engine path (candidates scored by the event-driven cluster engine) --

    def plan_cluster(
        self,
        dist: ServiceTime | None = None,
        objective: str = "mean",
        n_reps: int = 400,
        seed: int = 0,
        blend: float = 0.5,
        size_dependent=_UNSET,
        cancel_redundant=_UNSET,
        backend: str = "jax",
        speeds=_UNSET,
        churn=_UNSET,
        churn_schedule=_UNSET,
        replan=_UNSET,
        speculation=_UNSET,
        scheduler=_UNSET,
        workers_per_job=_UNSET,
        job_plans=_UNSET,
        jobs_per_stream=_UNSET,
        churn_pairs_per_worker=_UNSET,
        dtype=_UNSET,
        rep_chunk=_UNSET,
        devices=_UNSET,
        scenario=None,
    ) -> RedundancyPlan:
        """Pick (B, r) by *executing* each candidate on ``repro.cluster``.

        Unlike the closed-form/bootstrap paths, this scores candidates under
        the engine's operational semantics (dispatch, earliest cover, and --
        when enabled -- replica cancellation), so it extends to scenarios the
        formulas do not cover.  Lazy import: core stays importable without
        the cluster package loaded (cluster imports core).

        ``backend="jax"`` (default) scores the whole candidate frontier in
        batched device calls: the static grid kernel of
        ``repro.cluster.vectorized`` when the cluster is static, or the
        bounded epoch-scan step loop of ``repro.cluster.epoch_scan`` once any dynamic
        knob is set -- ``speeds`` (heterogeneous workers), ``churn`` /
        ``churn_schedule`` (fail/join dynamics with replica rescue),
        ``replan`` (a :class:`~repro.cluster.epoch_scan.ReplanConfig` running
        the windowed online replanner while candidates are scored), or
        ``speculation`` (a :class:`~repro.cluster.scenario.Speculation`
        policy launching reactive backups for laggards).  No
        scenario falls back to the Python engine.  ``backend="python"`` runs
        the event-driven engine per candidate over the same knobs -- the
        reference the differential tests compare against.  Replica
        cancellation reclaims worker-seconds but does not change compute
        times, so both backends score the same statistic.

        Under churn, samples arrive in correlated serial streams of
        ``jobs_per_stream`` jobs sharing one churn timeline (the Python
        engine's structure); the static path keeps drawing i.i.d. jobs.

        ``scheduler`` / ``workers_per_job`` / ``job_plans`` score the
        candidates under *space sharing* (see
        :mod:`repro.cluster.scheduler`): each stream's jobs run concurrently
        on disjoint ``workers_per_job``-worker subsets, and ``job_plans``
        (a cycle of :class:`~repro.cluster.scheduler.JobPlan`) pins
        heterogeneous per-job plans -- jobs whose plan leaves ``n_batches``
        unset take the candidate B, so the frontier is swept for one job
        class while competing classes hold fixed plans.  Any space knob
        routes ``backend="jax"`` to the epoch scan's space lane.

        Scale knobs: ``rep_chunk`` bounds device memory by scoring at most
        that many reps/streams per device call (any chunk size is
        bit-identical to any other; on the *dynamic* path it also matches
        the unchunked run exactly, while the static path's chunked
        derivation is a separate, equally valid stream).  ``dtype="float64"``
        (double-precision scan lanes for long-horizon workloads) and
        ``devices`` (``shard_map`` over the lane grid, seed-identical to
        single-device) apply to the dynamic epoch scan only -- the static
        frontier path raises if they are set, rather than silently ignoring
        them.

        ``Scenario.outputs`` rides through untouched: candidate scoring
        needs per-job compute times, so the frontier paths always run the
        reduced-output lanes (``full_outputs=False`` -- no per-event or
        per-job-plan buffers) regardless of the knob, and
        ``outputs="stream"`` changes nothing here.  The streaming
        aggregation applies to the *simulation* entry points
        (``simulate_epochs`` / ``simulate_stream``), not to planning.

        All scenario knobs are best passed as one validated
        ``scenario=Scenario(...)`` (which may also carry ``dist``); the
        loose keyword forms keep working behind a
        :class:`DeprecationWarning` shim, and both forms produce identical
        plans on identical seeds.
        """
        from ..cluster.scenario import resolve_scenario

        sc = resolve_scenario(
            scenario,
            {
                k: v
                for k, v in {
                    "cancel_redundant": cancel_redundant,
                    "size_dependent": size_dependent,
                    "speeds": speeds,
                    "churn": churn,
                    "churn_schedule": churn_schedule,
                    "churn_pairs_per_worker": churn_pairs_per_worker,
                    "replan": replan,
                    "speculation": speculation,
                    "scheduler": scheduler,
                    "workers_per_job": workers_per_job,
                    "job_plans": job_plans,
                    "jobs_per_stream": jobs_per_stream,
                    "dtype": dtype,
                    "rep_chunk": rep_chunk,
                    "devices": devices,
                }.items()
                if v is not _UNSET
            },
            where="plan_cluster",
        )
        dist = dist if dist is not None else sc.dist
        if dist is None:
            raise ValueError("plan_cluster needs dist (positionally or via scenario.dist)")
        if backend == "jax":
            sc.validate(n_workers=self.n_workers, backend="jax")
            if sc.is_dynamic or sc.is_space:
                from ..cluster.epoch_scan import frontier_job_times_dynamic

                rows = frontier_job_times_dynamic(
                    dist,
                    self.n_workers,
                    self.candidates,
                    n_reps,
                    seed=seed,
                    scenario=sc,
                )
            else:
                if sc.dtype != "float32" or sc.devices != 1:
                    raise ValueError(
                        "Scenario.dtype/devices apply to dynamic scenarios (the "
                        "jax epoch scan); the static frontier path supports "
                        "rep_chunk only"
                    )
                from ..cluster.vectorized import frontier_job_times

                rows = frontier_job_times(
                    dist,
                    self.n_workers,
                    self.candidates,
                    n_reps,
                    seed=seed,
                    size_dependent=sc.size_dependent,
                    rep_chunk=sc.rep_chunk,
                )
        elif backend == "python":
            from ..cluster.master import sample_job_times

            sc.validate(n_workers=self.n_workers, backend="python")
            rows = [
                sample_job_times(
                    dist,
                    self.n_workers,
                    b,
                    n_reps,
                    seed=seed + i,
                    scenario=sc,
                )
                for i, b in enumerate(self.candidates)
            ]
        else:
            raise ValueError(f"unknown backend {backend!r} (expected 'jax' or 'python')")
        means, covs = _frontier_stats(rows)
        b = self._select(means, covs, objective, blend)
        return self._mk_plan(b, means, covs, objective, f"cluster_engine:{backend}")

    # -- helpers -------------------------------------------------------------

    def _select(self, means, covs, objective, blend) -> int:
        if objective == "mean":
            idx = int(np.argmin(means))
        elif objective == "cov":
            idx = int(np.argmin(covs))
        elif objective == "blend":
            # normalized blend: the administrator's middle point.  Degenerate
            # candidates (zero/infinite mean => infinite CoV) would poison the
            # normalization with inf - inf = NaN and argmin would then pick
            # them; normalize over the finite candidates only and push the
            # rest to +inf score.
            finite = np.isfinite(means) & np.isfinite(covs)
            if not finite.any():
                idx = 0  # every candidate is degenerate; nothing to rank
            else:
                mn = _norm01(means, finite)
                cn = _norm01(covs, finite)
                score = np.where(finite, blend * mn + (1 - blend) * cn, np.inf)
                idx = int(np.argmin(score))
        else:
            raise ValueError(f"unknown objective {objective!r}")
        return self.candidates[idx]

    def _mk_plan(self, b, means, covs, objective, source) -> RedundancyPlan:
        i = self.candidates.index(b)
        return RedundancyPlan(
            n_workers=self.n_workers,
            n_batches=b,
            replication=self.n_workers // b,
            objective=objective,
            predicted_mean=float(means[i]),
            predicted_cov=float(covs[i]),
            frontier_B=tuple(self.candidates),
            frontier_mean=tuple(float(m) for m in means),
            frontier_cov=tuple(float(c) for c in covs),
            source=source,
        )


def _norm01(values: np.ndarray, finite: np.ndarray) -> np.ndarray:
    """Min-max normalize the finite lanes; non-finite lanes are left at 0
    (callers mask them out of the score separately, keeping inf - inf NaNs
    out of the arithmetic entirely)."""
    out = np.zeros_like(values, dtype=np.float64)
    vf = values[finite]
    lo = float(vf.min())
    out[finite] = (vf - lo) / max(float(vf.max()) - lo, 1e-12)
    return out


def _frontier_stats(rows) -> tuple[np.ndarray, np.ndarray]:
    """Per-candidate (mean, CoV) from job-time sample rows.

    Degenerate rows -- no finite samples, or an all-zero mean -- score
    (inf, inf) so selection can rank them last instead of dividing by zero.
    """
    means, covs = [], []
    for t in rows:
        t = np.asarray(t)
        t = t[np.isfinite(t)]
        m = float(t.mean()) if t.size else math.inf
        if t.size == 0 or m <= 0.0:
            means.append(math.inf if t.size == 0 else m)
            covs.append(math.inf)
            continue
        means.append(m)
        covs.append(float(t.std() / m))
    return np.array(means), np.array(covs)


def plan_sweep(
    dists: Sequence[ServiceTime],
    budgets: Sequence[int],
    objective: str = "mean",
    *,
    n_reps: int = 400,
    seed: int = 0,
    blend: float = 0.5,
    size_dependent=_UNSET,
    cancel_redundant=_UNSET,
    backend: str = "jax",
    candidates: Iterable[int] | None = None,
    speeds=_UNSET,
    churn=_UNSET,
    churn_schedule=_UNSET,
    replan=_UNSET,
    speculation=_UNSET,
    scheduler=_UNSET,
    workers_per_job=_UNSET,
    job_plans=_UNSET,
    jobs_per_stream=_UNSET,
    churn_pairs_per_worker=_UNSET,
    dtype=_UNSET,
    rep_chunk=_UNSET,
    devices=_UNSET,
    scenario=None,
) -> list:
    """Score redundancy frontiers for a (distribution x worker-budget) grid.

    Returns ``plans`` with ``plans[i][j]`` the :class:`RedundancyPlan` for
    ``dists[i]`` under ``budgets[j]``.  Each grid point scores its entire
    candidate frontier in one batched device call (``backend="jax"``), so a
    sweep that would take ``len(dists) * len(budgets) * len(candidates)``
    Python event loops is a handful of vectorized kernels -- the regime the
    §VI/§VII trade-off studies live in.

    ``churn`` / ``churn_schedule`` / ``replan`` (plus the
    ``jobs_per_stream`` / ``churn_pairs_per_worker`` stream-shape knobs)
    extend the sweep to dynamic scenarios, forwarded to every grid point's
    :meth:`plan_cluster` (scored on the epoch-scan step loop under
    ``backend="jax"``).  ``speeds`` takes either one per-worker sequence
    (every budget must then equal its length) or a callable
    ``budget -> speeds`` for heterogeneous grids.

    Grid point (i, j) uses seed ``seed + i * len(budgets) + j``; the
    property-test suite relies on that derivation to check each sweep entry
    against an identically-seeded per-candidate :meth:`plan_cluster` call.

    Dynamic grid points share compiled kernels across the whole sweep: the
    epoch scan pads worker/job/event/lane counts to shape buckets, so nearby
    budgets hit one compile (``repro.cluster.epoch_scan.runner_cache_stats``
    counts them).  ``dtype``/``rep_chunk``/``devices`` forward to every grid
    point -- ``devices > 1`` shards each point's lane grid via ``shard_map``
    with results identical to single-device execution.

    Scenario knobs are best passed as one ``scenario=Scenario(...)``; the
    loose keyword forms keep working behind a ``DeprecationWarning`` shim.
    A callable ``speeds`` stays a sweep-level convenience (it cannot live in
    a frozen Scenario) and is re-attached per budget.  ``Scenario.outputs``
    forwards like every other field but does not change planning: every grid
    point scores on the reduced-output frontier lanes either way (see
    :meth:`RedundancyPlanner.plan_cluster`).
    """
    from ..cluster.scenario import resolve_scenario

    speeds_fn = speeds if callable(speeds) else None
    if speeds_fn is not None and scenario is not None:
        raise ValueError(
            "plan_sweep: got scenario= and loose scenario kwargs (speeds); "
            "pass per-budget speeds by calling plan_sweep once per budget "
            "with scenario.replace(speeds=...)"
        )
    explicit = {
        k: v
        for k, v in {
            "size_dependent": size_dependent,
            "cancel_redundant": cancel_redundant,
            "speeds": speeds,
            "churn": churn,
            "churn_schedule": churn_schedule,
            "replan": replan,
            "speculation": speculation,
            "scheduler": scheduler,
            "workers_per_job": workers_per_job,
            "job_plans": job_plans,
            "jobs_per_stream": jobs_per_stream,
            "churn_pairs_per_worker": churn_pairs_per_worker,
            "dtype": dtype,
            "rep_chunk": rep_chunk,
            "devices": devices,
        }.items()
        if v is not _UNSET
    }
    if speeds_fn is not None:
        explicit.pop("speeds")  # re-attached per grid point below
    sc = resolve_scenario(scenario, explicit, where="plan_sweep")

    dists = list(dists)
    budgets = [int(n) for n in budgets]
    plans = []
    for i, dist in enumerate(dists):
        row = []
        for j, n_workers in enumerate(budgets):
            planner = RedundancyPlanner(n_workers, candidates=candidates)
            sc_ij = sc.replace(speeds=speeds_fn(n_workers)) if speeds_fn is not None else sc
            row.append(
                planner.plan_cluster(
                    dist,
                    objective,
                    n_reps=n_reps,
                    seed=seed + i * len(budgets) + j,
                    blend=blend,
                    backend=backend,
                    scenario=sc_ij,
                )
            )
        plans.append(row)
    return plans
