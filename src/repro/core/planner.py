"""RedundancyPlanner: the paper's §VI-§VII results as a control-plane service.

Given a worker budget N and knowledge of the task/step service-time behaviour
(a fitted distribution or raw trace samples), the planner returns the
operating point on the diversity-parallelism spectrum:

    B  = number of distinct (non-overlapping) batches / data shards
    r  = N / B = replication factor per batch

optimizing either average job time (paper Thms 3/5/8), predictability
(CoV, Thms 4/7/10), or a weighted blend -- the paper's "system administrator
middle point" (§VI-A closing remark).

The distributed runtime (repro.distributed) consumes the plan to factorize
the data mesh axis into ("replica", "shard"), and the elastic controller
replans on membership changes.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Iterable, Sequence

import numpy as np

from . import analysis
from .service_time import (
    Empirical,
    Exponential,
    Pareto,
    ServiceTime,
    ShiftedExponential,
)

__all__ = [
    "RedundancyPlan",
    "RedundancyPlanner",
    "SLOCandidate",
    "SLOPlan",
    "fit_service_time",
    "plan_sweep",
]

# local 'kwarg not passed' sentinel: core stays importable without the
# cluster package loaded, so the shared repro.cluster.scenario.UNSET is not
# importable here at module scope -- entries still carrying this sentinel
# are dropped before they reach resolve_scenario
_UNSET = type("_PlannerUnset", (), {"__repr__": lambda self: "UNSET"})()


@dataclasses.dataclass(frozen=True)
class RedundancyPlan:
    """A chosen (B, r) point plus the predicted frontier it was picked from."""

    n_workers: int
    n_batches: int  # B: distinct data shards
    replication: int  # r = N / B
    objective: str  # 'mean' | 'cov' | 'blend'
    predicted_mean: float
    predicted_cov: float
    # full frontier for observability dashboards
    frontier_B: tuple
    frontier_mean: tuple
    frontier_cov: tuple
    source: str  # 'closed_form:<dist>' | 'empirical_bootstrap'

    @property
    def diversity(self) -> float:
        """0 = full parallelism (B=N), 1 = full diversity (B=1)."""
        if self.n_workers == 1:
            return 1.0
        return 1.0 - (self.n_batches - 1) / (self.n_workers - 1)


@dataclasses.dataclass(frozen=True)
class SLOCandidate:
    """One evaluated point of the :meth:`RedundancyPlanner.plan_slo` grid.

    ``achieved`` holds the response quantile the candidate delivered for
    each SLO (in SLO order); ``feasible`` is whether every one of them met
    its target.  ``cost_worker_seconds`` is the per-rep mean charged
    worker-seconds over the evaluation stream -- the cost plan_slo
    minimizes among feasible candidates.
    """

    scheduler: str
    workers_per_job: int | None  # pool width (None on fifo_gang)
    n_batches: int
    replication: int
    feasible: bool
    cost_worker_seconds: float
    mean_response: float
    achieved: tuple  # response quantile per SLO, SLO order


@dataclasses.dataclass(frozen=True)
class SLOPlan:
    """The :meth:`RedundancyPlanner.plan_slo` verdict.

    ``feasible`` says whether *any* candidate met every SLO; when it did,
    ``best`` is the cheapest such candidate (worker-seconds, ties broken by
    mean response) -- when it did not, ``best`` is ``None`` and the sorted
    ``candidates`` tuple shows how close the grid came.  Infeasibility is
    an explicit verdict, never a silent fallback to the cheapest violator.
    """

    n_workers: int
    slos: tuple  # tuple[repro.cluster.SLO, ...]
    classes: tuple  # workload class names, stream source order
    feasible: bool
    best: SLOCandidate | None
    candidates: tuple  # every evaluated SLOCandidate, best-first
    source: str  # 'stream' | 'epoch_scan'

    def require_feasible(self) -> SLOCandidate:
        """The best candidate, or ``ValueError`` if no candidate met the SLOs."""
        if not self.feasible or self.best is None:
            raise ValueError(
                f"no (B, r, scheduler) candidate met the SLOs {self.slos!r} "
                f"on n_workers={self.n_workers} (closest: {self.candidates[0]!r})"
            )
        return self.best

    def best_for(self, job_class: str) -> SLOCandidate | None:
        """Cheapest candidate feasible for *one* class's SLOs alone.

        Filters the SLO list down to the entries naming ``job_class`` and
        re-ranks the already-evaluated grid against just those -- the
        per-class answer under space sharing, where one class's target may
        be achievable even when the joint plan is infeasible.  Returns
        ``None`` when no candidate meets the class's SLOs.
        """
        idx = [i for i, s in enumerate(self.slos) if s.job_class == job_class]
        if not idx:
            raise KeyError(f"no SLO names job_class {job_class!r}")
        ok = [
            c
            for c in self.candidates
            if all(c.achieved[i] <= self.slos[i].target_s for i in idx)
        ]
        return min(ok, key=lambda c: (c.cost_worker_seconds, c.mean_response)) if ok else None


def fit_service_time(samples: Sequence[float]) -> ServiceTime:
    """Fit Exp / SExp / Pareto by maximum likelihood and pick by log-lik.

    Mirrors §VII: classify a job's tasks as exponential-tail or heavy-tail
    from its service-time records, then plan with the matching closed form.
    """
    x = np.asarray(samples, dtype=np.float64)
    x = x[x > 0]
    if x.size < 2:
        raise ValueError("need at least 2 positive samples")
    n = x.size
    xmin, xbar = float(x.min()), float(x.mean())

    fits: list[tuple[float, ServiceTime]] = []

    # Exponential(mu): MLE mu = 1/mean
    mu = 1.0 / xbar
    ll_exp = n * math.log(mu) - mu * x.sum()
    fits.append((ll_exp, Exponential(mu=mu)))

    # ShiftedExponential(delta, mu): MLE delta = min, mu = 1/(mean - min)
    if xbar > xmin:
        delta = xmin
        mu_s = 1.0 / (xbar - xmin)
        ll_sexp = n * math.log(mu_s) - mu_s * float((x - delta).sum())
        fits.append((ll_sexp, ShiftedExponential(delta=delta, mu=mu_s)))

    # Pareto(sigma, alpha): MLE sigma = min, alpha = n / sum log(x/sigma)
    logs = np.log(x / xmin)
    s_logs = float(logs.sum())
    if s_logs > 0:
        alpha = n / s_logs
        ll_par = n * math.log(alpha) + n * alpha * math.log(xmin) - (alpha + 1.0) * float(
            np.log(x).sum()
        )
        fits.append((ll_par, Pareto(sigma=xmin, alpha=alpha)))

    fits.sort(key=lambda p: p[0], reverse=True)
    return fits[0][1]


class RedundancyPlanner:
    """Plans (B, r) for a worker budget from closed forms or traces."""

    def __init__(self, n_workers: int, candidates: Iterable[int] | None = None):
        self.n_workers = int(n_workers)
        self.candidates = (
            list(candidates) if candidates is not None else analysis.feasible_B(self.n_workers)
        )

    # -- closed-form path ---------------------------------------------------

    def plan(
        self, dist: ServiceTime, objective: str = "mean", blend: float = 0.5
    ) -> RedundancyPlan:
        """Pick (B, r) from the closed-form frontier of ``dist`` (§IV-§V)."""
        if isinstance(dist, Empirical):
            return self.plan_empirical(np.asarray(dist.samples), objective, blend=blend)
        n = self.n_workers
        means = np.array([analysis.mean_T(dist, n, b) for b in self.candidates])
        covs = np.array([analysis.cov_T(dist, n, b) for b in self.candidates])
        b = self._select(means, covs, objective, blend)
        return self._mk_plan(b, means, covs, objective, f"closed_form:{type(dist).__name__}")

    # -- trace/empirical path (bootstrap over the §VI size model) -----------

    def plan_empirical(
        self,
        samples: np.ndarray,
        objective: str = "mean",
        n_mc: int = 20_000,
        seed: int = 0,
        blend: float = 0.5,
    ) -> RedundancyPlan:
        """Estimate E[T](B) and CoV(B) by resampling task times from the trace.

        This is the experiment of Figs. 12-13: for each feasible B, draw task
        service times, form batch times (N/B)*tau, take max-min.
        """
        x = np.asarray(samples, dtype=np.float64)
        rng = np.random.default_rng(seed)
        n = self.n_workers
        means, covs = [], []
        for b in self.candidates:
            r = n // b
            draws = rng.choice(x, size=(n_mc, b, r), replace=True) * (n / b)
            t = draws.min(axis=2).max(axis=1)
            means.append(float(t.mean()))
            covs.append(float(t.std() / t.mean()))
        means, covs = np.array(means), np.array(covs)
        b = self._select(means, covs, objective, blend)
        return self._mk_plan(b, means, covs, objective, "empirical_bootstrap")

    def plan_auto(self, samples: np.ndarray, objective: str = "mean") -> RedundancyPlan:
        """§VII methodology: fit the tail family, then use its closed form."""
        dist = fit_service_time(samples)
        return self.plan(dist, objective=objective)

    # -- engine path (candidates scored by the event-driven cluster engine) --

    def plan_cluster(
        self,
        dist: ServiceTime | None = None,
        objective: str = "mean",
        n_reps: int = 400,
        seed: int = 0,
        blend: float = 0.5,
        size_dependent=_UNSET,
        cancel_redundant=_UNSET,
        backend: str = "jax",
        speeds=_UNSET,
        churn=_UNSET,
        churn_schedule=_UNSET,
        replan=_UNSET,
        speculation=_UNSET,
        scheduler=_UNSET,
        workers_per_job=_UNSET,
        job_plans=_UNSET,
        jobs_per_stream=_UNSET,
        churn_pairs_per_worker=_UNSET,
        dtype=_UNSET,
        rep_chunk=_UNSET,
        devices=_UNSET,
        scenario=None,
    ) -> RedundancyPlan:
        """Pick (B, r) by *executing* each candidate on ``repro.cluster``.

        Unlike the closed-form/bootstrap paths, this scores candidates under
        the engine's operational semantics (dispatch, earliest cover, and --
        when enabled -- replica cancellation), so it extends to scenarios the
        formulas do not cover.  Lazy import: core stays importable without
        the cluster package loaded (cluster imports core).

        ``backend="jax"`` (default) scores the whole candidate frontier in
        batched device calls: the static grid kernel of
        ``repro.cluster.vectorized`` when the cluster is static, or the
        bounded epoch-scan step loop of ``repro.cluster.epoch_scan`` once any dynamic
        knob is set -- ``speeds`` (heterogeneous workers), ``churn`` /
        ``churn_schedule`` (fail/join dynamics with replica rescue),
        ``replan`` (a :class:`~repro.cluster.epoch_scan.ReplanConfig` running
        the windowed online replanner while candidates are scored), or
        ``speculation`` (a :class:`~repro.cluster.scenario.Speculation`
        policy launching reactive backups for laggards).  No
        scenario falls back to the Python engine.  ``backend="python"`` runs
        the event-driven engine per candidate over the same knobs -- the
        reference the differential tests compare against.  Replica
        cancellation reclaims worker-seconds but does not change compute
        times, so both backends score the same statistic.

        Under churn, samples arrive in correlated serial streams of
        ``jobs_per_stream`` jobs sharing one churn timeline (the Python
        engine's structure); the static path keeps drawing i.i.d. jobs.

        ``scheduler`` / ``workers_per_job`` / ``job_plans`` score the
        candidates under *space sharing* (see
        :mod:`repro.cluster.scheduler`): each stream's jobs run concurrently
        on disjoint ``workers_per_job``-worker subsets, and ``job_plans``
        (a cycle of :class:`~repro.cluster.scheduler.JobPlan`) pins
        heterogeneous per-job plans -- jobs whose plan leaves ``n_batches``
        unset take the candidate B, so the frontier is swept for one job
        class while competing classes hold fixed plans.  Any space knob
        routes ``backend="jax"`` to the epoch scan's space lane.

        Scale knobs: ``rep_chunk`` bounds device memory by scoring at most
        that many reps/streams per device call (any chunk size is
        bit-identical to any other; on the *dynamic* path it also matches
        the unchunked run exactly, while the static path's chunked
        derivation is a separate, equally valid stream).  ``dtype="float64"``
        (double-precision scan lanes for long-horizon workloads) and
        ``devices`` (``shard_map`` over the lane grid, seed-identical to
        single-device) apply to the dynamic epoch scan only -- the static
        frontier path raises if they are set, rather than silently ignoring
        them.

        ``Scenario.outputs`` rides through untouched: candidate scoring
        needs per-job compute times, so the frontier paths always run the
        reduced-output lanes (``full_outputs=False`` -- no per-event or
        per-job-plan buffers) regardless of the knob, and
        ``outputs="stream"`` changes nothing here.  The streaming
        aggregation applies to the *simulation* entry points
        (``simulate_epochs`` / ``simulate_stream``), not to planning.

        All scenario knobs are best passed as one validated
        ``scenario=Scenario(...)`` (which may also carry ``dist``); the
        loose keyword forms keep working behind a
        :class:`DeprecationWarning` shim, and both forms produce identical
        plans on identical seeds.

        Example (tiny, engine-scored)::

            >>> from repro.core import Exponential, Scenario
            >>> plan = RedundancyPlanner(4).plan_cluster(
            ...     scenario=Scenario(dist=Exponential(1.0)),
            ...     n_reps=8, backend="python")
            >>> plan.n_batches in (1, 2, 4)
            True
        """
        from ..cluster.scenario import resolve_scenario

        sc = resolve_scenario(
            scenario,
            {
                k: v
                for k, v in {
                    "cancel_redundant": cancel_redundant,
                    "size_dependent": size_dependent,
                    "speeds": speeds,
                    "churn": churn,
                    "churn_schedule": churn_schedule,
                    "churn_pairs_per_worker": churn_pairs_per_worker,
                    "replan": replan,
                    "speculation": speculation,
                    "scheduler": scheduler,
                    "workers_per_job": workers_per_job,
                    "job_plans": job_plans,
                    "jobs_per_stream": jobs_per_stream,
                    "dtype": dtype,
                    "rep_chunk": rep_chunk,
                    "devices": devices,
                }.items()
                if v is not _UNSET
            },
            where="plan_cluster",
        )
        dist = dist if dist is not None else sc.dist
        if dist is None:
            raise ValueError("plan_cluster needs dist (positionally or via scenario.dist)")
        if backend == "jax":
            sc.validate(n_workers=self.n_workers, backend="jax")
            if sc.is_dynamic or sc.is_space:
                from ..cluster.epoch_scan import frontier_job_times_dynamic

                rows = frontier_job_times_dynamic(
                    dist,
                    self.n_workers,
                    self.candidates,
                    n_reps,
                    seed=seed,
                    scenario=sc,
                )
            else:
                if sc.dtype != "float32" or sc.devices != 1:
                    raise ValueError(
                        "Scenario.dtype/devices apply to dynamic scenarios (the "
                        "jax epoch scan); the static frontier path supports "
                        "rep_chunk only"
                    )
                from ..cluster.vectorized import frontier_job_times

                rows = frontier_job_times(
                    dist,
                    self.n_workers,
                    self.candidates,
                    n_reps,
                    seed=seed,
                    size_dependent=sc.size_dependent,
                    rep_chunk=sc.rep_chunk,
                )
        elif backend == "python":
            from ..cluster.master import sample_job_times

            sc.validate(n_workers=self.n_workers, backend="python")
            rows = [
                sample_job_times(
                    dist,
                    self.n_workers,
                    b,
                    n_reps,
                    seed=seed + i,
                    scenario=sc,
                )
                for i, b in enumerate(self.candidates)
            ]
        else:
            raise ValueError(f"unknown backend {backend!r} (expected 'jax' or 'python')")
        means, covs = _frontier_stats(rows)
        b = self._select(means, covs, objective, blend)
        return self._mk_plan(b, means, covs, objective, f"cluster_engine:{backend}")

    # -- tail-SLO path (cheapest candidate meeting a response target) --------

    def plan_slo(
        self,
        workload,
        slo=None,
        *,
        scenario=None,
        n_jobs: int = 2000,
        n_reps: int = 4,
        seed: int = 0,
        schedulers: Sequence[str] = ("fifo_gang", "packed", "balanced"),
        pool_widths: Sequence[int] | None = None,
        slab: int | None = 1024,
    ) -> SLOPlan:
        """Cheapest (B, r, scheduler) meeting tail response-time SLOs.

        The paper's second core result is that mean-optimal replication is
        not tail-optimal; this is the planner surface that acts on it.  Each
        grid candidate is *executed* against a seeded Poisson arrival stream
        (:func:`repro.core.traces.poisson_stream` at the SLO's
        ``arrival_rate``) on the trace-scale streaming kernel
        (:func:`repro.cluster.simulate_stream`), whose scan carries pooled
        *and per-class* response histograms -- so p99/p999 feasibility per
        job class costs O(n_reps) memory however long the stream.  The
        quantile estimator is conservative by construction (bin upper edge,
        see :data:`repro.cluster.STREAM_QUANTILE_RTOL`): a candidate is
        never declared feasible because of histogram resolution.

        ``workload`` is one job class or a sequence of them -- each a
        :class:`~repro.core.traces.TraceJob` or a fitted
        :class:`~repro.core.service_time.ServiceTime` (sampled into a
        seeded trace job); arrivals draw classes uniformly.  ``slo`` is one
        :class:`~repro.cluster.SLO` or a sequence (defaults to
        ``scenario.slo``); every SLO must share one ``arrival_rate``, and a
        per-class SLO names its class via ``SLO.job_class``.

        The grid: ``fifo_gang`` sweeps this planner's B candidates on the
        whole cluster; ``packed`` / ``balanced`` additionally sweep pool
        widths (``pool_widths``, default every proper divisor of the worker
        budget) with B over each width's divisors -- the statically
        space-shared case where per-class SLOs bind.  Dynamic scenarios
        (``speeds`` / ``churn``) route through the epoch-scan lane
        (:func:`repro.cluster.simulate_epochs`, exact quantiles) and
        support a single class on ``fifo_gang``.

        Returns an :class:`SLOPlan`: ``best`` is the cheapest feasible
        candidate in charged worker-seconds, or ``None`` with
        ``feasible=False`` -- an explicit infeasible verdict, never a
        silent fallback.

        Example (small grid, generous target)::

            >>> from repro.core import SLO, Exponential
            >>> plan = RedundancyPlanner(4).plan_slo(
            ...     [Exponential(1.0)],
            ...     SLO(quantile=0.9, target_s=30.0, arrival_rate=0.2),
            ...     n_jobs=200, n_reps=2, schedulers=("fifo_gang",))
            >>> plan.feasible
            True
            >>> plan.best.scheduler
            'fifo_gang'
        """
        from ..cluster.scenario import SLO, Scenario
        from .traces import TraceJob, poisson_stream

        # default to whole-job service draws: under the §VI size model
        # (size_dependent=True) a job's work scales with its source trace's
        # task count, which is meaningful for real TraceJobs but arbitrary
        # for ServiceTime workloads sampled into 4000-task stand-ins -- pass
        # an explicit scenario to opt in
        sc = scenario if scenario is not None else Scenario(size_dependent=False)
        if slo is None:
            slo = sc.slo
        if slo is None:
            raise ValueError("plan_slo needs an SLO (positionally or via scenario.slo)")
        slos = tuple(slo) if isinstance(slo, (list, tuple)) else (slo,)
        for s in slos:
            if not isinstance(s, SLO):
                raise ValueError(f"plan_slo: expected SLO entries, got {type(s)}")
        rates = {float(s.arrival_rate) for s in slos}
        if len(rates) != 1:
            raise ValueError(
                f"plan_slo: every SLO must share one arrival_rate, got {sorted(rates)}"
            )
        if isinstance(workload, (TraceJob, ServiceTime)):
            workload = [workload]
        sources = []
        for i, w in enumerate(workload):
            if isinstance(w, TraceJob):
                sources.append(w)
            elif isinstance(w, ServiceTime):
                rng = np.random.default_rng(
                    np.random.SeedSequence((int(seed), 0x51_0, i))
                )
                name = type(w).__name__.lower()
                if any(src.name == name for src in sources):
                    name = f"{name}{i}"
                sources.append(
                    TraceJob(
                        name=name,
                        family="fitted",
                        task_times=w.sample_np(rng, (4000,)),
                    )
                )
            else:
                raise ValueError(
                    f"plan_slo: workload entries must be TraceJob or "
                    f"ServiceTime, got {type(w)}"
                )
        names = tuple(src.name for src in sources)
        for s in slos:
            if s.job_class is not None and s.job_class not in names:
                raise ValueError(
                    f"plan_slo: SLO.job_class {s.job_class!r} is not a "
                    f"workload class (classes: {names})"
                )
        stream = poisson_stream(sources, rates.pop(), n_jobs, seed=seed)
        if sc.is_dynamic:
            evaluated = self._slo_epoch_candidates(
                workload, sc, slos, stream, n_reps, seed, schedulers
            )
            source = "epoch_scan"
        else:
            evaluated = self._slo_stream_candidates(
                sc, slos, stream, n_reps, schedulers, pool_widths, slab
            )
            source = "stream"
        evaluated.sort(
            key=lambda c: (not c.feasible, c.cost_worker_seconds, c.mean_response)
        )
        best = evaluated[0] if evaluated and evaluated[0].feasible else None
        return SLOPlan(
            n_workers=self.n_workers,
            slos=slos,
            classes=names,
            feasible=best is not None,
            best=best,
            candidates=tuple(evaluated),
            source=source,
        )

    def _slo_grid(self, schedulers, pool_widths):
        """(scheduler, pool_width, B) triples for the plan_slo sweep."""
        grid = []
        for sched in schedulers:
            if sched == "fifo_gang":
                grid.extend((sched, None, b) for b in self.candidates)
            elif sched in ("packed", "balanced"):
                widths = (
                    [int(w) for w in pool_widths]
                    if pool_widths is not None
                    else [w for w in analysis.feasible_B(self.n_workers) if w < self.n_workers]
                )
                for w in widths:
                    if self.n_workers % w:
                        raise ValueError(
                            f"plan_slo: pool width {w} must divide "
                            f"n_workers={self.n_workers}"
                        )
                    grid.extend((sched, w, b) for b in analysis.feasible_B(w))
            else:
                raise ValueError(f"plan_slo: unknown scheduler {sched!r}")
        return grid

    def _slo_stream_candidates(
        self, sc, slos, stream, n_reps, schedulers, pool_widths, slab
    ):
        """Score the static grid on the streaming kernel's class histograms."""
        from ..cluster.stream import simulate_stream

        out = []
        for sched, width, b in self._slo_grid(schedulers, pool_widths):
            sc_c = sc.replace(
                scheduler=sched, workers_per_job=width, outputs="stream",
                n_batches=None, n_workers=None,
            )
            stats = simulate_stream(
                stream, self.n_workers, b, n_reps, scenario=sc_c, slab=slab
            )
            achieved = tuple(
                stats.quantile(s.quantile, job_class=s.job_class) for s in slos
            )
            total = int(stats.count.sum())
            out.append(
                SLOCandidate(
                    scheduler=sched,
                    workers_per_job=width,
                    n_batches=b,
                    replication=(self.n_workers if width is None else width) // b,
                    feasible=all(a <= s.target_s for a, s in zip(achieved, slos)),
                    cost_worker_seconds=float(stats.busy_sum.mean()),
                    mean_response=float(stats.resp_sum.sum() / max(total, 1)),
                    achieved=achieved,
                )
            )
        return out

    def _slo_epoch_candidates(
        self, workload, sc, slos, stream, n_reps, seed, schedulers
    ):
        """Dynamic lane: exact response quantiles via the jax epoch scan."""
        from ..cluster.epoch_scan import simulate_epochs

        if len(stream.sources) != 1 or any(s.job_class is not None for s in slos):
            raise ValueError(
                "plan_slo: dynamic scenarios (speeds/churn/replan/speculation) "
                "support a single job class with pooled SLOs (the epoch scan "
                "has no per-class stream state)"
            )
        if tuple(schedulers) != ("fifo_gang",) and set(schedulers) != {
            "fifo_gang", "packed", "balanced",
        }:
            raise ValueError(
                "plan_slo: dynamic scenarios sweep B on fifo_gang only; pass "
                "schedulers=('fifo_gang',)"
            )
        dist = workload[0]
        if not isinstance(dist, ServiceTime):
            dist = Empirical(samples=tuple(np.asarray(workload[0].task_times)))
        out = []
        for b in self.candidates:
            rep = simulate_epochs(
                dist,
                self.n_workers,
                b,
                stream.arrivals,
                n_reps,
                seed=seed,
                scenario=sc.replace(n_batches=None, n_workers=None, outputs="full"),
            )
            resp = np.asarray(rep.finishes, np.float64) - stream.arrivals[None, :]
            resp = resp[np.isfinite(resp)]
            achieved = tuple(
                float(np.quantile(resp, s.quantile)) if resp.size else float("inf")
                for s in slos
            )
            out.append(
                SLOCandidate(
                    scheduler="fifo_gang",
                    workers_per_job=None,
                    n_batches=b,
                    replication=self.n_workers // b,
                    feasible=all(a <= s.target_s for a, s in zip(achieved, slos)),
                    cost_worker_seconds=float(
                        np.asarray(rep.worker_seconds, np.float64).mean()
                    ),
                    mean_response=float(resp.mean()) if resp.size else float("inf"),
                    achieved=achieved,
                )
            )
        return out

    # -- helpers -------------------------------------------------------------

    def _select(self, means, covs, objective, blend) -> int:
        if objective == "mean":
            idx = int(np.argmin(means))
        elif objective == "cov":
            idx = int(np.argmin(covs))
        elif objective == "blend":
            # normalized blend: the administrator's middle point.  Degenerate
            # candidates (zero/infinite mean => infinite CoV) would poison the
            # normalization with inf - inf = NaN and argmin would then pick
            # them; normalize over the finite candidates only and push the
            # rest to +inf score.
            finite = np.isfinite(means) & np.isfinite(covs)
            if not finite.any():
                idx = 0  # every candidate is degenerate; nothing to rank
            else:
                mn = _norm01(means, finite)
                cn = _norm01(covs, finite)
                score = np.where(finite, blend * mn + (1 - blend) * cn, np.inf)
                idx = int(np.argmin(score))
        else:
            raise ValueError(f"unknown objective {objective!r}")
        return self.candidates[idx]

    def _mk_plan(self, b, means, covs, objective, source) -> RedundancyPlan:
        i = self.candidates.index(b)
        return RedundancyPlan(
            n_workers=self.n_workers,
            n_batches=b,
            replication=self.n_workers // b,
            objective=objective,
            predicted_mean=float(means[i]),
            predicted_cov=float(covs[i]),
            frontier_B=tuple(self.candidates),
            frontier_mean=tuple(float(m) for m in means),
            frontier_cov=tuple(float(c) for c in covs),
            source=source,
        )


def _norm01(values: np.ndarray, finite: np.ndarray) -> np.ndarray:
    """Min-max normalize the finite lanes; non-finite lanes are left at 0
    (callers mask them out of the score separately, keeping inf - inf NaNs
    out of the arithmetic entirely)."""
    out = np.zeros_like(values, dtype=np.float64)
    vf = values[finite]
    lo = float(vf.min())
    out[finite] = (vf - lo) / max(float(vf.max()) - lo, 1e-12)
    return out


def _frontier_stats(rows) -> tuple[np.ndarray, np.ndarray]:
    """Per-candidate (mean, CoV) from job-time sample rows.

    Degenerate rows -- no finite samples, or an all-zero mean -- score
    (inf, inf) so selection can rank them last instead of dividing by zero.
    """
    means, covs = [], []
    for t in rows:
        t = np.asarray(t)
        t = t[np.isfinite(t)]
        m = float(t.mean()) if t.size else math.inf
        if t.size == 0 or m <= 0.0:
            means.append(math.inf if t.size == 0 else m)
            covs.append(math.inf)
            continue
        means.append(m)
        covs.append(float(t.std() / m))
    return np.array(means), np.array(covs)


def plan_sweep(
    dists: Sequence[ServiceTime],
    budgets: Sequence[int],
    objective: str = "mean",
    *,
    n_reps: int = 400,
    seed: int = 0,
    blend: float = 0.5,
    size_dependent=_UNSET,
    cancel_redundant=_UNSET,
    backend: str = "jax",
    candidates: Iterable[int] | None = None,
    speeds=_UNSET,
    churn=_UNSET,
    churn_schedule=_UNSET,
    replan=_UNSET,
    speculation=_UNSET,
    scheduler=_UNSET,
    workers_per_job=_UNSET,
    job_plans=_UNSET,
    jobs_per_stream=_UNSET,
    churn_pairs_per_worker=_UNSET,
    dtype=_UNSET,
    rep_chunk=_UNSET,
    devices=_UNSET,
    scenario=None,
) -> list:
    """Score redundancy frontiers for a (distribution x worker-budget) grid.

    Returns ``plans`` with ``plans[i][j]`` the :class:`RedundancyPlan` for
    ``dists[i]`` under ``budgets[j]``.  Each grid point scores its entire
    candidate frontier in one batched device call (``backend="jax"``), so a
    sweep that would take ``len(dists) * len(budgets) * len(candidates)``
    Python event loops is a handful of vectorized kernels -- the regime the
    §VI/§VII trade-off studies live in.

    ``churn`` / ``churn_schedule`` / ``replan`` (plus the
    ``jobs_per_stream`` / ``churn_pairs_per_worker`` stream-shape knobs)
    extend the sweep to dynamic scenarios, forwarded to every grid point's
    :meth:`plan_cluster` (scored on the epoch-scan step loop under
    ``backend="jax"``).  ``speeds`` takes either one per-worker sequence
    (every budget must then equal its length) or a callable
    ``budget -> speeds`` for heterogeneous grids.

    Grid point (i, j) uses seed ``seed + i * len(budgets) + j``; the
    property-test suite relies on that derivation to check each sweep entry
    against an identically-seeded per-candidate :meth:`plan_cluster` call.

    Dynamic grid points share compiled kernels across the whole sweep: the
    epoch scan pads worker/job/event/lane counts to shape buckets, so nearby
    budgets hit one compile (``repro.cluster.epoch_scan.runner_cache_stats``
    counts them).  ``dtype``/``rep_chunk``/``devices`` forward to every grid
    point -- ``devices > 1`` shards each point's lane grid via ``shard_map``
    with results identical to single-device execution.

    Scenario knobs are best passed as one ``scenario=Scenario(...)``; the
    loose keyword forms keep working behind a ``DeprecationWarning`` shim.
    A callable ``speeds`` stays a sweep-level convenience (it cannot live in
    a frozen Scenario) and is re-attached per budget.  ``Scenario.outputs``
    forwards like every other field but does not change planning: every grid
    point scores on the reduced-output frontier lanes either way (see
    :meth:`RedundancyPlanner.plan_cluster`).
    """
    from ..cluster.scenario import resolve_scenario

    speeds_fn = speeds if callable(speeds) else None
    if speeds_fn is not None and scenario is not None:
        raise ValueError(
            "plan_sweep: got scenario= and loose scenario kwargs (speeds); "
            "pass per-budget speeds by calling plan_sweep once per budget "
            "with scenario.replace(speeds=...)"
        )
    explicit = {
        k: v
        for k, v in {
            "size_dependent": size_dependent,
            "cancel_redundant": cancel_redundant,
            "speeds": speeds,
            "churn": churn,
            "churn_schedule": churn_schedule,
            "replan": replan,
            "speculation": speculation,
            "scheduler": scheduler,
            "workers_per_job": workers_per_job,
            "job_plans": job_plans,
            "jobs_per_stream": jobs_per_stream,
            "churn_pairs_per_worker": churn_pairs_per_worker,
            "dtype": dtype,
            "rep_chunk": rep_chunk,
            "devices": devices,
        }.items()
        if v is not _UNSET
    }
    if speeds_fn is not None:
        explicit.pop("speeds")  # re-attached per grid point below
    sc = resolve_scenario(scenario, explicit, where="plan_sweep")

    dists = list(dists)
    budgets = [int(n) for n in budgets]
    plans = []
    for i, dist in enumerate(dists):
        row = []
        for j, n_workers in enumerate(budgets):
            planner = RedundancyPlanner(n_workers, candidates=candidates)
            sc_ij = sc.replace(speeds=speeds_fn(n_workers)) if speeds_fn is not None else sc
            row.append(
                planner.plan_cluster(
                    dist,
                    objective,
                    n_reps=n_reps,
                    seed=seed + i * len(budgets) + j,
                    blend=blend,
                    backend=backend,
                    scenario=sc_ij,
                )
            )
        plans.append(row)
    return plans
