"""RedundancyPlanner: the paper's §VI-§VII results as a control-plane service.

Given a worker budget N and knowledge of the task/step service-time behaviour
(a fitted distribution or raw trace samples), the planner returns the
operating point on the diversity-parallelism spectrum:

    B  = number of distinct (non-overlapping) batches / data shards
    r  = N / B = replication factor per batch

optimizing either average job time (paper Thms 3/5/8), predictability
(CoV, Thms 4/7/10), or a weighted blend -- the paper's "system administrator
middle point" (§VI-A closing remark).

The distributed runtime (repro.distributed) consumes the plan to factorize
the data mesh axis into ("replica", "shard"), and the elastic controller
replans on membership changes.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Iterable, Sequence

import numpy as np

from . import analysis
from .service_time import (
    Empirical,
    Exponential,
    Pareto,
    ServiceTime,
    ShiftedExponential,
)

__all__ = ["RedundancyPlan", "RedundancyPlanner", "fit_service_time"]


@dataclasses.dataclass(frozen=True)
class RedundancyPlan:
    n_workers: int
    n_batches: int  # B: distinct data shards
    replication: int  # r = N / B
    objective: str  # 'mean' | 'cov' | 'blend'
    predicted_mean: float
    predicted_cov: float
    # full frontier for observability dashboards
    frontier_B: tuple
    frontier_mean: tuple
    frontier_cov: tuple
    source: str  # 'closed_form:<dist>' | 'empirical_bootstrap'

    @property
    def diversity(self) -> float:
        """0 = full parallelism (B=N), 1 = full diversity (B=1)."""
        if self.n_workers == 1:
            return 1.0
        return 1.0 - (self.n_batches - 1) / (self.n_workers - 1)


def fit_service_time(samples: Sequence[float]) -> ServiceTime:
    """Fit Exp / SExp / Pareto by maximum likelihood and pick by log-lik.

    Mirrors §VII: classify a job's tasks as exponential-tail or heavy-tail
    from its service-time records, then plan with the matching closed form.
    """
    x = np.asarray(samples, dtype=np.float64)
    x = x[x > 0]
    if x.size < 2:
        raise ValueError("need at least 2 positive samples")
    n = x.size
    xmin, xbar = float(x.min()), float(x.mean())

    fits: list[tuple[float, ServiceTime]] = []

    # Exponential(mu): MLE mu = 1/mean
    mu = 1.0 / xbar
    ll_exp = n * math.log(mu) - mu * x.sum()
    fits.append((ll_exp, Exponential(mu=mu)))

    # ShiftedExponential(delta, mu): MLE delta = min, mu = 1/(mean - min)
    if xbar > xmin:
        delta = xmin
        mu_s = 1.0 / (xbar - xmin)
        ll_sexp = n * math.log(mu_s) - mu_s * float((x - delta).sum())
        fits.append((ll_sexp, ShiftedExponential(delta=delta, mu=mu_s)))

    # Pareto(sigma, alpha): MLE sigma = min, alpha = n / sum log(x/sigma)
    logs = np.log(x / xmin)
    s_logs = float(logs.sum())
    if s_logs > 0:
        alpha = n / s_logs
        ll_par = n * math.log(alpha) + n * alpha * math.log(xmin) - (alpha + 1.0) * float(
            np.log(x).sum()
        )
        fits.append((ll_par, Pareto(sigma=xmin, alpha=alpha)))

    fits.sort(key=lambda p: p[0], reverse=True)
    return fits[0][1]


class RedundancyPlanner:
    """Plans (B, r) for a worker budget from closed forms or traces."""

    def __init__(self, n_workers: int, candidates: Iterable[int] | None = None):
        self.n_workers = int(n_workers)
        self.candidates = (
            list(candidates) if candidates is not None else analysis.feasible_B(self.n_workers)
        )

    # -- closed-form path ---------------------------------------------------

    def plan(
        self, dist: ServiceTime, objective: str = "mean", blend: float = 0.5
    ) -> RedundancyPlan:
        if isinstance(dist, Empirical):
            return self.plan_empirical(np.asarray(dist.samples), objective, blend=blend)
        n = self.n_workers
        means = np.array([analysis.mean_T(dist, n, b) for b in self.candidates])
        covs = np.array([analysis.cov_T(dist, n, b) for b in self.candidates])
        b = self._select(means, covs, objective, blend)
        return self._mk_plan(b, means, covs, objective, f"closed_form:{type(dist).__name__}")

    # -- trace/empirical path (bootstrap over the §VI size model) -----------

    def plan_empirical(
        self,
        samples: np.ndarray,
        objective: str = "mean",
        n_mc: int = 20_000,
        seed: int = 0,
        blend: float = 0.5,
    ) -> RedundancyPlan:
        """Estimate E[T](B) and CoV(B) by resampling task times from the trace.

        This is the experiment of Figs. 12-13: for each feasible B, draw task
        service times, form batch times (N/B)*tau, take max-min.
        """
        x = np.asarray(samples, dtype=np.float64)
        rng = np.random.default_rng(seed)
        n = self.n_workers
        means, covs = [], []
        for b in self.candidates:
            r = n // b
            draws = rng.choice(x, size=(n_mc, b, r), replace=True) * (n / b)
            t = draws.min(axis=2).max(axis=1)
            means.append(float(t.mean()))
            covs.append(float(t.std() / t.mean()))
        means, covs = np.array(means), np.array(covs)
        b = self._select(means, covs, objective, blend)
        return self._mk_plan(b, means, covs, objective, "empirical_bootstrap")

    def plan_auto(self, samples: np.ndarray, objective: str = "mean") -> RedundancyPlan:
        """§VII methodology: fit the tail family, then use its closed form."""
        dist = fit_service_time(samples)
        return self.plan(dist, objective=objective)

    # -- engine path (candidates scored by the event-driven cluster engine) --

    def plan_cluster(
        self,
        dist: ServiceTime,
        objective: str = "mean",
        n_reps: int = 400,
        seed: int = 0,
        blend: float = 0.5,
        size_dependent: bool = True,
        cancel_redundant: bool = False,
    ) -> RedundancyPlan:
        """Pick (B, r) by *executing* each candidate on ``repro.cluster``.

        Unlike the closed-form/bootstrap paths, this scores candidates under
        the engine's operational semantics (dispatch, earliest cover, and --
        when enabled -- replica cancellation), so it extends to scenarios the
        formulas do not cover.  Lazy import: core stays importable without
        the cluster package loaded (cluster imports core).
        """
        from ..cluster.master import sample_job_times

        means, covs = [], []
        for i, b in enumerate(self.candidates):
            t = sample_job_times(
                dist,
                self.n_workers,
                b,
                n_reps,
                seed=seed + i,
                size_dependent=size_dependent,
                cancel_redundant=cancel_redundant,
            )
            t = t[np.isfinite(t)]
            m = float(t.mean())
            means.append(m)
            covs.append(float(t.std() / m) if m > 0 else np.inf)
        means, covs = np.array(means), np.array(covs)
        b = self._select(means, covs, objective, blend)
        return self._mk_plan(b, means, covs, objective, "cluster_engine")

    # -- helpers -------------------------------------------------------------

    def _select(self, means, covs, objective, blend) -> int:
        if objective == "mean":
            idx = int(np.argmin(means))
        elif objective == "cov":
            idx = int(np.argmin(covs))
        elif objective == "blend":
            # normalized blend: the administrator's middle point
            mn = (means - means.min()) / max(float(np.ptp(means)), 1e-12)
            cn = (covs - covs.min()) / max(float(np.ptp(covs)), 1e-12)
            idx = int(np.argmin(blend * mn + (1 - blend) * cn))
        else:
            raise ValueError(f"unknown objective {objective!r}")
        return self.candidates[idx]

    def _mk_plan(self, b, means, covs, objective, source) -> RedundancyPlan:
        i = self.candidates.index(b)
        return RedundancyPlan(
            n_workers=self.n_workers,
            n_batches=b,
            replication=self.n_workers // b,
            objective=objective,
            predicted_mean=float(means[i]),
            predicted_cov=float(covs[i]),
            frontier_B=tuple(self.candidates),
            frontier_mean=tuple(float(m) for m in means),
            frontier_cov=tuple(float(c) for c in covs),
            source=source,
        )
