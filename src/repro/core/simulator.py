"""Monte-Carlo job compute-time simulator (oracle for §IV-§VI, engine for §VII).

Semantics: every worker w computes its batch and delivers at time ``T_w``;
the job completes at the earliest time when the union of delivered batches
covers all N tasks.  For balanced non-overlapping batches this reduces to the
paper's ``T = max_i min_j T_ij``; for overlapping schemes (Fig. 5) it equals
the min-over-covers expressions (12)-(15).

All samplers are jax so that millions of samples vectorize; chunked ``lax.map``
keeps the (samples x workers x tasks) cover tensor inside memory.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from .service_time import ServiceTime

__all__ = [
    "gang_cover_times",
    "simulate_balanced",
    "simulate_counts",
    "simulate_membership",
    "JobTimeStats",
    "stats_from_samples",
]


@dataclasses.dataclass(frozen=True)
class JobTimeStats:
    """Summary statistics of a job-time sample (mean, spread, tail quantiles)."""

    mean: float
    std: float
    cov: float  # coefficient of variation -- the paper's predictability metric
    p50: float
    p95: float
    p99: float
    n_samples: int

    @staticmethod
    def empty() -> "JobTimeStats":
        """The all-NaN stats object for an empty sample."""
        return JobTimeStats(np.nan, np.nan, np.nan, np.nan, np.nan, np.nan, 0)


def stats_from_samples(samples: np.ndarray) -> JobTimeStats:
    """Fold a sample of job times into :class:`JobTimeStats`."""
    s = np.asarray(samples, dtype=np.float64)
    m = float(s.mean())
    sd = float(s.std())
    return JobTimeStats(
        mean=m,
        std=sd,
        cov=sd / m if m > 0 else np.inf,
        p50=float(np.percentile(s, 50)),
        p95=float(np.percentile(s, 95)),
        p99=float(np.percentile(s, 99)),
        n_samples=int(s.size),
    )


# --------------------------------------------------------------------------
# balanced non-overlapping fast path:  T = max_{i<=B} min_{j<=r} s * tau_ij
# --------------------------------------------------------------------------


def gang_cover_times(
    draws: jax.Array,
    n_batches: jax.Array | int | None = None,
    replication: jax.Array | int | None = None,
) -> jax.Array:
    """Earliest-cover completion of a balanced gang dispatch: ``max_b min_r``.

    ``draws`` carries replica durations on its last two axes, shaped
    ``(..., B_pad, r_pad)``.  With ``n_batches``/``replication`` given
    (scalars, possibly traced), slots beyond them are masked out, so one
    padded ``(B_pad, r_pad)`` grid serves a whole frontier of (B, r)
    candidates -- the vectorized cluster backend (``repro.cluster.vectorized``)
    vmaps this kernel over candidates, while ``simulate_balanced`` and the
    event engine's semantics are its unmasked special case.  The epoch-scan
    step loop (``repro.cluster.epoch_scan``) realizes the same contract
    incrementally: each commit step takes a segment-min over each batch's
    live replicas and the max over batches, which reduces to this kernel
    whenever a job fits inside one churn epoch; ``repro.kernels.cover``
    carries the Pallas-fused formulation (TPU opt-in).
    """
    b_pad, r_pad = draws.shape[-2], draws.shape[-1]
    if replication is not None:
        draws = jnp.where(jnp.arange(r_pad) < replication, draws, jnp.inf)
    t_batch = jnp.min(draws, axis=-1)
    if n_batches is not None:
        t_batch = jnp.where(jnp.arange(b_pad) < n_batches, t_batch, -jnp.inf)
    return jnp.max(t_batch, axis=-1)


def simulate_balanced(
    key: jax.Array,
    dist: ServiceTime,
    n_workers: int,
    n_batches: int,
    n_samples: int,
    size_dependent: bool = True,
) -> np.ndarray:
    """Job times under the balanced non-overlapping policy.

    size_dependent=True uses the §VI model (batch time = (N/B) * tau);
    False uses the §IV model (batch times drawn from ``dist`` directly).
    """
    if n_workers % n_batches:
        raise ValueError("B must divide N")
    r = n_workers // n_batches
    scale = n_workers / n_batches if size_dependent else 1.0
    draws = dist.sample(key, (n_samples, n_batches, r)) * scale
    return np.asarray(gang_cover_times(draws))


# --------------------------------------------------------------------------
# general counts vector (possibly unbalanced; §IV Lemma 2 experiments)
# --------------------------------------------------------------------------


def simulate_counts(
    key: jax.Array,
    dist: ServiceTime,
    counts: np.ndarray,
    n_samples: int,
    size_dependent: bool = False,
    n_tasks: int | None = None,
) -> np.ndarray:
    """T = max_i min over N_i hosts, for an arbitrary host-count vector.

    Batches with zero hosts make the job incomplete; we return inf for those
    samples (the paper's "inaccurate result" failure of random assignment).
    """
    counts = np.asarray(counts)
    n_batches = counts.shape[0]
    max_c = int(counts.max())
    if max_c == 0:
        # All batches hostless: the mask-based inf path below would sample a
        # zero-width axis and jnp.min over it is undefined -- guard explicitly.
        return np.full(n_samples, np.inf)
    scale = 1.0
    if size_dependent:
        if n_tasks is None:
            raise ValueError("size_dependent requires n_tasks")
        scale = n_tasks / n_batches
    draws = dist.sample(key, (n_samples, n_batches, max_c)) * scale
    # mask out slots beyond each batch's host count
    mask = jnp.arange(max_c)[None, :] < jnp.asarray(counts)[:, None]  # (B, max_c)
    draws = jnp.where(mask[None], draws, jnp.inf)
    batch_t = jnp.min(draws, axis=2)  # (S, B); inf where count == 0
    return np.asarray(jnp.max(batch_t, axis=1))


# --------------------------------------------------------------------------
# general membership matrix (overlapping schemes; earliest-cover semantics)
# --------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("chunk",))
def _cover_times(times: jax.Array, membership: jax.Array, chunk: int = 4096) -> jax.Array:
    """times: (S, W); membership: (W, T) bool -> (S,) job completion times."""

    def one(ts):
        order = jnp.argsort(ts)
        m = membership[order]  # (W, T)
        covered = jnp.all(jnp.cumsum(m, axis=0) > 0, axis=1)  # (W,)
        idx = jnp.argmax(covered)  # first worker index at which cover completes
        complete = covered[-1]
        t = jnp.sort(ts)[idx]
        return jnp.where(complete, t, jnp.inf)

    s = times.shape[0]
    pad = (-s) % chunk
    padded = jnp.pad(times, ((0, pad), (0, 0)))
    out = jax.lax.map(jax.vmap(one), padded.reshape(-1, chunk, times.shape[1]))
    return out.reshape(-1)[:s]


def simulate_membership(
    key: jax.Array,
    dist: ServiceTime,
    membership: np.ndarray,
    n_samples: int,
    size_dependent: bool = True,
) -> np.ndarray:
    """Job times for any batching scheme (Fig. 5 schemes 1/2/3, random, ...)."""
    membership = np.asarray(membership, dtype=bool)
    n_workers, _ = membership.shape
    batch_sizes = membership.sum(axis=1)
    scale = jnp.asarray(batch_sizes, dtype=jnp.float32) if size_dependent else 1.0
    draws = dist.sample(key, (n_samples, n_workers)) * scale
    return np.asarray(_cover_times(draws, jnp.asarray(membership)))
