"""Batch-to-worker assignment and majorization machinery (§IV, Lemmas 2-3).

An assignment of B non-overlapping batches to N workers is summarized by the
vector Nbar = (N_1, ..., N_B) of per-batch host counts, sum N_i = N.  The
paper's result: if batch service times are stochastically decreasing-convex,
E[T(Nbar1)] >= E[T(Nbar2)] whenever Nbar1 majorizes Nbar2 -- so the balanced
vector (N/B, .., N/B), majorized by everything (Lemma 3), is optimal (Thm 1-2).
"""
from __future__ import annotations

import numpy as np

__all__ = [
    "balanced_counts",
    "counts_from_membership",
    "majorizes",
    "is_balanced",
    "assignment_from_counts",
    "random_counts",
]


def balanced_counts(n_workers: int, n_batches: int) -> np.ndarray:
    """Lemma 3's vector: (N/B, ..., N/B).  Requires B | N like the paper."""
    if n_workers % n_batches:
        raise ValueError(f"B={n_batches} must divide N={n_workers}")
    return np.full(n_batches, n_workers // n_batches, dtype=np.int64)


def counts_from_membership(membership: np.ndarray) -> np.ndarray:
    """Per-batch host counts from a non-overlapping membership matrix.

    Workers with identical rows host the same batch.
    """
    _, inverse = np.unique(membership, axis=0, return_inverse=True)
    return np.bincount(inverse)


def majorizes(v: np.ndarray, w: np.ndarray) -> bool:
    """True iff v majorizes w (Definition 4)."""
    v = np.sort(np.asarray(v))[::-1]
    w = np.sort(np.asarray(w))[::-1]
    if v.shape != w.shape or v.sum() != w.sum():
        return False
    return bool(np.all(np.cumsum(v) >= np.cumsum(w)))


def is_balanced(counts: np.ndarray) -> bool:
    """Whether every batch landed on the same number of workers."""
    counts = np.asarray(counts)
    return bool(counts.min() == counts.max())


def assignment_from_counts(counts: np.ndarray) -> np.ndarray:
    """Worker -> batch id map realizing a host-count vector."""
    out = np.concatenate([np.full(c, i, dtype=np.int64) for i, c in enumerate(counts)])
    return out


def random_counts(n_workers: int, n_batches: int, rng: np.random.Generator) -> np.ndarray:
    """Host-count vector of the coupon-collector assignment (may have zeros)."""
    draws = rng.integers(0, n_batches, size=n_workers)
    return np.bincount(draws, minlength=n_batches)
