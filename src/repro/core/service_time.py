"""Service-time models from §II-D of the paper.

Three families, all "stochastically decreasing and convex" in the sense the
paper needs for the majorization results:

  * ``Exp(mu)``            -- memoryless baseline (Eq. 3)
  * ``SExp(delta, mu)``    -- shifted exponential, minimum service time delta (Eq. 4)
  * ``Pareto(sigma, alpha)`` -- heavy tail, scale sigma / shape alpha (Eq. 5)

Two usage modes mirror the paper:

  * §IV (batch-level model): the service time of *batch i at worker j*,
    ``T_ij``, is drawn i.i.d. from the distribution directly.
  * §VI (size-dependent model, from Gardner et al. [71]): a *task* has service
    time ``tau`` and a batch of ``s`` tasks takes ``s * tau``.  This is what
    the optimal-redundancy-level results use; ``scaled_by`` implements it.

Everything is a small frozen dataclass so it can be passed around configs and
hashed into jit static args.  Sampling works with both numpy Generators and
jax PRNG keys (the Monte-Carlo simulator uses jax, the planner's bootstrap
uses numpy).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Union

import jax
import jax.numpy as jnp
import numpy as np

ArrayLike = Union[np.ndarray, jax.Array]


@dataclasses.dataclass(frozen=True)
class ServiceTime:
    """Base class: a positive random variable with a CCDF and samplers."""

    def ccdf(self, t: ArrayLike) -> ArrayLike:
        """Survival function ``P[tau > t]``."""
        raise NotImplementedError

    def mean(self) -> float:
        """Expected service time ``E[tau]``."""
        raise NotImplementedError

    def var(self) -> float:
        """Service-time variance ``Var[tau]``."""
        raise NotImplementedError

    def sample(self, key: jax.Array, shape: tuple) -> jax.Array:
        """Draw ``shape`` service times on device (jit-traceable)."""
        raise NotImplementedError

    def sample_np(self, rng: np.random.Generator, shape: tuple) -> np.ndarray:
        """Draw ``shape`` service times on host (planning paths)."""
        raise NotImplementedError

    def scaled_by(self, s: float) -> "ServiceTime":
        """Distribution of ``s * tau`` (size-dependent batch model, §VI)."""
        raise NotImplementedError

    def cov(self) -> float:
        """Coefficient of variation ``sqrt(Var)/E`` -- the §V spread metric."""
        m = self.mean()
        return math.sqrt(self.var()) / m


@dataclasses.dataclass(frozen=True)
class Exponential(ServiceTime):
    """Exponential service times ``Exp(mu)`` -- the paper's light-tail model."""

    mu: float  # rate

    def ccdf(self, t):
        """Survival function ``P[tau > t]``."""
        xp = jnp if isinstance(t, jax.Array) else np
        t = xp.asarray(t)
        return xp.where(t >= 0.0, xp.exp(-self.mu * t), 1.0)

    def mean(self):
        """Expected service time ``E[tau]``."""
        return 1.0 / self.mu

    def var(self):
        """Service-time variance ``Var[tau]``."""
        return 1.0 / self.mu**2

    def sample(self, key, shape):
        """Draw ``shape`` service times on device (jit-traceable)."""
        return jax.random.exponential(key, shape) / self.mu

    def sample_np(self, rng, shape):
        """Draw ``shape`` service times on host (planning paths)."""
        return rng.exponential(scale=1.0 / self.mu, size=shape)

    def scaled_by(self, s):
        """Distribution of ``s * tau`` (size-dependent batch model, §VI)."""
        # s * Exp(mu) ~ Exp(mu / s)
        return Exponential(mu=self.mu / s)


@dataclasses.dataclass(frozen=True)
class ShiftedExponential(ServiceTime):
    """Shifted exponential ``delta + Exp(mu)``: a hard floor plus memoryless tail."""

    delta: float  # minimum service time (shift)
    mu: float  # rate of the random part

    def ccdf(self, t):
        """Survival function ``P[tau > t]``."""
        xp = jnp if isinstance(t, jax.Array) else np
        t = xp.asarray(t)
        return xp.where(t >= self.delta, xp.exp(-self.mu * (t - self.delta)), 1.0)

    def mean(self):
        """Expected service time ``E[tau]``."""
        return self.delta + 1.0 / self.mu

    def var(self):
        """Service-time variance ``Var[tau]``."""
        return 1.0 / self.mu**2

    def sample(self, key, shape):
        """Draw ``shape`` service times on device (jit-traceable)."""
        return self.delta + jax.random.exponential(key, shape) / self.mu

    def sample_np(self, rng, shape):
        """Draw ``shape`` service times on host (planning paths)."""
        return self.delta + rng.exponential(scale=1.0 / self.mu, size=shape)

    def scaled_by(self, s):
        """Distribution of ``s * tau`` (size-dependent batch model, §VI)."""
        # s * SExp(delta, mu) ~ SExp(s * delta, mu / s)
        return ShiftedExponential(delta=self.delta * s, mu=self.mu / s)


@dataclasses.dataclass(frozen=True)
class Pareto(ServiceTime):
    """Pareto service times -- the paper's heavy-tail straggler model."""

    sigma: float  # scale (minimum value)
    alpha: float  # shape (tail index); mean finite iff alpha > 1

    def ccdf(self, t):
        """Survival function ``P[tau > t]``."""
        xp = jnp if isinstance(t, jax.Array) else np
        t = xp.asarray(t)
        return xp.where(t >= self.sigma, (t / self.sigma) ** (-self.alpha), 1.0)

    def mean(self):
        """Expected service time ``E[tau]``."""
        if self.alpha <= 1.0:
            return math.inf
        return self.alpha * self.sigma / (self.alpha - 1.0)

    def var(self):
        """Service-time variance ``Var[tau]``."""
        if self.alpha <= 2.0:
            return math.inf
        a = self.alpha
        return self.sigma**2 * a / ((a - 1.0) ** 2 * (a - 2.0))

    def sample(self, key, shape):
        """Draw ``shape`` service times on device (jit-traceable)."""
        u = jax.random.uniform(key, shape, minval=jnp.finfo(jnp.float32).tiny, maxval=1.0)
        return self.sigma * u ** (-1.0 / self.alpha)

    def sample_np(self, rng, shape):
        """Draw ``shape`` service times on host (planning paths)."""
        u = rng.uniform(low=np.finfo(np.float64).tiny, high=1.0, size=shape)
        return self.sigma * u ** (-1.0 / self.alpha)

    def scaled_by(self, s):
        """Distribution of ``s * tau`` (size-dependent batch model, §VI)."""
        # s * Pareto(sigma, alpha) ~ Pareto(s * sigma, alpha)  (alpha unchanged)
        return Pareto(sigma=self.sigma * s, alpha=self.alpha)


@dataclasses.dataclass(frozen=True)
class Empirical(ServiceTime):
    """Trace-driven service time: resample (with replacement) from observations.

    ``samples`` is a tuple so the dataclass stays hashable; the paper's §VII
    experiments draw task service times straight from the Google-trace-derived
    per-job datasets, which is exactly this.
    """

    samples: tuple

    def _arr(self):
        return np.asarray(self.samples, dtype=np.float64)

    def ccdf(self, t):
        """Survival function ``P[tau > t]``."""
        s = self._arr()
        t = np.asarray(t, dtype=np.float64)
        # P(X > t) estimated from the empirical distribution.
        return (s[None, ...] > np.expand_dims(t, -1)).mean(axis=-1)

    def mean(self):
        """Expected service time ``E[tau]``."""
        return float(self._arr().mean())

    def var(self):
        """Service-time variance ``Var[tau]``."""
        return float(self._arr().var())

    def sample(self, key, shape):
        """Draw ``shape`` service times on device (jit-traceable)."""
        s = jnp.asarray(self._arr())
        idx = jax.random.randint(key, shape, 0, s.shape[0])
        return s[idx]

    def sample_np(self, rng, shape):
        """Draw ``shape`` service times on host (planning paths)."""
        s = self._arr()
        return rng.choice(s, size=shape, replace=True)

    def scaled_by(self, s):
        """Distribution of ``s * tau`` (size-dependent batch model, §VI)."""
        return Empirical(samples=tuple(float(x) * s for x in self.samples))


def min_of(dist: ServiceTime, n: int) -> ServiceTime:
    """Distribution of min of n i.i.d. draws, where closed under the family.

    Used in §IV: the compute time of a batch hosted by n workers is the first
    order statistic.  Exp(mu) -> Exp(n mu); SExp(d, mu) -> SExp(d, n mu);
    Pareto(s, a) -> Pareto(s, n a).
    """
    if isinstance(dist, Exponential):
        return Exponential(mu=dist.mu * n)
    if isinstance(dist, ShiftedExponential):
        return ShiftedExponential(delta=dist.delta, mu=dist.mu * n)
    if isinstance(dist, Pareto):
        return Pareto(sigma=dist.sigma, alpha=dist.alpha * n)
    raise TypeError(f"min_of not closed for {type(dist).__name__}")
