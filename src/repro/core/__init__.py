"""The paper's primary contribution: efficient replication planning.

Public surface:
  * batching / assignment  -- §III-§IV schemes and majorization tools
  * service_time           -- Exp / SExp / Pareto / Empirical models
  * analysis               -- closed-form E[T], CoV[T] and regime boundaries
  * coupon                 -- Lemma 1 coverage probability of random placement
  * simulator              -- vectorized Monte-Carlo job-time oracle
  * planner                -- RedundancyPlanner -> (B, r) for the runtime
  * traces                 -- Google-trace-like workload generator (§VII)

Plans produced here are *executed* by ``repro.cluster``: an event-driven
master-worker engine with queueing, replica cancellation, worker churn, and
an online replanner that refits the service-time model from observed task
times (``RedundancyPlanner.plan_cluster`` scores candidates on that engine).
"""
from . import analysis, assignment, batching, coupon, simulator, traces
from .planner import (
    RedundancyPlan,
    RedundancyPlanner,
    SLOCandidate,
    SLOPlan,
    fit_service_time,
    plan_sweep,
)

# re-exported after core's own submodules are bound: cluster's modules import
# those submodules directly, so this back-edge stays cycle-safe either way
# the packages are first imported
from ..cluster.scenario import SLO, Scenario
from .service_time import (
    Empirical,
    Exponential,
    Pareto,
    ServiceTime,
    ShiftedExponential,
    min_of,
)

__all__ = [
    "analysis",
    "assignment",
    "batching",
    "coupon",
    "simulator",
    "traces",
    "RedundancyPlan",
    "RedundancyPlanner",
    "SLO",
    "SLOCandidate",
    "SLOPlan",
    "Scenario",
    "fit_service_time",
    "plan_sweep",
    "Empirical",
    "Exponential",
    "Pareto",
    "ServiceTime",
    "ShiftedExponential",
    "min_of",
]
