"""Closed-form compute-time analysis (§IV and §VI of the paper).

All formulas are for the *balanced assignment of non-overlapping batches*
(shown optimal in Thms 1-2), under the size-dependent service model of §VI:
a batch of ``N/B`` tasks at one worker takes ``(N/B) * tau``, each of the
``N/B`` workers hosting a batch is i.i.d., and the job time is

    T = max_{i in 1..B} min_{j in 1..N/B} T_ij.

Implemented results:

  * ``H(B)``, ``H2(B)``          -- harmonic numbers (first / second order)
  * Exponential:      E[T] (Thm 3, Eq. 26), CoV (Lemma 4, Eq. 18)
  * Shifted-Exp:      E[T] (Thm 5, Eq. 19/33), CoV (Lemma 5, Eq. 21),
                      regime boundaries (Thm 6), B* approx (Cor 2),
                      CoV end-point rules (Thm 7 / Cor 3)
  * Pareto:           E[T] (Thm 8, Eq. 22/61), CoV (Lemma 6, Eq. 24),
                      alpha* root of Eq. (23) (Thm 9), CoV monotone (Thm 10)

Everything is scalar/numpy math (the planner calls these thousands of times;
no jit needed).  Gamma ratios use ``math.lgamma`` for stability at large B.
"""
from __future__ import annotations

import math
from typing import Iterable, List

import numpy as np

from .service_time import Exponential, Pareto, ServiceTime, ShiftedExponential

# --------------------------------------------------------------------------
# harmonic numbers
# --------------------------------------------------------------------------


def harmonic(n: int, order: int = 1) -> float:
    """H_{(n,order)} = sum_{k=1..n} 1/k^order  (paper's H_{(B,1)}, H_{(B,2)})."""
    if n < 0:
        raise ValueError("n must be >= 0")
    return float(sum(1.0 / k**order for k in range(1, n + 1)))


def feasible_B(n_workers: int) -> List[int]:
    """F_B: the feasible redundancy levels, B | N (paper §II-C)."""
    return [b for b in range(1, n_workers + 1) if n_workers % b == 0]


def divisor_table(n: int) -> np.ndarray:
    """Rows m = 0..n of ``feasible_B(m)``, zero-padded to a rectangle.

    The in-scan replanner of ``repro.cluster.epoch_scan`` indexes this by the
    (traced) alive-worker count to re-pick B without leaving the device.
    """
    divs = [feasible_B(m) for m in range(n + 1)]
    width = max((len(d) for d in divs), default=1)
    tab = np.zeros((n + 1, max(width, 1)), dtype=np.int32)
    for m, d in enumerate(divs):
        tab[m, : len(d)] = d
    return tab


def harmonic_tables(n: int) -> tuple:
    """(H_{(k,1)}, H_{(k,2)}) for k = 0..n as arrays (closed forms on device)."""
    h1 = np.zeros(n + 1)
    h2 = np.zeros(n + 1)
    for k in range(1, n + 1):
        h1[k] = h1[k - 1] + 1.0 / k
        h2[k] = h2[k - 1] + 1.0 / k**2
    return h1, h2


# --------------------------------------------------------------------------
# Exponential tasks  (§VI-A)
# --------------------------------------------------------------------------


def exp_mean_T(n: int, b: int, mu: float) -> float:
    """E[T] = H_B / mu  (Eq. 26).  Independent of N under the size model."""
    del n
    return harmonic(b) / mu


def exp_cov_T(b: int) -> float:
    """CoV[T] = sqrt(H_{B,2}) / H_{B,1}  (Lemma 4, Eq. 18)."""
    return math.sqrt(harmonic(b, 2)) / harmonic(b)


# --------------------------------------------------------------------------
# Shifted-exponential tasks  (§VI-B)
# --------------------------------------------------------------------------


def sexp_mean_T(n: int, b: int, delta: float, mu: float) -> float:
    """E[T] = N*delta/B + H_B/mu  (Thm 5, Eq. 33)."""
    return n * delta / b + harmonic(b) / mu


def sexp_cov_T(n: int, b: int, delta: float, mu: float) -> float:
    """CoV[T] = sqrt(H_{B,2}) / (N*delta*mu/B + H_{B,1})  (Lemma 5, Eq. 21)."""
    return math.sqrt(harmonic(b, 2)) / (n * delta * mu / b + harmonic(b))


def sexp_mean_regime(n: int, delta: float, mu: float) -> str:
    """Thm 6 regimes for the E[T]-optimal operating point.

    Returns one of 'full_diversity' | 'middle' | 'full_parallelism'.
    """
    dm = delta * mu
    lo = 1.0 / n
    hi = harmonic(n) - harmonic(n // 2)  # sum_{k=N/2+1}^{N} 1/k
    if dm < lo:
        return "full_diversity"
    if dm > hi:
        return "full_parallelism"
    return "middle"


def sexp_B_star_approx(n: int, delta: float, mu: float) -> float:
    """Cor 2: in the middle regime the continuous optimum is B ~= N*delta*mu."""
    return n * delta * mu


def sexp_cov_regime(n: int, delta: float, mu: float) -> str:
    """Thm 7 / Cor 3 regimes for the CoV-optimal operating point."""
    dm = delta * mu
    lo = 3.0 / ((math.sqrt(5.0) - 1.0) * n)
    h_n1, h_n2 = harmonic(n), harmonic(n, 2)
    h_h1, h_h2 = harmonic(n // 2), harmonic(n // 2, 2)
    hi = (h_n1 * math.sqrt(h_h2) - h_h1 * math.sqrt(h_n2)) / (
        2.0 * math.sqrt(h_n2) - math.sqrt(h_h2)
    )
    if dm < lo:
        return "full_parallelism"
    if dm > hi:
        return "full_diversity"
    # Middle band: minimum at one of the two ends (Thm 7); Cor 3 tie-break.
    # NOTE the paper prints the threshold with ambiguous parenthesization and
    # its Fig.-8 commentary swaps the directions; deriving from the Thm 7
    # proof (CoV(B=1)=1/(N d mu) vs CoV(B=N)) gives
    #     dm* = H_{N,1} / (N sqrt(H_{N,2}) - 1)
    # with full *parallelism* below dm* and full *diversity* above -- this
    # matches exact evaluation of Lemma 5 (see tests + EXPERIMENTS.md note).
    thr = h_n1 / (n * math.sqrt(h_n2) - 1.0)
    return "full_parallelism" if dm < thr else "full_diversity"


# --------------------------------------------------------------------------
# Pareto tasks  (§VI-C)
# --------------------------------------------------------------------------


def _lgamma_ratio(a: float, b: float) -> float:
    """log( Gamma(a) / Gamma(b) )."""
    return math.lgamma(a) - math.lgamma(b)


def pareto_mean_T(n: int, b: int, sigma: float, alpha: float) -> float:
    """E[T] = (N sigma / B) * Gamma(B+1) Gamma(1 - B/(N alpha)) / Gamma(B+1 - B/(N alpha)).

    (Thm 8, Eq. 22/61.)  Finite iff B/(N alpha) < 1, i.e. the max order
    statistic of Pareto(N sigma/B, N alpha/B) has a mean.
    """
    x = b / (n * alpha)
    if x >= 1.0:
        return math.inf
    lg = _lgamma_ratio(b + 1.0, b + 1.0 - x) + math.lgamma(1.0 - x)
    return (n * sigma / b) * math.exp(lg)


def pareto_var_T(n: int, b: int, sigma: float, alpha: float) -> float:
    """Var[T] from Eq. (76)."""
    x = b / (n * alpha)
    if 2.0 * x >= 1.0:
        return math.inf
    s = n * sigma / b
    e2 = s**2 * math.exp(_lgamma_ratio(b + 1.0, b + 1.0 - 2.0 * x) + math.lgamma(1.0 - 2.0 * x))
    m = pareto_mean_T(n, b, sigma, alpha)
    return e2 - m**2


def pareto_cov_T(n: int, b: int, alpha: float) -> float:
    """CoV[T] for Pareto tasks -- scale-free (sigma drops out).

    NOTE: the paper's printed Lemma 6 (Eq. 24) drops a Gamma(B+1) factor and a
    power of Gamma(1-x): at B=1 it disagrees with the CoV of a plain Pareto
    maximum (and with Monte-Carlo).  Re-deriving from the paper's own Eq. (75)
    gives, with x = B/(N alpha):

        CoV^2 = Gamma(1-2x) Gamma(B+1-x)^2
                / ( Gamma(B+1) Gamma(B+1-2x) Gamma(1-x)^2 )  -  1

    which reduces to Var/E^2 of Pareto(N sigma/B, N alpha/B) at B=1 and
    matches MC for all B (see tests).  Thm 10's conclusion (CoV minimized at
    full diversity) still holds for the corrected form.
    """
    x = b / (n * alpha)
    if 2.0 * x >= 1.0:
        return math.inf
    log_q = (
        math.lgamma(1.0 - 2.0 * x)
        + 2.0 * math.lgamma(b + 1.0 - x)
        - math.lgamma(b + 1.0)
        - math.lgamma(b + 1.0 - 2.0 * x)
        - 2.0 * math.lgamma(1.0 - x)
    )
    ratio = math.exp(log_q)
    # numerical guard: ratio >= 1 mathematically
    return math.sqrt(max(ratio - 1.0, 0.0))


def pareto_alpha_star(n: int) -> float:
    """alpha*: the root of Eq. (23); full parallelism is E[T]-optimal iff alpha >= alpha*.

        (4a^2 + (a-1)^2)/(2a(a-1)) - sqrt(pi) N^{-1/2a} 2^{1+1/2a} - 0.58 = 0
    """

    def f(a: float) -> float:
        lhs = (4.0 * a**2 + (a - 1.0) ** 2) / (2.0 * a * (a - 1.0))
        rhs = math.sqrt(math.pi) * n ** (-1.0 / (2.0 * a)) * 2.0 ** (1.0 + 1.0 / (2.0 * a))
        return lhs - rhs - 0.58

    # f is decreasing-then... : paper shows LHS increasing, RHS decreasing in
    # alpha for alpha > 1, so f has a single sign change; bisect on (1+eps, 64).
    lo, hi = 1.0 + 1e-6, 64.0
    flo, fhi = f(lo), f(hi)
    if flo > 0.0 and fhi > 0.0:
        return lo  # always-parallel regime
    if flo < 0.0 and fhi < 0.0:
        return hi
    # f(lo) may be huge positive (pole at a=1): the equation's relevant root has
    # f < 0 below alpha* and f > 0 above it in the paper's convention -- detect
    # orientation from which end is negative.
    for _ in range(200):
        mid = 0.5 * (lo + hi)
        if (f(mid) > 0.0) == (fhi > 0.0):
            hi = mid
        else:
            lo = mid
    return 0.5 * (lo + hi)


# --------------------------------------------------------------------------
# generic dispatch + argmin over feasible B
# --------------------------------------------------------------------------


def mean_T(dist: ServiceTime, n: int, b: int) -> float:
    """Closed-form E[T] for balanced non-overlapping batches, size model (§VI)."""
    if isinstance(dist, Exponential):
        return exp_mean_T(n, b, dist.mu)
    if isinstance(dist, ShiftedExponential):
        return sexp_mean_T(n, b, dist.delta, dist.mu)
    if isinstance(dist, Pareto):
        return pareto_mean_T(n, b, dist.sigma, dist.alpha)
    raise TypeError(f"no closed form for {type(dist).__name__}")


def cov_T(dist: ServiceTime, n: int, b: int) -> float:
    """Closed-form CoV of job time T(n, b) for the parametric families."""
    if isinstance(dist, Exponential):
        return exp_cov_T(b)
    if isinstance(dist, ShiftedExponential):
        return sexp_cov_T(n, b, dist.delta, dist.mu)
    if isinstance(dist, Pareto):
        return pareto_cov_T(n, b, dist.alpha)
    raise TypeError(f"no closed form for {type(dist).__name__}")


def argmin_B(
    dist: ServiceTime, n: int, metric: str = "mean", candidates: Iterable[int] | None = None
) -> int:
    """Discrete argmin over feasible B of E[T] or CoV[T] (Thms 5/8 optimizations)."""
    cands = list(candidates) if candidates is not None else feasible_B(n)
    fn = mean_T if metric == "mean" else cov_T
    vals = [fn(dist, n, b) for b in cands]
    return int(cands[int(np.argmin(vals))])


# --------------------------------------------------------------------------
# §IV batch-level model (no size scaling): sanity forms used in tests
# --------------------------------------------------------------------------


def batch_model_exp_mean_T(assignment_counts: Iterable[int], mu: float, n_mc: int = 0) -> float:
    """E[max_i Exp(N_i mu)] for a general assignment vector (used to verify
    Lemma 2/3 orderings).  Uses the exact inclusion-exclusion for the max of
    independent (non-identical) exponentials.
    """
    counts = list(assignment_counts)
    rates = [c * mu for c in counts]
    bsz = len(rates)
    # E[max] = sum over non-empty subsets S of (-1)^{|S|+1} / sum_{i in S} rate_i
    total = 0.0
    for mask in range(1, 1 << bsz):
        rsum = 0.0
        bits = 0
        for i in range(bsz):
            if mask >> i & 1:
                rsum += rates[i]
                bits += 1
        total += (-1.0) ** (bits + 1) / rsum
    return total
