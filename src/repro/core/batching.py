"""Task batching schemes (§III and Fig. 5 of the paper).

A *batching* for (N tasks, batch size s = N/B) is a boolean membership matrix
``M[w, t] = True`` iff worker w's batch contains task t.  The paper's schemes:

  * ``non_overlapping``  -- N tasks chopped into B contiguous batches, each
    replicated on r = N/B workers (scheme 3 in Fig. 5).  Optimal (Thms 1-2).
  * ``cyclic``           -- N overlapping batches, batch w = tasks
    {w, w+1, .., w+s-1} mod N (scheme 1 in Fig. 5; the gradient-coding
    placement of Tandon et al. [41]).
  * ``hybrid``           -- the Fig. 5 scheme 2 middle point: one subset of
    workers gets cyclic-overlapped windows, the rest non-overlapping chops.
  * ``random``           -- each worker draws one of the B non-overlapping
    batches uniformly at random (coupon collector placement of [72]).

All schemes keep the batch size equal (the paper's comparability constraint)
and, except ``random``, give every task equal replication (fairness
assumption of §III-B).
"""
from __future__ import annotations

import numpy as np

__all__ = [
    "non_overlapping",
    "cyclic",
    "hybrid",
    "random_nonoverlapping",
    "membership_from_batches",
    "validate_scheme",
    "replication_counts",
]


def _check(n_tasks: int, n_batches: int) -> int:
    if n_tasks % n_batches:
        raise ValueError(f"B={n_batches} must divide N={n_tasks} (paper §II-C)")
    return n_tasks // n_batches


def membership_from_batches(batches: list, n_tasks: int) -> np.ndarray:
    """Boolean (worker, task) membership matrix from per-worker batch sets."""
    m = np.zeros((len(batches), n_tasks), dtype=bool)
    for w, batch in enumerate(batches):
        m[w, list(batch)] = True
    return m


def non_overlapping(n_tasks: int, n_batches: int, n_workers: int | None = None) -> np.ndarray:
    """Balanced replication of B contiguous batches over N workers.

    Worker w hosts batch (w % B) -- i.e. batches are dealt round-robin, which
    for n_workers = N gives each batch exactly r = N/B hosts (balanced,
    Lemma 3's majorization-minimal vector).
    """
    size = _check(n_tasks, n_batches)
    n_workers = n_tasks if n_workers is None else n_workers
    batches = [range(i * size, (i + 1) * size) for i in range(n_batches)]
    return membership_from_batches([batches[w % n_batches] for w in range(n_workers)], n_tasks)


def cyclic(n_tasks: int, n_batches: int) -> np.ndarray:
    """Scheme 1: worker w hosts the cyclic window starting at task w."""
    size = _check(n_tasks, n_batches)
    batches = [[(w + j) % n_tasks for j in range(size)] for w in range(n_tasks)]
    return membership_from_batches(batches, n_tasks)


def hybrid(n_tasks: int, n_batches: int) -> np.ndarray:
    """Scheme 2 of Fig. 5, generalized.

    The N workers are split into r = N/B subsets, each subset covering every
    task exactly once.  The first r-1 subsets use shifted cyclic-style chops
    (offset by one task per subset, wrapping), the last subset uses the plain
    non-overlapping chop.  For (N=6, B=3) this reproduces the paper's scheme 2
    batch multiset {12, 23, 34, 45, 56, 56}-style middle point: batches overlap
    across subsets but fewer pairs share tasks than full cyclic.
    """
    size = _check(n_tasks, n_batches)
    r = n_tasks // n_batches
    batches = []
    for subset in range(r):
        off = subset  # subset 0 = aligned chop; later subsets shifted by 1 task each
        for i in range(n_batches):
            batches.append([(off + i * size + j) % n_tasks for j in range(size)])
    return membership_from_batches(batches, n_tasks)


def random_nonoverlapping(
    n_tasks: int, n_batches: int, rng: np.random.Generator, n_workers: int | None = None
) -> np.ndarray:
    """Coupon-collector placement: each worker draws a batch uniformly."""
    size = _check(n_tasks, n_batches)
    n_workers = n_tasks if n_workers is None else n_workers
    batches = [range(i * size, (i + 1) * size) for i in range(n_batches)]
    draws = rng.integers(0, n_batches, size=n_workers)
    return membership_from_batches([batches[d] for d in draws], n_tasks)


def replication_counts(membership: np.ndarray) -> np.ndarray:
    """How many workers host each task (fairness diagnostics)."""
    return membership.sum(axis=0)


def validate_scheme(membership: np.ndarray, equal_batch_size: bool = True) -> dict:
    """Runtime invariants (the coverage guard of DESIGN §3.3).

    Returns diagnostics; raises if a task is uncovered (Lemma 1's failure mode).
    """
    per_task = replication_counts(membership)
    if (per_task == 0).any():
        missing = np.flatnonzero(per_task == 0).tolist()
        raise ValueError(f"uncovered tasks {missing}: job result would be incorrect")
    sizes = membership.sum(axis=1)
    if equal_batch_size and len(set(sizes.tolist())) != 1:
        raise ValueError(f"unequal batch sizes {sorted(set(sizes.tolist()))}")
    return {
        "n_workers": int(membership.shape[0]),
        "n_tasks": int(membership.shape[1]),
        "batch_size": int(sizes[0]),
        "min_replication": int(per_task.min()),
        "max_replication": int(per_task.max()),
        "balanced": bool(per_task.min() == per_task.max()),
    }
