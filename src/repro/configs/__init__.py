"""Architecture registry: ``--arch <id>`` lookup for launchers and tests."""
from __future__ import annotations

from typing import Dict, List

from . import (
    dbrx_132b,
    gemma_7b,
    hubert_xlarge,
    mamba2_2_7b,
    qwen2_1_5b,
    qwen2_vl_7b,
    qwen3_moe_235b,
    recurrentgemma_2b,
    starcoder2_3b,
    yi_9b,
)
from .base import SHAPES, ArchConfig, ShapeConfig

_MODULES = {
    m.ARCH_ID: m
    for m in (
        qwen2_1_5b,
        yi_9b,
        gemma_7b,
        starcoder2_3b,
        hubert_xlarge,
        recurrentgemma_2b,
        qwen2_vl_7b,
        dbrx_132b,
        qwen3_moe_235b,
        mamba2_2_7b,
    )
}

ARCH_IDS: List[str] = list(_MODULES)

# Which shape cells are applicable per arch (DESIGN.md §5 skip notes):
#   - encoder-only: no autoregressive decode
#   - pure full-attention decoders: no long_500k (quadratic regime)
_FULL_ATTENTION = {
    "qwen2-1.5b", "yi-9b", "gemma-7b", "starcoder2-3b", "qwen2-vl-7b",
    "dbrx-132b", "qwen3-moe-235b-a22b",
}


def get_config(arch: str, smoke: bool = False, **overrides) -> ArchConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; available: {ARCH_IDS}")
    m = _MODULES[arch]
    return m.smoke_config(**overrides) if smoke else m.full_config(**overrides)


def applicable_shapes(arch: str) -> Dict[str, ShapeConfig]:
    cfg = get_config(arch)
    out = {}
    for name, shape in SHAPES.items():
        if cfg.family == "encoder" and shape.kind == "decode":
            continue  # no autoregressive step
        if name == "long_500k" and arch in _FULL_ATTENTION:
            continue  # needs sub-quadratic attention
        out[name] = shape
    return out


def skipped_shapes(arch: str) -> Dict[str, str]:
    """Cells recorded as N/A-by-design with the reason (EXPERIMENTS §Dry-run)."""
    cfg = get_config(arch)
    out = {}
    for name, shape in SHAPES.items():
        if cfg.family == "encoder" and shape.kind == "decode":
            out[name] = "encoder-only arch: no autoregressive decode step"
        elif name == "long_500k" and arch in _FULL_ATTENTION:
            out[name] = "pure full-attention arch: 512k dense KV decode is the quadratic regime"
    return out


__all__ = [
    "ARCH_IDS",
    "SHAPES",
    "ArchConfig",
    "ShapeConfig",
    "applicable_shapes",
    "get_config",
    "skipped_shapes",
]
