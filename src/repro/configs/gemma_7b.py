"""gemma-7b [dense]: 28L d=3072 16H (kv=16, MHA) d_ff=24576 vocab=256000.

GeGLU, head_dim=256, (1+w) RMSNorm, sqrt(d) embedding scale, tied embeddings.
[arXiv:2403.08295; hf]
"""
from .base import ArchConfig

ARCH_ID = "gemma-7b"


def full_config(**overrides) -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID,
        family="dense",
        n_layers=28,
        d_model=3072,
        n_heads=16,
        n_kv_heads=16,
        d_ff=24576,
        vocab_size=256000,
        head_dim=256,
        act="gelu",
        norm_plus_one=True,
        embed_scale=True,
        tie_embeddings=True,
        **overrides,
    )


def smoke_config(**overrides) -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID + "-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=256,
        vocab_size=512,
        head_dim=32,
        act="gelu",
        norm_plus_one=True,
        embed_scale=True,
        tie_embeddings=True,
        **overrides,
    )
