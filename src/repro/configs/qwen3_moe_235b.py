"""qwen3-moe-235b-a22b [moe]: 94L d=4096 64H (GQA kv=4) per-expert d_ff=1536
vocab=151936, 128 experts top-8, head_dim=128.  [hf:Qwen/Qwen3-30B-A3B; hf]
"""
from .base import ArchConfig

ARCH_ID = "qwen3-moe-235b-a22b"


def full_config(**overrides) -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID,
        family="moe",
        n_layers=94,
        d_model=4096,
        n_heads=64,
        n_kv_heads=4,
        d_ff=1536,
        vocab_size=151936,
        head_dim=128,
        n_experts=128,
        n_experts_per_tok=8,
        rope_theta=1_000_000.0,
        **overrides,
    )


def smoke_config(**overrides) -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID + "-smoke",
        family="moe",
        n_layers=2,
        d_model=64,
        n_heads=8,
        n_kv_heads=2,
        d_ff=32,
        vocab_size=512,
        head_dim=16,
        n_experts=8,
        n_experts_per_tok=2,
        rope_theta=1_000_000.0,
        **overrides,
    )
