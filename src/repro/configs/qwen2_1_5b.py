"""qwen2-1.5b [dense]: 28L d=1536 12H (GQA kv=2) d_ff=8960 vocab=151936.

GQA with QKV bias, tied embeddings.  [arXiv:2407.10671; hf]
"""
from .base import ArchConfig

ARCH_ID = "qwen2-1.5b"


def full_config(**overrides) -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID,
        family="dense",
        n_layers=28,
        d_model=1536,
        n_heads=12,
        n_kv_heads=2,
        d_ff=8960,
        vocab_size=151936,
        qkv_bias=True,
        tie_embeddings=True,
        rope_theta=1_000_000.0,
        **overrides,
    )


def smoke_config(**overrides) -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID + "-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab_size=512,
        qkv_bias=True,
        tie_embeddings=True,
        rope_theta=1_000_000.0,
        **overrides,
    )
