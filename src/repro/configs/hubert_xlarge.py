"""hubert-xlarge [audio]: 48L d=1280 16H d_ff=5120 vocab=504, encoder-only.

Same backbone arch as wav2vec2; the convolutional waveform frontend is a STUB
per the assignment (input_specs provides precomputed frame embeddings).
Training objective: masked-frame cluster prediction (CE over 504 units).
[arXiv:2106.07447; unverified]
"""
from .base import ArchConfig

ARCH_ID = "hubert-xlarge"


def full_config(**overrides) -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID,
        family="encoder",
        n_layers=48,
        d_model=1280,
        n_heads=16,
        n_kv_heads=16,
        d_ff=5120,
        vocab_size=504,
        is_causal=False,
        norm_type="layer",
        gated_mlp=False,
        act="gelu",
        mlp_bias=True,
        qkv_bias=True,
        **overrides,
    )


def smoke_config(**overrides) -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID + "-smoke",
        family="encoder",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab_size=64,
        is_causal=False,
        norm_type="layer",
        gated_mlp=False,
        act="gelu",
        mlp_bias=True,
        qkv_bias=True,
        **overrides,
    )
