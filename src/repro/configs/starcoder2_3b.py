"""starcoder2-3b [dense]: 30L d=3072 24H (GQA kv=2) d_ff=12288 vocab=49152.

GQA + RoPE, LayerNorm, plain GeLU MLP with biases, QKV bias.
[arXiv:2402.19173; hf]
"""
from .base import ArchConfig

ARCH_ID = "starcoder2-3b"


def full_config(**overrides) -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID,
        family="dense",
        n_layers=30,
        d_model=3072,
        n_heads=24,
        n_kv_heads=2,
        d_ff=12288,
        vocab_size=49152,
        norm_type="layer",
        gated_mlp=False,
        act="gelu",
        mlp_bias=True,
        qkv_bias=True,
        rope_theta=1_000_000.0,
        **overrides,
    )


def smoke_config(**overrides) -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID + "-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=256,
        vocab_size=512,
        norm_type="layer",
        gated_mlp=False,
        act="gelu",
        mlp_bias=True,
        qkv_bias=True,
        rope_theta=1_000_000.0,
        **overrides,
    )
