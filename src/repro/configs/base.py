"""Architecture + run configuration.

One frozen dataclass covers all 10 assigned families; per-arch modules under
``repro.configs`` provide ``full_config()`` (the exact published numbers) and
``smoke_config()`` (same family, tiny dims, CPU-runnable).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encoder | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # default d_model // n_heads (gemma overrides: 256)

    # attention details
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    window: Optional[int] = None  # sliding-window width (recurrentgemma local attn)
    attn_logit_softcap: Optional[float] = None

    # block details
    norm_type: str = "rms"  # rms | layer
    norm_plus_one: bool = False  # gemma (1+w) convention
    act: str = "silu"  # silu | gelu (gated) -- or plain mlp when gated_mlp=False
    gated_mlp: bool = True
    mlp_bias: bool = False
    tie_embeddings: bool = False
    embed_scale: bool = False  # gemma-style sqrt(d_model) embedding scaling

    # MoE
    n_experts: int = 0
    n_experts_per_tok: int = 0
    capacity_factor: float = 1.25
    router_aux_loss: float = 0.01

    # SSM (mamba2)
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 128

    # hybrid (recurrentgemma): block pattern, e.g. ("rglru","rglru","attn")
    block_pattern: Tuple[str, ...] = ()
    rglru_c: float = 8.0  # RG-LRU gate exponent constant

    # VLM
    mrope_sections: Tuple[int, ...] = ()  # (t,h,w) freq slots, sum = head_dim//2

    # encoder
    is_causal: bool = True  # False for encoder-only (hubert)

    # numerics / layout
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: bool = True
    # 'full' = recompute everything (cheapest memory, but the backward RERUNS
    # the TP psums); 'block_outs' = save the attn/ffn psum outputs so the
    # recompute pass skips the collectives (EXPERIMENTS §Perf cell A)
    remat_policy: str = "full"
    scan_layers: bool = True
    attn_block_k: int = 1024
    # sharding-time padding (applied by the launcher for TP meshes; 0 = off)
    pad_heads_to: int = 0
    pad_vocab_to_multiple: int = 0
    # causal-attention blockwise skip (hillclimb lever; see EXPERIMENTS §Perf)
    causal_block_skip: bool = False
    # ---- beyond-paper perf levers (EXPERIMENTS.md §Perf) ----
    # Megatron-style sequence parallelism: residual stream seq-shards over
    # the TP axis (cuts saved-activation memory TP-fold -> fewer microbatches)
    sequence_parallel: bool = False
    # decode KV cache lives in the layer-scan carry (in-place ring-buffer
    # updates alias; avoids the xs/ys double-buffer)
    cache_in_carry: bool = False
    # decode KV cache stores TRUE kv heads sharded over the TP axis by
    # SEQUENCE (shard_map partial-softmax combine) instead of repeated heads:
    # -R x footprint and read traffic for kv < TP archs (full-attention only)
    decode_kv_seq_sharded: bool = False

    # paper-technique integration defaults (replication plan for the data axis)
    replication: int = 1  # r: replicas per data shard (B = dp_size / r)

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // max(self.n_heads, 1))
        if self.n_heads and self.n_heads % max(self.n_kv_heads, 1):
            raise ValueError("n_heads must be a multiple of n_kv_heads")

    # -- derived ------------------------------------------------------------

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def padded_heads(self) -> int:
        if self.pad_heads_to and self.n_heads % self.pad_heads_to:
            return ((self.n_heads + self.pad_heads_to - 1) // self.pad_heads_to) * self.pad_heads_to
        return self.n_heads

    @property
    def padded_kv_heads(self) -> int:
        """KV heads after TP-repetition (kv < axis -> repeat to axis)."""
        if self.pad_heads_to and self.n_kv_heads < self.pad_heads_to:
            return self.pad_heads_to
        if self.pad_heads_to and self.n_kv_heads % self.pad_heads_to:
            return (
                (self.n_kv_heads + self.pad_heads_to - 1) // self.pad_heads_to
            ) * self.pad_heads_to
        return self.n_kv_heads

    @property
    def padded_vocab(self) -> int:
        m = self.pad_vocab_to_multiple
        if m and self.vocab_size % m:
            return ((self.vocab_size + m - 1) // m) * m
        return self.vocab_size

    def dtype(self, which: str):
        return jnp.dtype({"param": self.param_dtype, "compute": self.compute_dtype}[which])

    # -- model-FLOPs accounting for the roofline (6ND rule) ------------------

    def param_count_estimate(self) -> int:
        """Analytic total parameter count (pre-padding)."""
        d, v, L = self.d_model, self.vocab_size, self.n_layers
        hd = self.head_dim
        if self.family == "ssm":
            d_in = self.ssm_expand * d
            nh = d_in // self.ssm_headdim
            conv_dim = d_in + 2 * self.ssm_state
            per = (
                d * (2 * d_in + 2 * self.ssm_state + nh)  # in_proj
                + conv_dim * self.ssm_conv
                + 2 * nh  # A, D
                + d_in  # norm
                + d_in * d
            )
            return v * d + L * per + d
        att = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) + (self.n_heads * hd) * d
        if self.gated_mlp:
            ffn = 3 * d * self.d_ff
        else:
            ffn = 2 * d * self.d_ff
        if self.is_moe:
            ffn = self.n_experts * ffn + d * self.n_experts
        per = att + ffn + 2 * d
        rglru = 0
        if self.family == "hybrid":
            # replace attention with RG-LRU recurrent block on pattern layers
            pass  # estimate handled roughly; exact count comes from init
        total = v * d + L * per + d
        if not self.tie_embeddings:
            total += d * v
        return total + rglru

    def active_param_count_estimate(self) -> int:
        """Active params per token (MoE: only routed experts count)."""
        if not self.is_moe:
            return self.param_count_estimate()
        d, L = self.d_model, self.n_layers
        hd = self.head_dim
        att = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) + (self.n_heads * hd) * d
        ffn_one = 3 * d * self.d_ff
        per = att + self.n_experts_per_tok * ffn_one + d * self.n_experts + 2 * d
        total = self.vocab_size * d + L * per + d
        if not self.tie_embeddings:
            total += d * self.vocab_size
        return total


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str  # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}
