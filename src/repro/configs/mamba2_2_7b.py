"""mamba2-2.7b [ssm]: 64L d=2560, attention-free, vocab=50280, ssm_state=128.

SSD (state-space duality), d_inner = 2*d, headdim=64 (80 heads), conv k=4.
[arXiv:2405.21060; unverified]
"""
from .base import ArchConfig

ARCH_ID = "mamba2-2.7b"


def full_config(**overrides) -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID,
        family="ssm",
        n_layers=64,
        d_model=2560,
        n_heads=0,
        n_kv_heads=0,
        d_ff=0,
        vocab_size=50280,
        head_dim=1,  # unused (attention-free)
        ssm_state=128,
        ssm_headdim=64,
        ssm_expand=2,
        ssm_conv=4,
        ssm_chunk=128,
        **overrides,
    )


def smoke_config(**overrides) -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID + "-smoke",
        family="ssm",
        n_layers=2,
        d_model=64,
        n_heads=0,
        n_kv_heads=0,
        d_ff=0,
        vocab_size=512,
        head_dim=1,
        ssm_state=16,
        ssm_headdim=16,
        ssm_expand=2,
        ssm_conv=4,
        ssm_chunk=8,
        **overrides,
    )
