"""recurrentgemma-2b [hybrid]: 26L d=2560 10H (MQA kv=1) d_ff=7680 vocab=256000.

RG-LRU + local attention, pattern (R,R,A); window 2048; gemma conventions.
[arXiv:2402.19427; hf]
"""
from .base import ArchConfig

ARCH_ID = "recurrentgemma-2b"


def full_config(**overrides) -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID,
        family="hybrid",
        n_layers=26,
        d_model=2560,
        n_heads=10,
        n_kv_heads=1,
        d_ff=7680,
        vocab_size=256000,
        head_dim=256,
        window=2048,
        block_pattern=("rglru", "rglru", "attn"),
        act="gelu",
        norm_plus_one=True,
        embed_scale=True,
        tie_embeddings=True,
        **overrides,
    )


def smoke_config(**overrides) -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID + "-smoke",
        family="hybrid",
        n_layers=5,  # 1 full (R,R,A) group + (R,R) tail
        d_model=64,
        n_heads=4,
        n_kv_heads=1,
        d_ff=128,
        vocab_size=512,
        head_dim=16,
        window=8,
        block_pattern=("rglru", "rglru", "attn"),
        act="gelu",
        norm_plus_one=True,
        embed_scale=True,
        tie_embeddings=True,
        **overrides,
    )
