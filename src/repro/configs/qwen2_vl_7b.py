"""qwen2-vl-7b [vlm]: 28L d=3584 28H (GQA kv=4) d_ff=18944 vocab=152064.

M-RoPE (temporal/height/width sections 16/24/24 of hd/2=64), dynamic
resolution -- the vision tower is a STUB per the assignment (input_specs
provides precomputed patch embeddings + 3-D position ids).
[arXiv:2409.12191; hf]
"""
from .base import ArchConfig

ARCH_ID = "qwen2-vl-7b"


def full_config(**overrides) -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID,
        family="vlm",
        n_layers=28,
        d_model=3584,
        n_heads=28,
        n_kv_heads=4,
        d_ff=18944,
        vocab_size=152064,
        qkv_bias=True,
        rope_theta=1_000_000.0,
        mrope_sections=(16, 24, 24),
        **overrides,
    )


def smoke_config(**overrides) -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID + "-smoke",
        family="vlm",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab_size=512,
        qkv_bias=True,
        rope_theta=1_000_000.0,
        mrope_sections=(4, 2, 2),  # head_dim 16 -> hd/2 = 8
        **overrides,
    )
