"""yi-9b [dense]: 48L d=4096 32H (GQA kv=4) d_ff=11008 vocab=64000.

llama-architecture GQA.  [arXiv:2403.04652; hf]
"""
from .base import ArchConfig

ARCH_ID = "yi-9b"


def full_config(**overrides) -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID,
        family="dense",
        n_layers=48,
        d_model=4096,
        n_heads=32,
        n_kv_heads=4,
        d_ff=11008,
        vocab_size=64000,
        rope_theta=5_000_000.0,
        **overrides,
    )


def smoke_config(**overrides) -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID + "-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=8,
        n_kv_heads=2,
        d_ff=160,
        vocab_size=512,
        rope_theta=5_000_000.0,
        **overrides,
    )
