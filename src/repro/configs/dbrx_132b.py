"""dbrx-132b [moe]: 40L d=6144 48H (GQA kv=8) d_ff=10752 vocab=100352.

16 experts, top-4, fine-grained; LayerNorm.  [hf:databricks/dbrx-base; unverified]
"""
from .base import ArchConfig

ARCH_ID = "dbrx-132b"


def full_config(**overrides) -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID,
        family="moe",
        n_layers=40,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        d_ff=10752,
        vocab_size=100352,
        n_experts=16,
        n_experts_per_tok=4,
        norm_type="layer",
        rope_theta=500_000.0,
        **overrides,
    )


def smoke_config(**overrides) -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID + "-smoke",
        family="moe",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=96,
        vocab_size=512,
        n_experts=4,
        n_experts_per_tok=2,
        norm_type="layer",
        rope_theta=500_000.0,
        **overrides,
    )
