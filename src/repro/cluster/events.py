"""Discrete-event core: event heap, simulation clock, named RNG streams.

The engine is a classic event-driven simulator: every state change (a job
arriving, a batch replica finishing, a worker failing or rejoining) is an
event on a single time-ordered heap.  Determinism is load-bearing -- the
planner scores candidate plans by running the engine, and tests replay runs
bit-for-bit -- so ties are broken by insertion order and all randomness flows
through :class:`RngStreams`, which derives independent, named, reproducible
numpy generators from one root seed.
"""
from __future__ import annotations

import heapq
import itertools
import zlib

import numpy as np

__all__ = [
    "JOB_ARRIVAL",
    "BATCH_DONE",
    "WORKER_FAIL",
    "WORKER_JOIN",
    "SPEC_CHECK",
    "TASK_FAIL",
    "RETRY",
    "EventQueue",
    "SimClock",
    "RngStreams",
]

# event kinds
JOB_ARRIVAL = "job_arrival"
BATCH_DONE = "batch_done"
WORKER_FAIL = "worker_fail"
WORKER_JOIN = "worker_join"
SPEC_CHECK = "spec_check"  # speculative-backup heartbeat check (reactive replication)
TASK_FAIL = "task_fail"  # a replica's payload raised (vs WORKER_FAIL: the worker died)
RETRY = "retry"  # a failed replica's backoff expired; re-queue it through rescue


class EventQueue:
    """Min-heap of (time, seq, kind, payload); seq makes ordering total."""

    def __init__(self):
        self._heap: list = []
        self._seq = itertools.count()

    def push(self, time: float, kind: str, **payload) -> None:
        """Schedule an event; FIFO-stable among equal timestamps."""
        heapq.heappush(self._heap, (float(time), next(self._seq), kind, payload))

    def pop(self) -> tuple:
        """Remove and return the earliest ``(time, kind, payload)``."""
        time, _, kind, payload = heapq.heappop(self._heap)
        return time, kind, payload

    def peek_time(self) -> float:
        """Timestamp of the earliest pending event."""
        return self._heap[0][0]

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)


class SimClock:
    """Monotone simulation clock (guards against out-of-order processing)."""

    def __init__(self):
        self.now = 0.0

    def advance(self, t: float) -> None:
        """Move simulated time forward to ``t`` (never backwards)."""
        if t < self.now - 1e-9:
            raise RuntimeError(f"clock moved backwards: {self.now} -> {t}")
        self.now = max(self.now, float(t))


class RngStreams:
    """Named independent generators derived from a single root seed.

    Each name maps to its own ``np.random.Generator`` (via a SeedSequence
    spawn key hashed from the name), so e.g. service-time draws are not
    perturbed by whether churn is enabled -- a property the cancellation
    on/off comparison tests rely on.
    """

    def __init__(self, seed: int):
        self.seed = int(seed)
        self._streams: dict = {}

    def get(self, name: str) -> np.random.Generator:
        """The named substream, created on first use (order-independent)."""
        if name not in self._streams:
            key = zlib.crc32(name.encode("utf-8"))
            ss = np.random.SeedSequence(entropy=self.seed, spawn_key=(key,))
            self._streams[name] = np.random.default_rng(ss)
        return self._streams[name]
