"""jax epoch-scan engine: churn, heterogeneous speeds, rescue, and replanning.

This module closes the vectorization gap left by :mod:`repro.cluster.vectorized`
(which covers the static case): it replays the *dynamic* semantics of the
event-driven :class:`~repro.cluster.master.ClusterEngine` -- worker fail/join
churn, replica rescue, per-worker speed factors, FIFO multi-job dispatch, and
windowed online replanning -- as a ``lax.scan`` over **churn epochs**, batched
over Monte-Carlo reps (and, for planning, over a whole candidate frontier).

The structural insight making this vectorizable: between two churn events the
alive set is constant, so no replica can die and no rescue can be requested --
every job that starts and ends inside an epoch is a pure masked
``max_b min_r`` cover computation (the shared
:func:`~repro.core.simulator.gang_cover_times` semantics), and the only
sequential state is the one job straddling the boundary.  The scan therefore
carries the in-flight job's padded ``(B_pad, r_pad)`` slot grid (slot ->
worker id, start, scheduled end) across epochs; each step

  1. applies one fail/join event (killing the dead worker's replica and
     queueing a rescue when a batch loses its last live replica),
  2. dispatches pending rescues onto the earliest-freeing alive workers
     (a bounded ``fori_loop`` -- at most one rescue per batch per epoch),
  3. runs a ``while_loop`` that alternately *commits* completions up to the
     epoch's end (batch wins, sibling cancellation accounting, job finishes)
     and *dispatches* queued jobs once every alive worker is free.

Replanning mirrors :class:`~repro.cluster.control.OnlineReplanner` in jax: a
ring buffer of censoring-tagged task-time observations, maximum-likelihood
refits of the Exp/SExp/Pareto families picked by log-likelihood, the
min-of-r censoring inversion, and a closed-form frontier argmin over the
divisors of the alive-worker count (harmonic/``gammaln`` tables).

Accounting matches the engine's identities: with a shared seed,
``worker_seconds(cancel on) + cancelled_seconds_saved == worker_seconds(cancel
off)`` holds per rep in churn-free runs, and the report exposes the same
counter fields (:meth:`EpochReport.accounting`) as
:class:`~repro.cluster.master.EngineReport` for the differential tests.

Precision note: the scan runs in float32 on absolute simulation time, so keep
timescales moderate (the engine runs float64); tests compare with ~1e-4
relative tolerances where the engine asserts 1e-9.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.scipy.special import gammaln

from ..core.analysis import divisor_table, harmonic_tables
from ..core.service_time import ServiceTime
from .workers import ChurnProcess, ChurnSchedule

__all__ = [
    "ReplanConfig",
    "EpochReport",
    "simulate_epochs",
    "frontier_job_times_dynamic",
]


@dataclasses.dataclass(frozen=True)
class ReplanConfig:
    """Static mirror of :class:`~repro.cluster.control.OnlineReplanner` knobs.

    Hashable (it keys the jit cache); ``to_controller`` builds the equivalent
    Python-engine controller so differential tests drive both backends from
    one config.
    """

    window: int = 512
    refit_every: int = 128
    min_observations: int = 64
    objective: str = "mean"
    blend: float = 0.5

    def to_controller(self, n_workers: int):
        from .control import OnlineReplanner

        return OnlineReplanner(
            n_workers,
            objective=self.objective,
            window=self.window,
            refit_every=self.refit_every,
            min_observations=self.min_observations,
            blend=self.blend,
        )


@dataclasses.dataclass(frozen=True)
class EpochReport:
    """Batched outcome of :func:`simulate_epochs` (axis 0 = Monte-Carlo rep).

    Mirrors :class:`~repro.cluster.master.EngineReport` field-for-field where
    the semantics overlap; ``inf`` marks jobs never dispatched / completed
    (dead cluster), exactly like the engine's unfinished records.
    ``epoch_times`` are the applied churn-event times per rep (inf-padded),
    the same epoch boundaries ``EngineReport.epoch_times`` records.
    """

    arrivals: np.ndarray  # (n_jobs,)
    starts: np.ndarray  # (n_reps, n_jobs)
    finishes: np.ndarray  # (n_reps, n_jobs)
    n_batches_used: np.ndarray  # (n_reps, n_jobs)
    replication_used: np.ndarray  # (n_reps, n_jobs)
    worker_seconds: np.ndarray  # (n_reps,)
    cancelled_seconds_saved: np.ndarray  # (n_reps,)
    n_worker_failures: np.ndarray  # (n_reps,)
    n_replicas_rescued: np.ndarray  # (n_reps,)
    n_replans: np.ndarray  # (n_reps,)
    epoch_times: np.ndarray  # (n_reps, n_events) applied boundaries, inf pad

    @property
    def compute_times(self) -> np.ndarray:
        return self.finishes - self.starts

    @property
    def response_times(self) -> np.ndarray:
        return self.finishes - self.arrivals[None, :]

    @property
    def queue_waits(self) -> np.ndarray:
        return self.starts - self.arrivals[None, :]

    @property
    def final_n_batches(self) -> np.ndarray:
        return self.n_batches_used[:, -1]

    def accounting(self) -> dict:
        """Per-rep counters, keyed identically to ``EngineReport.accounting``."""
        return {
            "worker_seconds": self.worker_seconds,
            "cancelled_seconds_saved": self.cancelled_seconds_saved,
            "n_worker_failures": self.n_worker_failures,
            "n_replicas_rescued": self.n_replicas_rescued,
            "n_replans": self.n_replans,
        }


# --------------------------------------------------------------------------
# the per-lane scan (one Monte-Carlo rep of one candidate), vmapped + jitted
# --------------------------------------------------------------------------

_RUNNERS: dict = {}


def _get_runner(n: int, cancel: bool, size_dep: bool, replan: Optional[ReplanConfig]):
    key = (n, cancel, size_dep, replan)
    if key in _RUNNERS:
        return _RUNNERS[key]

    bidx = jnp.arange(n)
    W = replan.window if replan is not None else 0

    def _obs_push(st, vals, comps, times, valid):
        # ring-buffer push in completion-time order: valid entries take ranks
        # 0..nv-1 under a stable sort of their times, landing at head+rank
        valid = valid & (vals > 0.0) & jnp.isfinite(vals)
        nv = valid.sum()
        rank = jnp.argsort(jnp.argsort(jnp.where(valid, times, jnp.inf)))
        pos = jnp.where(valid, (st["obs_head"] + rank) % W, W)
        st2 = {**st}
        st2["obs_val"] = jnp.append(st["obs_val"], 0.0).at[pos].set(vals)[:W]
        st2["obs_comp"] = jnp.append(st["obs_comp"], 0.0).at[pos].set(comps)[:W]
        st2["obs_head"] = (st["obs_head"] + nv) % W
        st2["obs_count"] = jnp.minimum(st["obs_count"] + nv, W)
        st2["since_refit"] = st["since_refit"] + nv
        return st2

    def _replan_pick(st, div_tab, h1, h2, blend):
        # MLE refit of Exp/SExp/Pareto on the window (mirrors
        # core.planner.fit_service_time), min-of-c censoring inversion
        # (control._inverse_min), closed-form frontier argmin over the
        # divisors of the current alive count (core.analysis forms).
        m = jnp.arange(W) < st["obs_count"]
        nobs = jnp.maximum(st["obs_count"], 1).astype(jnp.float32)
        x = st["obs_val"]
        sx = jnp.where(m, x, 0.0).sum()
        mean = sx / nobs
        xmin = jnp.min(jnp.where(m, x, jnp.inf))
        slogx = jnp.where(m, jnp.log(jnp.maximum(x, 1e-30)), 0.0).sum()
        tiny = 1e-30
        mu_e = 1.0 / jnp.maximum(mean, tiny)
        ll_e = nobs * jnp.log(mu_e) - mu_e * sx
        gap = mean - xmin
        mu_s = 1.0 / jnp.maximum(gap, tiny)
        ll_s = jnp.where(gap > 0, nobs * jnp.log(mu_s) - mu_s * (sx - nobs * xmin), -jnp.inf)
        slogs = slogx - nobs * jnp.log(jnp.maximum(xmin, tiny))
        alpha = nobs / jnp.maximum(slogs, tiny)
        ll_p = jnp.where(
            slogs > 0,
            nobs * jnp.log(alpha) + nobs * alpha * jnp.log(jnp.maximum(xmin, tiny))
            - (alpha + 1.0) * slogx,
            -jnp.inf,
        )
        fam = jnp.argmax(jnp.stack([ll_e, ll_s, ll_p]))
        c = jnp.where(m, st["obs_comp"], 0.0).sum() / nobs
        c = jnp.maximum(c, 1.0)
        mu_e, mu_s, alpha_c = mu_e / c, mu_s / c, alpha / c

        n_alive = st["alive"].sum()
        cands = div_tab[n_alive]  # (D,) zero-padded
        vb = cands > 0
        b = jnp.maximum(cands, 1).astype(jnp.float32)
        H1, H2 = h1[jnp.maximum(cands, 1)], h2[jnp.maximum(cands, 1)]
        na = n_alive.astype(jnp.float32)
        mean_e = H1 / mu_e
        cov_e = jnp.sqrt(H2) / H1
        mean_s = na * xmin / b + H1 / mu_s
        cov_s = jnp.sqrt(H2) / (na * xmin * mu_s / b + H1)
        xp = b / jnp.maximum(na * alpha_c, tiny)
        lgm = jnp.log(jnp.maximum(na * xmin / b, tiny)) + gammaln(b + 1.0)
        lgm = lgm - gammaln(b + 1.0 - xp) + gammaln(1.0 - xp)
        mean_p = jnp.where(xp < 1.0, jnp.exp(lgm), jnp.inf)
        lgq = (
            gammaln(1.0 - 2.0 * xp)
            + 2.0 * gammaln(b + 1.0 - xp)
            - gammaln(b + 1.0)
            - gammaln(b + 1.0 - 2.0 * xp)
            - 2.0 * gammaln(1.0 - xp)
        )
        cov_p = jnp.where(
            2.0 * xp < 1.0, jnp.sqrt(jnp.maximum(jnp.exp(lgq) - 1.0, 0.0)), jnp.inf
        )
        means = jnp.select([fam == 0, fam == 1], [mean_e, mean_s], mean_p)
        covs = jnp.select([fam == 0, fam == 1], [cov_e, cov_s], cov_p)
        means = jnp.where(vb, means, jnp.inf)
        covs = jnp.where(vb, covs, jnp.inf)
        if replan.objective == "mean":
            score = means
        elif replan.objective == "cov":
            score = covs
        elif replan.objective == "blend":
            finite = jnp.isfinite(means) & jnp.isfinite(covs)

            def norm01(v):
                vf = jnp.where(finite, v, jnp.inf)
                lo = jnp.min(vf)
                hi = jnp.max(jnp.where(finite, v, -jnp.inf))
                return jnp.where(finite, (v - lo) / jnp.maximum(hi - lo, 1e-12), 0.0)

            score = jnp.where(
                finite, blend * norm01(means) + (1.0 - blend) * norm01(covs), jnp.inf
            )
        else:  # pragma: no cover - validated at the wrapper
            raise ValueError(f"unknown objective {replan.objective!r}")
        new_b = cands[jnp.argmin(score)]
        return jnp.where(n_alive > 0, jnp.maximum(new_b, 1), st["plan_b"])

    def lane(tau, tau_resc, ev_t, ev_w, ev_up, next_t, arrivals, speeds, b0, n_tasks,
             blend, div_tab, h1, h2):
        n_jobs = tau.shape[0]

        def batch_scale(job_b):
            return n_tasks / job_b.astype(jnp.float32) if size_dep else jnp.float32(1.0)

        def commit(st, t_limit):
            """Commit completions up to t_limit: batch wins, cancellation,
            accounting, job finish, observations, and the replan hook."""
            live = st["slot_live"]
            end = st["slot_end"]
            masked = jnp.where(live, end, jnp.inf)
            win = jnp.min(masked, axis=1)  # (B,)
            newly = (~st["batch_done"]) & (win <= t_limit) & jnp.isfinite(win)
            if cancel:
                nb = newly[:, None] & live
                busy_add = jnp.where(nb, win[:, None] - st["slot_start"], 0.0).sum()
                saved_add = jnp.where(nb, end - win[:, None], 0.0).sum()
                live2 = live & ~nb
                t_new = jnp.max(jnp.where(newly, win, -jnp.inf))
            else:
                done_slots = live & (end <= t_limit)
                busy_add = jnp.where(done_slots, end - st["slot_start"], 0.0).sum()
                saved_add = 0.0
                live2 = live & ~done_slots
                t_new = jnp.max(jnp.where(done_slots, end, -jnp.inf))
            done2 = st["batch_done"] | newly
            done_t2 = jnp.where(newly, win, st["batch_done_t"])
            all_done = jnp.all(done2)
            fin = jnp.max(jnp.where(bidx < st["job_b"], done_t2, -jnp.inf))
            completes = st["job_active"] & all_done
            qa = st["q_active"]

            st2 = {**st}
            st2["slot_live"] = live2
            st2["busy"] = st["busy"] + busy_add
            st2["saved"] = st["saved"] + saved_add
            st2["batch_done"] = done2
            st2["batch_done_t"] = done_t2
            st2["t_cursor"] = jnp.maximum(
                st["t_cursor"], jnp.maximum(t_new, jnp.where(completes, fin, -jnp.inf))
            )
            st2["fins"] = st["fins"].at[qa].set(jnp.where(completes, fin, st["fins"][qa]))
            st2["job_active"] = st["job_active"] & ~all_done
            st2["resc_pending"] = st["resc_pending"] & ~completes

            if replan is not None:
                sc = batch_scale(st["job_b"])
                spd = speeds[jnp.clip(st["slot_w"], 0, n - 1)]
                if cancel:
                    # one observation per newly-won batch: the winner's task
                    # time, censored by however many rivals it raced
                    widx = jnp.argmin(masked, axis=1)  # (B,)
                    dur = win - jnp.take_along_axis(
                        st["slot_start"], widx[:, None], axis=1
                    )[:, 0]
                    spd_w = jnp.take_along_axis(spd, widx[:, None], axis=1)[:, 0]
                    vals = dur * spd_w / sc
                    comps = live.sum(axis=1).astype(jnp.float32)
                    st2 = _obs_push(st2, vals, comps, win, newly)
                else:
                    # every replica that completes while its job is active is
                    # an uncensored observation (the engine drops stragglers
                    # that outlive their job)
                    fin_limit = jnp.where(completes, fin, jnp.inf)
                    ovalid = done_slots & st["job_active"] & (end <= fin_limit)
                    vals = (end - st["slot_start"]) * spd / sc
                    ones = jnp.ones_like(vals)
                    st2 = _obs_push(
                        st2, vals.ravel(), ones.ravel(), end.ravel(), ovalid.ravel()
                    )
                do_replan = (
                    completes
                    & (st2["obs_count"] >= replan.min_observations)
                    & (st2["since_refit"] >= replan.refit_every)
                )
                # _replan_pick runs unconditionally: under vmap a lax.cond on
                # the (batched) do_replan lowers to a select that evaluates
                # both branches anyway, so gating would add bookkeeping
                # without skipping the work
                new_b = _replan_pick(st2, div_tab, h1, h2, blend)
                st2["plan_b"] = jnp.where(do_replan, new_b, st2["plan_b"])
                st2["n_replans"] = st2["n_replans"] + do_replan
                st2["since_refit"] = jnp.where(do_replan, 0, st2["since_refit"])
            return st2

        def boundary(st, ev_t, ev_w, ev_up):
            """Apply one fail/join event (the engine stops replaying churn
            once every job is recorded -- mirror with the sim_over gate)."""
            sim_over = (st["q"] >= n_jobs) & ~st["job_active"]
            act = (ev_w >= 0) & jnp.isfinite(ev_t) & ~sim_over
            w = jnp.clip(ev_w, 0, n - 1)
            was = st["alive"][w]
            do_fail = act & ~ev_up & was
            do_join = act & ev_up & ~was
            st2 = {**st}
            st2["alive"] = st["alive"].at[w].set(
                jnp.where(do_fail, False, jnp.where(do_join, True, was))
            )
            kill = st["slot_live"] & (st["slot_w"] == w) & do_fail
            st2["busy"] = st["busy"] + jnp.where(kill, ev_t - st["slot_start"], 0.0).sum()
            live2 = st["slot_live"] & ~kill
            st2["slot_live"] = live2
            lost = kill.any(axis=1) & ~live2.any(axis=1) & ~st["batch_done"]
            st2["resc_pending"] = st["resc_pending"] | lost
            st2["resc_t"] = jnp.where(lost, ev_t, st["resc_t"])
            st2["n_fail"] = st["n_fail"] + do_fail
            # No dispatch in this epoch can precede its boundary: when the
            # *churn event itself* is what frees the gang (a fail killing the
            # last straggler, or a join reviving a dead cluster), the engine
            # dispatches at the event time -- not at the stale last-completion
            # cursor.  Floor the cursor at the (finite) boundary.
            st2["t_cursor"] = jnp.maximum(
                st["t_cursor"],
                jnp.where(jnp.isfinite(ev_t), jnp.maximum(ev_t, 0.0), -jnp.inf),
            )
            applied_t = jnp.where(do_fail | do_join, ev_t, jnp.inf)
            return st2, applied_t

        def rescues(st, t_start, t_next, tau_row):
            """Dispatch pending rescues onto the earliest-freeing alive
            workers (engine: first free worker, FIFO rescue queue).

            Progress-gated while_loop: one trip per dispatched rescue plus a
            final no-op trip, so churn epochs with nothing pending (the vast
            majority) pay a single cheap iteration instead of a fixed
            n-worker unroll."""

            def body(st):
                live = st["slot_live"]
                masked = jnp.where(live, st["slot_end"], jnp.inf)
                win = jnp.min(masked, axis=1)
                slot_free = jnp.broadcast_to(win[:, None], (n, n)) if cancel else st["slot_end"]
                flat_w = jnp.where(live, st["slot_w"], n).ravel()
                vals = jnp.where(live, slot_free, -jnp.inf).ravel()
                wbusy = jnp.full(n + 1, -jnp.inf).at[flat_w].max(vals)[:n]
                wfree = jnp.where(st["alive"], jnp.maximum(wbusy, t_start), jnp.inf)
                wfree = jnp.where(wfree <= t_next, wfree, jnp.inf)
                tgt = jnp.argmin(jnp.where(st["resc_pending"], st["resc_t"], jnp.inf))
                wstar = jnp.argmin(wfree)
                can = st["resc_pending"].any() & jnp.isfinite(wfree[wstar]) & st["job_active"]
                td = wfree[wstar]
                dur = tau_row[tgt] * batch_scale(st["job_b"]) / speeds[wstar]
                st2 = {**st}
                st2["slot_w"] = st["slot_w"].at[tgt, 0].set(
                    jnp.where(can, wstar, st["slot_w"][tgt, 0])
                )
                st2["slot_start"] = st["slot_start"].at[tgt, 0].set(
                    jnp.where(can, td, st["slot_start"][tgt, 0])
                )
                st2["slot_end"] = st["slot_end"].at[tgt, 0].set(
                    jnp.where(can, td + dur, st["slot_end"][tgt, 0])
                )
                st2["slot_live"] = st["slot_live"].at[tgt, 0].set(
                    jnp.where(can, True, st["slot_live"][tgt, 0])
                )
                st2["resc_pending"] = st["resc_pending"].at[tgt].set(
                    jnp.where(can, False, st["resc_pending"][tgt])
                )
                st2["n_resc"] = st["n_resc"] + can
                return can, st2

            def loop_body(cs):
                _, st = cs
                return body(st)

            _, st = jax.lax.while_loop(lambda cs: cs[0], loop_body, (jnp.array(True), st))
            return st

        def dispatch_loop(st, t_next):
            """Alternate commit / gang-dispatch until nothing more can start
            inside this epoch (engine: whole-cluster FIFO gangs)."""

            def cond(cs):
                return cs[0]

            def body(cs):
                _, st = cs
                st = commit(st, t_next)
                n_alive = st["alive"].sum()
                qsafe = jnp.clip(st["q"], 0, n_jobs - 1)
                can = (
                    (~st["job_active"])
                    & (st["q"] < n_jobs)
                    & (n_alive > 0)
                    & ~st["slot_live"].any()
                )
                td = jnp.maximum(st["t_cursor"], arrivals[qsafe])
                can = can & (td < t_next)
                b = jnp.where(st["plan_b"] > 0, st["plan_b"], n_alive)
                b = jnp.clip(b, 1, jnp.maximum(n_alive, 1))
                r = n_alive // jnp.maximum(b, 1)
                rank = jnp.cumsum(st["alive"]) - 1
                sel = st["alive"] & (rank < b * r)
                flat_slot = jnp.where(sel, (rank % b) * n + (rank // b), n * n)
                new_w = (
                    jnp.full(n * n + 1, -1, jnp.int32)
                    .at[flat_slot]
                    .set(jnp.arange(n, dtype=jnp.int32))[: n * n]
                    .reshape(n, n)
                )
                slot_i = bidx[:, None]
                slot_j = bidx[None, :]
                active_slot = (slot_i < b) & (slot_j < r)
                flat_idx = jnp.clip(slot_j * b + slot_i, 0, n - 1)
                spd = speeds[jnp.clip(new_w, 0, n - 1)]
                dur = tau[qsafe][flat_idx] * batch_scale(b) / spd
                st2 = {**st}
                st2["slot_w"] = jnp.where(can, new_w, st["slot_w"])
                st2["slot_live"] = jnp.where(can, active_slot, st["slot_live"])
                st2["slot_start"] = jnp.where(can, td, st["slot_start"])
                st2["slot_end"] = jnp.where(
                    can, jnp.where(active_slot, td + dur, jnp.inf), st["slot_end"]
                )
                st2["batch_done"] = jnp.where(can, bidx >= b, st["batch_done"])
                st2["batch_done_t"] = jnp.where(
                    can, jnp.where(bidx >= b, -jnp.inf, jnp.inf), st["batch_done_t"]
                )
                st2["job_active"] = st["job_active"] | can
                st2["job_b"] = jnp.where(can, b, st["job_b"])
                st2["job_r"] = jnp.where(can, r, st["job_r"])
                st2["q_active"] = jnp.where(can, st["q"], st["q_active"])
                st2["starts"] = st["starts"].at[qsafe].set(
                    jnp.where(can, td, st["starts"][qsafe])
                )
                st2["bs"] = st["bs"].at[qsafe].set(jnp.where(can, b, st["bs"][qsafe]))
                st2["rs"] = st["rs"].at[qsafe].set(jnp.where(can, r, st["rs"][qsafe]))
                st2["q"] = st["q"] + can
                return can, st2

            _, st = jax.lax.while_loop(cond, body, (jnp.array(True), st))
            return st

        def step(st, xs):
            ev_t, ev_w, ev_up, t_next, tau_row = xs
            st, applied_t = boundary(st, ev_t, ev_w, ev_up)
            st = rescues(st, jnp.maximum(ev_t, 0.0), t_next, tau_row)
            st = dispatch_loop(st, t_next)
            return st, applied_t

        st = {
            "t_cursor": jnp.float32(0.0),
            "alive": jnp.ones(n, dtype=bool),
            "q": jnp.int32(0),
            "job_active": jnp.array(False),
            "job_b": jnp.int32(1),
            "job_r": jnp.int32(1),
            "q_active": jnp.int32(0),
            "slot_w": jnp.full((n, n), -1, jnp.int32),
            "slot_live": jnp.zeros((n, n), dtype=bool),
            "slot_start": jnp.zeros((n, n), jnp.float32),
            "slot_end": jnp.full((n, n), jnp.inf, jnp.float32),
            "batch_done": jnp.ones(n, dtype=bool),
            "batch_done_t": jnp.full(n, -jnp.inf, jnp.float32),
            "resc_pending": jnp.zeros(n, dtype=bool),
            "resc_t": jnp.full(n, jnp.inf, jnp.float32),
            "busy": jnp.float32(0.0),
            "saved": jnp.float32(0.0),
            "n_fail": jnp.int32(0),
            "n_resc": jnp.int32(0),
            "n_replans": jnp.int32(0),
            "plan_b": jnp.asarray(b0, jnp.int32),
            "starts": jnp.full(n_jobs, jnp.inf, jnp.float32),
            "fins": jnp.full(n_jobs, jnp.inf, jnp.float32),
            "bs": jnp.zeros(n_jobs, jnp.int32),
            "rs": jnp.zeros(n_jobs, jnp.int32),
        }
        if replan is not None:
            st.update(
                obs_val=jnp.zeros(W, jnp.float32),
                obs_comp=jnp.ones(W, jnp.float32),
                obs_head=jnp.int32(0),
                obs_count=jnp.int32(0),
                since_refit=jnp.int32(0),
            )
        st, applied = jax.lax.scan(step, st, (ev_t, ev_w, ev_up, next_t, tau_resc))
        return {
            "starts": st["starts"],
            "finishes": st["fins"],
            "bs": st["bs"],
            "rs": st["rs"],
            "worker_seconds": st["busy"],
            "cancelled_seconds_saved": st["saved"],
            "n_worker_failures": st["n_fail"],
            "n_replicas_rescued": st["n_resc"],
            "n_replans": st["n_replans"],
            "epoch_times": applied,
        }

    runner = jax.jit(
        jax.vmap(
            lane,
            in_axes=(0, 0, 0, 0, 0, 0, None, None, 0, None, None, None, None, None),
        )
    )
    _RUNNERS[key] = runner
    return runner


# --------------------------------------------------------------------------
# churn realization sampling / schedule packing
# --------------------------------------------------------------------------


def _pack_schedule(schedule: ChurnSchedule, n_lanes: int):
    k = max(len(schedule), 1)
    t = np.full(k, np.inf, np.float32)
    w = np.full(k, -1, np.int32)
    u = np.zeros(k, bool)
    if len(schedule):
        t[: len(schedule)] = np.asarray(schedule.times, np.float32)
        w[: len(schedule)] = np.asarray(schedule.wids, np.int32)
        u[: len(schedule)] = np.asarray(schedule.ups, bool)
    tile = lambda a: jnp.broadcast_to(jnp.asarray(a), (n_lanes,) + a.shape)  # noqa: E731
    return tile(t), tile(w), tile(u)


def _sample_churn(key, churn: ChurnProcess, n_workers: int, n_lanes: int, pairs: int):
    """Per-lane alternating-renewal timelines, the engine's churn law."""
    if churn.fail_rate <= 0.0 or pairs <= 0:
        shape = (n_lanes, 1)
        return (
            jnp.full(shape, jnp.inf, jnp.float32),
            jnp.full(shape, -1, jnp.int32),
            jnp.zeros(shape, bool),
        )
    ku, kd = jax.random.split(key)
    ups = jax.random.exponential(ku, (n_lanes, n_workers, pairs)) / churn.fail_rate
    if churn.mean_downtime > 0.0:
        downs = jax.random.exponential(kd, (n_lanes, n_workers, pairs)) * churn.mean_downtime
    else:
        downs = jnp.full((n_lanes, n_workers, pairs), jnp.inf)
    iv = jnp.stack([ups, downs], axis=-1).reshape(n_lanes, n_workers, 2 * pairs)
    t = jnp.cumsum(iv, axis=-1)  # fail at even positions, join at odd
    up_kind = (jnp.arange(2 * pairs) % 2).astype(bool)
    wid = jnp.broadcast_to(
        jnp.arange(n_workers, dtype=jnp.int32)[None, :, None], t.shape
    )
    kinds = jnp.broadcast_to(up_kind[None, None, :], t.shape)
    t = t.reshape(n_lanes, -1)
    order = jnp.argsort(t, axis=-1)
    t = jnp.take_along_axis(t, order, axis=-1)
    w = jnp.take_along_axis(wid.reshape(n_lanes, -1), order, axis=-1)
    u = jnp.take_along_axis(kinds.reshape(n_lanes, -1), order, axis=-1)
    w = jnp.where(jnp.isfinite(t), w, -1)
    return t.astype(jnp.float32), w, u


def _prepend_sentinel(ev_t, ev_w, ev_up):
    """Step 0 carries no event: epoch [0, first event)."""
    s = ev_t.shape[0]
    ev_t = jnp.concatenate([jnp.full((s, 1), -jnp.inf, ev_t.dtype), ev_t], axis=1)
    ev_w = jnp.concatenate([jnp.full((s, 1), -1, ev_w.dtype), ev_w], axis=1)
    ev_up = jnp.concatenate([jnp.zeros((s, 1), bool), ev_up], axis=1)
    next_t = jnp.concatenate([ev_t[:, 1:], jnp.full((s, 1), jnp.inf, ev_t.dtype)], axis=1)
    return ev_t, ev_w, ev_up, next_t


def _prepare_lanes(dist, n_workers, n_lanes, n_jobs, seed, churn, churn_schedule, pairs):
    """Per-lane inputs shared by both entry points: service draws, rescue
    draws, and the sentinel-prefixed churn event stream."""
    key = jax.random.key(seed)
    k_svc, k_resc, k_churn = jax.random.split(key, 3)
    tau = dist.sample(k_svc, (n_lanes, n_jobs, n_workers))
    if churn is not None:
        ev_t, ev_w, ev_up = _sample_churn(k_churn, churn, n_workers, n_lanes, pairs)
    elif churn_schedule is not None:
        ev_t, ev_w, ev_up = _pack_schedule(churn_schedule, n_lanes)
    else:
        ev_t = jnp.full((n_lanes, 1), jnp.inf, jnp.float32)
        ev_w = jnp.full((n_lanes, 1), -1, jnp.int32)
        ev_up = jnp.zeros((n_lanes, 1), bool)
    ev_t, ev_w, ev_up, next_t = _prepend_sentinel(ev_t, ev_w, ev_up)
    tau_resc = dist.sample(k_resc, (n_lanes, ev_t.shape[1], n_workers))
    return tau, tau_resc, ev_t, ev_w, ev_up, next_t


# --------------------------------------------------------------------------
# public entry points
# --------------------------------------------------------------------------


def _validate_common(n_workers, speeds, churn, churn_schedule, replan):
    if speeds is None:
        speeds = np.ones(n_workers, np.float32)
    else:
        speeds = np.asarray(speeds, np.float32)
        if speeds.shape != (n_workers,):
            raise ValueError("speeds must have one entry per worker")
        if (speeds <= 0).any():
            raise ValueError("speeds must be positive")
    if churn is not None and churn_schedule is not None:
        raise ValueError("pass either churn (sampled per rep) or churn_schedule, not both")
    if churn_schedule is not None and len(churn_schedule):
        if min(churn_schedule.wids) < 0 or max(churn_schedule.wids) >= n_workers:
            raise ValueError("churn_schedule worker ids must lie in [0, n_workers)")
    if replan is not None:
        if replan.objective not in ("mean", "cov", "blend"):
            raise ValueError(f"unknown objective {replan.objective!r}")
        if replan.window < n_workers:
            raise ValueError("replan.window must be >= n_workers (ring push bound)")
    return speeds


def simulate_epochs(
    dist: ServiceTime,
    n_workers: int,
    n_batches: Optional[int],
    arrivals,
    n_reps: int,
    *,
    seed: int = 0,
    cancel_redundant: bool = False,
    size_dependent: bool = True,
    n_tasks: Optional[int] = None,
    speeds: Optional[Sequence[float]] = None,
    churn: Optional[ChurnProcess] = None,
    churn_schedule: Optional[ChurnSchedule] = None,
    churn_pairs_per_worker: int = 8,
    replan: Optional[ReplanConfig] = None,
) -> EpochReport:
    """Replay the full engine semantics on the jax epoch scan.

    Statistically identical to ``ClusterEngine(n_workers, n_batches=...,
    cancel_redundant=..., speeds=..., churn=..., controller=...)`` run on the
    same arrival vector (the differential suite in ``tests/test_epoch_scan.py``
    enforces this at 3 sigma, and bit-comparably on shared
    ``churn_schedule`` + degenerate service times).  ``n_batches=None`` means
    full parallelism (B = alive workers at dispatch), like the engine.

    Each Monte-Carlo rep redraws every replica duration and (when ``churn`` is
    given) its own fail/join timeline of ``churn_pairs_per_worker`` up/down
    pairs per worker -- after which that worker stays up: the truncation an
    explicit ``churn_schedule`` makes shared and exact.
    """
    arrivals = np.asarray(arrivals, dtype=np.float64)
    if arrivals.ndim != 1 or arrivals.size == 0:
        raise ValueError("arrivals must be a non-empty 1-D array")
    if (np.diff(arrivals) < 0).any():
        raise ValueError("arrivals must be sorted (FIFO order)")
    if n_batches is not None and not (1 <= int(n_batches) <= n_workers):
        raise ValueError(f"n_batches must lie in [1, {n_workers}] or be None")
    speeds = _validate_common(n_workers, speeds, churn, churn_schedule, replan)
    if n_tasks is None:
        n_tasks = n_workers
    n_jobs, s = arrivals.size, int(n_reps)
    tau, tau_resc, ev_t, ev_w, ev_up, next_t = _prepare_lanes(
        dist, n_workers, s, n_jobs, seed, churn, churn_schedule, churn_pairs_per_worker
    )
    div_tab, (h1, h2) = divisor_table(n_workers), harmonic_tables(n_workers)
    runner = _get_runner(n_workers, bool(cancel_redundant), bool(size_dependent), replan)
    out = runner(
        tau,
        tau_resc,
        ev_t,
        ev_w,
        ev_up,
        next_t,
        jnp.asarray(arrivals, jnp.float32),
        jnp.asarray(speeds),
        jnp.full(s, 0 if n_batches is None else int(n_batches), jnp.int32),
        jnp.float32(n_tasks),
        jnp.float32(replan.blend if replan is not None else 0.5),
        jnp.asarray(div_tab),
        jnp.asarray(h1, jnp.float32),
        jnp.asarray(h2, jnp.float32),
    )
    return EpochReport(
        arrivals=arrivals,
        starts=np.asarray(out["starts"], np.float64),
        finishes=np.asarray(out["finishes"], np.float64),
        n_batches_used=np.asarray(out["bs"]),
        replication_used=np.asarray(out["rs"]),
        worker_seconds=np.asarray(out["worker_seconds"], np.float64),
        cancelled_seconds_saved=np.asarray(out["cancelled_seconds_saved"], np.float64),
        n_worker_failures=np.asarray(out["n_worker_failures"]),
        n_replicas_rescued=np.asarray(out["n_replicas_rescued"]),
        n_replans=np.asarray(out["n_replans"]),
        epoch_times=np.asarray(out["epoch_times"], np.float64)[:, 1:],
    )


def frontier_job_times_dynamic(
    dist: ServiceTime,
    n_workers: int,
    candidates,
    n_reps: int,
    *,
    seed: int = 0,
    n_jobs: int = 16,
    cancel_redundant: bool = False,
    size_dependent: bool = True,
    n_tasks: Optional[int] = None,
    speeds: Optional[Sequence[float]] = None,
    churn: Optional[ChurnProcess] = None,
    churn_schedule: Optional[ChurnSchedule] = None,
    churn_pairs_per_worker: int = 8,
    replan: Optional[ReplanConfig] = None,
) -> np.ndarray:
    """Per-candidate job compute times under churn/hetero/replan dynamics.

    The dynamic sibling of :func:`repro.cluster.vectorized.frontier_job_times`
    and the workhorse behind ``plan_cluster(backend="jax")`` on dynamic
    scenarios: every candidate B runs serial job streams of ``n_jobs`` jobs
    (matching the Python engine's ``sample_job_times`` structure -- under
    churn, consecutive jobs share a timeline, so samples come in correlated
    streams) across ``ceil(n_reps / n_jobs)`` independent reps.  Returns
    ``(len(candidates), >= n_reps)`` compute times; unfinished jobs are inf
    (callers filter, like ``planner._frontier_stats``).
    """
    bs = np.asarray(list(candidates), dtype=np.int32)
    if bs.size == 0:
        raise ValueError("need at least one candidate B")
    if (bs < 1).any() or (bs > n_workers).any():
        raise ValueError(f"candidates must lie in [1, {n_workers}], got {bs.tolist()}")
    speeds = _validate_common(n_workers, speeds, churn, churn_schedule, replan)
    if n_tasks is None:
        n_tasks = n_workers
    n_jobs = max(1, min(int(n_jobs), int(n_reps)))
    s = math.ceil(n_reps / n_jobs)
    c = len(bs)
    lanes = c * s
    tau, tau_resc, ev_t, ev_w, ev_up, next_t = _prepare_lanes(
        dist, n_workers, lanes, n_jobs, seed, churn, churn_schedule, churn_pairs_per_worker
    )
    div_tab, (h1, h2) = divisor_table(n_workers), harmonic_tables(n_workers)
    runner = _get_runner(n_workers, bool(cancel_redundant), bool(size_dependent), replan)
    out = runner(
        tau,
        tau_resc,
        ev_t,
        ev_w,
        ev_up,
        next_t,
        jnp.zeros(n_jobs, jnp.float32),
        jnp.asarray(speeds),
        jnp.repeat(jnp.asarray(bs), s),
        jnp.float32(n_tasks),
        jnp.float32(replan.blend if replan is not None else 0.5),
        jnp.asarray(div_tab),
        jnp.asarray(h1, jnp.float32),
        jnp.asarray(h2, jnp.float32),
    )
    t = np.asarray(out["finishes"], np.float64) - np.asarray(out["starts"], np.float64)
    return t.reshape(c, s * n_jobs)
