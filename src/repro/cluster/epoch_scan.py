"""The jax epoch-scan engine: churn, heterogeneous speeds, rescue, and replanning.

This module closes the vectorization gap left by :mod:`repro.cluster.vectorized`
(which covers the static case): it replays the *dynamic* semantics of the
event-driven :class:`~repro.cluster.master.ClusterEngine` -- worker fail/join
churn, replica rescue, per-worker speed factors, FIFO multi-job dispatch, and
windowed online replanning -- as a bounded device loop, batched over
Monte-Carlo reps (and, for planning, over a whole candidate frontier).

The structural insight making this vectorizable: between two churn events the
alive set is constant, so no replica can die and no rescue can be requested --
every job that starts and ends inside an epoch is a pure masked
``max_b min_r`` cover computation (the shared
:func:`~repro.core.simulator.gang_cover_times` semantics), and the only
sequential state is the one job straddling the boundary.  Earlier revisions
expressed this as a ``lax.scan`` over churn epochs whose steps ran
progress-gated ``while_loop``s for rescue dispatch and commit/dispatch; under
``vmap`` those loops serialize -- every lane waits for the slowest lane's trip
count at every scan step.  The current formulation removes the inner loops
entirely: one flat, trip-count-static step loop in which **each step performs
exactly one action** --

  * *rescue*: dispatch the oldest pending rescue onto the earliest-freeing
    alive worker (engine: first free worker, FIFO rescue queue), or
  * *commit + dispatch*: commit batch wins up to the next churn boundary
    (batch wins, sibling cancellation accounting, job finishes, replanner
    observations) and gang-dispatch the next queued job, or
  * *commit + boundary*: apply one fail/join event (replica kill, rescue
    queueing, the engine's sim-over churn truncation).

The step budget is static (``#events + #jobs + rescue allowance``), chunked
under an early-exit ``while_loop`` so finished lanes stop paying for churn
noise past their last job.  State is O(workers) -- per-worker gang assignment
vectors plus one rescue slot per batch -- instead of the previous
O(workers^2) slot grid, which shrinks both the compiled graph and the
per-step work.  Shapes are padded to buckets (workers to multiples of 4,
jobs to multiples of 32, events and lanes to powers of two), so frontier/grid
sweeps of nearby sizes share one compile (see :func:`runner_cache_stats`).

Replanning mirrors :class:`~repro.cluster.control.OnlineReplanner` in jax: a
ring buffer of censoring-tagged task-time observations, maximum-likelihood
refits of the Exp/SExp/Pareto families picked by log-likelihood, the
min-of-r censoring inversion, and a closed-form frontier argmin over the
divisors of the alive-worker count (harmonic/``gammaln`` tables).

Accounting matches the engine's identities: with a shared seed,
``worker_seconds(cancel on) + cancelled_seconds_saved == worker_seconds(cancel
off)`` holds per rep in churn-free runs, and the report exposes the same
counter fields (:meth:`EpochReport.accounting`) as
:class:`~repro.cluster.master.EngineReport` for the differential tests.

Space sharing (the scheduler subsystem of :mod:`repro.cluster.scheduler`)
runs on a second lane builder, :func:`_build_space_lane`: per-worker
job-assignment and availability-timestamp vectors plus per-job plan tables
replay concurrent jobs on disjoint worker subsets under heterogeneous
(B, r, cancellation) plans -- ``packed`` / ``balanced`` / gang-mode
``fifo_gang`` placement, first-fit dispatch by earliest feasible time, and
churn-aware rescue regrants.  ``scheduler`` / ``workers_per_job`` /
``job_plans`` on the public entry points select it; the default
configuration keeps the legacy single-gang lane untouched.

Reproducibility contract: every lane (one Monte-Carlo rep of one candidate)
derives its draws host-side from
``numpy.random.default_rng(SeedSequence((seed, global_lane_index)))`` -- a
pure function of the global lane index -- so results are bit-identical
whether reps run in one call or chunked (``rep_chunk``) and whether lanes run
on one device or sharded across several (``devices``).

Precision: lanes default to float32 absolute simulation time; pass
``dtype="float64"`` (with jax x64 enabled) for long-horizon workloads where
float32 quantizes large arrival offsets -- the engine always runs float64.
"""
from __future__ import annotations

import dataclasses
import math
import warnings
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.scipy.special import gammaln

from ..core.analysis import divisor_table, harmonic_tables
from ..core.service_time import ServiceTime
from .scenario import UNSET, Scenario, Speculation, resolve_scenario
from .scheduler import SCHEDULERS, JobPlan, is_space
from .workers import ChurnProcess, ChurnSchedule

__all__ = [
    "ReplanConfig",
    "EpochReport",
    "EpochStreamReport",
    "simulate_epochs",
    "frontier_job_times_dynamic",
    "runner_cache_stats",
    "clear_runner_cache",
]


@dataclasses.dataclass(frozen=True)
class ReplanConfig:
    """Static mirror of :class:`~repro.cluster.control.OnlineReplanner` knobs.

    Hashable (it keys the jit cache); ``to_controller`` builds the equivalent
    Python-engine controller so differential tests drive both backends from
    one config.
    """

    window: int = 512
    refit_every: int = 128
    min_observations: int = 64
    objective: str = "mean"
    blend: float = 0.5

    def to_controller(self, n_workers: int):
        """Materialize this config as an :class:`~repro.cluster.control.OnlineReplanner`."""
        from .control import OnlineReplanner

        return OnlineReplanner(
            n_workers,
            objective=self.objective,
            window=self.window,
            refit_every=self.refit_every,
            min_observations=self.min_observations,
            blend=self.blend,
        )


@dataclasses.dataclass(frozen=True)
class EpochReport:
    """Batched outcome of :func:`simulate_epochs` (axis 0 = Monte-Carlo rep).

    Mirrors :class:`~repro.cluster.master.EngineReport` field-for-field where
    the semantics overlap; ``inf`` marks jobs never dispatched / completed
    (dead cluster), exactly like the engine's unfinished records.
    ``epoch_times`` are the applied churn-event times per rep (inf-padded),
    the same epoch boundaries ``EngineReport.epoch_times`` records.
    """

    arrivals: np.ndarray  # (n_jobs,)
    starts: np.ndarray  # (n_reps, n_jobs)
    finishes: np.ndarray  # (n_reps, n_jobs)
    n_batches_used: np.ndarray  # (n_reps, n_jobs)
    replication_used: np.ndarray  # (n_reps, n_jobs)
    worker_seconds: np.ndarray  # (n_reps,)
    cancelled_seconds_saved: np.ndarray  # (n_reps,)
    n_worker_failures: np.ndarray  # (n_reps,)
    n_replicas_rescued: np.ndarray  # (n_reps,)
    n_replans: np.ndarray  # (n_reps,)
    epoch_times: np.ndarray  # (n_reps, n_events) applied boundaries, inf pad
    n_speculative: np.ndarray = None  # (n_reps,) reactive backups launched
    # (n_reps,) bool: the rep's timeline outran its sampled churn horizon
    # (workers stayed up past it while the engine's law keeps churning);
    # None when churn is scheduled or absent -- see simulate_epochs
    churn_truncated: np.ndarray = None

    @property
    def compute_times(self) -> np.ndarray:
        """Per-(rep, job) compute time: finish minus start."""
        return self.finishes - self.starts

    @property
    def response_times(self) -> np.ndarray:
        """Per-(rep, job) response time: finish minus arrival."""
        return self.finishes - self.arrivals[None, :]

    @property
    def queue_waits(self) -> np.ndarray:
        """Per-(rep, job) queueing delay: start minus arrival."""
        return self.starts - self.arrivals[None, :]

    @property
    def final_n_batches(self) -> np.ndarray:
        """The B each rep's replanner ended the run on."""
        return self.n_batches_used[:, -1]

    def accounting(self) -> dict:
        """Per-rep counters, keyed identically to ``EngineReport.accounting``."""
        return {
            "worker_seconds": self.worker_seconds,
            "cancelled_seconds_saved": self.cancelled_seconds_saved,
            "n_worker_failures": self.n_worker_failures,
            "n_replicas_rescued": self.n_replicas_rescued,
            "n_replans": self.n_replans,
            "n_speculative": (
                self.n_speculative
                if self.n_speculative is not None
                else np.zeros_like(self.n_replans)
            ),
            # task-level payload failures exist on the Python engine and the
            # live runtime only; the jax lanes report structural zeros so the
            # accounting key set stays identical across backends
            "n_task_failures": np.zeros_like(self.n_replans),
            "n_retries": np.zeros_like(self.n_replans),
        }


@dataclasses.dataclass(frozen=True)
class EpochStreamReport:
    """``Scenario.outputs="stream"`` outcome of :func:`simulate_epochs`.

    Carries O(n_reps) streaming aggregates instead of ``(n_reps, n_jobs)``
    per-job records: ``stats`` is a
    :class:`~repro.cluster.stream.StreamStats` whose response/compute fields
    come from the on-device fold (its ``busy_sum`` / ``saved_sum`` are the
    lane's per-rep worker-seconds totals), plus the usual per-rep counters.
    ``n_unfinished`` counts jobs never completed (dead cluster) -- those are
    excluded from the statistics rather than surfacing as ``inf`` records.
    On float64 lanes the stats equal the host fold of the equivalent
    ``outputs="full"`` report bit for bit (shared seeds; the draw pipeline
    is identical in both modes).
    """

    arrivals: np.ndarray  # (n_jobs,)
    stats: "object"  # StreamStats (declared loose: stream.py imports us not)
    n_unfinished: np.ndarray  # (n_reps,)
    worker_seconds: np.ndarray  # (n_reps,)
    cancelled_seconds_saved: np.ndarray  # (n_reps,)
    n_worker_failures: np.ndarray  # (n_reps,)
    n_replicas_rescued: np.ndarray  # (n_reps,)
    n_replans: np.ndarray  # (n_reps,)
    n_speculative: np.ndarray = None  # (n_reps,)
    churn_truncated: np.ndarray = None  # see EpochReport

    def accounting(self) -> dict:
        """Per-rep counters, keyed identically to ``EpochReport.accounting``."""
        return {
            "worker_seconds": self.worker_seconds,
            "cancelled_seconds_saved": self.cancelled_seconds_saved,
            "n_worker_failures": self.n_worker_failures,
            "n_replicas_rescued": self.n_replicas_rescued,
            "n_replans": self.n_replans,
            "n_speculative": (
                self.n_speculative
                if self.n_speculative is not None
                else np.zeros_like(self.n_replans)
            ),
            # task-level payload failures exist on the Python engine and the
            # live runtime only; the jax lanes report structural zeros so the
            # accounting key set stays identical across backends
            "n_task_failures": np.zeros_like(self.n_replans),
            "n_retries": np.zeros_like(self.n_replans),
        }


# --------------------------------------------------------------------------
# shape buckets and the bucketed jit cache
# --------------------------------------------------------------------------

_RUNNERS: dict = {}
_STEP_CHUNK = 16  # steps per early-exit check


def _pow2(x: int) -> int:
    """Smallest power of two >= max(x, 1): the shape-bucket rounding."""
    return 1 << (max(int(x), 1) - 1).bit_length()


def _bucket_workers(n: int) -> int:
    """Worker counts bucket to multiples of 4: most per-step work is O(n),
    so a finer granularity than power-of-two buys back real element count
    (16 -> 12 for the common mid-size clusters) at a few extra compiles."""
    return max(4, -(-int(n) // 4) * 4)


def runner_cache_stats() -> dict:
    """Compiled-runner cache: ``{bucket_key: number_of_jit_cache_entries}``.

    One entry per *shape bucket* (padded worker/job/event/lane sizes plus the
    static cancel/size-dep/replan/dtype/devices knobs).  The jit cache size of
    each runner counts actual compiles (one per distinct lane-batch shape);
    the regression test asserts a dynamic ``plan_sweep`` grid stays at one.
    """
    return {key: fn._cache_size() for key, fn in _RUNNERS.items()}


def clear_runner_cache() -> None:
    """Drop all cached compiled runners (test/bench isolation helper)."""
    _RUNNERS.clear()


@dataclasses.dataclass(frozen=True)
class _RunnerCfg:
    """Static configuration of one compiled runner (the bucket key)."""

    n: int  # padded worker count
    jobs_pad: int
    ev_pad: int
    resc_cap: int
    n_chunks: int
    cancel: bool
    size_dep: bool
    replan: Optional[ReplanConfig]
    dtype: str
    devices: int
    # False drops the per-event epoch-times buffer and the per-job B/r
    # records plus their per-step scatters; the cheap scalar counters stay.
    # The plan_cluster/plan_sweep hot path only reads starts/finishes.
    full_outputs: bool = True
    # True folds the per-job starts/finishes into streaming accumulators
    # (count, response moment sums, min/max, log histogram) on device before
    # anything leaves the lane -- Scenario.outputs="stream".  Implies
    # full_outputs=False; the lane internals are untouched, so "full" paths
    # stay bit-identical.
    stream: bool = False
    # None selects the legacy single-gang lane; a policy name selects the
    # space-sharing lane (per-worker job assignment, per-job plan tables).
    scheduler: Optional[str] = None
    # Reactive replication (gang lane only -- Scenario.validate rejects the
    # space + speculation combination on this backend).  Enabling it switches
    # the commit pass to event-granular groups so the trigger's median and
    # candidate set evolve exactly as the engine's event loop interleaves them.
    spec: Optional[Speculation] = None


# --------------------------------------------------------------------------
# the per-lane step loop (one Monte-Carlo rep of one candidate)
# --------------------------------------------------------------------------


def _build_lane(cfg: _RunnerCfg):
    n, jobs_pad, ev_pad = cfg.n, cfg.jobs_pad, cfg.ev_pad
    replan = cfg.replan
    spec = cfg.spec
    assert not (spec is not None and replan is not None)  # Scenario.validate
    dt = jnp.dtype(cfg.dtype)
    bidx = jnp.arange(n)
    wid = jnp.arange(n)
    # replica slots: [0, n) gang replica of worker i, [n, 2n) rescue replica
    # of batch i - n, and -- with speculation on -- [2n, 3n) the reactive
    # backup of batch i - 2n.  One flat axis keeps every per-replica
    # reduction a single vector op (the de-serialized sibling of
    # gang_cover_times).  One backup slot per batch means a batch whose
    # backup is still running is not re-eligible; the engine's
    # youngest-replica rule re-arms on the backup instead, so the two differ
    # only when a backup itself lags past theta x median (not exercised by
    # the differential suite).
    rp_batch_rescue = bidx  # rescue slot i hosts batch i
    n_slots = 3 * n if spec is not None else 2 * n
    W = replan.window if replan is not None else 0

    def _seg_min(seg, vals, mask):
        """Per-batch min of ``vals`` over entries with ``mask`` (inf empty).

        ``seg`` is always in-bounds; masked-out entries contribute the
        neutral inf, so only the values need masking."""
        return (
            jnp.full(n + 1, jnp.inf, dt).at[seg].min(jnp.where(mask, vals, jnp.inf))[:n]
        )

    def _obs_push(st, vals, comps, times, valid):
        # ring-buffer push in completion-time order: valid entries take ranks
        # 0..nv-1 under a stable sort of their times, landing at head+rank
        valid = valid & (vals > 0.0) & jnp.isfinite(vals)
        nv = valid.sum()
        rank = jnp.argsort(jnp.argsort(jnp.where(valid, times, jnp.inf)))
        pos = jnp.where(valid, (st["obs_head"] + rank) % W, W)
        st2 = {**st}
        st2["obs_val"] = jnp.append(st["obs_val"], 0.0).at[pos].set(vals)[:W]
        st2["obs_comp"] = jnp.append(st["obs_comp"], 0.0).at[pos].set(comps)[:W]
        st2["obs_head"] = (st["obs_head"] + nv) % W
        st2["obs_count"] = jnp.minimum(st["obs_count"] + nv, W)
        st2["since_refit"] = st["since_refit"] + nv
        return st2

    def _replan_pick(st, div_tab, h1, h2, blend):
        # MLE refit of Exp/SExp/Pareto on the window (mirrors
        # core.planner.fit_service_time), min-of-c censoring inversion
        # (control._inverse_min), closed-form frontier argmin over the
        # divisors of the current alive count (core.analysis forms).
        m = jnp.arange(W) < st["obs_count"]
        nobs = jnp.maximum(st["obs_count"], 1).astype(dt)
        x = st["obs_val"]
        sx = jnp.where(m, x, 0.0).sum()
        mean = sx / nobs
        xmin = jnp.min(jnp.where(m, x, jnp.inf))
        slogx = jnp.where(m, jnp.log(jnp.maximum(x, 1e-30)), 0.0).sum()
        tiny = 1e-30
        mu_e = 1.0 / jnp.maximum(mean, tiny)
        ll_e = nobs * jnp.log(mu_e) - mu_e * sx
        gap = mean - xmin
        mu_s = 1.0 / jnp.maximum(gap, tiny)
        ll_s = jnp.where(gap > 0, nobs * jnp.log(mu_s) - mu_s * (sx - nobs * xmin), -jnp.inf)
        slogs = slogx - nobs * jnp.log(jnp.maximum(xmin, tiny))
        alpha = nobs / jnp.maximum(slogs, tiny)
        ll_p = jnp.where(
            slogs > 0,
            nobs * jnp.log(alpha) + nobs * alpha * jnp.log(jnp.maximum(xmin, tiny))
            - (alpha + 1.0) * slogx,
            -jnp.inf,
        )
        fam = jnp.argmax(jnp.stack([ll_e, ll_s, ll_p]))
        c = jnp.where(m, st["obs_comp"], 0.0).sum() / nobs
        c = jnp.maximum(c, 1.0)
        mu_e, mu_s, alpha_c = mu_e / c, mu_s / c, alpha / c

        n_alive = st["alive"].sum()
        cands = div_tab[n_alive]  # (D,) zero-padded
        vb = cands > 0
        b = jnp.maximum(cands, 1).astype(dt)
        H1, H2 = h1[jnp.maximum(cands, 1)], h2[jnp.maximum(cands, 1)]
        na = n_alive.astype(dt)
        mean_e = H1 / mu_e
        cov_e = jnp.sqrt(H2) / H1
        mean_s = na * xmin / b + H1 / mu_s
        cov_s = jnp.sqrt(H2) / (na * xmin * mu_s / b + H1)
        xp = b / jnp.maximum(na * alpha_c, tiny)
        lgm = jnp.log(jnp.maximum(na * xmin / b, tiny)) + gammaln(b + 1.0)
        lgm = lgm - gammaln(b + 1.0 - xp) + gammaln(1.0 - xp)
        mean_p = jnp.where(xp < 1.0, jnp.exp(lgm), jnp.inf)
        lgq = (
            gammaln(1.0 - 2.0 * xp)
            + 2.0 * gammaln(b + 1.0 - xp)
            - gammaln(b + 1.0)
            - gammaln(b + 1.0 - 2.0 * xp)
            - 2.0 * gammaln(1.0 - xp)
        )
        cov_p = jnp.where(
            2.0 * xp < 1.0, jnp.sqrt(jnp.maximum(jnp.exp(lgq) - 1.0, 0.0)), jnp.inf
        )
        means = jnp.select([fam == 0, fam == 1], [mean_e, mean_s], mean_p)
        covs = jnp.select([fam == 0, fam == 1], [cov_e, cov_s], cov_p)
        means = jnp.where(vb, means, jnp.inf)
        covs = jnp.where(vb, covs, jnp.inf)
        if replan.objective == "mean":
            score = means
        elif replan.objective == "cov":
            score = covs
        elif replan.objective == "blend":
            finite = jnp.isfinite(means) & jnp.isfinite(covs)

            def norm01(v):
                vf = jnp.where(finite, v, jnp.inf)
                lo = jnp.min(vf)
                hi = jnp.max(jnp.where(finite, v, -jnp.inf))
                return jnp.where(finite, (v - lo) / jnp.maximum(hi - lo, 1e-12), 0.0)

            score = jnp.where(
                finite, blend * norm01(means) + (1.0 - blend) * norm01(covs), jnp.inf
            )
        else:  # pragma: no cover - validated at the wrapper
            raise ValueError(f"unknown objective {replan.objective!r}")
        new_b = cands[jnp.argmin(score)]
        return jnp.where(n_alive > 0, jnp.maximum(new_b, 1), st["plan_b"])

    def lane(tau, tau_resc, tau_spec, ev_t, ev_w, ev_up, b0, arrivals, speeds, n_real,
             jobs_real, n_tasks, blend, div_tab, h1, h2):
        inf = jnp.asarray(jnp.inf, dt)

        def batch_scale(job_b):
            return n_tasks / job_b.astype(dt) if cfg.size_dep else jnp.asarray(1.0, dt)

        def step(st):
            """One action per step -- rescue, else commit + (dispatch |
            boundary) -- applied as a single gated pass: every update is
            masked by its action predicate, so no state branching/merging
            is materialized (the predicates are mutually exclusive)."""
            st = {**st}
            e = st["e"]
            t_next = ev_t[e]
            # replica slot -> (batch, worker): gang, rescue, then backup bank
            if spec is not None:
                rp_b = jnp.concatenate([st["g_b"], rp_batch_rescue, bidx])
                rp_w = jnp.concatenate([wid, st["rb_w"], st["sb_w"]])
            else:
                rp_b = jnp.concatenate([st["g_b"], rp_batch_rescue])
                rp_w = jnp.concatenate([wid, st["rb_w"]])
            win = _seg_min(rp_b, st["rp_end"], st["rp_live"])

            # -- rescue: oldest pending rescue onto the earliest-freeing
            # alive worker (engine: first free worker, FIFO rescue queue).
            # Computed on the pre-commit state so projected worker free
            # times still see replicas that commit later this epoch.
            if cfg.cancel:
                # with cancellation a worker frees at its batch's win
                proj_vals = jnp.where(st["rp_live"], win[rp_b], -inf)
            else:
                proj_vals = jnp.where(st["rp_live"], st["rp_end"], -inf)
            # rp_w of a dead rescue slot may be stale but is always in
            # bounds, and its -inf value is the scatter-max neutral
            proj = jnp.full(n + 1, -jnp.inf, dt).at[rp_w].max(proj_vals)[:n]
            # pending rescues block commits/dispatches, so t_cursor has been
            # floored to the request boundary: it is the epoch start time
            wfree = jnp.where(st["alive"], jnp.maximum(proj, st["t_cursor"]), inf)
            wfree = jnp.where(wfree <= t_next, wfree, inf)
            tgt = jnp.argmin(jnp.where(st["resc_pending"], st["resc_t"], inf))
            wstar = jnp.argmin(wfree)
            can_r = st["resc_pending"].any() & jnp.isfinite(wfree[wstar]) & st["job_active"]
            td_r = wfree[wstar]
            rk = jnp.clip(st["resc_k"], 0, cfg.resc_cap - 1)
            dur_r = tau_resc[rk, tgt] * batch_scale(st["job_b"]) / speeds[wstar]
            # gated writes: the index goes out of bounds when the action is
            # off, and jax scatters drop out-of-bounds updates
            i_tgt = jnp.where(can_r, tgt, n)
            i_slot = jnp.where(can_r, n + tgt, n_slots)
            st["rb_w"] = st["rb_w"].at[i_tgt].set(wstar.astype(jnp.int32))
            st["rp_start"] = st["rp_start"].at[i_slot].set(td_r)
            st["rp_end"] = st["rp_end"].at[i_slot].set(td_r + dur_r)
            st["rp_live"] = st["rp_live"].at[i_slot].set(True)
            st["resc_pending"] = st["resc_pending"].at[i_tgt].set(False)
            st["n_resc"] = st["n_resc"] + can_r
            st["resc_k"] = st["resc_k"] + can_r

            # -- speculative backup trigger (reactive replication).  All of
            # it is a pure function of the committed state, evaluated with
            # the exact float expressions of SpeculativePolicy /
            # ClusterEngine._next_spec_time so the differential tests can
            # demand bit-equality: the running lower median of completed
            # sibling durations, each unfinished batch's youngest live
            # replica crossing at start + theta x median, and the launch on
            # the first heartbeat epoch strictly after both the crossing and
            # the last processed event.
            if spec is not None:
                iv, theta = spec.interval, spec.theta
                ofin = jnp.isfinite(st["spec_obs"])
                cnt = ofin.sum()
                med = jnp.sort(jnp.where(ofin, st["spec_obs"], jnp.inf))[
                    jnp.maximum((cnt - 1) // 2, 0)
                ]
                live = st["rp_live"]
                y_b = (
                    jnp.full(n + 1, -jnp.inf, dt)
                    .at[rp_b].max(jnp.where(live, st["rp_start"], -inf))[:n]
                )
                occ = jnp.zeros(n + 1, bool).at[jnp.where(live, rp_w, n)].set(True)[:n]
                free_ok = (st["alive"] & ~occ).any()
                elig = (
                    st["job_active"]
                    & (cnt >= spec.min_observations)
                    & free_ok
                    & (st["spec_used"] < spec.max_backups)
                    & ~st["batch_done"]
                    & jnp.isfinite(y_b)  # the batch holds a live replica
                    & ~live[2 * n :]  # one live backup per batch (see above)
                )
                now_s = jnp.maximum(st["t_cursor"], st["spec_now"])
                k = (
                    jnp.maximum(
                        jnp.floor((y_b + theta * med) / iv), jnp.floor(now_s / iv)
                    )
                    + 1.0
                )
                t_spec = jnp.min(jnp.where(elig, k * iv, jnp.inf))
                # the next replica-completion event: a batch win under
                # cancellation (the win retires the whole batch), any
                # replica end otherwise.  A launch happens only strictly
                # before it -- a completion at the same instant is an
                # earlier-queued event on the engine's heap, and its re-arm
                # supersedes the stale check.
                if cfg.cancel:
                    t_evm = jnp.min(jnp.where(~st["batch_done"], win, jnp.inf))
                else:
                    t_evm = jnp.min(jnp.where(live, st["rp_end"], jnp.inf))
                can_s = (
                    (~can_r) & jnp.isfinite(t_spec) & (t_spec < t_evm) & (t_spec < t_next)
                )
                # fire re-check at the epoch itself, the engine's
                # lagging(now - y, med); a check that launches nothing (the
                # two forms can disagree by 1 ulp) still consumes the epoch,
                # and the next arming lands one grid point later -- the same
                # self-healing re-arm the engine performs
                lag = elig & ((t_spec - y_b) > theta * med)
                b_s = jnp.argmin(jnp.where(lag, bidx, n))
                do_l = can_s & lag.any()
                w_s = jnp.argmin(jnp.where(st["alive"] & ~occ, wid, n))
                sk = jnp.clip(st["spec_k"], 0, tau_spec.shape[0] - 1)
                dur_s = (
                    tau_spec[sk, jnp.clip(b_s, 0, n - 1)]
                    * batch_scale(st["job_b"])
                    / speeds[w_s]
                )
                i_sl = jnp.where(do_l, 2 * n + b_s, n_slots)
                st["sb_w"] = st["sb_w"].at[jnp.where(do_l, b_s, n)].set(
                    w_s.astype(jnp.int32)
                )
                st["rp_start"] = st["rp_start"].at[i_sl].set(t_spec)
                st["rp_end"] = st["rp_end"].at[i_sl].set(t_spec + dur_s)
                st["rp_live"] = st["rp_live"].at[i_sl].set(True)
                st["spec_used"] = st["spec_used"] + do_l
                st["n_spec"] = st["n_spec"] + do_l
                st["spec_k"] = st["spec_k"] + do_l
                st["spec_now"] = jnp.where(can_s, t_spec, st["spec_now"])
            else:
                can_s = jnp.array(False)
                t_evm = inf

            # -- commit completions up to the next boundary (masked out
            # entirely on rescue steps: pending rescues must dispatch before
            # any commit clears the replicas their free times project from).
            # With speculation on, commit only the earliest completion-time
            # group: every completion changes the trigger's median and
            # candidate set, so later completions must see the launches (and
            # re-armed epochs) that precede them, one event at a time.
            newly = (~st["batch_done"]) & (win <= t_next) & jnp.isfinite(win) & ~can_r
            if spec is not None:
                newly = newly & (win == t_evm) & ~can_s
            if cfg.cancel:
                win_r = win[rp_b]
                done_r = st["rp_live"] & newly[rp_b]
                busy_add = jnp.where(done_r, win_r - st["rp_start"], 0.0).sum()
                saved_add = jnp.where(done_r, st["rp_end"] - win_r, 0.0).sum()
                t_new = jnp.max(jnp.where(newly, win, -inf))
            else:
                done_r = st["rp_live"] & (st["rp_end"] <= t_next) & ~can_r
                if spec is not None:
                    done_r = done_r & (st["rp_end"] == t_evm) & ~can_s
                busy_add = jnp.where(done_r, st["rp_end"] - st["rp_start"], 0.0).sum()
                saved_add = 0.0
                t_new = jnp.max(jnp.where(done_r, st["rp_end"], -inf))
            if spec is not None:
                # the winning replica's wall-clock duration is the sibling
                # observation the policy's median runs over (engine:
                # jexec.obs.append(now - worker.busy_since)); ties keep the
                # earliest-queued gang replica, i.e. the smallest start
                is_w = st["rp_live"] & newly[rp_b] & (st["rp_end"] <= win[rp_b])
                w_st = (
                    jnp.full(n + 1, jnp.inf, dt)
                    .at[jnp.where(is_w, rp_b, n)].min(st["rp_start"])[:n]
                )
                st["spec_obs"] = jnp.where(newly, win - w_st, st["spec_obs"])
            live2 = st["rp_live"] & ~done_r
            done2 = st["batch_done"] | newly
            done_t2 = jnp.where(newly, win, st["batch_done_t"])
            all_done = jnp.all(done2)
            fin = jnp.max(jnp.where(bidx < st["job_b"], done_t2, -inf))
            completes = st["job_active"] & all_done & ~can_r
            qa = st["q_active"]
            st["rp_live"] = live2
            st["busy"] = st["busy"] + busy_add
            st["saved"] = st["saved"] + saved_add
            st["batch_done"] = done2
            st["batch_done_t"] = done_t2
            st["t_cursor"] = jnp.maximum(
                st["t_cursor"], jnp.maximum(t_new, jnp.where(completes, fin, -inf))
            )
            st["fins"] = st["fins"].at[jnp.where(completes, qa, jobs_pad)].set(fin)
            st["job_active"] = st["job_active"] & ~(all_done & ~can_r)
            st["resc_pending"] = st["resc_pending"] & ~completes

            if replan is not None:
                sc = batch_scale(st["job_b"])
                spd = speeds[rp_w]
                if cfg.cancel:
                    # one observation per newly-won batch: the winner's task
                    # time, censored by however many rivals it raced
                    cand = (st["rp_live"] | done_r) & (st["rp_end"] <= win[rp_b])
                    win_slot = (
                        jnp.full(n + 1, 2 * n, jnp.int32)
                        .at[jnp.where(cand, rp_b, n)]
                        .min(jnp.arange(2 * n, dtype=jnp.int32))[:n]
                    )
                    ws = jnp.clip(win_slot, 0, 2 * n - 1)
                    vals = (win - st["rp_start"][ws]) * spd[ws] / sc
                    comps = (
                        jnp.zeros(n + 1, jnp.int32)
                        .at[jnp.where(st["rp_live"] | done_r, rp_b, n)]
                        .add(1)[:n]
                    ).astype(dt)
                    st = _obs_push(st, vals, comps, win, newly)
                else:
                    # every replica that completes while its job is active is
                    # an uncensored observation (the engine drops stragglers
                    # that outlive their job)
                    fin_limit = jnp.where(completes, fin, inf)
                    ovalid = done_r & (st["job_active"] | completes) & (
                        st["rp_end"] <= fin_limit
                    )
                    vals = (st["rp_end"] - st["rp_start"]) * spd / sc
                    st = _obs_push(st, vals, jnp.ones_like(vals), st["rp_end"], ovalid)
                do_replan = (
                    completes
                    & (st["obs_count"] >= replan.min_observations)
                    & (st["since_refit"] >= replan.refit_every)
                )
                # _replan_pick runs unconditionally: under vmap a lax.cond on
                # the (batched) do_replan lowers to a select that evaluates
                # both branches anyway, so gating would add bookkeeping
                # without skipping the work
                new_b = _replan_pick(st, div_tab, h1, h2, blend)
                st["plan_b"] = jnp.where(do_replan, new_b, st["plan_b"])
                st["n_replans"] = st["n_replans"] + do_replan
                st["since_refit"] = jnp.where(do_replan, 0, st["since_refit"])

            # -- gang-dispatch the next queued job (engine: whole-cluster
            # FIFO gangs); mutually exclusive with rescue via job_active
            n_alive = st["alive"].sum(dtype=jnp.int32)
            q = st["q"]
            can_d = (
                (~st["job_active"])
                & (q < jobs_real)
                & (n_alive > 0)
                & ~st["rp_live"].any()
                & ~can_r
            )
            # out-of-range job gathers clamp (jax default), and can_d is
            # already false there -- no explicit clip needed
            td = jnp.maximum(st["t_cursor"], arrivals[q])
            can_d = can_d & (td < t_next)
            b = jnp.where(st["plan_b"] > 0, st["plan_b"], n_alive)
            b = jnp.clip(b, 1, jnp.maximum(n_alive, 1))
            r = n_alive // jnp.maximum(b, 1)
            rank = jnp.cumsum(st["alive"]) - 1
            sel = st["alive"] & (rank < b * r)
            # draw index = alive-rank (the engine assigns free workers in wid
            # order, drawing sequentially); batch = rank mod b
            dur = tau[q][rank] * batch_scale(b) / speeds
            sel2 = jnp.concatenate([sel, jnp.zeros(n_slots - n, bool)])
            end2 = jnp.concatenate([td + dur, jnp.full(n_slots - n, jnp.inf, dt)])
            st["g_b"] = jnp.where(can_d & sel, (rank % b).astype(jnp.int32), st["g_b"])
            st["rp_live"] = jnp.where(can_d, sel2, st["rp_live"])
            st["rp_start"] = jnp.where(can_d & sel2, td, st["rp_start"])
            st["rp_end"] = jnp.where(can_d & sel2, end2, st["rp_end"])
            st["batch_done"] = jnp.where(can_d, bidx >= b, st["batch_done"])
            st["batch_done_t"] = jnp.where(
                can_d, jnp.where(bidx >= b, -inf, inf), st["batch_done_t"]
            )
            st["job_active"] = st["job_active"] | can_d
            st["job_b"] = jnp.where(can_d, b, st["job_b"])
            st["q_active"] = jnp.where(can_d, st["q"], st["q_active"])
            i_q = jnp.where(can_d, q, jobs_pad)
            st["starts"] = st["starts"].at[i_q].set(td)
            if cfg.full_outputs:
                st["br"] = st["br"].at[i_q].set((b << 16 | r).astype(jnp.int32))
            st["q"] = st["q"] + can_d
            if spec is not None:
                # per-job policy state resets at dispatch (a fresh _JobExec)
                st["spec_obs"] = jnp.where(can_d, inf, st["spec_obs"])
                st["spec_used"] = jnp.where(can_d, 0, st["spec_used"])

            # -- otherwise apply one fail/join event (the engine stops
            # replaying churn once every job is recorded: the sim_over gate)
            t_ev, w_raw, up = ev_t[e], ev_w[e], ev_up[e]
            if spec is not None:
                # a launch or a committed completion group consumed this
                # step; the boundary waits for a step with neither
                do_b = ~can_r & ~can_d & ~can_s & ~newly.any() & ~done_r.any()
            else:
                do_b = ~can_r & ~can_d
            sim_over = (st["q"] >= jobs_real) & ~st["job_active"]
            act = do_b & (w_raw >= 0) & jnp.isfinite(t_ev) & ~sim_over
            w = jnp.clip(w_raw, 0, n - 1)
            was = st["alive"][w]
            do_fail = act & ~up & was
            do_join = act & up & ~was
            # a fail flips alive to False (= up), a join to True (= up)
            st["alive"] = st["alive"].at[jnp.where(do_fail | do_join, w, n)].set(up)
            kill = st["rp_live"] & (rp_w == w) & do_fail
            st["busy"] = st["busy"] + jnp.where(kill, t_ev - st["rp_start"], 0.0).sum()
            live3 = st["rp_live"] & ~kill
            st["rp_live"] = live3
            # a batch that just lost its last live replica needs a rescue:
            # one segment count carries both indicators (kills in the low
            # bits, survivors shifted past any possible kill count)
            seg = jnp.zeros(n + 1, jnp.int32).at[rp_b].add(kill + 4096 * live3)[:n]
            lost = (seg & 4095) > 0
            lost = lost & (seg < 4096) & ~st["batch_done"]
            st["resc_pending"] = st["resc_pending"] | lost
            st["resc_t"] = jnp.where(lost, t_ev, st["resc_t"])
            st["n_fail"] = st["n_fail"] + do_fail
            # No dispatch in this epoch can precede its boundary: when the
            # *churn event itself* is what frees the gang (a fail killing the
            # last straggler, or a join reviving a dead cluster), the engine
            # dispatches at the event time -- not at the stale last-completion
            # cursor.  Floor the cursor at the (finite) boundary.
            st["t_cursor"] = jnp.maximum(
                st["t_cursor"],
                jnp.where(do_b & jnp.isfinite(t_ev), jnp.maximum(t_ev, 0.0), -inf),
            )
            if cfg.full_outputs:
                st["ep_times"] = st["ep_times"].at[
                    jnp.where(do_fail | do_join, e, ev_pad)
                ].set(t_ev)
            st["e"] = jnp.minimum(e + do_b, ev_pad - 1)
            return st

        def done(st):
            return (st["q"] >= jobs_real) & ~st["job_active"]

        st = {
            "t_cursor": jnp.asarray(0.0, dt),
            "e": jnp.int32(0),
            "alive": wid < n_real,
            "q": jnp.int32(0),
            "job_active": jnp.array(False),
            "job_b": jnp.int32(1),
            "q_active": jnp.int32(0),
            "g_b": jnp.zeros(n, jnp.int32),
            "rb_w": jnp.zeros(n, jnp.int32),
            "rp_live": jnp.zeros(n_slots, bool),
            "rp_start": jnp.zeros(n_slots, dt),
            "rp_end": jnp.full(n_slots, jnp.inf, dt),
            "batch_done": jnp.ones(n, bool),
            "batch_done_t": jnp.full(n, -jnp.inf, dt),
            "resc_pending": jnp.zeros(n, bool),
            "resc_t": jnp.full(n, jnp.inf, dt),
            "resc_k": jnp.int32(0),
            "busy": jnp.asarray(0.0, dt),
            "saved": jnp.asarray(0.0, dt),
            "n_fail": jnp.int32(0),
            "n_resc": jnp.int32(0),
            "n_replans": jnp.int32(0),
            "plan_b": b0.astype(jnp.int32),
            "starts": jnp.full(jobs_pad, jnp.inf, dt),
            "fins": jnp.full(jobs_pad, jnp.inf, dt),
        }
        if cfg.full_outputs:
            st["br"] = jnp.zeros(jobs_pad, jnp.int32)
            st["ep_times"] = jnp.full(ev_pad, jnp.inf, dt)
        if replan is not None:
            st.update(
                obs_val=jnp.zeros(W, dt),
                obs_comp=jnp.ones(W, dt),
                obs_head=jnp.int32(0),
                obs_count=jnp.int32(0),
                since_refit=jnp.int32(0),
            )
        if spec is not None:
            st.update(
                sb_w=jnp.zeros(n, jnp.int32),
                spec_obs=jnp.full(n, jnp.inf, dt),
                spec_used=jnp.int32(0),
                spec_k=jnp.int32(0),
                spec_now=jnp.asarray(0.0, dt),
                n_spec=jnp.int32(0),
            )

        def chunk_body(carry):
            st, it = carry
            st = jax.lax.fori_loop(0, _STEP_CHUNK, lambda _, s: step(s), st)
            return st, it + 1

        def chunk_cond(carry):
            st, it = carry
            return (it < cfg.n_chunks) & ~done(st)

        st, _ = jax.lax.while_loop(chunk_cond, chunk_body, (st, jnp.int32(0)))
        # flush replicas still in flight: their full duration is committed
        # worker time (it will burn whether or not we simulate it), which
        # keeps the invariant  ws(cancel on) + saved == ws(cancel off)
        flush = jnp.where(st["rp_live"], st["rp_end"] - st["rp_start"], 0.0).sum()
        out = {
            "starts": st["starts"],
            "finishes": st["fins"],
            "worker_seconds": st["busy"] + flush,
            "cancelled_seconds_saved": st["saved"],
            "n_worker_failures": st["n_fail"],
            "n_replicas_rescued": st["n_resc"],
            "n_replans": st["n_replans"],
        }
        if spec is not None:
            out["n_speculative"] = st["n_spec"]
        if cfg.full_outputs:
            out["br"] = st["br"]
            out["epoch_times"] = st["ep_times"]
        return out

    return lane


# --------------------------------------------------------------------------
# the space-sharing lane: concurrent jobs on disjoint worker subsets
# --------------------------------------------------------------------------


def _build_space_lane(cfg: _RunnerCfg):
    """One lane of the space-sharing replay (packed / balanced / fifo_gang).

    Extends the event-step formulation with per-worker vectors -- ``w_job``
    (queue index of the owning job, ``jobs_pad`` = unallocated), ``w_avail``
    (the *time* the worker is next available: set to the replica's scheduled
    end at placement, corrected down to the batch win under cancellation,
    to the job finish at release, to inf on fail and the join time on join)
    and ``w_load`` (cumulative assigned wall-clock, the 'balanced' metric) --
    plus per-job plan tables (worker request, B, cancellation mode) indexed
    by queue position, so concurrent jobs run heterogeneous plans.

    Batches of in-flight jobs live in *segment slots*: a (n,)-sized id space
    mapping each unfinished batch to its rescue bookkeeping and win
    reduction.  n slots always suffice -- rescues are served before any
    dispatch, so at dispatch time every unfinished batch of every active job
    holds a live replica on a distinct worker, and slots are freed the
    moment a batch wins.

    Each step still performs exactly one action, chosen by earliest time
    (rescues outrank dispatches at equal times, matching the engine's
    rescues-first event handlers):

      * *rescue*: the earliest-serveable pending rescue onto the earliest
        available worker -- free workers of the job's own allocation first,
        else a free unallocated worker is regranted (churn-aware
        reassignment);
      * *dispatch*: the first-fit queued job (earliest feasible time, ties
        by queue order) onto the policy's choice of free unallocated
        workers (packed: lowest wids; balanced: least ``w_load``;
        fifo_gang: the whole alive set);
      * *boundary*: one fail/join event.

    Batch wins and replica retirements up to the next churn boundary are
    committed at the top of every step -- timestamps in ``w_avail`` make
    commit order irrelevant to placement decisions, unlike the legacy
    lane's projection from live replica state.
    """
    n, jobs_pad, ev_pad = cfg.n, cfg.jobs_pad, cfg.ev_pad
    dt = jnp.dtype(cfg.dtype)
    widx = jnp.arange(n)
    J = jobs_pad  # sentinel: unallocated worker / free segment slot
    balanced = cfg.scheduler == "balanced"

    def lane(tau, tau_resc, tau_spec, ev_t, ev_w, ev_up, b0, arrivals, speeds, n_real,
             jobs_real, n_tasks, req_tab, b_tab, cancel_tab, default_req):
        del tau_spec  # speculation is gang-lane only (Scenario.validate)
        inf = jnp.asarray(jnp.inf, dt)
        jidx = jnp.arange(jobs_pad)

        def bscale(b):
            return n_tasks / b.astype(dt) if cfg.size_dep else jnp.asarray(1.0, dt)

        def step(st):
            st = {**st}
            e = st["e"]
            t_next = ev_t[e]
            rp_seg = jnp.concatenate([st["g_s"], widx])
            rp_w = jnp.concatenate([widx, st["rb_w"]])
            seg_of = jnp.clip(rp_seg, 0, n - 1)
            occupied = st["seg_job"] < J

            # -- commit batch wins and replica retirements up to t_next
            win = (
                jnp.full(n + 1, jnp.inf, dt)
                .at[rp_seg].min(jnp.where(st["rp_live"], st["rp_end"], jnp.inf))[:n]
            )
            newly = occupied & jnp.isfinite(win) & (win <= t_next)
            on_win = st["rp_live"] & newly[seg_of] & (rp_seg < n)
            win_r = win[seg_of]
            # cancellation: every replica of a winning segment stops at the
            # win (the winner by construction, the losers reclaimed)
            kill_c = on_win & st["rp_cancel"]
            st["busy"] = st["busy"] + jnp.where(kill_c, win_r - st["rp_start"], 0.0).sum()
            st["saved"] = st["saved"] + jnp.where(kill_c, st["rp_end"] - win_r, 0.0).sum()
            st["w_avail"] = st["w_avail"].at[jnp.where(kill_c, rp_w, 2 * n)].set(
                jnp.where(kill_c, win_r, 0.0)
            )
            # non-cancel replicas retire individually at their own end
            retire = st["rp_live"] & ~st["rp_cancel"] & (st["rp_end"] <= t_next)
            st["busy"] = st["busy"] + jnp.where(
                retire, st["rp_end"] - st["rp_start"], 0.0
            ).sum()
            live2 = st["rp_live"] & ~(kill_c | retire)
            st["rp_live"] = live2
            # non-cancel survivors of a winning segment detach: the batch is
            # done but the straggler replica keeps burning to its end
            gone = ~live2[:n] | (newly[jnp.clip(st["g_s"], 0, n - 1)] & (st["g_s"] < n))
            st["g_s"] = jnp.where(gone, n, st["g_s"])

            # -- job bookkeeping: wins decrement the owner's open count
            segj = st["seg_job"]
            i_new = jnp.where(newly, jnp.clip(segj, 0, J - 1), J)
            st["job_left"] = st["job_left"].at[i_new].add(-1)
            st["job_fin"] = st["job_fin"].at[i_new].max(win)
            st["seg_job"] = jnp.where(newly, J, segj)  # freed at the win
            st["resc_pending"] = st["resc_pending"] & ~newly
            comp = st["dispatched"] & (st["job_left"] == 0) & ~st["recorded"]
            st["fins"] = jnp.where(comp, st["job_fin"], st["fins"])
            st["recorded"] = st["recorded"] | comp
            st["n_done"] = st["n_done"] + comp.sum(dtype=jnp.int32)
            wj = jnp.clip(st["w_job"], 0, J - 1)
            rel = (st["w_job"] < J) & comp[wj]
            st["w_avail"] = jnp.where(
                rel, jnp.maximum(st["w_avail"], st["job_fin"][wj]), st["w_avail"]
            )
            st["w_job"] = jnp.where(rel, J, st["w_job"])

            # -- rescue: earliest-serveable pending segment, oldest first on
            # ties; eligible workers are the job's own free allocation plus
            # free unallocated workers (regrant)
            pend = st["resc_pending"]
            segjob = jnp.clip(st["seg_job"], 0, J - 1)
            free_w = st["alive"] & (st["w_job"] == J)
            elig = (free_w[None, :] | (st["w_job"][None, :] == segjob[:, None])) & (
                st["alive"][None, :] & pend[:, None]
            )
            serve0 = jnp.min(jnp.where(elig, st["w_avail"][None, :], jnp.inf), axis=1)
            serve_t = jnp.where(pend, jnp.maximum(st["resc_t"], serve0), jnp.inf)
            serve_min = jnp.min(serve_t)
            m1 = serve_t == serve_min
            r_min = jnp.min(jnp.where(m1, st["resc_t"], jnp.inf))
            s_star = jnp.argmin(jnp.where(m1 & (st["resc_t"] == r_min), widx, n))
            can_r = pend.any() & jnp.isfinite(serve_min) & (serve_min <= t_next)
            j_star = segjob[s_star]
            cand = st["alive"] & (st["w_avail"] <= serve_min) & (
                (st["w_job"] == j_star) | (st["w_job"] == J)
            )
            # space policies serve rescues from the job's own free workers
            # before regranting an unallocated one; the gang engine has no
            # allocations and just takes the policy-first free worker
            if cfg.scheduler == "fifo_gang":
                tier = jnp.zeros(n, jnp.int32)
            else:
                tier = jnp.where(st["w_job"] == j_star, 0, 1)
            key2 = st["w_load"] if balanced else widx.astype(dt)
            mt = cand & (tier == jnp.min(jnp.where(cand, tier, 2)))
            mk = mt & (key2 == jnp.min(jnp.where(mt, key2, jnp.inf)))
            w_star = jnp.argmin(jnp.where(mk, widx, n))
            rk = jnp.clip(st["resc_k"], 0, cfg.resc_cap - 1)
            dur_r = (
                tau_resc[rk, s_star]
                * bscale(jnp.maximum(st["job_b"][j_star], 1))
                / speeds[w_star]
            )
            i_w = jnp.where(can_r, w_star, n)
            i_s = jnp.where(can_r, s_star, n)
            i_slot = jnp.where(can_r, n + s_star, 2 * n)
            st["rb_w"] = st["rb_w"].at[i_s].set(w_star.astype(jnp.int32))
            st["rp_start"] = st["rp_start"].at[i_slot].set(serve_min)
            st["rp_end"] = st["rp_end"].at[i_slot].set(serve_min + dur_r)
            st["rp_live"] = st["rp_live"].at[i_slot].set(True)
            st["rp_cancel"] = st["rp_cancel"].at[i_slot].set(cancel_tab[j_star])
            st["resc_pending"] = st["resc_pending"].at[i_s].set(False)
            st["w_job"] = st["w_job"].at[i_w].set(j_star.astype(jnp.int32))
            st["w_avail"] = st["w_avail"].at[i_w].set(serve_min + dur_r)
            # speed-weighted load (duration / speed), same op order as the
            # engine's _assign so f64 lanes replay placement bit-for-bit
            st["w_load"] = st["w_load"].at[i_w].add(dur_r / speeds[w_star])
            st["n_resc"] = st["n_resc"] + can_r
            st["resc_k"] = st["resc_k"] + can_r

            # -- dispatch: first-fit over undispatched jobs -- earliest
            # feasible time (req-th smallest availability among free
            # unallocated workers, floored at the job's arrival and the
            # epoch start), ties broken by queue order
            n_alive = st["alive"].sum(dtype=jnp.int32)
            free_w2 = st["alive"] & (st["w_job"] == J)
            sa = jnp.sort(jnp.where(free_w2, st["w_avail"], jnp.inf))
            req = jnp.where(
                req_tab > 0, req_tab, jnp.where(default_req > 0, default_req, n_alive)
            )
            req_eff = jnp.clip(req, 1, jnp.maximum(n_alive, 1))
            kth = sa[jnp.clip(req_eff - 1, 0, n - 1)]
            segfree = st["seg_job"] == J
            seg_rank = jnp.cumsum(segfree) - 1
            n_segfree = segfree.sum(dtype=jnp.int32)
            bq = jnp.clip(
                jnp.where(b_tab > 0, b_tab, jnp.where(b0 > 0, b0, req_eff)), 1, req_eff
            )
            t_q = jnp.maximum(arrivals, jnp.maximum(kth, st["t_epoch"]))
            t_q = jnp.where(
                (~st["dispatched"]) & (jidx < jobs_real) & (n_alive > 0)
                & (bq <= n_segfree),
                t_q,
                jnp.inf,
            )
            q_star = jnp.argmin(t_q)  # first min: lowest queue index
            td = t_q[q_star]
            can_d = ~can_r & jnp.isfinite(td) & (td < t_next)
            b_d = bq[q_star]
            r_d = req_eff[q_star] // b_d
            elig_d = free_w2 & (st["w_avail"] <= td)
            keyd = jnp.where(elig_d, st["w_load"] if balanced else widx.astype(dt), jnp.inf)
            rank = jnp.argsort(jnp.argsort(keyd, stable=True), stable=True)
            sel_rep = elig_d & (rank < b_d * r_d)
            sel_alloc = elig_d & (rank < req_eff[q_star])
            # the beta-th dispatched batch takes the beta-th free segment
            seg_by_beta = (
                jnp.full(n + 1, n, jnp.int32)
                .at[jnp.where(segfree, seg_rank, n)]
                .set(widx.astype(jnp.int32))[:n]
            )
            w_seg = seg_by_beta[jnp.clip(rank % jnp.maximum(b_d, 1), 0, n - 1)]
            # draw index = policy rank: the engine draws in placement order
            dur = tau[q_star][jnp.clip(rank, 0, n - 1)] * bscale(b_d) / speeds
            sel2 = jnp.concatenate([can_d & sel_rep, jnp.zeros(n, bool)])
            st["g_s"] = jnp.where(can_d & sel_rep, w_seg, st["g_s"])
            st["rp_live"] = st["rp_live"] | sel2
            st["rp_start"] = jnp.where(sel2, td, st["rp_start"])
            st["rp_end"] = jnp.where(
                sel2, jnp.concatenate([td + dur, jnp.zeros(n, dt)]), st["rp_end"]
            )
            st["rp_cancel"] = jnp.where(sel2, cancel_tab[q_star], st["rp_cancel"])
            st["w_job"] = jnp.where(can_d & sel_alloc, q_star.astype(jnp.int32), st["w_job"])
            st["w_avail"] = jnp.where(
                can_d & sel_rep,
                td + dur,
                jnp.where(can_d & sel_alloc, td, st["w_avail"]),
            )
            st["w_load"] = st["w_load"] + jnp.where(can_d & sel_rep, dur / speeds, 0.0)
            st["seg_job"] = jnp.where(
                can_d & segfree & (seg_rank < b_d), q_star.astype(jnp.int32), st["seg_job"]
            )
            i_q = jnp.where(can_d, q_star, jobs_pad)
            st["starts"] = st["starts"].at[i_q].set(td)
            st["dispatched"] = st["dispatched"].at[i_q].set(True)
            st["job_left"] = st["job_left"].at[i_q].set(b_d)
            st["job_b"] = st["job_b"].at[i_q].set(b_d)
            if cfg.full_outputs:
                st["br"] = st["br"].at[i_q].set((b_d << 16 | r_d).astype(jnp.int32))

            # -- otherwise apply one fail/join event (sim-over gated)
            do_b = ~can_r & ~can_d
            sim_over = st["n_done"] >= jobs_real
            t_ev, w_raw, up = ev_t[e], ev_w[e], ev_up[e]
            act = do_b & (w_raw >= 0) & jnp.isfinite(t_ev) & ~sim_over
            w = jnp.clip(w_raw, 0, n - 1)
            was = st["alive"][w]
            do_fail = act & ~up & was
            do_join = act & up & ~was
            st["alive"] = st["alive"].at[jnp.where(do_fail | do_join, w, n)].set(up)
            kill = st["rp_live"] & (rp_w == w) & do_fail
            st["busy"] = st["busy"] + jnp.where(kill, t_ev - st["rp_start"], 0.0).sum()
            live3 = st["rp_live"] & ~kill
            st["rp_live"] = live3
            rp_seg3 = jnp.concatenate([st["g_s"], widx])
            seg_cnt = jnp.zeros(n + 1, jnp.int32).at[rp_seg3].add(kill + 4096 * live3)[:n]
            lost = ((seg_cnt & 4095) > 0) & (seg_cnt < 4096) & (st["seg_job"] < J)
            st["resc_pending"] = st["resc_pending"] | lost
            st["resc_t"] = jnp.where(lost, t_ev, st["resc_t"])
            st["g_s"] = jnp.where(do_fail & (widx == w), n, st["g_s"])
            st["w_job"] = st["w_job"].at[jnp.where(do_fail | do_join, w, n)].set(J)
            st["w_avail"] = st["w_avail"].at[jnp.where(do_fail, w, n)].set(jnp.inf)
            st["w_avail"] = st["w_avail"].at[jnp.where(do_join, w, n)].set(t_ev)
            st["n_fail"] = st["n_fail"] + do_fail
            st["t_epoch"] = jnp.maximum(
                st["t_epoch"],
                jnp.where(do_b & jnp.isfinite(t_ev), jnp.maximum(t_ev, 0.0), -inf),
            )
            if cfg.full_outputs:
                st["ep_times"] = st["ep_times"].at[
                    jnp.where(do_fail | do_join, e, ev_pad)
                ].set(t_ev)
            st["e"] = jnp.minimum(e + do_b, ev_pad - 1)
            return st

        st = {
            "t_epoch": jnp.asarray(0.0, dt),
            "e": jnp.int32(0),
            "alive": widx < n_real,
            "w_job": jnp.full(n, J, jnp.int32),
            "w_avail": jnp.where(widx < n_real, 0.0, jnp.inf).astype(dt),
            "w_load": jnp.zeros(n, dt),
            "g_s": jnp.full(n, n, jnp.int32),
            "rb_w": jnp.zeros(n, jnp.int32),
            "rp_live": jnp.zeros(2 * n, bool),
            "rp_start": jnp.zeros(2 * n, dt),
            "rp_end": jnp.full(2 * n, jnp.inf, dt),
            "rp_cancel": jnp.zeros(2 * n, bool),
            "seg_job": jnp.full(n, J, jnp.int32),
            "resc_pending": jnp.zeros(n, bool),
            "resc_t": jnp.full(n, jnp.inf, dt),
            "resc_k": jnp.int32(0),
            "busy": jnp.asarray(0.0, dt),
            "saved": jnp.asarray(0.0, dt),
            "n_fail": jnp.int32(0),
            "n_resc": jnp.int32(0),
            "n_done": jnp.int32(0),
            "dispatched": jnp.zeros(jobs_pad, bool),
            "recorded": jnp.zeros(jobs_pad, bool),
            "job_left": jnp.zeros(jobs_pad, jnp.int32),
            "job_b": jnp.ones(jobs_pad, jnp.int32),
            "job_fin": jnp.full(jobs_pad, -jnp.inf, dt),
            "starts": jnp.full(jobs_pad, jnp.inf, dt),
            "fins": jnp.full(jobs_pad, jnp.inf, dt),
        }
        if cfg.full_outputs:
            st["br"] = jnp.zeros(jobs_pad, jnp.int32)
            st["ep_times"] = jnp.full(ev_pad, jnp.inf, dt)

        def chunk_body(carry):
            s, it = carry
            s = jax.lax.fori_loop(0, _STEP_CHUNK, lambda _, x: step(x), s)
            return s, it + 1

        def chunk_cond(carry):
            s, it = carry
            return (it < cfg.n_chunks) & (s["n_done"] < jobs_real)

        st, _ = jax.lax.while_loop(chunk_cond, chunk_body, (st, jnp.int32(0)))
        flush = jnp.where(st["rp_live"], st["rp_end"] - st["rp_start"], 0.0).sum()
        out = {
            "starts": st["starts"],
            "finishes": st["fins"],
            "worker_seconds": st["busy"] + flush,
            "cancelled_seconds_saved": st["saved"],
            "n_worker_failures": st["n_fail"],
            "n_replicas_rescued": st["n_resc"],
            "n_replans": jnp.int32(0),
        }
        if cfg.full_outputs:
            out["br"] = st["br"]
            out["epoch_times"] = st["ep_times"]
        return out

    return lane


def _wrap_stream_lane(lane, cfg: _RunnerCfg):
    """Fold a lane's per-job outputs into streaming accumulators on device.

    Runs *after* the untouched lane body, as a sequential ``lax.scan`` over
    the job axis in arrival order -- the exact fold order the host reference
    (:func:`repro.cluster.stream.epoch_stream_stats`) replays over a full
    report, which is what makes streaming equal materialized bit for bit
    (float64 lanes).  Jobs past the real count and jobs never finished
    (dead cluster) are masked out of the statistics; the latter are counted
    in ``n_unfinished`` and force ``fin_max`` to the sampled-churn check's
    conservative side via the unfinished flag.
    """
    from .vectorized import STREAM_HIST_BINS, STREAM_HIST_EDGES

    dt = jnp.dtype(cfg.dtype)
    edges = jnp.asarray(STREAM_HIST_EDGES, dt)

    def wrapped(*args):
        out = lane(*args)
        arrivals, jobs_real = args[7], args[10]
        starts = out.pop("starts")
        fins = out.pop("finishes")

        def fold(acc, inp):
            a, s, f, j = inp
            real = j < jobs_real
            m = real & jnp.isfinite(f)
            resp = f - a
            comp = f - s
            one = m.astype(jnp.int32)
            bins = jnp.searchsorted(edges, resp, side="right")
            # max(sq, 0) pins the square as a standalone IEEE multiply --
            # see the matching comment in vectorized._stream_slab
            resp2 = jnp.maximum(resp * resp, 0.0)
            return {
                "count": acc["count"] + one,
                "resp_sum": acc["resp_sum"] + jnp.where(m, resp, 0.0),
                "resp_sq": acc["resp_sq"] + jnp.where(m, resp2, 0.0),
                "resp_min": jnp.minimum(acc["resp_min"], jnp.where(m, resp, jnp.inf)),
                "resp_max": jnp.maximum(acc["resp_max"], jnp.where(m, resp, -jnp.inf)),
                "comp_sum": acc["comp_sum"] + jnp.where(m, comp, 0.0),
                "hist": acc["hist"].at[bins].add(one),
                "n_unfinished": acc["n_unfinished"] + (real & ~jnp.isfinite(f)).astype(jnp.int32),
                "fin_max": jnp.maximum(acc["fin_max"], jnp.where(m, f, -jnp.inf)),
            }, None

        zero = jnp.asarray(0.0, dt)
        acc0 = {
            "count": jnp.int32(0),
            "resp_sum": zero,
            "resp_sq": zero,
            "resp_min": jnp.asarray(jnp.inf, dt),
            "resp_max": jnp.asarray(-jnp.inf, dt),
            "comp_sum": zero,
            "hist": jnp.zeros(STREAM_HIST_BINS, jnp.int32),
            "n_unfinished": jnp.int32(0),
            "fin_max": jnp.asarray(-jnp.inf, dt),
        }
        acc, _ = jax.lax.scan(
            fold,
            acc0,
            (arrivals, starts, fins, jnp.arange(cfg.jobs_pad, dtype=jnp.int32)),
        )
        out.update(acc)
        return out

    return wrapped


def _get_runner(cfg: _RunnerCfg):
    if cfg in _RUNNERS:
        return _RUNNERS[cfg]
    lane = _build_space_lane(cfg) if cfg.scheduler is not None else _build_lane(cfg)
    if cfg.stream:
        lane = _wrap_stream_lane(lane, cfg)
    fn = jax.vmap(lane, in_axes=(0,) * 7 + (None,) * 9)
    if cfg.devices > 1:
        from jax.sharding import Mesh, PartitionSpec as P

        from ..distributed.compat import shard_map

        mesh = Mesh(np.array(jax.devices()[: cfg.devices]), ("lanes",))
        # check_vma=False: the early-exit while_loop has no replication rule,
        # and every lane is independent anyway (out_specs split the lane axis)
        fn = shard_map(
            fn,
            mesh=mesh,
            in_specs=(P("lanes"),) * 7 + (P(),) * 9,
            out_specs=P("lanes"),
            check_vma=False,
        )
    # donating the big per-lane buffers lets XLA reuse them for the loop
    # carry; CPU does not support donation (it would only warn), so gate it
    donate = () if jax.default_backend() == "cpu" else (0, 1, 2, 3, 4, 5, 6)
    runner = jax.jit(fn, donate_argnums=donate)
    _RUNNERS[cfg] = runner
    return runner


# --------------------------------------------------------------------------
# per-lane draw preparation (chunk- and shard-invariant seed derivation)
# --------------------------------------------------------------------------


def _sample_churn_np(rng, churn: ChurnProcess, n_workers: int, pairs: int):
    """One lane's alternating-renewal fail/join timeline, the engine's law.

    Also returns the lane's *horizon*: the earliest time any worker's
    sampled stream runs dry (its last of ``2 * pairs`` events).  Past the
    horizon the lane's workers stay up while the engine keeps churning, so
    a simulation that outruns it has silently left the engine's law --
    callers compare finish times against it and warn.  With
    ``mean_downtime == 0`` downtimes are infinite (failures are permanent),
    every stream ends at +inf, and the horizon is never reached.
    """
    ups = rng.exponential(1.0 / churn.fail_rate, (n_workers, pairs))
    if churn.mean_downtime > 0.0:
        downs = rng.exponential(churn.mean_downtime, (n_workers, pairs))
    else:
        downs = np.full((n_workers, pairs), np.inf)
    iv = np.stack([ups, downs], axis=-1).reshape(n_workers, 2 * pairs)
    t = np.cumsum(iv, axis=-1)  # fail at even positions, join at odd
    horizon = float(np.min(t[:, -1]))
    u = np.broadcast_to((np.arange(2 * pairs) % 2).astype(bool), t.shape).ravel()
    w = np.broadcast_to(np.arange(n_workers, dtype=np.int32)[:, None], t.shape).ravel()
    t = t.ravel()
    order = np.argsort(t, kind="stable")
    t, w, u = t[order], w[order], u[order]
    return t, np.where(np.isfinite(t), w, -1), u, horizon


def _pack_schedule(schedule: Optional[ChurnSchedule], n_lanes: int, ev_pad: int, dtype):
    """Shared explicit timeline (or the no-churn stream), inf-padded."""
    t = np.full(ev_pad, np.inf, np.float64)
    w = np.full(ev_pad, -1, np.int32)
    u = np.zeros(ev_pad, bool)
    if schedule is not None and len(schedule):
        t[: len(schedule)] = np.asarray(schedule.times, np.float64)
        w[: len(schedule)] = np.asarray(schedule.wids, np.int32)
        u[: len(schedule)] = np.asarray(schedule.ups, bool)
    tile = lambda a: jnp.broadcast_to(jnp.asarray(a), (n_lanes,) + a.shape)  # noqa: E731
    return tile(t.astype(dtype)), tile(w), tile(u)


def _prepare_lanes(dist, n_workers, n_pad, lane_idx, n_real, jobs_pad, ev_pad, resc_cap,
                   seed, churn, churn_schedule, pairs, dtype, spec_cap=0):
    """Per-lane inputs shared by both entry points: service draws, rescue
    draws, and the churn event stream.

    Host-side numpy on purpose: lane ``i`` draws from
    ``default_rng(SeedSequence((seed, i)))``, a pure function of the global
    lane index, so results are bit-identical under ``rep_chunk`` chunking,
    ``devices`` sharding, and shape-bucket padding -- and the cold path pays
    zero sampling compiles (the fastest jax program is the one never traced).

    Only the first ``n_real`` lanes carry results; bucket-padding lanes get
    constant durations (their outputs are sliced off, no need to sample).
    Rescue draws are sampled only when churn events can actually create
    rescues -- tau is drawn first per lane, so skipping them changes nothing.
    """
    n_lanes = len(lane_idx)
    seed = int(seed)
    sample_churn = churn is not None and churn.fail_rate > 0.0 and pairs > 0
    need_resc = sample_churn or (churn_schedule is not None and len(churn_schedule))
    tau = np.ones((n_lanes, jobs_pad, n_pad), dtype)
    tau_resc = np.ones((n_lanes, resc_cap, n_pad), dtype)
    tau_spec = np.ones((n_lanes, max(spec_cap, 1), n_pad), dtype)
    horizon = np.full(n_lanes, np.inf)
    if sample_churn:
        ev_t = np.full((n_lanes, ev_pad), np.inf, dtype)
        ev_w = np.full((n_lanes, ev_pad), -1, np.int32)
        ev_up = np.zeros((n_lanes, ev_pad), bool)
    for i, lane in enumerate(lane_idx[:n_real]):
        rng = np.random.default_rng(np.random.SeedSequence((seed, int(lane))))
        tau[i] = dist.sample_np(rng, (jobs_pad, n_pad))
        if need_resc:
            tau_resc[i] = dist.sample_np(rng, (resc_cap, n_pad))
        if spec_cap:
            tau_spec[i] = dist.sample_np(rng, (spec_cap, n_pad))
        if sample_churn:
            t, w, u, horizon[i] = _sample_churn_np(rng, churn, n_workers, pairs)
            k = min(len(t), ev_pad)
            ev_t[i, :k], ev_w[i, :k], ev_up[i, :k] = t[:k], w[:k], u[:k]
    if not sample_churn:
        ev_t, ev_w, ev_up = _pack_schedule(churn_schedule, n_lanes, ev_pad, dtype)
    else:
        ev_t, ev_w, ev_up = jnp.asarray(ev_t), jnp.asarray(ev_w), jnp.asarray(ev_up)
    return (
        jnp.asarray(tau), jnp.asarray(tau_resc), jnp.asarray(tau_spec),
        ev_t, ev_w, ev_up, horizon,
    )


def _shapes(n_workers, n_jobs, churn, churn_schedule, pairs, speculation=None):
    n_pad = _bucket_workers(n_workers)
    # per-job output arrays are scattered into every step: bucket them at a
    # finer granularity than power-of-two (32) to keep the carried elements
    # close to the real job count
    jobs_pad = _pow2(n_jobs) if n_jobs < 32 else -(-n_jobs // 32) * 32
    if churn is not None and churn.fail_rate > 0.0 and pairs > 0:
        ev_real = 2 * pairs * n_workers
    elif churn_schedule is not None:
        ev_real = len(churn_schedule)
    else:
        ev_real = 0
    ev_pad = _pow2(ev_real + 1)
    # rescue dispatches are bounded by worker failures, at most half the
    # event stream under the alternating fail/join law
    resc_cap = max(8, ev_pad // 2)
    # step budget: one step per job dispatch + one per churn event + a rescue
    # allowance, plus one trailing commit; overruns leave jobs at inf exactly
    # like the engine's max_events cap
    if speculation is not None:
        # event-granular commits consume one step per completion-time group
        # (at most one per batch plus straggler/rescue retirements) plus one
        # per backup launch and its (rare) 1-ulp re-arm
        mb = speculation.max_backups
        budget = jobs_pad * (n_pad + 1 + 2 * mb) + ev_pad + 2 * resc_cap + 2
    else:
        budget = jobs_pad + ev_pad + resc_cap + 2
    n_chunks = -(-budget // _STEP_CHUNK)
    return n_pad, jobs_pad, ev_pad, resc_cap, n_chunks


def _run_lanes(dist, cfg, n_workers, lane_idx, b0, arrivals_pad, n_jobs_real, seed,
               speeds, churn, churn_schedule, pairs, n_tasks, replan, space_tabs=None):
    """Pad the lane batch to its bucket, run the compiled runner, unpad.

    ``space_tabs`` carries the space-sharing lane's per-job plan tables
    ``(req_tab, b_tab, cancel_tab, default_req)``; the legacy lane instead
    receives the replanner's blend/divisor/harmonic tables.  Both variants
    take 15 arguments with the same batched/broadcast split, so one vmap /
    shard_map wrapper serves either.
    """
    lanes = len(lane_idx)
    lanes_pad = _pow2(lanes)
    if cfg.devices > 1 and lanes_pad % cfg.devices:
        lanes_pad = -(-lanes_pad // cfg.devices) * cfg.devices
    idx = np.concatenate([lane_idx, np.arange(lanes_pad - lanes) + (1 << 30)])
    b0 = np.concatenate([b0, np.zeros(lanes_pad - lanes, np.int32)])
    dtype = jnp.dtype(cfg.dtype)
    spec_cap = cfg.jobs_pad * cfg.spec.max_backups if cfg.spec is not None else 0
    tau, tau_resc, tau_spec, ev_t, ev_w, ev_up, horizon = _prepare_lanes(
        dist, n_workers, cfg.n, idx, lanes, cfg.jobs_pad, cfg.ev_pad, cfg.resc_cap,
        seed, churn, churn_schedule, pairs, dtype, spec_cap=spec_cap,
    )
    if cfg.scheduler is not None:
        req_tab, b_tab, cancel_tab, default_req = space_tabs
        tail = (
            jnp.asarray(req_tab, jnp.int32),
            jnp.asarray(b_tab, jnp.int32),
            jnp.asarray(cancel_tab, bool),
            jnp.int32(default_req),
        )
    else:
        div_tab, (h1, h2) = divisor_table(n_workers), harmonic_tables(n_workers)
        div_pad = np.zeros((cfg.n + 1, _pow2(div_tab.shape[1])), div_tab.dtype)
        div_pad[: div_tab.shape[0], : div_tab.shape[1]] = div_tab
        h_pad = np.zeros(cfg.n + 1)
        hp1, hp2 = h_pad.copy(), h_pad.copy()
        hp1[: len(h1)], hp2[: len(h2)] = h1, h2
        tail = (
            jnp.asarray(replan.blend if replan is not None else 0.5, dtype),
            jnp.asarray(div_pad),
            jnp.asarray(hp1, dtype),
            jnp.asarray(hp2, dtype),
        )
    runner = _get_runner(cfg)
    out = runner(
        tau,
        tau_resc,
        tau_spec,
        ev_t,
        ev_w,
        ev_up,
        jnp.asarray(b0, jnp.int32),
        jnp.asarray(arrivals_pad, dtype),
        jnp.asarray(speeds, dtype),
        jnp.int32(n_workers),
        jnp.int32(n_jobs_real),
        jnp.asarray(n_tasks, dtype),
        *tail,
    )
    res = {k: np.asarray(v)[:lanes] for k, v in out.items()}
    res["churn_horizon"] = horizon[:lanes]  # host-side, inf unless churn sampled
    return res


# --------------------------------------------------------------------------
# public entry points
# --------------------------------------------------------------------------


# float32 resolves consecutive integers only up to 2^24; past half that, a
# single ulp of an absolute timestamp already approaches one second, and
# sub-second queue waits / service times start quantizing away.
_F32_SAFE_TIME = float(2**23)


def _check_arrival_span(arrivals, dtype):
    """Refuse f32 lanes whose absolute arrivals exceed the f32-safe range.

    Unlike the gang kernel in :mod:`repro.cluster.vectorized` (whose scan
    carries only backlog-sized slack and rebuilds absolute times in
    float64), the epoch-scan lanes -- the space-delegated lane in
    particular -- carry *absolute* event times in the lane dtype.  Under
    float32 an arrival near 1e7 s has a ulp around 1 s, so statistics come
    back subtly wrong with no error.  Fail loudly and name the fix instead.
    """
    if dtype != "float32":
        return  # float64 is safe; invalid dtypes get the validation error
    finite = arrivals[np.isfinite(arrivals)]
    span = float(np.abs(finite).max()) if finite.size else 0.0
    if span > _F32_SAFE_TIME:
        raise ValueError(
            f"arrival magnitude {span:.6g} s exceeds the float32-safe range "
            f"(~{_F32_SAFE_TIME:.3g} s): the scan lanes carry absolute times "
            "in the lane dtype, and float32 ulps this large silently quantize "
            'queue waits and service times.  Pass dtype="float64" (requires '
            "jax x64) or rebase arrivals near zero."
        )


def _validate_common(n_workers, sc):
    """Scenario validation + the jax-environment checks, returning the
    bucket-padded speed vector.

    The cross-field rules live in :meth:`repro.cluster.scenario.Scenario.validate`
    (the single validation path shared with the engine and the planner); only
    the process-environment checks -- x64 enabled, visible device count --
    stay here, because they are properties of the jax runtime, not of the
    scenario.
    """
    sc.validate(n_workers=n_workers, backend="jax")
    if sc.dtype == "float64" and not jax.config.jax_enable_x64:
        raise ValueError(
            "dtype='float64' needs jax x64 enabled (jax.config.update('jax_enable_x64', True))"
        )
    if sc.devices > len(jax.devices()):
        raise ValueError(f"devices={sc.devices} but only {len(jax.devices())} jax devices visible")
    speeds = np.ones(n_workers) if sc.speeds is None else np.asarray(sc.speeds, np.float64)
    pad = _bucket_workers(n_workers) - n_workers
    return np.concatenate([speeds, np.ones(pad)])


def _space_tabs(scheduler, workers_per_job, job_plans, n_jobs, jobs_pad, n_workers,
                cancel_default, replan):
    """Resolve space-sharing routing and build the per-job plan tables.

    Returns ``(scheduler_name_or_None, tabs)``: ``None`` means the legacy
    single-gang lane (scheduler ``fifo_gang`` with no per-job plans -- the
    bit-compatible fast path); otherwise the space lane runs with
    ``tabs = (req_tab, b_tab, cancel_tab, default_req)``, zero meaning
    "inherit the engine-wide default" exactly like
    :class:`~repro.cluster.scheduler.JobPlan`'s None fields.
    """
    if scheduler is None:
        scheduler = "fifo_gang"
    if not is_space(scheduler, workers_per_job, job_plans):
        return None, None
    # scheduler / workers_per_job / job_plans / replan-exclusion constraints
    # were already checked by Scenario.validate() (the single validation
    # path) in the public entry points above
    req_tab = np.zeros(jobs_pad, np.int32)
    b_tab = np.zeros(jobs_pad, np.int32)
    cancel_tab = np.full(jobs_pad, bool(cancel_default))
    if job_plans is not None:
        plans = list(job_plans)
        for q in range(n_jobs):
            p = plans[q % len(plans)]
            if p is None:
                continue
            if p.workers is not None:
                req_tab[q] = min(int(p.workers), n_workers)
            if p.n_batches is not None:
                b_tab[q] = int(p.n_batches)
            if p.cancel_redundant is not None:
                cancel_tab[q] = bool(p.cancel_redundant)
    if scheduler == "fifo_gang":
        req_tab[:] = 0  # the gang regime ignores worker requests, like the engine
        default_req = 0
    else:
        default_req = int(workers_per_job) if workers_per_job is not None else 0
    return scheduler, (req_tab, b_tab, cancel_tab, default_req)


def _resolve_churn_pairs(pairs, dist, churn, n_workers, n_batches, n_tasks,
                         size_dependent, speeds, arrivals, n_jobs):
    """Resolve ``churn_pairs_per_worker`` (None = auto-size from the stream).

    The engine's alternating-renewal churn runs forever; the scan lanes
    sample a finite stream of fail/join pairs per worker, after which that
    worker stays up -- so a horizon shorter than the simulated timeline
    silently leaves the engine's law.  Auto-sizing estimates the timeline
    (arrival span plus a serial-gang bound on total service: jobs x mean
    batch duration at the slowest speed) and draws enough pairs to cover
    twice that, floored at the historical default of 8 and capped at 1024
    to bound the event-step budget -- the post-run truncation check warns
    loudly if even the cap fell short.  An explicit integer is honoured
    bit-for-bit (pair count determines the lanes' draw shapes).
    """
    if pairs is not None:
        return int(pairs)
    if churn is None or churn.fail_rate <= 0.0:
        return 8  # no sampled churn: the horizon is never consulted
    # mean service estimate from a fixed-seed host draw: it only sizes an
    # integer, so it must not perturb (or depend on) the caller's seed
    rng = np.random.default_rng(np.random.SeedSequence((0x5A11, 0)))
    mean_tau = float(np.mean(dist.sample_np(rng, (256,))))
    b = int(n_batches) if n_batches else n_workers
    scale = (float(n_tasks) / b) if size_dependent else 1.0
    slow = float(np.min(speeds)) if len(speeds) else 1.0
    span = float(arrivals[-1] - arrivals[0]) if arrivals is not None and len(arrivals) else 0.0
    t_est = span + n_jobs * mean_tau * scale / max(slow, 1e-12)
    period = 1.0 / churn.fail_rate + churn.mean_downtime
    pairs = math.ceil(2.0 * t_est / max(period, 1e-12)) + 4
    return max(8, min(int(pairs), 1024))


def _warn_churn_truncated(truncated, pairs):
    n_hit, n_reps = int(np.sum(truncated)), len(truncated)
    warnings.warn(
        f"sampled churn horizon ended before the simulated timeline in "
        f"{n_hit}/{n_reps} rep(s): past the horizon the lanes' workers stay "
        "up while the Python engine keeps churning, so results diverge from "
        f"the engine's law.  Raise churn_pairs_per_worker (resolved to "
        f"{pairs}; None auto-sizes from the stream) or pass an explicit "
        "churn_schedule, which both backends replay identically.",
        RuntimeWarning,
        stacklevel=3,
    )


def _rep_slices(total: int, rep_chunk: Optional[int]):
    if rep_chunk is None or rep_chunk >= total:
        return [(0, total)]
    if rep_chunk < 1:
        raise ValueError("rep_chunk must be >= 1")
    return [(lo, min(lo + rep_chunk, total)) for lo in range(0, total, rep_chunk)]


def simulate_epochs(
    dist: Optional[ServiceTime] = None,
    n_workers: Optional[int] = None,
    n_batches: Optional[int] = None,
    arrivals=None,
    n_reps: Optional[int] = None,
    *,
    seed: int = 0,
    cancel_redundant=UNSET,
    size_dependent=UNSET,
    n_tasks=UNSET,
    speeds=UNSET,
    churn=UNSET,
    churn_schedule=UNSET,
    churn_pairs_per_worker=UNSET,
    replan=UNSET,
    speculation=UNSET,
    scheduler=UNSET,
    workers_per_job=UNSET,
    job_plans=UNSET,
    dtype=UNSET,
    rep_chunk=UNSET,
    devices=UNSET,
    outputs=UNSET,
    scenario: Optional["Scenario"] = None,
) -> EpochReport:
    """Replay the full engine semantics on the jax epoch scan.

    Statistically identical to ``ClusterEngine(n_workers, n_batches=...,
    cancel_redundant=..., speeds=..., churn=..., controller=...)`` run on the
    same arrival vector (the differential suite in ``tests/test_epoch_scan.py``
    enforces this at 3 sigma, and bit-comparably on shared
    ``churn_schedule`` + degenerate service times).  ``n_batches=None`` means
    full parallelism (B = alive workers at dispatch), like the engine.

    ``scheduler`` / ``workers_per_job`` / ``job_plans`` mirror the engine's
    space-sharing knobs: under ``"packed"`` or ``"balanced"`` jobs run
    concurrently on disjoint worker subsets, each under its own
    :class:`~repro.cluster.scheduler.JobPlan` (``job_plans`` cycles over the
    arrival vector; unset fields inherit ``n_batches`` /
    ``cancel_redundant`` / ``workers_per_job``).  The default ``fifo_gang``
    with no per-job plans keeps the legacy single-gang lane bit-compatibly;
    ``fifo_gang`` *with* per-job plans runs the space lane in gang mode
    (whole-cluster dispatch, per-job B and cancellation).  ``replan`` is
    mutually exclusive with space sharing.

    ``speculation=Speculation(...)`` enables reactive backup replicas on the
    gang lane: completed sibling-batch durations feed a running lower
    median, and a batch whose youngest live replica lags past ``theta x``
    that median earns one backup at the next heartbeat epoch (one launch per
    epoch, capped at ``max_backups`` per job) -- the exact trigger
    :class:`~repro.cluster.master.ClusterEngine` fires, computed with the
    same float expressions so the differential tests demand bit-equality on
    shared schedules.  One live backup per batch: a batch whose backup is
    still running is not re-eligible until it resolves (the engine's
    youngest-replica rule differs only when the backup itself lags past the
    trigger).  Mutually exclusive with ``replan`` and, on this backend, with
    space sharing.

    Each Monte-Carlo rep derives every draw (replica durations, rescue draws,
    and -- when ``churn`` is given -- its own fail/join timeline of
    ``churn_pairs_per_worker`` up/down pairs per worker, after which that
    worker stays up) from ``default_rng(SeedSequence((seed, rep)))``, so results are
    bit-identical under ``rep_chunk`` chunking (bounding device memory for
    rep budgets in the hundreds-to-thousands) and under multi-device
    ``devices`` sharding.  ``churn_pairs_per_worker=None`` (the default)
    auto-sizes the sampled-churn horizon from the stream length; a rep whose
    timeline still outruns its horizon triggers a loud ``RuntimeWarning``
    and is flagged in ``EpochReport.churn_truncated``.  ``dtype="float64"``
    runs the scan lanes in double precision for long-horizon workloads
    (requires jax x64).

    ``outputs="stream"`` (``Scenario.outputs``) folds the per-job records
    into streaming accumulators on device and returns an
    :class:`EpochStreamReport` instead -- O(n_reps) memory for trace-scale
    job counts.  The lane internals and the draw pipeline are identical in
    both modes, so on float64 lanes the streamed statistics equal the host
    fold of the ``outputs="full"`` report bit for bit (the property
    ``tests/test_stream.py`` enforces); the default ``"full"`` path is
    untouched.

    The scenario knobs (dynamics, space sharing, scale) are best passed as
    one validated ``scenario=Scenario(...)``; the loose keyword forms keep
    working behind a :class:`DeprecationWarning` shim.
    """
    sc = resolve_scenario(
        scenario,
        {
            "cancel_redundant": cancel_redundant,
            "size_dependent": size_dependent,
            "n_tasks": n_tasks,
            "speeds": speeds,
            "churn": churn,
            "churn_schedule": churn_schedule,
            "churn_pairs_per_worker": churn_pairs_per_worker,
            "replan": replan,
            "speculation": speculation,
            "scheduler": scheduler,
            "workers_per_job": workers_per_job,
            "job_plans": job_plans,
            "dtype": dtype,
            "rep_chunk": rep_chunk,
            "devices": devices,
            "outputs": outputs,
        },
        where="simulate_epochs",
    )
    dist = dist if dist is not None else sc.dist
    n_workers = int(n_workers if n_workers is not None else sc.n_workers)
    n_batches = n_batches if n_batches is not None else sc.n_batches
    if dist is None or arrivals is None or n_reps is None:
        raise ValueError("simulate_epochs needs dist (or scenario.dist), arrivals, and n_reps")
    arrivals = np.asarray(arrivals, dtype=np.float64)
    if arrivals.ndim != 1 or arrivals.size == 0:
        raise ValueError("arrivals must be a non-empty 1-D array")
    if (np.diff(arrivals) < 0).any():
        raise ValueError("arrivals must be sorted (FIFO order)")
    _check_arrival_span(arrivals, sc.dtype)
    if n_batches is not None and not (1 <= int(n_batches) <= n_workers):
        raise ValueError(f"n_batches must lie in [1, {n_workers}] or be None")
    speeds = _validate_common(n_workers, sc)
    cancel_redundant = sc.cancel_redundant
    size_dependent = sc.size_dependent
    churn = sc.churn
    churn_schedule = sc.churn_schedule
    churn_pairs_per_worker = sc.churn_pairs_per_worker
    replan = sc.replan
    speculation = sc.speculation
    scheduler = sc.scheduler_name
    workers_per_job = sc.workers_per_job
    job_plans = sc.job_plans
    dtype = sc.dtype
    rep_chunk = sc.rep_chunk
    devices = sc.devices
    n_tasks = sc.n_tasks if sc.n_tasks is not None else n_workers
    n_jobs = arrivals.size
    churn_pairs_per_worker = _resolve_churn_pairs(
        churn_pairs_per_worker, dist, churn, n_workers, n_batches, n_tasks,
        size_dependent, speeds, arrivals, n_jobs,
    )
    n_pad, jobs_pad, ev_pad, resc_cap, n_chunks = _shapes(
        n_workers, n_jobs, churn, churn_schedule, churn_pairs_per_worker,
        speculation=speculation,
    )
    sched_name, tabs = _space_tabs(
        scheduler, workers_per_job, job_plans, n_jobs, jobs_pad, n_workers,
        cancel_redundant, replan,
    )
    stream_mode = sc.outputs == "stream"
    cfg = _RunnerCfg(
        n_pad, jobs_pad, ev_pad, resc_cap, n_chunks,
        bool(cancel_redundant), bool(size_dependent), replan, dtype, int(devices),
        full_outputs=not stream_mode,
        stream=stream_mode,
        scheduler=sched_name,
        spec=speculation,
    )
    arrivals_pad = np.concatenate([arrivals, np.full(jobs_pad - n_jobs, np.inf)])
    b0_val = 0 if n_batches is None else int(n_batches)
    chunks = []
    for lo, hi in _rep_slices(int(n_reps), rep_chunk):
        chunks.append(
            _run_lanes(
                dist, cfg, n_workers, np.arange(lo, hi), np.full(hi - lo, b0_val, np.int32),
                arrivals_pad, n_jobs, seed, speeds, churn, churn_schedule,
                churn_pairs_per_worker, n_tasks, replan, space_tabs=tabs,
            )
        )
    out = {k: np.concatenate([c[k] for c in chunks], axis=0) for k in chunks[0]}
    sampled = churn is not None and churn.fail_rate > 0.0
    if stream_mode:
        from .stream import StreamStats

        n_unfinished = np.asarray(out["n_unfinished"])
        truncated = None
        if sampled:
            # unfinished jobs have no finish stamp: count them as outrunning
            # the horizon, exactly like the full path's inf finishes do
            truncated = (np.asarray(out["fin_max"], np.float64) > out["churn_horizon"]) | (
                n_unfinished > 0
            )
            if truncated.any():
                _warn_churn_truncated(truncated, churn_pairs_per_worker)
        stats = StreamStats(
            count=np.asarray(out["count"]),
            resp_sum=np.asarray(out["resp_sum"]),
            resp_sq=np.asarray(out["resp_sq"]),
            resp_min=np.asarray(out["resp_min"]),
            resp_max=np.asarray(out["resp_max"]),
            comp_sum=np.asarray(out["comp_sum"]),
            busy_sum=np.asarray(out["worker_seconds"]),
            saved_sum=np.asarray(out["cancelled_seconds_saved"]),
            hist=np.asarray(out["hist"]),
        )
        return EpochStreamReport(
            arrivals=arrivals,
            stats=stats,
            n_unfinished=n_unfinished,
            worker_seconds=np.asarray(out["worker_seconds"], np.float64),
            cancelled_seconds_saved=np.asarray(out["cancelled_seconds_saved"], np.float64),
            n_worker_failures=np.asarray(out["n_worker_failures"]),
            n_replicas_rescued=np.asarray(out["n_replicas_rescued"]),
            n_replans=np.asarray(out["n_replans"]),
            n_speculative=(
                np.asarray(out["n_speculative"]) if "n_speculative" in out else None
            ),
            churn_truncated=truncated,
        )
    br = np.asarray(out["br"])[:, :n_jobs]
    finishes = np.asarray(out["finishes"], np.float64)[:, :n_jobs]
    truncated = None
    if sampled:
        # a rep whose timeline outran its sampled horizon ran its tail
        # churn-free (unfinished jobs at inf count as outrunning it)
        truncated = finishes.max(axis=1) > out["churn_horizon"]
        if truncated.any():
            _warn_churn_truncated(truncated, churn_pairs_per_worker)
    return EpochReport(
        arrivals=arrivals,
        starts=np.asarray(out["starts"], np.float64)[:, :n_jobs],
        finishes=finishes,
        n_batches_used=br >> 16,
        replication_used=br & 0xFFFF,
        worker_seconds=np.asarray(out["worker_seconds"], np.float64),
        cancelled_seconds_saved=np.asarray(out["cancelled_seconds_saved"], np.float64),
        n_worker_failures=np.asarray(out["n_worker_failures"]),
        n_replicas_rescued=np.asarray(out["n_replicas_rescued"]),
        n_replans=np.asarray(out["n_replans"]),
        epoch_times=np.asarray(out["epoch_times"], np.float64),
        n_speculative=(
            np.asarray(out["n_speculative"]) if "n_speculative" in out else None
        ),
        churn_truncated=truncated,
    )


def frontier_job_times_dynamic(
    dist: Optional[ServiceTime] = None,
    n_workers: Optional[int] = None,
    candidates=None,
    n_reps: Optional[int] = None,
    *,
    seed: int = 0,
    n_jobs: Optional[int] = None,
    cancel_redundant=UNSET,
    size_dependent=UNSET,
    n_tasks=UNSET,
    speeds=UNSET,
    churn=UNSET,
    churn_schedule=UNSET,
    churn_pairs_per_worker=UNSET,
    replan=UNSET,
    speculation=UNSET,
    scheduler=UNSET,
    workers_per_job=UNSET,
    job_plans=UNSET,
    dtype=UNSET,
    rep_chunk=UNSET,
    devices=UNSET,
    scenario: Optional["Scenario"] = None,
) -> np.ndarray:
    """Per-candidate job compute times under churn/hetero/replan dynamics.

    ``scheduler`` / ``workers_per_job`` / ``job_plans`` score the candidates
    under space sharing: each stream's jobs run concurrently on disjoint
    worker subsets, the candidate B filling the plan of every job whose
    :class:`~repro.cluster.scheduler.JobPlan` leaves ``n_batches`` unset --
    so a frontier can be swept for one job class while competing classes
    hold fixed heterogeneous plans.

    The dynamic sibling of :func:`repro.cluster.vectorized.frontier_job_times`
    and the workhorse behind ``plan_cluster(backend="jax")`` on dynamic
    scenarios: every candidate B runs serial job streams of ``n_jobs`` jobs
    (matching the Python engine's ``sample_job_times`` structure -- under
    churn, consecutive jobs share a timeline, so samples come in correlated
    streams) across ``ceil(n_reps / n_jobs)`` independent reps.  Returns
    ``(len(candidates), >= n_reps)`` compute times; unfinished jobs are inf
    (callers filter, like ``planner._frontier_stats``).

    ``rep_chunk`` bounds device memory by scoring at most that many streams
    per candidate per device call; ``devices`` shards the (candidate x
    stream) lane grid via ``shard_map``.  Both are bit-identical to the
    single-call single-device result (per-lane ``SeedSequence`` derivation).

    ``Scenario.outputs`` is accepted and ignored: this path *is* the
    planner's per-job-times source, so it always runs the reduced-output
    lanes (no per-event/per-plan buffers) and never the streaming fold.
    """
    sc = resolve_scenario(
        scenario,
        {
            "cancel_redundant": cancel_redundant,
            "size_dependent": size_dependent,
            "n_tasks": n_tasks,
            "speeds": speeds,
            "churn": churn,
            "churn_schedule": churn_schedule,
            "churn_pairs_per_worker": churn_pairs_per_worker,
            "replan": replan,
            "speculation": speculation,
            "scheduler": scheduler,
            "workers_per_job": workers_per_job,
            "job_plans": job_plans,
            "dtype": dtype,
            "rep_chunk": rep_chunk,
            "devices": devices,
        },
        where="frontier_job_times_dynamic",
    )
    dist = dist if dist is not None else sc.dist
    n_workers = int(n_workers if n_workers is not None else sc.n_workers)
    if dist is None or candidates is None or n_reps is None:
        raise ValueError(
            "frontier_job_times_dynamic needs dist (or scenario.dist), candidates, and n_reps"
        )
    bs = np.asarray(list(candidates), dtype=np.int32)
    if bs.size == 0:
        raise ValueError("need at least one candidate B")
    if (bs < 1).any() or (bs > n_workers).any():
        raise ValueError(f"candidates must lie in [1, {n_workers}], got {bs.tolist()}")
    speeds = _validate_common(n_workers, sc)
    cancel_redundant = sc.cancel_redundant
    size_dependent = sc.size_dependent
    churn = sc.churn
    churn_schedule = sc.churn_schedule
    churn_pairs_per_worker = sc.churn_pairs_per_worker
    replan = sc.replan
    speculation = sc.speculation
    scheduler = sc.scheduler_name
    workers_per_job = sc.workers_per_job
    job_plans = sc.job_plans
    dtype = sc.dtype
    rep_chunk = sc.rep_chunk
    devices = sc.devices
    n_tasks = sc.n_tasks if sc.n_tasks is not None else n_workers
    n_jobs = sc.jobs_per_stream if n_jobs is None else n_jobs
    n_jobs = max(1, min(int(n_jobs), int(n_reps)))
    s = math.ceil(n_reps / n_jobs)
    c = len(bs)
    # auto-size against the widest-scale candidate (smallest B): its jobs
    # run longest, so its streams are the ones that outlive short horizons
    churn_pairs_per_worker = _resolve_churn_pairs(
        churn_pairs_per_worker, dist, churn, n_workers, int(bs.min()), n_tasks,
        size_dependent, speeds, None, n_jobs,
    )
    n_pad, jobs_pad, ev_pad, resc_cap, n_chunks = _shapes(
        n_workers, n_jobs, churn, churn_schedule, churn_pairs_per_worker,
        speculation=speculation,
    )
    sched_name, tabs = _space_tabs(
        scheduler, workers_per_job, job_plans, n_jobs, jobs_pad, n_workers,
        cancel_redundant, replan,
    )
    cfg = _RunnerCfg(
        n_pad, jobs_pad, ev_pad, resc_cap, n_chunks,
        bool(cancel_redundant), bool(size_dependent), replan, dtype, int(devices),
        full_outputs=False,  # planning reads starts/finishes only
        scheduler=sched_name,
        spec=speculation,
    )
    arrivals_pad = np.concatenate([np.zeros(n_jobs), np.full(jobs_pad - n_jobs, np.inf)])
    chunks = []
    trunc = np.zeros(0, bool)
    for lo, hi in _rep_slices(s, rep_chunk):
        # lane (ci, rep) has global index ci * s + rep: chunking over reps
        # keeps every lane's SeedSequence identity, hence its draws, unchanged
        lane_idx = (np.arange(c)[:, None] * s + np.arange(lo, hi)[None, :]).ravel()
        b0 = np.repeat(bs, hi - lo)
        out = _run_lanes(
            dist, cfg, n_workers, lane_idx, b0, arrivals_pad, n_jobs, seed,
            speeds, churn, churn_schedule, churn_pairs_per_worker, n_tasks, replan,
            space_tabs=tabs,
        )
        fin = np.asarray(out["finishes"], np.float64)
        start = np.asarray(out["starts"], np.float64)
        if churn is not None and churn.fail_rate > 0.0:
            trunc = np.append(trunc, fin[:, :n_jobs].max(axis=1) > out["churn_horizon"])
        # unfinished jobs (inf start and finish) score inf, not inf - inf
        with np.errstate(invalid="ignore"):
            t = np.where(np.isfinite(fin), fin - start, np.inf)
        chunks.append(t[:, :n_jobs].reshape(c, (hi - lo) * n_jobs))
    if trunc.any():
        _warn_churn_truncated(trunc, churn_pairs_per_worker)
    return np.concatenate(chunks, axis=1)
