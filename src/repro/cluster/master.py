"""Master: job queue, batch dispatch, earliest-cover completion, cancellation.

:class:`ClusterEngine` executes :class:`~repro.core.planner.RedundancyPlan`
operating points instead of merely evaluating them.  Per job the master
splits the job's N tasks into B balanced non-overlapping batches, assigns
each batch to r = n_alive // B workers (the paper's optimal scheme), and
declares the job complete at the earliest time the union of finished batch
replicas covers all tasks -- ``T = max_B min_r T_ij``, the §VI job time.

Beyond the closed forms, the engine expresses the dynamics the analysis
cannot: FIFO multi-job queueing (jobs gang-schedule onto the whole cluster),
cancellation of outstanding sibling replicas the moment a batch first
completes (reclaiming wasted worker-seconds), worker fail/join churn with
replica rescue, heterogeneous worker speeds, and mid-stream replanning via
an :class:`~repro.cluster.control.OnlineReplanner`.

Scheduling is pluggable (:mod:`repro.cluster.scheduler`): the default
``fifo_gang`` policy keeps the legacy whole-cluster gang bit-compatibly,
while the space-sharing policies (``packed`` first-fit, ``balanced``
least-loaded) run jobs concurrently on disjoint worker subsets of
``workers_per_job`` workers, each job under its *own* redundancy plan --
per-job B, r, and cancellation mode via :class:`~repro.cluster.scheduler.JobPlan`.

With a single job, homogeneous workers, no churn, and no queueing the engine
is statistically identical to ``core.simulator.simulate_balanced`` -- a
property the test suite enforces.
"""
from __future__ import annotations

import collections
import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Set

import numpy as np

from ..core.service_time import Empirical, ServiceTime
from ..core.simulator import JobTimeStats, stats_from_samples
from . import events as ev
from .control import OnlineReplanner, SpeculativePolicy
from .scenario import UNSET, Retry, Scenario, Speculation, resolve_scenario
from .scheduler import JobPlan, Scheduler, make_scheduler
from .workers import ChurnProcess, ChurnSchedule, Worker, WorkerPool, draw_batch_time

__all__ = [
    "Job",
    "JobRecord",
    "EngineReport",
    "ClusterEngine",
    "sample_job_times",
    "jobs_from_traces",
]


@dataclasses.dataclass(frozen=True)
class Job:
    """One job: N tasks whose service times follow ``dist``.

    ``plan`` optionally overrides the engine-wide worker request, batch
    count, and cancellation mode for this job alone (see
    :class:`~repro.cluster.scheduler.JobPlan`) -- meaningful under a
    space-sharing scheduler, where concurrent jobs run heterogeneous plans.
    """

    job_id: int
    dist: ServiceTime
    n_tasks: int
    arrival: float = 0.0
    name: str = ""
    plan: Optional[JobPlan] = None


@dataclasses.dataclass(frozen=True)
class JobRecord:
    """Execution outcome of one job (finish = inf if it never completed)."""

    job_id: int
    name: str
    arrival: float
    start: float
    finish: float
    n_batches: int
    replication: int

    @property
    def compute_time(self) -> float:
        """Finish minus start: time the job spent executing."""
        return self.finish - self.start

    @property
    def response_time(self) -> float:
        """Finish minus arrival: queueing delay plus compute."""
        return self.finish - self.arrival

    @property
    def queue_wait(self) -> float:
        """Start minus arrival: time spent waiting for workers."""
        return self.start - self.arrival


@dataclasses.dataclass
class EngineReport:
    """Aggregate outcome of one engine run.

    ``epoch_times`` are the applied churn-event times, i.e. the boundaries of
    the run's churn epochs (the intervals on which the alive set is constant).
    The jax epoch-scan backend (:mod:`repro.cluster.epoch_scan`) reports the
    same fields per Monte-Carlo rep; :meth:`accounting` is the shared,
    directly comparable summary the differential tests key on.
    """

    records: List[JobRecord]
    worker_seconds: float  # total busy time actually burned
    cancelled_seconds_saved: float  # scheduled-but-reclaimed replica time
    n_events: int
    n_worker_failures: int
    n_replicas_rescued: int
    n_replans: int
    final_n_batches: int
    epoch_times: tuple = ()  # applied churn-event times (epoch boundaries)
    n_speculative: int = 0  # reactive backup replicas launched
    n_task_failures: int = 0  # replicas whose payload raised (vs the worker dying)
    n_retries: int = 0  # failed replicas re-dispatched after backoff

    @property
    def compute_times(self) -> np.ndarray:
        """Compute time per completed job, record order."""
        return np.array([r.compute_time for r in self.records])

    @property
    def response_times(self) -> np.ndarray:
        """Response time per completed job, record order."""
        return np.array([r.response_time for r in self.records])

    @property
    def n_epochs(self) -> int:
        """Number of dispatch epochs the run went through."""
        return len(self.epoch_times) + 1

    def accounting(self) -> dict:
        """The invariant-bearing counters, keyed identically on both backends."""
        return {
            "worker_seconds": float(self.worker_seconds),
            "cancelled_seconds_saved": float(self.cancelled_seconds_saved),
            "n_worker_failures": int(self.n_worker_failures),
            "n_replicas_rescued": int(self.n_replicas_rescued),
            "n_replans": int(self.n_replans),
            "n_speculative": int(self.n_speculative),
            "n_task_failures": int(self.n_task_failures),
            "n_retries": int(self.n_retries),
        }

    def stats(self) -> JobTimeStats:
        """Summary statistics over the finite compute times."""
        t = self.compute_times
        t = t[np.isfinite(t)]
        return stats_from_samples(t) if t.size else JobTimeStats.empty()


@dataclasses.dataclass
class _JobExec:
    """Mutable per-job execution state while the job is on the cluster."""

    job: Job
    start: float
    n_batches: int
    replication: int
    # per-job cancellation mode (JobPlan override or the engine default)
    cancel: bool = False
    # wids allocated to this job under a space-sharing scheduler; None means
    # the whole cluster (fifo_gang), so joins serve the active gang's rescues
    alloc: Optional[Set[int]] = None
    done: Set[int] = dataclasses.field(default_factory=set)
    # batch -> wids with an in-flight replica of that batch
    outstanding: Dict[int, Set[int]] = dataclasses.field(default_factory=dict)
    # completed sibling batch durations, in completion order: the running
    # observations the speculative policy takes its median over
    obs: List[float] = dataclasses.field(default_factory=list)
    # speculative backups launched for this job (capped by the policy)
    spec_used: int = 0

    @property
    def batch_tasks(self) -> float:
        return self.job.n_tasks / self.n_batches

    @property
    def complete(self) -> bool:
        return len(self.done) == self.n_batches


class ClusterEngine:
    """Event-driven master-worker cluster executing redundancy plans.

    Parameters
    ----------
    n_workers:
        Initial cluster size.
    seed:
        Root seed; every stochastic stream (service draws, churn, arrivals)
        derives from it, so runs replay exactly.
    n_batches:
        Static plan: split every job into this many batches (clamped to the
        alive-worker count at dispatch).  ``None`` means full parallelism
        (B = alive workers) unless a controller supplies a plan.
    cancel_redundant:
        Cancel a batch's outstanding sibling replicas the moment its first
        replica finishes, reclaiming their remaining worker-seconds.
    size_dependent:
        §VI size model (batch time = (N/B) tau) vs §IV batch-level model.
    speeds:
        Optional per-worker speed factors (heterogeneous cluster).
    churn:
        Optional fail/join process applied independently to every worker.
    churn_schedule:
        Optional explicit fail/join timeline (:class:`ChurnSchedule`) replayed
        verbatim instead of sampling ``churn`` online -- the shared-epoch mode
        the differential tests run both backends on.  Mutually exclusive with
        ``churn``.
    controller:
        Optional :class:`OnlineReplanner`; fed observed task times, asked to
        replan after each job completes, and consulted at dispatch.
    scheduler:
        Placement policy name (``"fifo_gang"`` | ``"packed"`` |
        ``"balanced"``) or a :class:`~repro.cluster.scheduler.Scheduler`
        instance.  The default keeps the legacy whole-cluster FIFO gang
        bit-compatibly; the space-sharing policies run queued jobs
        concurrently on disjoint worker subsets.
    workers_per_job:
        Engine-wide worker request per job under a space-sharing scheduler
        (``Job.plan.workers`` overrides it per job).  ``None`` means every
        job requests the whole alive set, which degenerates packed/balanced
        placement to gang-like serial execution.
    """

    def __init__(
        self,
        n_workers: int,
        *,
        seed: int = 0,
        n_batches: Optional[int] = None,
        cancel_redundant: bool = False,
        size_dependent: bool = True,
        speeds: Optional[Sequence[float]] = None,
        churn: Optional[ChurnProcess] = None,
        churn_schedule: Optional[ChurnSchedule] = None,
        controller: Optional[OnlineReplanner] = None,
        speculation: Optional[Speculation] = None,
        speculation_times: Optional[Sequence[float]] = None,
        retry: Optional[Retry] = None,
        task_fail_script: Optional[Sequence[int]] = None,
        retry_times: Optional[Sequence[float]] = None,
        scheduler: "str | Scheduler" = "fifo_gang",
        workers_per_job: Optional[int] = None,
    ):
        # one validation path for every backend: the same Scenario.validate()
        # the jax epoch scan and the planner route through
        Scenario(
            speeds=speeds,
            churn=churn,
            churn_schedule=churn_schedule,
            speculation=speculation,
            retry=retry,
            scheduler=scheduler,
            workers_per_job=workers_per_job,
        ).validate(n_workers=n_workers, backend="python", controller=controller)
        if speculation_times is not None and speculation is None:
            raise ValueError(
                "speculation_times (scripted replay epochs) requires the "
                "speculation=Speculation(...) policy they were recorded under"
            )
        if retry_times is not None and retry is None:
            raise ValueError(
                "retry_times (scripted retry stamps) requires the "
                "retry=Retry(...) policy they were recorded under"
            )
        _scheduler = make_scheduler(scheduler)
        self.pool = WorkerPool(n_workers, speeds)
        self.rng = ev.RngStreams(seed)
        self.n_batches = n_batches
        self.cancel_redundant = cancel_redundant
        self.size_dependent = size_dependent
        self.churn = churn
        self.churn_schedule = churn_schedule
        self.controller = controller
        self.speculation = speculation
        self._spec = SpeculativePolicy(speculation) if speculation is not None else None
        # scripted mode (trace replay): launches happen at the recorded
        # stamps instead of the policy's self-armed heartbeat grid
        self._spec_script = tuple(speculation_times) if speculation_times is not None else None
        self._spec_seq = 0
        self._spec_armed_t = math.inf
        self._n_spec = 0
        # task-level failure semantics: which global dispatch indices raise
        # mid-payload (scripted from a trace's task_fail events), and the
        # recorded stamps at which failed replicas re-enter the rescue queue
        self.retry = retry
        self._task_fail_set = frozenset(int(i) for i in (task_fail_script or ()))
        self._retry_script = tuple(retry_times) if retry_times is not None else None
        self._dispatch_idx = 0
        self._attempts: Dict[tuple, int] = {}  # (job_id, batch) -> payload failures
        self._pending_retries: List[tuple] = []  # (release, seq, job_id, batch)
        self._retry_seq = 0
        self._retry_batches: Set[tuple] = set()  # rescue entries that are retries
        self._n_task_failures = 0
        self._n_retries = 0
        self.scheduler = _scheduler
        self.workers_per_job = None if workers_per_job is None else int(workers_per_job)

        self.events = ev.EventQueue()
        self.clock = ev.SimClock()
        self.queue: collections.deque = collections.deque()
        self.active: Dict[int, _JobExec] = {}
        self.rescue: collections.deque = collections.deque()  # (job_id, batch)
        self.records: List[JobRecord] = []

        self._worker_seconds = 0.0
        self._saved_seconds = 0.0
        # cumulative speed-weighted assigned load per worker (wall-clock
        # duration / speed, accrued at placement so the jax lane can replay
        # it): the 'balanced' policy's load metric.  Dividing by speed makes
        # a slow worker accrue more load per batch than a fast one, so under
        # heterogeneous speeds the policy steers work toward fast workers
        # instead of treating equally-busy workers as equally attractive.
        self._load_w = [0.0] * n_workers
        self._n_failures = 0
        self._n_rescued = 0
        self._n_jobs_expected = 0
        self._epoch_times: List[float] = []  # applied churn events, in order
        self._ran = False

    # -- plan resolution ----------------------------------------------------

    def _choose_B(self, job: Job, n_avail: int) -> int:
        if job.plan is not None and job.plan.n_batches is not None:
            b = job.plan.n_batches
        elif self.controller is not None and self.controller.current is not None:
            b = self.controller.current.n_batches
        elif self.n_batches is not None:
            b = self.n_batches
        else:
            b = n_avail
        return max(1, min(int(b), n_avail))

    def _job_cancel(self, job: Job) -> bool:
        if job.plan is not None and job.plan.cancel_redundant is not None:
            return bool(job.plan.cancel_redundant)
        return self.cancel_redundant

    def _job_request(self, job: Job, n_alive: int) -> int:
        """Worker-subset size the job gets, clamped to the alive count
        (a job asking for more than is alive runs on what there is, exactly
        like the gang regime does)."""
        if job.plan is not None and job.plan.workers is not None:
            req = job.plan.workers
        elif self.workers_per_job is not None:
            req = self.workers_per_job
        else:
            req = n_alive
        return max(1, min(int(req), n_alive))

    def _allocated_wids(self) -> Set[int]:
        out: Set[int] = set()
        for jexec in self.active.values():
            if jexec.alloc is not None:
                out |= jexec.alloc
        return out

    # -- dispatch -----------------------------------------------------------

    def _assign(self, worker: Worker, jexec: _JobExec, batch: int) -> None:
        duration = draw_batch_time(
            jexec.job.dist,
            self.rng.get("service"),
            jexec.batch_tasks,
            worker.speed,
            self.size_dependent,
        )
        now = self.clock.now
        worker.assignment = (jexec.job.job_id, batch)
        worker.busy_since = now
        worker.scheduled_end = now + duration
        self._load_w[worker.wid] += duration / worker.speed
        jexec.outstanding.setdefault(batch, set()).add(worker.wid)
        # scripted task failures (trace replay): the k-th dispatch of the run
        # raises mid-payload instead of completing -- identified by its global
        # dispatch index, which live and replay agree on because dispatch
        # order IS decision order on both sides
        idx = self._dispatch_idx
        self._dispatch_idx += 1
        kind = ev.TASK_FAIL if idx in self._task_fail_set else ev.BATCH_DONE
        self.events.push(
            now + duration,
            kind,
            job_id=jexec.job.job_id,
            batch=batch,
            wid=worker.wid,
            epoch=worker.epoch,
        )

    def _try_dispatch(self) -> None:
        if not self.scheduler.space_sharing:
            # Whole-cluster FIFO gang scheduling: the next job starts once no
            # job is active and every alive worker is free (stragglers of the
            # previous job -- unless cancelled -- delay the next one:
            # redundancy's queueing cost, which cancellation reclaims).
            while self.queue and not self.active:
                n_alive = self.pool.n_alive()
                free = self.pool.free_workers()
                if n_alive == 0 or len(free) < n_alive:
                    return
                job = self.queue.popleft()
                b = self._choose_B(job, n_alive)
                r = n_alive // b
                jexec = _JobExec(
                    job=job,
                    start=self.clock.now,
                    n_batches=b,
                    replication=r,
                    cancel=self._job_cancel(job),
                )
                self.active[job.job_id] = jexec
                for idx, worker in enumerate(free[: b * r]):
                    self._assign(worker, jexec, idx % b)
            return
        # Space sharing: one first-fit pass over the FIFO queue -- every
        # queued job that fits on the currently free *unallocated* workers
        # starts now on its own disjoint subset (a narrow job may overtake a
        # wide head-of-line job that does not fit yet).  One pass suffices:
        # placements only consume eligible workers, so a job that did not
        # fit earlier in the pass cannot fit later in it.
        n_alive = self.pool.n_alive()
        if n_alive == 0:
            return
        allocated = self._allocated_wids()
        eligible = [w for w in self.pool.free_workers() if w.wid not in allocated]
        for job in list(self.queue):
            if not eligible:
                break  # nothing left to place
            req = self._job_request(job, n_alive)
            if len(eligible) < req:
                continue
            chosen = self.scheduler.select(req, eligible, self._load_w)
            b = self._choose_B(job, req)
            r = req // b
            jexec = _JobExec(
                job=job,
                start=self.clock.now,
                n_batches=b,
                replication=r,
                cancel=self._job_cancel(job),
                alloc={w.wid for w in chosen},
            )
            self.active[job.job_id] = jexec
            self.queue.remove(job)
            for idx, worker in enumerate(chosen[: b * r]):
                self._assign(worker, jexec, idx % b)
            taken = jexec.alloc
            eligible = [w for w in eligible if w.wid not in taken]

    def _assign_rescues(self) -> None:
        if not self.scheduler.space_sharing:
            while self.rescue:
                free = self.pool.free_workers()
                if not free:
                    return
                job_id, batch = self.rescue.popleft()
                jexec = self.active.get(job_id)
                if jexec is None or batch in jexec.done:
                    continue
                self._assign(free[0], jexec, batch)
                self._count_rescue(job_id, batch)
            return
        # Space sharing: serve the FIFO rescue queue without head-of-line
        # blocking across jobs (a blocked rescue must not starve another
        # job's rescue whose own workers are free -- that would deadlock).
        # Eligible workers are free workers still allocated to the job;
        # failing that, a free unallocated worker is *regranted* into the
        # allocation -- the churn-aware reassignment that restores a job
        # whose allocation shrank below its replica need.
        remaining = []
        allocated = self._allocated_wids()
        for job_id, batch in list(self.rescue):
            jexec = self.active.get(job_id)
            if jexec is None or batch in jexec.done:
                continue  # stale entry: the job or batch already finished
            free = self.pool.free_workers()
            own = [w for w in free if w.wid in jexec.alloc]
            if own:
                worker = self.scheduler.select(1, own, self._load_w)[0]
            else:
                outside = [w for w in free if w.wid not in allocated]
                if not outside:
                    remaining.append((job_id, batch))
                    continue
                worker = self.scheduler.select(1, outside, self._load_w)[0]
                jexec.alloc.add(worker.wid)
                allocated.add(worker.wid)
            self._assign(worker, jexec, batch)
            self._count_rescue(job_id, batch)
        self.rescue = collections.deque(remaining)

    def _count_rescue(self, job_id: int, batch: int) -> None:
        """A served rescue entry is either a retry re-dispatch (the replica's
        payload failed and its backoff expired) or a genuine churn rescue."""
        if (job_id, batch) in self._retry_batches:
            self._retry_batches.discard((job_id, batch))
            self._n_retries += 1
        else:
            self._n_rescued += 1

    # -- speculative backups (reactive replication) --------------------------

    def _spec_pick_worker(self, jexec: _JobExec):
        """The worker a backup for this job would take: lowest free wid under
        the gang regime; under space sharing the job's own free workers first,
        else a free unallocated worker *regranted* into the allocation (the
        same preference order rescues use).  Returns (worker, regrant)."""
        free = self.pool.free_workers()
        if not self.scheduler.space_sharing:
            return (free[0], False) if free else (None, False)
        own = [w for w in free if w.wid in jexec.alloc]
        if own:
            return self.scheduler.select(1, own, self._load_w)[0], False
        outside = [w for w in free if w.wid not in self._allocated_wids()]
        if outside:
            return self.scheduler.select(1, outside, self._load_w)[0], True
        return None, False

    def _next_spec_time(self) -> float:
        """Earliest heartbeat epoch at which some batch earns a backup.

        A pure function of the current state -- the jax epoch scan computes
        the identical formula on its replica vectors, which is what lets the
        differential tests demand exact agreement: for every active job with
        at least ``min_observations`` completed sibling durations, backup
        budget left, and a worker available to it, each unfinished batch's
        youngest in-flight replica crosses at ``start + theta x median``;
        the launch lands on the first heartbeat strictly after the crossing
        (or after now, when the crossing is already past).
        """
        cfg, pol = self.speculation, self._spec
        best = math.inf
        for job_id in sorted(self.active):
            jexec = self.active[job_id]
            if jexec.spec_used >= cfg.max_backups:
                continue
            med = pol.median(jexec.obs)
            if med is None:
                continue
            if self._spec_pick_worker(jexec)[0] is None:
                continue
            for batch, wids in jexec.outstanding.items():
                if batch in jexec.done or not wids:
                    continue
                y = max(self.pool[w].busy_since for w in wids)
                best = min(best, pol.next_epoch(y + cfg.theta * med, self.clock.now))
        return best

    def _arm_spec(self) -> None:
        """Re-arm the single outstanding SPEC_CHECK timer after a state
        change (classic DES timer pattern: a bumped seq invalidates any
        stale check already on the heap)."""
        t = self._next_spec_time()
        if t == self._spec_armed_t:
            return
        self._spec_seq += 1
        self._spec_armed_t = t
        if math.isfinite(t):
            self.events.push(t, ev.SPEC_CHECK, seq=self._spec_seq)

    def _on_spec_check(self, seq: Optional[int] = None, scripted: bool = False) -> None:
        """Launch at most ONE backup: the first lagging (job, batch) in sorted
        order.  One launch per check keeps every substrate aligned -- the jax
        scan applies one action per event step, and the live trace stamps each
        launch separately -- and the re-arm (next recorded stamp) picks up any
        remaining laggard at the next heartbeat epoch, identically everywhere.
        """
        cfg, pol = self.speculation, self._spec
        if not scripted:
            if seq != self._spec_seq:
                return  # stale timer: state changed since it was armed
            self._spec_armed_t = math.inf  # consumed; the loop re-arms
        now = self.clock.now
        for job_id in sorted(self.active):
            jexec = self.active[job_id]
            if jexec.spec_used >= cfg.max_backups:
                continue
            med = pol.median(jexec.obs)
            if med is None:
                continue
            for batch in sorted(jexec.outstanding):
                wids = jexec.outstanding[batch]
                if batch in jexec.done or not wids:
                    continue
                y = max(self.pool[w].busy_since for w in wids)
                if not pol.lagging(now - y, med):
                    continue
                worker, regrant = self._spec_pick_worker(jexec)
                if worker is None:
                    break
                if regrant:
                    jexec.alloc.add(worker.wid)
                self._assign(worker, jexec, batch)
                jexec.spec_used += 1
                self._n_spec += 1
                return
        if scripted:
            raise RuntimeError(
                "speculation replay diverged: the trace recorded a backup "
                f"launch at t={now} but no batch is eligible under the policy"
            )

    # -- event handlers -----------------------------------------------------

    def _release(self, worker: Worker) -> None:
        """Account busy time and mark the worker idle."""
        self._worker_seconds += self.clock.now - worker.busy_since
        worker.assignment = None
        worker.scheduled_end = math.inf

    def _on_batch_done(self, job_id: int, batch: int, wid: int, epoch: int) -> None:
        worker = self.pool[wid]
        if not worker.alive or worker.epoch != epoch or worker.assignment != (job_id, batch):
            return  # stale: the replica was cancelled or the worker failed
        jexec = self.active.get(job_id)
        if jexec is None:
            # the job already completed (earliest cover); this replica ran to
            # the end -- release the worker so the next job can gang-schedule
            self._release(worker)
            self._assign_rescues()
            self._try_dispatch()
            return
        now = self.clock.now
        duration = now - worker.busy_since
        self._release(worker)
        jexec.outstanding[batch].discard(wid)

        # a completed replica is a genuine service-time observation; with
        # cancellation only the batch winner completes, so tag it with the
        # number of replicas it raced (the replanner undoes the min-of-r bias)
        if self.controller is not None:
            tau = duration * worker.speed
            if self.size_dependent:
                tau /= jexec.batch_tasks
            censored = jexec.cancel and batch not in jexec.done
            n_rivals = len(jexec.outstanding[batch]) if censored else 0
            self.controller.observe(tau, n_competitors=1 + n_rivals)

        if batch not in jexec.done:
            jexec.done.add(batch)
            # the batch's first completion is a sibling-duration observation
            # for the speculative policy's running median
            jexec.obs.append(duration)
            if jexec.cancel:
                for sib_wid in sorted(jexec.outstanding[batch]):
                    sib = self.pool[sib_wid]
                    self._saved_seconds += sib.scheduled_end - now
                    sib.epoch += 1  # invalidate its in-flight BATCH_DONE
                    self._release(sib)
                jexec.outstanding[batch].clear()
            if jexec.complete:
                self._finish_job(jexec)
        self._assign_rescues()
        self._try_dispatch()

    def _finish_job(self, jexec: _JobExec) -> None:
        job = jexec.job
        self.records.append(
            JobRecord(
                job_id=job.job_id,
                name=job.name,
                arrival=job.arrival,
                start=jexec.start,
                finish=self.clock.now,
                n_batches=jexec.n_batches,
                replication=jexec.replication,
            )
        )
        del self.active[job.job_id]
        # drop rescues belonging to the finished job
        still_needed = [(j, b) for (j, b) in self.rescue if j != job.job_id]
        self.rescue = collections.deque(still_needed)
        self._drop_retry_state(job.job_id)
        if self.controller is not None:
            # future dispatches read controller.current
            self.controller.maybe_replan(self.pool.n_alive())

    def _drop_retry_state(self, job_id: int) -> None:
        self._pending_retries = [e for e in self._pending_retries if e[2] != job_id]
        self._retry_batches = {x for x in self._retry_batches if x[0] != job_id}

    def _on_task_fail(self, job_id: int, batch: int, wid: int, epoch: int) -> None:
        """A replica's payload raised: count the attempt, release the worker,
        and either arm a backoff retry or -- budget exhausted with no sibling
        running or pending -- abandon the job (record finish = inf)."""
        worker = self.pool[wid]
        if not worker.alive or worker.epoch != epoch or worker.assignment != (job_id, batch):
            return  # stale: the replica was cancelled or the worker failed
        self._n_task_failures += 1
        self._release(worker)
        jexec = self.active.get(job_id)
        if jexec is not None:
            jexec.outstanding[batch].discard(wid)
            if batch not in jexec.done:
                attempt = self._attempts.get((job_id, batch), 0) + 1
                self._attempts[(job_id, batch)] = attempt
                if self.retry is not None and attempt <= self.retry.max_attempts:
                    self._retry_seq += 1
                    self._pending_retries.append(
                        (self.clock.now + self.retry.backoff(attempt), self._retry_seq,
                         job_id, batch)
                    )
                elif not jexec.outstanding[batch] and not any(
                    j == job_id and b == batch for _, _, j, b in self._pending_retries
                ):
                    self._abandon_job(jexec)
        self._assign_rescues()
        self._try_dispatch()

    def _on_retry(self, scripted: bool = True) -> None:
        """Scripted retry (trace replay): the earliest-armed pending retry
        whose batch is still undone re-enters the rescue queue -- mirroring
        the live master's backoff timers, which fire in release order and
        no-op silently when the batch completed meanwhile."""
        valid = [
            e for e in self._pending_retries
            if e[2] in self.active and e[3] not in self.active[e[2]].done
        ]
        if not valid:
            raise RuntimeError(
                "retry replay diverged: the trace recorded a retry at "
                f"t={self.clock.now} but no failed replica is pending"
            )
        entry = min(valid)
        self._pending_retries.remove(entry)
        _, _, job_id, batch = entry
        self._retry_batches.add((job_id, batch))
        self.rescue.append((job_id, batch))
        self._assign_rescues()
        self._try_dispatch()

    def _abandon_job(self, jexec: _JobExec) -> None:
        """Retry budget exhausted with nothing in flight: the job can never
        cover all batches -- record it unfinished and free its state (any
        cross-batch stragglers keep running and release on completion)."""
        job = jexec.job
        self.records.append(
            JobRecord(
                job_id=job.job_id,
                name=job.name,
                arrival=job.arrival,
                start=jexec.start,
                finish=math.inf,
                n_batches=jexec.n_batches,
                replication=jexec.replication,
            )
        )
        del self.active[job.job_id]
        self.rescue = collections.deque((j, b) for (j, b) in self.rescue if j != job.job_id)
        self._drop_retry_state(job.job_id)

    def _schedule_failure(self, worker: Worker) -> None:
        if self.churn is None:
            return
        dt = self.churn.next_failure(self.rng.get("churn"))
        if math.isfinite(dt):
            when = self.clock.now + dt
            self.events.push(when, ev.WORKER_FAIL, wid=worker.wid, epoch=worker.churn_epoch)

    def _on_worker_fail(self, wid: int, epoch: int) -> None:
        worker = self.pool[wid]
        if not worker.alive or worker.churn_epoch != epoch:
            return  # stale failure (scheduled before an earlier fail/join)
        self._n_failures += 1
        self._epoch_times.append(self.clock.now)
        if worker.assignment is not None:
            job_id, batch = worker.assignment
            self._worker_seconds += self.clock.now - worker.busy_since
            jexec = self.active.get(job_id)
            if jexec is not None:
                jexec.outstanding[batch].discard(wid)
                if batch not in jexec.done and not jexec.outstanding[batch]:
                    # last replica of an unfinished batch died: rescue it
                    self.rescue.append((job_id, batch))
            worker.assignment = None
            worker.scheduled_end = math.inf
        # a failed worker leaves whatever allocation held it (space sharing):
        # the job recovers through rescue regrants, not by keeping dead wids
        for jexec in self.active.values():
            if jexec.alloc is not None:
                jexec.alloc.discard(wid)
        worker.alive = False
        worker.epoch += 1
        worker.churn_epoch += 1
        if self.churn is not None:
            down = self.churn.downtime(self.rng.get("churn"))
            if math.isfinite(down):
                self.events.push(
                    self.clock.now + down,
                    ev.WORKER_JOIN,
                    wid=wid,
                    epoch=worker.churn_epoch,
                )
        self._assign_rescues()
        self._try_dispatch()

    def _on_worker_join(self, wid: int, epoch: int) -> None:
        worker = self.pool[wid]
        if worker.alive or worker.churn_epoch != epoch:
            return
        self._epoch_times.append(self.clock.now)
        worker.alive = True
        worker.epoch += 1
        worker.churn_epoch += 1
        self._schedule_failure(worker)
        self._assign_rescues()
        self._try_dispatch()

    # -- main loop ----------------------------------------------------------

    def run(self, jobs: Sequence[Job], max_events: int = 2_000_000) -> EngineReport:
        """Execute ``jobs`` to completion and return the run report.

        Single-shot: clock, records, and churn state persist after a run, so
        reusing the engine would mix workloads -- construct a new one.
        """
        if self._ran:
            raise RuntimeError("ClusterEngine.run() is single-shot; construct a new engine")
        self._ran = True
        self._n_jobs_expected = len(jobs)
        for job in jobs:
            self.events.push(job.arrival, ev.JOB_ARRIVAL, job=job)
        for worker in self.pool:
            self._schedule_failure(worker)
        if self._spec_script is not None:
            # trace replay: launches happen at the recorded stamps; the
            # engine re-derives which batch and which worker from the policy
            for t in self._spec_script:
                self.events.push(t, ev.SPEC_CHECK, scripted=True)
        if self._retry_script is not None:
            for t in self._retry_script:
                self.events.push(t, ev.RETRY, scripted=True)
        if self.churn_schedule is not None:
            # replay the explicit timeline: the k-th event of worker w expects
            # churn_epoch k (transitions are schedule-driven only, so the
            # staleness guards see exactly the epoch they were tagged with)
            per_worker: Dict[int, int] = {}
            sched = self.churn_schedule
            for t, wid, up in zip(sched.times, sched.wids, sched.ups):
                epoch = per_worker.get(wid, 0)
                kind = ev.WORKER_JOIN if up else ev.WORKER_FAIL
                self.events.push(t, kind, wid=wid, epoch=epoch)
                per_worker[wid] = epoch + 1

        n_events = 0
        while self.events and n_events < max_events:
            if len(self.records) == self._n_jobs_expected:
                break  # only churn noise remains
            t, kind, payload = self.events.pop()
            self.clock.advance(t)
            n_events += 1
            if kind == ev.JOB_ARRIVAL:
                self.queue.append(payload["job"])
                # rescues get first pick of free capacity even at arrivals
                # (a no-op under fifo_gang: rescues pending implies no free
                # worker here); keeps the space-sharing invariant that a
                # dispatch never overtakes a serviceable rescue
                self._assign_rescues()
                self._try_dispatch()
            elif kind == ev.BATCH_DONE:
                self._on_batch_done(**payload)
            elif kind == ev.WORKER_FAIL:
                self._on_worker_fail(**payload)
            elif kind == ev.WORKER_JOIN:
                self._on_worker_join(**payload)
            elif kind == ev.SPEC_CHECK:
                self._on_spec_check(**payload)
            elif kind == ev.TASK_FAIL:
                self._on_task_fail(**payload)
            elif kind == ev.RETRY:
                self._on_retry(**payload)
            else:  # pragma: no cover - no other kinds are ever pushed
                raise RuntimeError(f"unknown event kind {kind!r}")
            if self._spec is not None and self._spec_script is None:
                self._arm_spec()

        # flush replicas still in flight: their full duration is committed
        # worker time (it will burn whether or not we simulate it), which
        # keeps the invariant  ws(cancel on) + saved == ws(cancel off)
        for worker in self.pool:
            if worker.alive and worker.assignment is not None:
                self._worker_seconds += worker.scheduled_end - worker.busy_since
                worker.assignment = None
                worker.scheduled_end = math.inf

        # jobs that never completed (cluster died / event budget exhausted)
        for jexec in list(self.active.values()):
            job = jexec.job
            self.records.append(
                JobRecord(
                    job_id=job.job_id,
                    name=job.name,
                    arrival=job.arrival,
                    start=jexec.start,
                    finish=math.inf,
                    n_batches=jexec.n_batches,
                    replication=jexec.replication,
                )
            )
        for job in self.queue:
            self.records.append(
                JobRecord(
                    job_id=job.job_id,
                    name=job.name,
                    arrival=job.arrival,
                    start=math.inf,
                    finish=math.inf,
                    n_batches=0,
                    replication=0,
                )
            )
        self.records.sort(key=lambda r: r.job_id)

        last_b = self.records[-1].n_batches if self.records else 0
        return EngineReport(
            records=self.records,
            worker_seconds=self._worker_seconds,
            cancelled_seconds_saved=self._saved_seconds,
            n_events=n_events,
            n_worker_failures=self._n_failures,
            n_replicas_rescued=self._n_rescued,
            n_replans=len(self.controller.history) if self.controller else 0,
            final_n_batches=last_b,
            epoch_times=tuple(self._epoch_times),
            n_speculative=self._n_spec,
            n_task_failures=self._n_task_failures,
            n_retries=self._n_retries,
        )


# --------------------------------------------------------------------------
# conveniences: i.i.d. sampling and trace-driven workloads
# --------------------------------------------------------------------------


def sample_job_times(
    dist: Optional[ServiceTime] = None,
    n_workers: Optional[int] = None,
    n_batches: Optional[int] = None,
    n_samples: Optional[int] = None,
    *,
    seed: int = 0,
    size_dependent=UNSET,
    cancel_redundant=UNSET,
    n_tasks=UNSET,
    backend: str = "python",
    speeds=UNSET,
    churn=UNSET,
    churn_schedule=UNSET,
    controller: Optional[OnlineReplanner] = None,
    replan=UNSET,
    speculation=UNSET,
    scheduler=UNSET,
    workers_per_job=UNSET,
    job_plans=UNSET,
    churn_pairs_per_worker=UNSET,
    dtype=UNSET,
    rep_chunk=UNSET,
    devices=UNSET,
    scenario=None,
) -> np.ndarray:
    """Job compute-time samples from the engine (i.i.d. when the cluster is
    static; correlated through the shared churn timeline otherwise).

    ``backend="python"`` runs one event-driven engine with ``n_samples``
    identical jobs queued at t=0: under whole-cluster FIFO scheduling they
    execute serially -- the engine-side analogue of ``simulate_balanced``.
    ``backend="jax"`` draws the same statistic from the vectorized replay of
    these semantics: :func:`repro.cluster.vectorized.frontier_job_times` for
    the static case, or the epoch scan
    (:func:`repro.cluster.epoch_scan.simulate_epochs`) once any dynamic knob
    -- ``speeds``, ``churn``, ``churn_schedule``, ``replan`` -- is set.

    ``controller`` (an :class:`OnlineReplanner`) drives the Python engine;
    ``replan`` (a :class:`~repro.cluster.epoch_scan.ReplanConfig`) drives the
    jax path -- pass one matching the other for differential runs.

    ``dtype``/``rep_chunk``/``devices`` apply to the jax dynamic path only:
    float64 scan lanes for long-horizon workloads, chunked rep batches to
    bound device memory, and multi-device lane sharding (see
    :func:`repro.cluster.epoch_scan.simulate_epochs`).

    ``scheduler`` / ``workers_per_job`` / ``job_plans`` run the stream under
    space sharing on both backends: jobs execute concurrently on disjoint
    worker subsets, each under its own
    :class:`~repro.cluster.scheduler.JobPlan` (``job_plans`` cycles over the
    stream; unset fields inherit ``n_batches`` / ``cancel_redundant`` /
    ``workers_per_job``).  Any space knob routes ``backend="jax"`` to the
    epoch scan's space lane even when the cluster is otherwise static.

    Churn-horizon note: the jax path samples ``churn`` as a finite stream of
    ``churn_pairs_per_worker`` fail/join pairs per worker (each worker then
    stays up), while the Python engine samples churn for the whole run.
    The default (``None``) auto-sizes that horizon from the stream length,
    and a run whose timeline still outruns it emits a loud
    ``RuntimeWarning`` and sets ``EpochReport.churn_truncated`` -- raise
    ``churn_pairs_per_worker`` explicitly, or pass a ``churn_schedule``,
    which both backends replay identically and truncate identically.

    The scenario knobs are best passed as one validated
    ``scenario=Scenario(...)`` (which may also carry ``dist`` /
    ``n_workers`` / ``n_batches``); the loose keyword forms keep working
    behind a :class:`DeprecationWarning` shim.
    """
    sc = resolve_scenario(
        scenario,
        {
            "cancel_redundant": cancel_redundant,
            "size_dependent": size_dependent,
            "n_tasks": n_tasks,
            "speeds": speeds,
            "churn": churn,
            "churn_schedule": churn_schedule,
            "churn_pairs_per_worker": churn_pairs_per_worker,
            "replan": replan,
            "speculation": speculation,
            "scheduler": scheduler,
            "workers_per_job": workers_per_job,
            "job_plans": job_plans,
            "dtype": dtype,
            "rep_chunk": rep_chunk,
            "devices": devices,
        },
        where="sample_job_times",
    )
    dist = dist if dist is not None else sc.dist
    n_batches = n_batches if n_batches is not None else sc.n_batches
    if dist is None or (n_workers is None and sc.n_workers is None) or n_samples is None:
        raise ValueError(
            "sample_job_times needs dist, n_workers (or scenario fields), and n_samples"
        )
    n_workers = int(n_workers if n_workers is not None else sc.n_workers)
    if backend == "jax":
        if controller is not None:
            raise ValueError("backend='jax' takes replan=ReplanConfig(...), not controller")
        if sc.is_dynamic or sc.is_space:
            from .epoch_scan import simulate_epochs

            rep = simulate_epochs(
                dist,
                n_workers,
                n_batches,
                np.zeros(n_samples),
                1,
                seed=seed,
                scenario=sc,
            )
            return rep.compute_times[0]
        sc.validate(n_workers=n_workers, backend="jax")
        from .vectorized import frontier_job_times

        return frontier_job_times(
            dist,
            n_workers,
            [n_batches],
            n_samples,
            seed=seed,
            size_dependent=sc.size_dependent,
            n_tasks=sc.n_tasks,
        )[0]
    if backend != "python":
        raise ValueError(f"unknown backend {backend!r} (expected 'jax' or 'python')")
    sc.validate(n_workers=n_workers, backend="python", controller=controller)
    if controller is None and sc.replan is not None:
        controller = sc.replan.to_controller(n_workers)
    jobs = [
        Job(
            job_id=i,
            dist=dist,
            n_tasks=sc.n_tasks if sc.n_tasks is not None else n_workers,
            plan=sc.job_plan_for(i),
        )
        for i in range(n_samples)
    ]
    engine_kwargs = sc.to_engine_kwargs(n_workers)
    engine_kwargs["n_batches"] = n_batches
    engine_kwargs["controller"] = controller
    engine = ClusterEngine(n_workers, seed=seed, **engine_kwargs)
    report = engine.run(jobs)
    return report.compute_times


def jobs_from_traces(
    trace_jobs,
    n_tasks: int,
    arrival_rate: float,
    seed: int = 0,
) -> List[Job]:
    """§VII trace jobs -> a Poisson-arrival workload for the engine.

    Each :class:`~repro.core.traces.TraceJob` becomes one engine job whose
    task service times resample the trace's empirical distribution.
    """
    rng = np.random.default_rng(seed)
    t = 0.0
    out: List[Job] = []
    for i, tj in enumerate(trace_jobs):
        t += float(rng.exponential(1.0 / arrival_rate))
        out.append(
            Job(
                job_id=i,
                dist=Empirical(samples=tuple(float(x) for x in tj.task_times)),
                n_tasks=n_tasks,
                arrival=t,
                name=tj.name,
            )
        )
    return out
