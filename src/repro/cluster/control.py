"""Online control: replanning and reactive (speculative) replication.

Closes the planner -> runtime loop promised in ``core.planner``: the engine
feeds every genuinely observed per-task service time into the replanner,
which periodically refits a distribution family by maximum likelihood
(``fit_service_time``) and re-picks the operating point (B, r) with the
paper's closed forms.  Dispatches after a refit use the new plan, so a
workload whose tail drifts mid-stream (straggler onset) is re-batched
without restarting the cluster.

The jax epoch-scan backend mirrors this controller on device
(:class:`repro.cluster.epoch_scan.ReplanConfig` holds the same knobs and
``ReplanConfig.to_controller`` builds the equivalent instance of this class);
the differential suite checks both converge to the same closed-form optimum.
"""
from __future__ import annotations

import collections
import math
from typing import Optional, Sequence

import numpy as np

from ..core.planner import RedundancyPlan, RedundancyPlanner, fit_service_time
from ..core.service_time import Exponential, Pareto, ServiceTime, ShiftedExponential

__all__ = ["OnlineReplanner", "SpeculativePolicy"]


class SpeculativePolicy:
    """The reactive-replication decision rule, shared by every substrate.

    Wraps a frozen :class:`~repro.cluster.scenario.Speculation` config with
    the three pure computations the DES engine, the jax epoch scan, and the
    live runtime master all need to agree on bit-for-bit:

    * ``median(obs)`` -- the running *lower* median of completed sibling
      batch durations (``None`` until ``min_observations`` have completed);
    * ``lagging(elapsed, median)`` -- the MapReduce backup-task trigger,
      ``elapsed > theta x median``;
    * ``next_epoch(crossing, now)`` -- the first heartbeat epoch
      ``k x interval`` strictly after both the crossing time and ``now``
      (a replica that crossed in the past is reconsidered at the next
      epoch, never retroactively).
    """

    def __init__(self, cfg):
        self.cfg = cfg

    def median(self, obs: Sequence[float]) -> Optional[float]:
        """Running median of observed task times, or None below min_observations."""
        if len(obs) < self.cfg.min_observations:
            return None
        s = sorted(obs)
        return s[(len(s) - 1) // 2]

    def lagging(self, elapsed: float, median: float) -> bool:
        """Whether a task ``elapsed`` seconds in counts as a laggard."""
        return elapsed > self.cfg.theta * median

    def next_epoch(self, crossing: float, now: float) -> float:
        """First check-epoch boundary after both ``crossing`` and ``now``."""
        iv = self.cfg.interval
        k = max(math.floor(crossing / iv), math.floor(now / iv)) + 1
        return k * iv


def _inverse_min(dist: ServiceTime, c: float) -> ServiceTime:
    """Undo min-of-c censoring: the inverse of ``service_time.min_of``.

    When redundant replicas are cancelled, only each batch's fastest replica
    is observed -- a draw from the first order statistic of c i.i.d. tasks.
    For the closed families the base distribution is recoverable exactly:
    Exp(mu') -> Exp(mu'/c), SExp(d, mu') -> SExp(d, mu'/c),
    Pareto(s, a') -> Pareto(s, a'/c).
    """
    if c <= 1.0:
        return dist
    if isinstance(dist, Exponential):
        return Exponential(mu=dist.mu / c)
    if isinstance(dist, ShiftedExponential):
        return ShiftedExponential(delta=dist.delta, mu=dist.mu / c)
    if isinstance(dist, Pareto):
        return Pareto(sigma=dist.sigma, alpha=dist.alpha / c)
    return dist


class OnlineReplanner:
    """Sliding-window service-time refit + (B, r) replanning.

    Parameters
    ----------
    n_workers:
        Default worker budget to plan for (overridable per replan call, e.g.
        after churn changed the alive count).
    objective:
        ``'mean'`` | ``'cov'`` | ``'blend'`` -- forwarded to the planner.
    blend:
        Mean/CoV weight used when ``objective='blend'`` (forwarded to the
        planner on every replan).
    window:
        Number of most recent task-time observations kept.
    refit_every:
        Replan after this many new observations since the last refit.
    min_observations:
        Do not fit before this many samples are available (MLE stability).
    initial_plan:
        Optional starting operating point (e.g. a closed-form plan) used by
        dispatchers until the first data-driven refit; it is not counted in
        ``history`` (which records replans only).
    """

    def __init__(
        self,
        n_workers: int,
        objective: str = "mean",
        window: int = 512,
        refit_every: int = 128,
        min_observations: int = 64,
        initial_plan: Optional[RedundancyPlan] = None,
        blend: float = 0.5,
    ):
        self.n_workers = int(n_workers)
        self.objective = objective
        self.blend = float(blend)
        self.window = int(window)
        self.refit_every = int(refit_every)
        self.min_observations = int(min_observations)
        self.observations: collections.deque = collections.deque(maxlen=self.window)
        self.current: Optional[RedundancyPlan] = initial_plan
        self.history: list = []
        self.last_fit: Optional[ServiceTime] = None
        self._since_refit = 0

    def observe(self, task_time: float, n_competitors: int = 1) -> None:
        """Record one observed per-task service time (completed replicas only).

        ``n_competitors`` is the number of replicas that were racing when this
        one won (1 = uncensored).  With replica cancellation only the winner
        of each batch completes, so its time is a min-of-r draw; the count
        lets ``replan`` undo that censoring instead of fitting a tail that is
        r times lighter than reality.
        """
        if task_time > 0.0 and np.isfinite(task_time):
            self.observations.append((float(task_time), max(1, int(n_competitors))))
            self._since_refit += 1

    def observe_many(self, task_times, n_competitors: int = 1) -> None:
        """Feed a batch of task times into :meth:`observe`."""
        for t in np.asarray(task_times, dtype=np.float64).ravel():
            self.observe(float(t), n_competitors)

    def maybe_replan(self, n_workers: Optional[int] = None) -> Optional[RedundancyPlan]:
        """Refit + replan if enough new evidence accumulated; else None."""
        if len(self.observations) < self.min_observations:
            return None
        if self._since_refit < self.refit_every:
            return None
        return self.replan(n_workers)

    def replan(self, n_workers: Optional[int] = None) -> RedundancyPlan:
        """Unconditionally refit the window and re-pick (B, r)."""
        self._since_refit = 0
        n = int(n_workers) if n_workers is not None else self.n_workers
        planner = RedundancyPlanner(n)
        samples = np.array([t for t, _ in self.observations])
        counts = np.array([c for _, c in self.observations], dtype=np.float64)
        dist = fit_service_time(samples)
        dist = _inverse_min(dist, float(counts.mean()))
        self.last_fit = dist
        plan = planner.plan(dist, objective=self.objective, blend=self.blend)
        self.current = plan
        self.history.append(plan)
        return plan
