"""Scenario: one frozen, validated spec for every backend of the system.

Five PRs of kwarg-threading left ``plan_cluster`` / ``plan_sweep`` /
``sample_job_times`` / ``frontier_job_times_dynamic`` each carrying ~15
loose keyword arguments (speeds, churn, schedules, replan, space-sharing
knobs, jax scale knobs), with four separately-maintained copies of the
validation rules.  :class:`Scenario` collapses all of that into a single
frozen dataclass:

* ``Scenario.validate()`` is *the* validation path -- the Python engine,
  the jax epoch scan, the vectorized frontier, and the planner all route
  through it, so an error names the offending field once, the same way,
  everywhere, and says which backends support the knob;
* ``to_engine_kwargs()`` / ``to_scan_cfg()`` translate the one spec into
  the constructor kwargs of :class:`~repro.cluster.master.ClusterEngine`
  and the keyword set of the jax epoch scan, so callers hold exactly one
  object per scenario;
* the legacy loose-kwarg call forms keep working behind
  :func:`resolve_scenario`, which rebuilds the equivalent ``Scenario`` and
  emits a :class:`DeprecationWarning`.

The live execution runtime (:mod:`repro.cluster.runtime`) takes the same
object: ``Runtime.run(plan, scenario=...)`` executes against real worker
processes what ``sample_job_times(scenario=...)`` simulates.
"""

from __future__ import annotations

import dataclasses
import json
import warnings
from typing import TYPE_CHECKING, Optional, Tuple, Union

from .scheduler import SCHEDULERS, JobPlan, Scheduler
from .workers import ChurnProcess, ChurnSchedule

if TYPE_CHECKING:  # pragma: no cover - annotation-only (avoids an import cycle
    # with epoch_scan, which routes its validation through this module)
    from .epoch_scan import ReplanConfig

__all__ = [
    "FaultPlan",
    "Retry",
    "SLO",
    "Scenario",
    "Speculation",
    "UNSET",
    "resolve_scenario",
]


class _Unset:
    """Sentinel distinguishing 'kwarg not passed' from an explicit None."""

    _instance = None

    def __new__(cls):
        if cls._instance is None:
            cls._instance = super().__new__(cls)
        return cls._instance

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return "UNSET"


UNSET = _Unset()

# fields a Scenario owns; the legacy call forms accept them loose (shimmed
# through resolve_scenario with a DeprecationWarning)
_LEGACY_FIELDS = (
    "cancel_redundant",
    "size_dependent",
    "n_tasks",
    "speeds",
    "churn",
    "churn_schedule",
    "churn_pairs_per_worker",
    "replan",
    "speculation",
    "scheduler",
    "workers_per_job",
    "job_plans",
    "jobs_per_stream",
    "dtype",
    "rep_chunk",
    "devices",
    "outputs",
)


@dataclasses.dataclass(frozen=True)
class Speculation:
    """Reactive (speculative) replication policy: MapReduce backup tasks.

    Per-task progress is observed at *heartbeat epochs* -- the time grid
    ``k * interval`` in simulation, the workers' progress heartbeats in the
    live runtime.  A batch whose youngest in-flight replica has been running
    longer than ``theta x`` the running median of its completed siblings'
    durations gets a backup replica launched on a free worker at the first
    heartbeat epoch strictly after the crossing.  The backup races its
    sibling under the usual earliest-cover rule (and is reclaimed by
    ``cancel_redundant`` like any other redundant replica).

    ``min_observations`` completed sibling batches are required before the
    median is trusted; ``max_backups`` caps speculative launches per job.
    Launches are opportunistic: a laggard with no free worker available is
    reconsidered at the first heartbeat after one frees up.
    """

    interval: float = 0.25
    theta: float = 1.5
    min_observations: int = 1
    max_backups: int = 1

    def __post_init__(self):
        if not (self.interval > 0.0):
            raise ValueError(f"Speculation.interval: must be > 0, got {self.interval}")
        if not (self.theta > 0.0):
            raise ValueError(f"Speculation.theta: must be > 0, got {self.theta}")
        if self.min_observations < 1:
            raise ValueError(
                f"Speculation.min_observations: must be >= 1, got {self.min_observations}"
            )
        if self.max_backups < 1:
            raise ValueError(f"Speculation.max_backups: must be >= 1, got {self.max_backups}")


@dataclasses.dataclass(frozen=True)
class Retry:
    """Task-level failure semantics: retry a failed replica with backoff.

    A worker whose payload raises sends a ``fail`` frame (live runtime) /
    fires a ``TASK_FAIL`` event (engine replay).  The master releases the
    worker, counts the attempt, and -- while the batch's attempt count is
    ``<= max_attempts`` -- re-queues the replica after a capped exponential
    backoff (``min(backoff_s * 2**(k-1), max_backoff_s)`` for attempt ``k``),
    serving it through the rescue queue.  Once the budget is exhausted and no
    sibling replica is still running or pending, the job is *abandoned*: a
    ``job_fail`` event is stamped and its record finishes at ``inf``.

    Supported by the Python engine (trace replay) and the live runtime;
    rejected on ``backend="jax"``.
    """

    max_attempts: int = 2
    backoff_s: float = 0.05
    max_backoff_s: float = 1.0

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError(f"Retry.max_attempts: must be >= 1, got {self.max_attempts}")
        if not (self.backoff_s >= 0.0):
            raise ValueError(f"Retry.backoff_s: must be >= 0, got {self.backoff_s}")
        if not (self.max_backoff_s >= self.backoff_s):
            raise ValueError(
                f"Retry.max_backoff_s: must be >= backoff_s, got {self.max_backoff_s}"
            )

    def backoff(self, attempt: int) -> float:
        """Delay before re-queueing attempt ``attempt`` (1-based)."""
        return min(self.backoff_s * (2.0 ** max(attempt - 1, 0)), self.max_backoff_s)


@dataclasses.dataclass(frozen=True)
class SLO:
    """A tail response-time objective: ``P[response <= target_s] >= quantile``.

    The paper's second core result is that the replication level minimizing
    *mean* compute time is not the one minimizing tail response -- an SLO
    makes that trade-off an explicit planning input instead of a blend
    weight.  ``quantile`` is the tail level (0.99 for p99, 0.999 for p999),
    ``target_s`` the response-time bound it must meet, and ``arrival_rate``
    the offered load (jobs/second, Poisson) the target must hold under.
    ``job_class`` restricts the objective to one workload class (a source
    trace-job name under :class:`~repro.core.traces.TraceStream` streaming);
    ``None`` applies it to the pooled response distribution.

    Consumed by :meth:`repro.core.planner.RedundancyPlanner.plan_slo`, which
    sweeps (B, r, scheduler) candidates and returns the cheapest feasible
    one in worker-seconds (or an explicit infeasible verdict).

    Example (validates on construction)::

        >>> SLO(quantile=0.99, target_s=30.0, arrival_rate=0.5)
        SLO(quantile=0.99, target_s=30.0, arrival_rate=0.5, job_class=None)
    """

    quantile: float = 0.99
    target_s: float = 1.0
    arrival_rate: float = 1.0
    job_class: Optional[str] = None

    def __post_init__(self):
        if not (0.0 < self.quantile < 1.0):
            raise ValueError(
                f"SLO.quantile: must lie in (0, 1), got {self.quantile}"
            )
        if not (self.target_s > 0.0):
            raise ValueError(f"SLO.target_s: must be > 0, got {self.target_s}")
        if not (self.arrival_rate > 0.0):
            raise ValueError(
                f"SLO.arrival_rate: must be > 0, got {self.arrival_rate}"
            )


def _freeze_rows(name: str, rows, width: int) -> Tuple[tuple, ...]:
    out = []
    for row in rows:
        row = tuple(row)
        if len(row) != width:
            raise ValueError(f"FaultPlan.{name}: entries must have {width} fields, got {row!r}")
        out.append(row)
    return tuple(out)


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A deterministic chaos schedule for the live runtime.

    Every fault decision is made master-side by one seeded injector
    (:class:`repro.cluster.runtime.chaos.FaultInjector`) and stamped on the
    binary trace grid as an informational ``chaos`` event, so a faulted run
    stays bit-exactly replayable and crash-recovery can restore which faults
    were already delivered.

    * ``kills`` -- ``(wid, at_s)``: the master tears down the worker's
      connection at elapsed time ``at_s`` (the worker observes EOF and
      exits; the master detects the torn connection exactly as it would a
      real crash).
    * ``slowdowns`` -- ``(wid, at_s, factor)``: tasks dispatched to ``wid``
      at or after ``at_s`` run ``factor``x slower (the task frame carries
      the factor; compounding entries multiply).
    * ``hb_stalls`` -- ``(wid, at_s, duration_s)``: the master drops the
      worker's inbound heartbeats in the window, provoking missed-heartbeat
      detection without killing anything.
    * ``payload_errors`` -- ``(job, batch, n_raises)``: the first
      ``n_raises`` dispatches of that replica raise mid-payload (exercising
      the ``fail``-frame path and :class:`Retry`).
    * ``drop_p`` / ``dup_p`` / ``delay_p`` -- per-frame wire-fault
      probabilities (drop, duplicate, or delay by ``delay_s``), decided by a
      counter-seeded hash so each frame's fate is a pure function of
      ``(seed, direction, frame index)``.

    Live runtime only; rejected on ``backend="python"`` / ``"jax"`` (the
    engine sees the *consequences* -- churn, task failures -- via the trace).
    """

    seed: int = 0
    kills: Tuple[Tuple[int, float], ...] = ()
    slowdowns: Tuple[Tuple[int, float, float], ...] = ()
    hb_stalls: Tuple[Tuple[int, float, float], ...] = ()
    payload_errors: Tuple[Tuple[int, int, int], ...] = ()
    drop_p: float = 0.0
    dup_p: float = 0.0
    delay_p: float = 0.0
    delay_s: float = 0.02

    def __post_init__(self):
        # coerce nested lists (e.g. from from_dict) so the dataclass stays
        # hashable, then validate shape and ranges once, here
        object.__setattr__(self, "kills", _freeze_rows("kills", self.kills, 2))
        object.__setattr__(self, "slowdowns", _freeze_rows("slowdowns", self.slowdowns, 3))
        object.__setattr__(self, "hb_stalls", _freeze_rows("hb_stalls", self.hb_stalls, 3))
        object.__setattr__(
            self, "payload_errors", _freeze_rows("payload_errors", self.payload_errors, 3)
        )
        for wid, at in self.kills:
            if int(wid) < 0 or not (at >= 0.0):
                raise ValueError(f"FaultPlan.kills: bad entry {(wid, at)!r}")
        for wid, at, factor in self.slowdowns:
            if int(wid) < 0 or not (at >= 0.0) or not (factor > 0.0):
                raise ValueError(f"FaultPlan.slowdowns: bad entry {(wid, at, factor)!r}")
        for wid, at, dur in self.hb_stalls:
            if int(wid) < 0 or not (at >= 0.0) or not (dur > 0.0):
                raise ValueError(f"FaultPlan.hb_stalls: bad entry {(wid, at, dur)!r}")
        for job, batch, k in self.payload_errors:
            if int(job) < 0 or int(batch) < 0 or int(k) < 1:
                raise ValueError(f"FaultPlan.payload_errors: bad entry {(job, batch, k)!r}")
        for name in ("drop_p", "dup_p", "delay_p"):
            p = getattr(self, name)
            if not (0.0 <= p <= 1.0):
                raise ValueError(f"FaultPlan.{name}: must lie in [0, 1], got {p}")
        if self.drop_p + self.dup_p + self.delay_p > 1.0:
            raise ValueError("FaultPlan: drop_p + dup_p + delay_p must be <= 1")
        if not (self.delay_s >= 0.0):
            raise ValueError(f"FaultPlan.delay_s: must be >= 0, got {self.delay_s}")

    @property
    def max_wid(self) -> int:
        """Highest worker id any scheduled fault names (-1 when none do)."""
        wids = [int(w) for w, *_ in (*self.kills, *self.slowdowns, *self.hb_stalls)]
        return max(wids) if wids else -1


@dataclasses.dataclass(frozen=True)
class Scenario:
    """Everything that defines a straggler-mitigation scenario, in one object.

    Workload shape (``dist``, ``n_workers``, ``n_batches``, ``n_tasks``),
    engine semantics (``cancel_redundant``, ``size_dependent``), dynamics
    (``speeds``, ``churn`` | ``churn_schedule``, ``replan``), space sharing
    (``scheduler``, ``workers_per_job``, ``job_plans``), and the jax scale
    knobs (``dtype``, ``rep_chunk``, ``devices``).  Fields left ``None``
    inherit each entry point's call-level arguments (e.g. ``plan_cluster``
    sweeps candidate B's, so it ignores ``n_batches``; ``sample_job_times``
    takes ``n_batches`` positionally and falls back to the scenario's).

    Frozen and hashable, so a Scenario can key caches and ride inside jit
    bucketing the way :class:`~repro.cluster.epoch_scan.ReplanConfig` does.

    Example (the routing predicates pick the execution lane)::

        >>> sc = Scenario(scheduler="packed", workers_per_job=4)
        >>> sc.is_space
        True
        >>> sc.is_dynamic
        False
        >>> sc.replace(speeds=(1.0, 0.5)).is_dynamic
        True
    """

    dist: Optional[object] = None  # ServiceTime; kept loose to avoid core import cycle
    n_workers: Optional[int] = None
    n_batches: Optional[int] = None
    n_tasks: Optional[int] = None
    cancel_redundant: bool = False
    size_dependent: bool = True
    speeds: Optional[Tuple[float, ...]] = None
    churn: Optional[ChurnProcess] = None
    churn_schedule: Optional[ChurnSchedule] = None
    # sampled-churn horizon (fail/join pairs per worker) on the jax lanes;
    # None auto-sizes it from the stream length (epoch_scan warns loudly if
    # the simulated timeline still outruns it)
    churn_pairs_per_worker: Optional[int] = None
    replan: Optional[ReplanConfig] = None
    speculation: Optional[Speculation] = None
    # task-level failure semantics (payload exception -> backoff retry ->
    # abandon); Python engine (replay) + live runtime
    retry: Optional[Retry] = None
    # deterministic chaos schedule; live runtime only
    faults: Optional[FaultPlan] = None
    # tail response-time objective; consumed by RedundancyPlanner.plan_slo
    slo: Optional[SLO] = None
    scheduler: Union[str, Scheduler] = "fifo_gang"
    workers_per_job: Optional[int] = None
    job_plans: Optional[Tuple[Optional[JobPlan], ...]] = None
    jobs_per_stream: int = 16
    dtype: str = "float32"
    rep_chunk: Optional[int] = None
    devices: int = 1
    # "full" returns per-job starts/finishes (the classic reports); "stream"
    # carries running aggregates (count, moment sums, min/max, a log-spaced
    # response histogram) in the scan instead, so trace-scale runs never
    # materialize (reps x jobs) outputs.  jax backends only; "full" paths
    # stay bit-identical when this is left at the default.
    outputs: str = "full"

    def __post_init__(self):
        # freeze the sequence-valued fields so the dataclass stays hashable
        if self.speeds is not None and not isinstance(self.speeds, tuple):
            object.__setattr__(self, "speeds", tuple(float(s) for s in self.speeds))
        if self.job_plans is not None and not isinstance(self.job_plans, tuple):
            object.__setattr__(self, "job_plans", tuple(self.job_plans))

    # -- routing predicates --------------------------------------------------

    @property
    def scheduler_name(self) -> str:
        """The scheduler's registry name, whether set by name or instance."""
        return self.scheduler if isinstance(self.scheduler, str) else self.scheduler.name

    @property
    def is_space(self) -> bool:
        """Whether any space-sharing knob routes this scenario off the
        legacy single-gang lane (shared predicate with
        :func:`repro.cluster.scheduler.is_space`).
        """
        from .scheduler import is_space

        return is_space(self.scheduler_name, self.workers_per_job, self.job_plans)

    @property
    def is_dynamic(self) -> bool:
        """Whether the scenario needs the dynamic (epoch-scan) semantics."""
        return (
            self.speeds is not None
            or self.churn is not None
            or self.churn_schedule is not None
            or self.replan is not None
            or self.speculation is not None
        )

    # -- the single validation path ------------------------------------------

    def validate(
        self,
        n_workers: Optional[int] = None,
        *,
        backend: Optional[str] = None,
        controller=None,
    ) -> "Scenario":
        """Check every cross-field constraint once, for every backend.

        ``n_workers`` is the call-level worker budget (e.g. the planner's);
        it must agree with ``self.n_workers`` when both are set.  ``backend``
        tightens the check to what that backend supports -- error messages
        name the offending field *and* the backends that accept it.
        ``controller`` is the Python engine's live
        :class:`~repro.cluster.control.OnlineReplanner`, which shares
        ``replan``'s exclusion rules.  Returns ``self`` so call sites can
        chain.  Environment-dependent checks (jax x64 enabled, visible
        device count) stay with the jax modules -- they are properties of
        the process, not of the scenario.
        """
        if self.n_workers is not None and n_workers is not None:
            if int(self.n_workers) != int(n_workers):
                raise ValueError(
                    f"Scenario.n_workers={self.n_workers} does not match the "
                    f"call-level worker budget {n_workers}"
                )
        n = self.n_workers if n_workers is None else n_workers
        if n is not None and int(n) < 1:
            raise ValueError(f"Scenario.n_workers: must be >= 1, got {n}")
        if self.n_batches is not None:
            if self.n_batches < 1 or (n is not None and self.n_batches > n):
                hi = n if n is not None else "n_workers"
                raise ValueError(
                    f"Scenario.n_batches: must lie in [1, {hi}] or be None, "
                    f"got {self.n_batches}"
                )
        if self.n_tasks is not None and self.n_tasks < 1:
            raise ValueError(f"Scenario.n_tasks: must be >= 1, got {self.n_tasks}")
        if self.speeds is not None:
            if n is not None and len(self.speeds) != n:
                raise ValueError(
                    "Scenario.speeds: speeds must have one entry per worker "
                    f"(got {len(self.speeds)} for {n} workers)"
                )
            if any(not (s > 0) for s in self.speeds):
                raise ValueError("Scenario.speeds: speeds must be positive")
        if self.churn is not None and self.churn_schedule is not None:
            raise ValueError(
                "Scenario.churn/churn_schedule: pass either churn (sampled "
                "online) or churn_schedule, not both"
            )
        if self.churn_schedule is not None and len(self.churn_schedule) and n is not None:
            if min(self.churn_schedule.wids) < 0 or max(self.churn_schedule.wids) >= n:
                raise ValueError(f"Scenario.churn_schedule: worker ids must lie in [0, {n})")
        if self.churn_pairs_per_worker is not None and self.churn_pairs_per_worker < 1:
            raise ValueError(
                "Scenario.churn_pairs_per_worker: must be >= 1 (or None to "
                f"auto-size from the stream), got {self.churn_pairs_per_worker}"
            )
        if self.jobs_per_stream < 1:
            raise ValueError(f"Scenario.jobs_per_stream: must be >= 1, got {self.jobs_per_stream}")
        if self.replan is not None and controller is not None:
            raise ValueError(
                "Scenario.replan: pass either controller (Python engine) or "
                "replan (both backends), not both"
            )
        if self.replan is not None:
            if self.replan.objective not in ("mean", "cov", "blend"):
                raise ValueError(f"Scenario.replan: unknown objective {self.replan.objective!r}")
            if backend == "jax" and n is not None and self.replan.window < n:
                raise ValueError(
                    "Scenario.replan: replan.window must be >= n_workers on "
                    "backend='jax' (ring push bound); the Python engine has no "
                    "such floor"
                )
        if self.speculation is not None:
            if not isinstance(self.speculation, Speculation):
                raise ValueError(
                    f"Scenario.speculation: expected a Speculation, got {type(self.speculation)}"
                )
            if self.replan is not None or controller is not None:
                raise ValueError(
                    "Scenario.speculation: speculative backups and online "
                    "replanning are mutually exclusive adaptive policies -- "
                    "pass one of speculation / replan (controller)"
                )
            if backend == "jax" and self.is_space:
                raise ValueError(
                    "Scenario.speculation: speculative backups under "
                    "space-sharing schedulers / per-job plans run on "
                    "backend='python' only (the jax lane implements the gang "
                    "regime)"
                )
        if self.retry is not None:
            if not isinstance(self.retry, Retry):
                raise ValueError(f"Scenario.retry: expected a Retry, got {type(self.retry)}")
            if backend == "jax":
                raise ValueError(
                    "Scenario.retry: task-failure retry runs on the Python "
                    "engine (trace replay) and the live runtime only; the jax "
                    "lanes have no task-failure notion"
                )
        if self.faults is not None:
            if not isinstance(self.faults, FaultPlan):
                raise ValueError(f"Scenario.faults: expected a FaultPlan, got {type(self.faults)}")
            if backend in ("python", "jax"):
                raise ValueError(
                    "Scenario.faults: chaos fault injection drives the live "
                    "runtime only (backend='live'); simulations see its "
                    "consequences through the recorded trace"
                )
            if n is not None and self.faults.max_wid >= int(n):
                raise ValueError(
                    f"Scenario.faults: worker ids must lie in [0, {n}), "
                    f"got {self.faults.max_wid}"
                )
        if self.slo is not None and not isinstance(self.slo, SLO):
            # SLO value constraints live in SLO.__post_init__; job_class is
            # resolved against the workload by plan_slo (unknown names raise
            # there, where the class list exists)
            raise ValueError(f"Scenario.slo: expected an SLO, got {type(self.slo)}")
        if not isinstance(self.scheduler, Scheduler) and self.scheduler not in SCHEDULERS:
            raise ValueError(
                f"Scenario.scheduler: unknown scheduler {self.scheduler!r} "
                f"(expected one of {sorted(SCHEDULERS)})"
            )
        if self.is_space and (self.replan is not None or controller is not None):
            raise ValueError(
                "Scenario.replan: replan/controller is not supported with "
                "space-sharing schedulers / per-job plans on any backend "
                "(the online replanner picks one cluster-wide B)"
            )
        if self.workers_per_job is not None:
            hi = n if n is not None else "n_workers"
            if self.workers_per_job < 1 or (n is not None and self.workers_per_job > n):
                raise ValueError(
                    f"Scenario.workers_per_job: must lie in [1, {hi}], "
                    f"got {self.workers_per_job}"
                )
        if self.job_plans is not None:
            if not len(self.job_plans):
                raise ValueError(
                    "Scenario.job_plans: must be a non-empty sequence "
                    "(it cycles over jobs)"
                )
            for p in self.job_plans:
                if p is not None and not isinstance(p, JobPlan):
                    raise ValueError(
                        f"Scenario.job_plans: entries must be JobPlan or None, "
                        f"got {type(p)}"
                    )
        if self.dtype not in ("float32", "float64"):
            raise ValueError(
                f"Scenario.dtype: dtype must be 'float32' or 'float64', got {self.dtype!r}"
            )
        if self.rep_chunk is not None and self.rep_chunk < 1:
            raise ValueError(f"Scenario.rep_chunk: rep_chunk must be >= 1, got {self.rep_chunk}")
        if self.outputs not in ("full", "stream"):
            raise ValueError(
                f"Scenario.outputs: must be 'full' or 'stream', got {self.outputs!r}"
            )
        if self.devices < 1:
            raise ValueError(f"Scenario.devices: devices must be >= 1, got {self.devices}")
        if backend in ("python", "live"):
            if self.dtype != "float32":
                raise ValueError(
                    "Scenario.dtype: float64 lanes are a jax epoch-scan knob "
                    "(backend='jax' on dynamic scenarios); the Python engine "
                    "computes in float64 natively"
                )
            if self.devices != 1:
                raise ValueError(
                    "Scenario.devices: device sharding is a jax epoch-scan knob "
                    "(backend='jax' on dynamic scenarios); the Python engine is "
                    "single-process"
                )
            if self.outputs != "full":
                raise ValueError(
                    "Scenario.outputs: streaming aggregation is a jax knob "
                    "(simulate_epochs / simulate_stream); the Python engine "
                    "returns full per-job records"
                )
        return self

    # -- translations --------------------------------------------------------

    def to_engine_kwargs(self, n_workers: Optional[int] = None) -> dict:
        """Constructor kwargs for :class:`~repro.cluster.master.ClusterEngine`.

        ``replan`` becomes the equivalent live
        :class:`~repro.cluster.control.OnlineReplanner` (the engine drives a
        controller object, the jax scan a static config).  The caller adds
        ``seed`` -- seeds are per-run, not per-scenario.
        """
        n = n_workers if n_workers is not None else self.n_workers
        if n is None:
            raise ValueError("Scenario.n_workers: required to build engine kwargs")
        controller = self.replan.to_controller(int(n)) if self.replan is not None else None
        return {
            "n_batches": self.n_batches,
            "cancel_redundant": self.cancel_redundant,
            "size_dependent": self.size_dependent,
            "speeds": list(self.speeds) if self.speeds is not None else None,
            "churn": self.churn,
            "churn_schedule": self.churn_schedule,
            "controller": controller,
            "speculation": self.speculation,
            "retry": self.retry,
            "scheduler": self.scheduler,
            "workers_per_job": self.workers_per_job,
        }

    def to_scan_cfg(self) -> dict:
        """Keyword set for the jax epoch scan
        (:func:`~repro.cluster.epoch_scan.simulate_epochs` /
        :func:`~repro.cluster.epoch_scan.frontier_job_times_dynamic`).
        """
        return {
            "cancel_redundant": self.cancel_redundant,
            "size_dependent": self.size_dependent,
            "n_tasks": self.n_tasks,
            "speeds": self.speeds,
            "churn": self.churn,
            "churn_schedule": self.churn_schedule,
            "churn_pairs_per_worker": self.churn_pairs_per_worker,
            "replan": self.replan,
            "speculation": self.speculation,
            "scheduler": self.scheduler_name,
            "workers_per_job": self.workers_per_job,
            "job_plans": self.job_plans,
            "dtype": self.dtype,
            "rep_chunk": self.rep_chunk,
            "devices": self.devices,
            "outputs": self.outputs,
        }

    def job_plan_for(self, i: int) -> Optional[JobPlan]:
        """The i-th job's :class:`JobPlan` (``job_plans`` cycles over jobs)."""
        if self.job_plans is None:
            return None
        return self.job_plans[i % len(self.job_plans)]

    def replace(self, **changes) -> "Scenario":
        """A modified copy: ``sc.replace(cancel_redundant=True)`` -- the
        ergonomic way to derive scenario variants from a base spec.
        """
        return dataclasses.replace(self, **changes)

    # -- serialization (Scenario v2 JSON) ------------------------------------
    #
    # Schema: a flat object of the dataclass fields plus ``"version": 2``.
    # Nested configs serialize as tagged objects -- ``dist`` as
    # ``{"kind": "<ServiceTime subclass>", ...fields}``; ``churn`` /
    # ``churn_schedule`` / ``replan`` / ``speculation`` as their dataclass
    # fields; ``job_plans`` as a list of JobPlan objects or nulls;
    # ``scheduler`` as its registry name.  Floats ride through ``json`` via
    # ``repr`` shortest-round-trip, so ``from_json(to_json())`` is *exact*,
    # not approximate -- the property the trace-embeds rely on.

    def to_dict(self) -> dict:
        """JSON-ready flat dict of the fields plus ``"version": 2``."""
        out = {"version": 2}
        for f in dataclasses.fields(self):
            out[f.name] = _encode_field(f.name, getattr(self, f.name))
        return out

    def to_json(self, *, indent: Optional[int] = None) -> str:
        """Serialize to JSON; ``Scenario.from_json`` round-trips exactly."""
        return json.dumps(self.to_dict(), indent=indent)

    @classmethod
    def from_dict(cls, d: dict) -> "Scenario":
        """Decode :meth:`to_dict` output; unknown fields or versions raise."""
        d = dict(d)
        version = d.pop("version", None)
        if version != 2:
            raise ValueError(f"Scenario.from_dict: unsupported schema version {version!r}")
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"Scenario.from_dict: unknown fields {sorted(unknown)}")
        return cls(**{k: _decode_field(k, v) for k, v in d.items()})

    @classmethod
    def from_json(cls, s: str) -> "Scenario":
        """Decode a :meth:`to_json` string."""
        return cls.from_dict(json.loads(s))


def _dist_registry() -> dict:
    from ..core import service_time as st

    return {
        "Exponential": st.Exponential,
        "ShiftedExponential": st.ShiftedExponential,
        "Pareto": st.Pareto,
        "Empirical": st.Empirical,
    }


def _encode_field(name: str, v):
    if v is None:
        return None
    if name == "dist":
        kind = type(v).__name__
        if kind not in _dist_registry():
            raise ValueError(
                f"Scenario.dist: cannot serialize {kind} (expected one of "
                f"{sorted(_dist_registry())})"
            )
        out = {"kind": kind}
        out.update(
            {k: (list(x) if isinstance(x, tuple) else x) for k, x in dataclasses.asdict(v).items()}
        )
        return out
    if name in ("churn", "churn_schedule", "replan", "speculation", "retry", "faults", "slo"):
        return {k: (list(x) if isinstance(x, tuple) else x) for k, x in dataclasses.asdict(v).items()}
    if name == "scheduler":
        if isinstance(v, Scheduler):
            if v.name not in SCHEDULERS:
                raise ValueError(
                    f"Scenario.scheduler: cannot serialize unregistered scheduler {v.name!r}"
                )
            return v.name
        return v
    if name == "job_plans":
        return [None if p is None else dataclasses.asdict(p) for p in v]
    if name == "speeds":
        return list(v)
    return v


def _decode_field(name: str, v):
    if v is None:
        return None
    if name == "dist":
        d = dict(v)
        kind = d.pop("kind", None)
        reg = _dist_registry()
        if kind not in reg:
            raise ValueError(f"Scenario.dist: unknown distribution kind {kind!r}")
        if "samples" in d:
            d["samples"] = tuple(d["samples"])
        return reg[kind](**d)
    if name == "churn":
        return ChurnProcess(**v)
    if name == "churn_schedule":
        return ChurnSchedule(
            times=tuple(v["times"]), wids=tuple(v["wids"]), ups=tuple(v["ups"])
        )
    if name == "replan":
        from .epoch_scan import ReplanConfig

        return ReplanConfig(**v)
    if name == "speculation":
        return Speculation(**v)
    if name == "retry":
        return Retry(**v)
    if name == "faults":
        return FaultPlan(**v)
    if name == "slo":
        return SLO(**v)
    if name == "job_plans":
        return tuple(None if p is None else JobPlan(**p) for p in v)
    if name == "speeds":
        return tuple(v)
    return v


def resolve_scenario(
    scenario: Optional[Scenario],
    explicit: dict,
    *,
    where: str,
    stacklevel: int = 3,
) -> Scenario:
    """The legacy-kwarg compat shim behind the four public entry points.

    ``explicit`` maps scenario-owned kwarg names to their call values, with
    :data:`UNSET` marking 'not passed'.  With ``scenario=`` given, loose
    scenario kwargs are rejected (one spec, one source of truth); without
    it, a Scenario is rebuilt from the loose kwargs and a
    ``DeprecationWarning`` points callers at the new API.
    """
    passed = {k: v for k, v in explicit.items() if v is not UNSET}
    if scenario is not None:
        if passed:
            raise ValueError(
                f"{where}: got scenario= and loose scenario kwargs "
                f"({', '.join(sorted(passed))}); fold them into the Scenario"
            )
        return scenario
    if passed:
        warnings.warn(
            f"{where}: passing {', '.join(sorted(passed))} as loose keyword "
            "arguments is deprecated; pass scenario=Scenario(...) instead",
            DeprecationWarning,
            stacklevel=stacklevel,
        )
    return Scenario(**passed)


def scenario_from_kwargs(**kwargs) -> Scenario:
    """Build a Scenario from loose kwargs without the deprecation warning
    (internal plumbing for modules that still speak the kwarg dialect).
    """
    return Scenario(**{k: v for k, v in kwargs.items() if v is not UNSET})
