"""Vectorized jax backend for the cluster engine's operational semantics.

The event-driven :class:`~repro.cluster.master.ClusterEngine` scores one
(B, r) candidate per Python event loop, which caps
:meth:`~repro.core.planner.RedundancyPlanner.plan_cluster` at a handful of
candidates.  This module replays the engine's semantics -- gang dispatch,
earliest-cover completion (``T = max_b min_r``, the shared
:func:`~repro.core.simulator.gang_cover_times` kernel), replica-cancellation
accounting, and whole-cluster FIFO multi-job queueing -- as jax array
programs, fully batched over (candidate B, replication r, Monte-Carlo rep),
so one device call scores an entire frontier.

Two entry points:

* :func:`frontier_job_times` -- i.i.d. single-job compute times for every
  candidate at once (the ``plan_cluster``/``plan_sweep`` workhorse).  The
  frontier is padded to a ``(B_pad, r_pad)`` grid and masked per candidate,
  mirroring ``simulate_balanced`` exactly in the unmasked case.
* :func:`simulate_fifo` -- multi-job FIFO gang queueing via a ``lax.scan``
  over job arrivals, vmapped over Monte-Carlo reps: job k+1 starts once the
  cluster is free (at job k's cover time with cancellation, at its last
  replica otherwise), reproducing the engine's response times and its
  worker-seconds / cancelled-seconds-saved accounting.

Not covered here: fail/join churn, replica rescue, heterogeneous speeds, and
online replanning live in :mod:`repro.cluster.epoch_scan`, which replays
those dynamics as a bounded event-step loop (one rescue / dispatch /
churn-boundary action per trip-count-static step, sharing this module's
masked ``max_b min_r`` cover semantics per batch) -- ``plan_cluster`` routes
to it automatically when any dynamic knob is set, so no scenario falls back
to the Python event engine anymore.

Memory note: the padded frontier grid materializes
``(C, n_reps, B_pad, r_pad)`` draws.  For a full divisor frontier of N
workers that is ``C * n_reps * N**2`` floats -- fine for the N <= a few
hundred regimes the planner sweeps; pass ``rep_chunk`` to
:func:`frontier_job_times` to bound device memory for larger grids (chunked
calls derive draws per rep via ``fold_in``, so any chunking of the same
``rep_chunk``-enabled call is bit-identical).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from ..core.service_time import ServiceTime
from ..core.simulator import gang_cover_times

__all__ = [
    "frontier_job_times",
    "simulate_fifo",
    "FifoReport",
    "STREAM_HIST_EDGES",
    "STREAM_HIST_BINS",
]


def _candidate_grid(n_workers: int, candidates) -> tuple[np.ndarray, np.ndarray]:
    bs = np.asarray(list(candidates), dtype=np.int32)
    if bs.size == 0:
        raise ValueError("need at least one candidate B")
    if (bs < 1).any() or (bs > n_workers).any():
        raise ValueError(f"candidates must lie in [1, {n_workers}], got {bs.tolist()}")
    rs = (n_workers // bs).astype(np.int32)
    return bs, rs


@jax.jit
def _frontier_cover(flat: jax.Array, idx: jax.Array, bs: jax.Array, rs: jax.Array, scales):
    """(C, S, n_slots) flat draws -> (C, S) job times, masked per candidate.

    ``idx`` maps each candidate's padded ``(B_pad, r_pad)`` grid slot to a
    flat replica draw (row-major ``i * r + j``), so the expensive RNG work is
    one draw per *replica actually dispatched* rather than per padded slot.
    """

    def one(f, ix, b, r, s):
        return gang_cover_times(f[:, ix] * s, b, r)

    return jax.vmap(one)(flat, idx, bs, rs, scales)


@jax.jit
def _frontier_cover_pallas(flat, idx, bs, rs, scales):
    """Pallas-fused sibling of :func:`_frontier_cover` (TPU opt-in only:
    ``repro.kernels.cover`` records that interpret mode loses on CPU)."""
    from ..kernels.cover import masked_cover_times

    def one(f, ix, b, r, s):
        return masked_cover_times(f[:, ix] * s, b, r, interpret=False)

    return jax.vmap(one)(flat, idx, bs, rs, scales)


def _cover_impl():
    from ..kernels.cover import pallas_cover_wins

    return _frontier_cover_pallas if pallas_cover_wins() else _frontier_cover


def frontier_job_times(
    dist: ServiceTime,
    n_workers: int,
    candidates,
    n_reps: int,
    *,
    seed: int = 0,
    size_dependent: bool = True,
    n_tasks: int | None = None,
    rep_chunk: int | None = None,
) -> np.ndarray:
    """i.i.d. job compute times for every candidate B in one device call.

    Returns an ``(len(candidates), n_reps)`` array; row i is statistically
    identical to ``sample_job_times(dist, n_workers, candidates[i], n_reps)``
    on the Python engine (single job, no churn, homogeneous workers) and to
    ``simulate_balanced`` -- the equivalence the test suite enforces at
    3 sigma.

    ``rep_chunk`` bounds device memory to ``C * rep_chunk * n_slots`` draws
    per call.  Chunked calls derive rep ``k``'s draws from
    ``fold_in(key(seed), k)`` -- a pure function of the rep index -- so
    ``rep_chunk=N`` in one chunk and the same budget split across ``k``
    chunks are bit-identical on device (a different, equally valid stream
    from the default single-draw path, which is kept for baseline/golden
    stability).
    """
    bs, rs = _candidate_grid(n_workers, candidates)
    if n_tasks is None:
        n_tasks = n_workers
    b_pad, r_pad = int(bs.max()), int(rs.max())
    n_slots = int((bs * rs).max())  # replicas a gang actually dispatches
    idx = np.zeros((len(bs), b_pad, r_pad), dtype=np.int32)
    for c, (b, r) in enumerate(zip(bs, rs)):
        idx[c, :b, :r] = np.arange(b * r, dtype=np.int32).reshape(b, r)
    scales = (n_tasks / bs) if size_dependent else np.ones(len(bs))
    idx_j, bs_j, rs_j = jnp.asarray(idx), jnp.asarray(bs), jnp.asarray(rs)
    cover = _cover_impl()
    if rep_chunk is None:
        key = jax.random.key(seed)
        flat = dist.sample(key, (len(bs), int(n_reps), n_slots))
        t = cover(flat, idx_j, bs_j, rs_j, jnp.asarray(scales, flat.dtype))
        return np.asarray(t)
    if rep_chunk < 1:
        raise ValueError("rep_chunk must be >= 1")
    base = jax.random.key(seed)
    parts = []
    for lo in range(0, int(n_reps), int(rep_chunk)):
        hi = min(lo + int(rep_chunk), int(n_reps))
        keys = jax.vmap(lambda k: jax.random.fold_in(base, k))(
            jnp.arange(lo, hi, dtype=jnp.uint32)
        )
        flat = jax.vmap(lambda k: dist.sample(k, (len(bs), n_slots)))(keys)
        flat = jnp.moveaxis(flat, 0, 1)  # (C, chunk, n_slots)
        t = cover(flat, idx_j, bs_j, rs_j, jnp.asarray(scales, flat.dtype))
        parts.append(np.asarray(t))
    return np.concatenate(parts, axis=1)


# --------------------------------------------------------------------------
# multi-job FIFO gang queueing: lax.scan over arrivals, vmap over MC reps
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FifoReport:
    """Batched outcome of :func:`simulate_fifo` (axis 0 = Monte-Carlo rep).

    Mirrors the fields of :class:`~repro.cluster.master.EngineReport` that
    the vectorized semantics cover, with the engine's accounting invariant
    ``worker_seconds(cancel on) + saved == worker_seconds(cancel off)``.
    """

    arrivals: np.ndarray  # (n_jobs,)
    starts: np.ndarray  # (n_reps, n_jobs)
    finishes: np.ndarray  # (n_reps, n_jobs)
    worker_seconds: np.ndarray  # (n_reps,)
    cancelled_seconds_saved: np.ndarray  # (n_reps,)

    @property
    def compute_times(self) -> np.ndarray:
        """Per-(rep, job) compute time: finish minus start."""
        return self.finishes - self.starts

    @property
    def response_times(self) -> np.ndarray:
        """Per-(rep, job) response time: finish minus arrival."""
        return self.finishes - self.arrivals[None, :]

    @property
    def queue_waits(self) -> np.ndarray:
        """Per-(rep, job) queueing delay: start minus arrival."""
        return self.starts - self.arrivals[None, :]


@functools.partial(jax.jit, static_argnames=("cancel_redundant",))
def _fifo_scan(
    draws: jax.Array,
    gaps: jax.Array,
    neg_first_arrival: jax.Array,
    b: jax.Array,
    r: jax.Array,
    cancel_redundant: bool,
):
    """draws: (S, J, B_pad, r_pad) scaled durations -> per-rep FIFO schedule.

    The scan carries *slack* -- the cluster's free time relative to the next
    job's arrival (``gaps`` are inter-arrival deltas, the initial carry is
    ``-arrivals[0]``) -- so only queue-backlog-sized magnitudes flow through
    float32; the caller rebuilds absolute start times in float64.  Carrying
    absolute times would quantize queue waits by the (arbitrarily large)
    arrival timestamps.
    """
    b_pad, r_pad = draws.shape[-2], draws.shape[-1]
    valid = (jnp.arange(b_pad)[:, None] < b) & (jnp.arange(r_pad)[None, :] < r)
    masked = jnp.where(valid, draws, jnp.inf)  # (S, J, B, R)
    batch_min = jnp.min(masked, axis=-1)  # (S, J, B)
    t_job = gang_cover_times(draws, b, r)  # (S, J) cover time
    # the cluster frees at the cover time when losers are cancelled, at the
    # last replica otherwise (stragglers delay the next gang dispatch)
    last_replica = jnp.max(jnp.where(valid, draws, -jnp.inf), axis=(-2, -1))
    hold = t_job if cancel_redundant else last_replica
    # busy worker-seconds: with cancellation each of a batch's r replicas
    # burns exactly the batch min (winner's duration); without it every
    # replica runs to completion
    busy_off = jnp.sum(jnp.where(valid, draws, 0.0), axis=(-2, -1))  # (S, J)
    busy_on = r * jnp.sum(jnp.where(jnp.arange(b_pad) < b, batch_min, 0.0), axis=-1)
    busy = busy_on if cancel_redundant else busy_off
    saved = busy_off - busy

    def step(slack, inp):
        h, gap = inp
        wait = jnp.maximum(slack, 0.0)
        return wait + h - gap, wait

    _, waits = jax.lax.scan(
        jax.vmap(step),
        jnp.full(draws.shape[0], neg_first_arrival, dtype=draws.dtype),
        (hold.T, jnp.broadcast_to(gaps[:, None], hold.T.shape)),
    )
    # waits: (S, J) after transpose
    return waits.T, t_job, jnp.sum(busy, axis=-1), jnp.sum(saved, axis=-1)


def simulate_fifo(
    dist: ServiceTime,
    n_workers: int,
    n_batches: int,
    arrivals,
    n_reps: int,
    *,
    seed: int = 0,
    cancel_redundant: bool = False,
    size_dependent: bool = True,
    n_tasks: int | None = None,
    scheduler: str = "fifo_gang",
    workers_per_job: int | None = None,
    job_plans=None,
    dtype: str = "float32",
) -> FifoReport:
    """Whole-cluster FIFO gang queueing, batched over Monte-Carlo reps.

    ``arrivals`` is the (sorted) job arrival-time vector shared by all reps;
    each rep redraws every replica duration.  Statistically identical to
    ``ClusterEngine(n_workers, n_batches=..., cancel_redundant=...)`` on the
    same workload (no churn, homogeneous speeds).

    ``scheduler`` / ``workers_per_job`` / ``job_plans`` extend the replay to
    space sharing (jobs on disjoint worker subsets under per-job
    heterogeneous plans).  The arrival-scan kernel below is inherently
    single-gang -- its carry is one scalar of cluster slack -- so any space
    knob delegates to the epoch scan's space lane
    (:func:`repro.cluster.epoch_scan.simulate_epochs` on a churn-free
    timeline), which shares this module's masked-cover semantics per batch.
    Precision caveat on that delegated path: the scan lanes carry *absolute*
    times in ``dtype`` (default float32), unlike this kernel's float64
    arrival arithmetic -- arrival offsets large enough to quantize (beyond
    ~8.4e6 s, where a float32 ulp approaches one second) now raise a
    ``ValueError`` naming the fix rather than returning silently corrupted
    statistics; pass ``dtype="float64"`` (requires jax x64) exactly as with
    :func:`~repro.cluster.epoch_scan.simulate_epochs`.
    """
    from .scheduler import is_space

    if is_space(scheduler, workers_per_job, job_plans):
        from .epoch_scan import simulate_epochs
        from .scenario import scenario_from_kwargs

        rep = simulate_epochs(
            dist,
            n_workers,
            n_batches,
            arrivals,
            n_reps,
            seed=seed,
            scenario=scenario_from_kwargs(
                cancel_redundant=cancel_redundant,
                size_dependent=size_dependent,
                n_tasks=n_tasks,
                scheduler=scheduler,
                workers_per_job=workers_per_job,
                job_plans=job_plans,
                dtype=dtype,
            ),
        )
        return FifoReport(
            arrivals=rep.arrivals,
            starts=rep.starts,
            finishes=rep.finishes,
            worker_seconds=rep.worker_seconds,
            cancelled_seconds_saved=rep.cancelled_seconds_saved,
        )
    if dtype != "float32":
        raise ValueError(
            "dtype applies to the space-sharing delegation only; the gang kernel "
            "already rebuilds absolute times in float64"
        )
    arrivals = np.asarray(arrivals, dtype=np.float64)
    if arrivals.ndim != 1 or arrivals.size == 0:
        raise ValueError("arrivals must be a non-empty 1-D array")
    if (np.diff(arrivals) < 0).any():
        raise ValueError("arrivals must be sorted (FIFO order)")
    bs, rs = _candidate_grid(n_workers, [n_batches])
    b, r = int(bs[0]), int(rs[0])
    if n_tasks is None:
        n_tasks = n_workers
    scale = (n_tasks / b) if size_dependent else 1.0
    key = jax.random.key(seed)
    draws = dist.sample(key, (int(n_reps), arrivals.size, b, r)) * scale
    gaps = np.append(np.diff(arrivals), 0.0)  # last gap is never read
    waits, t_job, busy, saved = _fifo_scan(
        draws,
        jnp.asarray(gaps, dtype=draws.dtype),
        jnp.asarray(-arrivals[0], dtype=draws.dtype),
        jnp.asarray(b),
        jnp.asarray(r),
        bool(cancel_redundant),
    )
    # absolute times rebuilt in float64: the device scan only ever sees
    # queue-backlog-sized magnitudes (waits, holds, inter-arrival gaps)
    starts = arrivals[None, :] + np.asarray(waits, dtype=np.float64)
    return FifoReport(
        arrivals=arrivals,
        starts=starts,
        finishes=starts + np.asarray(t_job, dtype=np.float64),
        worker_seconds=np.asarray(busy, dtype=np.float64),
        cancelled_seconds_saved=np.asarray(saved, dtype=np.float64),
    )


# --------------------------------------------------------------------------
# trace-scale streaming: multi-gang pools with an accumulator carry
# --------------------------------------------------------------------------
#
# The arrival scan above materializes (n_reps, n_jobs) outputs and one gang.
# The slab kernel below is its trace-scale sibling: G symmetric gang *pools*
# (fifo_gang is the G=1 special case), an accumulator dict carried through
# the scan instead of per-job ys, and a fixed padded slab width so a
# 10k-job stream compiles once and runs in slabs.  The carry holds pool
# free-times *relative to the current arrival* (shifted by each
# inter-arrival gap), so no absolute timestamp ever enters the device --
# the float32 arrival-span hazard of the absolute-time lanes does not
# exist here.

# Log-spaced response-time histogram edges shared by the on-device fold and
# the host reference fold: 1 ms .. 1e6 s at ~18% per-bin resolution.  Bin i
# holds responses in [edges[i-1], edges[i]); integer counts make the sketch
# exactly order-independent, so streaming equals materialized bit for bit.
STREAM_HIST_EDGES = np.logspace(-3.0, 6.0, 128)
STREAM_HIST_BINS = STREAM_HIST_EDGES.size + 1

# Committed accuracy of histogram quantiles: the estimator returns the upper
# edge of the bin holding the k-th order statistic, so for any response in
# [edges[0], edges[-1]] the true quantile r satisfies
# ``r <= estimate <= r * (1 + STREAM_QUANTILE_RTOL)`` -- one log bin, never
# an underestimate.  Tests pin this bound against the materialized f64 fold.
STREAM_QUANTILE_RTOL = float(STREAM_HIST_EDGES[1] / STREAM_HIST_EDGES[0]) - 1.0


def stream_acc_init(n_reps: int, dtype, n_classes: int = 0) -> dict:
    """Zeroed accumulator carry for :func:`_stream_slab` (one row per rep).

    With ``n_classes > 0`` the carry also holds per-class response state
    (count / response sum / histogram), keyed by the job's source-trace
    index -- the on-device substrate of per-class SLO quantiles.
    """
    z = jnp.zeros(n_reps, dtype=dtype)
    acc = {
        "count": jnp.zeros(n_reps, dtype=jnp.int32),
        "resp_sum": z,
        "resp_sq": z,
        "resp_min": jnp.full(n_reps, jnp.inf, dtype=dtype),
        "resp_max": jnp.full(n_reps, -jnp.inf, dtype=dtype),
        "comp_sum": z,
        "busy_sum": z,
        "saved_sum": z,
        "hist": jnp.zeros((n_reps, STREAM_HIST_BINS), dtype=jnp.int32),
    }
    if n_classes:
        acc["class_count"] = jnp.zeros((n_reps, n_classes), dtype=jnp.int32)
        acc["class_resp_sum"] = jnp.zeros((n_reps, n_classes), dtype=dtype)
        acc["class_hist"] = jnp.zeros(
            (n_reps, n_classes, STREAM_HIST_BINS), dtype=jnp.int32
        )
    return acc


@functools.partial(
    jax.jit,
    static_argnames=(
        "b", "r", "n_gangs", "cancel_redundant", "balanced", "collect", "n_classes",
    ),
)
def _stream_slab(
    draws: jax.Array,  # (S, J, b, r) unscaled service draws
    scales: jax.Array,  # (J,) per-job batch-size scale
    gaps: jax.Array,  # (J,) inter-arrival deltas (gap[j] = a[j+1] - a[j])
    mask: jax.Array,  # (J,) bool: real job vs slab padding
    cls: jax.Array,  # (J,) int32 job-class ids (ignored when n_classes == 0)
    rel_free: jax.Array,  # (S, G) pool free-times relative to current arrival
    load: jax.Array,  # (S, G) cumulative placed load (balanced tie-break)
    acc: dict,  # accumulator carry, see stream_acc_init
    edges: jax.Array,  # histogram edges in the compute dtype
    *,
    b: int,
    r: int,
    n_gangs: int,
    cancel_redundant: bool,
    balanced: bool,
    collect: bool,
    n_classes: int = 0,
):
    """One slab of the multi-gang streaming FIFO scan.

    Each arrival is a gang of ``b`` batches x ``r`` replicas dispatched to
    the earliest-free pool (ties: lowest index for packed/fifo, least
    cumulative placed load for balanced).  The accumulators update *inside*
    the scan step -- response = wait + cover time, computed before any
    absolute time could exist -- so ``collect=False`` returns only
    O(n_reps)-sized state.  ``collect=True`` additionally returns the per-job
    arrays (waits, cover times, charged/planned/saved worker-seconds) for
    the materialized reference path; the accumulators are computed in both
    modes, which is what the bit-for-bit streaming-vs-materialized property
    asserts against.
    """
    d = draws * scales[None, :, None, None]
    batch_min = jnp.min(d, axis=-1)  # (S, J, b)
    t_job = gang_cover_times(d, jnp.asarray(b), jnp.asarray(r))  # (S, J)
    last_replica = jnp.max(d, axis=(-2, -1))
    hold = t_job if cancel_redundant else last_replica
    planned = jnp.sum(d, axis=(-2, -1))  # every replica's full duration
    busy = r * jnp.sum(batch_min, axis=-1) if cancel_redundant else planned
    saved = planned - busy
    gidx = jnp.arange(n_gangs, dtype=d.dtype)

    def step(carry, inp):
        rel_free, load, acc = carry
        t, h, w, pl, v, gap, m, c = inp  # (S,) each; gap/m/c scalar
        feas = jnp.min(rel_free, axis=1)  # (S,) earliest any pool frees
        elig = rel_free <= feas[:, None]
        key = jnp.where(elig, load if balanced else gidx[None, :], jnp.inf)
        g = jnp.argmin(key, axis=1)  # ties -> lowest pool index
        wait = jnp.maximum(feas, 0.0)
        resp = wait + t
        sel = jnp.arange(n_gangs)[None, :] == g[:, None]
        upd = jnp.where(sel, (wait + h)[:, None], rel_free)
        rel_free = jnp.where(m, upd, rel_free) - jnp.where(m, gap, 0.0)
        load = load + jnp.where(m & sel, pl[:, None], 0.0)
        one = m.astype(jnp.int32)
        bins = jnp.searchsorted(edges, resp, side="right")
        # max(sq, 0) is a value-identity on a square, but it pins the multiply
        # as a standalone IEEE op: XLA's CPU loop codegen otherwise contracts
        # mul+accumulate into an FMA (even across optimization_barrier once
        # the select is hoisted), breaking bit-equality with the fma-free
        # host reference fold
        resp2 = jnp.maximum(resp * resp, 0.0)
        rows = jnp.arange(resp.shape[0])
        nxt = {
            "count": acc["count"] + one,
            "resp_sum": acc["resp_sum"] + jnp.where(m, resp, 0.0),
            "resp_sq": acc["resp_sq"] + jnp.where(m, resp2, 0.0),
            "resp_min": jnp.minimum(acc["resp_min"], jnp.where(m, resp, jnp.inf)),
            "resp_max": jnp.maximum(acc["resp_max"], jnp.where(m, resp, -jnp.inf)),
            "comp_sum": acc["comp_sum"] + jnp.where(m, t, 0.0),
            "busy_sum": acc["busy_sum"] + jnp.where(m, w, 0.0),
            "saved_sum": acc["saved_sum"] + jnp.where(m, v, 0.0),
            "hist": acc["hist"].at[rows, bins].add(one),
        }
        if n_classes:
            nxt["class_count"] = acc["class_count"].at[rows, c].add(one)
            nxt["class_resp_sum"] = acc["class_resp_sum"].at[rows, c].add(
                jnp.where(m, resp, 0.0)
            )
            nxt["class_hist"] = acc["class_hist"].at[rows, c, bins].add(one)
        return (rel_free, load, nxt), (wait if collect else 0.0)

    (rel_free, load, acc), waits = jax.lax.scan(
        step,
        (rel_free, load, acc),
        (t_job.T, hold.T, busy.T, planned.T, saved.T, gaps, mask, cls),
    )
    if collect:
        return rel_free, load, acc, (waits.T, t_job, busy, planned, saved)
    return rel_free, load, acc, None
