"""Trace-scale streaming simulation: a cluster-day through the jax path.

:func:`~repro.cluster.vectorized.simulate_fifo` materializes ``(n_reps,
n_jobs, B, r)`` draws and ``(n_reps, n_jobs)`` outputs -- fine for tens of
jobs, hopeless for the thousands a real cluster trace holds.  This module
is the trace-scale path:

* the workload is a :class:`~repro.core.traces.TraceStream` -- thousands of
  arrivals, each resampling one source trace job's empirical service-time
  distribution (per-job ECDF inverse draws, seeded and versioned);
* service draws are generated **per slab** on the host (a prefix-stable
  consumption of each rep's generator, so any slab partition yields the
  same numbers bit for bit) and fed to one fixed-width compiled kernel --
  a 10k-job stream compiles once and runs in arrival-ordered slabs;
* statistics stream: the scan carries running count / moment sums /
  min-max / a log-spaced response histogram per rep
  (:data:`~repro.cluster.vectorized.STREAM_HIST_EDGES`) instead of
  returning per-job outputs, so peak memory is O(slab), independent of the
  stream length.

The queueing model is **symmetric gang pools**: ``fifo_gang`` is the exact
single-pool FIFO gang regime of ``simulate_fifo``; ``packed`` / ``balanced``
split the cluster into ``n_workers // workers_per_job`` disjoint pools and
dispatch each arrival to the earliest-free pool (ties: lowest index /
least cumulative placed load) -- the statically-partitioned limit of the
space-sharing schedulers.  The engine-exact space lane (workers freed
individually, first-fit over the whole cluster) remains
:func:`~repro.cluster.epoch_scan.simulate_epochs`, which offers the same
``outputs="stream"`` aggregation for moderate job counts.

``outputs="full"`` runs the identical kernel while *also* collecting the
per-job arrays, and :func:`fold_stream_stats` re-derives the accumulators
from them with the same fold, in the same job order, in the same dtype --
the property suite asserts streaming == materialized **bit for bit** (f64).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from ..core.traces import TraceStream
from .scenario import Scenario
from .vectorized import (
    STREAM_HIST_BINS,
    STREAM_HIST_EDGES,
    STREAM_QUANTILE_RTOL,
    _stream_slab,
    stream_acc_init,
)

__all__ = [
    "StreamStats",
    "StreamFullReport",
    "simulate_stream",
    "fold_stream_stats",
    "epoch_stream_stats",
    "STREAM_QUANTILE_RTOL",
]

_ACC_FIELDS = (
    "count",
    "resp_sum",
    "resp_sq",
    "resp_min",
    "resp_max",
    "comp_sum",
    "busy_sum",
    "saved_sum",
    "hist",
)

_CLASS_FIELDS = ("class_count", "class_resp_sum", "class_hist")


@dataclasses.dataclass(frozen=True)
class StreamStats:
    """Streaming aggregates of one run (axis 0 = Monte-Carlo rep).

    Everything a trace-scale sweep reports, in O(n_reps) memory: response
    moments and extremes, total compute / charged worker-seconds /
    cancellation savings, and a fixed log-spaced response histogram
    (:data:`~repro.cluster.vectorized.STREAM_HIST_EDGES`) standing in for
    the full response vector.  Integer counts and a fixed fold order make
    every field an exact function of the run, not an approximation -- only
    :meth:`quantile` is resolution-limited (one histogram bin, ~18%).
    """

    count: np.ndarray  # (S,) completed jobs
    resp_sum: np.ndarray  # (S,) sum of response times
    resp_sq: np.ndarray  # (S,) sum of squared response times
    resp_min: np.ndarray  # (S,)
    resp_max: np.ndarray  # (S,)
    comp_sum: np.ndarray  # (S,) sum of compute (cover) times
    busy_sum: np.ndarray  # (S,) charged worker-seconds
    saved_sum: np.ndarray  # (S,) cancelled-seconds-saved
    hist: np.ndarray  # (S, STREAM_HIST_BINS) response histogram
    class_count: np.ndarray | None = None  # (S, C) per-class completed jobs
    class_resp_sum: np.ndarray | None = None  # (S, C) per-class response sums
    class_hist: np.ndarray | None = None  # (S, C, STREAM_HIST_BINS)
    classes: tuple | None = None  # (C,) class names (source trace jobs)

    @classmethod
    def from_device(cls, acc: dict, classes: tuple | None = None) -> "StreamStats":
        """Pull a device accumulator dict back to host-side numpy arrays."""
        kw = {k: np.asarray(acc[k]) for k in _ACC_FIELDS}
        if "class_hist" in acc:
            kw.update({k: np.asarray(acc[k]) for k in _CLASS_FIELDS})
            kw["classes"] = classes
        return cls(**kw)

    @property
    def mean_response(self) -> np.ndarray:
        """Per-rep mean response time, ``resp_sum / count``."""
        return self.resp_sum / np.maximum(self.count, 1)

    @property
    def std_response(self) -> np.ndarray:
        """Per-rep response-time standard deviation from the moment sums."""
        m = self.mean_response
        var = self.resp_sq / np.maximum(self.count, 1) - m * m
        return np.sqrt(np.maximum(var, 0.0))

    @property
    def worker_seconds(self) -> np.ndarray:
        """Per-rep charged worker-seconds (alias of ``busy_sum``)."""
        return self.busy_sum

    @property
    def cancelled_seconds_saved(self) -> np.ndarray:
        """Per-rep worker-seconds saved by replica cancellation."""
        return self.saved_sum

    def _class_index(self, job_class) -> int:
        if isinstance(job_class, str):
            if self.classes is None or job_class not in self.classes:
                raise KeyError(
                    f"unknown job class {job_class!r}; classes={self.classes}"
                )
            return self.classes.index(job_class)
        return int(job_class)

    def quantile(self, q: float, job_class=None) -> float:
        """Pooled response quantile from the histogram (bin upper edge).

        The estimator returns the *upper* edge of the bin holding the k-th
        order statistic (``k = ceil(q * total)``), so for responses inside
        the grid it never understates the true quantile and overstates it by
        at most one log bin:
        ``r <= quantile(q) <= r * (1 + STREAM_QUANTILE_RTOL)`` (~18%).  The
        exact extremes are ``resp_min`` / ``resp_max``.

        ``job_class`` (a source-trace name or index) restricts the quantile
        to that class's responses; it needs the per-class state carried by
        :func:`simulate_stream` and overflow past the last edge returns
        ``inf`` (conservative: a would-be-feasible SLO is never reported
        feasible because of histogram saturation).
        """
        if job_class is None:
            h = self.hist.sum(axis=0)
        else:
            if self.class_hist is None:
                raise ValueError("per-class quantile needs per-class stream state")
            h = self.class_hist[:, self._class_index(job_class), :].sum(axis=0)
        total = int(h.sum())
        if total == 0:
            return float("nan")
        k = int(np.ceil(float(q) * total))
        idx = int(np.searchsorted(np.cumsum(h), max(k, 1)))
        if idx >= STREAM_HIST_EDGES.size:
            if job_class is None:
                return float(self.resp_max.max())
            return float("inf")  # saturated class histogram: no upper bound
        return float(STREAM_HIST_EDGES[idx])

    def summary(self) -> dict:
        """Pooled scalar summary (the bench/golden payload)."""
        total = int(self.count.sum())
        return {
            "n_jobs_done": total,
            "mean_response": float(self.resp_sum.sum() / max(total, 1)),
            "p50_response": self.quantile(0.50),
            "p95_response": self.quantile(0.95),
            "p99_response": self.quantile(0.99),
            "max_response": float(self.resp_max.max()),
            "mean_compute": float(self.comp_sum.sum() / max(total, 1)),
            "worker_seconds": float(self.busy_sum.sum() / self.count.shape[0]),
            "cancelled_seconds_saved": float(
                self.saved_sum.sum() / self.count.shape[0]
            ),
        }

    def class_summary(self) -> dict:
        """Per-class scalar summary: ``{name: {n_jobs_done, mean, p50..p999}}``.

        Needs the per-class state :func:`simulate_stream` carries; raises if
        the stats were produced without it (e.g. the epoch-scan stream lane).
        """
        if self.class_hist is None:
            raise ValueError("class_summary needs per-class stream state")
        names = self.classes or tuple(range(self.class_hist.shape[1]))
        out = {}
        for i, name in enumerate(names):
            total = int(self.class_count[:, i].sum())
            out[name] = {
                "n_jobs_done": total,
                "mean_response": float(
                    self.class_resp_sum[:, i].sum() / max(total, 1)
                ),
                "p50_response": self.quantile(0.50, job_class=i),
                "p95_response": self.quantile(0.95, job_class=i),
                "p99_response": self.quantile(0.99, job_class=i),
                "p999_response": self.quantile(0.999, job_class=i),
            }
        return out


@dataclasses.dataclass(frozen=True)
class StreamFullReport:
    """``outputs="full"`` result: the materialized reference of the stream.

    Per-job arrays stay in the kernel's compute dtype (what the device
    actually produced); absolute times are rebuilt on the host in float64
    from the relative waits, exactly like :func:`simulate_fifo`.  ``stats``
    carries the accumulators the very same kernel run computed -- the
    streaming side of the bit-for-bit property.
    """

    arrivals: np.ndarray  # (J,) float64
    waits: np.ndarray  # (S, J) queue waits, compute dtype
    t_job: np.ndarray  # (S, J) cover times, compute dtype
    busy_j: np.ndarray  # (S, J) charged worker-seconds per job
    planned_j: np.ndarray  # (S, J) placed (full-duration) worker-seconds
    saved_j: np.ndarray  # (S, J) cancellation savings per job
    stats: StreamStats

    @property
    def starts(self) -> np.ndarray:
        """Per-(rep, job) start time: arrival plus queue wait."""
        return self.arrivals[None, :] + np.asarray(self.waits, dtype=np.float64)

    @property
    def finishes(self) -> np.ndarray:
        """Per-(rep, job) finish time: start plus job time."""
        return self.starts + np.asarray(self.t_job, dtype=np.float64)

    @property
    def response_times(self) -> np.ndarray:
        """Per-(rep, job) response time: finish minus arrival."""
        return self.finishes - self.arrivals[None, :]


def fold_stream_stats(
    waits, t_job, busy_j, planned_j, saved_j, class_ids=None, classes=None
) -> StreamStats:
    """The host reference fold: materialized arrays -> StreamStats.

    Replays exactly the accumulator updates the device scan performs -- same
    job order (arrival order), same operations, same dtype, same histogram
    edges -- as a sequential numpy loop.  This is what "streaming equals
    materialized bit for bit" means operationally: this fold of the full
    outputs must equal the device's carried accumulators exactly.

    ``class_ids`` (a (J,) int array, with ``classes`` the tuple of class
    names) additionally folds the per-class state the device carries when
    classes are threaded through :func:`simulate_stream`.
    """
    waits = np.asarray(waits)
    t_job = np.asarray(t_job)
    dt = waits.dtype
    s, n = waits.shape
    edges = STREAM_HIST_EDGES.astype(dt)
    count = np.zeros(s, dtype=np.int32)
    resp_sum = np.zeros(s, dtype=dt)
    resp_sq = np.zeros(s, dtype=dt)
    resp_min = np.full(s, np.inf, dtype=dt)
    resp_max = np.full(s, -np.inf, dtype=dt)
    comp_sum = np.zeros(s, dtype=dt)
    busy_sum = np.zeros(s, dtype=dt)
    saved_sum = np.zeros(s, dtype=dt)
    hist = np.zeros((s, STREAM_HIST_BINS), dtype=np.int32)
    cls = None
    class_count = class_resp_sum = class_hist = None
    if class_ids is not None:
        cls = np.asarray(class_ids, dtype=np.int64)
        n_cls = len(classes) if classes is not None else int(cls.max()) + 1
        class_count = np.zeros((s, n_cls), dtype=np.int32)
        class_resp_sum = np.zeros((s, n_cls), dtype=dt)
        class_hist = np.zeros((s, n_cls, STREAM_HIST_BINS), dtype=np.int32)
    rows = np.arange(s)
    for j in range(n):
        resp = waits[:, j] + t_job[:, j]
        count += 1
        resp_sum += resp
        resp_sq += resp * resp
        resp_min = np.minimum(resp_min, resp)
        resp_max = np.maximum(resp_max, resp)
        comp_sum += t_job[:, j]
        busy_sum += np.asarray(busy_j)[:, j].astype(dt, copy=False)
        saved_sum += np.asarray(saved_j)[:, j].astype(dt, copy=False)
        bins = np.searchsorted(edges, resp, side="right")
        hist[rows, bins] += 1
        if cls is not None:
            class_count[rows, cls[j]] += 1
            class_resp_sum[:, cls[j]] += resp
            class_hist[rows, cls[j], bins] += 1
    return StreamStats(
        count=count,
        resp_sum=resp_sum,
        resp_sq=resp_sq,
        resp_min=resp_min,
        resp_max=resp_max,
        comp_sum=comp_sum,
        busy_sum=busy_sum,
        saved_sum=saved_sum,
        hist=hist,
        class_count=class_count,
        class_resp_sum=class_resp_sum,
        class_hist=class_hist,
        classes=tuple(classes) if classes is not None else None,
    )


def epoch_stream_stats(report) -> StreamStats:
    """Host reference fold for the epoch scan's ``outputs="stream"`` mode.

    Folds an ``outputs="full"`` :class:`~repro.cluster.epoch_scan.EpochReport`
    into the same accumulators the on-device wrapper
    (``epoch_scan._wrap_stream_lane``) carries -- same arrival order, same
    masking of never-finished jobs, same operations.  On float64 lanes the
    result equals ``simulate_epochs(..., outputs="stream").stats`` bit for
    bit on shared seeds (float32 lanes fold on device in f32, so only the
    f64 contract is exact).  ``busy_sum`` / ``saved_sum`` mirror the
    report's per-rep worker-seconds totals, as in the device report.
    """
    arr = np.asarray(report.arrivals, dtype=np.float64)
    st = np.asarray(report.starts, dtype=np.float64)
    fin = np.asarray(report.finishes, dtype=np.float64)
    s, n = fin.shape
    edges = STREAM_HIST_EDGES
    count = np.zeros(s, dtype=np.int32)
    resp_sum = np.zeros(s)
    resp_sq = np.zeros(s)
    resp_min = np.full(s, np.inf)
    resp_max = np.full(s, -np.inf)
    comp_sum = np.zeros(s)
    hist = np.zeros((s, STREAM_HIST_BINS), dtype=np.int32)
    rows = np.arange(s)
    for j in range(n):
        f = fin[:, j]
        m = np.isfinite(f)
        resp = f - arr[j]
        comp = f - st[:, j]
        count += m
        resp_sum += np.where(m, resp, 0.0)
        resp_sq += np.where(m, resp * resp, 0.0)
        resp_min = np.minimum(resp_min, np.where(m, resp, np.inf))
        resp_max = np.maximum(resp_max, np.where(m, resp, -np.inf))
        comp_sum += np.where(m, comp, 0.0)
        hist[rows, np.searchsorted(edges, resp, side="right")] += m
    return StreamStats(
        count=count,
        resp_sum=resp_sum,
        resp_sq=resp_sq,
        resp_min=resp_min,
        resp_max=resp_max,
        comp_sum=comp_sum,
        busy_sum=np.asarray(report.worker_seconds, dtype=np.float64),
        saved_sum=np.asarray(report.cancelled_seconds_saved, dtype=np.float64),
        hist=hist,
    )


def _resolve_pools(sc: Scenario, n_workers: int, n_batches: int):
    """Map the scenario's scheduler knobs onto (n_gangs, pool_width, b, r)."""
    name = sc.scheduler_name
    if name == "fifo_gang":
        if sc.workers_per_job is not None and int(sc.workers_per_job) != int(n_workers):
            raise ValueError(
                "simulate_stream: workers_per_job applies to the packed/"
                "balanced pool schedulers; fifo_gang uses the whole cluster"
            )
        pool, gangs = int(n_workers), 1
    else:
        if sc.workers_per_job is None:
            raise ValueError(
                f"simulate_stream: scheduler={name!r} needs workers_per_job "
                "(the pool width) set on the Scenario"
            )
        pool = int(sc.workers_per_job)
        gangs = int(n_workers) // pool
        if gangs < 1:
            raise ValueError(
                f"simulate_stream: workers_per_job={pool} exceeds "
                f"n_workers={n_workers}"
            )
    b = int(n_batches)
    if not (1 <= b <= pool):
        raise ValueError(
            f"simulate_stream: n_batches must lie in [1, {pool}] "
            f"(the pool width), got {b}"
        )
    return gangs, pool, b, pool // b


def simulate_stream(
    stream: TraceStream,
    n_workers: int,
    n_batches: int,
    n_reps: int,
    *,
    scenario: Scenario | None = None,
    slab: int | None = 1024,
):
    """Run a :class:`~repro.core.traces.TraceStream` through the jax path.

    Returns :class:`StreamStats` (``scenario.outputs == "stream"``, the
    default here) or :class:`StreamFullReport` (``outputs="full"``).  Knobs
    honoured from the scenario: ``cancel_redundant``, ``size_dependent``,
    ``scheduler`` (+ ``workers_per_job``), ``dtype``, ``outputs``.  Dynamic
    knobs (churn, speeds, replan, speculation, per-job plans) belong to
    :func:`~repro.cluster.epoch_scan.simulate_epochs` -- this path raises
    on them rather than silently ignoring the physics.

    ``slab`` bounds host+device memory: draws, padding, and outputs are all
    O(slab) per step, and the kernel compiles once for the fixed slab width.
    Draw streams are owned by the :class:`TraceStream` seed (one generator
    per rep, consumed slab-wise in arrival order), so the slab size never
    changes a single drawn number.
    """
    if not isinstance(stream, TraceStream):
        raise TypeError(f"simulate_stream expects a TraceStream, got {type(stream)}")
    sc = scenario if scenario is not None else Scenario(outputs="stream")
    sc.validate(n_workers, backend="jax")
    for field in ("churn", "churn_schedule", "speeds", "replan", "speculation", "job_plans"):
        if getattr(sc, field) is not None:
            raise ValueError(
                f"simulate_stream: Scenario.{field} is not supported on the "
                "streaming gang-pool path; use simulate_epochs for dynamic "
                "scenarios"
            )
    if sc.dtype == "float64":
        import jax

        if not jax.config.jax_enable_x64:
            raise ValueError(
                "dtype='float64' needs jax x64 enabled "
                "(jax.config.update('jax_enable_x64', True))"
            )
    dt = jnp.float64 if sc.dtype == "float64" else jnp.float32
    gangs, _pool, b, r = _resolve_pools(sc, n_workers, n_batches)
    balanced = sc.scheduler_name == "balanced"
    n_reps = int(n_reps)
    n = stream.n_jobs
    j_pad = n if slab is None else min(int(slab), n)
    collect = sc.outputs == "full"

    rngs = [stream.make_rng(rep) for rep in range(n_reps)]
    # host-side f64 precompute, O(n): gaps and per-job batch-size scales
    diffs = np.append(np.diff(stream.arrivals), 0.0)
    scales_all = (
        stream.n_tasks.astype(np.float64) / b
        if sc.size_dependent
        else np.ones(n, dtype=np.float64)
    )
    edges = jnp.asarray(STREAM_HIST_EDGES, dtype=dt)
    rel_free = jnp.full((n_reps, gangs), -float(stream.arrivals[0]), dtype=dt)
    load = jnp.zeros((n_reps, gangs), dtype=dt)
    classes = tuple(src.name for src in stream.sources)
    n_classes = len(classes)
    acc = stream_acc_init(n_reps, dt, n_classes)
    full_parts: list = []
    for lo, hi in stream.slabs(j_pad):
        k = hi - lo
        draws = np.stack(
            [stream.sample_slab(rngs[s], lo, hi, b * r) for s in range(n_reps)]
        ).reshape(n_reps, k, b, r)
        if k < j_pad:  # final partial slab: pad with masked-out unit jobs
            draws = np.concatenate(
                [draws, np.ones((n_reps, j_pad - k, b, r))], axis=1
            )
        pad = (0, j_pad - k)
        rel_free, load, acc, outs = _stream_slab(
            jnp.asarray(draws, dtype=dt),
            jnp.asarray(np.pad(scales_all[lo:hi], pad, constant_values=1.0), dtype=dt),
            jnp.asarray(np.pad(diffs[lo:hi], pad), dtype=dt),
            jnp.asarray(np.arange(j_pad) < k),
            jnp.asarray(np.pad(stream.job_ids[lo:hi], pad), dtype=jnp.int32),
            rel_free,
            load,
            acc,
            edges,
            b=b,
            r=r,
            n_gangs=gangs,
            cancel_redundant=bool(sc.cancel_redundant),
            balanced=balanced,
            collect=collect,
            n_classes=n_classes,
        )
        if collect:
            full_parts.append(tuple(np.asarray(o)[:, :k] for o in outs))
    stats = StreamStats.from_device(acc, classes=classes)
    if not collect:
        return stats
    waits, t_job, busy_j, planned_j, saved_j = (
        np.concatenate(parts, axis=1) for parts in zip(*full_parts)
    )
    return StreamFullReport(
        arrivals=stream.arrivals,
        waits=waits,
        t_job=t_job,
        busy_j=busy_j,
        planned_j=planned_j,
        saved_j=saved_j,
        stats=stats,
    )
