"""Space-sharing schedulers: pluggable job-to-worker placement policies.

The engine's original (and still default) regime is the whole-cluster FIFO
gang: one job at a time, dispatched once every alive worker is free.  That is
the one scheduling regime in which redundancy levels *cannot* differ across
concurrent jobs -- the paper's balanced-assignment results are per job, and
the interesting trade-offs (Aktas & Soljanin, arXiv:1906.05345; the
task-assignment companion, arXiv:1808.02838) appear exactly when jobs share
the cluster under different (B, r) plans.

A :class:`Scheduler` decides which queued jobs start and on which workers.
Space-sharing policies place each job on a *disjoint* worker subset of
``workers_per_job`` workers (requested per job via :class:`JobPlan`, or
engine-wide), so jobs with heterogeneous redundancy plans run concurrently:

* ``fifo_gang``  -- the legacy whole-cluster gang (no space sharing); kept
  bit-compatible with the pre-scheduler engine on the same seeds.
* ``packed``     -- first-fit: scan the FIFO queue, place every job that
  fits on the lowest-wid free workers.  Packs the cluster tightly and lets
  later narrow jobs overtake a wide head-of-line job that does not fit yet.
* ``balanced``   -- same first-fit admission, but workers are chosen by
  least cumulative *speed-weighted* assigned load (ties by wid), spreading
  load across the pool instead of hammering the low wids.

"Least loaded" is deliberately measured as cumulative assigned duration
divided by the worker's speed (accrued when a replica is placed, not when
it finishes): the jax epoch scan replays placement decisions out of the
event loop, and an accrue-at-assignment metric is exactly reproducible
there, where accrue-at-release would depend on commit order within an
epoch.  The speed weighting makes heterogeneous clusters behave: a slow
worker accrues more load per placed replica than a fast one, so the policy
steers work toward fast workers instead of piling it on slow ones (with
homogeneous speeds the metric reduces to plain assigned wall-clock).

Per-job plans: a :class:`JobPlan` attached to a
:class:`~repro.cluster.master.Job` overrides any of (worker request, B,
cancellation mode) for that job; unset fields inherit the engine-wide
defaults.  The engine clamps requests to the alive-worker count and B to the
granted allocation, mirroring the gang engine's clamping.

Churn-aware reassignment: allocations shrink when an allocated worker fails.
A batch that lost its last replica queues a rescue; rescues are served first
from free workers still allocated to the job, and otherwise *regrant* a free
unallocated worker into the allocation -- so a job whose allocation fell
below its replica need recovers as capacity frees, without stealing busy
workers from its neighbours.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Union

__all__ = [
    "JobPlan",
    "Scheduler",
    "FifoGangScheduler",
    "PackedScheduler",
    "BalancedScheduler",
    "SCHEDULERS",
    "make_scheduler",
]


@dataclasses.dataclass(frozen=True)
class JobPlan:
    """Per-job overrides of the engine-wide plan (None = inherit).

    ``workers`` is the size of the disjoint worker subset the job requests
    under a space-sharing scheduler; ``n_batches`` and ``cancel_redundant``
    are the job's own redundancy level and cancellation mode -- the per-job
    heterogeneous (B, r) plans the gang regime cannot express.
    """

    workers: Optional[int] = None
    n_batches: Optional[int] = None
    cancel_redundant: Optional[bool] = None

    def __post_init__(self):
        if self.workers is not None and self.workers < 1:
            raise ValueError(f"JobPlan.workers must be >= 1, got {self.workers}")
        if self.n_batches is not None and self.n_batches < 1:
            raise ValueError(f"JobPlan.n_batches must be >= 1, got {self.n_batches}")


class Scheduler:
    """Placement policy: which free workers a job (or rescue) gets.

    ``space_sharing`` distinguishes the two dispatch regimes the engine
    implements: ``False`` runs the legacy whole-cluster FIFO gang loop,
    ``True`` runs first-fit queue scans onto disjoint per-job allocations.
    ``select`` returns ``k`` workers from ``free`` in *placement order* --
    the engine assigns batch ``i % B`` to the i-th returned worker, so the
    order is part of the policy's semantics (and is mirrored by the jax
    space lane).
    """

    name: str = "base"
    space_sharing: bool = True

    def select(self, k: int, free: Sequence, load: Sequence[float]) -> List:
        """Pick ``k`` of the ``free`` workers for the next job."""
        raise NotImplementedError


class FifoGangScheduler(Scheduler):
    """Whole-cluster FIFO gang: the legacy (default) regime."""

    name = "fifo_gang"
    space_sharing = False

    def select(self, k: int, free: Sequence, load: Sequence[float]) -> List:
        """Pick ``k`` of the ``free`` workers for the next job."""
        return list(free[:k])


class PackedScheduler(Scheduler):
    """First-fit packing onto the lowest-wid free workers."""

    name = "packed"
    space_sharing = True

    def select(self, k: int, free: Sequence, load: Sequence[float]) -> List:
        """Pick ``k`` of the ``free`` workers for the next job."""
        return list(free[:k])  # free lists are wid-ordered


class BalancedScheduler(Scheduler):
    """Least-loaded placement: least speed-weighted assigned load, ties by wid."""

    name = "balanced"
    space_sharing = True

    def select(self, k: int, free: Sequence, load: Sequence[float]) -> List:
        """Pick ``k`` of the ``free`` workers for the next job."""
        return sorted(free, key=lambda w: (load[w.wid], w.wid))[:k]


SCHEDULERS = {
    "fifo_gang": FifoGangScheduler,
    "packed": PackedScheduler,
    "balanced": BalancedScheduler,
}


def make_scheduler(spec: Union[str, Scheduler]) -> Scheduler:
    """Resolve a policy name (or pass a Scheduler instance through)."""
    if isinstance(spec, Scheduler):
        return spec
    try:
        return SCHEDULERS[spec]()
    except KeyError:
        raise ValueError(
            f"unknown scheduler {spec!r} (expected one of {sorted(SCHEDULERS)})"
        ) from None


def is_space(scheduler, workers_per_job, job_plans) -> bool:
    """Whether any space-sharing knob is set (the shared routing predicate).

    The jax backends use it to pick the space lane over the legacy
    single-gang kernels; keeping it here, next to the policy registry, means
    a future knob changes the routing in exactly one place.  Note
    ``fifo_gang`` *with* per-job plans still counts as space routing -- the
    gang regime then runs on the space lane so per-job B/cancellation apply.
    """
    return (
        scheduler not in (None, "fifo_gang")
        or workers_per_job is not None
        or job_plans is not None
    )
