"""Event-driven master-worker cluster engine (the paper's system, executed).

Where ``repro.core`` evaluates redundancy plans (closed forms + vectorized
Monte-Carlo), ``repro.cluster`` *runs* them: a seeded discrete-event engine
with a master (job queue, batch dispatch, earliest-cover completion,
replica cancellation), workers (service-time draws, heterogeneous speeds,
fail/join churn), and an online control loop that refits the service-time
model from observed task times and replans (B, r) mid-stream.

The planner -> engine -> replanner loop:

  1. ``RedundancyPlanner`` picks (B, r) -- closed form, bootstrap, or
     ``plan_cluster`` (scored by this engine);
  2. ``ClusterEngine`` executes jobs under that plan, under dynamics the
     closed forms cannot express (queueing, churn, cancellation);
  3. ``OnlineReplanner`` watches completed-task service times and re-picks
     (B, r) when the fitted distribution drifts.

Public surface:
  * events     -- event heap, simulation clock, named RNG streams
  * workers    -- Worker/WorkerPool, ChurnProcess, service draws
  * master     -- Job/JobRecord/EngineReport, ClusterEngine, workload helpers
  * scheduler  -- space-sharing placement policies (fifo_gang | packed |
    balanced) and per-job ``JobPlan`` overrides: concurrent jobs on
    disjoint worker subsets, each with its own (B, r, cancellation) plan
  * control    -- OnlineReplanner (sliding-window refit + replan)
  * vectorized -- batched jax replay of the static engine semantics:
    whole-frontier candidate scoring (``frontier_job_times``) and FIFO
    queueing via ``lax.scan`` (``simulate_fifo``), the fast path behind
    ``plan_cluster(backend="jax")`` / ``plan_sweep``
  * epoch_scan  -- batched jax replay of the *dynamic* semantics: fail/join
    churn with replica rescue, heterogeneous speeds, and windowed online
    replanning as a bounded event-step loop (``simulate_epochs``,
    ``frontier_job_times_dynamic``; bucketed compiles, ``rep_chunk``
    memory chunking, ``devices`` sharding, float64 lanes) -- the path
    ``plan_cluster`` takes when any dynamic knob is set, so
    ``backend="jax"`` never falls back to the Python engine for
    churned/heterogeneous scenarios
  * stream     -- trace-scale streaming: a
    :class:`~repro.core.traces.TraceStream` (thousands of arrivals
    resampling per-job trace ECDFs, seeded/versioned, chunked) driven
    through a fixed-slab jax kernel whose scan carries running statistics
    (count, moment sums, min/max, log-spaced response histogram) instead of
    per-job outputs -- a 10k-job cluster-day compiles once and streams in
    O(slab) memory (``simulate_stream``); ``Scenario.outputs="stream"``
    gives ``simulate_epochs`` the same aggregation, bit-identical to the
    materialized fold on float64 lanes
  * scenario   -- the one frozen, validated spec shared by every entry
    point: ``Scenario`` + ``Scenario.validate()`` replace the four
    separately-maintained copies of the dynamics-kwarg validation;
    legacy loose kwargs keep working behind a ``DeprecationWarning`` shim
  * runtime    -- the *live* system: an asyncio master serving real worker
    processes over length-prefixed JSON on localhost sockets (leases,
    heartbeats, missed-heartbeat failure detection, replica dispatch with
    cancel-on-earliest-cover), recording a trace the DES engine replays
    bit-for-bit (``replay_trace``) -- the engine as the runtime's digital
    twin.  Imported lazily (``import repro.cluster.runtime``): simulation
    users never pay for the service stack
"""
from . import control, epoch_scan, events, master, scenario, scheduler, stream, vectorized, workers
from .control import OnlineReplanner, SpeculativePolicy
from .epoch_scan import (
    EpochReport,
    EpochStreamReport,
    ReplanConfig,
    frontier_job_times_dynamic,
    simulate_epochs,
)
from .scenario import SLO, FaultPlan, Retry, Scenario, Speculation
from .scheduler import JobPlan, Scheduler, make_scheduler
from .master import (
    ClusterEngine,
    EngineReport,
    Job,
    JobRecord,
    jobs_from_traces,
    sample_job_times,
)
from .stream import (
    STREAM_QUANTILE_RTOL,
    StreamFullReport,
    StreamStats,
    epoch_stream_stats,
    fold_stream_stats,
    simulate_stream,
)
from .vectorized import FifoReport, frontier_job_times, simulate_fifo
from .workers import ChurnProcess, ChurnSchedule, Worker, WorkerPool, sample_churn_schedule

__all__ = [
    "control",
    "epoch_scan",
    "events",
    "master",
    "scenario",
    "scheduler",
    "stream",
    "vectorized",
    "workers",
    "FaultPlan",
    "Retry",
    "SLO",
    "Scenario",
    "Speculation",
    "STREAM_QUANTILE_RTOL",
    "JobPlan",
    "Scheduler",
    "make_scheduler",
    "OnlineReplanner",
    "SpeculativePolicy",
    "ClusterEngine",
    "EngineReport",
    "EpochReport",
    "EpochStreamReport",
    "ReplanConfig",
    "Job",
    "JobRecord",
    "jobs_from_traces",
    "sample_job_times",
    "simulate_epochs",
    "FifoReport",
    "StreamFullReport",
    "StreamStats",
    "simulate_stream",
    "fold_stream_stats",
    "epoch_stream_stats",
    "frontier_job_times",
    "frontier_job_times_dynamic",
    "simulate_fifo",
    "ChurnProcess",
    "ChurnSchedule",
    "Worker",
    "WorkerPool",
    "sample_churn_schedule",
]
