"""Live execution runtime: the DES engine's semantics against real processes.

Everything else in :mod:`repro.cluster` *simulates* a redundancy plan; this
subpackage *executes* one.  An asyncio master (:mod:`.master`) serves real
worker processes (:mod:`.worker`) over a length-prefixed JSON protocol on
localhost sockets (:mod:`.protocol`): worker registration, task leases with
deadlines, heartbeat tracking with missed-heartbeat failure detection, and
replica dispatch under the engine's exact FIFO-gang semantics --
``RedundancyPlan``/:class:`~repro.cluster.scheduler.JobPlan` redundancy
levels, cancel-on-earliest-cover, and rescue re-dispatch when a worker dies
holding a batch's last replica.

The master records every state transition as a trace event
(:mod:`.trace`: ``join``/``submit``/``dispatch``/``finish``/``cancel``/
``fail``/``flush``/``job_done`` with timestamps and worker ids), stamped on
a binary time grid so all accounting arithmetic is exact, and
:func:`~repro.cluster.runtime.trace.replay_trace` replays the identical
event schedule through the discrete-event :class:`~repro.cluster.master.
ClusterEngine` -- the engine is the runtime's digital twin, and the
differential tests assert worker-seconds, saved-seconds, rescues, and
per-job completion records match *bit for bit*.

Scenario semantics come from the same frozen
:class:`~repro.cluster.scenario.Scenario` the simulation entry points take:
``Runtime(n_workers, scenario=Scenario(n_batches=2, cancel_redundant=True))``
executes what ``sample_job_times(scenario=...)`` predicts.

Failure is a first-class input.  A serializable
:class:`~repro.cluster.scenario.FaultPlan` on the scenario drives a
deterministic fault injector (:mod:`.chaos`): scheduled worker kills,
slowdowns, heartbeat stalls, injected payload exceptions, and seeded wire
drop/dup/delay -- every delivered fault stamped on the trace grid so the
twin replays the faulted run exactly.  A
:class:`~repro.cluster.scenario.Retry` policy turns payload failures
(``fail`` frames carrying tracebacks) into capped-exponential-backoff
retries, then abandonment.  With ``journal=``, the recorder doubles as an
fsync'd JSONL write-ahead log and :meth:`RuntimeMaster.recover` rebuilds a
crashed master from it -- queued and in-flight jobs, leases, retry timers,
accounting -- resuming with re-joined workers; crash plus recovery replay
as one exact trace (``tests/test_chaos.py``).

This subpackage is *not* imported by ``repro.cluster.__init__`` -- simulation
users never pay for the service stack; ``import repro.cluster.runtime``
explicitly.
"""

from .chaos import FaultInjector
from .master import LiveJob, LiveReport, Runtime, RuntimeMaster
from .trace import TICK, TraceRecorder, read_journal, replay_trace, trace_accounting
from .worker import spawn_worker_subprocess, spawn_worker_thread, worker_loop

__all__ = [
    "FaultInjector",
    "LiveJob",
    "LiveReport",
    "Runtime",
    "RuntimeMaster",
    "TICK",
    "TraceRecorder",
    "read_journal",
    "replay_trace",
    "trace_accounting",
    "spawn_worker_subprocess",
    "spawn_worker_thread",
    "worker_loop",
]
