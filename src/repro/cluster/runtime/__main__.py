"""``python -m repro.cluster.runtime HOST PORT`` -- run one worker process."""

import sys

from .worker import main

main(sys.argv)
