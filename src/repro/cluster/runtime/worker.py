"""Worker process: executes real task payloads, streams heartbeats, honors
cancellation.

A worker owns one socket to the master and runs one batch replica at a time.
Three payload kinds cover the behaviours the runtime tests need:

* ``sleep``  -- ``asyncio.sleep`` for the batch's total cost: a perfectly
  cancellable stand-in for I/O-bound work.
* ``numpy``  -- real matmul work in small chunks with an ``await`` between
  chunks, so cancellation lands at chunk boundaries: CPU-bound but
  cooperative.
* ``block``  -- ``time.sleep`` on the event loop thread: a *misbehaving*
  task that starves the heartbeat coroutine, which is exactly how the
  master's missed-heartbeat failure detection gets exercised.

Workers run either in-process (one thread per worker, each with its own
event loop -- cheap, coverage-friendly) via :func:`spawn_worker_thread`, or
as real subprocesses via :func:`spawn_worker_subprocess` (``python -m
repro.cluster.runtime.worker HOST PORT``) when a test needs to SIGKILL one
mid-task.
"""

from __future__ import annotations

import asyncio
import atexit
import ctypes
import os
import random
import signal
import subprocess
import sys
import threading
import time
import traceback

import numpy as np

from .protocol import read_msg, send_msg

__all__ = ["run_payload", "spawn_worker_subprocess", "spawn_worker_thread", "worker_loop"]


class PayloadError(RuntimeError):
    """A task payload failed (organically or chaos-injected)."""


async def run_payload(payload: str, costs, factor: float = 1.0) -> None:
    """Execute one batch replica's work; raises CancelledError if cancelled.

    ``factor`` scales the real execution time (the per-worker speed skew the
    master dispatches but does not model -- its straggling replicas are what
    cancel-on-earliest-cover reclaims).
    """
    if payload == "sleep":
        await asyncio.sleep(float(sum(costs)) * factor)
    elif payload == "numpy":
        # ~cost seconds of matmul per task, chunked so cancellation can land
        a = np.random.default_rng(0).standard_normal((96, 96))
        for c in costs:
            deadline = time.monotonic() + float(c) * factor
            while time.monotonic() < deadline:
                a = np.tanh(a @ a.T / 96.0)
                await asyncio.sleep(0)
    elif payload == "block":
        # deliberately hostile: blocks the loop, starving heartbeats
        time.sleep(float(sum(costs)) * factor)
    elif payload == "raise":
        # a broken task: burns ~30% of its nominal cost, then explodes --
        # the organic path into the fail-frame / retry machinery
        await asyncio.sleep(float(sum(costs)) * factor * 0.3)
        raise PayloadError("payload exploded (kind='raise')")
    else:
        raise ValueError(f"unknown payload kind {payload!r}")


async def _heartbeat(
    writer, wid: int, interval_s: float, state: dict, jitter_seed: int = 0
) -> None:
    """Heartbeats double as progress reports: while a replica is running,
    each beat carries its (job, batch, epoch) and the fraction of the
    nominal cost elapsed -- the partial-progress evidence the master's
    speculative policy requires before it backs a laggard up.

    Each sleep is jittered +-10% (seeded per worker) so a fleet of workers
    reconnecting together -- e.g. right after master recovery -- does not
    heartbeat in lockstep and thundering-herd the master's read loops."""
    rng = random.Random((int(jitter_seed) << 20) ^ int(wid))
    try:
        while True:
            await asyncio.sleep(interval_s * (0.9 + 0.2 * rng.random()))
            msg = {"type": "hb", "wid": wid}
            cur = state.get("current")
            if cur is not None:
                total = state["total"]
                elapsed = time.monotonic() - state["t0"]
                frac = 1.0 if total <= 0.0 else min(elapsed / total, 1.0)
                msg.update(job=cur["job"], batch=cur["batch"], epoch=cur["epoch"], frac=frac)
            await send_msg(writer, msg)
    except (ConnectionError, RuntimeError):
        return  # the master tore the socket down; the read loop will exit too


async def worker_loop(host: str, port: int) -> None:
    """Connect, register, then serve task/cancel messages until shutdown."""
    reader, writer = await asyncio.open_connection(host, port)
    await send_msg(writer, {"type": "register", "pid": os.getpid()})
    welcome = await read_msg(reader)
    if welcome is None or welcome.get("type") != "welcome":
        writer.close()
        return
    wid = int(welcome["wid"])
    state: dict = {"current": None, "t0": 0.0, "total": 0.0}
    hb = asyncio.ensure_future(
        _heartbeat(
            writer,
            wid,
            float(welcome["heartbeat_s"]),
            state,
            int(welcome.get("hb_seed", 0)),
        )
    )
    current: dict | None = None
    task: asyncio.Task | None = None

    def _task_factor(msg: dict) -> float:
        # per-worker skew the master dispatches plus any chaos-injected
        # slowdown riding on the task frame
        return (1.0 + wid * float(msg.get("skew", 0.0))) * float(msg.get("chaos_factor", 1.0))

    async def execute(msg: dict) -> None:
        try:
            factor = _task_factor(msg)
            if msg.get("chaos_raise"):
                # injected mid-payload failure: burn part of the nominal cost,
                # then die exactly like a broken payload would
                await asyncio.sleep(float(sum(msg["costs"])) * factor * 0.5)
                raise PayloadError("chaos: injected payload failure")
            await run_payload(msg["payload"], msg["costs"], factor)
            await send_msg(
                writer,
                {
                    "type": "finish",
                    "wid": wid,
                    "job": msg["job"],
                    "batch": msg["batch"],
                    "epoch": msg["epoch"],
                },
            )
        except asyncio.CancelledError:
            raise
        except Exception:
            # a broken payload is a first-class outcome, not something to
            # swallow: report it with the traceback so the master can retry
            # (or abandon) and the failure surfaces in LiveReport
            try:
                await send_msg(
                    writer,
                    {
                        "type": "fail",
                        "wid": wid,
                        "job": msg["job"],
                        "batch": msg["batch"],
                        "epoch": msg["epoch"],
                        "error": traceback.format_exc(limit=20),
                    },
                )
            except Exception:
                return  # torn socket: nothing to report to; the lease reaps it
        finally:
            if state.get("current") is msg:
                state["current"] = None

    try:
        while True:
            msg = await read_msg(reader)
            if msg is None or msg["type"] == "shutdown":
                break
            if msg["type"] == "task":
                if (
                    task is not None
                    and not task.done()
                    and current is not None
                    and (current["job"], current["batch"], current["epoch"])
                    == (msg["job"], msg["batch"], msg["epoch"])
                ):
                    continue  # duplicated dispatch frame (chaos): already running
                current = msg
                state["current"] = msg
                state["t0"] = time.monotonic()
                state["total"] = float(sum(msg["costs"])) * _task_factor(msg)
                task = asyncio.ensure_future(execute(msg))
            elif msg["type"] == "cancel":
                if (
                    task is not None
                    and current is not None
                    and (current["job"], current["batch"], current["epoch"])
                    == (msg["job"], msg["batch"], msg["epoch"])
                ):
                    task.cancel()
                    state["current"] = None
    finally:
        hb.cancel()
        if task is not None:
            task.cancel()
        writer.close()


def spawn_worker_thread(host: str, port: int) -> threading.Thread:
    """One in-process worker on its own thread + event loop.

    A separate loop per worker matters: a ``block`` payload then stalls only
    its own worker (exactly like a wedged remote process) instead of the
    master's loop.
    """
    t = threading.Thread(
        target=lambda: asyncio.run(worker_loop(host, port)),
        name=f"repro-worker-{port}",
        daemon=True,
    )
    t.start()
    return t


# children spawned by this process, reaped at interpreter exit if the normal
# shutdown path never ran (the cross-platform fallback behind PDEATHSIG)
_children: list = []
_atexit_registered = False

PR_SET_PDEATHSIG = 1  # linux/prctl.h


def _pdeathsig_preexec() -> None:  # pragma: no cover - runs in the child
    # die with the parent: if the master process is SIGKILLed (no atexit
    # runs there), the kernel delivers SIGKILL to this child.  prctl clears
    # the deathsig across setuid execve, not across fork/exec here.
    try:
        ctypes.CDLL("libc.so.6", use_errno=True).prctl(PR_SET_PDEATHSIG, signal.SIGKILL)
    except OSError:
        pass  # non-glibc platform: the atexit fallback still covers clean exits


def _kill_orphans() -> None:
    for proc in _children:
        if proc.poll() is None:
            try:
                proc.kill()
            except OSError:  # pragma: no cover - already gone
                pass


def spawn_worker_subprocess(host: str, port: int) -> subprocess.Popen:
    """A real worker process -- killable mid-task with ``proc.kill()``.

    Child lifetime is tied to the spawning process: on Linux the child sets
    ``PR_SET_PDEATHSIG`` so the kernel SIGKILLs it the instant its parent
    dies (even via SIGKILL), and an ``atexit`` hook kills any survivors on
    ordinary interpreter exit -- chaos runs that crash the master must not
    leak worker processes.

    Note worker ids are assigned in *registration* order, which need not be
    spawn order: to kill a specific wid, look up its registered pid on the
    master (``master.workers[wid].pid``) rather than indexing the Popens.
    """
    global _atexit_registered
    env = os.environ.copy()
    # make repro importable in the child even when it is not installed
    # (e.g. pytest's `pythonpath` ini only patches the parent's sys.path)
    here = os.path.abspath(__file__)
    pkg_root = os.path.dirname(os.path.dirname(os.path.dirname(os.path.dirname(here))))
    env["PYTHONPATH"] = pkg_root + os.pathsep + env.get("PYTHONPATH", "")
    preexec = _pdeathsig_preexec if sys.platform.startswith("linux") else None
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.cluster.runtime", host, str(port)],
        env=env,
        preexec_fn=preexec,
    )
    _children.append(proc)
    if not _atexit_registered:
        atexit.register(_kill_orphans)
        _atexit_registered = True
    return proc


def main(argv) -> None:
    """CLI entry point: ``python -m repro.cluster.runtime HOST PORT``."""
    if len(argv) != 3:
        raise SystemExit("usage: python -m repro.cluster.runtime HOST PORT")
    asyncio.run(worker_loop(argv[1], int(argv[2])))


if __name__ == "__main__":  # pragma: no cover - exercised as a subprocess
    main(sys.argv)
