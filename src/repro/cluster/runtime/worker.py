"""Worker process: executes real task payloads, streams heartbeats, honors
cancellation.

A worker owns one socket to the master and runs one batch replica at a time.
Three payload kinds cover the behaviours the runtime tests need:

* ``sleep``  -- ``asyncio.sleep`` for the batch's total cost: a perfectly
  cancellable stand-in for I/O-bound work.
* ``numpy``  -- real matmul work in small chunks with an ``await`` between
  chunks, so cancellation lands at chunk boundaries: CPU-bound but
  cooperative.
* ``block``  -- ``time.sleep`` on the event loop thread: a *misbehaving*
  task that starves the heartbeat coroutine, which is exactly how the
  master's missed-heartbeat failure detection gets exercised.

Workers run either in-process (one thread per worker, each with its own
event loop -- cheap, coverage-friendly) via :func:`spawn_worker_thread`, or
as real subprocesses via :func:`spawn_worker_subprocess` (``python -m
repro.cluster.runtime.worker HOST PORT``) when a test needs to SIGKILL one
mid-task.
"""

from __future__ import annotations

import asyncio
import os
import subprocess
import sys
import threading
import time

import numpy as np

from .protocol import read_msg, send_msg

__all__ = ["run_payload", "spawn_worker_subprocess", "spawn_worker_thread", "worker_loop"]


async def run_payload(payload: str, costs, factor: float = 1.0) -> None:
    """Execute one batch replica's work; raises CancelledError if cancelled.

    ``factor`` scales the real execution time (the per-worker speed skew the
    master dispatches but does not model -- its straggling replicas are what
    cancel-on-earliest-cover reclaims).
    """
    if payload == "sleep":
        await asyncio.sleep(float(sum(costs)) * factor)
    elif payload == "numpy":
        # ~cost seconds of matmul per task, chunked so cancellation can land
        a = np.random.default_rng(0).standard_normal((96, 96))
        for c in costs:
            deadline = time.monotonic() + float(c) * factor
            while time.monotonic() < deadline:
                a = np.tanh(a @ a.T / 96.0)
                await asyncio.sleep(0)
    elif payload == "block":
        # deliberately hostile: blocks the loop, starving heartbeats
        time.sleep(float(sum(costs)) * factor)
    else:
        raise ValueError(f"unknown payload kind {payload!r}")


async def _heartbeat(writer, wid: int, interval_s: float, state: dict) -> None:
    """Heartbeats double as progress reports: while a replica is running,
    each beat carries its (job, batch, epoch) and the fraction of the
    nominal cost elapsed -- the partial-progress evidence the master's
    speculative policy requires before it backs a laggard up."""
    try:
        while True:
            await asyncio.sleep(interval_s)
            msg = {"type": "hb", "wid": wid}
            cur = state.get("current")
            if cur is not None:
                total = state["total"]
                elapsed = time.monotonic() - state["t0"]
                frac = 1.0 if total <= 0.0 else min(elapsed / total, 1.0)
                msg.update(job=cur["job"], batch=cur["batch"], epoch=cur["epoch"], frac=frac)
            await send_msg(writer, msg)
    except (ConnectionError, RuntimeError):
        return  # the master tore the socket down; the read loop will exit too


async def worker_loop(host: str, port: int) -> None:
    """Connect, register, then serve task/cancel messages until shutdown."""
    reader, writer = await asyncio.open_connection(host, port)
    await send_msg(writer, {"type": "register", "pid": os.getpid()})
    welcome = await read_msg(reader)
    if welcome is None or welcome.get("type") != "welcome":
        writer.close()
        return
    wid = int(welcome["wid"])
    state: dict = {"current": None, "t0": 0.0, "total": 0.0}
    hb = asyncio.ensure_future(_heartbeat(writer, wid, float(welcome["heartbeat_s"]), state))
    current: dict | None = None
    task: asyncio.Task | None = None

    async def execute(msg: dict) -> None:
        try:
            factor = 1.0 + wid * float(msg.get("skew", 0.0))
            await run_payload(msg["payload"], msg["costs"], factor)
            await send_msg(
                writer,
                {
                    "type": "finish",
                    "wid": wid,
                    "job": msg["job"],
                    "batch": msg["batch"],
                    "epoch": msg["epoch"],
                },
            )
        except asyncio.CancelledError:
            raise
        except Exception:
            return  # broken payload or torn socket: no finish; the lease reaps it
        finally:
            if state.get("current") is msg:
                state["current"] = None

    try:
        while True:
            msg = await read_msg(reader)
            if msg is None or msg["type"] == "shutdown":
                break
            if msg["type"] == "task":
                current = msg
                factor = 1.0 + wid * float(msg.get("skew", 0.0))
                state["current"] = msg
                state["t0"] = time.monotonic()
                state["total"] = float(sum(msg["costs"])) * factor
                task = asyncio.ensure_future(execute(msg))
            elif msg["type"] == "cancel":
                if (
                    task is not None
                    and current is not None
                    and (current["job"], current["batch"], current["epoch"])
                    == (msg["job"], msg["batch"], msg["epoch"])
                ):
                    task.cancel()
                    state["current"] = None
    finally:
        hb.cancel()
        if task is not None:
            task.cancel()
        writer.close()


def spawn_worker_thread(host: str, port: int) -> threading.Thread:
    """One in-process worker on its own thread + event loop.

    A separate loop per worker matters: a ``block`` payload then stalls only
    its own worker (exactly like a wedged remote process) instead of the
    master's loop.
    """
    t = threading.Thread(
        target=lambda: asyncio.run(worker_loop(host, port)),
        name=f"repro-worker-{port}",
        daemon=True,
    )
    t.start()
    return t


def spawn_worker_subprocess(host: str, port: int) -> subprocess.Popen:
    """A real worker process -- killable mid-task with ``proc.kill()``.

    Note worker ids are assigned in *registration* order, which need not be
    spawn order: to kill a specific wid, look up its registered pid on the
    master (``master.workers[wid].pid``) rather than indexing the Popens.
    """
    env = os.environ.copy()
    # make repro importable in the child even when it is not installed
    # (e.g. pytest's `pythonpath` ini only patches the parent's sys.path)
    here = os.path.abspath(__file__)
    pkg_root = os.path.dirname(os.path.dirname(os.path.dirname(os.path.dirname(here))))
    env["PYTHONPATH"] = pkg_root + os.pathsep + env.get("PYTHONPATH", "")
    return subprocess.Popen(
        [sys.executable, "-m", "repro.cluster.runtime", host, str(port)],
        env=env,
    )


def main(argv) -> None:
    if len(argv) != 3:
        raise SystemExit("usage: python -m repro.cluster.runtime HOST PORT")
    asyncio.run(worker_loop(argv[1], int(argv[2])))


if __name__ == "__main__":  # pragma: no cover - exercised as a subprocess
    main(sys.argv)
