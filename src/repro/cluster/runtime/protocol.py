"""Length-prefixed JSON framing for the master <-> worker localhost sockets.

Every message is one JSON object encoded UTF-8 and prefixed with a 4-byte
big-endian length.  JSON keeps the wire debuggable (``tcpdump``/``nc`` show
readable frames) and the payloads are tiny control messages -- task
dispatches, heartbeats, cancellations -- so framing overhead is irrelevant.

Message vocabulary (the only shapes either side sends):

========== ======================================================= =========
type       fields                                                  direction
========== ======================================================= =========
register   pid                                                     w -> m
welcome    wid, heartbeat_s [, hb_seed -- heartbeat-jitter seed]   m -> w
hb         wid [, job, batch, epoch, frac -- progress when busy]   w -> m
task       job, batch, epoch, payload, costs, lease_s              m -> w
           [, chaos_factor, chaos_raise -- injected slowdown /
           mid-payload exception (chaos harness)]
finish     wid, job, batch, epoch                                  w -> m
fail       wid, job, batch, epoch, error -- the payload raised;    w -> m
           ``error`` carries the traceback text
cancel     job, batch, epoch                                       m -> w
shutdown   --                                                      m -> w
========== ======================================================= =========

The master's chaos layer (:mod:`repro.cluster.runtime.chaos`) injects wire
faults *around* this framing -- dropping, duplicating, or delaying whole
frames at the master's send/receive boundary -- so the framing itself stays
byte-exact; a dropped frame is simply never processed / never written.
"""

from __future__ import annotations

import asyncio
import json
import struct

__all__ = ["MAX_FRAME", "ProtocolError", "read_msg", "send_msg", "send_nowait"]

_HEADER = struct.Struct(">I")
MAX_FRAME = 1 << 20  # 1 MiB: orders of magnitude above any control message


class ProtocolError(RuntimeError):
    """A frame violated the length-prefixed JSON protocol."""


def _encode(obj: dict) -> bytes:
    data = json.dumps(obj, separators=(",", ":")).encode("utf-8")
    if len(data) > MAX_FRAME:
        raise ProtocolError(f"frame of {len(data)} bytes exceeds MAX_FRAME")
    return _HEADER.pack(len(data)) + data


def send_nowait(writer: asyncio.StreamWriter, obj: dict) -> None:
    """Queue one frame on the transport without awaiting the drain.

    The master sends from inside event handlers whose ordering *is* the
    recorded semantics; buffering synchronously keeps send order identical
    to decision order (messages are tiny, so the kernel buffer absorbs them).
    """
    writer.write(_encode(obj))


async def send_msg(writer: asyncio.StreamWriter, obj: dict) -> None:
    """Send one frame and drain (the polite worker-side variant)."""
    writer.write(_encode(obj))
    await writer.drain()


async def read_msg(reader: asyncio.StreamReader) -> dict | None:
    """Read one frame; ``None`` on clean or torn connection loss."""
    try:
        head = await reader.readexactly(_HEADER.size)
    except (asyncio.IncompleteReadError, ConnectionResetError, BrokenPipeError):
        return None
    (n,) = _HEADER.unpack(head)
    if n > MAX_FRAME:
        raise ProtocolError(f"incoming frame of {n} bytes exceeds MAX_FRAME")
    try:
        data = await reader.readexactly(n)
    except (asyncio.IncompleteReadError, ConnectionResetError, BrokenPipeError):
        return None
    try:
        msg = json.loads(data)
    except json.JSONDecodeError as e:
        raise ProtocolError(f"undecodable frame: {e}") from None
    if not isinstance(msg, dict) or "type" not in msg:
        raise ProtocolError(f"frame is not a typed message: {msg!r}")
    return msg
