"""Asyncio master: leases, heartbeats, failure detection, replica dispatch.

:class:`RuntimeMaster` is the live counterpart of the discrete-event
:class:`~repro.cluster.master.ClusterEngine`, and is written decision-for-
decision against it so the engine can replay its traces exactly:

* whole-cluster FIFO gang dispatch -- the next job starts only when no job
  is active and every alive worker is free; batch ``i % B`` goes to the
  i-th free worker in wid order, B resolved with the engine's precedence
  (``Job.plan.n_batches`` > scenario ``n_batches`` > alive count, clamped);
* cancel-on-earliest-cover -- when a batch's first replica finishes, its
  outstanding siblings (in wid order) are cancelled; the reclaimed time is
  ``scheduled_end - now`` against the replica's planned duration;
* rescue -- a worker dying with a batch's last replica queues the batch for
  re-dispatch to the lowest-wid free worker;
* failure detection -- a torn connection (EOF), a missed-heartbeat window,
  or a blown task lease all declare the worker dead at one stamped instant.

Every state transition is stamped once, on the strictly-increasing binary
grid of :class:`~repro.cluster.runtime.trace.TraceRecorder`, and appended to
the trace that :func:`~repro.cluster.runtime.trace.replay_trace` feeds back
through the engine.  Handlers mutate state without awaiting (sends are
buffered synchronously), so each recorded event is atomic and the recorded
order *is* the decision order.

:class:`Runtime` is the one-call facade: spawn workers (threads or real
subprocesses), run a workload under a
:class:`~repro.cluster.scenario.Scenario`, return a :class:`LiveReport`.
"""

from __future__ import annotations

import asyncio
import dataclasses
import math
import time
from typing import Dict, List, Optional, Sequence, Set, Tuple

from ..control import SpeculativePolicy
from ..master import JobRecord
from ..scenario import UNSET, Scenario, resolve_scenario
from ..scheduler import JobPlan
from .protocol import read_msg, send_nowait
from .trace import TICK, TraceRecorder, quantize, trace_accounting
from .worker import spawn_worker_subprocess, spawn_worker_thread

__all__ = ["LiveJob", "LiveReport", "Runtime", "RuntimeMaster"]


@dataclasses.dataclass(frozen=True)
class LiveJob:
    """One live job: real task payloads instead of a service-time law.

    ``costs[i]`` is task i's nominal cost (seconds of sleep / compute);
    batch ``b`` of B executes tasks ``costs[b::B]``.  ``plan`` carries the
    same per-job :class:`~repro.cluster.scheduler.JobPlan` overrides the
    engine honours under the gang regime (``n_batches``,
    ``cancel_redundant``).  ``arrival`` is an offset in seconds from the
    run's start at which the job is submitted.
    """

    job_id: int
    costs: Tuple[float, ...]
    payload: str = "sleep"
    arrival: float = 0.0
    name: str = ""
    plan: Optional[JobPlan] = None
    # worker wid scales its real execution by (1 + wid * skew): cheap
    # stand-in for machines whose true speeds the master does not know --
    # the straggler spread that makes cancellation reclaim real time
    skew: float = 0.0

    @property
    def n_tasks(self) -> int:
        return len(self.costs)

    def batch_costs(self, batch: int, n_batches: int) -> Tuple[float, ...]:
        return tuple(self.costs[batch::n_batches])


@dataclasses.dataclass
class LiveReport:
    """Outcome of one live run: the engine-report surface plus the trace."""

    records: List[JobRecord]
    worker_seconds: float
    cancelled_seconds_saved: float
    n_worker_failures: int
    n_replicas_rescued: int
    trace: tuple
    completion_order: Tuple[int, ...]
    n_speculative: int = 0

    def accounting(self) -> dict:
        """Same key set as :meth:`~repro.cluster.master.EngineReport.accounting`."""
        return {
            "worker_seconds": float(self.worker_seconds),
            "cancelled_seconds_saved": float(self.cancelled_seconds_saved),
            "n_worker_failures": int(self.n_worker_failures),
            "n_replicas_rescued": int(self.n_replicas_rescued),
            "n_replans": 0,
            "n_speculative": int(self.n_speculative),
        }


@dataclasses.dataclass
class _LiveWorker:
    wid: int
    writer: asyncio.StreamWriter
    pid: int
    alive: bool = True
    assignment: Optional[Tuple[int, int]] = None  # (job_id, batch)
    epoch: int = 0
    busy_since: float = 0.0
    scheduled_end: float = math.inf
    last_hb: float = 0.0  # raw monotonic, detection only
    lease_deadline: float = math.inf  # raw monotonic, detection only
    # latest heartbeat-reported progress fraction for the CURRENT assignment
    # (None until the worker proves it is actually executing the replica)
    progress: Optional[float] = None

    @property
    def free(self) -> bool:
        return self.alive and self.assignment is None


@dataclasses.dataclass
class _LiveExec:
    job: LiveJob
    start: float
    n_batches: int
    replication: int
    cancel: bool
    done: Set[int] = dataclasses.field(default_factory=set)
    outstanding: Dict[int, Set[int]] = dataclasses.field(default_factory=dict)
    # completed sibling durations (the speculative policy's running median)
    # and the per-job backup budget consumed, mirroring the engine's _JobExec
    obs: List[float] = dataclasses.field(default_factory=list)
    spec_used: int = 0

    @property
    def complete(self) -> bool:
        return len(self.done) == self.n_batches


def _validate_runtime_scenario(sc: Scenario, n_workers: int) -> Scenario:
    """The runtime's slice of the one validation path.

    Shares :meth:`Scenario.validate` (python-backend rules), then rejects
    the simulation-only knobs: the live gang has real speeds and real
    churn, and space sharing / online replanning are not implemented yet.
    """
    sc.validate(n_workers=n_workers, backend="python")
    if sc.is_space:
        raise ValueError(
            "Scenario.scheduler/workers_per_job/job_plans: the live runtime "
            "runs the whole-cluster FIFO gang only (per-job plans ride on "
            "LiveJob.plan); space-sharing schedulers are simulation-only"
        )
    for knob in ("speeds", "churn", "churn_schedule", "replan"):
        if getattr(sc, knob) is not None:
            raise ValueError(
                f"Scenario.{knob}: simulation-only -- the live runtime "
                "measures real worker speeds and real failures"
            )
    return sc


class RuntimeMaster:
    """The asyncio master service.  See the module docstring for semantics.

    Lifecycle: ``await start()`` (returns the bound port), spawn workers at
    it, ``await wait_for_workers()``, ``await run(jobs)``, ``await close()``.
    """

    def __init__(
        self,
        n_workers: int,
        scenario: Optional[Scenario] = None,
        *,
        host: str = "127.0.0.1",
        port: int = 0,
        heartbeat_s: float = 0.05,
        heartbeat_timeout_s: float = 0.5,
        lease_factor: float = 8.0,
        lease_floor_s: float = 2.0,
        n_batches=UNSET,
        cancel_redundant=UNSET,
        speculation=UNSET,
    ):
        sc = resolve_scenario(
            scenario,
            {
                "n_batches": n_batches,
                "cancel_redundant": cancel_redundant,
                "speculation": speculation,
            },
            where="RuntimeMaster",
        )
        self.scenario = _validate_runtime_scenario(sc, n_workers)
        self.n_workers = int(n_workers)
        self.host = host
        self._port_req = int(port)
        self.heartbeat_s = float(heartbeat_s)
        self.heartbeat_timeout_s = float(heartbeat_timeout_s)
        self.lease_factor = float(lease_factor)
        self.lease_floor_s = float(lease_floor_s)

        self.recorder = TraceRecorder()
        # first trace event: the originating scenario + worker budget, so a
        # trace file alone is replayable (replay_trace re-reads it when the
        # caller passes neither n_workers nor scenario)
        self.recorder.record(
            "scenario",
            self.recorder.stamp(),
            n_workers=self.n_workers,
            scenario=self.scenario.to_dict(),
        )
        self.workers: List[_LiveWorker] = []
        self.queue: List[LiveJob] = []
        self.active: Dict[int, _LiveExec] = {}
        self.rescue: List[Tuple[int, int]] = []
        self.records: List[JobRecord] = []
        self.completion_order: List[int] = []
        self._arrival_stamp: Dict[int, float] = {}

        self._ws = 0.0
        self._saved = 0.0
        self._n_failures = 0
        self._n_rescued = 0
        self._n_spec = 0
        self._spec_policy = (
            SpeculativePolicy(self.scenario.speculation)
            if self.scenario.speculation is not None
            else None
        )
        self._n_jobs_expected = 0
        self._finalized = False
        self._server: Optional[asyncio.base_events.Server] = None
        self._watchdog_task: Optional[asyncio.Task] = None
        self._spec_task: Optional[asyncio.Task] = None
        self._all_joined = asyncio.Event()
        self._done = asyncio.Event()
        self._ran = False

    # -- lifecycle -----------------------------------------------------------

    async def start(self) -> int:
        self._server = await asyncio.start_server(self._handle_conn, self.host, self._port_req)
        self.port = self._server.sockets[0].getsockname()[1]
        self._watchdog_task = asyncio.ensure_future(self._watchdog())
        if self._spec_policy is not None:
            self._spec_task = asyncio.ensure_future(self._spec_loop())
        return self.port

    async def wait_for_workers(self, timeout_s: float = 30.0) -> None:
        await asyncio.wait_for(self._all_joined.wait(), timeout_s)

    async def run(self, jobs: Sequence[LiveJob], timeout_s: float = 120.0) -> LiveReport:
        """Submit ``jobs`` at their arrival offsets and run to completion."""
        if self._ran:
            raise RuntimeError("RuntimeMaster.run() is single-shot; construct a new master")
        self._ran = True
        self._n_jobs_expected = len(jobs)
        if not jobs:
            self._finalize(self.recorder.stamp())
        for job in sorted(jobs, key=lambda j: (j.arrival, j.job_id)):
            delay = job.arrival - self.recorder.elapsed()
            if delay > 0:
                await asyncio.sleep(delay)
            self._on_submit(job)
        await asyncio.wait_for(self._done.wait(), timeout_s)
        return LiveReport(
            records=sorted(self.records, key=lambda r: r.job_id),
            worker_seconds=self._ws,
            cancelled_seconds_saved=self._saved,
            n_worker_failures=self._n_failures,
            n_replicas_rescued=self._n_rescued,
            trace=self.recorder.events,
            completion_order=tuple(self.completion_order),
            n_speculative=self._n_spec,
        )

    async def close(self) -> None:
        if self._watchdog_task is not None:
            self._watchdog_task.cancel()
        if self._spec_task is not None:
            self._spec_task.cancel()
        for w in self.workers:
            try:
                send_nowait(w.writer, {"type": "shutdown"})
            except (ConnectionError, RuntimeError):
                pass
            w.writer.close()
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()

    # -- connection handling -------------------------------------------------

    async def _handle_conn(self, reader, writer) -> None:
        msg = await read_msg(reader)
        if msg is None or msg.get("type") != "register":
            writer.close()
            return
        worker = self._grant_registration(writer, int(msg.get("pid", -1)))
        if worker is None:
            writer.close()
            return
        while True:
            msg = await read_msg(reader)
            if worker.writer is not writer:
                # this connection's registration was retired by a re-join:
                # whatever the stale socket still delivers (late heartbeats,
                # its eventual EOF) must not touch the fresh registration
                writer.close()
                return
            if msg is None:
                self._fail(worker, "eof")
                return
            kind = msg["type"]
            if kind == "hb":
                worker.last_hb = time.monotonic()
                if (
                    worker.assignment is not None
                    and msg.get("job") == worker.assignment[0]
                    and msg.get("batch") == worker.assignment[1]
                    and msg.get("epoch") == worker.epoch
                ):
                    worker.progress = float(msg.get("frac", 0.0))
            elif kind == "finish":
                self._on_finish(worker, msg)

    def _grant_registration(self, writer, pid: int) -> Optional[_LiveWorker]:
        """Admit a registering connection: fresh wid, re-joined slot, or None.

        Below the worker budget, registrations fill fresh wids exactly as
        before.  At budget, a new connection may *re-join*: if some worker
        is dead, its stale registration is retired (socket closed at failure
        time, epoch already bumped so in-flight messages stay stale) and its
        wid granted to the newcomer, which becomes dispatchable immediately
        -- pending rescues first, then the gang, like any capacity gain.
        The re-join is stamped as a ``join`` event, which
        :func:`~repro.cluster.runtime.trace.replay_trace` feeds to the
        engine as an up-transition on the shared churn timeline, so the
        digital twin replays the recovery exactly.  Registrations after the
        run finalized (or with every wid alive) are refused.
        """
        if self._finalized:
            return None
        if len(self.workers) < self.n_workers:
            worker = _LiveWorker(
                wid=len(self.workers),
                writer=writer,
                pid=pid,
                last_hb=time.monotonic(),
            )
            self.workers.append(worker)
            self.recorder.record("join", self.recorder.stamp(), wid=worker.wid, pid=worker.pid)
            send_nowait(
                writer, {"type": "welcome", "wid": worker.wid, "heartbeat_s": self.heartbeat_s}
            )
            if len(self.workers) == self.n_workers:
                self._all_joined.set()
            return worker
        worker = next((w for w in self.workers if not w.alive), None)
        if worker is None:
            return None
        worker.writer = writer
        worker.pid = pid
        worker.alive = True
        worker.assignment = None
        worker.scheduled_end = math.inf
        worker.lease_deadline = math.inf
        worker.progress = None
        worker.last_hb = time.monotonic()
        now = self.recorder.stamp()
        self.recorder.record("join", now, wid=worker.wid, pid=worker.pid)
        send_nowait(
            writer, {"type": "welcome", "wid": worker.wid, "heartbeat_s": self.heartbeat_s}
        )
        self._assign_rescues(now)
        self._try_dispatch(now)
        return worker

    async def _watchdog(self) -> None:
        """Missed-heartbeat and blown-lease detection."""
        period = max(self.heartbeat_timeout_s / 4.0, 0.01)
        while True:
            await asyncio.sleep(period)
            now_m = time.monotonic()
            for w in self.workers:
                if not w.alive:
                    continue
                if now_m - w.last_hb > self.heartbeat_timeout_s:
                    self._fail(w, "heartbeat")
                elif w.assignment is not None and now_m > w.lease_deadline:
                    self._fail(w, "lease")

    # -- speculative backups (reactive replication, engine-aligned) ----------

    async def _spec_loop(self) -> None:
        """Heartbeat-epoch timer for the speculative policy: every interval,
        look for a laggard and back at most one up (one stamped launch per
        firing, the engine's rule)."""
        interval = self.scenario.speculation.interval
        while True:
            await asyncio.sleep(interval)
            if not self._finalized:
                self._spec_check()

    def _spec_check(self) -> None:
        """Launch at most ONE backup: the first lagging (job, batch) in
        sorted order, on the lowest-wid free worker -- decision-for-decision
        the engine's ``_on_spec_check``, evaluated at one grid stamp so
        :func:`~repro.cluster.runtime.trace.replay_trace` can feed the stamp
        to the engine as a scripted ``speculation_times`` epoch and re-derive
        the identical launch.

        On top of the engine's policy the live master demands *partial
        progress*: every outstanding replica of the laggard must have
        heartbeat-reported progress on its current assignment.  A replica
        that never reported is the failure detector's problem, not the
        speculator's.  The gate only suppresses a launch (no stamp, so the
        replay never checks it); it can never redirect one, which is what
        keeps the scripted replay exact.
        """
        cfg, pol = self.scenario.speculation, self._spec_policy
        now = self.recorder.stamp()
        for job_id in sorted(self.active):
            jexec = self.active[job_id]
            if jexec.spec_used >= cfg.max_backups:
                continue
            med = pol.median(jexec.obs)
            if med is None:
                continue
            for batch in sorted(jexec.outstanding):
                wids = jexec.outstanding[batch]
                if batch in jexec.done or not wids:
                    continue
                y = max(self.workers[w].busy_since for w in wids)
                if not pol.lagging(now - y, med):
                    continue
                if any(self.workers[w].progress is None for w in wids):
                    return  # laggard found but unproven: no launch this epoch
                free = self._free_workers()
                if not free:
                    return
                jexec.spec_used += 1
                self._n_spec += 1
                self._assign(free[0], jexec, batch, now, rescue=False, spec=True)
                return

    # -- plan resolution (the engine's precedence, verbatim) -----------------

    def _choose_B(self, job: LiveJob, n_avail: int) -> int:
        if job.plan is not None and job.plan.n_batches is not None:
            b = job.plan.n_batches
        elif self.scenario.n_batches is not None:
            b = self.scenario.n_batches
        else:
            b = n_avail
        return max(1, min(int(b), n_avail))

    def _job_cancel(self, job: LiveJob) -> bool:
        if job.plan is not None and job.plan.cancel_redundant is not None:
            return bool(job.plan.cancel_redundant)
        return self.scenario.cancel_redundant

    # -- event handlers (one stamp each, mirroring the engine) ---------------

    def _on_submit(self, job: LiveJob) -> None:
        now = self.recorder.stamp()
        plan = None
        if job.plan is not None:
            plan = {
                "workers": job.plan.workers,
                "n_batches": job.plan.n_batches,
                "cancel_redundant": job.plan.cancel_redundant,
            }
        self.recorder.record(
            "submit", now, job=job.job_id, n_tasks=job.n_tasks, plan=plan, name=job.name
        )
        self._arrival_stamp[job.job_id] = now
        self.queue.append(job)
        self._assign_rescues(now)
        self._try_dispatch(now)

    def _on_finish(self, worker: _LiveWorker, msg: dict) -> None:
        job_id, batch = int(msg["job"]), int(msg["batch"])
        if (
            self._finalized
            or not worker.alive
            or int(msg["epoch"]) != worker.epoch
            or worker.assignment != (job_id, batch)
        ):
            return  # stale: cancelled, superseded, or the run already ended
        now = self.recorder.stamp()
        self.recorder.record("finish", now, wid=worker.wid, job=job_id, batch=batch)
        self._release(worker, now)
        jexec = self.active.get(job_id)
        if jexec is None:
            # the job already covered; this straggler ran to completion
            self._assign_rescues(now)
            self._try_dispatch(now)
            return
        jexec.outstanding[batch].discard(worker.wid)
        if batch not in jexec.done:
            jexec.done.add(batch)
            # the batch's first completion is a sibling-duration observation
            # for the speculative policy's running median (engine-identical:
            # grid-stamped finish minus grid-stamped dispatch)
            jexec.obs.append(now - worker.busy_since)
            if jexec.cancel:
                for sib_wid in sorted(jexec.outstanding[batch]):
                    self._cancel_replica(self.workers[sib_wid], now)
                jexec.outstanding[batch].clear()
            if jexec.complete:
                self._finish_job(jexec, now)
        if not self._finalized:
            self._assign_rescues(now)
            self._try_dispatch(now)

    def _fail(self, worker: _LiveWorker, cause: str) -> None:
        if self._finalized or not worker.alive:
            return
        now = self.recorder.stamp()
        self.recorder.record("fail", now, wid=worker.wid, cause=cause)
        self._n_failures += 1
        if worker.assignment is not None:
            job_id, batch = worker.assignment
            self._ws += now - worker.busy_since
            jexec = self.active.get(job_id)
            if jexec is not None:
                jexec.outstanding[batch].discard(worker.wid)
                if batch not in jexec.done and not jexec.outstanding[batch]:
                    self.rescue.append((job_id, batch))
            worker.assignment = None
            worker.scheduled_end = math.inf
        worker.alive = False
        worker.epoch += 1
        worker.writer.close()
        self._assign_rescues(now)
        self._try_dispatch(now)

    # -- dispatch (the engine's gang loop, verbatim) -------------------------

    def _free_workers(self) -> List[_LiveWorker]:
        return [w for w in self.workers if w.free]  # wid order by construction

    def _try_dispatch(self, now: float) -> None:
        while self.queue and not self.active:
            n_alive = sum(1 for w in self.workers if w.alive)
            free = self._free_workers()
            if n_alive == 0 or len(free) < n_alive:
                return
            job = self.queue.pop(0)
            b = self._choose_B(job, n_alive)
            r = n_alive // b
            jexec = _LiveExec(
                job=job,
                start=now,
                n_batches=b,
                replication=r,
                cancel=self._job_cancel(job),
            )
            self.active[job.job_id] = jexec
            for idx, worker in enumerate(free[: b * r]):
                self._assign(worker, jexec, idx % b, now, rescue=False)

    def _assign_rescues(self, now: float) -> None:
        while self.rescue:
            free = self._free_workers()
            if not free:
                return
            job_id, batch = self.rescue.pop(0)
            jexec = self.active.get(job_id)
            if jexec is None or batch in jexec.done:
                continue
            self._assign(free[0], jexec, batch, now, rescue=True)
            self._n_rescued += 1

    def _assign(
        self,
        worker: _LiveWorker,
        jexec: _LiveExec,
        batch: int,
        now: float,
        *,
        rescue: bool,
        spec: bool = False,
    ) -> None:
        costs = jexec.job.batch_costs(batch, jexec.n_batches)
        # per-replica expectation: the master schedules with the worker's
        # speed factor (it would measure one on a real cluster), so a batch's
        # replicas get distinct scheduled ends -- the slack that cancellation
        # reclaims and that lease deadlines must respect
        planned = quantize(sum(costs) * (1.0 + worker.wid * jexec.job.skew))
        worker.assignment = (jexec.job.job_id, batch)
        worker.busy_since = now
        worker.scheduled_end = now + planned
        worker.progress = None
        worker.lease_deadline = time.monotonic() + max(
            self.lease_floor_s, planned * self.lease_factor
        )
        jexec.outstanding.setdefault(batch, set()).add(worker.wid)
        self.recorder.record(
            "dispatch",
            now,
            wid=worker.wid,
            job=jexec.job.job_id,
            batch=batch,
            planned=planned,
            rescue=rescue,
            spec=spec,
        )
        send_nowait(
            worker.writer,
            {
                "type": "task",
                "job": jexec.job.job_id,
                "batch": batch,
                "epoch": worker.epoch,
                "payload": jexec.job.payload,
                "costs": list(costs),
                "skew": jexec.job.skew,
                "lease_s": max(self.lease_floor_s, planned * self.lease_factor),
            },
        )

    # -- accounting transitions ----------------------------------------------

    def _release(self, worker: _LiveWorker, now: float) -> None:
        self._ws += now - worker.busy_since
        worker.assignment = None
        worker.scheduled_end = math.inf
        worker.lease_deadline = math.inf
        worker.progress = None

    def _cancel_replica(self, sib: _LiveWorker, now: float) -> None:
        job_id, batch = sib.assignment
        # the effective scheduled end is pushed at least one tick past 'now'
        # so reclaimed time stays positive and the replay's event for this
        # replica pops strictly after the winner's (where it is stale)
        sched_end = max(sib.scheduled_end, now + TICK)
        self._saved += sched_end - now
        self.recorder.record(
            "cancel", now, wid=sib.wid, job=job_id, batch=batch, sched_end=sched_end
        )
        send_nowait(
            sib.writer, {"type": "cancel", "job": job_id, "batch": batch, "epoch": sib.epoch}
        )
        sib.epoch += 1  # the in-flight finish (if any) is now stale
        self._release(sib, now)

    def _finish_job(self, jexec: _LiveExec, now: float) -> None:
        job = jexec.job
        self.records.append(
            JobRecord(
                job_id=job.job_id,
                name=job.name,
                # the recorded submit stamp, not the requested offset: this is
                # the arrival the engine replay sees, so records match exactly
                arrival=self._arrival_stamp[job.job_id],
                start=jexec.start,
                finish=now,
                n_batches=jexec.n_batches,
                replication=jexec.replication,
            )
        )
        self.completion_order.append(job.job_id)
        self.recorder.record(
            "job_done",
            now,
            job=job.job_id,
            start=jexec.start,
            n_batches=jexec.n_batches,
            replication=jexec.replication,
        )
        del self.active[job.job_id]
        self.rescue = [(j, b) for (j, b) in self.rescue if j != job.job_id]
        if len(self.records) == self._n_jobs_expected:
            self._finalize(now)

    def _finalize(self, now: float) -> None:
        """End of run: charge still-in-flight replicas their full planned
        duration (the engine's flush rule) and freeze the trace -- nothing
        that happens on the sockets after this instant is part of the run."""
        for worker in self.workers:
            if worker.alive and worker.assignment is not None:
                job_id, batch = worker.assignment
                self._ws += worker.scheduled_end - worker.busy_since
                self.recorder.record(
                    "flush",
                    now,
                    wid=worker.wid,
                    job=job_id,
                    batch=batch,
                    sched_end=worker.scheduled_end,
                )
                send_nowait(
                    worker.writer,
                    {"type": "cancel", "job": job_id, "batch": batch, "epoch": worker.epoch},
                )
                worker.epoch += 1
                worker.assignment = None
                worker.scheduled_end = math.inf
        self._finalized = True
        self.recorder.frozen = True
        self._done.set()


class Runtime:
    """One-call facade: spawn workers, execute a workload, return the report.

    ``spawn="thread"`` runs each worker in-process on its own thread and
    event loop (cheap, deterministic teardown); ``spawn="subprocess"`` forks
    real ``python -m repro.cluster.runtime.worker`` processes, which chaos
    tests can SIGKILL mid-task.  Either way the master talks to them over
    real localhost sockets -- the protocol path is identical.
    """

    def __init__(
        self,
        n_workers: int,
        scenario: Optional[Scenario] = None,
        *,
        spawn: str = "thread",
        heartbeat_s: float = 0.05,
        heartbeat_timeout_s: float = 0.5,
        host: str = "127.0.0.1",
        n_batches=UNSET,
        cancel_redundant=UNSET,
        speculation=UNSET,
    ):
        if spawn not in ("thread", "subprocess"):
            raise ValueError(f"spawn must be 'thread' or 'subprocess', got {spawn!r}")
        self.n_workers = int(n_workers)
        self.scenario = resolve_scenario(
            scenario,
            {
                "n_batches": n_batches,
                "cancel_redundant": cancel_redundant,
                "speculation": speculation,
            },
            where="Runtime",
        )
        self.spawn = spawn
        self.heartbeat_s = heartbeat_s
        self.heartbeat_timeout_s = heartbeat_timeout_s
        self.host = host

    def run(self, jobs: Sequence[LiveJob], timeout_s: float = 120.0) -> LiveReport:
        return asyncio.run(self.run_async(jobs, timeout_s=timeout_s))

    async def run_async(self, jobs: Sequence[LiveJob], timeout_s: float = 120.0) -> LiveReport:
        master = RuntimeMaster(
            self.n_workers,
            self.scenario,
            host=self.host,
            heartbeat_s=self.heartbeat_s,
            heartbeat_timeout_s=self.heartbeat_timeout_s,
        )
        port = await master.start()
        spawner = spawn_worker_thread if self.spawn == "thread" else spawn_worker_subprocess
        handles = [spawner(self.host, port) for _ in range(self.n_workers)]
        try:
            await master.wait_for_workers()
            report = await master.run(jobs, timeout_s=timeout_s)
        finally:
            await master.close()
            for h in handles:
                if hasattr(h, "join"):
                    h.join(timeout=5.0)
                else:
                    try:
                        h.wait(timeout=5.0)
                    except Exception:
                        h.kill()
        # sanity: the master's own counters must agree with the trace fold
        acct = trace_accounting(report.trace)
        if acct != report.accounting():  # pragma: no cover - internal invariant
            raise RuntimeError(f"trace fold disagrees with live counters: {acct}")
        return report
